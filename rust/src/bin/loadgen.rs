//! `loadgen` — open-loop load generator against the serving stack.
//!
//! Replays a seeded Poisson or bursty (ON-OFF) arrival schedule against
//! a fresh coordinator and reports goodput plus per-priority
//! p50/p99/p999 end-to-end and queue-wait latency; `--out` emits
//! `BENCH_loadgen.json`. Same flags as `repro loadgen` (one shared
//! implementation in `dnateq::loadgen::cli`).
//!
//! ```bash
//! cargo run --release --bin loadgen -- \
//!     --engine counting --pattern poisson --rate 150 --duration 2 \
//!     --seed 42 --fail-on-errors --out artifacts/reports/BENCH_loadgen.json
//! ```
//!
//! `--fail-on-errors` exits 1 when any request ends in a typed failure
//! (the CI smoke's zero-failure assertion). Force the SIMD backend via
//! `--simd scalar|avx2|avx512|auto` (or the `DNATEQ_SIMD` env var, as
//! everywhere else).

use std::collections::BTreeMap;

fn parse_flags(args: &[String]) -> BTreeMap<String, String> {
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let Some(key) = args[i].strip_prefix("--") else {
            eprintln!("unexpected positional argument `{}` (flags only)", args[i]);
            std::process::exit(2);
        };
        match args.get(i + 1) {
            Some(v) if !v.starts_with("--") => {
                flags.insert(key.to_string(), v.clone());
                i += 2;
            }
            _ => {
                // Value-less flag (e.g. --fail-on-errors).
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        }
    }
    flags
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = parse_flags(&args);
    // Install the SIMD override before any engine is constructed, same
    // as the `repro` front-end (backends bind at construction time).
    if let Some(v) = flags.get("simd") {
        let forced = dnateq::expdot::simd::parse(v).and_then(dnateq::expdot::simd::force);
        if let Err(e) = forced {
            eprintln!("loadgen error: {e}");
            std::process::exit(2);
        }
    }
    let fail_on_errors = flags.contains_key("fail-on-errors");
    match dnateq::loadgen::cli::run_from_flags(&flags) {
        Ok(report) => {
            if fail_on_errors && report.failed > 0 {
                eprintln!(
                    "loadgen FAILED: {} of {} requests ended in typed failures: {:?}",
                    report.failed, report.offered, report.failures
                );
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("loadgen error: {e:#}");
            std::process::exit(2);
        }
    }
}
