//! `bench_gate` — CI bench-regression gate for the batched serving path.
//!
//! Runs the fixed-shape counting-FC sweep (batcher `max_batch` ∈
//! {1, 8, 32}, FC 3072→256, 64 requests) end-to-end through the
//! coordinator via the typed `InferenceClient` API, emits the
//! machine-readable result JSON (timings **and** the serving failure
//! counters), and compares against a committed baseline. The gate
//! **fails** when:
//! * throughput regresses by more than `--tolerance` (default 15%) on
//!   any case;
//! * the batch-32-vs-1 speedup — the PR-1 batched hot path — drops
//!   below `--min-speedup`;
//! * **any request fails during the sweep**: engine failures, shed,
//!   rejected, cancelled, expired, or dropped-receiver sends must all
//!   be zero under this healthy fixed-shape load;
//! * on a SIMD-capable runner, the forced-SIMD kernel cases fall below
//!   `--min-simd-ratio` × the forced-scalar cases at any batch size —
//!   the explicit-SIMD counting path must never lose to its fallback.
//!   The floor is capability-aware: the runner's executable backends
//!   are probed once (emitted as `simd_capability` in the JSON), every
//!   executable non-scalar backend gets its own forced-kernel rows, the
//!   AVX-512 rows carry a raised `≥ 1.15×` floor (the replicated-
//!   histogram path must decisively beat scalar where it can run), and
//!   backends the runner cannot execute are warn-skipped, not failed;
//! * the open-loop **tail-latency SLO** regresses: a short seeded
//!   Poisson loadgen scenario on the counting backend must keep its
//!   end-to-end p99/p999 under the baseline `loadgen` ceilings ×
//!   (1 + `--tail-tolerance`), with zero typed failures;
//! * the **energy co-simulation** loses the paper's headline: the
//!   seeded `ci-energy` scenario (exp-4 vs INT8 plans through the real
//!   batcher on the identical arrival schedule) must report exp
//!   joules/request ≤ 0.5× INT8, and must not drift above the
//!   baseline's recorded ratio × (1 + `--tolerance`) when the baseline
//!   carries an `energy` section.
//!
//! ```bash
//! cargo run --release --bin bench_gate -- \
//!     --out artifacts/reports/BENCH_ci.json --baseline ci/bench_baseline.json
//! # refresh the baseline on the reference machine:
//! cargo run --release --bin bench_gate -- --baseline ci/bench_baseline.json --update-baseline
//! ```

use dnateq::coordinator::{
    AdmissionPolicy, BatcherConfig, Coordinator, CoordinatorConfig, CountingFcBackend,
    MetricsSnapshot, Payload,
};
use dnateq::dataset::ImageDataset;
use dnateq::dnateq::ExpQuantParams;
use dnateq::energysim::{run_ci_energy, CiEnergyReport};
use dnateq::expdot::simd::{self, SimdBackend};
use dnateq::expdot::CountingFc;
use dnateq::loadgen::{self, LoadReport, Scenario};
use dnateq::tensor::{SplitMix64, Tensor};
use dnateq::util::bench::{bench, black_box, BenchResult};
use dnateq::util::Json;
use std::sync::Arc;
use std::time::Duration;

const IN_FEATURES: usize = 3 * 32 * 32;
const OUT_FEATURES: usize = 256;
const REQUESTS: usize = 64;
const SWEEP: [usize; 3] = [1, 8, 32];
/// Offered rate of the tail-latency scenario: modest enough that the
/// autoscaled pool keeps up on a hosted runner, so the p99 measures
/// batching/queueing behavior rather than raw saturation.
const LOADGEN_RATE_RPS: f64 = 120.0;
const LOADGEN_DURATION_S: f64 = 1.5;
/// Offered load of the seeded `ci-energy` co-simulation case. Short:
/// the joule totals are pure arithmetic over the (seeded) arrival
/// count, so the case needs enough requests to be representative, not
/// enough wall time to be statistically quiet.
const ENERGY_RATE_RPS: f64 = 120.0;
const ENERGY_DURATION_S: f64 = 0.75;
/// Paper-direction ceiling on exp ÷ INT8 joules per request (Fig. 9:
/// ~66% savings ⇒ ratio ≈ 0.34–0.42; 0.5 leaves headroom for plan
/// tweaks without ever letting the headline invert).
const ENERGY_RATIO_CEILING: f64 = 0.5;
/// Floor applied to AVX-512 kernel rows on runners that can execute
/// them: `max(--min-simd-ratio, 1.15)`. The replicated-histogram
/// counting path must beat forced scalar by a real margin, not merely
/// avoid losing to it (warn-skipped where AVX-512 is unavailable).
const AVX512_RATIO_FLOOR: f64 = 1.15;

struct Opts {
    out: Option<String>,
    baseline: Option<String>,
    update_baseline: bool,
    tolerance: f64,
    min_speedup: f64,
    /// SIMD/scalar median ratio floor per kernel case; slightly below
    /// parity (0.85) so runner noise cannot fail a genuinely-equal pair,
    /// while a real SIMD regression still trips the gate.
    min_simd_ratio: f64,
    /// Headroom over the baseline loadgen p99/p999 ceilings. Tails are
    /// far noisier than medians on shared runners, so the default is
    /// looser than `--tolerance`.
    tail_tolerance: f64,
}

fn parse_opts() -> Opts {
    let mut o = Opts {
        out: None,
        baseline: None,
        update_baseline: false,
        tolerance: 0.15,
        min_speedup: 0.8,
        min_simd_ratio: 0.85,
        tail_tolerance: 0.5,
    };
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let value = |i: usize| {
            args.get(i + 1).cloned().unwrap_or_else(|| {
                eprintln!("flag {} needs a value", args[i]);
                std::process::exit(2);
            })
        };
        match args[i].as_str() {
            "--out" => {
                o.out = Some(value(i));
                i += 2;
            }
            "--baseline" => {
                o.baseline = Some(value(i));
                i += 2;
            }
            "--update-baseline" => {
                o.update_baseline = true;
                i += 1;
            }
            "--tolerance" => {
                o.tolerance = value(i).parse().expect("--tolerance is a fraction, e.g. 0.15");
                i += 2;
            }
            "--min-speedup" => {
                o.min_speedup = value(i).parse().expect("--min-speedup is a ratio, e.g. 0.8");
                i += 2;
            }
            "--min-simd-ratio" => {
                o.min_simd_ratio =
                    value(i).parse().expect("--min-simd-ratio is a ratio, e.g. 0.85");
                i += 2;
            }
            "--tail-tolerance" => {
                o.tail_tolerance =
                    value(i).parse().expect("--tail-tolerance is a fraction, e.g. 0.5");
                i += 2;
            }
            other => {
                eprintln!("unknown flag `{other}`");
                std::process::exit(2);
            }
        }
    }
    o
}

/// Serving failure counters accumulated over every coordinator the
/// sweep starts (warm-ups included): under this healthy fixed-shape
/// load, every one of them must stay zero. Names and order come from
/// [`MetricsSnapshot::failure_counters`], so new counters flow through
/// the gate automatically.
#[derive(Default)]
struct FailureCounters {
    totals: std::collections::BTreeMap<&'static str, u64>,
}

impl FailureCounters {
    fn absorb(&mut self, snap: &MetricsSnapshot) {
        for (name, value) in snap.failure_counters() {
            *self.totals.entry(name).or_default() += value;
        }
    }

    fn total(&self) -> u64 {
        self.totals.values().sum()
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        for (&name, &value) in &self.totals {
            o.set(name, value);
        }
        o
    }

    fn describe(&self) -> String {
        self.totals
            .iter()
            .map(|(name, value)| format!("{value} {name}"))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// Drive `n` requests through a fresh coordinator at one batcher
/// setting; per-request wall time becomes the case median. The
/// measurement itself is [`Coordinator::drive`] — the same harness the
/// serving benches use, so the gate guards exactly what they report.
fn drive(
    backend: Arc<CountingFcBackend>,
    max_batch: usize,
    data: &ImageDataset,
    n: usize,
    counters: &mut FailureCounters,
) -> Duration {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(1) },
        min_workers: 2,
        max_workers: 2,
        queue_depth: 256,
        admission: AdmissionPolicy::Block,
        power_envelope_watts: None,
    };
    let c = Coordinator::start(backend, cfg);
    let payloads: Vec<Payload> =
        (0..data.len().min(n)).map(|i| Payload::Image(data.image(i))).collect();
    let per = c.drive(&payloads, n).expect("bench drive").per_request;
    counters.absorb(&c.shutdown_and_drain());
    per
}

/// The tail-latency SLO case: a short seeded open-loop Poisson scenario
/// on the counting backend through an autoscaling pool. Returns the
/// report plus its JSON section (`loadgen` in BENCH_ci.json).
fn run_loadgen(counters: &mut FailureCounters) -> (Json, LoadReport) {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        min_workers: 1,
        max_workers: 4,
        queue_depth: 1024,
        admission: AdmissionPolicy::Block,
        power_envelope_watts: None,
    };
    let c = Coordinator::start(loadgen::cli::counting_engine(loadgen::cli::CI_ENGINE_SEED), cfg);
    let data = ImageDataset::synthetic(32, 0xC1DA7A);
    let payloads: Vec<Payload> = (0..data.len()).map(|i| Payload::Image(data.image(i))).collect();
    let scenario = Scenario {
        name: "ci-poisson".into(),
        rate_rps: LOADGEN_RATE_RPS,
        duration_s: LOADGEN_DURATION_S,
        seed: 0x51_0AD,
        ..Scenario::default()
    };
    let report = scenario.run(&c.client(), &payloads);
    counters.absorb(&c.shutdown_and_drain());
    println!("loadgen {}: {}", scenario.name, report.summary());
    println!("{}", report.class_table());
    let mut section = report.to_json();
    section.set("scenario", scenario.to_json());
    (section, report)
}

/// The energy co-simulation case: the seeded `ci-energy` scenario runs
/// the same arrival schedule twice — once under the exp-4 plan, once
/// under uniform INT8 — through the real batcher, and reports simulated
/// joules/request for each. The totals are pure per-item arithmetic
/// over the plan, so they are bit-identical run to run; only the ratio
/// is gated. Returns the report plus its JSON section (`energy` in
/// BENCH_ci.json).
fn run_energy() -> (Json, CiEnergyReport) {
    let report = run_ci_energy(ENERGY_RATE_RPS, ENERGY_DURATION_S);
    println!("{}", report.summary());
    (report.to_json(), report)
}

fn run_sweep(counters: &mut FailureCounters) -> Vec<BenchResult> {
    let mut rng = SplitMix64::new(0xC1_BE7C);
    let w = Tensor::rand_signed_exponential(&[OUT_FEATURES, IN_FEATURES], 3.0, &mut rng);
    let x_cal = Tensor::rand_signed_exponential(&[1, IN_FEATURES], 1.0, &mut rng);
    let wp = ExpQuantParams::init_for_tensor(&w, 4);
    let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: 4 };
    ap.refit_scale_offset(&x_cal);
    let backend = Arc::new(CountingFcBackend { fc: CountingFc::new(&w, wp, ap, None) });
    let data = ImageDataset::synthetic(32, 0xC1DA7A);

    let mut results = Vec::new();
    for max_batch in SWEEP {
        drive(Arc::clone(&backend), max_batch, &data, 16, counters); // warm-up
        // Three timed repetitions; keep the fastest (least-noise) run.
        let best = (0..3)
            .map(|_| drive(Arc::clone(&backend), max_batch, &data, REQUESTS, counters))
            .min()
            .unwrap();
        let r = BenchResult {
            name: format!("ci-fc {IN_FEATURES}x{OUT_FEATURES} max_batch={max_batch}"),
            median: best,
            mean: best,
            mad: Duration::ZERO,
            iters: REQUESTS as u64,
            backend: None,
        }
        .with_backend(simd::active_backend().name());
        println!("{}", r.summary());
        results.push(r);
    }
    results
}

/// One forced-backend kernel measurement: `ratio` is the forced-scalar
/// median divided by this backend's median at `batch` (>1 ⇒ faster than
/// scalar).
struct KernelRatio {
    backend: SimdBackend,
    batch: usize,
    ratio: f64,
}

/// Probe (once) which SIMD backends this runner can execute. Emitted as
/// the report's top-level `simd_capability` section so `BENCH_ci.json`
/// trajectories record what the runner could run, and consulted by the
/// gate to warn-skip `--min-simd-ratio` floors for backends the runner
/// cannot execute.
fn probe_capability() -> Json {
    let mut o = Json::obj();
    o.set("best", simd::best_available().name());
    for b in SimdBackend::all() {
        o.set(b.name(), simd::available(b));
    }
    o
}

/// Direct scalar-vs-SIMD kernel cases: the same 4-bit 3072→256 layer as
/// the serving sweep, benched as bare `forward_batch` calls under forced
/// backends at batch {1, 8, 32}. The legacy "scalar"/"simd" case names
/// are kept for baseline compatibility (the "simd" instance is the
/// runner's best backend; on scalar-only runners it *is* scalar, so
/// baseline names always resolve and the ratio sits at ~1). Every other
/// executable non-scalar backend gets its own explicitly-named rows, so
/// an AVX-512 runner also records its AVX2 kernel trajectory. Appends
/// all cases to `results` and returns the per-backend speedups as the
/// report's `simd` section.
fn run_kernel_sweep(results: &mut Vec<BenchResult>) -> (Json, Vec<KernelRatio>) {
    let mut rng = SplitMix64::new(0xC1_BE7C);
    let w = Tensor::rand_signed_exponential(&[OUT_FEATURES, IN_FEATURES], 3.0, &mut rng);
    let x_cal = Tensor::rand_signed_exponential(&[1, IN_FEATURES], 1.0, &mut rng);
    let wp = ExpQuantParams::init_for_tensor(&w, 4);
    let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: 4 };
    ap.refit_scale_offset(&x_cal);
    let best = simd::best_available();
    let fc_scalar = CountingFc::new(&w, wp, ap, None).with_backend(SimdBackend::Scalar);
    let fc_simd = CountingFc::new(&w, wp, ap, None).with_backend(best);
    let extra_fcs: Vec<(SimdBackend, CountingFc)> = SimdBackend::all()
        .into_iter()
        .filter(|&b| b != SimdBackend::Scalar && b != best && simd::available(b))
        .map(|b| (b, CountingFc::new(&w, wp, ap, None).with_backend(b)))
        .collect();

    let mut info = Json::obj();
    info.set("active", best.name());
    let mut ratios = Vec::new();
    for batch in SWEEP {
        let x = Tensor::rand_signed_exponential(&[batch, IN_FEATURES], 1.0, &mut rng);
        let sname = format!("ci-fc-kernel {IN_FEATURES}x{OUT_FEATURES} scalar b={batch}");
        let vname = format!("ci-fc-kernel {IN_FEATURES}x{OUT_FEATURES} simd b={batch}");
        let rs = bench(&sname, 200, || {
            black_box(fc_scalar.forward_batch(&x));
        })
        .with_backend("scalar");
        let rv = bench(&vname, 200, || {
            black_box(fc_simd.forward_batch(&x));
        })
        .with_backend(best.name());
        let scalar_s = rs.median.as_secs_f64();
        let ratio = scalar_s / rv.median.as_secs_f64().max(1e-12);
        println!("{}", rs.summary());
        println!("{}", rv.summary());
        println!("kernel simd speedup (b={batch}, backend {}): {ratio:.2}x", best.name());
        info.set(&format!("speedup_b{batch}"), ratio);
        ratios.push(KernelRatio { backend: best, batch, ratio });
        results.push(rs);
        results.push(rv);
        for (b, fc) in &extra_fcs {
            let name = format!("ci-fc-kernel {IN_FEATURES}x{OUT_FEATURES} {} b={batch}", b.name());
            let rb = bench(&name, 200, || {
                black_box(fc.forward_batch(&x));
            })
            .with_backend(b.name());
            let ratio = scalar_s / rb.median.as_secs_f64().max(1e-12);
            println!("{}", rb.summary());
            println!("kernel simd speedup (b={batch}, backend {}): {ratio:.2}x", b.name());
            info.set(&format!("speedup_{}_b{batch}", b.name()), ratio);
            ratios.push(KernelRatio { backend: *b, batch, ratio });
            results.push(rb);
        }
    }
    (info, ratios)
}

fn median_of<'a>(results: &'a [BenchResult], suffix: &str) -> Option<&'a BenchResult> {
    results.iter().find(|r| r.name.ends_with(suffix))
}

/// Encode a run as the gate's report JSON: timing cases + the failure
/// counters the gate asserts on + the runner's probed SIMD capability +
/// the scalar-vs-SIMD kernel section + the open-loop tail-latency
/// section + the energy co-sim section.
fn report_json(
    results: &[BenchResult],
    counters: &FailureCounters,
    capability: &Json,
    simd_info: &Json,
    loadgen_info: &Json,
    energy_info: &Json,
) -> Json {
    let mut o = Json::obj();
    o.set("cases", Json::Arr(results.iter().map(|r| r.to_json()).collect()))
        .set("counters", counters.to_json())
        .set("simd_capability", capability.clone())
        .set("simd", simd_info.clone())
        .set("loadgen", loadgen_info.clone())
        .set("energy", energy_info.clone());
    o
}

fn write_report(path: &str, j: &Json) {
    if let Some(parent) = std::path::Path::new(path).parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    j.write_file(path).expect("writing bench JSON");
}

/// Load baseline cases as `(name, median_ms)`. Accepts both the
/// current `{cases: [...], counters: {...}}` shape and the legacy bare
/// array, so a stale baseline fails with a regression message rather
/// than a parse panic.
fn load_baseline(path: &str) -> Vec<(String, f64)> {
    let j = match Json::read_file(path) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("cannot read baseline {path}: {e}");
            std::process::exit(1);
        }
    };
    let cases = j.get("cases").unwrap_or(&j);
    cases
        .as_arr()
        .expect("baseline cases is a JSON array")
        .iter()
        .map(|case| {
            let name = case.req("name").unwrap().as_str().unwrap().to_string();
            let median = case.req("median_ms").unwrap().as_f64().unwrap();
            (name, median)
        })
        .collect()
}

/// Pull the tail-latency ceilings out of a baseline's `loadgen`
/// section. Accepts the hand-written ceiling shape
/// (`{e2e_p99_ms, e2e_p999_ms}`) and the `--update-baseline` output
/// (`{e2e_ms: {p99_ms, p999_ms, ...}, ...}`). `None` when the baseline
/// predates the loadgen gate — the caller warns and skips.
fn load_tail_ceilings(baseline: &Json) -> Option<(f64, f64)> {
    let lg = baseline.get("loadgen")?;
    let flat = |key: &str| lg.get(key).and_then(|v| v.as_f64().ok());
    let nested =
        |key: &str| lg.get("e2e_ms").and_then(|e| e.get(key)).and_then(|v| v.as_f64().ok());
    let p99 = flat("e2e_p99_ms").or_else(|| nested("p99_ms"))?;
    let p999 = flat("e2e_p999_ms").or_else(|| nested("p999_ms"))?;
    Some((p99, p999))
}

/// Pull the recorded exp÷INT8 joules-per-request ratio out of a
/// baseline's `energy` section. `None` when the baseline predates the
/// energy gate — the caller warns and skips.
fn load_energy_ratio(baseline: &Json) -> Option<f64> {
    baseline.get("energy")?.get("ratio_j_per_request").and_then(|v| v.as_f64().ok())
}

fn main() {
    let opts = parse_opts();
    let capability = probe_capability();
    println!("simd capability: {}", capability.encode());
    let mut counters = FailureCounters::default();
    let mut results = run_sweep(&mut counters);
    let (simd_info, kernel_ratios) = run_kernel_sweep(&mut results);
    let (loadgen_info, load) = run_loadgen(&mut counters);
    let (energy_info, energy) = run_energy();

    // Machine-independent guard: the batched hot path must actually beat
    // (or at minimum match, within tolerance) unbatched serving.
    let b1 = median_of(&results, "max_batch=1").unwrap().median.as_secs_f64();
    let b32 = median_of(&results, "max_batch=32").unwrap().median.as_secs_f64();
    let speedup = b1 / b32.max(1e-12);
    let floor = opts.min_speedup;
    println!("batching speedup (max_batch 32 vs 1): {speedup:.2}x (floor {floor:.2}x)");
    println!("failure counters: {}", counters.describe());

    if let Some(out) = &opts.out {
        write_report(
            out,
            &report_json(&results, &counters, &capability, &simd_info, &loadgen_info, &energy_info),
        );
        println!("JSON -> {out}");
    }

    let mut failures = Vec::new();
    if speedup < opts.min_speedup {
        failures.push(format!(
            "batched serving speedup {speedup:.2}x fell below the {:.2}x floor",
            opts.min_speedup
        ));
    }
    if counters.total() > 0 {
        failures.push(format!(
            "serving errors during the sweep: {} (all must be zero)",
            counters.describe()
        ));
    }
    // Capability-aware SIMD floors. Backends the runner cannot execute
    // never produced rows — warn-skip them instead of failing. Rows
    // whose backend is scalar (scalar-only runners, where the dispatch
    // "simd" instance fell back) carry a pure-noise ratio and are also
    // skipped. AVX-512 rows must clear the raised replicated-histogram
    // floor, not merely the parity floor.
    for b in SimdBackend::all() {
        if b != SimdBackend::Scalar && !simd::available(b) {
            println!(
                "warning: runner cannot execute {} — its --min-simd-ratio floor is skipped",
                b.name()
            );
        }
    }
    for kr in &kernel_ratios {
        if kr.backend == SimdBackend::Scalar {
            continue;
        }
        let floor = if kr.backend == SimdBackend::Avx512 {
            opts.min_simd_ratio.max(AVX512_RATIO_FLOOR)
        } else {
            opts.min_simd_ratio
        };
        if kr.ratio < floor {
            failures.push(format!(
                "{} kernel at b={} ran {:.2}x vs scalar, below the {floor:.2}x floor",
                kr.backend.name(),
                kr.batch,
                kr.ratio
            ));
        }
    }
    // The open-loop scenario must complete cleanly: every typed failure
    // kind (deadline, shed, engine failure, ...) is a gate failure here,
    // even ones the coordinator metrics would not count.
    if load.failed > 0 {
        failures.push(format!(
            "loadgen scenario had {} typed failures out of {} offered: {:?}",
            load.failed, load.offered, load.failures
        ));
    }
    // Paper-direction energy gate: absolute, baseline-independent. The
    // exp plan must keep its joules/request at or under half of INT8 on
    // the identical seeded arrival schedule.
    let energy_ratio = energy.ratio();
    println!(
        "energy co-sim exp/int8 joules-per-request ratio: {energy_ratio:.4} \
         (ceiling {ENERGY_RATIO_CEILING:.2})"
    );
    let energy_ok = energy_ratio.is_finite() && energy_ratio <= ENERGY_RATIO_CEILING;
    if !energy_ok {
        failures.push(format!(
            "energy co-sim ratio {energy_ratio:.4} exceeds the {ENERGY_RATIO_CEILING:.2} \
             exp-vs-INT8 joules/request ceiling"
        ));
    }

    if let Some(baseline_path) = &opts.baseline {
        if opts.update_baseline {
            let refreshed = report_json(
                &results,
                &counters,
                &capability,
                &simd_info,
                &loadgen_info,
                &energy_info,
            );
            write_report(baseline_path, &refreshed);
            println!("baseline refreshed -> {baseline_path}");
        } else {
            for (name, base_ms) in load_baseline(baseline_path) {
                let Some(cur) = results.iter().find(|r| r.name == name) else {
                    failures.push(format!("baseline case `{name}` missing from this run"));
                    continue;
                };
                let cur_ms = cur.per_iter_ms();
                // Throughput ∝ 1/median: a >tolerance throughput drop
                // means cur_ms > base_ms / (1 - tolerance).
                let limit_ms = base_ms / (1.0 - opts.tolerance);
                let verdict = if cur_ms > limit_ms { "REGRESSED" } else { "ok" };
                println!(
                    "{name:<40} {cur_ms:>9.3} ms vs baseline {base_ms:>9.3} ms (limit {limit_ms:>9.3}) {verdict}"
                );
                if cur_ms > limit_ms {
                    failures.push(format!(
                        "`{name}`: {cur_ms:.3} ms/req vs baseline {base_ms:.3} ms/req \
                         (> {:.0}% throughput regression)",
                        opts.tolerance * 100.0
                    ));
                }
            }
            // Tail-latency SLO gate: the scenario's measured e2e p99/p999
            // must stay under the baseline ceilings × (1 + tail tolerance).
            let baseline = Json::read_file(baseline_path).ok();
            match baseline.as_ref().and_then(load_tail_ceilings) {
                Some((p99_ceiling_ms, p999_ceiling_ms)) => {
                    let checks = [
                        ("e2e p99", load.e2e.p99 * 1e3, p99_ceiling_ms),
                        ("e2e p999", load.e2e.p999 * 1e3, p999_ceiling_ms),
                    ];
                    for (name, cur_ms, base_ms) in checks {
                        let limit_ms = base_ms * (1.0 + opts.tail_tolerance);
                        let verdict = if cur_ms > limit_ms { "REGRESSED" } else { "ok" };
                        println!(
                            "loadgen {name:<32} {cur_ms:>9.3} ms vs ceiling {base_ms:>9.3} ms (limit {limit_ms:>9.3}) {verdict}"
                        );
                        if cur_ms > limit_ms {
                            failures.push(format!(
                                "loadgen {name}: {cur_ms:.3} ms vs baseline ceiling {base_ms:.3} ms \
                                 (limit {limit_ms:.3} ms at +{:.0}% tail tolerance)",
                                opts.tail_tolerance * 100.0
                            ));
                        }
                    }
                }
                None => {
                    println!(
                        "baseline {baseline_path} has no `loadgen` ceilings — tail-latency gate skipped"
                    );
                }
            }
            // Energy drift gate: the measured ratio must not creep above
            // the baseline's recorded ratio × (1 + tolerance). The joule
            // totals are deterministic, so tolerance here guards plan
            // edits, not runner noise.
            match baseline.as_ref().and_then(load_energy_ratio) {
                Some(base_ratio) => {
                    let limit = base_ratio * (1.0 + opts.tolerance);
                    let verdict = if energy_ratio > limit { "REGRESSED" } else { "ok" };
                    println!(
                        "energy ratio {energy_ratio:>9.4} vs baseline {base_ratio:>9.4} (limit {limit:>9.4}) {verdict}"
                    );
                    if energy_ratio > limit {
                        failures.push(format!(
                            "energy co-sim ratio {energy_ratio:.4} vs baseline {base_ratio:.4} \
                             (limit {limit:.4} at +{:.0}% tolerance)",
                            opts.tolerance * 100.0
                        ));
                    }
                }
                None => {
                    println!(
                        "baseline {baseline_path} has no `energy` section — energy drift gate skipped"
                    );
                }
            }
        }
    }

    if !failures.is_empty() {
        eprintln!("bench gate FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
    println!("bench gate passed");
}
