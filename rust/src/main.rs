//! `repro` — DNA-TEQ reproduction CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   calibrate [--model M] [--force]      run the Fig.-3 pipeline (cached)
//!   report    [--all|--table N|--figure N|--area]   regenerate exhibits
//!   simulate                             accelerator comparison (Figs. 8/9)
//!   serve     [--models a,b,c] [--requests N] [--backend KIND] [--plan-policy P]
//!   plans     list | show <model> [--version V] | diff <model> <v1> <v2>
//!             | build <model> [--thr-w T] | front <model>
//!   swap      <model> [--thr-w T] [--requests N]   hot-swap demo under load
//!   infer     [--model M] [--index I]    one PJRT inference from artifacts
//!   loadgen   [--rate R] [--pattern poisson|burst] [--admission P] [--out F]
//!             open-loop load generation (same flags as the `loadgen` bin)
//!   energy    [--rate R] [--duration S] [--out F]   seeded ci-energy
//!             head-to-head: exp vs INT8 joules/request through the batcher
//!
//! Global flag (after the subcommand): `--simd scalar|avx2|avx512|auto`
//! forces the kernel dispatch backend before any engine is constructed
//! (default: `DNATEQ_SIMD` env var, then runtime CPU detection).

use anyhow::{bail, Context, Result};
use dnateq::coordinator::{
    AdmissionPolicy, AlexNetBackend, CoordinatorConfig, ModelRegistry, Output, Payload,
    PjrtClassifierBackend, ResNetBackend, SwappableEngine, TranslatorBackend,
};
use dnateq::dataset::{ImageDataset, SeqDataset};
use dnateq::dnateq::{
    config_for_threshold, diff_plans, render_front, render_plan, CalibrationInput,
    CalibrationOptions, PlanPolicy, PlanStore, Planner, QuantConfig, SearchOptions, SearchSpace,
};
use dnateq::nn::{
    collect_image_calibration, collect_seq_calibration, eval::ImageModel, AlexNetMini, ExecPlan,
    ResNetMini, TransformerMini, WeightMap,
};
use dnateq::report::{calibrate_or_load, tables, CalibOutcome, MODELS};
use dnateq::runtime::Runtime;
use dnateq::{artifact_path, tensor::Tensor};
use std::collections::BTreeMap;
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Flags that never take a value (so `--force alexnet_mini` keeps
/// `alexnet_mini` as a positional instead of swallowing it).
const BOOL_FLAGS: &[&str] = &["force", "quick", "all", "area"];

/// Tiny argument parser: `<cmd> [positionals] [--key value | --flag]`.
struct Args {
    cmd: String,
    positionals: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Self {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let rest: Vec<String> = it.collect();
        let mut positionals = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < rest.len() {
            if rest[i].starts_with('-') {
                let k = rest[i].trim_start_matches('-').to_string();
                let takes_value = !BOOL_FLAGS.contains(&k.as_str());
                if takes_value && i + 1 < rest.len() && !rest[i + 1].starts_with('-') {
                    flags.insert(k, rest[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(k, "true".into());
                    i += 1;
                }
            } else {
                positionals.push(rest[i].clone());
                i += 1;
            }
        }
        Self { cmd, positionals, flags }
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn has(&self, k: &str) -> bool {
        self.flags.contains_key(k)
    }

    fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }
}

// ---------------------------------------------------------------------
// Shared validation (consistent across every subcommand).
// ---------------------------------------------------------------------

/// Resolve a user-supplied model name (short alias or canonical) to the
/// canonical `*_mini` name, or fail listing what exists.
fn canonical_model(name: &str) -> Result<&'static str> {
    match name {
        "alexnet" | "alexnet_mini" => Ok("alexnet_mini"),
        "resnet" | "resnet_mini" => Ok("resnet_mini"),
        "transformer" | "transformer_mini" => Ok("transformer_mini"),
        other => {
            let trained = WeightMap::list_models(artifact_path("models"));
            bail!(
                "unknown model `{other}`; known: {MODELS:?} (aliases: alexnet, resnet, \
                 transformer); trained weights present for: {trained:?}"
            )
        }
    }
}

/// Admission policy names accepted by `serve --admission` (shared with
/// the loadgen CLI via [`AdmissionPolicy::parse`]).
fn parse_admission(name: &str) -> Result<AdmissionPolicy> {
    AdmissionPolicy::parse(name).map_err(anyhow::Error::msg)
}

/// Serving backend kinds and the feature gate for `pjrt`.
fn validate_backend(kind: &str) -> Result<()> {
    let available: &[&str] = if cfg!(feature = "pjrt") {
        &["engine", "quantized", "pjrt"]
    } else {
        &["engine", "quantized"]
    };
    if kind == "pjrt" && !cfg!(feature = "pjrt") {
        bail!(
            "backend `pjrt` is unavailable: this binary was built without the `pjrt` feature \
             (rebuild with `--features pjrt` and a vendored xla crate); available backends: \
             engine, quantized"
        );
    }
    if !available.contains(&kind) {
        bail!("unknown backend `{kind}`; available backends: {}", available.join(", "));
    }
    Ok(())
}

fn calib_options(quick: bool) -> CalibrationOptions {
    let mut o = CalibrationOptions::default();
    if quick {
        o.thr_max = 0.10;
    }
    o
}

fn all_outcomes(force: bool, quick: bool) -> Result<BTreeMap<String, CalibOutcome>> {
    let opts = calib_options(quick);
    MODELS
        .iter()
        .map(|m| Ok((m.to_string(), calibrate_or_load(m, force, &opts)?)))
        .collect()
}

/// The DNA-TEQ plan for `model`: the latest stored plan artifact when
/// one exists, otherwise a fresh (quick) calibration — which itself
/// stores its plan, so the second call hits the store.
fn plan_for(model: &str) -> Result<QuantConfig> {
    let store = PlanStore::open_default();
    if let Some((v, cfg)) = store.latest(model)? {
        eprintln!("[plan] {model}: serving stored plan v{v} (checksum {})", cfg.checksum_hex());
        return Ok(cfg);
    }
    Ok(calibrate_or_load(model, false, &calib_options(true))?.config)
}

/// Calibration inputs for the hybrid planner: trained weights + the
/// calib split when the artifacts exist, reproducible synthetic
/// otherwise (mirrors how `swap` builds its recalibration inputs).
fn calibration_input_for(model: &str) -> Result<CalibrationInput> {
    let images = || {
        ImageDataset::load(artifact_path("data"), "calib")
            .unwrap_or_else(|_| ImageDataset::synthetic(8, 0xCA11B))
    };
    Ok(match model {
        "alexnet_mini" => collect_image_calibration(&alexnet_model(), &images().take(4)),
        "resnet_mini" => collect_image_calibration(&resnet_model(), &images().take(4)),
        "transformer_mini" => {
            let calib = SeqDataset::load(artifact_path("data"), "calib")
                .unwrap_or_else(|_| SeqDataset::synthetic(8, 0xCA11B));
            collect_seq_calibration(&transformer_model(), &calib.take(4))
        }
        other => bail!("no calibration wiring for model `{other}`"),
    })
}

/// `--thr-w` accepts a fraction (`0.08`) or percent (`8` / `8%`).
fn parse_thr_w(raw: &str) -> Result<f64> {
    let mut thr: f64 = raw.trim_end_matches('%').parse()?;
    if thr >= 1.0 {
        thr /= 100.0;
    }
    Ok(thr)
}

// ---------------------------------------------------------------------
// serve — multi-model registry serving.
// ---------------------------------------------------------------------

/// What a model's clients send and how responses are scored.
enum Traffic {
    Image(ImageDataset),
    Seq(SeqDataset),
}

fn image_traffic() -> Traffic {
    let data = ImageDataset::load(artifact_path("data"), "eval").unwrap_or_else(|_| {
        eprintln!("[serve] artifacts missing (`make artifacts`); using synthetic images");
        ImageDataset::synthetic(64, 0xDA7A)
    });
    Traffic::Image(data)
}

fn seq_traffic() -> Traffic {
    let data = SeqDataset::load(artifact_path("data"), "eval").unwrap_or_else(|_| {
        eprintln!("[serve] artifacts missing (`make artifacts`); using synthetic sequences");
        SeqDataset::synthetic(64, 0x5E9)
    });
    Traffic::Seq(data)
}

/// Trained weights when present, reproducible random weights otherwise.
fn alexnet_model() -> AlexNetMini {
    match WeightMap::load_dir(artifact_path("models/alexnet_mini")) {
        Ok(w) => AlexNetMini::from_weights(&w).expect("artifact weights well-formed"),
        Err(_) => {
            eprintln!("[serve] alexnet_mini weights missing; using random weights");
            AlexNetMini::random(0x41E)
        }
    }
}

fn resnet_model() -> ResNetMini {
    match WeightMap::load_dir(artifact_path("models/resnet_mini")) {
        Ok(w) => ResNetMini::from_weights(&w).expect("artifact weights well-formed"),
        Err(_) => {
            eprintln!("[serve] resnet_mini weights missing; using random weights");
            ResNetMini::random(0x4E5)
        }
    }
}

fn transformer_model() -> TransformerMini {
    match WeightMap::load_dir(artifact_path("models/transformer_mini")) {
        Ok(w) => TransformerMini::from_weights(&w).expect("artifact weights well-formed"),
        Err(_) => {
            eprintln!("[serve] transformer_mini weights missing; using random weights");
            TransformerMini::random(0x7F2)
        }
    }
}

fn classifier_backend<M: ImageModel + 'static>(
    model: M,
    name: &str,
    kind: &str,
) -> Result<Arc<dyn SwappableEngine>> {
    Ok(match kind {
        "quantized" => {
            let cfg = plan_for(name)?;
            Arc::new(dnateq::coordinator::ClassifierBackend::quantized(
                model,
                &cfg,
                &format!("{name}-dnateq"),
            ))
        }
        _ => Arc::new(dnateq::coordinator::ClassifierBackend::fp32(
            model,
            &format!("{name}-fp32"),
        )),
    })
}

/// Register `model` (canonical name) with the right backend + traffic.
fn register_model(
    registry: &ModelRegistry,
    model: &str,
    kind: &str,
    cfg: CoordinatorConfig,
) -> Result<Traffic> {
    match model {
        "alexnet_mini" => {
            if kind == "pjrt" {
                registry.register(
                    model,
                    Arc::new(PjrtClassifierBackend::spawn(artifact_path(
                        "alexnet_fp32.hlo.txt",
                    ))?),
                    cfg,
                )?;
            } else {
                registry.register_swappable(
                    model,
                    classifier_backend(alexnet_model(), model, kind)?,
                    cfg,
                )?;
            }
            Ok(image_traffic())
        }
        "resnet_mini" => {
            if kind == "pjrt" {
                bail!("backend `pjrt` only serves alexnet_mini (one AOT artifact is compiled)");
            }
            registry.register_swappable(
                model,
                classifier_backend(resnet_model(), model, kind)?,
                cfg,
            )?;
            Ok(image_traffic())
        }
        "transformer_mini" => {
            if kind == "pjrt" {
                bail!("backend `pjrt` only serves alexnet_mini (one AOT artifact is compiled)");
            }
            let model_impl = transformer_model();
            let plan = if kind == "quantized" {
                ExecPlan::exp(&model_impl, &plan_for(model)?)
            } else {
                ExecPlan::fp32()
            };
            registry.register(
                model,
                Arc::new(TranslatorBackend { model: model_impl, plan, max_len: 16 }),
                cfg,
            )?;
            Ok(seq_traffic())
        }
        other => bail!("no backend wiring for model `{other}`"),
    }
}

fn serve(args: &Args) -> Result<()> {
    let n: usize = args.get("requests").unwrap_or("64").parse()?;
    let kind = args.get("backend").unwrap_or("engine");
    validate_backend(kind)?;
    let admission = parse_admission(args.get("admission").unwrap_or("block"))?;
    let policy = args.get("plan-policy").map(PlanPolicy::parse).transpose()?;
    let spec = match (args.get("models"), args.get("model")) {
        (Some(_), Some(_)) => bail!("pass either --models or --model, not both"),
        (Some(list), None) => list.to_string(),
        (None, Some(one)) => one.to_string(),
        (None, None) => "alexnet_mini".to_string(),
    };
    let mut models = Vec::new();
    for name in spec.split(',').filter(|s| !s.is_empty()) {
        let canon = canonical_model(name.trim())?;
        if !models.contains(&canon) {
            models.push(canon);
        }
    }
    if models.is_empty() {
        bail!("no models requested");
    }

    let defaults = CoordinatorConfig::default();
    let min_workers: usize = args
        .get("min-workers")
        .map(str::parse)
        .transpose()
        .context("--min-workers must be an integer")?
        .unwrap_or(defaults.min_workers);
    let max_workers: usize = args
        .get("max-workers")
        .map(str::parse)
        .transpose()
        .context("--max-workers must be an integer")?
        .unwrap_or(defaults.max_workers)
        .max(min_workers);

    let power_envelope_watts: Option<f64> = args
        .get("power-envelope-watts")
        .map(str::parse)
        .transpose()
        .context("--power-envelope-watts must be a number")?;

    let registry = ModelRegistry::new();
    let mut traffic = BTreeMap::new();
    let coord_cfg = CoordinatorConfig {
        admission,
        min_workers,
        max_workers,
        power_envelope_watts,
        ..defaults
    };
    for m in &models {
        let t = register_model(&registry, m, kind, coord_cfg)?;
        traffic.insert(m.to_string(), t);
    }
    println!(
        "serving {} model(s) [{}] with backend `{kind}` (admission {admission:?}, simd {})",
        models.len(),
        models.join(", "),
        dnateq::expdot::simd::active_backend().name()
    );

    // SLA-driven startup plan selection: resolve the policy against each
    // model's stored Pareto front and hot-swap the winning version in
    // (counted by the per-model swap metric). Fixed-plan engines (pjrt,
    // the translator) cannot swap and are skipped with a notice.
    if let Some(policy) = policy {
        let store = PlanStore::open_default();
        for m in &models {
            if registry.plan_label(m).is_err() {
                eprintln!("[policy] {m}: fixed-plan engine; --plan-policy skipped");
                continue;
            }
            let (v, cfg) = registry.apply_policy(m, &store, policy)?;
            println!(
                "[policy] {m}: {} → plan v{v} (avg bits {:.2}, schemes {}, checksum {})",
                policy.name(),
                cfg.avg_bitwidth(),
                cfg.scheme_names().join("+"),
                cfg.checksum_hex()
            );
        }
    }

    // One typed client per model (the single- and multi-model API);
    // interleave traffic round-robin across models so every batcher
    // sees concurrent mixed load.
    let clients: BTreeMap<&str, dnateq::coordinator::InferenceClient> = models
        .iter()
        .map(|m| Ok((*m, registry.client(m)?)))
        .collect::<Result<_>>()?;
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        let model = models[i % models.len()];
        let (payload, label) = match &traffic[model] {
            Traffic::Image(d) => {
                let idx = (i / models.len()) % d.len();
                (Payload::Image(d.image(idx)), Some(d.labels[idx]))
            }
            Traffic::Seq(d) => {
                let idx = (i / models.len()) % d.len();
                (Payload::Seq(d.src[idx].clone()), None)
            }
        };
        pending.push((model, label, clients[model].submit(payload)?));
    }

    let mut hits: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
    for (model, label, ticket) in pending {
        let entry = hits.entry(model).or_default();
        entry.1 += 1;
        match (label, ticket.wait()) {
            (Some(want), Ok(resp)) if resp.output == Output::ClassId(want) => entry.0 += 1,
            (None, Ok(resp)) if matches!(&resp.output, Output::Tokens(t) if !t.is_empty()) => {
                entry.0 += 1
            }
            (_, Err(e)) => eprintln!("[serve] {model}: request failed: {e}"),
            _ => {}
        }
    }

    let snaps = registry.shutdown_and_drain();
    for (model, snap) in &snaps {
        let (ok, total) = hits.get(model.as_str()).copied().unwrap_or((0, 0));
        let metric = if matches!(traffic[model.as_str()], Traffic::Image(_)) {
            format!("accuracy {:.4}", ok as f64 / total.max(1) as f64)
        } else {
            format!("{ok}/{total} decoded")
        };
        println!("{model:<18} {metric} | {}", snap.summary());
    }
    Ok(())
}

// ---------------------------------------------------------------------
// plans — artifact store inspection.
// ---------------------------------------------------------------------

fn plans(args: &Args) -> Result<()> {
    let store = PlanStore::open_default();
    match args.positional(0) {
        Some("list") | None => {
            let listing = store.list()?;
            if listing.is_empty() {
                let root = store.root().display();
                println!("no plans stored under {root} (run `repro calibrate`)");
                return Ok(());
            }
            println!(
                "{:<18} {:>4} {:>18} {:>8} {:>7} {:>9}",
                "model", "ver", "checksum", "thr_w", "layers", "avg bits"
            );
            for s in listing {
                println!(
                    "{:<18} {:>4} {:>18} {:>7.2}% {:>7} {:>9.2}",
                    s.model,
                    s.version,
                    s.checksum,
                    s.thr_w * 100.0,
                    s.layers,
                    s.avg_bitwidth
                );
            }
        }
        Some("show") => {
            let model = canonical_model(
                args.positional(1).or(args.get("model")).context("plans show <model>")?,
            )?;
            let (version, cfg) = match args.get("version") {
                Some(v) => {
                    let v: u32 = v.parse().context("--version must be an integer")?;
                    (v, store.load(model, v)?)
                }
                None => store
                    .latest(model)?
                    .with_context(|| format!("no stored plans for `{model}`"))?,
            };
            print!("{}", render_plan(&cfg, version));
        }
        Some("diff") => {
            let usage = "plans diff <model> <v1> <v2>";
            let model = canonical_model(args.positional(1).context(usage)?)?;
            let v1: u32 = args.positional(2).context(usage)?.parse()?;
            let v2: u32 = args.positional(3).context(usage)?.parse()?;
            let a = store.load(model, v1)?;
            let b = store.load(model, v2)?;
            let lines = diff_plans(&a, &b);
            if lines.is_empty() {
                println!("{model}: v{v1} and v{v2} are content-identical");
            } else {
                println!("{model}: v{v1} → v{v2} ({} change(s))", lines.len());
                for l in lines {
                    println!("  {l}");
                }
            }
        }
        Some("build") => {
            let model = canonical_model(
                args.positional(1)
                    .or(args.get("model"))
                    .context("plans build <model> [--thr-w T]")?,
            )?;
            let thr = parse_thr_w(args.get("thr-w").unwrap_or("0.04"))?;
            let input = calibration_input_for(model)?;
            let set = Planner::new(SearchSpace::full(thr)).plan_set(&input);
            let front = store.save_front(&set)?;
            print!("{}", render_front(&front));
        }
        Some("front") => {
            let model = canonical_model(
                args.positional(1).or(args.get("model")).context("plans front <model>")?,
            )?;
            match store.load_front(model)? {
                Some(front) => print!("{}", render_front(&front)),
                None => bail!(
                    "no stored front for `{model}`; run `repro plans build {model}` first"
                ),
            }
        }
        Some(other) => {
            bail!("unknown plans action `{other}`; use list, show, diff, build or front")
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// swap — live plan hot-swap demonstration.
// ---------------------------------------------------------------------

/// Build the quantized backend for the hot-swap demo: serves the latest
/// stored plan (or a fresh 4% calibration) and prepares the replacement
/// plan at threshold `thr`.
fn build_swap_backend(
    model: &str,
    calib: &ImageDataset,
    thr: f64,
) -> (Arc<dyn SwappableEngine>, QuantConfig, QuantConfig) {
    fn plans_for<M: ImageModel>(
        m: &M,
        model: &str,
        calib: &ImageDataset,
        thr: f64,
    ) -> (QuantConfig, QuantConfig) {
        let input = collect_image_calibration(m, &calib.take(4));
        let old = plan_for(model)
            .unwrap_or_else(|_| config_for_threshold(&input, 0.04, &SearchOptions::default()));
        let new = config_for_threshold(&input, thr, &SearchOptions::default());
        (old, new)
    }
    if model == "alexnet_mini" {
        let m = alexnet_model();
        let (old, new) = plans_for(&m, model, calib, thr);
        (Arc::new(AlexNetBackend::quantized(m, &old, "alexnet-dnateq")), old, new)
    } else {
        let m = resnet_model();
        let (old, new) = plans_for(&m, model, calib, thr);
        (Arc::new(ResNetBackend::quantized(m, &old, "resnet-dnateq")), old, new)
    }
}

fn swap(args: &Args) -> Result<()> {
    let model = canonical_model(
        args.positional(0).or(args.get("model")).context("swap <model> [--thr-w T]")?,
    )?;
    if model == "transformer_mini" {
        bail!("plan hot-swap is wired for the image classifiers (alexnet_mini, resnet_mini)");
    }
    let thr = parse_thr_w(args.get("thr-w").unwrap_or("0.08"))?;
    let n: usize = args.get("requests").unwrap_or("96").parse()?;

    // Calibration inputs: trained weights + real calib split when the
    // artifacts exist, reproducible synthetic everywhere otherwise.
    let calib = ImageDataset::load(artifact_path("data"), "calib")
        .unwrap_or_else(|_| ImageDataset::synthetic(8, 0xCA11B));
    let eval = ImageDataset::load(artifact_path("data"), "eval")
        .unwrap_or_else(|_| ImageDataset::synthetic(32, 0xE7A1));

    let (backend, old_cfg, new_cfg) = build_swap_backend(model, &calib, thr);

    let version = PlanStore::open_default().save_next(&new_cfg)?;
    println!(
        "{model}: stored recalibrated plan v{version} (thr_w {:.2}%, checksum {})",
        new_cfg.thr_w * 100.0,
        new_cfg.checksum_hex()
    );

    let registry = ModelRegistry::new();
    registry.register_swappable(model, backend, CoordinatorConfig::default())?;
    println!("serving plan: {}", registry.plan_label(model)?);

    // Submit the first half, swap mid-stream, submit the rest — nothing
    // may be dropped or reordered.
    let client = registry.client(model)?;
    let mut pending = Vec::with_capacity(n);
    for i in 0..n / 2 {
        pending.push(client.submit(Payload::Image(eval.image(i % eval.len())))?);
    }
    registry.swap_plan(model, &new_cfg)?;
    println!("swapped to:   {}", registry.plan_label(model)?);
    for i in n / 2..n {
        pending.push(client.submit(Payload::Image(eval.image(i % eval.len())))?);
    }
    let mut answered = 0usize;
    for ticket in pending {
        let resp = ticket.wait().context("response dropped during hot-swap")?;
        if matches!(resp.output, Output::ClassId(_)) {
            answered += 1;
        }
    }

    let snaps = registry.shutdown_and_drain();
    println!("{model}: {answered}/{n} answered | {}", snaps[model].summary());
    let changes = diff_plans(&old_cfg, &new_cfg);
    println!("plan delta ({} change(s)):", changes.len());
    for l in changes.iter().take(12) {
        println!("  {l}");
    }
    Ok(())
}

// ---------------------------------------------------------------------

fn run() -> Result<()> {
    let args = Args::parse();
    // Global SIMD override (`--simd scalar|avx2|avx512|auto`), installed before
    // any engine is constructed so every backend binds to it.
    if let Some(v) = args.get("simd") {
        let backend = dnateq::expdot::simd::parse(v).map_err(anyhow::Error::msg)?;
        dnateq::expdot::simd::force(backend).map_err(anyhow::Error::msg)?;
    }
    match args.cmd.as_str() {
        "calibrate" => {
            let force = args.has("force");
            let quick = args.has("quick");
            let models: Vec<&str> = match args.get("model") {
                Some(m) => vec![canonical_model(m)?],
                None => MODELS.to_vec(),
            };
            for m in models {
                let o = calibrate_or_load(m, force, &calib_options(quick))?;
                println!(
                    "{m}: thr_w {:.2}% | avg bits {:.2} | compression {:.1}% | fp32 {:.4} → \
                     dnateq {:.4}",
                    o.config.thr_w * 100.0,
                    o.config.avg_bitwidth(),
                    o.config.compression_ratio() * 100.0,
                    o.fp32_accuracy,
                    o.dnateq_accuracy
                );
            }
        }
        "report" => {
            let quick = args.has("quick");
            let outcomes = all_outcomes(args.has("force"), quick)?;
            let want = |k: &str, v: &str| args.has("all") || args.get(k) == Some(v);
            let mut printed = false;
            if want("table", "1") {
                println!("{}", tables::table_rss(&outcomes, true)?);
                printed = true;
            }
            if want("table", "2") {
                println!("{}", tables::table_rss(&outcomes, false)?);
                printed = true;
            }
            if want("figure", "1") {
                println!("{}", tables::figure_fit(true)?);
                printed = true;
            }
            if want("figure", "2") {
                println!("{}", tables::figure_fit(false)?);
                printed = true;
            }
            if want("table", "3") {
                println!("{}", tables::table3(quick)?);
                printed = true;
            }
            if want("table", "4") {
                println!("{}", tables::table4(&outcomes)?);
                printed = true;
            }
            if want("table", "5") {
                println!("{}", tables::table5(&outcomes)?);
                printed = true;
            }
            if want("figure", "8") || want("figure", "9") {
                println!("{}", tables::figures_8_9(&outcomes)?);
                printed = true;
            }
            if want("figure", "10") {
                println!("{}", tables::figure10()?);
                printed = true;
            }
            if want("figure", "11") {
                println!("{}", tables::figure11(&outcomes)?);
                printed = true;
            }
            if args.has("all") || args.has("area") {
                println!("{}", tables::area_report());
                println!("{}", tables::bitwidth_histogram(&outcomes));
                printed = true;
            }
            if !printed {
                bail!("nothing selected: use --all, --table N, --figure N or --area");
            }
        }
        "simulate" => {
            let outcomes = all_outcomes(false, args.has("quick"))?;
            println!("{}", tables::figures_8_9(&outcomes)?);
            println!("{}", tables::figure10()?);
        }
        "serve" => serve(&args)?,
        "plans" => plans(&args)?,
        "swap" => swap(&args)?,
        "loadgen" => {
            // `--simd` was already consumed above; the shared CLI also
            // accepts it, so passing it through is harmless.
            let report = dnateq::loadgen::cli::run_from_flags(&args.flags)?;
            if args.has("fail-on-errors") && report.failed > 0 {
                bail!(
                    "loadgen: {} of {} requests ended in typed failures: {:?}",
                    report.failed,
                    report.offered,
                    report.failures
                );
            }
        }
        "energy" => {
            let rate: f64 = args.get("rate").unwrap_or("120").parse()?;
            let duration: f64 = args.get("duration").unwrap_or("1.0").parse()?;
            let report = dnateq::energysim::run_ci_energy(rate, duration);
            println!("{}", report.summary());
            for case in [&report.exp, &report.int8] {
                println!(
                    "  {:<16} offered {:>5}, completed {:>5}, total {:.6e} J, \
                     {:.6e} J/req, {:.6e} J/output",
                    case.plan,
                    case.offered,
                    case.completed,
                    case.energy_total_j,
                    case.j_per_request,
                    case.j_per_output,
                );
            }
            if let Some(out) = args.get("out") {
                report
                    .to_json()
                    .write_file(out)
                    .with_context(|| format!("writing energy report to {out}"))?;
                println!("JSON -> {out}");
            }
        }
        "infer" => {
            let model = match args.get("model").unwrap_or("alexnet") {
                "alexnet" | "alexnet_mini" => "alexnet",
                "resnet" | "resnet_mini" => "resnet",
                other => bail!("unknown model `{other}` for infer; known: alexnet, resnet"),
            };
            let index: usize = args.get("index").unwrap_or("0").parse()?;
            let rt = Runtime::cpu()?;
            let exe = rt.load_hlo(artifact_path(&format!("{model}_fp32.hlo.txt")))?;
            let data = ImageDataset::load(artifact_path("data"), "eval")?;
            let img = data.image(index);
            let input = Tensor::from_vec(&[1, 3, 32, 32], img.data().to_vec());
            let logits = exe.run1(&input)?;
            println!(
                "platform={} model={model} sample={index} predicted={} label={}",
                rt.platform(),
                logits.argmax(),
                data.labels[index]
            );
        }
        _ => {
            println!(
                "repro — DNA-TEQ reproduction\n\
                 usage: repro <calibrate|report|simulate|serve|plans|swap|infer|loadgen|energy> \
                 [flags]\n  \
                 calibrate [--model M] [--force] [--quick]\n  \
                 report    --all | --table N | --figure N | --area [--quick]\n  \
                 simulate  [--quick]\n  \
                 serve     [--models a,b,c] [--backend engine|quantized|pjrt] [--requests N]\n            \
                 [--admission block|reject|shed|energy-budget] [--power-envelope-watts W]\n            \
                 [--min-workers N] [--max-workers N]\n            \
                 [--plan-policy max-accuracy|min-bits|min-energy]\n  \
                 global    --simd scalar|avx2|avx512|auto   force the kernel dispatch backend\n  \
                 plans     list | show <model> [--version V] | diff <model> <v1> <v2>\n            \
                 | build <model> [--thr-w T] | front <model>\n  \
                 swap      <model> [--thr-w T] [--requests N]\n  \
                 infer     [--model alexnet|resnet] [--index I]\n  \
                 loadgen   [--engine counting|echo] [--pattern poisson|burst] [--rate R]\n            \
                 [--duration S] [--seed N] [--priority-mix h:n:l] [--admission P]\n            \
                 [--power-envelope-watts W] [--min-workers N] [--max-workers N]\n            \
                 [--out BENCH_loadgen.json]\n  \
                 energy    [--rate R] [--duration S] [--out BENCH_energy.json]\n            \
                 seeded exp-vs-INT8 joules/request co-simulation through the batcher"
            );
        }
    }
    Ok(())
}
