//! `repro` — DNA-TEQ reproduction CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   calibrate [--model M] [--force]   run the Fig.-3 pipeline (cached)
//!   report    [--all|--table N|--figure N|--area] regenerate exhibits
//!   simulate                          accelerator comparison (Figs. 8/9)
//!   serve     [--model M] [--requests N] [--backend engine|pjrt|quantized]
//!   infer     [--model M] [--index I] one PJRT inference from artifacts

use anyhow::{bail, Context, Result};
use dnateq::coordinator::{
    AlexNetBackend, Coordinator, CoordinatorConfig, Payload, PjrtClassifierBackend,
};
use dnateq::dataset::ImageDataset;
use dnateq::dnateq::CalibrationOptions;
use dnateq::report::{calibrate_or_load, tables, CalibOutcome, MODELS};
use dnateq::runtime::Runtime;
use dnateq::{artifact_path, nn::AlexNetMini, nn::WeightMap};
use std::collections::BTreeMap;
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` and bare flags.
struct Args {
    cmd: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Self {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut flags = BTreeMap::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let k = rest[i].trim_start_matches('-').to_string();
            if i + 1 < rest.len() && !rest[i + 1].starts_with('-') {
                flags.insert(k, rest[i + 1].clone());
                i += 2;
            } else {
                flags.insert(k, "true".into());
                i += 1;
            }
        }
        Self { cmd, flags }
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn has(&self, k: &str) -> bool {
        self.flags.contains_key(k)
    }
}

fn calib_options(quick: bool) -> CalibrationOptions {
    let mut o = CalibrationOptions::default();
    if quick {
        o.thr_max = 0.10;
    }
    o
}

fn all_outcomes(force: bool, quick: bool) -> Result<BTreeMap<String, CalibOutcome>> {
    let opts = calib_options(quick);
    MODELS
        .iter()
        .map(|m| Ok((m.to_string(), calibrate_or_load(m, force, &opts)?)))
        .collect()
}

fn run() -> Result<()> {
    let args = Args::parse();
    match args.cmd.as_str() {
        "calibrate" => {
            let force = args.has("force");
            let quick = args.has("quick");
            let models: Vec<&str> = match args.get("model") {
                Some(m) => vec![m],
                None => MODELS.to_vec(),
            };
            for m in models {
                let o = calibrate_or_load(m, force, &calib_options(quick))?;
                println!(
                    "{m}: thr_w {:.2}% | avg bits {:.2} | compression {:.1}% | fp32 {:.4} → dnateq {:.4}",
                    o.config.thr_w * 100.0,
                    o.config.avg_bitwidth(),
                    o.config.compression_ratio() * 100.0,
                    o.fp32_accuracy,
                    o.dnateq_accuracy
                );
            }
        }
        "report" => {
            let quick = args.has("quick");
            let outcomes = all_outcomes(args.has("force"), quick)?;
            let want = |k: &str, v: &str| {
                args.has("all") || args.get(k) == Some(v)
            };
            let mut printed = false;
            if want("table", "1") {
                println!("{}", tables::table_rss(&outcomes, true)?);
                printed = true;
            }
            if want("table", "2") {
                println!("{}", tables::table_rss(&outcomes, false)?);
                printed = true;
            }
            if want("figure", "1") {
                println!("{}", tables::figure_fit(true)?);
                printed = true;
            }
            if want("figure", "2") {
                println!("{}", tables::figure_fit(false)?);
                printed = true;
            }
            if want("table", "3") {
                println!("{}", tables::table3(quick)?);
                printed = true;
            }
            if want("table", "4") {
                println!("{}", tables::table4(&outcomes)?);
                printed = true;
            }
            if want("table", "5") {
                println!("{}", tables::table5(&outcomes)?);
                printed = true;
            }
            if want("figure", "8") || want("figure", "9") {
                println!("{}", tables::figures_8_9(&outcomes)?);
                printed = true;
            }
            if want("figure", "10") {
                println!("{}", tables::figure10()?);
                printed = true;
            }
            if want("figure", "11") {
                println!("{}", tables::figure11(&outcomes)?);
                printed = true;
            }
            if args.has("all") || args.has("area") {
                println!("{}", tables::area_report());
                println!("{}", tables::bitwidth_histogram(&outcomes));
                printed = true;
            }
            if !printed {
                bail!("nothing selected: use --all, --table N, --figure N or --area");
            }
        }
        "simulate" => {
            let outcomes = all_outcomes(false, args.has("quick"))?;
            println!("{}", tables::figures_8_9(&outcomes)?);
            println!("{}", tables::figure10()?);
        }
        "serve" => {
            let n: usize = args.get("requests").unwrap_or("64").parse()?;
            let backend_kind = args.get("backend").unwrap_or("engine");
            let data = ImageDataset::load(artifact_path("data"), "eval")?;
            let cfg = CoordinatorConfig::default();
            let coordinator = match backend_kind {
                "pjrt" => Coordinator::start(
                    Arc::new(PjrtClassifierBackend::spawn(artifact_path("alexnet_fp32.hlo.txt"))?),
                    cfg,
                ),
                "quantized" => {
                    let w = WeightMap::load_dir(artifact_path("models/alexnet_mini"))?;
                    let model = AlexNetMini::from_weights(&w)?;
                    let o = calibrate_or_load("alexnet_mini", false, &calib_options(true))?;
                    Coordinator::start(
                        Arc::new(AlexNetBackend::quantized(model, &o.config, "alexnet-dnateq")),
                        cfg,
                    )
                }
                _ => {
                    let w = WeightMap::load_dir(artifact_path("models/alexnet_mini"))?;
                    Coordinator::start(
                        Arc::new(AlexNetBackend::fp32(AlexNetMini::from_weights(&w)?, "alexnet-fp32")),
                        cfg,
                    )
                }
            };
            let mut hits = 0usize;
            let mut rxs = Vec::new();
            for i in 0..n {
                rxs.push((i % data.len(), coordinator.submit(Payload::Image(data.image(i % data.len())))?));
            }
            for (idx, rx) in rxs {
                let resp = rx.recv().context("response channel closed")?;
                if let dnateq::coordinator::Output::ClassId(k) = resp.output {
                    if k == data.labels[idx] {
                        hits += 1;
                    }
                }
            }
            let snap = coordinator.shutdown();
            println!("backend={backend_kind} accuracy={:.4}", hits as f64 / n as f64);
            println!("{}", snap.summary());
        }
        "infer" => {
            let model = args.get("model").unwrap_or("alexnet");
            let index: usize = args.get("index").unwrap_or("0").parse()?;
            let rt = Runtime::cpu()?;
            let exe = rt.load_hlo(artifact_path(&format!("{model}_fp32.hlo.txt")))?;
            let data = ImageDataset::load(artifact_path("data"), "eval")?;
            let img = data.image(index);
            let input = dnateq::tensor::Tensor::from_vec(&[1, 3, 32, 32], img.data().to_vec());
            let logits = exe.run1(&input)?;
            println!(
                "platform={} model={model} sample={index} predicted={} label={}",
                rt.platform(),
                logits.argmax(),
                data.labels[index]
            );
        }
        "help" | _ => {
            println!(
                "repro — DNA-TEQ reproduction\n\
                 usage: repro <calibrate|report|simulate|serve|infer> [flags]\n  \
                 calibrate [--model M] [--force] [--quick]\n  \
                 report    --all | --table N | --figure N | --area [--quick]\n  \
                 simulate  [--quick]\n  \
                 serve     [--backend engine|pjrt|quantized] [--requests N]\n  \
                 infer     [--model alexnet|resnet] [--index I]"
            );
        }
    }
    Ok(())
}
