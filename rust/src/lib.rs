//! # DNA-TEQ — Adaptive Exponential Quantization of Tensors for DNN Inference
//!
//! Reproduction of *DNA-TEQ* (Khabbazan, Riera, González, 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the complete system: calibration pipeline
//!   (distribution analysis, Algorithm-1 base search, bitwidth selection),
//!   the exponential-domain dot-product engine, an f32 inference engine for
//!   the evaluated model zoo, a cycle-level simulator of the DNA-TEQ
//!   accelerator vs. an INT8 baseline, and a serving coordinator that runs
//!   AOT-compiled model artifacts through PJRT.
//! * **L2 (python/compile)** — JAX model definitions + build-time training,
//!   lowered once to HLO text artifacts (`make artifacts`).
//! * **L1 (python/compile/kernels)** — Pallas kernels for exponential
//!   quantization and the counting dot-product, validated against pure-jnp
//!   oracles.
//!
//! Python never runs on the request path; the rust binary is self-contained
//! once `artifacts/` exists.
//!
//! ## Crate map
//!
//! | module | paper section | role |
//! |---|---|---|
//! | [`tensor`] | — | nd-array substrate + binary interchange with python |
//! | [`dataset`] | §VI-A | synthetic workload readers/generators |
//! | [`nn`] | §VI-A | f32 inference engine + mini model zoo |
//! | [`dnateq`] | §III | the quantization methodology (the contribution) |
//! | [`expdot`] | §III-C, §IV | **batched** exponential counting-GEMM engines + INT8 baseline |
//! | [`accel`] | §V, §VI-C/D | 3D-stacked accelerator simulator + energy |
//! | [`energysim`] | §VI-C/D | energy co-simulation: accelerator-sim `Engine` decorator, joules/request metrics, power-envelope admission, seeded `ci-energy` gate |
//! | [`runtime`] | — | PJRT loading/execution of AOT artifacts (feature `pjrt`) |
//! | [`coordinator`] | — | serving: typed `InferenceClient`/`Ticket` API over fallible `Engine`s, priority queue + admission policies, continuous batching, autoscaling pools, registry, hot-swap, metrics |
//! | [`loadgen`] | — | open-loop Poisson/bursty load generator + per-priority p50/p99/p999 recorder (`BENCH_loadgen.json`, tail-latency SLO gate) |
//! | [`report`] | §VI | table/figure emitters for every paper exhibit |
//!
//! ## Build / test / bench
//!
//! ```bash
//! cargo build --release && cargo test -q   # tier-1 gate (make verify)
//! cargo bench --bench table3_simd_fc       # FC latency, batch ∈ {1, 8, 32}
//! cargo bench --bench e2e_serving          # serving throughput vs max_batch
//! ```
//!
//! The `expdot` engines are batched: [`expdot::CountingFc::forward_batch`]
//! quantizes activations once per batch and register-blocks over output
//! rows *and* batch columns (bit-identical to stacked batch-1 forwards);
//! the serving backends forward whole batches through it.

pub mod accel;
pub mod coordinator;
pub mod dataset;
pub mod dnateq;
pub mod energysim;
pub mod expdot;
pub mod loadgen;
pub mod nn;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Canonical location of build artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve a path under the artifacts directory, honoring the
/// `DNATEQ_ARTIFACTS` environment variable (used by tests and examples run
/// from other working directories).
pub fn artifact_path(rel: &str) -> std::path::PathBuf {
    let base = std::env::var("DNATEQ_ARTIFACTS").unwrap_or_else(|_| {
        // Walk up from CWD looking for an `artifacts/` dir so examples work
        // from target/ subdirectories too.
        let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
        loop {
            let cand = dir.join(ARTIFACTS_DIR);
            if cand.is_dir() {
                return cand.to_string_lossy().into_owned();
            }
            if !dir.pop() {
                return ARTIFACTS_DIR.to_string();
            }
        }
    });
    std::path::Path::new(&base).join(rel)
}
