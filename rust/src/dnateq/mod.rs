//! DNA-TEQ — the paper's contribution (§III) plus the hybrid planner
//! built on top of it.
//!
//! Tensors are represented as `x̄ = sign(x) · (α·bⁱ + β)` with per-layer
//! parameters found by an adaptive offline search:
//!
//! 1. [`rss`] — goodness-of-fit analysis selecting the tensor that starts
//!    the base search (step 2 of Fig. 3; Tables I & II).
//! 2. [`search`] — Algorithm 1 (`SOB`), the unified [`Planner`] over a
//!    scheme × bit-width [`SearchSpace`] (the paper's 3→7-bit exp sweep
//!    or the full {exp, uniform, pwl} × 2..=8 space), and the
//!    Pareto-front search producing a [`PlanSet`].
//! 3. [`quant`] — the exponential quantizer itself (Eqs. 2–5) and RMAE
//!    (Eq. 6).
//! 4. [`uniform`] — the linear INT-n baseline DNA-TEQ is compared against
//!    (Tables IV & V).
//! 5. [`pwl`] — piecewise-linear quantization for outlier-heavy layers
//!    (PWLQ-style), the third scheme of the hybrid space.
//! 6. [`calib`] — end-to-end calibration of a model: traces → [`config`].
//! 7. [`plans`] — versioned, checksummed on-disk store for the resulting
//!    plan artifacts (`artifacts/plans/<model>/<version>.json`) plus the
//!    per-model Pareto-front index (`front.json`) and the SLA
//!    [`PlanPolicy`] that picks a front point at serve time.

pub mod calib;
pub mod config;
pub mod plans;
pub mod pwl;
pub mod quant;
pub mod rss;
pub mod search;
pub mod uniform;

pub use calib::{
    calibrate_model, config_for_threshold, CalibrationInput, CalibrationOptions,
    CalibrationReport, LayerTensors, SweepPoint,
};
pub use config::{LayerKind, LayerQuant, PLAN_SCHEMA_VERSION, QuantConfig, Scheme, TensorQuant};
pub use plans::{
    diff_plans, render_front, render_plan, store_index_json, FrontIndex, FrontPoint, PlanPolicy,
    PlanStore, PlanSummary,
};
pub use pwl::PwlParams;
pub use quant::{ExpQuantParams, QuantizedTensor, ZERO_CODE_SENTINEL};
pub use rss::{fit_distributions, DistKind, FitReport};
pub use search::{
    search_base, search_layer, LayerCandidate, LayerSearchResult, PlanPoint, PlanSet, Planner,
    SearchOptions, SearchSpace,
};
pub use uniform::UniformParams;
