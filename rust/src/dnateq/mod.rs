//! DNA-TEQ — the paper's contribution (§III).
//!
//! Tensors are represented as `x̄ = sign(x) · (α·bⁱ + β)` with per-layer
//! parameters found by an adaptive offline search:
//!
//! 1. [`rss`] — goodness-of-fit analysis selecting the tensor that starts
//!    the base search (step 2 of Fig. 3; Tables I & II).
//! 2. [`search`] — Algorithm 1 (`SOB`) plus the bitwidth loop (3→7 bits)
//!    and the network-level `Thr_w` controller (step 3–4 of Fig. 3;
//!    Fig. 11).
//! 3. [`quant`] — the quantizer itself (Eqs. 2–5) and RMAE (Eq. 6).
//! 4. [`uniform`] — the linear INT-n baseline DNA-TEQ is compared against
//!    (Tables IV & V).
//! 5. [`calib`] — end-to-end calibration of a model: traces → [`config`].
//! 6. [`plans`] — versioned, checksummed on-disk store for the resulting
//!    plan artifacts (`artifacts/plans/<model>/<version>.json`).

pub mod calib;
pub mod config;
pub mod plans;
pub mod quant;
pub mod rss;
pub mod search;
pub mod uniform;

pub use calib::{
    calibrate_model, config_for_threshold, CalibrationInput, CalibrationOptions,
    CalibrationReport, LayerTensors, SweepPoint,
};
pub use config::{LayerKind, LayerQuant, PLAN_SCHEMA_VERSION, QuantConfig, TensorQuant};
pub use plans::{diff_plans, render_plan, store_index_json, PlanStore, PlanSummary};
pub use quant::{ExpQuantParams, QuantizedTensor, ZERO_CODE_SENTINEL};
pub use rss::{fit_distributions, DistKind, FitReport};
pub use search::{search_base, search_layer, LayerSearchResult, SearchOptions};
pub use uniform::UniformParams;
