//! Piecewise-linear quantization (PWLQ-style; arXiv 2002.00104) for
//! outlier-heavy tensors.
//!
//! `|x|` is split into contiguous regions by ascending breakpoints; each
//! region carries its own uniform grid. An `n`-bit code spends 1 sign
//! bit, `region_bits` to index the region, and the remaining
//! `level_bits = n − 1 − region_bits` on the in-region level, so the
//! accounting is storage-honest like [`super::uniform`]. Code 0 decodes
//! to exactly 0.0, keeping the zero-is-exact contract of
//! [`super::quant`].

use crate::tensor::{Tensor, TensorI8};
use anyhow::{bail, Result};

/// Parameters of a piecewise-linear quantizer over `|x|`.
#[derive(Clone, Debug, PartialEq)]
pub struct PwlParams {
    /// Ascending region upper edges; the last edge is the clip max. A
    /// quantizer with `k` interior breakpoints stores `k + 1` edges.
    pub breaks: Vec<f64>,
    /// Per-region step size Δ (same length as `breaks`).
    pub deltas: Vec<f64>,
    /// Total code bitwidth: sign + region index + level.
    pub n_bits: u8,
}

/// Bits needed to index `regions` regions (`ceil(log2(regions))`).
fn region_bits_for(regions: usize) -> u8 {
    debug_assert!(regions >= 1);
    (usize::BITS - (regions - 1).leading_zeros()).min(7) as u8
}

impl PwlParams {
    pub fn regions(&self) -> usize {
        self.breaks.len()
    }

    /// Interior breakpoint count (the `breaks` of [`Scheme::Pwl`]).
    ///
    /// [`Scheme::Pwl`]: super::config::Scheme::Pwl
    pub fn interior_breaks(&self) -> u8 {
        (self.breaks.len() - 1) as u8
    }

    pub fn region_bits(&self) -> u8 {
        region_bits_for(self.regions())
    }

    /// In-region level count: `2^{n − 1 − region_bits}`.
    pub fn levels(&self) -> usize {
        1usize << (self.n_bits - 1 - self.region_bits())
    }

    /// First-region step Δ₀ (recorded as `TensorQuant::alpha`).
    pub fn first_delta(&self) -> f64 {
        self.deltas[0]
    }

    /// First region edge (recorded as `TensorQuant::beta`).
    pub fn first_break(&self) -> f64 {
        self.breaks[0]
    }

    /// Calibrate a quantizer with `n_breaks` interior breakpoints on `t`.
    ///
    /// A single breakpoint is grid-searched over high quantiles of the
    /// nonzero magnitudes (minimizing RMAE); more breakpoints land on
    /// evenly spaced quantiles. Deterministic: depends only on the tensor
    /// contents.
    pub fn calibrate(t: &Tensor, n_bits: u8, n_breaks: u8) -> Self {
        assert!(n_breaks >= 1, "pwl needs at least one interior breakpoint");
        let regions = n_breaks as usize + 1;
        let region_bits = region_bits_for(regions);
        assert!(
            n_bits >= region_bits + 2 && n_bits <= 8,
            "pwl bitwidth {n_bits} out of range for {regions} regions"
        );
        let max = t.data().iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64;
        if max <= 0.0 {
            // All-zero tensor: any positive grid is fine; everything
            // encodes to code 0 and decodes to exactly 0.0.
            let edges: Vec<f64> = (1..=regions).map(|r| r as f64 / regions as f64).collect();
            return Self::from_edges(edges, n_bits);
        }
        let mut mags: Vec<f64> =
            t.data().iter().map(|x| x.abs() as f64).filter(|&m| m > 0.0).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let quantile = |q: f64| -> f64 {
            let i = ((mags.len() - 1) as f64 * q).round() as usize;
            mags[i]
        };
        if regions == 2 {
            // One breakpoint: pick the RMAE-minimizing high quantile.
            let mut best: Option<(f64, Self)> = None;
            for q in [0.5, 0.7, 0.8, 0.9, 0.95] {
                let b = quantile(q);
                if b <= 0.0 || b >= max {
                    continue;
                }
                let cand = Self::from_edges(vec![b, max], n_bits);
                let e = cand.rmae(t);
                if best.as_ref().map(|(be, _)| e < *be).unwrap_or(true) {
                    best = Some((e, cand));
                }
            }
            match best {
                Some((_, p)) => p,
                None => Self::from_edges(vec![max * 0.5, max], n_bits),
            }
        } else {
            let mut edges = Vec::with_capacity(regions);
            let mut prev = 0.0f64;
            for r in 1..regions {
                let mut e = quantile(r as f64 / regions as f64);
                let floor = prev + max * 1e-9;
                if e <= floor {
                    e = floor;
                }
                edges.push(e.min(max * (1.0 - 1e-9)));
                prev = *edges.last().unwrap();
            }
            edges.push(max.max(prev + max * 1e-9));
            Self::from_edges(edges, n_bits)
        }
    }

    /// Build params from explicit ascending region edges.
    fn from_edges(edges: Vec<f64>, n_bits: u8) -> Self {
        let region_bits = region_bits_for(edges.len());
        let levels = (1usize << (n_bits - 1 - region_bits)) as f64;
        let deltas = edges
            .iter()
            .scan(0.0f64, |lo, &hi| {
                let d = (hi - *lo) / (levels - 1.0);
                *lo = hi;
                Some(d)
            })
            .collect();
        Self { breaks: edges, deltas, n_bits }
    }

    /// Lower edge of region `r`.
    fn lo(&self, r: usize) -> f64 {
        if r == 0 {
            0.0
        } else {
            self.breaks[r - 1]
        }
    }

    #[inline]
    pub fn encode(&self, x: f32) -> i8 {
        let m = x.abs() as f64;
        if m == 0.0 {
            return 0;
        }
        let regions = self.regions();
        let mut r = regions - 1; // clip above the top edge
        for (i, &hi) in self.breaks.iter().enumerate() {
            if m <= hi {
                r = i;
                break;
            }
        }
        let levels = self.levels();
        let k = (((m - self.lo(r)) / self.deltas[r]).round() as i64).clamp(0, levels as i64 - 1);
        let idx = (r * levels) as i64 + k; // < 2^{n-1} ≤ 128
        if x < 0.0 {
            -(idx as i8)
        } else {
            idx as i8
        }
    }

    #[inline]
    pub fn decode(&self, q: i8) -> f32 {
        if q == 0 {
            return 0.0;
        }
        let levels = self.levels();
        let idx = q.unsigned_abs() as usize;
        let r = (idx / levels).min(self.regions() - 1);
        let k = idx % levels;
        let mag = self.lo(r) + k as f64 * self.deltas[r];
        if q < 0 {
            -mag as f32
        } else {
            mag as f32
        }
    }

    pub fn quantize(&self, t: &Tensor) -> TensorI8 {
        TensorI8::from_vec(t.shape(), t.data().iter().map(|&x| self.encode(x)).collect())
    }

    pub fn dequantize(&self, q: &TensorI8) -> Tensor {
        Tensor::from_vec(q.shape(), q.data().iter().map(|&v| self.decode(v)).collect())
    }

    /// Quantize-dequantize roundtrip for error/accuracy evaluation.
    pub fn roundtrip(&self, t: &Tensor) -> Tensor {
        t.map(|x| self.decode(self.encode(x)))
    }

    /// RMAE (Eq. 6) of this quantizer on `t`.
    pub fn rmae(&self, t: &Tensor) -> f64 {
        let denom: f64 = t.data().iter().map(|&x| x.abs() as f64).sum();
        if denom == 0.0 {
            return 0.0;
        }
        let num: f64 = t
            .data()
            .iter()
            .map(|&x| (self.decode(self.encode(x)) as f64 - x as f64).abs())
            .sum();
        num / denom
    }

    /// Stored bits per element (sign + region + level — all of `n_bits`).
    pub fn bits_per_element(&self) -> f64 {
        self.n_bits as f64
    }

    /// Reject parameter sets that cannot have come from a well-formed
    /// calibration, mirroring the other quantizers' artifact-boundary
    /// checks.
    pub fn validate(&self) -> Result<()> {
        if self.breaks.is_empty() || self.breaks.len() != self.deltas.len() {
            bail!(
                "pwl params need matching non-empty breaks/deltas ({} vs {})",
                self.breaks.len(),
                self.deltas.len()
            );
        }
        let region_bits = self.region_bits();
        if self.n_bits < region_bits + 2 || self.n_bits > 8 {
            bail!(
                "pwl bitwidth {} out of range for {} regions",
                self.n_bits,
                self.regions()
            );
        }
        let mut prev = 0.0f64;
        for (&b, &d) in self.breaks.iter().zip(&self.deltas) {
            if !b.is_finite() || b <= prev {
                bail!("pwl breaks must be finite, positive and ascending (got {b})");
            }
            if !d.is_finite() || d <= 0.0 {
                bail!("pwl step {d} must be finite and positive");
            }
            prev = b;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnateq::uniform::UniformParams;
    use crate::tensor::SplitMix64;

    /// Mostly-small tensor with a sprinkle of large outliers — the shape
    /// PWLQ is built for.
    fn outlier_tensor(n: usize, seed: u64) -> Tensor {
        let mut rng = SplitMix64::new(seed);
        let bulk = Tensor::rand_normal(&[n], 0.0, 0.05, &mut rng);
        let mut data = bulk.data().to_vec();
        for i in (0..n).step_by(97) {
            data[i] *= 50.0;
        }
        Tensor::from_vec(&[n], data)
    }

    #[test]
    fn beats_uniform_on_outlier_heavy_data() {
        let t = outlier_tensor(8192, 11);
        for n in [4u8, 6] {
            let p = PwlParams::calibrate(&t, n, 1);
            let u = UniformParams::calibrate(&t, n);
            assert!(
                p.rmae(&t) < u.rmae(&t),
                "n={n}: pwl {} should beat uniform {}",
                p.rmae(&t),
                u.rmae(&t)
            );
        }
    }

    #[test]
    fn zero_maps_to_zero_exactly() {
        let t = Tensor::from_vec(&[4], vec![0.0, 1.0, -2.0, 0.0]);
        let p = PwlParams::calibrate(&t, 4, 1);
        let d = p.roundtrip(&t);
        assert_eq!(d.data()[0], 0.0);
        assert_eq!(d.data()[3], 0.0);
        assert_eq!(p.encode(0.0), 0);
    }

    #[test]
    fn sign_is_preserved_and_codes_in_range() {
        let t = outlier_tensor(2048, 12);
        let p = PwlParams::calibrate(&t, 5, 1);
        let limit = (p.regions() * p.levels()) as i32; // 2^{n-1}
        for &x in t.data() {
            let q = p.encode(x);
            assert!((q as i32).abs() < limit, "code {q} out of range");
            if x != 0.0 && q != 0 {
                assert_eq!(x.signum(), p.decode(q).signum(), "sign flip at {x}");
            }
        }
    }

    #[test]
    fn calibrate_is_deterministic() {
        let t = outlier_tensor(1024, 13);
        for breaks in [1u8, 3] {
            let a = PwlParams::calibrate(&t, 6, breaks);
            let b = PwlParams::calibrate(&t, 6, breaks);
            assert_eq!(a, b);
            assert_eq!(a.regions(), breaks as usize + 1);
            a.validate().unwrap();
        }
    }

    #[test]
    fn zero_tensor_is_safe() {
        let t = Tensor::zeros(&[16]);
        let p = PwlParams::calibrate(&t, 4, 1);
        p.validate().unwrap();
        assert_eq!(p.rmae(&t), 0.0);
        assert_eq!(p.roundtrip(&t).data(), t.data());
    }

    #[test]
    fn validate_rejects_degenerate_params() {
        let ok = PwlParams::calibrate(&outlier_tensor(512, 14), 5, 1);
        assert!(ok.validate().is_ok());
        let mut bad = ok.clone();
        bad.breaks[1] = bad.breaks[0]; // not ascending
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.deltas[0] = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.n_bits = 2; // no room for sign + region + level
        assert!(bad.validate().is_err());
        let mut bad = ok;
        bad.breaks[0] = f64::NAN;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn rmae_decreases_with_bitwidth() {
        let t = outlier_tensor(4096, 15);
        let mut prev = f64::INFINITY;
        for n in [3u8, 4, 5, 6, 8] {
            let p = PwlParams::calibrate(&t, n, 1);
            let e = p.rmae(&t);
            assert!(e < prev * 1.05, "n={n}: RMAE {e} vs prev {prev}");
            prev = e;
        }
        assert!(prev < 0.05, "8-bit pwl RMAE too high: {prev}");
    }
}
