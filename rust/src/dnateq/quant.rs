//! The exponential quantizer (Eqs. 2–5) and its parameter initialization.
//!
//! A tensor element `x` is stored as a sign bit plus an `n`-bit signed
//! exponent code `i`, reconstructing to `x̄ = sign(x)·(α·bⁱ + β)`.
//! The code `-(2^{n-1})` (one below `R_min`) is reserved for exact zero
//! (§III-B), so an `n`-bit quantization has `2ⁿ - 1` usable intervals.

use crate::tensor::Tensor;

/// Reserved exponent code for exact zeros: `-(2^{n-1})`, i.e. `R_min - 1`.
/// Stored here as the i8 sentinel for the widest supported n (n ≤ 7 keeps
/// every code in i8 range).
pub const ZERO_CODE_SENTINEL: i8 = i8::MIN; // normalized sentinel in memory

/// Per-tensor exponential quantization parameters
/// (`x̄ = sign(x)·(α·bⁱ + β)` with `i ∈ [R_min, R_max]`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExpQuantParams {
    /// Exponential base `b` (shared between both tensors of a layer).
    pub base: f64,
    /// Scale factor `α`.
    pub alpha: f64,
    /// Offset `β`.
    pub beta: f64,
    /// Exponent bitwidth `n` (3..=7); codes live in `[-(2^{n-1}-1), 2^{n-1}-1]`.
    pub n_bits: u8,
}

impl ExpQuantParams {
    /// `R_max = 2^{n-1} - 1` (Eq. 2).
    pub fn r_max(&self) -> i32 {
        (1i32 << (self.n_bits - 1)) - 1
    }

    /// `R_min = -(2^{n-1} - 1)`.
    pub fn r_min(&self) -> i32 {
        -self.r_max()
    }

    /// Number of distinct representable magnitudes (`2ⁿ - 1` intervals).
    pub fn levels(&self) -> usize {
        (1usize << self.n_bits) - 1
    }

    /// Initialize `b` and `α` for a tensor per Eq. 4, covering the full
    /// scale range (FSR): `α·b^{R_max} = max(|t|)`.
    ///
    /// Eq. 4's literal init `b = max(t)^{1/R_max}` (which makes `α = 1`)
    /// assumes `max(|t|) > 1`; for sub-unit tensors (typical weights) it
    /// would produce a degenerate base `b ≤ 1`. In that case we initialize
    /// from the tensor's dynamic range instead —
    /// `b = (max/min_nz)^{1/(R_max - R_min)}` — which covers the same FSR
    /// and hands a well-formed starting point to Algorithm 1's search
    /// (documented in DESIGN.md §Substitutions).
    pub fn init_for_tensor(t: &Tensor, n_bits: u8) -> Self {
        let max = t.abs_max() as f64;
        let min_nz = {
            let m = t.abs_min_nonzero() as f64;
            if m.is_finite() {
                m
            } else {
                1e-6
            }
        };
        let r_max = ((1i32 << (n_bits - 1)) - 1) as f64;
        let mut base = if max > 1.0 {
            max.powf(1.0 / r_max)
        } else {
            (max.max(1e-12) / min_nz.min(max).max(1e-12)).powf(1.0 / (2.0 * r_max))
        };
        base = base.max(MIN_BASE);
        let mut p = Self { base, alpha: 1.0, beta: 0.0, n_bits };
        p.refit_scale_offset(t);
        p
    }

    /// Recompute `α` (FSR coverage, Eq. 4) and `β` (Eq. 5) for the current
    /// base against a tensor — the `Update(α, β, NewBase)` step of
    /// Algorithm 1.
    pub fn refit_scale_offset(&mut self, t: &Tensor) {
        let max = t.abs_max() as f64;
        let min_nz = {
            let m = t.abs_min_nonzero() as f64;
            if m.is_finite() {
                m
            } else {
                0.0
            }
        };
        let r_max = self.r_max() as f64;
        let r_min = self.r_min() as f64;
        // α so that the top interval reaches the tensor max (FSR).
        self.alpha = if max > 0.0 { max / self.base.powf(r_max) } else { 1.0 };
        // Eq. 5: β = min(t) − α·b^{R_min − 0.5}; the two-term form in the
        // paper telescopes to this (term 1 shifts intervals to the tensor
        // minimum, term 2 compensates the rounding boundary).
        self.beta = min_nz - self.alpha * self.base.powf(r_min - 0.5);
    }

    /// Quantize one magnitude to an exponent code (Eq. 2). Caller handles
    /// the zero special case.
    #[inline]
    pub fn encode_magnitude(&self, mag: f64) -> i32 {
        debug_assert!(mag > 0.0);
        let arg = (mag - self.beta) / self.alpha;
        if arg <= 0.0 {
            // Below the smallest representable magnitude: clamp to R_min.
            return self.r_min();
        }
        let i = (arg.ln() / self.base.ln()).round() as i64;
        i.clamp(self.r_min() as i64, self.r_max() as i64) as i32
    }

    /// Reconstruct a magnitude from an exponent code.
    #[inline]
    pub fn decode_magnitude(&self, code: i32) -> f64 {
        self.alpha * self.base.powi(code) + self.beta
    }

    /// Quantize a full tensor into sign/exponent storage.
    pub fn quantize(&self, t: &Tensor) -> QuantizedTensor {
        let mut codes = Vec::with_capacity(t.len());
        let mut signs = Vec::with_capacity(t.len());
        for &x in t.data() {
            if x == 0.0 {
                codes.push(ZERO_CODE_SENTINEL);
                signs.push(1i8);
            } else {
                codes.push(self.encode_magnitude(x.abs() as f64) as i8);
                signs.push(if x < 0.0 { -1 } else { 1 });
            }
        }
        QuantizedTensor { shape: t.shape().to_vec(), codes, signs, params: *self }
    }

    /// Quantize-then-dequantize (the "fake quant" path used for error and
    /// accuracy evaluation).
    pub fn roundtrip(&self, t: &Tensor) -> Tensor {
        let data = t
            .data()
            .iter()
            .map(|&x| {
                if x == 0.0 {
                    0.0
                } else {
                    let code = self.encode_magnitude(x.abs() as f64);
                    let mag = self.decode_magnitude(code);
                    (x.signum() as f64 * mag) as f32
                }
            })
            .collect();
        Tensor::from_vec(t.shape(), data)
    }

    /// RMAE (Eq. 6) of quantizing `t` with these parameters.
    pub fn rmae(&self, t: &Tensor) -> f64 {
        let denom: f64 = t.data().iter().map(|&x| x.abs() as f64).sum();
        if denom == 0.0 {
            return 0.0;
        }
        let mut num = 0.0f64;
        for &x in t.data() {
            if x == 0.0 {
                continue; // exact zero code
            }
            let code = self.encode_magnitude(x.abs() as f64);
            let mag = self.decode_magnitude(code);
            num += (x.abs() as f64 - mag).abs();
        }
        num / denom
    }

    /// Effective stored bits per element. The paper's averages (Table V)
    /// count the exponent bitwidth `n`; the sign bit is reported
    /// separately in EXPERIMENTS.md.
    pub fn bits_per_element(&self) -> f64 {
        self.n_bits as f64
    }

    /// Reject parameter sets that cannot have come from a well-formed
    /// calibration: non-finite scale/offset, a degenerate base, or a
    /// bitwidth outside the representable code range. Plan-artifact
    /// loading runs this so corrupted or hand-edited JSON fails with a
    /// clear error instead of NaNs at inference time.
    pub fn validate(&self) -> anyhow::Result<()> {
        if !(1..=7).contains(&self.n_bits) {
            anyhow::bail!("n_bits {} outside supported range 1..=7", self.n_bits);
        }
        if !self.base.is_finite() || self.base <= 1.0 {
            anyhow::bail!("exponential base {} must be finite and > 1", self.base);
        }
        if !self.alpha.is_finite() || !self.beta.is_finite() {
            anyhow::bail!("non-finite scale/offset (alpha {}, beta {})", self.alpha, self.beta);
        }
        Ok(())
    }
}

/// Floor for the exponential base: `b ≤ 1` makes the level set
/// non-monotone/degenerate, so initialization and search clamp here.
pub const MIN_BASE: f64 = 1.0001;

/// A tensor stored in DNA-TEQ form: per-element sign and `n`-bit exponent
/// code (zeros use [`ZERO_CODE_SENTINEL`]).
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub shape: Vec<usize>,
    /// Exponent codes in `[R_min, R_max]`, or `ZERO_CODE_SENTINEL`.
    pub codes: Vec<i8>,
    /// `+1` / `-1` (sign of the original value; `+1` for zeros).
    pub signs: Vec<i8>,
    pub params: ExpQuantParams,
}

impl QuantizedTensor {
    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Dequantize back to f32.
    pub fn dequantize(&self) -> Tensor {
        let data = self
            .codes
            .iter()
            .zip(&self.signs)
            .map(|(&c, &s)| {
                if c == ZERO_CODE_SENTINEL {
                    0.0
                } else {
                    (s as f64 * self.params.decode_magnitude(c as i32)) as f32
                }
            })
            .collect();
        Tensor::from_vec(&self.shape, data)
    }

    /// Memory footprint in bits (n exponent bits + 1 sign bit per element),
    /// the honest storage accounting.
    pub fn storage_bits(&self) -> usize {
        self.len() * (self.params.n_bits as usize + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn expo_tensor(n: usize, seed: u64) -> Tensor {
        let mut rng = SplitMix64::new(seed);
        Tensor::rand_signed_exponential(&[n], 3.0, &mut rng)
    }

    #[test]
    fn r_bounds_match_paper() {
        let p = ExpQuantParams { base: 1.3, alpha: 1.0, beta: 0.0, n_bits: 3 };
        assert_eq!(p.r_max(), 3);
        assert_eq!(p.r_min(), -3);
        assert_eq!(p.levels(), 7);
        let p7 = ExpQuantParams { base: 1.1, alpha: 1.0, beta: 0.0, n_bits: 7 };
        assert_eq!(p7.r_max(), 63);
    }

    #[test]
    fn init_covers_full_scale_range() {
        let t = expo_tensor(4096, 1);
        for n in 3..=7u8 {
            let p = ExpQuantParams::init_for_tensor(&t, n);
            assert!(p.base > 1.0, "base {} must exceed 1", p.base);
            let top = p.decode_magnitude(p.r_max());
            let max = t.abs_max() as f64;
            // FSR: top level reaches the max magnitude (β shifts it a bit).
            assert!(
                (top - max).abs() / max < 0.35,
                "n={n}: top level {top} vs max {max}"
            );
        }
    }

    #[test]
    fn zero_maps_to_zero_exactly() {
        let t = Tensor::from_vec(&[4], vec![0.0, 1.0, -2.0, 0.0]);
        let p = ExpQuantParams::init_for_tensor(&t, 4);
        let q = p.quantize(&t);
        let d = q.dequantize();
        assert_eq!(d.data()[0], 0.0);
        assert_eq!(d.data()[3], 0.0);
        assert_eq!(q.codes[0], ZERO_CODE_SENTINEL);
    }

    #[test]
    fn sign_is_preserved() {
        let t = expo_tensor(2000, 2);
        let p = ExpQuantParams::init_for_tensor(&t, 5);
        let d = p.roundtrip(&t);
        for (&x, &y) in t.data().iter().zip(d.data()) {
            if x != 0.0 {
                assert_eq!(x.signum(), y.signum(), "sign flip at {x} -> {y}");
            }
        }
    }

    #[test]
    fn codes_within_clip_range() {
        let t = expo_tensor(5000, 3);
        let p = ExpQuantParams::init_for_tensor(&t, 4);
        let q = p.quantize(&t);
        for &c in &q.codes {
            if c != ZERO_CODE_SENTINEL {
                assert!((c as i32) >= p.r_min() && (c as i32) <= p.r_max());
            }
        }
    }

    #[test]
    fn rmae_decreases_with_bitwidth() {
        let t = expo_tensor(8192, 4);
        let mut prev = f64::INFINITY;
        for n in 3..=7u8 {
            let p = ExpQuantParams::init_for_tensor(&t, n);
            let e = p.rmae(&t);
            assert!(e < prev * 1.05, "n={n}: RMAE {e} vs prev {prev}");
            prev = e;
        }
        // 7-bit exponential quantization of an exponential tensor is tight.
        assert!(prev < 0.05, "7-bit RMAE too high: {prev}");
    }

    #[test]
    fn rmae_matches_roundtrip_rmae() {
        let t = expo_tensor(1024, 5);
        let p = ExpQuantParams::init_for_tensor(&t, 5);
        let direct = p.rmae(&t);
        let via_roundtrip = p.roundtrip(&t).rmae(&t) as f64;
        assert!((direct - via_roundtrip).abs() < 1e-4, "{direct} vs {via_roundtrip}");
    }

    #[test]
    fn encode_monotone_in_magnitude() {
        let t = expo_tensor(512, 6);
        let p = ExpQuantParams::init_for_tensor(&t, 5);
        let mut prev_code = i32::MIN;
        let mut mags: Vec<f64> =
            t.data().iter().map(|x| x.abs() as f64).filter(|&m| m > 0.0).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for m in mags {
            let c = p.encode_magnitude(m);
            assert!(c >= prev_code, "monotonicity violated at mag {m}");
            prev_code = c;
        }
    }

    #[test]
    fn storage_bits_counts_sign() {
        let t = expo_tensor(100, 7);
        let p = ExpQuantParams::init_for_tensor(&t, 3);
        let q = p.quantize(&t);
        assert_eq!(q.storage_bits(), 100 * 4);
    }

    #[test]
    fn validate_rejects_degenerate_params() {
        let ok = ExpQuantParams { base: 1.3, alpha: 1.0, beta: 0.0, n_bits: 4 };
        assert!(ok.validate().is_ok());
        assert!(ExpQuantParams { n_bits: 0, ..ok }.validate().is_err());
        assert!(ExpQuantParams { n_bits: 8, ..ok }.validate().is_err());
        assert!(ExpQuantParams { base: 1.0, ..ok }.validate().is_err());
        assert!(ExpQuantParams { base: f64::NAN, ..ok }.validate().is_err());
        assert!(ExpQuantParams { alpha: f64::INFINITY, ..ok }.validate().is_err());
        assert!(ExpQuantParams { beta: f64::NAN, ..ok }.validate().is_err());
    }

    #[test]
    fn sub_unit_tensor_gets_valid_base() {
        // Typical weight tensor: max |w| ≈ 0.2 — Eq. 4's literal init
        // would give b < 1; we must still get a sane quantizer.
        let mut rng = SplitMix64::new(8);
        let t = Tensor::rand_normal(&[4096], 0.0, 0.05, &mut rng);
        let p = ExpQuantParams::init_for_tensor(&t, 5);
        assert!(p.base > 1.0);
        let e = p.rmae(&t);
        assert!(e < 0.30, "sub-unit init RMAE {e}");
    }
}
