//! Linear (uniform) quantization baseline (§II, Tables IV & V).
//!
//! Symmetric linear quantizer: `q = clip(round(x / Δ), -(2^{n-1}-1),
//! 2^{n-1}-1)`, `x̄ = q·Δ` with `Δ = max|x| / (2^{n-1}-1)`. This is the
//! INT8 scheme of the baseline accelerator and, at matched bitwidths, the
//! "Uniform Quantization" row of Table IV.

use crate::tensor::{Tensor, TensorI8};

/// Parameters of a symmetric uniform quantizer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UniformParams {
    /// Step size Δ.
    pub delta: f64,
    /// Bitwidth n (≤ 8; values stored in i8).
    pub n_bits: u8,
}

impl UniformParams {
    pub fn q_max(&self) -> i32 {
        (1i32 << (self.n_bits - 1)) - 1
    }

    /// Calibrate Δ from the tensor's max magnitude (full-scale symmetric).
    pub fn calibrate(t: &Tensor, n_bits: u8) -> Self {
        Self::calibrate_slice(t.data(), n_bits)
    }

    /// Slice variant of [`UniformParams::calibrate`] — used by the batched
    /// INT8 engine to calibrate each batch row in place without
    /// materializing per-row tensors.
    pub fn calibrate_slice(data: &[f32], n_bits: u8) -> Self {
        assert!((2..=8).contains(&n_bits), "uniform bitwidth {n_bits} out of range");
        let q_max = ((1i32 << (n_bits - 1)) - 1) as f64;
        let max = data.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64;
        Self { delta: if max > 0.0 { max / q_max } else { 1.0 }, n_bits }
    }

    #[inline]
    pub fn encode(&self, x: f32) -> i8 {
        let q = (x as f64 / self.delta).round() as i64;
        q.clamp(-(self.q_max() as i64), self.q_max() as i64) as i8
    }

    #[inline]
    pub fn decode(&self, q: i8) -> f32 {
        (q as f64 * self.delta) as f32
    }

    pub fn quantize(&self, t: &Tensor) -> TensorI8 {
        TensorI8::from_vec(t.shape(), t.data().iter().map(|&x| self.encode(x)).collect())
    }

    pub fn dequantize(&self, q: &TensorI8) -> Tensor {
        Tensor::from_vec(q.shape(), q.data().iter().map(|&v| self.decode(v)).collect())
    }

    /// Quantize-dequantize roundtrip for error/accuracy evaluation.
    pub fn roundtrip(&self, t: &Tensor) -> Tensor {
        t.map(|x| self.decode(self.encode(x)))
    }

    /// RMAE (Eq. 6) of this quantizer on `t`.
    pub fn rmae(&self, t: &Tensor) -> f64 {
        let denom: f64 = t.data().iter().map(|&x| x.abs() as f64).sum();
        if denom == 0.0 {
            return 0.0;
        }
        let num: f64 = t
            .data()
            .iter()
            .map(|&x| (self.decode(self.encode(x)) as f64 - x as f64).abs())
            .sum();
        num / denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    #[test]
    fn int8_roundtrip_is_tight_for_uniform_data() {
        let mut rng = SplitMix64::new(51);
        let t = Tensor::rand_uniform(&[10_000], -1.0, 1.0, &mut rng);
        let p = UniformParams::calibrate(&t, 8);
        assert!(p.rmae(&t) < 0.01, "INT8 RMAE {}", p.rmae(&t));
    }

    #[test]
    fn low_bit_uniform_hurts_exponential_data() {
        // The paper's core observation: exponential-shaped tensors are
        // poorly served by low-bit uniform quantization.
        let mut rng = SplitMix64::new(52);
        let t = Tensor::rand_signed_exponential(&[10_000], 3.0, &mut rng);
        let u4 = UniformParams::calibrate(&t, 4);
        let e4 = crate::dnateq::quant::ExpQuantParams::init_for_tensor(&t, 4);
        assert!(
            e4.rmae(&t) < u4.rmae(&t),
            "exp {} should beat uniform {}",
            e4.rmae(&t),
            u4.rmae(&t)
        );
    }

    #[test]
    fn encode_respects_clip() {
        let t = Tensor::from_vec(&[2], vec![1.0, -1.0]);
        let p = UniformParams::calibrate(&t, 4);
        assert_eq!(p.encode(10.0), 7);
        assert_eq!(p.encode(-10.0), -7);
        assert_eq!(p.encode(0.0), 0);
    }

    #[test]
    fn quantize_dequantize_shapes() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, -0.2, 0.3, -0.4, 0.5, -0.6]);
        let p = UniformParams::calibrate(&t, 8);
        let q = p.quantize(&t);
        assert_eq!(q.shape(), t.shape());
        let d = p.dequantize(&q);
        assert!(d.rmae(&t) < 0.01);
    }

    #[test]
    fn calibrate_slice_matches_tensor_calibrate() {
        let mut rng = SplitMix64::new(53);
        let t = Tensor::rand_uniform(&[257], -2.0, 2.0, &mut rng);
        for n in [4u8, 8] {
            let from_slice = UniformParams::calibrate_slice(t.data(), n);
            assert_eq!(UniformParams::calibrate(&t, n), from_slice);
        }
    }

    #[test]
    fn zero_tensor_is_safe() {
        let t = Tensor::zeros(&[16]);
        let p = UniformParams::calibrate(&t, 8);
        assert_eq!(p.rmae(&t), 0.0);
        assert_eq!(p.roundtrip(&t).data(), t.data());
    }
}
