//! Versioned on-disk store for calibration plans.
//!
//! Layout: `<root>/<model>/<version>.json`, where `<root>` defaults to
//! `artifacts/plans` and `<version>` is a monotonically increasing
//! integer starting at 1. Every file is a checksummed artifact envelope
//! ([`QuantConfig::save_json`]); re-saving a plan whose content checksum
//! matches the latest stored version is a no-op (calibration reruns do
//! not mint new versions).

use super::config::{QuantConfig, PLAN_SCHEMA_VERSION};
use super::search::PlanSet;
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// SLA-style policy for picking one point off a stored Pareto front.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanPolicy {
    /// Lowest accumulated RMAE (most accurate plan).
    MaxAccuracy,
    /// Highest compression (fewest average bits).
    MinBits,
    /// Lowest estimated energy per inference element.
    MinEnergy,
}

impl PlanPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PlanPolicy::MaxAccuracy => "max-accuracy",
            PlanPolicy::MinBits => "min-bits",
            PlanPolicy::MinEnergy => "min-energy",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "max-accuracy" => PlanPolicy::MaxAccuracy,
            "min-bits" => PlanPolicy::MinBits,
            "min-energy" => PlanPolicy::MinEnergy,
            other => bail!(
                "unknown plan policy `{other}`; use max-accuracy, min-bits or min-energy"
            ),
        })
    }
}

/// One front entry in the persisted index: the stored plan version plus
/// the metrics the selection policies rank by.
#[derive(Clone, Debug)]
pub struct FrontPoint {
    pub version: u32,
    pub checksum: String,
    pub rmae: f64,
    pub compression: f64,
    pub avg_bits: f64,
    pub energy_j: f64,
    /// Distinct scheme names used by the plan, first-appearance order.
    pub schemes: Vec<String>,
}

/// The persisted Pareto-front index for one model
/// (`<root>/<model>/front.json`). Points are sorted by ascending RMAE.
#[derive(Clone, Debug)]
pub struct FrontIndex {
    pub model: String,
    pub thr_w: f64,
    pub points: Vec<FrontPoint>,
}

impl FrontIndex {
    /// Pick the front point a policy asks for. Ties resolve to the first
    /// (most accurate) point, keeping selection deterministic.
    pub fn select(&self, policy: PlanPolicy) -> Option<&FrontPoint> {
        let better = |a: &FrontPoint, b: &FrontPoint| -> bool {
            match policy {
                PlanPolicy::MaxAccuracy => a.rmae < b.rmae,
                PlanPolicy::MinBits => a.compression > b.compression,
                PlanPolicy::MinEnergy => a.energy_j < b.energy_j,
            }
        };
        let mut best: Option<&FrontPoint> = None;
        for p in &self.points {
            if best.map(|b| better(p, b)).unwrap_or(true) {
                best = Some(p);
            }
        }
        best
    }

    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("version", p.version as u64)
                    .set("checksum", p.checksum.as_str())
                    .set("rmae", p.rmae)
                    .set("compression", p.compression)
                    .set("avg_bits", p.avg_bits)
                    .set("energy_j", p.energy_j)
                    .set(
                        "schemes",
                        p.schemes.iter().map(|s| Json::from(s.as_str())).collect::<Vec<_>>(),
                    );
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("schema_version", PLAN_SCHEMA_VERSION)
            .set("model", self.model.as_str())
            .set("thr_w", self.thr_w)
            .set("points", points);
        o
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let version = j.req("schema_version")?.as_usize()? as u64;
        if version > PLAN_SCHEMA_VERSION {
            bail!(
                "front index has schema version {version}, newer than supported {}",
                PLAN_SCHEMA_VERSION
            );
        }
        let points = j
            .req("points")?
            .as_arr()?
            .iter()
            .map(|p| {
                let schemes = p
                    .req("schemes")?
                    .as_arr()?
                    .iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?;
                Ok(FrontPoint {
                    version: p.req("version")?.as_usize()? as u32,
                    checksum: p.req("checksum")?.as_str()?.to_string(),
                    rmae: p.req("rmae")?.as_f64()?,
                    compression: p.req("compression")?.as_f64()?,
                    avg_bits: p.req("avg_bits")?.as_f64()?,
                    energy_j: p.req("energy_j")?.as_f64()?,
                    schemes,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            model: j.req("model")?.as_str()?.to_string(),
            thr_w: j.req("thr_w")?.as_f64()?,
            points,
        })
    }
}

/// Handle to a plan-artifact directory tree.
#[derive(Clone, Debug)]
pub struct PlanStore {
    root: PathBuf,
}

/// Summary of one stored plan version (what `repro plans list` prints).
#[derive(Clone, Debug)]
pub struct PlanSummary {
    pub model: String,
    pub version: u32,
    pub checksum: String,
    pub thr_w: f64,
    pub layers: usize,
    pub avg_bitwidth: f64,
}

impl PlanStore {
    /// Store rooted at an explicit directory (tests, tooling).
    pub fn new<P: AsRef<Path>>(root: P) -> Self {
        Self { root: root.as_ref().to_path_buf() }
    }

    /// The canonical store under the artifacts directory.
    pub fn open_default() -> Self {
        Self::new(crate::artifact_path("plans"))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Path of one plan artifact (whether or not it exists yet).
    pub fn path(&self, model: &str, version: u32) -> PathBuf {
        self.root.join(model).join(format!("{version}.json"))
    }

    /// Model names that have at least one stored version, sorted.
    pub fn models(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&self.root) {
            Ok(e) => e,
            Err(_) => return Ok(out), // no store yet — empty listing
        };
        for entry in entries {
            let entry = entry?;
            if !entry.path().is_dir() {
                continue;
            }
            let name = entry.file_name().to_string_lossy().into_owned();
            if !self.versions(&name)?.is_empty() {
                out.push(name);
            }
        }
        out.sort();
        Ok(out)
    }

    /// Stored versions for `model`, ascending. Empty when none exist.
    pub fn versions(&self, model: &str) -> Result<Vec<u32>> {
        let dir = self.root.join(model);
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&dir) {
            Ok(e) => e,
            Err(_) => return Ok(out),
        };
        for entry in entries {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            if let Some(v) = path
                .file_stem()
                .and_then(|s| s.to_str())
                .and_then(|s| s.parse::<u32>().ok())
            {
                out.push(v);
            }
        }
        out.sort_unstable();
        Ok(out)
    }

    /// Load one version, verifying schema + checksum.
    pub fn load(&self, model: &str, version: u32) -> Result<QuantConfig> {
        let cfg = QuantConfig::load_json(self.path(model, version))?;
        if cfg.model != model {
            bail!(
                "plan {}/{version} is for model `{}`, not `{model}` — misfiled artifact",
                model,
                cfg.model
            );
        }
        Ok(cfg)
    }

    /// Latest stored version of `model`, if any.
    pub fn latest(&self, model: &str) -> Result<Option<(u32, QuantConfig)>> {
        match self.versions(model)?.last() {
            Some(&v) => Ok(Some((v, self.load(model, v)?))),
            None => Ok(None),
        }
    }

    /// Persist `cfg` as the next version of its model. Idempotent: when
    /// the latest stored version has the same content checksum, no new
    /// file is written and the existing version number is returned.
    pub fn save_next(&self, cfg: &QuantConfig) -> Result<u32> {
        if let Some((v, latest)) = self.latest(&cfg.model)? {
            if latest.checksum() == cfg.checksum() {
                return Ok(v);
            }
        }
        let next = self.versions(&cfg.model)?.last().copied().unwrap_or(0) + 1;
        cfg.save_json(self.path(&cfg.model, next))
            .with_context(|| format!("storing plan {}/{next}", cfg.model))?;
        Ok(next)
    }

    /// Path of a model's persisted front index. The `front.json` stem is
    /// non-numeric, so [`PlanStore::versions`] never mistakes it for a
    /// plan artifact.
    pub fn front_path(&self, model: &str) -> PathBuf {
        self.root.join(model).join("front.json")
    }

    /// Persist a planner [`PlanSet`]: every front point's config is stored
    /// as a versioned plan artifact (idempotently — re-saving an identical
    /// front mints no new versions), then the front index is written to
    /// `front.json`. Returns the index as written.
    pub fn save_front(&self, set: &PlanSet) -> Result<FrontIndex> {
        let mut points = Vec::with_capacity(set.points.len());
        for p in &set.points {
            // save_next only dedupes against the latest version; a front
            // stores several configs per model, so match any existing
            // version by checksum to keep re-saves from minting versions.
            let checksum = p.config.checksum_hex();
            let existing = self.versions(&set.model)?.into_iter().find(|&v| {
                self.load(&set.model, v).map(|c| c.checksum_hex() == checksum).unwrap_or(false)
            });
            let version = match existing {
                Some(v) => v,
                None => self
                    .save_next(&p.config)
                    .with_context(|| format!("storing front point for {}", set.model))?,
            };
            points.push(FrontPoint {
                version,
                checksum: p.config.checksum_hex(),
                rmae: p.rmae,
                compression: p.compression,
                avg_bits: p.avg_bits,
                energy_j: p.energy_j,
                schemes: p.config.scheme_names(),
            });
        }
        let index = FrontIndex { model: set.model.clone(), thr_w: set.thr_w, points };
        index
            .to_json()
            .write_file(self.front_path(&set.model))
            .with_context(|| format!("writing front index for {}", set.model))?;
        Ok(index)
    }

    /// Load a model's front index, if one has been saved.
    pub fn load_front(&self, model: &str) -> Result<Option<FrontIndex>> {
        let path = self.front_path(model);
        if !path.exists() {
            return Ok(None);
        }
        let idx = FrontIndex::from_json(&Json::read_file(&path)?)
            .with_context(|| format!("loading front index {}", path.display()))?;
        if idx.model != model {
            bail!(
                "front index at {} is for model `{}`, not `{model}` — misfiled artifact",
                path.display(),
                idx.model
            );
        }
        Ok(Some(idx))
    }

    /// Summaries of every stored plan (model-major, version-minor order).
    pub fn list(&self) -> Result<Vec<PlanSummary>> {
        let mut out = Vec::new();
        for model in self.models()? {
            for v in self.versions(&model)? {
                let cfg = self.load(&model, v)?;
                out.push(PlanSummary {
                    model: model.clone(),
                    version: v,
                    checksum: cfg.checksum_hex(),
                    thr_w: cfg.thr_w,
                    layers: cfg.layers.len(),
                    avg_bitwidth: cfg.avg_bitwidth(),
                });
            }
        }
        Ok(out)
    }
}

/// Human-readable difference between two plans, one line per change.
/// Empty when the plans are content-identical.
pub fn diff_plans(a: &QuantConfig, b: &QuantConfig) -> Vec<String> {
    let mut out = Vec::new();
    if a.checksum() == b.checksum() {
        return out;
    }
    if a.model != b.model {
        out.push(format!("model: {} → {}", a.model, b.model));
    }
    if a.thr_w != b.thr_w {
        out.push(format!("thr_w: {:.4} → {:.4}", a.thr_w, b.thr_w));
    }
    let fmt_layer = |l: &super::config::LayerQuant| {
        format!(
            "{} bits, base {:.4}, w(α {:.4}, β {:.4}), a(α {:.4}, β {:.4})",
            l.n_bits, l.base, l.weights.alpha, l.weights.beta, l.acts.alpha, l.acts.beta
        )
    };
    for la in &a.layers {
        match b.layer(&la.name) {
            None => out.push(format!("- {} (only in first plan)", la.name)),
            Some(lb) => {
                let da = fmt_layer(la);
                let db = fmt_layer(lb);
                if da != db {
                    out.push(format!("~ {}: {da}  →  {db}", la.name));
                }
            }
        }
    }
    for lb in &b.layers {
        if a.layer(&lb.name).is_none() {
            out.push(format!("+ {} (only in second plan)", lb.name));
        }
    }
    if out.is_empty() {
        // Content differs (checksums diverge) but not in any field the
        // summary formats — report at full precision.
        out.push(format!("checksum: {} → {}", a.checksum_hex(), b.checksum_hex()));
    }
    out
}

/// Render one stored plan as the `repro plans show` table.
pub fn render_plan(cfg: &QuantConfig, version: u32) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "plan {}/{version}  (schema v{}, checksum {})",
        cfg.model,
        super::config::PLAN_SCHEMA_VERSION,
        cfg.checksum_hex()
    );
    let _ = writeln!(
        s,
        "thr_w {:.2}% | {} layers | avg bits {:.2} | compression {:.1}%",
        cfg.thr_w * 100.0,
        cfg.layers.len(),
        cfg.avg_bitwidth(),
        cfg.compression_ratio() * 100.0
    );
    let _ = writeln!(
        s,
        "{:<14} {:>5} {:>8} {:>5} {:>9} {:>11} {:>11} {:>9} {:>6}",
        "layer", "kind", "scheme", "bits", "base", "rmae(w)", "rmae(act)", "seed", "conv"
    );
    for l in &cfg.layers {
        let _ = writeln!(
            s,
            "{:<14} {:>5} {:>8} {:>5} {:>9.4} {:>11.5} {:>11.5} {:>9} {:>6}",
            l.name,
            l.kind.name(),
            l.scheme.name(),
            l.n_bits,
            l.base,
            l.weights.rmae,
            l.acts.rmae,
            if l.seeded_by_weights { "W" } else { "A" },
            if l.converged { "yes" } else { "no" }
        );
    }
    s
}

/// Render a stored front index as the `repro plans front` table, with
/// the point each selection policy would pick marked on the right.
pub fn render_front(idx: &FrontIndex) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "front {}  (thr_w {:.2}%, {} points)",
        idx.model,
        idx.thr_w * 100.0,
        idx.points.len()
    );
    let _ = writeln!(
        s,
        "{:>4} {:>18} {:>10} {:>9} {:>11} {:>11}  {:<18} {}",
        "ver", "checksum", "rmae", "avg bits", "compression", "energy(uJ)", "schemes", "policy"
    );
    let picks = [PlanPolicy::MaxAccuracy, PlanPolicy::MinBits, PlanPolicy::MinEnergy]
        .into_iter()
        .map(|p| (p, idx.select(p).map(|fp| fp.version)))
        .collect::<Vec<_>>();
    for p in &idx.points {
        let chosen_by: Vec<&str> = picks
            .iter()
            .filter(|(_, v)| *v == Some(p.version))
            .map(|(policy, _)| policy.name())
            .collect();
        let _ = writeln!(
            s,
            "{:>4} {:>18} {:>10.5} {:>9.2} {:>10.1}% {:>11.4}  {:<18} {}",
            p.version,
            p.checksum,
            p.rmae,
            p.avg_bits,
            p.compression * 100.0,
            p.energy_j * 1e6,
            p.schemes.join("+"),
            chosen_by.join(",")
        );
    }
    s
}

/// Expose the store contents as JSON (used by tooling and tests).
pub fn store_index_json(store: &PlanStore) -> Result<Json> {
    let mut arr = Vec::new();
    for s in store.list()? {
        let mut o = Json::obj();
        o.set("model", s.model.as_str())
            .set("version", s.version as u64)
            .set("checksum", s.checksum.as_str())
            .set("thr_w", s.thr_w)
            .set("layers", s.layers)
            .set("avg_bitwidth", s.avg_bitwidth);
        arr.push(o);
    }
    Ok(Json::Arr(arr))
}

#[cfg(test)]
mod tests {
    use super::super::config::{LayerKind, LayerQuant, Scheme, TensorQuant};
    use super::super::search::PlanPoint;
    use super::*;
    use crate::util::TempDir;

    fn mk_cfg(model: &str, thr_w: f64, bits: u8) -> QuantConfig {
        QuantConfig {
            model: model.into(),
            thr_w,
            layers: vec![LayerQuant {
                name: "fc0".into(),
                kind: LayerKind::Fc,
                scheme: Scheme::Exp,
                n_bits: bits,
                base: 1.31,
                weights: TensorQuant { alpha: 0.7, beta: 0.01, rmae: 0.02, elems: 128 },
                acts: TensorQuant { alpha: 1.4, beta: 0.02, rmae: 0.03, elems: 64 },
                seeded_by_weights: true,
                rss_w: 0.4,
                rss_a: 0.9,
                converged: true,
            }],
        }
    }

    #[test]
    fn versions_increment_and_reload() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        assert!(store.models().unwrap().is_empty());
        assert_eq!(store.save_next(&mk_cfg("m", 0.04, 4)).unwrap(), 1);
        assert_eq!(store.save_next(&mk_cfg("m", 0.08, 3)).unwrap(), 2);
        assert_eq!(store.versions("m").unwrap(), vec![1, 2]);
        let (v, cfg) = store.latest("m").unwrap().unwrap();
        assert_eq!(v, 2);
        assert_eq!(cfg.layers[0].n_bits, 3);
        assert_eq!(store.load("m", 1).unwrap().layers[0].n_bits, 4);
    }

    #[test]
    fn identical_content_does_not_mint_a_version() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        assert_eq!(store.save_next(&mk_cfg("m", 0.04, 4)).unwrap(), 1);
        assert_eq!(store.save_next(&mk_cfg("m", 0.04, 4)).unwrap(), 1);
        assert_eq!(store.versions("m").unwrap(), vec![1]);
    }

    #[test]
    fn list_covers_all_models() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        store.save_next(&mk_cfg("alex", 0.04, 4)).unwrap();
        store.save_next(&mk_cfg("res", 0.05, 5)).unwrap();
        store.save_next(&mk_cfg("res", 0.06, 3)).unwrap();
        let listing = store.list().unwrap();
        assert_eq!(listing.len(), 3);
        assert_eq!(listing[0].model, "alex");
        assert_eq!(listing[2].version, 2);
        assert_eq!(store.models().unwrap(), vec!["alex".to_string(), "res".to_string()]);
        let idx = store_index_json(&store).unwrap();
        assert_eq!(idx.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn misfiled_artifact_is_rejected() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        // Write a plan for model `other` under directory `m`.
        mk_cfg("other", 0.04, 4).save_json(store.path("m", 1)).unwrap();
        assert!(store.load("m", 1).is_err());
    }

    #[test]
    fn diff_reports_changes_and_is_empty_for_identical() {
        let a = mk_cfg("m", 0.04, 4);
        assert!(diff_plans(&a, &a.clone()).is_empty());
        let b = mk_cfg("m", 0.08, 3);
        let d = diff_plans(&a, &b);
        assert!(d.iter().any(|l| l.contains("thr_w")), "{d:?}");
        assert!(d.iter().any(|l| l.starts_with("~ fc0")), "{d:?}");
        let mut c = mk_cfg("m", 0.04, 4);
        c.layers[0].name = "fc1".into();
        let d2 = diff_plans(&a, &c);
        assert!(d2.iter().any(|l| l.starts_with("- fc0")), "{d2:?}");
        assert!(d2.iter().any(|l| l.starts_with("+ fc1")), "{d2:?}");
    }

    #[test]
    fn render_plan_mentions_every_layer() {
        let cfg = mk_cfg("m", 0.04, 4);
        let s = render_plan(&cfg, 3);
        assert!(s.contains("m/3"));
        assert!(s.contains("fc0"));
        assert!(s.contains("exp"));
        assert!(s.contains(&cfg.checksum_hex()));
    }

    fn mk_set() -> PlanSet {
        let point = |bits: u8, rmae: f64, energy_j: f64| {
            let config = mk_cfg("m", 0.05, bits);
            PlanPoint {
                rmae,
                compression: 1.0 - bits as f64 / 8.0,
                avg_bits: bits as f64,
                energy_j,
                config,
            }
        };
        PlanSet {
            model: "m".into(),
            thr_w: 0.05,
            points: vec![point(7, 0.01, 3e-6), point(5, 0.05, 2e-6), point(3, 0.2, 1e-6)],
        }
    }

    #[test]
    fn front_roundtrips_and_policies_pick_their_ends() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        let idx = store.save_front(&mk_set()).unwrap();
        assert_eq!(idx.points.len(), 3);
        // front.json has a non-numeric stem: never mistaken for a plan.
        assert_eq!(store.versions("m").unwrap(), vec![1, 2, 3]);
        let loaded = store.load_front("m").unwrap().unwrap();
        assert_eq!(loaded.model, "m");
        assert_eq!(loaded.points.len(), 3);
        let acc = loaded.select(PlanPolicy::MaxAccuracy).unwrap();
        let bits = loaded.select(PlanPolicy::MinBits).unwrap();
        let energy = loaded.select(PlanPolicy::MinEnergy).unwrap();
        assert_eq!(acc.version, idx.points[0].version);
        assert_eq!(bits.version, idx.points[2].version);
        assert_eq!(energy.version, idx.points[2].version);
        assert_ne!(acc.version, bits.version);
        // Each selected version loads back to a checksum-verified plan.
        let cfg = store.load("m", bits.version).unwrap();
        assert_eq!(cfg.checksum_hex(), bits.checksum);
    }

    #[test]
    fn saving_identical_front_twice_is_byte_stable() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        store.save_front(&mk_set()).unwrap();
        let first = std::fs::read(store.front_path("m")).unwrap();
        let again = store.save_front(&mk_set()).unwrap();
        // No new versions minted, byte-identical index rewritten.
        assert_eq!(store.versions("m").unwrap(), vec![1, 2, 3]);
        assert_eq!(again.points.iter().map(|p| p.version).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(std::fs::read(store.front_path("m")).unwrap(), first);
    }

    #[test]
    fn missing_front_is_none_and_policy_parse_roundtrips() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        assert!(store.load_front("ghost").unwrap().is_none());
        for p in [PlanPolicy::MaxAccuracy, PlanPolicy::MinBits, PlanPolicy::MinEnergy] {
            assert_eq!(PlanPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(PlanPolicy::parse("fastest").is_err());
    }

    #[test]
    fn render_front_marks_policy_picks() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        let idx = store.save_front(&mk_set()).unwrap();
        let s = render_front(&idx);
        assert!(s.contains("front m"));
        assert!(s.contains("max-accuracy"));
        assert!(s.contains("min-bits"));
        assert!(s.contains("min-energy"));
        assert!(s.contains("exp"));
    }
}
