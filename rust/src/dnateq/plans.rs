//! Versioned on-disk store for calibration plans.
//!
//! Layout: `<root>/<model>/<version>.json`, where `<root>` defaults to
//! `artifacts/plans` and `<version>` is a monotonically increasing
//! integer starting at 1. Every file is a checksummed artifact envelope
//! ([`QuantConfig::save_json`]); re-saving a plan whose content checksum
//! matches the latest stored version is a no-op (calibration reruns do
//! not mint new versions).

use super::config::QuantConfig;
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Handle to a plan-artifact directory tree.
#[derive(Clone, Debug)]
pub struct PlanStore {
    root: PathBuf,
}

/// Summary of one stored plan version (what `repro plans list` prints).
#[derive(Clone, Debug)]
pub struct PlanSummary {
    pub model: String,
    pub version: u32,
    pub checksum: String,
    pub thr_w: f64,
    pub layers: usize,
    pub avg_bitwidth: f64,
}

impl PlanStore {
    /// Store rooted at an explicit directory (tests, tooling).
    pub fn new<P: AsRef<Path>>(root: P) -> Self {
        Self { root: root.as_ref().to_path_buf() }
    }

    /// The canonical store under the artifacts directory.
    pub fn open_default() -> Self {
        Self::new(crate::artifact_path("plans"))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Path of one plan artifact (whether or not it exists yet).
    pub fn path(&self, model: &str, version: u32) -> PathBuf {
        self.root.join(model).join(format!("{version}.json"))
    }

    /// Model names that have at least one stored version, sorted.
    pub fn models(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&self.root) {
            Ok(e) => e,
            Err(_) => return Ok(out), // no store yet — empty listing
        };
        for entry in entries {
            let entry = entry?;
            if !entry.path().is_dir() {
                continue;
            }
            let name = entry.file_name().to_string_lossy().into_owned();
            if !self.versions(&name)?.is_empty() {
                out.push(name);
            }
        }
        out.sort();
        Ok(out)
    }

    /// Stored versions for `model`, ascending. Empty when none exist.
    pub fn versions(&self, model: &str) -> Result<Vec<u32>> {
        let dir = self.root.join(model);
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&dir) {
            Ok(e) => e,
            Err(_) => return Ok(out),
        };
        for entry in entries {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            if let Some(v) = path
                .file_stem()
                .and_then(|s| s.to_str())
                .and_then(|s| s.parse::<u32>().ok())
            {
                out.push(v);
            }
        }
        out.sort_unstable();
        Ok(out)
    }

    /// Load one version, verifying schema + checksum.
    pub fn load(&self, model: &str, version: u32) -> Result<QuantConfig> {
        let cfg = QuantConfig::load_json(self.path(model, version))?;
        if cfg.model != model {
            bail!(
                "plan {}/{version} is for model `{}`, not `{model}` — misfiled artifact",
                model,
                cfg.model
            );
        }
        Ok(cfg)
    }

    /// Latest stored version of `model`, if any.
    pub fn latest(&self, model: &str) -> Result<Option<(u32, QuantConfig)>> {
        match self.versions(model)?.last() {
            Some(&v) => Ok(Some((v, self.load(model, v)?))),
            None => Ok(None),
        }
    }

    /// Persist `cfg` as the next version of its model. Idempotent: when
    /// the latest stored version has the same content checksum, no new
    /// file is written and the existing version number is returned.
    pub fn save_next(&self, cfg: &QuantConfig) -> Result<u32> {
        if let Some((v, latest)) = self.latest(&cfg.model)? {
            if latest.checksum() == cfg.checksum() {
                return Ok(v);
            }
        }
        let next = self.versions(&cfg.model)?.last().copied().unwrap_or(0) + 1;
        cfg.save_json(self.path(&cfg.model, next))
            .with_context(|| format!("storing plan {}/{next}", cfg.model))?;
        Ok(next)
    }

    /// Summaries of every stored plan (model-major, version-minor order).
    pub fn list(&self) -> Result<Vec<PlanSummary>> {
        let mut out = Vec::new();
        for model in self.models()? {
            for v in self.versions(&model)? {
                let cfg = self.load(&model, v)?;
                out.push(PlanSummary {
                    model: model.clone(),
                    version: v,
                    checksum: cfg.checksum_hex(),
                    thr_w: cfg.thr_w,
                    layers: cfg.layers.len(),
                    avg_bitwidth: cfg.avg_bitwidth(),
                });
            }
        }
        Ok(out)
    }
}

/// Human-readable difference between two plans, one line per change.
/// Empty when the plans are content-identical.
pub fn diff_plans(a: &QuantConfig, b: &QuantConfig) -> Vec<String> {
    let mut out = Vec::new();
    if a.checksum() == b.checksum() {
        return out;
    }
    if a.model != b.model {
        out.push(format!("model: {} → {}", a.model, b.model));
    }
    if a.thr_w != b.thr_w {
        out.push(format!("thr_w: {:.4} → {:.4}", a.thr_w, b.thr_w));
    }
    let fmt_layer = |l: &super::config::LayerQuant| {
        format!(
            "{} bits, base {:.4}, w(α {:.4}, β {:.4}), a(α {:.4}, β {:.4})",
            l.n_bits, l.base, l.weights.alpha, l.weights.beta, l.acts.alpha, l.acts.beta
        )
    };
    for la in &a.layers {
        match b.layer(&la.name) {
            None => out.push(format!("- {} (only in first plan)", la.name)),
            Some(lb) => {
                let da = fmt_layer(la);
                let db = fmt_layer(lb);
                if da != db {
                    out.push(format!("~ {}: {da}  →  {db}", la.name));
                }
            }
        }
    }
    for lb in &b.layers {
        if a.layer(&lb.name).is_none() {
            out.push(format!("+ {} (only in second plan)", lb.name));
        }
    }
    if out.is_empty() {
        // Content differs (checksums diverge) but not in any field the
        // summary formats — report at full precision.
        out.push(format!("checksum: {} → {}", a.checksum_hex(), b.checksum_hex()));
    }
    out
}

/// Render one stored plan as the `repro plans show` table.
pub fn render_plan(cfg: &QuantConfig, version: u32) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "plan {}/{version}  (schema v{}, checksum {})",
        cfg.model,
        super::config::PLAN_SCHEMA_VERSION,
        cfg.checksum_hex()
    );
    let _ = writeln!(
        s,
        "thr_w {:.2}% | {} layers | avg bits {:.2} | compression {:.1}%",
        cfg.thr_w * 100.0,
        cfg.layers.len(),
        cfg.avg_bitwidth(),
        cfg.compression_ratio() * 100.0
    );
    let _ = writeln!(
        s,
        "{:<14} {:>5} {:>5} {:>9} {:>11} {:>11} {:>9} {:>6}",
        "layer", "kind", "bits", "base", "rmae(w)", "rmae(act)", "seed", "conv"
    );
    for l in &cfg.layers {
        let _ = writeln!(
            s,
            "{:<14} {:>5} {:>5} {:>9.4} {:>11.5} {:>11.5} {:>9} {:>6}",
            l.name,
            l.kind.name(),
            l.n_bits,
            l.base,
            l.weights.rmae,
            l.acts.rmae,
            if l.seeded_by_weights { "W" } else { "A" },
            if l.converged { "yes" } else { "no" }
        );
    }
    s
}

/// Expose the store contents as JSON (used by tooling and tests).
pub fn store_index_json(store: &PlanStore) -> Result<Json> {
    let mut arr = Vec::new();
    for s in store.list()? {
        let mut o = Json::obj();
        o.set("model", s.model.as_str())
            .set("version", s.version as u64)
            .set("checksum", s.checksum.as_str())
            .set("thr_w", s.thr_w)
            .set("layers", s.layers)
            .set("avg_bitwidth", s.avg_bitwidth);
        arr.push(o);
    }
    Ok(Json::Arr(arr))
}

#[cfg(test)]
mod tests {
    use super::super::config::{LayerKind, LayerQuant, TensorQuant};
    use super::*;
    use crate::util::TempDir;

    fn mk_cfg(model: &str, thr_w: f64, bits: u8) -> QuantConfig {
        QuantConfig {
            model: model.into(),
            thr_w,
            layers: vec![LayerQuant {
                name: "fc0".into(),
                kind: LayerKind::Fc,
                n_bits: bits,
                base: 1.31,
                weights: TensorQuant { alpha: 0.7, beta: 0.01, rmae: 0.02, elems: 128 },
                acts: TensorQuant { alpha: 1.4, beta: 0.02, rmae: 0.03, elems: 64 },
                seeded_by_weights: true,
                rss_w: 0.4,
                rss_a: 0.9,
                converged: true,
            }],
        }
    }

    #[test]
    fn versions_increment_and_reload() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        assert!(store.models().unwrap().is_empty());
        assert_eq!(store.save_next(&mk_cfg("m", 0.04, 4)).unwrap(), 1);
        assert_eq!(store.save_next(&mk_cfg("m", 0.08, 3)).unwrap(), 2);
        assert_eq!(store.versions("m").unwrap(), vec![1, 2]);
        let (v, cfg) = store.latest("m").unwrap().unwrap();
        assert_eq!(v, 2);
        assert_eq!(cfg.layers[0].n_bits, 3);
        assert_eq!(store.load("m", 1).unwrap().layers[0].n_bits, 4);
    }

    #[test]
    fn identical_content_does_not_mint_a_version() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        assert_eq!(store.save_next(&mk_cfg("m", 0.04, 4)).unwrap(), 1);
        assert_eq!(store.save_next(&mk_cfg("m", 0.04, 4)).unwrap(), 1);
        assert_eq!(store.versions("m").unwrap(), vec![1]);
    }

    #[test]
    fn list_covers_all_models() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        store.save_next(&mk_cfg("alex", 0.04, 4)).unwrap();
        store.save_next(&mk_cfg("res", 0.05, 5)).unwrap();
        store.save_next(&mk_cfg("res", 0.06, 3)).unwrap();
        let listing = store.list().unwrap();
        assert_eq!(listing.len(), 3);
        assert_eq!(listing[0].model, "alex");
        assert_eq!(listing[2].version, 2);
        assert_eq!(store.models().unwrap(), vec!["alex".to_string(), "res".to_string()]);
        let idx = store_index_json(&store).unwrap();
        assert_eq!(idx.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn misfiled_artifact_is_rejected() {
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        // Write a plan for model `other` under directory `m`.
        mk_cfg("other", 0.04, 4).save_json(store.path("m", 1)).unwrap();
        assert!(store.load("m", 1).is_err());
    }

    #[test]
    fn diff_reports_changes_and_is_empty_for_identical() {
        let a = mk_cfg("m", 0.04, 4);
        assert!(diff_plans(&a, &a.clone()).is_empty());
        let b = mk_cfg("m", 0.08, 3);
        let d = diff_plans(&a, &b);
        assert!(d.iter().any(|l| l.contains("thr_w")), "{d:?}");
        assert!(d.iter().any(|l| l.starts_with("~ fc0")), "{d:?}");
        let mut c = mk_cfg("m", 0.04, 4);
        c.layers[0].name = "fc1".into();
        let d2 = diff_plans(&a, &c);
        assert!(d2.iter().any(|l| l.starts_with("- fc0")), "{d2:?}");
        assert!(d2.iter().any(|l| l.starts_with("+ fc1")), "{d2:?}");
    }

    #[test]
    fn render_plan_mentions_every_layer() {
        let cfg = mk_cfg("m", 0.04, 4);
        let s = render_plan(&cfg, 3);
        assert!(s.contains("m/3"));
        assert!(s.contains("fc0"));
        assert!(s.contains(&cfg.checksum_hex()));
    }
}
