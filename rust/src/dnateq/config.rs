//! Serializable calibration output: per-layer DNA-TEQ parameters.
//!
//! A [`QuantConfig`] is the artifact the offline search produces and the
//! runtime consumes — it fully determines how every CONV/FC layer of a
//! model quantizes its weights and activations. Serialized as JSON via
//! the crate's own codec ([`crate::util::json`]).
//!
//! On disk a plan is a **versioned artifact**: the config body is wrapped
//! in an envelope carrying a `schema_version` and an FNV-1a-64 content
//! `checksum` over the canonical (compact, sorted-key) encoding of the
//! body. Because the JSON codec prints every finite `f64` in its shortest
//! round-trip form, save → load → re-encode reproduces the identical byte
//! stream, so the checksum doubles as a bit-exactness proof for every
//! α/β/base in the plan.

use super::quant::ExpQuantParams;
use crate::util::{fnv1a64, Json};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Version of the on-disk plan-artifact schema. Bump when the envelope or
/// body layout changes; loaders reject artifacts from a newer schema.
///
/// v1 → v2: layers gained a `scheme` field (`exp` / `uniform` / `pwl<k>`).
/// The field is omitted from the encoding when it is `exp`, so an all-exp
/// v2 body is byte-identical to its v1 form and v1 checksums still verify;
/// loaders default a missing `scheme` to [`Scheme::Exp`].
pub const PLAN_SCHEMA_VERSION: u64 = 2;

/// Layer operator kind (the paper quantizes CONV and FC layers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Fc,
}

impl LayerKind {
    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::Conv => "CONV",
            LayerKind::Fc => "FC",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "CONV" => LayerKind::Conv,
            "FC" => LayerKind::Fc,
            other => bail!("unknown layer kind `{other}`"),
        })
    }
}

/// Quantization scheme for one layer: the paper's exponential codes, a
/// plain uniform grid, or a piecewise-linear grid (PWLQ-style) for
/// outlier-heavy distributions. Carried by [`LayerQuant`] so a single
/// plan can mix schemes per layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// DNA-TEQ exponential codes `sign(x)·(α·bⁱ + β)`.
    Exp,
    /// Symmetric uniform grid (Δ per level).
    Uniform,
    /// Piecewise-linear: `breaks` interior breakpoints split `|x|` into
    /// regions, each with its own uniform grid.
    Pwl { breaks: u8 },
}

impl Scheme {
    pub fn name(&self) -> String {
        match self {
            Scheme::Exp => "exp".to_string(),
            Scheme::Uniform => "uniform".to_string(),
            Scheme::Pwl { breaks } => format!("pwl{breaks}"),
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "exp" => Scheme::Exp,
            "uniform" => Scheme::Uniform,
            "pwl" => Scheme::Pwl { breaks: 1 },
            other => match other.strip_prefix("pwl").and_then(|k| k.parse::<u8>().ok()) {
                Some(breaks) if breaks >= 1 => Scheme::Pwl { breaks },
                _ => bail!("unknown scheme `{other}`"),
            },
        })
    }

    /// Inclusive bit-width range this scheme supports. Exp is capped at 7
    /// by the counting-GEMM datapath; uniform/pwl extend to 8. Pwl needs
    /// enough bits for sign + region index + at least one level bit.
    pub fn bit_range(&self) -> (u8, u8) {
        match self {
            Scheme::Exp => (2, 7),
            Scheme::Uniform => (2, 8),
            Scheme::Pwl { breaks } => {
                let regions = *breaks as u32 + 1;
                let region_bits = 32 - (regions - 1).leading_zeros().min(31);
                ((region_bits as u8 + 2).max(2), 8)
            }
        }
    }
}

/// Per-tensor (weights or activations) scale/offset + achieved error.
#[derive(Clone, Copy, Debug)]
pub struct TensorQuant {
    pub alpha: f64,
    pub beta: f64,
    /// RMAE achieved on the calibration trace.
    pub rmae: f64,
    /// Element count (drives weighted averages & compression accounting).
    pub elems: usize,
}

impl TensorQuant {
    fn to_json(self) -> Json {
        let mut o = Json::obj();
        o.set("alpha", self.alpha)
            .set("beta", self.beta)
            .set("rmae", self.rmae)
            .set("elems", self.elems);
        o
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            alpha: j.req("alpha")?.as_f64()?,
            beta: j.req("beta")?.as_f64()?,
            rmae: j.req("rmae")?.as_f64()?,
            elems: j.req("elems")?.as_usize()?,
        })
    }
}

/// Full quantization record for one layer.
#[derive(Clone, Debug)]
pub struct LayerQuant {
    pub name: String,
    pub kind: LayerKind,
    /// Quantization scheme this layer uses (per-layer adaptive).
    pub scheme: Scheme,
    /// Code bitwidth `n` (shared by both tensors).
    pub n_bits: u8,
    /// Exponential base `b` (shared by both tensors; 0.0 for non-exp
    /// schemes, which have no base).
    pub base: f64,
    pub weights: TensorQuant,
    pub acts: TensorQuant,
    /// Which tensor seeded Algorithm 1 (lower RSS; step 2 of Fig. 3).
    pub seeded_by_weights: bool,
    pub rss_w: f64,
    pub rss_a: f64,
    /// Whether the bitwidth sweep met both thresholds.
    pub converged: bool,
}

impl LayerQuant {
    pub fn w_params(&self) -> ExpQuantParams {
        ExpQuantParams {
            base: self.base,
            alpha: self.weights.alpha,
            beta: self.weights.beta,
            n_bits: self.n_bits,
        }
    }

    pub fn a_params(&self) -> ExpQuantParams {
        ExpQuantParams {
            base: self.base,
            alpha: self.acts.alpha,
            beta: self.acts.beta,
            n_bits: self.n_bits,
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("kind", self.kind.name())
            .set("n_bits", self.n_bits)
            .set("base", self.base);
        // `scheme` is omitted for Exp so all-exp bodies stay byte-identical
        // to schema-v1 encodings (their checksums keep verifying).
        if self.scheme != Scheme::Exp {
            o.set("scheme", self.scheme.name());
        }
        o.set("weights", self.weights.to_json())
            .set("acts", self.acts.to_json())
            .set("seeded_by_weights", self.seeded_by_weights)
            .set("rss_w", self.rss_w)
            .set("rss_a", self.rss_a)
            .set("converged", self.converged);
        o
    }

    fn from_json(j: &Json) -> Result<Self> {
        // Schema-v1 bodies have no `scheme` key; they are all-exponential.
        let scheme = match j.get("scheme") {
            Some(s) => Scheme::parse(s.as_str()?)?,
            None => Scheme::Exp,
        };
        Ok(Self {
            name: j.req("name")?.as_str()?.to_string(),
            kind: LayerKind::parse(j.req("kind")?.as_str()?)?,
            scheme,
            n_bits: j.req("n_bits")?.as_usize()? as u8,
            base: j.req("base")?.as_f64()?,
            weights: TensorQuant::from_json(j.req("weights")?)?,
            acts: TensorQuant::from_json(j.req("acts")?)?,
            seeded_by_weights: j.req("seeded_by_weights")?.as_bool()?,
            rss_w: j.req("rss_w")?.as_f64()?,
            rss_a: j.req("rss_a")?.as_f64()?,
            converged: j.req("converged")?.as_bool()?,
        })
    }
}

/// Calibrated quantization for a whole model.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub model: String,
    /// The network-level weight-error threshold this config was built at.
    pub thr_w: f64,
    pub layers: Vec<LayerQuant>,
}

impl QuantConfig {
    /// Parameter-weighted average exponent bitwidth (Table V "AVG
    /// Bitwidth"). Weighted by weight-element count, matching how the
    /// paper's compression ratios reduce to `1 − avg_bits/8`.
    pub fn avg_bitwidth(&self) -> f64 {
        let total: usize = self.layers.iter().map(|l| l.weights.elems).sum();
        if total == 0 {
            return 0.0;
        }
        self.layers
            .iter()
            .map(|l| l.n_bits as f64 * l.weights.elems as f64)
            .sum::<f64>()
            / total as f64
    }

    /// Compression ratio over the INT8 baseline (Table V), computed the
    /// way the paper's numbers reduce: `1 − avg_bits / 8`.
    pub fn compression_ratio(&self) -> f64 {
        1.0 - self.avg_bitwidth() / 8.0
    }

    /// Storage-honest compression including the sign bit:
    /// `1 − (avg_bits + 1) / 8`.
    pub fn compression_ratio_with_sign(&self) -> f64 {
        1.0 - (self.avg_bitwidth() + 1.0) / 8.0
    }

    /// Accumulated RMAE of weights + activations across layers (Table IV
    /// reports this sum for each scheme).
    pub fn accumulated_rmae(&self) -> f64 {
        self.layers.iter().map(|l| l.weights.rmae + l.acts.rmae).sum()
    }

    /// Histogram of layers per bitwidth (drives accelerator power-gating
    /// and the 7-bit overhead discussion, §VI-D). Bit-widths beyond the
    /// INT8 ceiling saturate into the top bucket rather than being
    /// dropped, so the bucket sum always equals the layer count; when
    /// that happens one warning per plan is logged to stderr (validated
    /// plans never hit it — only hand-built configs can).
    pub fn bitwidth_histogram(&self) -> [usize; 9] {
        let (h, warning) = self.bitwidth_histogram_checked();
        if let Some(w) = warning {
            eprintln!("warning: {w}");
        }
        h
    }

    /// [`Self::bitwidth_histogram`] plus the saturation warning (at most
    /// one per plan) instead of logging it, for callers — and tests —
    /// that want the condition as data.
    pub fn bitwidth_histogram_checked(&self) -> ([usize; 9], Option<String>) {
        let mut h = [0usize; 9];
        let top = h.len() - 1;
        let mut saturated = 0usize;
        for l in &self.layers {
            let n = l.n_bits as usize;
            if n > top {
                saturated += 1;
            }
            h[n.min(top)] += 1;
        }
        let warning = (saturated > 0).then(|| {
            format!(
                "plan `{}`: {saturated} layer(s) exceed the {top}-bit histogram ceiling; \
                 counted in the top bucket",
                self.model
            )
        });
        (h, warning)
    }

    pub fn layer(&self, name: &str) -> Option<&LayerQuant> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Distinct scheme names used by this plan, in first-appearance order
    /// (e.g. `["exp", "uniform"]`). Drives front-index summaries.
    pub fn scheme_names(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for l in &self.layers {
            let n = l.scheme.name();
            if !names.contains(&n) {
                names.push(n);
            }
        }
        names
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", self.model.as_str())
            .set("thr_w", self.thr_w)
            .set("layers", self.layers.iter().map(|l| l.to_json()).collect::<Vec<_>>());
        o
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let layers = j
            .req("layers")?
            .as_arr()?
            .iter()
            .map(LayerQuant::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            model: j.req("model")?.as_str()?.to_string(),
            thr_w: j.req("thr_w")?.as_f64()?,
            layers,
        })
    }

    /// Reject configs that cannot be served: degenerate quantizer
    /// parameters or a non-finite threshold. Runs on every artifact
    /// save/load so corruption is caught at the boundary.
    pub fn validate(&self) -> Result<()> {
        if !self.thr_w.is_finite() || self.thr_w <= 0.0 {
            bail!("thr_w {} must be finite and positive", self.thr_w);
        }
        for l in &self.layers {
            match l.scheme {
                Scheme::Exp => {
                    l.w_params()
                        .validate()
                        .with_context(|| format!("layer `{}` weight params", l.name))?;
                    l.a_params()
                        .validate()
                        .with_context(|| format!("layer `{}` activation params", l.name))?;
                }
                Scheme::Uniform | Scheme::Pwl { .. } => {
                    let (lo, hi) = l.scheme.bit_range();
                    if !(lo..=hi).contains(&l.n_bits) {
                        bail!(
                            "layer `{}`: scheme {} requires {lo}..={hi} bits, got {}",
                            l.name,
                            l.scheme.name(),
                            l.n_bits
                        );
                    }
                    for (which, t) in [("weight", &l.weights), ("activation", &l.acts)] {
                        if !t.alpha.is_finite() || t.alpha <= 0.0 {
                            bail!(
                                "layer `{}` {which} step {} must be finite and positive",
                                l.name,
                                t.alpha
                            );
                        }
                        if !t.beta.is_finite() {
                            bail!("layer `{}` {which} offset {} must be finite", l.name, t.beta);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Content checksum: FNV-1a 64 over the canonical compact encoding of
    /// the config body. Identical plans hash identically regardless of
    /// pretty-printing, field ordering in hand-edited files, or the
    /// machine that wrote them.
    pub fn checksum(&self) -> u64 {
        fnv1a64(self.to_json().encode().as_bytes())
    }

    /// Hex form of [`Self::checksum`] as stored in the artifact envelope.
    pub fn checksum_hex(&self) -> String {
        format!("{:016x}", self.checksum())
    }

    /// Wrap the config body in the versioned artifact envelope.
    pub fn to_artifact_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("schema_version", PLAN_SCHEMA_VERSION)
            .set("checksum", self.checksum_hex())
            .set("plan", self.to_json());
        o
    }

    /// Parse a versioned artifact envelope, verifying schema version and
    /// content checksum. Bare (legacy, pre-envelope) config bodies are
    /// still accepted so caches written before the schema existed load.
    pub fn from_artifact_json(j: &Json) -> Result<Self> {
        let cfg = match j.get("schema_version") {
            Some(v) => {
                let version = v.as_usize()? as u64;
                if version > PLAN_SCHEMA_VERSION {
                    bail!(
                        "plan artifact has schema version {version}, newer than supported {}",
                        PLAN_SCHEMA_VERSION
                    );
                }
                let cfg = Self::from_json(j.req("plan")?)?;
                let want = j.req("checksum")?.as_str()?.to_string();
                let got = cfg.checksum_hex();
                if want != got {
                    bail!("plan checksum mismatch: artifact says {want}, content hashes to {got}");
                }
                cfg
            }
            None => Self::from_json(j).context("parsing legacy (unversioned) QuantConfig")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Write the versioned artifact (envelope + body) to `path`.
    pub fn save_json<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        self.validate().with_context(|| format!("refusing to write {}", path.display()))?;
        self.to_artifact_json()
            .write_file(path)
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load a plan artifact (versioned envelope or legacy bare body).
    pub fn load_json<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        Self::from_artifact_json(&Json::read_file(path)?)
            .with_context(|| format!("loading plan artifact {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_layer(name: &str, n: u8, elems: usize) -> LayerQuant {
        LayerQuant {
            name: name.into(),
            kind: LayerKind::Fc,
            scheme: Scheme::Exp,
            n_bits: n,
            base: 1.3,
            weights: TensorQuant { alpha: 1.0, beta: 0.0, rmae: 0.01, elems },
            acts: TensorQuant { alpha: 2.0, beta: 0.1, rmae: 0.02, elems: elems / 2 },
            seeded_by_weights: true,
            rss_w: 0.5,
            rss_a: 1.5,
            converged: true,
        }
    }

    #[test]
    fn avg_bitwidth_is_weighted() {
        let cfg = QuantConfig {
            model: "m".into(),
            thr_w: 0.01,
            layers: vec![mk_layer("a", 3, 3000), mk_layer("b", 7, 1000)],
        };
        // (3*3000 + 7*1000) / 4000 = 4.0
        assert!((cfg.avg_bitwidth() - 4.0).abs() < 1e-9);
        assert!((cfg.compression_ratio() - 0.5).abs() < 1e-9);
        assert!((cfg.compression_ratio_with_sign() - 0.375).abs() < 1e-9);
    }

    #[test]
    fn accumulated_rmae_sums_both_tensors() {
        let cfg = QuantConfig {
            model: "m".into(),
            thr_w: 0.01,
            layers: vec![mk_layer("a", 3, 10), mk_layer("b", 4, 10)],
        };
        assert!((cfg.accumulated_rmae() - 0.06).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let cfg = QuantConfig {
            model: "alexnet_mini".into(),
            thr_w: 0.04,
            layers: vec![mk_layer("conv1", 5, 100), mk_layer("fc1", 3, 50)],
        };
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("cfg.json");
        cfg.save_json(&p).unwrap();
        let cfg2 = QuantConfig::load_json(&p).unwrap();
        assert_eq!(cfg2.model, cfg.model);
        assert_eq!(cfg2.layers.len(), 2);
        assert_eq!(cfg2.layers[0].n_bits, 5);
        assert_eq!(cfg2.layers[1].kind, LayerKind::Fc);
        let lp = cfg2.layers[0].w_params();
        assert_eq!(lp.n_bits, 5);
        assert_eq!(lp.base, 1.3);
        assert!((cfg2.layers[0].acts.beta - 0.1).abs() < 1e-12);
    }

    #[test]
    fn bitwidth_histogram_counts_layers() {
        let cfg = QuantConfig {
            model: "m".into(),
            thr_w: 0.01,
            layers: vec![mk_layer("a", 3, 10), mk_layer("b", 3, 10), mk_layer("c", 7, 10)],
        };
        let h = cfg.bitwidth_histogram();
        assert_eq!(h[3], 2);
        assert_eq!(h[7], 1);
    }

    #[test]
    fn bitwidth_histogram_saturates_above_eight() {
        // Bit-widths past the INT8 ceiling must land in the top bucket,
        // not be dropped (or panic). Built directly: histogram does not
        // validate, so out-of-range widths can reach it.
        let cfg = QuantConfig {
            model: "m".into(),
            thr_w: 0.01,
            layers: vec![mk_layer("a", 9, 10), mk_layer("b", 12, 10), mk_layer("c", 8, 10)],
        };
        let h = cfg.bitwidth_histogram();
        assert_eq!(h[8], 3);
        assert_eq!(h.iter().sum::<usize>(), 3);
    }

    #[test]
    fn bitwidth_histogram_warns_once_per_plan_on_saturation() {
        let sat = QuantConfig {
            model: "m".into(),
            thr_w: 0.01,
            layers: vec![mk_layer("a", 9, 10), mk_layer("b", 12, 10), mk_layer("c", 8, 10)],
        };
        let (h, warning) = sat.bitwidth_histogram_checked();
        assert_eq!(h[8], 3);
        // One warning per plan — not one per saturated layer — naming
        // how many layers overflowed.
        let w = warning.expect("saturating plan must warn");
        assert!(w.contains("2 layer(s)"), "{w}");
        assert!(w.contains("plan `m`"), "{w}");

        // In-range widths (8 included) must stay silent.
        let ok = QuantConfig {
            model: "m".into(),
            thr_w: 0.01,
            layers: vec![mk_layer("a", 8, 10), mk_layer("b", 3, 10)],
        };
        let (h, warning) = ok.bitwidth_histogram_checked();
        assert_eq!(h[8], 1);
        assert_eq!(h[3], 1);
        assert!(warning.is_none(), "{warning:?}");
    }

    #[test]
    fn scheme_parse_roundtrips() {
        let all =
            [Scheme::Exp, Scheme::Uniform, Scheme::Pwl { breaks: 1 }, Scheme::Pwl { breaks: 3 }];
        for s in all {
            assert_eq!(Scheme::parse(&s.name()).unwrap(), s);
        }
        assert_eq!(Scheme::parse("pwl").unwrap(), Scheme::Pwl { breaks: 1 });
        assert!(Scheme::parse("float4").is_err());
        assert!(Scheme::parse("pwl0").is_err());
    }

    #[test]
    fn v1_artifact_loads_with_exp_default() {
        // An all-exp plan encodes without any `scheme` key, so its body —
        // and therefore its checksum — is byte-identical to the schema-v1
        // form. Stamping the envelope `schema_version: 1` reconstructs a
        // true legacy artifact; it must load, defaulting every layer to
        // `Scheme::Exp` with the checksum verifying.
        let cfg = QuantConfig {
            model: "m".into(),
            thr_w: 0.04,
            layers: vec![mk_layer("conv1", 5, 100), mk_layer("fc1", 3, 50)],
        };
        assert!(!cfg.to_json().encode().contains("scheme"));
        let mut env = cfg.to_artifact_json();
        env.set("schema_version", 1u64);
        let loaded = QuantConfig::from_artifact_json(&env).unwrap();
        assert!(loaded.layers.iter().all(|l| l.scheme == Scheme::Exp));
        assert_eq!(loaded.checksum(), cfg.checksum());
    }

    #[test]
    fn mixed_scheme_roundtrip_is_checksum_exact() {
        let mut cfg = QuantConfig {
            model: "m".into(),
            thr_w: 0.04,
            layers: vec![mk_layer("conv1", 5, 100), mk_layer("fc1", 8, 50), mk_layer("fc2", 4, 50)],
        };
        cfg.layers[1].scheme = Scheme::Uniform;
        cfg.layers[1].base = 0.0;
        cfg.layers[1].weights.alpha = 0.03;
        cfg.layers[1].acts.alpha = 0.07;
        cfg.layers[2].scheme = Scheme::Pwl { breaks: 1 };
        cfg.layers[2].base = 0.0;
        cfg.layers[2].weights = TensorQuant { alpha: 0.01, beta: 0.4, rmae: 0.02, elems: 50 };
        cfg.layers[2].acts = TensorQuant { alpha: 0.05, beta: 1.2, rmae: 0.03, elems: 25 };
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("mixed.json");
        cfg.save_json(&p).unwrap();
        let cfg2 = QuantConfig::load_json(&p).unwrap();
        assert_eq!(cfg2.checksum(), cfg.checksum());
        assert_eq!(cfg2.layers[1].scheme, Scheme::Uniform);
        assert_eq!(cfg2.layers[2].scheme, Scheme::Pwl { breaks: 1 });
        assert_eq!(cfg2.scheme_names(), vec!["exp", "uniform", "pwl1"]);
    }

    #[test]
    fn load_rejects_malformed() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("bad.json");
        std::fs::write(&p, "{\"model\": 1}").unwrap();
        assert!(QuantConfig::load_json(&p).is_err());
    }

    #[test]
    fn artifact_roundtrip_is_checksum_exact() {
        // Awkward f64s (shortest-repr stress cases) must survive the
        // envelope round-trip bit-for-bit, proven by the checksum.
        let mut cfg = QuantConfig {
            model: "m".into(),
            thr_w: 0.1 + 0.2 - 0.2,
            layers: vec![mk_layer("a", 5, 1000)],
        };
        cfg.layers[0].weights.alpha = 1.0 / 3.0;
        cfg.layers[0].weights.beta = -1e-17;
        cfg.layers[0].base = f64::from_bits(1.0f64.to_bits() + 1);
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("plan.json");
        cfg.save_json(&p).unwrap();
        let cfg2 = QuantConfig::load_json(&p).unwrap();
        assert_eq!(cfg2.checksum(), cfg.checksum());
        assert_eq!(cfg2.layers[0].weights.alpha.to_bits(), cfg.layers[0].weights.alpha.to_bits());
        assert_eq!(cfg2.layers[0].weights.beta.to_bits(), cfg.layers[0].weights.beta.to_bits());
    }

    #[test]
    fn tampered_artifact_is_rejected() {
        let cfg = QuantConfig {
            model: "m".into(),
            thr_w: 0.04,
            layers: vec![mk_layer("a", 5, 100)],
        };
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("plan.json");
        cfg.save_json(&p).unwrap();
        // Flip a parameter in the stored body without fixing the checksum.
        let doctored =
            std::fs::read_to_string(&p).unwrap().replace("\"n_bits\": 5", "\"n_bits\": 6");
        assert_ne!(doctored, std::fs::read_to_string(&p).unwrap());
        std::fs::write(&p, doctored).unwrap();
        let err = QuantConfig::load_json(&p).unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("checksum mismatch"), "err: {chain}");
    }

    #[test]
    fn newer_schema_is_rejected() {
        let cfg = QuantConfig {
            model: "m".into(),
            thr_w: 0.04,
            layers: vec![mk_layer("a", 5, 100)],
        };
        let mut env = cfg.to_artifact_json();
        env.set("schema_version", PLAN_SCHEMA_VERSION + 1);
        assert!(QuantConfig::from_artifact_json(&env).is_err());
    }

    #[test]
    fn degenerate_plan_refused_at_save() {
        let mut cfg = QuantConfig {
            model: "m".into(),
            thr_w: 0.04,
            layers: vec![mk_layer("a", 5, 100)],
        };
        cfg.layers[0].base = f64::NAN;
        let dir = crate::util::TempDir::new().unwrap();
        assert!(cfg.save_json(dir.path().join("bad.json")).is_err());
    }
}
