//! End-to-end model calibration (Fig. 3, step 4 + the network-level
//! `Thr_w` controller of §III-B / §VI-E).
//!
//! The flow: for a candidate `Thr_w`, every layer runs the bitwidth sweep
//! of [`super::search`]; the resulting [`QuantConfig`] is scored by a
//! caller-supplied accuracy evaluator (full quantized inference on the
//! eval set); `Thr_w` then iterates in 1% steps while the accuracy loss
//! stays under the budget — reproducing both Table V and the Fig. 11
//! sensitivity sweep.

use super::config::{LayerKind, LayerQuant, QuantConfig, Scheme, TensorQuant};
use super::search::{activation_threshold, search_layer, SearchOptions};
use crate::tensor::Tensor;
use crate::util::parallel_map;

/// One layer's calibration inputs: trained weights plus an activation
/// trace from running inference over the calibration subset.
#[derive(Clone, Debug)]
pub struct LayerTensors {
    pub name: String,
    pub kind: LayerKind,
    pub weights: Tensor,
    /// Flattened input-activation trace of this layer.
    pub acts: Tensor,
    /// First layer of the network gets `Thr_w / 10` (§VI-E).
    pub is_first: bool,
}

/// Calibration inputs for a whole model.
#[derive(Clone, Debug)]
pub struct CalibrationInput {
    pub model: String,
    pub layers: Vec<LayerTensors>,
}

/// One point of the `Thr_w` sweep (a Fig. 11 sample).
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub thr_w: f64,
    pub accuracy: f64,
    pub accuracy_loss: f64,
    pub avg_bitwidth: f64,
    pub compression_ratio: f64,
}

/// Result of [`calibrate_model`].
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    /// The accepted configuration (largest `Thr_w` with loss < budget).
    pub config: QuantConfig,
    /// Accuracy of the accepted configuration.
    pub accuracy: f64,
    /// FP32 reference accuracy the loss is measured against.
    pub baseline_accuracy: f64,
    /// Every `Thr_w` step evaluated (Fig. 11 series, including the first
    /// rejected point).
    pub sweep: Vec<SweepPoint>,
}

/// Build a [`QuantConfig`] for a fixed network-level `Thr_w` by running
/// the per-layer search on every layer (in parallel — layers are
/// independent in the offline phase).
pub fn config_for_threshold(
    input: &CalibrationInput,
    thr_w: f64,
    opts: &SearchOptions,
) -> QuantConfig {
    let layers: Vec<LayerQuant> = parallel_map(&input.layers, |lt| {
        // First-layer special case: 10× tighter (§VI-E).
        let layer_thr_w = if lt.is_first { thr_w / 10.0 } else { thr_w };
        let thr_act = activation_threshold(
            layer_thr_w,
            lt.acts.mean_abs() as f64,
            lt.weights.mean_abs() as f64,
        );
        let res = search_layer(&lt.weights, &lt.acts, layer_thr_w, thr_act, opts);
        LayerQuant {
            name: lt.name.clone(),
            kind: lt.kind,
            scheme: Scheme::Exp,
            n_bits: res.n_bits,
            base: res.base,
            weights: TensorQuant {
                alpha: res.w_params.alpha,
                beta: res.w_params.beta,
                rmae: res.rmae_w,
                elems: lt.weights.len(),
            },
            acts: TensorQuant {
                alpha: res.a_params.alpha,
                beta: res.a_params.beta,
                rmae: res.rmae_a,
                elems: lt.acts.len(),
            },
            seeded_by_weights: res.seeded_by_weights,
            rss_w: res.rss_w,
            rss_a: res.rss_a,
            converged: res.converged,
        }
    });
    QuantConfig { model: input.model.clone(), thr_w, layers }
}

/// Options for the network-level threshold controller.
#[derive(Clone, Copy, Debug)]
pub struct CalibrationOptions {
    pub search: SearchOptions,
    /// Accuracy-loss budget (paper: 1% absolute / 1 BLEU point).
    pub max_accuracy_loss: f64,
    /// `Thr_w` step per iteration (paper: 1% = 0.01).
    pub thr_step: f64,
    /// Upper bound on `Thr_w` (paper's Transformer reached 30%).
    pub thr_max: f64,
}

impl Default for CalibrationOptions {
    fn default() -> Self {
        Self {
            search: SearchOptions::default(),
            max_accuracy_loss: 0.01,
            thr_step: 0.01,
            thr_max: 0.40,
        }
    }
}

/// Full DNA-TEQ calibration: iterate `Thr_w` in `thr_step` increments
/// while the model-level accuracy loss (measured by `eval`, which runs
/// quantized inference) stays within budget. Returns the last accepted
/// configuration plus the whole sweep for Fig. 11.
///
/// `eval(config) -> accuracy` must return accuracy in the same unit as
/// `baseline_accuracy` (top-1 fraction, or a 0–1-normalized BLEU).
pub fn calibrate_model(
    input: &CalibrationInput,
    baseline_accuracy: f64,
    opts: &CalibrationOptions,
    mut eval: impl FnMut(&QuantConfig) -> f64,
) -> CalibrationReport {
    let mut sweep = Vec::new();
    let mut accepted: Option<(QuantConfig, f64)> = None;

    let mut thr = opts.thr_step;
    while thr <= opts.thr_max + 1e-12 {
        let config = config_for_threshold(input, thr, &opts.search);
        let acc = eval(&config);
        let loss = baseline_accuracy - acc;
        sweep.push(SweepPoint {
            thr_w: thr,
            accuracy: acc,
            accuracy_loss: loss,
            avg_bitwidth: config.avg_bitwidth(),
            compression_ratio: config.compression_ratio(),
        });
        if loss <= opts.max_accuracy_loss {
            let at_floor = config.layers.iter().all(|l| l.n_bits == opts.search.min_bits);
            accepted = Some((config, acc));
            if at_floor {
                // Every layer already at the minimum bitwidth — a larger
                // threshold cannot compress further (Transformer case).
                break;
            }
        } else {
            break; // paper: continue while loss < budget
        }
        thr += opts.thr_step;
    }

    let (config, accuracy) = accepted.unwrap_or_else(|| {
        // Even Thr_w = step broke the budget: keep the tightest config —
        // the caller sees the loss in the sweep and can react.
        let config = config_for_threshold(input, opts.thr_step, &opts.search);
        let acc = sweep.first().map(|s| s.accuracy).unwrap_or(0.0);
        (config, acc)
    });

    CalibrationReport { config, accuracy, baseline_accuracy, sweep }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn mk_input(n_layers: usize, seed: u64) -> CalibrationInput {
        let mut rng = SplitMix64::new(seed);
        let layers = (0..n_layers)
            .map(|i| LayerTensors {
                name: format!("fc{i}"),
                kind: LayerKind::Fc,
                weights: Tensor::rand_signed_exponential(&[2048], 3.0, &mut rng),
                acts: Tensor::rand_signed_exponential(&[4096], 0.7, &mut rng),
                is_first: i == 0,
            })
            .collect();
        CalibrationInput { model: "toy".into(), layers }
    }

    #[test]
    fn config_has_all_layers_with_valid_bits() {
        let input = mk_input(4, 61);
        let cfg = config_for_threshold(&input, 0.05, &SearchOptions::default());
        assert_eq!(cfg.layers.len(), 4);
        for l in &cfg.layers {
            assert!((3..=7).contains(&l.n_bits));
            assert!(l.base > 1.0);
        }
    }

    #[test]
    fn first_layer_is_tighter() {
        // With a loose global threshold the first layer's 10× tighter
        // budget should usually force at least as many bits.
        let input = mk_input(4, 62);
        let cfg = config_for_threshold(&input, 0.20, &SearchOptions::default());
        let first = cfg.layers[0].n_bits;
        let rest_min = cfg.layers[1..].iter().map(|l| l.n_bits).min().unwrap();
        assert!(first >= rest_min, "first {first} vs rest min {rest_min}");
    }

    #[test]
    fn threshold_controller_stops_on_loss() {
        let input = mk_input(3, 63);
        // Synthetic accuracy model: degrades with threshold.
        let eval = |cfg: &QuantConfig| 0.9 - cfg.thr_w * 0.4;
        let report = calibrate_model(&input, 0.9, &CalibrationOptions::default(), eval);
        // loss(thr) = 0.4·thr ≤ 0.01 ⇒ thr ≤ 0.025 ⇒ accepted thr = 0.02.
        assert!((report.config.thr_w - 0.02).abs() < 1e-9, "thr {}", report.config.thr_w);
        assert_eq!(report.sweep.len(), 3); // 0.01 ok, 0.02 ok, 0.03 rejected
        assert!(report.sweep.last().unwrap().accuracy_loss > 0.01);
    }

    #[test]
    fn sweep_bitwidth_monotone_nonincreasing() {
        let input = mk_input(3, 64);
        let eval = |_: &QuantConfig| 1.0; // never lose accuracy
        let opts = CalibrationOptions { thr_max: 0.10, ..Default::default() };
        let report = calibrate_model(&input, 1.0, &opts, eval);
        let bits: Vec<f64> = report.sweep.iter().map(|s| s.avg_bitwidth).collect();
        for w in bits.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "bitwidth increased along sweep: {bits:?}");
        }
    }

    #[test]
    fn hopeless_budget_still_returns_config() {
        let input = mk_input(2, 65);
        let eval = |_: &QuantConfig| 0.0; // always catastrophic
        let report = calibrate_model(&input, 1.0, &CalibrationOptions::default(), eval);
        assert_eq!(report.sweep.len(), 1);
        assert!((report.config.thr_w - 0.01).abs() < 1e-12);
    }
}
