//! The adaptive offline parameter search (§III-B, Fig. 3 steps 2–4).
//!
//! * [`search_base`] — Algorithm 1 (`SOB`): hill-climb the exponential
//!   base `b` by ±ε, refitting `α`/`β` (Eqs. 4–5) at every step, until the
//!   RMAE (Eq. 6) stops improving.
//! * [`search_layer`] — the per-layer bitwidth loop: RSS selects which
//!   tensor seeds the search, `n` sweeps 3→7 bits until both tensors meet
//!   their error thresholds (`Thr_w`, `Thr_act` from Eq. 7).

use super::quant::{ExpQuantParams, MIN_BASE};
use super::rss::fit_distributions;
use crate::tensor::Tensor;

/// Knobs of the offline search. Defaults mirror the paper.
#[derive(Clone, Copy, Debug)]
pub struct SearchOptions {
    /// Base exploration step ε (Algorithm 1 line 4).
    pub epsilon: f64,
    /// Lowest bitwidth tried (paper: 3).
    pub min_bits: u8,
    /// Highest bitwidth tried (paper: 7).
    pub max_bits: u8,
    /// Safety cap on hill-climb iterations (the paper's loop terminates
    /// on first non-improvement; this guards degenerate plateaus).
    pub max_iters: usize,
}

impl Default for SearchOptions {
    fn default() -> Self {
        Self { epsilon: 0.01, min_bits: 3, max_bits: 7, max_iters: 4096 }
    }
}

/// Result of [`search_base`] on one tensor.
#[derive(Clone, Copy, Debug)]
pub struct BaseSearchResult {
    pub params: ExpQuantParams,
    pub rmae: f64,
    pub iterations: usize,
}

/// Algorithm 1 — Searching pseudo-Optimal Base (`SOB`).
///
/// Initializes `b`, `α`, `β` from Eqs. 4–5, picks the descent direction by
/// probing `b ± ε`, then walks until the quantization error no longer
/// improves.
pub fn search_base(t: &Tensor, n_bits: u8, opts: &SearchOptions) -> BaseSearchResult {
    // Line 2: Initialize(b, α, β).
    let init = ExpQuantParams::init_for_tensor(t, n_bits);
    // Line 3: InitErr.
    let init_err = init.rmae(t);

    let eval = |base: f64| -> (ExpQuantParams, f64) {
        let mut p = ExpQuantParams { base: base.max(MIN_BASE), ..init };
        p.refit_scale_offset(t);
        (p, p.rmae(t))
    };

    // Lines 4–8: probe both directions, pick the best of {Init, Inc, Dec}.
    let (inc_p, inc_err) = eval(init.base + opts.epsilon);
    let (dec_p, dec_err) = eval(init.base - opts.epsilon);

    let (mut cur_p, mut cur_err, step) = if inc_err < init_err && inc_err <= dec_err {
        (inc_p, inc_err, opts.epsilon)
    } else if dec_err < init_err && dec_err < inc_err {
        (dec_p, dec_err, -opts.epsilon)
    } else {
        // Initialization already at a local optimum.
        return BaseSearchResult { params: init, rmae: init_err, iterations: 1 };
    };

    // Lines 9–19: walk in the chosen direction while the error improves.
    let mut iters = 1usize;
    while iters < opts.max_iters {
        iters += 1;
        let next_base = cur_p.base + step;
        if next_base <= MIN_BASE {
            break;
        }
        let (new_p, new_err) = eval(next_base);
        if new_err < cur_err {
            cur_p = new_p;
            cur_err = new_err;
        } else {
            break; // Search = False
        }
    }
    BaseSearchResult { params: cur_p, rmae: cur_err, iterations: iters }
}

/// Derive the partner tensor's `α`/`β` for a fixed shared base/bitwidth —
/// "for the other tensor of this layer the same base is used, and we
/// simply compute the α and β parameters in the same manner" (§III-B).
pub fn fit_partner(t: &Tensor, base: f64, n_bits: u8) -> ExpQuantParams {
    let mut p = ExpQuantParams { base, alpha: 1.0, beta: 0.0, n_bits };
    p.refit_scale_offset(t);
    p
}

/// `Thr_act = Thr_w × log(mean(|Act|) / mean(|W|))` (Eq. 7), with the
/// scale factor clamped to stay a usable threshold when the magnitude
/// ratio is close to (or below) `e` — the paper leaves that regime
/// unspecified; clamping keeps Thr_act within [0.5×, 20×] of Thr_w.
pub fn activation_threshold(thr_w: f64, mean_abs_act: f64, mean_abs_w: f64) -> f64 {
    let ratio = (mean_abs_act.max(1e-12) / mean_abs_w.max(1e-12)).ln();
    thr_w * ratio.clamp(0.5, 20.0)
}

/// Outcome of the per-layer search (step 3–4 of Fig. 3).
#[derive(Clone, Debug)]
pub struct LayerSearchResult {
    /// Chosen exponent bitwidth `n`.
    pub n_bits: u8,
    /// Shared exponential base `b`.
    pub base: f64,
    /// Weight-tensor parameters.
    pub w_params: ExpQuantParams,
    /// Activation-tensor parameters.
    pub a_params: ExpQuantParams,
    pub rmae_w: f64,
    pub rmae_a: f64,
    /// True if weights had the lower RSS and seeded the base search.
    pub seeded_by_weights: bool,
    pub rss_w: f64,
    pub rss_a: f64,
    /// Whether both thresholds were met (false ⇒ fell back to `max_bits`).
    pub converged: bool,
    /// Total Algorithm-1 iterations spent across the bitwidth sweep.
    pub iterations: usize,
}

/// Full per-layer search: pick the seed tensor by RSS, sweep bitwidths
/// from `min_bits` up, accept the first `n` meeting both thresholds.
pub fn search_layer(
    weights: &Tensor,
    acts: &Tensor,
    thr_w: f64,
    thr_act: f64,
    opts: &SearchOptions,
) -> LayerSearchResult {
    let rss_w = fit_distributions(weights).best().rss;
    let rss_a = fit_distributions(acts).best().rss;
    let seeded_by_weights = rss_w < rss_a;

    let (seed, partner) =
        if seeded_by_weights { (weights, acts) } else { (acts, weights) };

    let mut total_iters = 0usize;
    let mut last: Option<LayerSearchResult> = None;
    for n in opts.min_bits..=opts.max_bits {
        let seed_res = search_base(seed, n, opts);
        total_iters += seed_res.iterations;
        let partner_params = fit_partner(partner, seed_res.params.base, n);
        let partner_err = partner_params.rmae(partner);

        let (w_params, a_params, rmae_w, rmae_a) = if seeded_by_weights {
            (seed_res.params, partner_params, seed_res.rmae, partner_err)
        } else {
            (partner_params, seed_res.params, partner_err, seed_res.rmae)
        };

        let res = LayerSearchResult {
            n_bits: n,
            base: seed_res.params.base,
            w_params,
            a_params,
            rmae_w,
            rmae_a,
            seeded_by_weights,
            rss_w,
            rss_a,
            converged: rmae_w <= thr_w && rmae_a <= thr_act,
            iterations: total_iters,
        };
        if res.converged {
            return res;
        }
        last = Some(res);
    }
    // No bitwidth satisfied both thresholds: report the widest attempt
    // (the paper keeps 7-bit layers; <3% of layers land here).
    last.expect("at least one bitwidth attempted")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn expo(n: usize, rate: f32, seed: u64) -> Tensor {
        let mut rng = SplitMix64::new(seed);
        Tensor::rand_signed_exponential(&[n], rate, &mut rng)
    }

    #[test]
    fn sob_never_worse_than_init() {
        let t = expo(8192, 2.0, 31);
        let opts = SearchOptions::default();
        for n in 3..=7u8 {
            let init = ExpQuantParams::init_for_tensor(&t, n);
            let res = search_base(&t, n, &opts);
            assert!(
                res.rmae <= init.rmae(&t) + 1e-12,
                "n={n}: searched {} vs init {}",
                res.rmae,
                init.rmae(&t)
            );
        }
    }

    #[test]
    fn sob_terminates_quickly() {
        let t = expo(4096, 3.0, 32);
        let res = search_base(&t, 5, &SearchOptions::default());
        assert!(res.iterations < 2048, "iterations {}", res.iterations);
        assert!(res.params.base > 1.0);
    }

    #[test]
    fn layer_search_prefers_lower_bits_for_tolerant_thresholds() {
        let w = expo(4096, 2.0, 33);
        let a = expo(4096, 0.5, 34);
        let tight = search_layer(&w, &a, 0.01, 0.02, &SearchOptions::default());
        let loose = search_layer(&w, &a, 0.30, 0.40, &SearchOptions::default());
        assert!(
            loose.n_bits <= tight.n_bits,
            "loose {} vs tight {}",
            loose.n_bits,
            tight.n_bits
        );
        assert!(loose.converged);
    }

    #[test]
    fn layer_search_shares_base_between_tensors() {
        let w = expo(2048, 2.0, 35);
        let a = expo(2048, 1.0, 36);
        let res = search_layer(&w, &a, 0.05, 0.10, &SearchOptions::default());
        assert_eq!(res.w_params.base, res.a_params.base);
        assert_eq!(res.w_params.n_bits, res.a_params.n_bits);
    }

    #[test]
    fn layer_search_falls_back_to_max_bits() {
        // Impossible thresholds: must report max_bits, not converge.
        let w = expo(2048, 2.0, 37);
        let a = expo(2048, 1.0, 38);
        let res = search_layer(&w, &a, 1e-9, 1e-9, &SearchOptions::default());
        assert_eq!(res.n_bits, 7);
        assert!(!res.converged);
    }

    #[test]
    fn threshold_scaling_clamped() {
        // Act magnitudes 100× weights → ln(100) ≈ 4.6 scale.
        let t = activation_threshold(0.01, 1.0, 0.01);
        assert!((t - 0.01 * 100f64.ln()).abs() < 1e-9);
        // Act magnitudes equal to weights → clamp at 0.5×, not 0.
        let t2 = activation_threshold(0.01, 1.0, 1.0);
        assert!((t2 - 0.005).abs() < 1e-12);
    }

    #[test]
    fn seed_selection_follows_rss() {
        // Weights strongly exponential, activations uniform: weights seed.
        let w = expo(20_000, 3.0, 39);
        let mut rng = SplitMix64::new(40);
        let a = Tensor::rand_uniform(&[20_000], -1.0, 1.0, &mut rng);
        let res = search_layer(&w, &a, 0.2, 0.4, &SearchOptions::default());
        assert!(res.seeded_by_weights, "rss_w={} rss_a={}", res.rss_w, res.rss_a);
    }
}
