//! The adaptive offline parameter search (§III-B, Fig. 3 steps 2–4) and
//! the hybrid per-layer planner built on top of it.
//!
//! * [`search_base`] — Algorithm 1 (`SOB`): hill-climb the exponential
//!   base `b` by ±ε, refitting `α`/`β` (Eqs. 4–5) at every step, until the
//!   RMAE (Eq. 6) stops improving.
//! * [`Planner`] — the unified per-layer search over a
//!   [`SearchSpace`] of scheme × bit-width candidates: the paper's
//!   exp-only 3→7 sweep ([`SearchSpace::exp_only`]) or the full hybrid
//!   {exp, uniform, pwl} × 2..=8 space ([`SearchSpace::full`]).
//! * [`Planner::plan_set`] — traces the accuracy/compression/energy
//!   Pareto front of a model as a [`PlanSet`]: one [`QuantConfig`] per
//!   non-dominated trade-off, ready to be persisted by the plan store.
//! * [`search_layer`] — thin compatibility shim over [`Planner`] with
//!   the legacy single-config signature.

use super::calib::CalibrationInput;
use super::config::{LayerKind, LayerQuant, QuantConfig, Scheme, TensorQuant};
use super::pwl::PwlParams;
use super::quant::{ExpQuantParams, MIN_BASE};
use super::rss::fit_distributions;
use super::uniform::UniformParams;
use crate::accel::energy::EnergyModel;
use crate::tensor::Tensor;
use crate::util::parallel_map;

/// Knobs of the offline search. Defaults mirror the paper.
#[derive(Clone, Copy, Debug)]
pub struct SearchOptions {
    /// Base exploration step ε (Algorithm 1 line 4).
    pub epsilon: f64,
    /// Lowest bitwidth tried (paper: 3).
    pub min_bits: u8,
    /// Highest bitwidth tried (paper: 7).
    pub max_bits: u8,
    /// Safety cap on hill-climb iterations (the paper's loop terminates
    /// on first non-improvement; this guards degenerate plateaus).
    pub max_iters: usize,
}

impl Default for SearchOptions {
    fn default() -> Self {
        Self { epsilon: 0.01, min_bits: 3, max_bits: 7, max_iters: 4096 }
    }
}

/// Result of [`search_base`] on one tensor.
#[derive(Clone, Copy, Debug)]
pub struct BaseSearchResult {
    pub params: ExpQuantParams,
    pub rmae: f64,
    pub iterations: usize,
}

/// Algorithm 1 — Searching pseudo-Optimal Base (`SOB`).
///
/// Initializes `b`, `α`, `β` from Eqs. 4–5, picks the descent direction by
/// probing `b ± ε`, then walks until the quantization error no longer
/// improves.
pub fn search_base(t: &Tensor, n_bits: u8, opts: &SearchOptions) -> BaseSearchResult {
    // Line 2: Initialize(b, α, β).
    let init = ExpQuantParams::init_for_tensor(t, n_bits);
    // Line 3: InitErr.
    let init_err = init.rmae(t);

    let eval = |base: f64| -> (ExpQuantParams, f64) {
        let mut p = ExpQuantParams { base: base.max(MIN_BASE), ..init };
        p.refit_scale_offset(t);
        (p, p.rmae(t))
    };

    // Lines 4–8: probe both directions, pick the best of {Init, Inc, Dec}.
    let (inc_p, inc_err) = eval(init.base + opts.epsilon);
    let (dec_p, dec_err) = eval(init.base - opts.epsilon);

    let (mut cur_p, mut cur_err, step) = if inc_err < init_err && inc_err <= dec_err {
        (inc_p, inc_err, opts.epsilon)
    } else if dec_err < init_err && dec_err < inc_err {
        (dec_p, dec_err, -opts.epsilon)
    } else {
        // Initialization already at a local optimum.
        return BaseSearchResult { params: init, rmae: init_err, iterations: 1 };
    };

    // Lines 9–19: walk in the chosen direction while the error improves.
    let mut iters = 1usize;
    while iters < opts.max_iters {
        iters += 1;
        let next_base = cur_p.base + step;
        if next_base <= MIN_BASE {
            break;
        }
        let (new_p, new_err) = eval(next_base);
        if new_err < cur_err {
            cur_p = new_p;
            cur_err = new_err;
        } else {
            break; // Search = False
        }
    }
    BaseSearchResult { params: cur_p, rmae: cur_err, iterations: iters }
}

/// Derive the partner tensor's `α`/`β` for a fixed shared base/bitwidth —
/// "for the other tensor of this layer the same base is used, and we
/// simply compute the α and β parameters in the same manner" (§III-B).
pub fn fit_partner(t: &Tensor, base: f64, n_bits: u8) -> ExpQuantParams {
    let mut p = ExpQuantParams { base, alpha: 1.0, beta: 0.0, n_bits };
    p.refit_scale_offset(t);
    p
}

/// `Thr_act = Thr_w × log(mean(|Act|) / mean(|W|))` (Eq. 7), with the
/// scale factor clamped to stay a usable threshold when the magnitude
/// ratio is close to (or below) `e` — the paper leaves that regime
/// unspecified; clamping keeps Thr_act within [0.5×, 20×] of Thr_w.
pub fn activation_threshold(thr_w: f64, mean_abs_act: f64, mean_abs_w: f64) -> f64 {
    let ratio = (mean_abs_act.max(1e-12) / mean_abs_w.max(1e-12)).ln();
    thr_w * ratio.clamp(0.5, 20.0)
}

/// Outcome of the per-layer search (step 3–4 of Fig. 3).
#[derive(Clone, Debug)]
pub struct LayerSearchResult {
    /// Chosen exponent bitwidth `n`.
    pub n_bits: u8,
    /// Shared exponential base `b`.
    pub base: f64,
    /// Weight-tensor parameters.
    pub w_params: ExpQuantParams,
    /// Activation-tensor parameters.
    pub a_params: ExpQuantParams,
    pub rmae_w: f64,
    pub rmae_a: f64,
    /// True if weights had the lower RSS and seeded the base search.
    pub seeded_by_weights: bool,
    pub rss_w: f64,
    pub rss_a: f64,
    /// Whether both thresholds were met (false ⇒ fell back to `max_bits`).
    pub converged: bool,
    /// Total Algorithm-1 iterations spent across the bitwidth sweep.
    pub iterations: usize,
}

/// Full per-layer search: pick the seed tensor by RSS, sweep bitwidths
/// from `min_bits` up, accept the first `n` meeting both thresholds.
///
/// Compatibility shim: delegates to [`Planner::plan_layer`] over an
/// exponential-only [`SearchSpace`]. New code should construct a
/// [`Planner`] directly — it exposes the same sweep plus the hybrid
/// scheme space and the Pareto-front search.
pub fn search_layer(
    weights: &Tensor,
    acts: &Tensor,
    thr_w: f64,
    thr_act: f64,
    opts: &SearchOptions,
) -> LayerSearchResult {
    let planner = Planner {
        space: SearchSpace {
            schemes: vec![Scheme::Exp],
            min_bits: opts.min_bits,
            max_bits: opts.max_bits,
            thr_w,
        },
        opts: *opts,
    };
    let c = planner.plan_layer(weights, acts, thr_w, thr_act);
    LayerSearchResult {
        n_bits: c.n_bits,
        base: c.base,
        w_params: ExpQuantParams {
            base: c.base,
            alpha: c.weights.alpha,
            beta: c.weights.beta,
            n_bits: c.n_bits,
        },
        a_params: ExpQuantParams {
            base: c.base,
            alpha: c.acts.alpha,
            beta: c.acts.beta,
            n_bits: c.n_bits,
        },
        rmae_w: c.weights.rmae,
        rmae_a: c.acts.rmae,
        seeded_by_weights: c.seeded_by_weights,
        rss_w: c.rss_w,
        rss_a: c.rss_a,
        converged: c.converged,
        iterations: c.iterations,
    }
}

/// The hybrid planner's search space: which schemes to try, the bit-width
/// sweep bounds, and the network-level weight-error threshold.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Schemes tried at each bit-width, in preference order.
    pub schemes: Vec<Scheme>,
    pub min_bits: u8,
    pub max_bits: u8,
    /// Network-level `Thr_w` (Eq. 7); per-layer thresholds derive from it.
    pub thr_w: f64,
}

impl SearchSpace {
    /// The paper's space: exponential codes only, 3→7 bits.
    pub fn exp_only(thr_w: f64) -> Self {
        Self { schemes: vec![Scheme::Exp], min_bits: 3, max_bits: 7, thr_w }
    }

    /// The full hybrid space: {exp, uniform, pwl} × 2..=8 bits.
    pub fn full(thr_w: f64) -> Self {
        Self {
            schemes: vec![Scheme::Exp, Scheme::Uniform, Scheme::Pwl { breaks: 1 }],
            min_bits: 2,
            max_bits: 8,
            thr_w,
        }
    }

    /// Whether `(scheme, n_bits)` lies inside both this space and the
    /// scheme's own representable range.
    pub fn admits(&self, scheme: Scheme, n_bits: u8) -> bool {
        let (lo, hi) = scheme.bit_range();
        n_bits >= self.min_bits.max(lo) && n_bits <= self.max_bits.min(hi)
    }
}

/// One evaluated (scheme, bit-width) candidate for a layer.
#[derive(Clone, Debug)]
pub struct LayerCandidate {
    pub scheme: Scheme,
    pub n_bits: u8,
    /// Exponential base (0.0 for non-exp schemes, which have none).
    pub base: f64,
    pub weights: TensorQuant,
    pub acts: TensorQuant,
    pub seeded_by_weights: bool,
    pub rss_w: f64,
    pub rss_a: f64,
    /// Both tensors met their thresholds.
    pub converged: bool,
    /// Algorithm-1 iterations accumulated across the sweep up to and
    /// including this candidate (uniform/pwl calibration is closed-form
    /// and adds none).
    pub iterations: usize,
}

impl LayerCandidate {
    /// Combined weight + activation error, the accuracy axis of the front.
    pub fn rmae_sum(&self) -> f64 {
        self.weights.rmae + self.acts.rmae
    }

    /// Materialize as a plan layer record.
    pub fn to_layer_quant(&self, name: &str, kind: LayerKind) -> LayerQuant {
        LayerQuant {
            name: name.to_string(),
            kind,
            scheme: self.scheme,
            n_bits: self.n_bits,
            base: self.base,
            weights: self.weights,
            acts: self.acts,
            seeded_by_weights: self.seeded_by_weights,
            rss_w: self.rss_w,
            rss_a: self.rss_a,
            converged: self.converged,
        }
    }
}

/// λ grid for scalarizing accuracy against bits while tracing the front:
/// per-layer `argmin(rmae_w + rmae_a + λ·n_bits)` from pure accuracy
/// (λ = 0) to bits-dominate (λ = 10³). Configs that coincide collapse in
/// the checksum dedupe, so a dense grid costs nothing extra.
const LAMBDA_GRID: [f64; 12] =
    [0.0, 1e-3, 2e-3, 5e-3, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 1e3];

/// One point on the accuracy/compression/energy Pareto front.
#[derive(Clone, Debug)]
pub struct PlanPoint {
    pub config: QuantConfig,
    /// Accumulated weight + activation RMAE (lower = more accurate).
    pub rmae: f64,
    /// Compression ratio vs INT8 (`1 − avg_bits/8`; higher = smaller).
    pub compression: f64,
    pub avg_bits: f64,
    /// Estimated compute energy per inference element, in joules.
    pub energy_j: f64,
}

/// The planner's Pareto front for one model: every non-dominated
/// accuracy/compression trade-off found in the scheme × bit-width space,
/// sorted by ascending RMAE (and therefore ascending compression).
#[derive(Clone, Debug)]
pub struct PlanSet {
    pub model: String,
    pub thr_w: f64,
    pub points: Vec<PlanPoint>,
}

/// Keep only non-dominated points: sort by RMAE ascending (compression
/// descending on ties), then keep each point whose compression strictly
/// exceeds every earlier kept point's. The survivors are strictly
/// ascending in both axes, so no kept point dominates another.
fn skyline(mut points: Vec<PlanPoint>) -> Vec<PlanPoint> {
    points.sort_by(|a, b| {
        a.rmae
            .partial_cmp(&b.rmae)
            .unwrap()
            .then(b.compression.partial_cmp(&a.compression).unwrap())
    });
    let mut kept: Vec<PlanPoint> = Vec::new();
    let mut best_comp = f64::NEG_INFINITY;
    for p in points {
        if p.compression > best_comp {
            best_comp = p.compression;
            kept.push(p);
        }
    }
    kept
}

/// Unified entry point for the per-layer search: one object owns the
/// scheme × bit-width [`SearchSpace`] and the Algorithm-1 knobs that
/// [`search_base`] / [`fit_partner`] / [`search_layer`] previously took
/// piecemeal.
#[derive(Clone, Debug)]
pub struct Planner {
    pub space: SearchSpace,
    pub opts: SearchOptions,
}

impl Planner {
    pub fn new(space: SearchSpace) -> Self {
        let opts = SearchOptions {
            min_bits: space.min_bits,
            max_bits: space.max_bits,
            ..SearchOptions::default()
        };
        Self { space, opts }
    }

    fn rss_pair(weights: &Tensor, acts: &Tensor) -> (f64, f64) {
        (fit_distributions(weights).best().rss, fit_distributions(acts).best().rss)
    }

    /// Evaluate one (scheme, n) candidate. `total_iters` accumulates
    /// Algorithm-1 hill-climb work across a sweep (exp only).
    #[allow(clippy::too_many_arguments)]
    fn candidate(
        &self,
        scheme: Scheme,
        n: u8,
        weights: &Tensor,
        acts: &Tensor,
        thr_w: f64,
        thr_act: f64,
        rss: (f64, f64),
        total_iters: &mut usize,
    ) -> LayerCandidate {
        let (rss_w, rss_a) = rss;
        let seeded_by_weights = rss_w < rss_a;
        let (base, w_alpha, w_beta, a_alpha, a_beta, rmae_w, rmae_a) = match scheme {
            Scheme::Exp => {
                let (seed, partner) =
                    if seeded_by_weights { (weights, acts) } else { (acts, weights) };
                let seed_res = search_base(seed, n, &self.opts);
                *total_iters += seed_res.iterations;
                let partner_params = fit_partner(partner, seed_res.params.base, n);
                let partner_err = partner_params.rmae(partner);
                let (w, a, ew, ea) = if seeded_by_weights {
                    (seed_res.params, partner_params, seed_res.rmae, partner_err)
                } else {
                    (partner_params, seed_res.params, partner_err, seed_res.rmae)
                };
                (w.base, w.alpha, w.beta, a.alpha, a.beta, ew, ea)
            }
            Scheme::Uniform => {
                let w = UniformParams::calibrate(weights, n);
                let a = UniformParams::calibrate(acts, n);
                (0.0, w.delta, 0.0, a.delta, 0.0, w.rmae(weights), a.rmae(acts))
            }
            Scheme::Pwl { breaks } => {
                let w = PwlParams::calibrate(weights, n, breaks);
                let a = PwlParams::calibrate(acts, n, breaks);
                (
                    0.0,
                    w.first_delta(),
                    w.first_break(),
                    a.first_delta(),
                    a.first_break(),
                    w.rmae(weights),
                    a.rmae(acts),
                )
            }
        };
        LayerCandidate {
            scheme,
            n_bits: n,
            base,
            weights: TensorQuant {
                alpha: w_alpha,
                beta: w_beta,
                rmae: rmae_w,
                elems: weights.len(),
            },
            acts: TensorQuant { alpha: a_alpha, beta: a_beta, rmae: rmae_a, elems: acts.len() },
            seeded_by_weights,
            rss_w,
            rss_a,
            converged: rmae_w <= thr_w && rmae_a <= thr_act,
            iterations: *total_iters,
        }
    }

    /// Single-plan per-layer search: sweep bit-widths ascending (schemes
    /// in declared order at each width), accept the first candidate
    /// meeting both thresholds — exactly the paper's sweep for the
    /// exp-only space. Falls back to the lowest-error widest candidate
    /// when nothing converges.
    pub fn plan_layer(
        &self,
        weights: &Tensor,
        acts: &Tensor,
        thr_w: f64,
        thr_act: f64,
    ) -> LayerCandidate {
        let rss = Self::rss_pair(weights, acts);
        let mut total_iters = 0usize;
        let mut last: Option<LayerCandidate> = None;
        for n in self.space.min_bits..=self.space.max_bits {
            for &scheme in &self.space.schemes {
                if !self.space.admits(scheme, n) {
                    continue;
                }
                let c =
                    self.candidate(scheme, n, weights, acts, thr_w, thr_act, rss, &mut total_iters);
                if c.converged {
                    return c;
                }
                let better = last
                    .as_ref()
                    .map(|l| c.n_bits > l.n_bits || c.rmae_sum() < l.rmae_sum())
                    .unwrap_or(true);
                if better {
                    last = Some(c);
                }
            }
        }
        last.expect("search space admits at least one candidate")
    }

    /// Every admissible (scheme, bit-width) candidate for one layer, in
    /// deterministic sweep order — fuel for the Pareto-front search.
    pub fn layer_candidates(
        &self,
        weights: &Tensor,
        acts: &Tensor,
        thr_w: f64,
        thr_act: f64,
    ) -> Vec<LayerCandidate> {
        let rss = Self::rss_pair(weights, acts);
        let mut total_iters = 0usize;
        let mut out = Vec::new();
        for n in self.space.min_bits..=self.space.max_bits {
            for &scheme in &self.space.schemes {
                if self.space.admits(scheme, n) {
                    out.push(self.candidate(
                        scheme,
                        n,
                        weights,
                        acts,
                        thr_w,
                        thr_act,
                        rss,
                        &mut total_iters,
                    ));
                }
            }
        }
        out
    }

    /// Trace the model's accuracy/compression/energy Pareto front.
    ///
    /// Per-layer candidates are evaluated once (layers in parallel); a λ
    /// grid then scalarizes accuracy against bits, each λ yielding one
    /// [`QuantConfig`] by independent per-layer argmin. Duplicate configs
    /// collapse by checksum and dominated points are discarded, so the
    /// result is the non-dominated staircase from most-accurate to
    /// most-compressed. Fully deterministic for a given input.
    pub fn plan_set(&self, input: &CalibrationInput) -> PlanSet {
        let thr_w = self.space.thr_w;
        let per_layer: Vec<Vec<LayerCandidate>> = parallel_map(&input.layers, |lt| {
            // First-layer special case: 10× tighter (§VI-E).
            let layer_thr_w = if lt.is_first { thr_w / 10.0 } else { thr_w };
            let thr_act = activation_threshold(
                layer_thr_w,
                lt.acts.mean_abs() as f64,
                lt.weights.mean_abs() as f64,
            );
            self.layer_candidates(&lt.weights, &lt.acts, layer_thr_w, thr_act)
        });

        let energy = EnergyModel::default();
        let mut points: Vec<PlanPoint> = Vec::new();
        let mut seen: Vec<u64> = Vec::new();
        for &lambda in &LAMBDA_GRID {
            let layers: Vec<LayerQuant> = input
                .layers
                .iter()
                .zip(&per_layer)
                .map(|(lt, cands)| {
                    let best = cands
                        .iter()
                        .min_by(|a, b| {
                            let sa = a.rmae_sum() + lambda * a.n_bits as f64;
                            let sb = b.rmae_sum() + lambda * b.n_bits as f64;
                            sa.partial_cmp(&sb).unwrap()
                        })
                        .expect("search space admits at least one candidate");
                    best.to_layer_quant(&lt.name, lt.kind)
                })
                .collect();
            let config = QuantConfig { model: input.model.clone(), thr_w, layers };
            let checksum = config.checksum();
            if seen.contains(&checksum) {
                continue;
            }
            seen.push(checksum);
            let rmae = config.accumulated_rmae();
            let compression = config.compression_ratio();
            let avg_bits = config.avg_bitwidth();
            let energy_j = energy.config_energy_j(&config);
            points.push(PlanPoint { config, rmae, compression, avg_bits, energy_j });
        }
        PlanSet { model: input.model.clone(), thr_w, points: skyline(points) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn expo(n: usize, rate: f32, seed: u64) -> Tensor {
        let mut rng = SplitMix64::new(seed);
        Tensor::rand_signed_exponential(&[n], rate, &mut rng)
    }

    #[test]
    fn sob_never_worse_than_init() {
        let t = expo(8192, 2.0, 31);
        let opts = SearchOptions::default();
        for n in 3..=7u8 {
            let init = ExpQuantParams::init_for_tensor(&t, n);
            let res = search_base(&t, n, &opts);
            assert!(
                res.rmae <= init.rmae(&t) + 1e-12,
                "n={n}: searched {} vs init {}",
                res.rmae,
                init.rmae(&t)
            );
        }
    }

    #[test]
    fn sob_terminates_quickly() {
        let t = expo(4096, 3.0, 32);
        let res = search_base(&t, 5, &SearchOptions::default());
        assert!(res.iterations < 2048, "iterations {}", res.iterations);
        assert!(res.params.base > 1.0);
    }

    #[test]
    fn layer_search_prefers_lower_bits_for_tolerant_thresholds() {
        let w = expo(4096, 2.0, 33);
        let a = expo(4096, 0.5, 34);
        let tight = search_layer(&w, &a, 0.01, 0.02, &SearchOptions::default());
        let loose = search_layer(&w, &a, 0.30, 0.40, &SearchOptions::default());
        assert!(
            loose.n_bits <= tight.n_bits,
            "loose {} vs tight {}",
            loose.n_bits,
            tight.n_bits
        );
        assert!(loose.converged);
    }

    #[test]
    fn layer_search_shares_base_between_tensors() {
        let w = expo(2048, 2.0, 35);
        let a = expo(2048, 1.0, 36);
        let res = search_layer(&w, &a, 0.05, 0.10, &SearchOptions::default());
        assert_eq!(res.w_params.base, res.a_params.base);
        assert_eq!(res.w_params.n_bits, res.a_params.n_bits);
    }

    #[test]
    fn layer_search_falls_back_to_max_bits() {
        // Impossible thresholds: must report max_bits, not converge.
        let w = expo(2048, 2.0, 37);
        let a = expo(2048, 1.0, 38);
        let res = search_layer(&w, &a, 1e-9, 1e-9, &SearchOptions::default());
        assert_eq!(res.n_bits, 7);
        assert!(!res.converged);
    }

    #[test]
    fn threshold_scaling_clamped() {
        // Act magnitudes 100× weights → ln(100) ≈ 4.6 scale.
        let t = activation_threshold(0.01, 1.0, 0.01);
        assert!((t - 0.01 * 100f64.ln()).abs() < 1e-9);
        // Act magnitudes equal to weights → clamp at 0.5×, not 0.
        let t2 = activation_threshold(0.01, 1.0, 1.0);
        assert!((t2 - 0.005).abs() < 1e-12);
    }

    fn mixed_input(seed: u64) -> CalibrationInput {
        // One exponential-shaped layer (exp codes shine) and one
        // uniform-shaped layer (linear grids shine): the hybrid planner
        // should use different schemes where each wins.
        let mut rng = SplitMix64::new(seed);
        let layers = vec![
            super::super::calib::LayerTensors {
                name: "conv1".into(),
                kind: LayerKind::Conv,
                weights: Tensor::rand_signed_exponential(&[2048], 3.0, &mut rng),
                acts: Tensor::rand_signed_exponential(&[4096], 0.7, &mut rng),
                is_first: true,
            },
            super::super::calib::LayerTensors {
                name: "fc1".into(),
                kind: LayerKind::Fc,
                weights: Tensor::rand_uniform(&[2048], -1.0, 1.0, &mut rng),
                acts: Tensor::rand_uniform(&[4096], 0.0, 2.0, &mut rng),
                is_first: false,
            },
        ];
        CalibrationInput { model: "toy".into(), layers }
    }

    #[test]
    fn full_space_reaches_eight_bits_when_needed() {
        // Impossible thresholds: the hybrid fallback must land on the
        // widest width, which only uniform/pwl can reach.
        let mut rng = SplitMix64::new(41);
        let w = Tensor::rand_uniform(&[2048], -1.0, 1.0, &mut rng);
        let a = Tensor::rand_uniform(&[2048], 0.0, 1.0, &mut rng);
        let planner = Planner::new(SearchSpace::full(0.05));
        let c = planner.plan_layer(&w, &a, 1e-9, 1e-9);
        assert_eq!(c.n_bits, 8);
        assert_ne!(c.scheme, Scheme::Exp);
        assert!(!c.converged);
    }

    #[test]
    fn planner_exp_only_matches_legacy_search_layer() {
        let w = expo(2048, 2.0, 42);
        let a = expo(2048, 1.0, 43);
        let opts = SearchOptions::default();
        let legacy = search_layer(&w, &a, 0.05, 0.10, &opts);
        let planner = Planner::new(SearchSpace::exp_only(0.05));
        let c = planner.plan_layer(&w, &a, 0.05, 0.10);
        assert_eq!(c.scheme, Scheme::Exp);
        assert_eq!(c.n_bits, legacy.n_bits);
        assert_eq!(c.base.to_bits(), legacy.base.to_bits());
        assert_eq!(c.weights.alpha.to_bits(), legacy.w_params.alpha.to_bits());
        assert_eq!(c.acts.beta.to_bits(), legacy.a_params.beta.to_bits());
        assert_eq!(c.iterations, legacy.iterations);
        assert_eq!(c.converged, legacy.converged);
    }

    #[test]
    fn plan_set_front_is_non_dominated_and_sorted() {
        let input = mixed_input(44);
        let set = Planner::new(SearchSpace::full(0.05)).plan_set(&input);
        assert!(!set.points.is_empty());
        for p in &set.points {
            p.config.validate().unwrap();
            assert!(p.energy_j > 0.0);
        }
        for w in set.points.windows(2) {
            assert!(w[0].rmae < w[1].rmae, "front not sorted by rmae");
            assert!(w[0].compression < w[1].compression, "front not ascending in compression");
        }
        for (i, p) in set.points.iter().enumerate() {
            for (j, q) in set.points.iter().enumerate() {
                if i == j {
                    continue;
                }
                let dominated = q.rmae <= p.rmae
                    && q.compression >= p.compression
                    && (q.rmae < p.rmae || q.compression > p.compression);
                assert!(!dominated, "point {i} dominated by {j}");
            }
        }
    }

    #[test]
    fn plan_set_is_deterministic() {
        let a = Planner::new(SearchSpace::full(0.05)).plan_set(&mixed_input(45));
        let b = Planner::new(SearchSpace::full(0.05)).plan_set(&mixed_input(45));
        assert_eq!(a.points.len(), b.points.len());
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.config.checksum(), pb.config.checksum());
            assert_eq!(pa.energy_j.to_bits(), pb.energy_j.to_bits());
        }
    }

    #[test]
    fn plan_set_spans_multiple_schemes() {
        let set = Planner::new(SearchSpace::full(0.05)).plan_set(&mixed_input(46));
        let mut schemes: Vec<String> = Vec::new();
        for p in &set.points {
            for s in p.config.scheme_names() {
                if !schemes.contains(&s) {
                    schemes.push(s);
                }
            }
        }
        assert!(
            schemes.len() >= 2,
            "hybrid front should span ≥ 2 schemes, got {schemes:?}"
        );
    }

    #[test]
    fn seed_selection_follows_rss() {
        // Weights strongly exponential, activations uniform: weights seed.
        let w = expo(20_000, 3.0, 39);
        let mut rng = SplitMix64::new(40);
        let a = Tensor::rand_uniform(&[20_000], -1.0, 1.0, &mut rng);
        let res = search_layer(&w, &a, 0.2, 0.4, &SearchOptions::default());
        assert!(res.seeded_by_weights, "rss_w={} rss_a={}", res.rss_w, res.rss_a);
    }
}
