//! Goodness-of-fit analysis (§III-A, Tables I & II, Figs. 1 & 2).
//!
//! The empirical density of a tensor's *absolute values* is compared
//! against four candidate distributions via the Residual Sum of Squares
//! (Eq. 1). Each candidate is parameterized by its maximum-likelihood /
//! moment estimate from the data, then evaluated at the histogram bin
//! centers. The distribution with the lowest RSS selects which tensor of
//! a layer seeds Algorithm 1's base search (step 2 of Fig. 3).

use crate::tensor::{Histogram, Tensor};

/// Number of histogram bins used for all RSS computations. Matching the
/// paper's exact bin count is impossible (unreported); RSS *ordering*
/// across distributions is insensitive to this for the populations here.
pub const RSS_BINS: usize = 100;

/// Candidate distribution families from Tables I & II.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DistKind {
    Normal,
    Exponential,
    Pareto,
    Uniform,
}

impl DistKind {
    pub const ALL: [DistKind; 4] =
        [DistKind::Normal, DistKind::Exponential, DistKind::Pareto, DistKind::Uniform];

    pub fn name(&self) -> &'static str {
        match self {
            DistKind::Normal => "Normal",
            DistKind::Exponential => "Exponential",
            DistKind::Pareto => "Pareto",
            DistKind::Uniform => "Uniform",
        }
    }
}

/// Fitted distribution over magnitudes with its RSS against the empirical
/// density.
#[derive(Clone, Copy, Debug)]
pub struct Fit {
    pub kind: DistKind,
    pub rss: f64,
    /// Family-specific parameters:
    /// Normal: (μ, σ); Exponential: (λ, 0); Pareto: (x_m, a); Uniform: (lo, hi).
    pub p0: f64,
    pub p1: f64,
}

/// Full fit report for one tensor.
#[derive(Clone, Debug)]
pub struct FitReport {
    pub fits: Vec<Fit>,
    /// Histogram bin centers (for Figs. 1 & 2 CSV emission).
    pub centers: Vec<f32>,
    /// Empirical density per bin.
    pub density: Vec<f32>,
}

impl FitReport {
    /// The distribution family with the lowest RSS.
    pub fn best(&self) -> Fit {
        *self
            .fits
            .iter()
            .min_by(|a, b| a.rss.partial_cmp(&b.rss).unwrap())
            .expect("non-empty fits")
    }

    pub fn rss_of(&self, kind: DistKind) -> f64 {
        self.fits.iter().find(|f| f.kind == kind).map(|f| f.rss).unwrap_or(f64::NAN)
    }

    /// Predicted density series for a family (for figure CSVs).
    pub fn predicted(&self, kind: DistKind) -> Vec<f64> {
        let fit = self.fits.iter().find(|f| f.kind == kind).copied().unwrap();
        self.centers.iter().map(|&c| pdf(fit, c as f64)).collect()
    }
}

fn pdf(fit: Fit, x: f64) -> f64 {
    match fit.kind {
        DistKind::Normal => {
            let (mu, sigma) = (fit.p0, fit.p1.max(1e-12));
            let z = (x - mu) / sigma;
            (-0.5 * z * z).exp() / (sigma * (2.0 * std::f64::consts::PI).sqrt())
        }
        DistKind::Exponential => {
            let lambda = fit.p0;
            if x < 0.0 {
                0.0
            } else {
                lambda * (-lambda * x).exp()
            }
        }
        DistKind::Pareto => {
            let (xm, a) = (fit.p0.max(1e-12), fit.p1);
            if x < xm {
                0.0
            } else {
                a * xm.powf(a) / x.powf(a + 1.0)
            }
        }
        DistKind::Uniform => {
            let (lo, hi) = (fit.p0, fit.p1);
            if x < lo || x > hi || hi <= lo {
                0.0
            } else {
                1.0 / (hi - lo)
            }
        }
    }
}

/// Fit all four families to the magnitudes of `t` and report RSS values
/// (Eq. 1) against the empirical histogram density.
pub fn fit_distributions(t: &Tensor) -> FitReport {
    let mags: Vec<f32> = t.data().iter().map(|x| x.abs()).filter(|&m| m > 0.0).collect();
    fit_magnitudes(&mags)
}

/// Same as [`fit_distributions`] but over pre-extracted magnitudes.
pub fn fit_magnitudes(mags: &[f32]) -> FitReport {
    assert!(!mags.is_empty(), "cannot fit an empty tensor");
    let hi = mags.iter().cloned().fold(f32::MIN, f32::max).max(1e-9);
    let hist = Histogram::build(mags, 0.0, hi, RSS_BINS);
    let centers = hist.centers();
    let density = hist.density();

    let n = mags.len() as f64;
    let mean = mags.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = mags.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    let min = mags.iter().cloned().fold(f32::MAX, f32::min) as f64;

    // MLE / moment parameter estimates per family.
    let normal = Fit { kind: DistKind::Normal, rss: 0.0, p0: mean, p1: var.sqrt() };
    let expo = Fit { kind: DistKind::Exponential, rss: 0.0, p0: 1.0 / mean.max(1e-12), p1: 0.0 };
    let pareto_a = {
        let xm = min.max(1e-12);
        let s: f64 = mags.iter().map(|&x| ((x as f64).max(xm) / xm).ln()).sum();
        (n / s.max(1e-12)).min(1e6)
    };
    let pareto = Fit { kind: DistKind::Pareto, rss: 0.0, p0: min, p1: pareto_a };
    let uniform = Fit { kind: DistKind::Uniform, rss: 0.0, p0: 0.0, p1: hi as f64 };

    let mut fits = vec![normal, expo, pareto, uniform];
    for fit in &mut fits {
        let mut rss = 0.0f64;
        for (&c, &d) in centers.iter().zip(&density) {
            let pred = pdf(*fit, c as f64);
            let resid = d as f64 - pred;
            rss += resid * resid;
        }
        fit.rss = rss;
    }
    FitReport { fits, centers, density }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    #[test]
    fn exponential_data_prefers_exponential() {
        let mut rng = SplitMix64::new(21);
        let t = Tensor::rand_signed_exponential(&[50_000], 2.5, &mut rng);
        let rep = fit_distributions(&t);
        assert_eq!(rep.best().kind, DistKind::Exponential, "fits: {:?}", rep.fits);
        // λ̂ ≈ rate
        assert!((rep.best().p0 - 2.5).abs() < 0.15, "λ̂ = {}", rep.best().p0);
    }

    #[test]
    fn uniform_data_prefers_uniform() {
        let mut rng = SplitMix64::new(22);
        let t = Tensor::rand_uniform(&[50_000], 0.0, 1.0, &mut rng);
        let rep = fit_distributions(&t);
        assert_eq!(rep.best().kind, DistKind::Uniform, "fits: {:?}", rep.fits);
    }

    #[test]
    fn halfnormal_magnitudes_do_not_pick_uniform() {
        // |N(0,1)| — bell magnitudes. Exact winner between Normal and
        // Exponential depends on folding, but Uniform/Pareto must lose.
        let mut rng = SplitMix64::new(23);
        let t = Tensor::rand_normal(&[50_000], 0.0, 1.0, &mut rng);
        let rep = fit_distributions(&t);
        let best = rep.best().kind;
        assert!(
            best == DistKind::Normal || best == DistKind::Exponential,
            "best = {best:?}"
        );
        assert!(rep.rss_of(DistKind::Uniform) > rep.best().rss);
    }

    #[test]
    fn report_has_all_families_and_series() {
        let mut rng = SplitMix64::new(24);
        let t = Tensor::rand_signed_exponential(&[5_000], 1.0, &mut rng);
        let rep = fit_distributions(&t);
        assert_eq!(rep.fits.len(), 4);
        assert_eq!(rep.centers.len(), RSS_BINS);
        assert_eq!(rep.density.len(), RSS_BINS);
        for kind in DistKind::ALL {
            assert!(rep.rss_of(kind).is_finite(), "{kind:?} rss not finite");
            assert_eq!(rep.predicted(kind).len(), RSS_BINS);
        }
    }

    #[test]
    fn density_integrates_to_one() {
        let mut rng = SplitMix64::new(25);
        let t = Tensor::rand_signed_exponential(&[20_000], 4.0, &mut rng);
        let rep = fit_distributions(&t);
        let w = rep.centers[1] - rep.centers[0];
        let mass: f32 = rep.density.iter().map(|&d| d * w).sum();
        assert!((mass - 1.0).abs() < 1e-3, "mass {mass}");
    }
}
