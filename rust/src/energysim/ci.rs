//! The seeded `ci-energy` head-to-head: exp-4-bit vs INT8 joules per
//! request through the *real* serving path (client → priority queue →
//! continuous batcher → counting engine), on the identical arrival
//! schedule.
//!
//! Both runs replay the same Poisson plan (same seed, rate, duration,
//! priority draw) against the same counting-FC backend; only the
//! co-simulated plan differs. Because per-request joules are pure
//! arithmetic over the plan (never timing-dependent), the reported
//! totals are bit-deterministic across runs — exactly what the CI
//! `energy-smoke` job asserts with `jq`.

use super::cosim::{CoSimEngine, CostModel};
use crate::accel::{AccelConfig, EnergyModel};
use crate::coordinator::{
    AdmissionPolicy, BatcherConfig, Coordinator, CoordinatorConfig, Payload,
};
use crate::dataset::ImageDataset;
use crate::dnateq::config::{LayerKind, LayerQuant, QuantConfig, Scheme, TensorQuant};
use crate::loadgen::cli::{counting_engine, CI_ENGINE_SEED};
use crate::loadgen::{ArrivalPattern, Scenario};
use crate::util::Json;
use std::sync::Arc;
use std::time::Duration;

/// Arrival seed of the `ci-energy` scenario (distinct from the loadgen
/// and bench_gate seeds so the three schedules never alias).
pub const CI_ENERGY_SEED: u64 = 0xE6_0C1;

/// Input features of the CI counting layer (a flattened `[3, 32, 32]`
/// image) — mirrors [`counting_engine`].
pub const CI_FC_IN: usize = 3 * 32 * 32;
/// Output features of the CI counting layer.
pub const CI_FC_OUT: usize = 256;

/// The quantization plan describing the CI counting layer under one
/// scheme/bitwidth — the plan the co-simulation prices.
pub fn ci_fc_plan(scheme: Scheme, n_bits: u8) -> QuantConfig {
    let tq = |elems| TensorQuant { alpha: 1.0, beta: 0.0, rmae: 0.02, elems };
    QuantConfig {
        model: format!("ci-fc-{}{n_bits}", scheme.name()),
        thr_w: 0.05,
        layers: vec![LayerQuant {
            name: "fc".into(),
            kind: LayerKind::Fc,
            scheme,
            n_bits,
            base: 1.5,
            weights: tq(CI_FC_IN * CI_FC_OUT),
            acts: tq(CI_FC_IN),
            seeded_by_weights: true,
            rss_w: 0.0,
            rss_a: 0.0,
            converged: true,
        }],
    }
}

/// The exponential-domain plan matching the real 4-bit counting engine.
pub fn exp_plan() -> QuantConfig {
    ci_fc_plan(Scheme::Exp, 4)
}

/// The INT8 baseline plan on the same layer shape.
pub fn int8_plan() -> QuantConfig {
    ci_fc_plan(Scheme::Uniform, 8)
}

/// Outcome of one `ci-energy` run.
#[derive(Clone, Debug)]
pub struct EnergyCase {
    /// Co-simulated plan name (`ci-fc-exp4` / `ci-fc-uniform8`).
    pub plan: String,
    pub offered: usize,
    pub completed: u64,
    pub energy_total_j: f64,
    pub j_per_request: f64,
    pub j_per_output: f64,
    pub energy_shed: u64,
}

impl EnergyCase {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("plan", self.plan.as_str())
            .set("offered", self.offered)
            .set("completed", self.completed)
            .set("energy_total_j", self.energy_total_j)
            .set("j_per_request", self.j_per_request)
            .set("j_per_output", self.j_per_output)
            .set("energy_shed", self.energy_shed);
        j
    }
}

/// The exp-vs-INT8 comparison `repro energy` prints and the bench gate
/// / `energy-smoke` CI job consume.
#[derive(Clone, Debug)]
pub struct CiEnergyReport {
    pub rate_rps: f64,
    pub duration_s: f64,
    pub exp: EnergyCase,
    pub int8: EnergyCase,
}

impl CiEnergyReport {
    /// exp ÷ INT8 joules per request — the paper's Fig. 9 direction
    /// demands ≤ 0.5 on this shape (≈ 66% savings ⇒ ratio ≈ 0.34–0.42
    /// depending on bitwidth).
    pub fn ratio(&self) -> f64 {
        if self.int8.j_per_request > 0.0 {
            self.exp.j_per_request / self.int8.j_per_request
        } else {
            f64::INFINITY
        }
    }

    pub fn to_json(&self) -> Json {
        let mut scenario = Json::obj();
        scenario
            .set("name", "ci-energy")
            .set("seed", CI_ENERGY_SEED)
            .set("rate_rps", self.rate_rps)
            .set("duration_s", self.duration_s);
        let mut j = Json::obj();
        j.set("scenario", scenario)
            .set("exp", self.exp.to_json())
            .set("int8", self.int8.to_json())
            .set("ratio_j_per_request", self.ratio());
        j
    }

    pub fn summary(&self) -> String {
        format!(
            "ci-energy: exp {:.4e} J/req vs int8 {:.4e} J/req (ratio {:.3}) over {} requests",
            self.exp.j_per_request,
            self.int8.j_per_request,
            self.ratio(),
            self.exp.offered,
        )
    }
}

fn run_case(plan: &QuantConfig, rate_rps: f64, duration_s: f64) -> EnergyCase {
    let em = EnergyModel::default();
    let accel = AccelConfig::default();
    let cost = CostModel::from_config(plan, &em, &accel);
    let engine = Arc::new(CoSimEngine::new(counting_engine(CI_ENGINE_SEED), cost));
    let coordinator = Coordinator::start(
        engine,
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            min_workers: 2,
            max_workers: 2,
            queue_depth: 4096,
            admission: AdmissionPolicy::Block,
            power_envelope_watts: None,
        },
    );
    let scenario = Scenario {
        name: "ci-energy".into(),
        pattern: ArrivalPattern::Poisson,
        rate_rps,
        duration_s,
        seed: CI_ENERGY_SEED,
        priority_mix: [1.0, 2.0, 1.0],
        deadline: None,
    };
    let data = ImageDataset::synthetic(32, 0xC1DA7A);
    let payloads: Vec<Payload> = (0..data.len()).map(|i| Payload::Image(data.image(i))).collect();
    let report = scenario.run(&coordinator.client(), &payloads);
    let snap = coordinator.shutdown_and_drain();
    EnergyCase {
        plan: plan.model.clone(),
        offered: report.offered,
        completed: snap.completed,
        energy_total_j: snap.energy_total_j,
        j_per_request: snap.energy_j_per_request,
        j_per_output: snap.energy_j_per_output,
        energy_shed: snap.energy_shed,
    }
}

/// Run the seeded head-to-head at the given offered load. Blocking
/// admission and no deadline mean every offered request completes, so
/// the joule totals depend only on the (seeded) arrival count and the
/// plans — not on machine speed.
pub fn run_ci_energy(rate_rps: f64, duration_s: f64) -> CiEnergyReport {
    let exp = run_case(&exp_plan(), rate_rps, duration_s);
    let int8 = run_case(&int8_plan(), rate_rps, duration_s);
    CiEnergyReport { rate_rps, duration_s, exp, int8 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ci_plans_price_in_the_papers_direction() {
        let em = EnergyModel::default();
        let accel = AccelConfig::default();
        let exp = CostModel::from_config(&exp_plan(), &em, &accel);
        let int8 = CostModel::from_config(&int8_plan(), &em, &accel);
        let ratio = exp.joules_per_item() / int8.joules_per_item();
        assert!(ratio <= 0.5, "exp/int8 per-item ratio {ratio}");
        // The INT8 anchor is exact: 3072·256 elements × 0.80 pJ.
        let want = (CI_FC_IN * CI_FC_OUT) as f64 * 0.80e-12;
        assert!((int8.joules_per_item() - want).abs() < 1e-9 * want);
    }

    #[test]
    fn report_json_has_the_gate_keys() {
        let report = CiEnergyReport {
            rate_rps: 100.0,
            duration_s: 1.0,
            exp: EnergyCase {
                plan: "ci-fc-exp4".into(),
                offered: 10,
                completed: 10,
                energy_total_j: 2.0e-6,
                j_per_request: 2.0e-7,
                j_per_output: 2.0e-7,
                energy_shed: 0,
            },
            int8: EnergyCase {
                plan: "ci-fc-uniform8".into(),
                offered: 10,
                completed: 10,
                energy_total_j: 6.0e-6,
                j_per_request: 6.0e-7,
                j_per_output: 6.0e-7,
                energy_shed: 0,
            },
        };
        assert!((report.ratio() - 1.0 / 3.0).abs() < 1e-12);
        let j = report.to_json();
        assert!(j.req("ratio_j_per_request").unwrap().as_f64().unwrap() < 0.5);
        assert!(j.req("exp").unwrap().req("energy_total_j").is_ok());
        assert!(j.req("int8").unwrap().req("j_per_request").is_ok());
        assert_eq!(
            j.req("scenario").unwrap().req("seed").unwrap().as_usize().unwrap() as u64,
            CI_ENERGY_SEED
        );
        assert!(report.summary().contains("ratio"));
    }
}
