//! Rolling-power estimation for energy-budget admission.
//!
//! Per-request joules are deterministic arithmetic (see
//! [`super::cosim`]); *power* is the one place wall-clock enters: a
//! [`PowerMeter`] holds the joules recorded over a sliding window and
//! reports their average watts. The `EnergyBudget` admission policy
//! compares that estimate against the configured envelope and sheds
//! lowest-priority submissions while the window runs hot — power only
//! gates admission, never the energy totals the CI gate pins.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Window the serving metrics average simulated power over. Long enough
/// to smooth per-batch quantization at CI rates (~tens of requests per
/// window), short enough that an idle envelope recovers quickly.
pub const DEFAULT_POWER_WINDOW: Duration = Duration::from_millis(250);

/// Sliding-window joules → watts estimator plus a cumulative total.
#[derive(Debug)]
pub struct PowerMeter {
    window: Duration,
    samples: VecDeque<(Instant, f64)>,
    total_j: f64,
}

impl Default for PowerMeter {
    fn default() -> Self {
        Self::new(DEFAULT_POWER_WINDOW)
    }
}

impl PowerMeter {
    pub fn new(window: Duration) -> Self {
        assert!(window > Duration::ZERO, "power window must be positive");
        Self { window, samples: VecDeque::new(), total_j: 0.0 }
    }

    /// Record `joules` of simulated energy spent now. Non-finite or
    /// non-positive samples are ignored (they could only poison the
    /// watts estimate and the cumulative total).
    pub fn record(&mut self, joules: f64) {
        self.record_at(Instant::now(), joules);
    }

    /// [`Self::record`] at an explicit instant (tests).
    pub fn record_at(&mut self, now: Instant, joules: f64) {
        if !joules.is_finite() || joules <= 0.0 {
            return;
        }
        self.total_j += joules;
        self.samples.push_back((now, joules));
        self.prune(now);
    }

    /// Average simulated power over the window ending now.
    pub fn watts(&mut self) -> f64 {
        self.watts_at(Instant::now())
    }

    /// [`Self::watts`] at an explicit instant (tests).
    pub fn watts_at(&mut self, now: Instant) -> f64 {
        self.prune(now);
        let in_window: f64 = self.samples.iter().map(|&(_, j)| j).sum();
        in_window / self.window.as_secs_f64()
    }

    /// Cumulative joules ever recorded (never decays with the window).
    pub fn total_j(&self) -> f64 {
        self.total_j
    }

    pub fn window(&self) -> Duration {
        self.window
    }

    fn prune(&mut self, now: Instant) {
        while let Some(&(t, _)) = self.samples.front() {
            // `duration_since` saturates to zero for samples "in the
            // future" (recorded between our `now` and theirs).
            if now.duration_since(t) > self.window {
                self.samples.pop_front();
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watts_is_window_energy_over_window_seconds() {
        let t0 = Instant::now();
        let mut m = PowerMeter::new(Duration::from_millis(100));
        m.record_at(t0, 0.5);
        m.record_at(t0 + Duration::from_millis(50), 0.5);
        // 1 J inside a 0.1 s window → 10 W.
        assert!((m.watts_at(t0 + Duration::from_millis(50)) - 10.0).abs() < 1e-9);
        // 140 ms in, the first sample has aged out: 0.5 J → 5 W.
        assert!((m.watts_at(t0 + Duration::from_millis(140)) - 5.0).abs() < 1e-9);
        // Far in the future the window is empty but the total persists.
        assert_eq!(m.watts_at(t0 + Duration::from_secs(10)), 0.0);
        assert!((m.total_j() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_samples_are_ignored() {
        let t0 = Instant::now();
        let mut m = PowerMeter::default();
        m.record_at(t0, 0.0);
        m.record_at(t0, -1.0);
        m.record_at(t0, f64::NAN);
        m.record_at(t0, f64::INFINITY);
        assert_eq!(m.total_j(), 0.0);
        assert_eq!(m.watts_at(t0), 0.0);
        m.record_at(t0, 2.5e-7);
        assert_eq!(m.total_j(), 2.5e-7);
        assert!(m.watts_at(t0) > 0.0);
    }
}
