//! Energy co-simulation (L3.75): the accelerator simulator as a
//! first-class serving concern.
//!
//! The paper's headline hardware claim — ~66% energy savings from
//! dot-products in the exponential domain (Figs. 9–10) — lives in the
//! offline [`crate::accel`] reproduction. This subsystem wires that
//! model into the serving loop so energy becomes a *measured,
//! per-request* property of a running coordinator:
//!
//! * [`CostModel`] — folds a quantization plan ([`crate::dnateq::config::QuantConfig`])
//!   through the per-scheme [`crate::accel::EnergyModel`] and replays
//!   every layer through [`crate::accel::simulate_layer`], yielding a
//!   per-inference joule cost plus a per-layer breakdown. The headline
//!   joules are *identical by construction* to the offline
//!   [`crate::accel::EnergyModel::config_energy_j`] score (both go
//!   through [`crate::accel::PJ_TO_J`]), so the planner's Pareto front
//!   and the serving-time accounting can never drift apart.
//! * [`CoSimEngine`] — an [`crate::coordinator::Engine`] decorator: the
//!   inner engine serves the batch, the decorator co-simulates the same
//!   workload and reports one [`EnergyReport`] per request. The
//!   coordinator threads the joules into [`crate::coordinator::Metrics`]
//!   (joules/request, joules/output, rolling watts) and into each
//!   [`crate::coordinator::Response`].
//! * [`PowerMeter`] — the rolling-window joules→watts estimator behind
//!   the `EnergyBudget` admission policy
//!   (`--admission energy-budget --power-envelope-watts W`): when the
//!   simulated rolling power exceeds the envelope, new lowest-priority
//!   submissions are shed (counted as `energy_shed`) until the window
//!   cools down. Higher classes are never energy-shed and the drain
//!   path is unaffected.
//! * [`ci`] — the seeded `ci-energy` head-to-head (exp-4-bit vs INT8 on
//!   the identical arrival schedule) behind `repro energy` and the
//!   bench-gate energy floor.

pub mod budget;
pub mod ci;
pub mod cosim;

pub use budget::{PowerMeter, DEFAULT_POWER_WINDOW};
pub use ci::{run_ci_energy, CiEnergyReport, EnergyCase, CI_ENERGY_SEED};
pub use cosim::{CoSimEngine, CostModel, EnergyReport, LayerEnergy};
