//! Plan → joules: the per-request energy cost model and the
//! [`CoSimEngine`] decorator that attaches it to any serving engine.
//!
//! The model is deliberately *per-item arithmetic*: one inference costs
//! the same joules regardless of how the batcher grouped it or how long
//! it waited in queue. That makes per-request energy — and therefore
//! the `ci-energy` totals the CI gate pins — bit-deterministic across
//! runs, while timing-dependent quantities (rolling watts) are derived
//! separately by the [`super::PowerMeter`].

use crate::accel::{
    simulate_layer, AccelConfig, EnergyModel, LayerShape, Scheme as AccelScheme, PJ_TO_J,
};
use crate::coordinator::{Capabilities, Engine, InferError, Output, Payload};
use crate::dnateq::config::{QuantConfig, Scheme as PlanScheme};
use std::sync::Arc;

/// Energy accounting for one layer of the active plan.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerEnergy {
    pub name: String,
    /// Planner scheme name (`exp` / `uniform` / `pwlN`).
    pub scheme: String,
    pub n_bits: u8,
    /// Headline compute joules per inference — weight elements ×
    /// [`EnergyModel::plan_element_pj`] × [`PJ_TO_J`], the same product
    /// [`EnergyModel::config_energy_j`] sums offline.
    pub joules: f64,
    /// Full accelerator-sim energy (DRAM + NoC + SRAM + compute + post
    /// + quantizer + leakage) for the layer replayed through
    /// [`simulate_layer`], in pJ.
    pub sim_total_pj: f64,
    /// Simulated layer latency in accelerator cycles.
    pub sim_cycles: u64,
}

/// Per-request energy report attached to responses and metrics.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    /// Simulated joules to serve this request.
    pub joules: f64,
    /// Joules per model output element (the plan-derived estimate; the
    /// metrics layer divides by *actual* output units — tokens for
    /// sequence outputs, 1 for a class id).
    pub joules_per_output: f64,
    /// Per-layer breakdown, plan order.
    pub breakdown_by_layer: Vec<LayerEnergy>,
}

/// The per-inference energy cost of one quantization plan on the
/// simulated accelerator.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Model name of the plan this was built from.
    pub model: String,
    /// Per-layer accounting, plan order.
    pub layers: Vec<LayerEnergy>,
    joules_per_item: f64,
    out_units: f64,
}

/// Map a planner scheme onto the accelerator's two hardware pipelines:
/// uniform grids run on the INT8 MAC datapath, exponential and PWL
/// codes on the Counter-Set datapath.
fn accel_scheme(scheme: PlanScheme) -> AccelScheme {
    match scheme {
        PlanScheme::Uniform => AccelScheme::Int8,
        PlanScheme::Exp | PlanScheme::Pwl { .. } => AccelScheme::DnaTeq,
    }
}

impl CostModel {
    /// Build the cost model for `cfg`: headline joules from the same
    /// `plan_element_pj` accounting the planner uses, plus a replay of
    /// every layer through the cycle-level simulator for the extended
    /// breakdown. Layer shapes are reconstructed from the plan's tensor
    /// statistics — `acts.elems` inputs against `weights.elems` weight
    /// elements, FC-style reuse (one MAC per weight element).
    pub fn from_config(cfg: &QuantConfig, em: &EnergyModel, accel: &AccelConfig) -> Self {
        let mut layers = Vec::with_capacity(cfg.layers.len());
        let mut out_units = 1.0;
        for l in &cfg.layers {
            let joules =
                l.weights.elems as f64 * em.plan_element_pj(l.scheme, l.n_bits) * PJ_TO_J;
            let w_elems = l.weights.elems as u64;
            let in_elems = (l.acts.elems as u64).max(1);
            let out_elems = (w_elems / in_elems).max(1);
            let shape = LayerShape {
                name: l.name.clone(),
                macs: w_elems,
                w_elems,
                in_elems,
                out_elems,
            };
            let hw = accel_scheme(l.scheme);
            let n_bits = if hw == AccelScheme::Int8 { 8 } else { l.n_bits };
            let sim = simulate_layer(accel, em, hw, &shape, n_bits);
            layers.push(LayerEnergy {
                name: l.name.clone(),
                scheme: l.scheme.name(),
                n_bits: l.n_bits,
                joules,
                sim_total_pj: sim.total_pj(),
                sim_cycles: sim.total_cycles,
            });
            out_units = out_elems as f64;
        }
        // The headline total goes through `config_energy_j` itself —
        // not a re-summation — so the serving-time accounting is equal
        // to the offline planner score to the last bit (unit-drift
        // audit: both share PJ_TO_J and the same per-element products).
        Self { model: cfg.model.clone(), layers, joules_per_item: em.config_energy_j(cfg), out_units }
    }

    /// Simulated joules for one inference.
    pub fn joules_per_item(&self) -> f64 {
        self.joules_per_item
    }

    /// Simulated accelerator cycles for one inference (all layers).
    pub fn cycles_per_item(&self) -> u64 {
        self.layers.iter().map(|l| l.sim_cycles).sum()
    }

    /// The per-request report this model produces.
    pub fn report(&self) -> EnergyReport {
        EnergyReport {
            joules: self.joules_per_item,
            joules_per_output: self.joules_per_item / self.out_units.max(1.0),
            breakdown_by_layer: self.layers.clone(),
        }
    }
}

/// Engine decorator: the inner engine serves every batch unchanged
/// while the decorator co-simulates the same workload through the
/// accelerator model and reports per-request [`EnergyReport`]s via
/// [`Engine::cosim_energy`]. Wraps an `Arc` so shared backends (the
/// counting engine, registry entries) decorate without re-construction.
pub struct CoSimEngine<E: Engine + ?Sized> {
    inner: Arc<E>,
    cost: CostModel,
    name: String,
}

impl<E: Engine + ?Sized> CoSimEngine<E> {
    pub fn new(inner: Arc<E>, cost: CostModel) -> Self {
        let name = format!("{}+cosim[{}]", inner.name(), cost.model);
        Self { inner, cost, name }
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }
}

impl<E: Engine + ?Sized> Engine for CoSimEngine<E> {
    fn infer_batch(&self, batch: &[Payload]) -> Vec<Result<Output, InferError>> {
        self.inner.infer_batch(batch)
    }

    fn capabilities(&self) -> Capabilities {
        self.inner.capabilities()
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn cosim_energy(&self, batch: &[Payload]) -> Option<Vec<EnergyReport>> {
        Some(batch.iter().map(|_| self.cost.report()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EchoEngine;
    use crate::dnateq::config::{LayerKind, LayerQuant, TensorQuant};

    fn mk_cfg(scheme: PlanScheme, n_bits: u8, in_elems: usize, out_elems: usize) -> QuantConfig {
        let tq = |elems| TensorQuant { alpha: 1.0, beta: 0.0, rmae: 0.01, elems };
        QuantConfig {
            model: format!("m-{}{n_bits}", scheme.name()),
            thr_w: 0.05,
            layers: vec![LayerQuant {
                name: "fc".into(),
                kind: LayerKind::Fc,
                scheme,
                n_bits,
                base: 1.5,
                weights: tq(in_elems * out_elems),
                acts: tq(in_elems),
                seeded_by_weights: true,
                rss_w: 0.0,
                rss_a: 0.0,
                converged: true,
            }],
        }
    }

    #[test]
    fn headline_joules_equal_offline_config_energy_exactly() {
        let em = EnergyModel::default();
        let accel = AccelConfig::default();
        for cfg in [
            mk_cfg(PlanScheme::Exp, 4, 128, 32),
            mk_cfg(PlanScheme::Uniform, 8, 128, 32),
            mk_cfg(PlanScheme::Pwl { breaks: 1 }, 5, 64, 16),
        ] {
            let cost = CostModel::from_config(&cfg, &em, &accel);
            // Bit-exact, not approximate: both sides are the same code path.
            assert_eq!(cost.joules_per_item(), em.config_energy_j(&cfg), "{}", cfg.model);
            assert!(cost.joules_per_item() > 0.0);
        }
    }

    #[test]
    fn breakdown_replays_the_layer_through_the_simulator() {
        let em = EnergyModel::default();
        let accel = AccelConfig::default();
        let cost = CostModel::from_config(&mk_cfg(PlanScheme::Exp, 4, 128, 32), &em, &accel);
        assert_eq!(cost.layers.len(), 1);
        let l = &cost.layers[0];
        assert_eq!(l.scheme, "exp");
        assert!(l.sim_total_pj > 0.0, "simulator energy missing");
        assert!(l.sim_cycles > 0, "simulator timing missing");
        assert!(cost.cycles_per_item() == l.sim_cycles);
        // The full-sim energy covers memory + leakage on top of the
        // compute-only headline joules.
        assert!(l.sim_total_pj * PJ_TO_J > l.joules);
    }

    #[test]
    fn report_divides_by_model_output_width() {
        let em = EnergyModel::default();
        let accel = AccelConfig::default();
        let cost = CostModel::from_config(&mk_cfg(PlanScheme::Exp, 4, 128, 32), &em, &accel);
        let r = cost.report();
        assert_eq!(r.joules, cost.joules_per_item());
        assert!((r.joules_per_output - r.joules / 32.0).abs() < 1e-24);
        assert_eq!(r.breakdown_by_layer.len(), 1);
    }

    #[test]
    fn cosim_engine_delegates_and_reports_per_item() {
        let em = EnergyModel::default();
        let accel = AccelConfig::default();
        let cost = CostModel::from_config(&mk_cfg(PlanScheme::Exp, 4, 128, 32), &em, &accel);
        let per_item = cost.joules_per_item();
        let engine = CoSimEngine::new(Arc::new(EchoEngine { delay_us: 0 }), cost);
        assert!(engine.name().contains("echo") && engine.name().contains("cosim"));
        let batch = [Payload::Seq(vec![1, 2]), Payload::Seq(vec![3])];
        let results = engine.infer_batch(&batch);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0], Ok(Output::Tokens(vec![1, 2])));
        let reports = engine.cosim_energy(&batch).expect("decorator must report energy");
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].joules, per_item);
        assert_eq!(reports[1].joules, per_item);
        // A bare engine reports nothing.
        assert!(EchoEngine { delay_us: 0 }.cosim_energy(&batch).is_none());
    }

    #[test]
    fn exp_plans_undercut_int8_on_the_same_shape() {
        let em = EnergyModel::default();
        let accel = AccelConfig::default();
        let exp = CostModel::from_config(&mk_cfg(PlanScheme::Exp, 4, 3072, 256), &em, &accel);
        let int8 = CostModel::from_config(&mk_cfg(PlanScheme::Uniform, 8, 3072, 256), &em, &accel);
        let ratio = exp.joules_per_item() / int8.joules_per_item();
        assert!(ratio <= 0.5, "exp/int8 joules ratio {ratio} exceeds the paper's direction");
    }
}
