//! Cycle-level model of the DNA-TEQ accelerator vs. the INT8 baseline
//! (§V hardware, §VI-A methodology, Figs. 8–10 + §VI-D overheads).
//!
//! Both designs share the 3D-stacked organization (4 GB, 4×4 vaults and
//! PEs, 10 GB/s/vault, 300 MHz logic die; [`config`]). The baseline's
//! PEs hold 16 INT8 MAC units; DNA-TEQ's hold 16 Counter-Sets plus the
//! runtime exponential Quantizer and two FP16 Dequantizers ([`pe`]).
//! Timing comes from a bandwidth/latency vault + mesh model ([`memory`]);
//! energy/area from published per-event constants calibrated to the
//! paper's own reported totals ([`energy`] — the Synopsys/CACTI/DRAMSim3
//! substitution is documented in DESIGN.md).

pub mod config;
pub mod energy;
pub mod memory;
pub mod pe;
pub mod sim;
pub mod workload;

pub use config::{AccelConfig, Scheme};
pub use energy::{AreaModel, EnergyModel, PJ_TO_J};
pub use memory::MemoryModel;
pub use sim::{geomean, simulate_layer, simulate_network, Comparison, LayerSim, NetworkSim};
pub use workload::{
    alexnet_shapes, assign_bits, resnet50_shapes, transformer_shapes, uniform_bits, LayerShape,
};
