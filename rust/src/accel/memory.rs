//! Vault memory + mesh NoC timing model.
//!
//! Weights/activations stream from the 3D-stacked vaults through the
//! 2-D-mesh routers into PE buffers. The model is bandwidth-centric
//! (the regime these accelerators operate in) with burst granularity,
//! per-transfer latency, and NoC hop accounting for the energy model.

use super::config::AccelConfig;

/// DRAM burst granularity (bytes) — transfers round up.
pub const BURST_BYTES: u64 = 32;
/// Fixed vault access latency per independent transfer (cycles at the
/// logic-die clock): tRCD+CAS through the TSVs + FIFO synchronization.
pub const VAULT_LATENCY_CYCLES: u64 = 24;

/// A modeled transfer.
#[derive(Clone, Copy, Debug, Default)]
pub struct Transfer {
    pub bytes: u64,
    /// Cycles until the last byte arrives (bandwidth + latency).
    pub cycles: u64,
    /// Total NoC byte-hops (for energy accounting).
    pub byte_hops: f64,
}

/// Memory-system timing model.
#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    pub cfg: AccelConfig,
}

impl MemoryModel {
    pub fn new(cfg: AccelConfig) -> Self {
        Self { cfg }
    }

    /// Stream `bytes` spread across all vaults (weights/activations are
    /// interleaved vault-round-robin, the Neurocube layout).
    pub fn stream(&self, bytes: u64) -> Transfer {
        if bytes == 0 {
            return Transfer::default();
        }
        let bursts = bytes.div_ceil(BURST_BYTES);
        let padded = bursts * BURST_BYTES;
        let seconds = padded as f64 / self.cfg.effective_bw();
        let bw_cycles = (seconds * self.cfg.freq_hz).ceil() as u64;
        Transfer {
            bytes: padded,
            cycles: bw_cycles + VAULT_LATENCY_CYCLES + self.cfg.hop_cycles * 2,
            byte_hops: padded as f64 * self.cfg.avg_mesh_hops(),
        }
    }

    /// Cycles to broadcast `bytes` from one tile to all PEs (activation
    /// broadcast): bounded by the mesh bisection, modeled as a pipelined
    /// multicast tree of depth `2·(dim−1)`.
    pub fn broadcast_cycles(&self, bytes: u64) -> u64 {
        let depth = 2 * (self.cfg.mesh_dim as u64 - 1);
        // One flit (burst) per cycle per link once the pipeline fills.
        bytes.div_ceil(BURST_BYTES) + depth * self.cfg.hop_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_is_free() {
        let m = MemoryModel::new(AccelConfig::default());
        assert_eq!(m.stream(0).cycles, 0);
    }

    #[test]
    fn bursts_round_up() {
        let m = MemoryModel::new(AccelConfig::default());
        let t = m.stream(1);
        assert_eq!(t.bytes, BURST_BYTES);
    }

    #[test]
    fn bandwidth_dominates_large_transfers() {
        let m = MemoryModel::new(AccelConfig::default());
        let small = m.stream(1024);
        let big = m.stream(16 * 1024 * 1024);
        // 16 MB at 56 GB/s effective and 300 MHz ≈ 86k cycles.
        assert!(big.cycles > 70_000 && big.cycles < 110_000, "{}", big.cycles);
        assert!(big.cycles > small.cycles * 100);
    }

    #[test]
    fn halving_bytes_roughly_halves_cycles() {
        // The core mechanism behind DNA-TEQ's speedup: fewer weight bytes.
        let m = MemoryModel::new(AccelConfig::default());
        let full = m.stream(8 * 1024 * 1024).cycles as f64;
        let half = m.stream(4 * 1024 * 1024).cycles as f64;
        let ratio = full / half;
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn broadcast_scales_with_bytes() {
        let m = MemoryModel::new(AccelConfig::default());
        assert!(m.broadcast_cycles(4096) > m.broadcast_cycles(64));
    }
}
