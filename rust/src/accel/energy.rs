//! Energy & area model (§VI-A methodology, §VI-D overheads, Fig. 10).
//!
//! The paper characterizes logic with Synopsys DC (28/32 nm), SRAM with
//! CACTI-P (0.78 V low-power) and DRAM with DRAMSim3. None of those run
//! here, so this module substitutes *published per-event energies* at a
//! matching node (Horowitz ISSCC'14 logic numbers, CACTI-class SRAM
//! access energies, HMC-class 3D-DRAM pJ/bit) and the paper's own
//! reported area totals. Figures 8–10 are relative metrics; the
//! substitution preserves their shape (DESIGN.md §Substitutions).

use super::config::Scheme;
use crate::dnateq::config::{QuantConfig, Scheme as PlanScheme};

/// Taps per output neuron assumed when amortizing the exponential
/// scheme's per-neuron post-processing (§VI-D) into a per-element cost.
/// 256 is a mid-size convolution window (3×3×~28 channels); the planner
/// only needs relative per-scheme ordering, which is stable across the
/// plausible 64–1024 range.
const NOMINAL_TAPS: f64 = 256.0;

/// The one pJ→J conversion factor. Every path that turns per-event
/// picojoules into joules — [`EnergyModel::config_energy_j`] offline,
/// the per-request co-simulation in [`crate::energysim`] online — must
/// go through this constant so the two accountings can never drift.
pub const PJ_TO_J: f64 = 1e-12;

/// Per-event energy constants in picojoules.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// INT8 multiply-accumulate incl. operand registers (Fig. 10 "INT8").
    pub mac_int8_pj: f64,
    /// Small adder used to sum exponents in a Counter-Set.
    pub exp_add_pj: f64,
    /// SRAM read-modify-write of one 8-bit counter entry in a bank of
    /// `bank_bytes` (CACTI-class scaling: energy grows ~√size).
    pub counter_rmw_base_pj: f64,
    /// FP16 multiply (Dequantizer BLUT product).
    pub fp16_mul_pj: f64,
    /// FP16 add (accumulation in the Dequantizer).
    pub fp16_add_pj: f64,
    /// 3D-stacked DRAM access per byte, vault-local sequential streaming
    /// (open-row dominated — DRAMSim3-class mix of ACT/PRE and row hits).
    pub dram_pj_per_byte: f64,
    /// NoC energy per byte per hop.
    pub noc_pj_per_byte_hop: f64,
    /// On-chip SRAM buffer access per byte.
    pub sram_pj_per_byte: f64,
    /// Comparator + encoder energy of the runtime Quantizer per
    /// activation (§V-B; 8 comparators + leading-one encode).
    pub quantizer_pj: f64,
    /// Static power of the whole logic die + memory controllers (W).
    /// The 0.78 V low-power corner trades frequency for leakage; static
    /// energy is a first-order term (§VI-C cites its reduction as a main
    /// source of savings).
    pub static_int8_w: f64,
    /// DNA-TEQ static power (smaller logic area — Counter-Sets in place
    /// of MACs — but more SRAM; §VI-D).
    pub static_dnateq_w: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            mac_int8_pj: 0.80,
            exp_add_pj: 0.03,
            counter_rmw_base_pj: 0.055,
            fp16_mul_pj: 0.55,
            fp16_add_pj: 0.20,
            dram_pj_per_byte: 4.0,
            noc_pj_per_byte_hop: 0.65,
            sram_pj_per_byte: 0.08,
            quantizer_pj: 0.30,
            static_int8_w: 0.30,
            static_dnateq_w: 0.22,
        }
    }
}

impl EnergyModel {
    /// Energy of one counting step at exponent bitwidth `n` (Fig. 10):
    /// exponent add + three counter RMWs, with counter-bank energy scaled
    /// by the active bank size (unused banks power-gated, §V-C).
    pub fn counting_step_pj(&self, n_bits: u8) -> f64 {
        // Active bank bytes: AC1 = 4·R_max+1 entries, AC2/AC3 = 2·R_max+1.
        let r_max = ((1u32 << (n_bits - 1)) - 1) as f64;
        let ac1 = 4.0 * r_max + 1.0;
        let ac23 = 2.0 * r_max + 1.0;
        // CACTI-class √size scaling normalized at a 32-byte bank.
        let rmw = |entries: f64| self.counter_rmw_base_pj * (entries / 32.0).sqrt().max(0.5);
        self.exp_add_pj + rmw(ac1) + 2.0 * rmw(ac23)
    }

    /// Post-processing energy per output neuron at bitwidth `n` (§VI-D):
    /// one FP16 multiply+add per *nonzero* count-table entry (zero counts
    /// are skipped — they contribute nothing to Eq. 8), plus the final
    /// coefficient combine. Expected occupancy follows the balls-in-bins
    /// estimate for `taps` contributions into the tables.
    pub fn post_process_pj(&self, n_bits: u8, taps: f64) -> f64 {
        let r_max = ((1u32 << (n_bits - 1)) - 1) as f64;
        let entries = (4.0 * r_max + 1.0) + 2.0 * (2.0 * r_max + 1.0);
        let occupancy = entries * (1.0 - (-taps / entries.max(1.0)).exp());
        occupancy.min(entries) * (self.fp16_mul_pj + self.fp16_add_pj)
            + 4.0 * self.fp16_mul_pj
    }

    /// Static power for a scheme (W).
    pub fn static_w(&self, scheme: Scheme) -> f64 {
        match scheme {
            Scheme::Int8 => self.static_int8_w,
            Scheme::DnaTeq => self.static_dnateq_w,
        }
    }

    /// Energy of one INT-`n` multiply-accumulate. Scaled from the INT8
    /// MAC: the multiplier array shrinks quadratically with operand
    /// width, while operand registers, accumulator and clocking are a
    /// fixed overhead (~35% at 8 bits, Horowitz-style breakdown). The
    /// fixed term keeps narrow uniform MACs *more* expensive than the
    /// counting step at matching width — the paper's motivation for the
    /// exponential scheme at 3–5 bits.
    pub fn uniform_mac_pj(&self, n_bits: u8) -> f64 {
        let w = n_bits as f64 / 8.0;
        self.mac_int8_pj * (0.35 + 0.65 * w * w)
    }

    /// Per-weight-element energy of a planner scheme at bitwidth `n`
    /// (the quantity the Pareto-front search trades against RMAE).
    ///
    /// * `Exp` — one counting step plus the per-neuron post-processing
    ///   of Eq. 8 amortized over [`NOMINAL_TAPS`] contributions. This
    ///   reproduces §VI-D's shape: cheaper than INT8 at 3–5 bits,
    ///   costlier at 7.
    /// * `Uniform` — one INT-`n` MAC.
    /// * `Pwl` — an INT MAC at the level-field width (region bits carry
    ///   no arithmetic) plus a region-select add.
    pub fn plan_element_pj(&self, scheme: PlanScheme, n_bits: u8) -> f64 {
        match scheme {
            PlanScheme::Exp => {
                self.counting_step_pj(n_bits)
                    + self.post_process_pj(n_bits, NOMINAL_TAPS) / NOMINAL_TAPS
            }
            PlanScheme::Uniform => self.uniform_mac_pj(n_bits),
            PlanScheme::Pwl { breaks } => {
                let regions = breaks as u32 + 1;
                let region_bits = (u32::BITS - (regions - 1).leading_zeros()).min(7) as u8;
                let level_bits = n_bits.saturating_sub(region_bits).max(2);
                self.uniform_mac_pj(level_bits) + self.exp_add_pj
            }
        }
    }

    /// Total model compute energy (J) of a quantization plan: every
    /// weight element costs one `plan_element_pj` event per inference.
    /// Absolute joules are nominal; the planner and the front index only
    /// rely on the relative ordering across front points.
    pub fn config_energy_j(&self, cfg: &QuantConfig) -> f64 {
        cfg.layers
            .iter()
            .map(|l| l.weights.elems as f64 * self.plan_element_pj(l.scheme, l.n_bits))
            .sum::<f64>()
            * PJ_TO_J
    }
}

/// Logic-die area accounting (mm², 32 nm) — §VI-D reports these totals;
/// the breakdown allocates them to components.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// 16 MAC-based PEs (baseline total).
    pub baseline_total_mm2: f64,
    /// 16 Counter-Set-based PEs (DNA-TEQ total).
    pub dnateq_total_mm2: f64,
    /// All MAC units across the baseline's PEs.
    pub baseline_macs_mm2: f64,
    /// All Counter-Sets across DNA-TEQ's PEs.
    pub dnateq_cs_mm2: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        Self {
            baseline_total_mm2: 0.78,
            dnateq_total_mm2: 0.59,
            baseline_macs_mm2: 0.67,
            dnateq_cs_mm2: 0.32,
        }
    }
}

impl AreaModel {
    /// Area everything-but-compute (quantizers, dequantizers, control,
    /// buffers) — shared structure between the two designs.
    pub fn shared_mm2(&self) -> f64 {
        self.baseline_total_mm2 - self.baseline_macs_mm2
    }

    /// DNA-TEQ area saving vs the baseline (fraction).
    pub fn saving(&self) -> f64 {
        1.0 - self.dnateq_total_mm2 / self.baseline_total_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnateq::config::{LayerKind, LayerQuant, TensorQuant};

    fn mk_cfg(scheme: PlanScheme, n_bits: u8, elems: usize) -> QuantConfig {
        let tq = |elems| TensorQuant { alpha: 1.0, beta: 0.0, rmae: 0.01, elems };
        QuantConfig {
            model: "m".into(),
            thr_w: 5.0,
            layers: vec![LayerQuant {
                name: "l0".into(),
                kind: LayerKind::Conv,
                scheme,
                n_bits,
                base: 0.0,
                weights: tq(elems),
                acts: tq(elems),
                seeded_by_weights: true,
                rss_w: 0.0,
                rss_a: 0.0,
                converged: true,
            }],
        }
    }

    #[test]
    fn counting_cheaper_than_mac_at_all_bitwidths() {
        // Fig. 10's headline: the counting step undercuts an INT8 MAC
        // regardless of numerical precision.
        let e = EnergyModel::default();
        for n in 3..=7u8 {
            let c = e.counting_step_pj(n);
            assert!(c < e.mac_int8_pj, "n={n}: counting {c} vs MAC {}", e.mac_int8_pj);
        }
    }

    #[test]
    fn counting_energy_grows_with_bitwidth() {
        let e = EnergyModel::default();
        let mut prev = 0.0;
        for n in 3..=7u8 {
            let c = e.counting_step_pj(n);
            assert!(c > prev, "n={n}");
            prev = c;
        }
    }

    #[test]
    fn post_processing_explodes_at_7bit() {
        // §VI-D: 7-bit layers are more energy-costly than INT8 overall —
        // driven by post-processing (hundreds of FP16 ops per neuron).
        let e = EnergyModel::default();
        let taps = 1024.0;
        assert!(e.post_process_pj(3, taps) < e.post_process_pj(7, taps));
        assert!(e.post_process_pj(7, taps) > 5.0 * e.post_process_pj(3, taps));
        // Shallow layers (few taps) touch few nonzero entries.
        assert!(e.post_process_pj(7, 16.0) < e.post_process_pj(7, 4096.0));
    }

    #[test]
    fn area_matches_paper_totals() {
        let a = AreaModel::default();
        assert!((a.saving() - (1.0 - 0.59 / 0.78)).abs() < 1e-12);
        // Shared (non-compute) area must be non-negative and smaller than
        // either total.
        assert!(a.shared_mm2() > 0.0 && a.shared_mm2() < a.dnateq_total_mm2);
    }

    #[test]
    fn dnateq_static_power_below_baseline() {
        let e = EnergyModel::default();
        assert!(e.static_w(Scheme::DnaTeq) < e.static_w(Scheme::Int8));
    }

    #[test]
    fn uniform_mac_energy_is_monotonic_and_anchored_at_int8() {
        let e = EnergyModel::default();
        let mut prev = 0.0;
        for n in 2..=8u8 {
            let c = e.uniform_mac_pj(n);
            assert!(c > prev, "n={n}");
            prev = c;
        }
        assert!((e.uniform_mac_pj(8) - e.mac_int8_pj).abs() < 1e-12);
    }

    #[test]
    fn exp_scheme_cheap_at_narrow_widths_costly_at_seven() {
        // §VI-D in plan-cost form: the exponential pipeline undercuts a
        // same-width uniform MAC at 3–5 bits but overshoots INT8 at 7.
        let e = EnergyModel::default();
        for n in 3..=5u8 {
            let exp = e.plan_element_pj(PlanScheme::Exp, n);
            let uni = e.plan_element_pj(PlanScheme::Uniform, n);
            assert!(exp < uni, "n={n}: exp {exp} vs uniform {uni}");
        }
        assert!(e.plan_element_pj(PlanScheme::Exp, 7) > e.mac_int8_pj);
    }

    #[test]
    fn pwl_undercuts_uniform_at_matching_width() {
        let e = EnergyModel::default();
        for n in 4..=8u8 {
            let pwl = e.plan_element_pj(PlanScheme::Pwl { breaks: 1 }, n);
            let uni = e.plan_element_pj(PlanScheme::Uniform, n);
            assert!(pwl > 0.0 && pwl < uni, "n={n}: pwl {pwl} vs uniform {uni}");
        }
    }

    #[test]
    fn plan_element_totals_pin_the_pj_to_j_conversion() {
        // Hand-computed anchor for the unit-drift audit: a Uniform-8
        // layer costs exactly one INT8 MAC (0.80 pJ) per weight element
        // — `uniform_mac_pj(8) = 0.80·(0.35 + 0.65·1²)` — so 1000
        // elements are exactly 800 pJ, i.e. 8.0e-10 J through PJ_TO_J.
        let e = EnergyModel::default();
        let cfg = mk_cfg(PlanScheme::Uniform, 8, 1_000);
        let total_pj: f64 = cfg
            .layers
            .iter()
            .map(|l| l.weights.elems as f64 * e.plan_element_pj(l.scheme, l.n_bits))
            .sum();
        assert!((total_pj - 800.0).abs() < 1e-9, "got {total_pj} pJ");
        let total_j = e.config_energy_j(&cfg);
        assert!((total_j - 8.0e-10).abs() < 1e-21, "got {total_j} J");
        // And the conversion is exactly the shared constant, not a
        // reimplementation that could drift.
        assert!((total_j - total_pj * PJ_TO_J).abs() < f64::EPSILON * total_j.abs());
    }

    #[test]
    fn config_energy_scales_with_elems_and_orders_by_cost() {
        let e = EnergyModel::default();
        let small = e.config_energy_j(&mk_cfg(PlanScheme::Exp, 4, 1_000));
        let big = e.config_energy_j(&mk_cfg(PlanScheme::Exp, 4, 2_000));
        assert!(small > 0.0);
        assert!((big - 2.0 * small).abs() < 1e-15 * big.max(1.0));
        let cheap = e.config_energy_j(&mk_cfg(PlanScheme::Exp, 3, 1_000));
        let dear = e.config_energy_j(&mk_cfg(PlanScheme::Uniform, 8, 1_000));
        assert!(cheap < dear);
    }
}
