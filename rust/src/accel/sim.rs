//! Layer-by-layer accelerator simulation (Figs. 8 & 9).
//!
//! For each CONV/FC layer: weights stream from the vaults (INT8: 1 B per
//! element; DNA-TEQ: `n+1` bits packed), activations stream FP16 in/out,
//! and the PE pipeline (pre / counting / post) runs overlapped with
//! memory thanks to double-buffering — `total = startup +
//! max(mem, pipeline)`. Energy combines per-event dynamic costs with
//! leakage over the layer's wall time.

use super::config::{AccelConfig, Scheme};
use super::energy::EnergyModel;
use super::memory::MemoryModel;
use super::pe;
use super::workload::LayerShape;

/// Simulation result for one layer.
#[derive(Clone, Debug)]
pub struct LayerSim {
    pub name: String,
    pub scheme: Scheme,
    pub n_bits: u8,
    // --- timing (cycles) ---
    pub mem_cycles: u64,
    pub compute_cycles: u64,
    pub post_cycles: u64,
    pub total_cycles: u64,
    // --- dynamic energy (pJ) ---
    pub e_dram_pj: f64,
    pub e_noc_pj: f64,
    pub e_sram_pj: f64,
    pub e_compute_pj: f64,
    pub e_post_pj: f64,
    pub e_quantizer_pj: f64,
    pub e_static_pj: f64,
}

impl LayerSim {
    pub fn dynamic_pj(&self) -> f64 {
        self.e_dram_pj
            + self.e_noc_pj
            + self.e_sram_pj
            + self.e_compute_pj
            + self.e_post_pj
            + self.e_quantizer_pj
    }

    pub fn total_pj(&self) -> f64 {
        self.dynamic_pj() + self.e_static_pj
    }
}

/// Weight *storage* bytes: INT8 stores 8 bits per element; DNA-TEQ packs
/// `n` exponent bits plus a sign bit.
pub fn weight_bytes(scheme: Scheme, w_elems: u64, n_bits: u8) -> u64 {
    match scheme {
        Scheme::Int8 => w_elems,
        Scheme::DnaTeq => (w_elems * (n_bits as u64 + 1)).div_ceil(8),
    }
}

/// Weight *traffic* bytes for a layer. This accelerator class
/// (Neurocube/Tetris-heritage, §VI-A) is memory-centric: with ~2.5 KB of
/// SRAM per PE there is no on-chip weight reuse across output positions,
/// so every MAC consumes a fresh weight fetch from its vault — traffic is
/// `macs × bits/8`, which reduces to the weight footprint exactly for FC
/// layers (reuse = 1). The paper's compression accounting is `n/8` per
/// element (sign bits ride the spare code space; Table V reduces to
/// `1 − n/8`), so traffic uses `n` bits while storage keeps `n+1`.
pub fn weight_traffic_bytes(scheme: Scheme, macs: u64, n_bits: u8) -> u64 {
    match scheme {
        Scheme::Int8 => macs,
        Scheme::DnaTeq => (macs * n_bits as u64).div_ceil(8),
    }
}

/// Simulate one layer.
pub fn simulate_layer(
    cfg: &AccelConfig,
    em: &EnergyModel,
    scheme: Scheme,
    shape: &LayerShape,
    n_bits: u8,
) -> LayerSim {
    let mem = MemoryModel::new(*cfg);
    let w_bytes = weight_traffic_bytes(scheme, shape.macs, n_bits);
    // Activations move as FP16 in both designs (runtime quantization
    // happens inside the PE, §V-B).
    let act_bytes = 2 * (shape.in_elems + shape.out_elems);
    let t_w = mem.stream(w_bytes);
    let t_a = mem.stream(act_bytes);
    let mem_cycles = t_w.cycles + t_a.cycles;

    let compute =
        pe::compute_cycles(cfg, shape.macs).max(pe::preprocess_cycles(cfg, shape.in_elems));
    let taps = shape.macs / shape.out_elems.max(1);
    let post = pe::postprocess_cycles(cfg, scheme, shape.out_elems, taps, n_bits);
    // Post overlaps counting via spare AC banks except at n=7 (§V-C/D).
    let pipeline = if scheme == Scheme::DnaTeq && !pe::post_overlaps(n_bits) {
        compute + post
    } else {
        compute.max(post)
    };
    let total_cycles = cfg.layer_startup_cycles + mem_cycles.max(pipeline);

    // --- energy ---
    let e_dram = (w_bytes + act_bytes) as f64 * em.dram_pj_per_byte;
    let e_noc = (t_w.byte_hops + t_a.byte_hops) * em.noc_pj_per_byte_hop;
    // Weights read once from PE buffers; activations buffered in and out.
    let e_sram = (w_bytes as f64 + 2.0 * act_bytes as f64) * em.sram_pj_per_byte;
    let (e_compute, e_post, e_quant) = match scheme {
        Scheme::Int8 => (
            shape.macs as f64 * em.mac_int8_pj,
            shape.out_elems as f64 * em.fp16_mul_pj,
            shape.in_elems as f64 * em.quantizer_pj * 0.5, // linear quantizer is simpler
        ),
        Scheme::DnaTeq => {
            let taps = shape.macs as f64 / shape.out_elems.max(1) as f64;
            (
                shape.macs as f64 * em.counting_step_pj(n_bits),
                shape.out_elems as f64 * em.post_process_pj(n_bits, taps),
                shape.in_elems as f64 * em.quantizer_pj,
            )
        }
    };
    let wall_s = total_cycles as f64 / cfg.freq_hz;
    let e_static = em.static_w(scheme) * wall_s * 1e12;

    LayerSim {
        name: shape.name.clone(),
        scheme,
        n_bits,
        mem_cycles,
        compute_cycles: compute,
        post_cycles: post,
        total_cycles,
        e_dram_pj: e_dram,
        e_noc_pj: e_noc,
        e_sram_pj: e_sram,
        e_compute_pj: e_compute,
        e_post_pj: e_post,
        e_quantizer_pj: e_quant,
        e_static_pj: e_static,
    }
}

/// Whole-network simulation result.
#[derive(Clone, Debug)]
pub struct NetworkSim {
    pub scheme: Scheme,
    pub layers: Vec<LayerSim>,
}

impl NetworkSim {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.total_cycles).sum()
    }

    pub fn total_time_s(&self, cfg: &AccelConfig) -> f64 {
        self.total_cycles() as f64 / cfg.freq_hz
    }

    pub fn dynamic_pj(&self) -> f64 {
        self.layers.iter().map(|l| l.dynamic_pj()).sum()
    }

    pub fn static_pj(&self) -> f64 {
        self.layers.iter().map(|l| l.e_static_pj).sum()
    }

    pub fn total_pj(&self) -> f64 {
        self.dynamic_pj() + self.static_pj()
    }
}

/// Simulate a network under one scheme (`bits[i]` pairs with `shapes[i]`;
/// INT8 ignores the bit assignment).
pub fn simulate_network(
    cfg: &AccelConfig,
    em: &EnergyModel,
    scheme: Scheme,
    shapes: &[LayerShape],
    bits: &[u8],
) -> NetworkSim {
    assert_eq!(shapes.len(), bits.len(), "one bitwidth per layer");
    let layers = shapes
        .iter()
        .zip(bits)
        .map(|(s, &n)| {
            simulate_layer(cfg, em, scheme, s, if scheme == Scheme::Int8 { 8 } else { n })
        })
        .collect();
    NetworkSim { scheme, layers }
}

/// Head-to-head comparison (one Fig. 8 bar + one Fig. 9 bar).
#[derive(Clone, Debug)]
pub struct Comparison {
    pub baseline: NetworkSim,
    pub dnateq: NetworkSim,
}

impl Comparison {
    pub fn run(cfg: &AccelConfig, em: &EnergyModel, shapes: &[LayerShape], bits: &[u8]) -> Self {
        Self {
            baseline: simulate_network(cfg, em, Scheme::Int8, shapes, bits),
            dnateq: simulate_network(cfg, em, Scheme::DnaTeq, shapes, bits),
        }
    }

    /// Fig. 8: execution-time speedup of DNA-TEQ over INT8.
    pub fn speedup(&self) -> f64 {
        self.baseline.total_cycles() as f64 / self.dnateq.total_cycles() as f64
    }

    /// Fig. 9: energy-consumption reduction factor.
    pub fn energy_savings(&self) -> f64 {
        self.baseline.total_pj() / self.dnateq.total_pj()
    }
}

/// Geometric mean over per-network factors (the paper's "average").
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::workload::{alexnet_shapes, resnet50_shapes, transformer_shapes, uniform_bits};

    fn setup() -> (AccelConfig, EnergyModel) {
        (AccelConfig::default(), EnergyModel::default())
    }

    #[test]
    fn dnateq_weight_bytes_packed() {
        assert_eq!(weight_bytes(Scheme::Int8, 1000, 3), 1000);
        assert_eq!(weight_bytes(Scheme::DnaTeq, 1000, 3), 500);
        assert_eq!(weight_bytes(Scheme::DnaTeq, 1000, 7), 1000);
    }

    #[test]
    fn fc_layers_speed_up_with_low_bits() {
        // Memory-bound FC layers are where DNA-TEQ's compression pays.
        let (cfg, em) = setup();
        let shapes = vec![LayerShape {
            name: "fc".into(),
            macs: 4096 * 4096,
            w_elems: 4096 * 4096,
            in_elems: 4096,
            out_elems: 4096,
        }];
        let cmp = Comparison::run(&cfg, &em, &shapes, &[3]);
        assert!(cmp.speedup() > 1.2, "speedup {}", cmp.speedup());
    }

    #[test]
    fn seven_bit_layers_can_lose() {
        // §VI-D: 7-bit post-processing can exceed the INT8 baseline cost
        // per layer for shallow (low-reuse) layers.
        let (cfg, em) = setup();
        let shapes = vec![LayerShape {
            name: "shallow".into(),
            macs: 64 * 100_000, // only 64 inputs per neuron
            w_elems: 64 * 100_000,
            in_elems: 64,
            out_elems: 100_000,
        }];
        let cmp = Comparison::run(&cfg, &em, &shapes, &[7]);
        assert!(cmp.speedup() < 1.05, "speedup {}", cmp.speedup());
    }

    #[test]
    fn full_networks_show_paper_shaped_speedups() {
        // Shape check against Fig. 8: every network gains, Transformer
        // (lowest bitwidth, FC-dominated) gains the most.
        let (cfg, em) = setup();
        let al = Comparison::run(&cfg, &em, &alexnet_shapes(), &uniform_bits(&alexnet_shapes(), 6));
        let rn =
            Comparison::run(&cfg, &em, &resnet50_shapes(), &uniform_bits(&resnet50_shapes(), 6));
        let tr = Comparison::run(
            &cfg,
            &em,
            &transformer_shapes(25),
            &uniform_bits(&transformer_shapes(25), 3),
        );
        assert!(al.speedup() >= 1.0, "alexnet {}", al.speedup());
        assert!(rn.speedup() >= 1.0, "resnet {}", rn.speedup());
        assert!(tr.speedup() > rn.speedup(), "tr {} vs rn {}", tr.speedup(), rn.speedup());
    }

    #[test]
    fn energy_savings_exceed_speedup() {
        // Fig. 9 vs Fig. 8: energy gains (2.5×) outpace speedups (1.45×)
        // because counting is much cheaper than MACs even when time ties.
        let (cfg, em) = setup();
        let shapes = resnet50_shapes();
        let cmp = Comparison::run(&cfg, &em, &shapes, &uniform_bits(&shapes, 5));
        assert!(
            cmp.energy_savings() > cmp.speedup(),
            "energy {} vs speedup {}",
            cmp.energy_savings(),
            cmp.speedup()
        );
        assert!(cmp.energy_savings() > 1.3, "energy {}", cmp.energy_savings());
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn network_totals_sum_layers() {
        let (cfg, em) = setup();
        let shapes = alexnet_shapes();
        let sim = simulate_network(&cfg, &em, Scheme::DnaTeq, &shapes, &uniform_bits(&shapes, 4));
        assert_eq!(sim.layers.len(), shapes.len());
        let sum: u64 = sim.layers.iter().map(|l| l.total_cycles).sum();
        assert_eq!(sim.total_cycles(), sum);
        assert!(sim.total_pj() > 0.0);
    }
}
