//! Hardware configuration of the simulated accelerators (§VI-A).
//!
//! Both the DNA-TEQ accelerator and the INT8 baseline share the same
//! 3D-stacked organization (Neurocube/Tetris-class): a logic die with a
//! 4×4 grid of tiles (PE + memory controller + router) under 4 DRAM dies
//! partitioned into vaults.

/// Quantization scheme an accelerator instance runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Uniform INT8 with MAC units (the baseline).
    Int8,
    /// DNA-TEQ with Counter-Set units.
    DnaTeq,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Int8 => "INT8",
            Scheme::DnaTeq => "DNA-TEQ",
        }
    }
}

/// Shared architecture parameters (paper values, §VI-A).
#[derive(Clone, Copy, Debug)]
pub struct AccelConfig {
    /// Tiles/PEs in the logic die (4×4).
    pub n_pes: usize,
    /// MAC or Counter-Set units per PE.
    pub units_per_pe: usize,
    /// De-quantization (FP16 multiplier) units per PE — two in both
    /// accelerators for a fair comparison (§V-D, §VI-D).
    pub dequant_units_per_pe: usize,
    /// Count-table entries a dequant unit drains per cycle: the AC SRAMs
    /// are 16-banked (§V-C), so a unit reads a bank row (8 entries) per
    /// cycle and multiplies the (few) nonzero counts in a short pipeline.
    /// This is what keeps post-processing latency "very small compared
    /// to the counting stage" (§V-D).
    pub dequant_vector_width: usize,
    /// Logic-die clock (Hz).
    pub freq_hz: f64,
    /// Vaults in the 3D stack (4×4).
    pub n_vaults: usize,
    /// Internal bandwidth per vault (bytes/s).
    pub vault_bw: f64,
    /// Achievable fraction of peak DRAM bandwidth. DRAMSim3-class
    /// modeling of the streaming-with-conflicts access mix lands at
    /// ~35% of peak for these dataflows — this is what makes large FC
    /// layers memory-bound on the INT8 baseline (the regime where
    /// DNA-TEQ's weight compression buys wall-clock time).
    pub bw_utilization: f64,
    /// Mesh dimension (4 ⇒ 4×4 grid of tiles).
    pub mesh_dim: usize,
    /// Router latency per hop (cycles).
    pub hop_cycles: u64,
    /// Per-layer control/configuration startup (cycles): loading interval
    /// boundaries, BLUT entries, power-gating reconfiguration.
    pub layer_startup_cycles: u64,
    /// SRAM buffer per PE for inputs/outputs/weights (bytes) — baseline.
    pub sram_per_pe: usize,
    /// Extra SRAM per PE for the Counter-Sets (bytes) — DNA-TEQ only.
    pub extra_sram_dnateq: usize,
}

impl Default for AccelConfig {
    fn default() -> Self {
        Self {
            n_pes: 16,
            units_per_pe: 16,
            dequant_units_per_pe: 2,
            dequant_vector_width: 8,
            freq_hz: 300e6,
            n_vaults: 16,
            vault_bw: 10e9,
            bw_utilization: 0.35,
            mesh_dim: 4,
            hop_cycles: 2,
            layer_startup_cycles: 1024,
            sram_per_pe: 2560,
            extra_sram_dnateq: 6144,
        }
    }
}

impl AccelConfig {
    /// Aggregate effective memory bandwidth (bytes/s).
    pub fn effective_bw(&self) -> f64 {
        self.n_vaults as f64 * self.vault_bw * self.bw_utilization
    }

    /// Total MAC/Counter-Set units across the logic die.
    pub fn total_units(&self) -> usize {
        self.n_pes * self.units_per_pe
    }

    /// Average hop count for vault→PE traffic on the 2-D mesh with XY
    /// routing (uniform traffic): `2·(d−1)/3` per dimension.
    pub fn avg_mesh_hops(&self) -> f64 {
        2.0 * (self.mesh_dim as f64 - 1.0) / 3.0 * 2.0
    }

    /// On-chip SRAM per PE for a scheme.
    pub fn sram_for(&self, scheme: Scheme) -> usize {
        match scheme {
            Scheme::Int8 => self.sram_per_pe,
            Scheme::DnaTeq => self.sram_per_pe + self.extra_sram_dnateq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults() {
        let c = AccelConfig::default();
        assert_eq!(c.n_pes, 16);
        assert_eq!(c.total_units(), 256);
        // 16 vaults × 10 GB/s × 0.35 = 56 GB/s effective.
        assert!((c.effective_bw() - 56e9).abs() < 1e6);
        assert_eq!(c.sram_for(Scheme::DnaTeq) - c.sram_for(Scheme::Int8), 6144);
    }

    #[test]
    fn mesh_hops_reasonable() {
        let c = AccelConfig::default();
        let h = c.avg_mesh_hops();
        assert!(h > 1.0 && h < 6.0, "hops {h}");
    }
}
