//! Accelerator workloads: per-layer shapes of the paper's *full-size*
//! evaluation networks (§VI-A).
//!
//! The accuracy pipeline runs on the mini models (trained weights
//! required), but the accelerator simulation needs only layer *shapes*
//! and per-layer bitwidths — so Figs. 8–10 are regenerated on the real
//! AlexNet / ResNet-50 / Transformer-base geometries, with bitwidths
//! transplanted from the calibrated mini configs by relative layer
//! position (DESIGN.md §Substitutions).

use crate::dnateq::QuantConfig;

/// Shape of one CONV/FC layer as the accelerator sees it.
#[derive(Clone, Debug)]
pub struct LayerShape {
    pub name: String,
    /// Multiply-accumulates (= counting steps) per inference.
    pub macs: u64,
    /// Weight elements.
    pub w_elems: u64,
    /// Input activation elements.
    pub in_elems: u64,
    /// Output activation elements.
    pub out_elems: u64,
}

impl LayerShape {
    fn conv(name: &str, c_in: u64, c_out: u64, k: u64, h_in: u64, stride: u64) -> Self {
        let h_out = h_in / stride;
        Self {
            name: name.into(),
            macs: c_out * c_in * k * k * h_out * h_out,
            w_elems: c_out * c_in * k * k,
            in_elems: c_in * h_in * h_in,
            out_elems: c_out * h_out * h_out,
        }
    }

    fn fc(name: &str, in_f: u64, out_f: u64, rows: u64) -> Self {
        Self {
            name: name.into(),
            macs: in_f * out_f * rows,
            w_elems: in_f * out_f,
            in_elems: in_f * rows,
            out_elems: out_f * rows,
        }
    }

    /// Arithmetic intensity proxy: MACs per weight element (reuse).
    pub fn weight_reuse(&self) -> f64 {
        self.macs as f64 / self.w_elems.max(1) as f64
    }
}

/// AlexNet (one-tower ImageNet variant, Krizhevsky 2014).
pub fn alexnet_shapes() -> Vec<LayerShape> {
    vec![
        LayerShape::conv("conv1", 3, 64, 11, 224, 4),
        LayerShape::conv("conv2", 64, 192, 5, 27, 1),
        LayerShape::conv("conv3", 192, 384, 3, 13, 1),
        LayerShape::conv("conv4", 384, 256, 3, 13, 1),
        LayerShape::conv("conv5", 256, 256, 3, 13, 1),
        LayerShape::fc("fc6", 9216, 4096, 1),
        LayerShape::fc("fc7", 4096, 4096, 1),
        LayerShape::fc("fc8", 4096, 1000, 1),
    ]
}

/// ResNet-50 (ImageNet): bottleneck stages [3,4,6,3].
pub fn resnet50_shapes() -> Vec<LayerShape> {
    let mut v = vec![LayerShape::conv("conv1", 3, 64, 7, 224, 2)];
    let stages: [(u64, u64, u64, u64); 4] = [
        // (blocks, mid_channels, out_channels, spatial)
        (3, 64, 256, 56),
        (4, 128, 512, 28),
        (6, 256, 1024, 14),
        (3, 512, 2048, 7),
    ];
    let mut c_in = 64u64;
    for (s, &(blocks, mid, out, hw)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let h_in = hw * stride;
            let p = format!("s{}b{}", s + 1, b + 1);
            v.push(LayerShape::conv(&format!("{p}c1"), c_in, mid, 1, h_in, stride));
            v.push(LayerShape::conv(&format!("{p}c2"), mid, mid, 3, hw, 1));
            v.push(LayerShape::conv(&format!("{p}c3"), mid, out, 1, hw, 1));
            if b == 0 {
                v.push(LayerShape::conv(&format!("{p}d"), c_in, out, 1, h_in, stride));
            }
            c_in = out;
        }
    }
    v.push(LayerShape::fc("fc", 2048, 1000, 1));
    v
}

/// Transformer base (WMT En–De, Vaswani 2017): 6+6 layers, d=512,
/// d_ff=2048, shared 32k vocab head; `l` tokens per sequence.
pub fn transformer_shapes(l: u64) -> Vec<LayerShape> {
    let d = 512u64;
    let dff = 2048u64;
    let mut v = Vec::new();
    for i in 0..6 {
        for p in ["q", "k", "v", "o"] {
            v.push(LayerShape::fc(&format!("enc{i}.{p}"), d, d, l));
        }
        v.push(LayerShape::fc(&format!("enc{i}.ff1"), d, dff, l));
        v.push(LayerShape::fc(&format!("enc{i}.ff2"), dff, d, l));
    }
    for i in 0..6 {
        for p in ["s.q", "s.k", "s.v", "s.o", "c.q", "c.k", "c.v", "c.o"] {
            v.push(LayerShape::fc(&format!("dec{i}.{p}"), d, d, l));
        }
        v.push(LayerShape::fc(&format!("dec{i}.ff1"), d, dff, l));
        v.push(LayerShape::fc(&format!("dec{i}.ff2"), dff, d, l));
    }
    v.push(LayerShape::fc("out", d, 32_000, l));
    v
}

/// Transplant per-layer bitwidths from a calibrated (mini) config onto a
/// full-size shape list by relative layer position. Falls back to
/// `default_bits` when the config is empty.
pub fn assign_bits(shapes: &[LayerShape], cfg: &QuantConfig, default_bits: u8) -> Vec<u8> {
    if cfg.layers.is_empty() {
        return vec![default_bits; shapes.len()];
    }
    (0..shapes.len())
        .map(|i| {
            let j = i * cfg.layers.len() / shapes.len();
            cfg.layers[j.min(cfg.layers.len() - 1)].n_bits
        })
        .collect()
}

/// Uniform bit assignment helper.
pub fn uniform_bits(shapes: &[LayerShape], bits: u8) -> Vec<u8> {
    vec![bits; shapes.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_macs_in_published_range() {
        // One-tower AlexNet ≈ 0.7–1.2 GMACs.
        let total: u64 = alexnet_shapes().iter().map(|l| l.macs).sum();
        assert!((600e6..1.3e9).contains(&(total as f64)), "total {total}");
        // FC6 is the famous 38M-weight layer.
        let fc6 = &alexnet_shapes()[5];
        assert_eq!(fc6.w_elems, 9216 * 4096);
    }

    #[test]
    fn resnet50_macs_and_params_in_published_range() {
        let shapes = resnet50_shapes();
        let macs: u64 = shapes.iter().map(|l| l.macs).sum();
        let params: u64 = shapes.iter().map(|l| l.w_elems).sum();
        assert!((3.2e9..4.6e9).contains(&(macs as f64)), "macs {macs}");
        assert!((20e6..28e6).contains(&(params as f64)), "params {params}");
        // 16 bottleneck blocks → 1 stem + 48 block convs + 4 proj + 1 fc.
        assert_eq!(shapes.len(), 54);
    }

    #[test]
    fn transformer_fc_count_matches_paper_population() {
        // 6·6 + 6·10 + 1 = 97 FC layers ≈ the paper's "96 FC layers"
        // (they exclude the vocabulary head).
        let shapes = transformer_shapes(25);
        assert_eq!(shapes.len(), 97);
    }

    #[test]
    fn fc_layers_have_no_weight_reuse() {
        let shapes = alexnet_shapes();
        assert_eq!(shapes[5].weight_reuse(), 1.0);
        // Conv layers reuse weights across spatial positions.
        assert!(shapes[2].weight_reuse() > 100.0);
    }

    #[test]
    fn assign_bits_transplants_by_position() {
        use crate::dnateq::{LayerKind, LayerQuant, Scheme, TensorQuant};
        let mk = |n: u8| LayerQuant {
            name: format!("l{n}"),
            kind: LayerKind::Fc,
            scheme: Scheme::Exp,
            n_bits: n,
            base: 1.2,
            weights: TensorQuant { alpha: 1.0, beta: 0.0, rmae: 0.0, elems: 1 },
            acts: TensorQuant { alpha: 1.0, beta: 0.0, rmae: 0.0, elems: 1 },
            seeded_by_weights: true,
            rss_w: 0.0,
            rss_a: 0.0,
            converged: true,
        };
        let cfg = QuantConfig { model: "m".into(), thr_w: 0.01, layers: vec![mk(3), mk(7)] };
        let shapes = alexnet_shapes();
        let bits = assign_bits(&shapes, &cfg, 5);
        assert_eq!(bits.len(), 8);
        assert_eq!(bits[0], 3); // first half ← first mini layer
        assert_eq!(bits[7], 7); // second half ← second mini layer
        let empty = QuantConfig { model: "m".into(), thr_w: 0.01, layers: vec![] };
        assert_eq!(assign_bits(&shapes, &empty, 5), vec![5; 8]);
    }
}
