//! PE pipeline timing (§V-B/C/D).
//!
//! Both accelerators process a layer as three stages:
//!
//! * **Pre-processing** — runtime quantization of incoming FP16
//!   activations (8 per cycle per PE, §V-B); runs *concurrently* with the
//!   compute stage, so it only binds when faster than compute.
//! * **Counting / MAC** — one operation per unit per cycle: a Counter-Set
//!   indexes+increments, a MAC multiplies+accumulates.
//! * **Post-processing** — serial per-layer Dequantizer pass (§V-D):
//!   DNA-TEQ multiplies every count-table entry by its BLUT power
//!   (`4·R_max+1` pair entries + 2·(`2·R_max+1`) single entries per
//!   neuron) on 2 FP16 units per PE; INT8 needs one scale multiply per
//!   output.

use super::config::{AccelConfig, Scheme};

/// Activations quantized per cycle per PE (§V-B: batches of eight).
pub const QUANTIZER_THROUGHPUT: u64 = 8;

/// BLUT entries visited per output neuron at bitwidth `n`.
pub fn blut_entries(n_bits: u8) -> u64 {
    let r_max = ((1u64 << (n_bits - 1)) - 1) as u64;
    (4 * r_max + 1) + 2 * (2 * r_max + 1)
}

/// Cycles of the compute (counting/MAC) stage.
pub fn compute_cycles(cfg: &AccelConfig, macs: u64) -> u64 {
    macs.div_ceil(cfg.total_units() as u64)
}

/// Cycles of the concurrent pre-processing stage (DNA-TEQ only; the
/// INT8 baseline's linear quantizer also keeps pace — divide by the same
/// throughput for symmetry).
pub fn preprocess_cycles(cfg: &AccelConfig, in_elems: u64) -> u64 {
    in_elems.div_ceil(QUANTIZER_THROUGHPUT * cfg.n_pes as u64)
}

/// Expected nonzero count-table entries per neuron: `taps` contributions
/// scattered into `blut_entries(n)` bins (balls-in-bins). The Dequantizer
/// skips empty entries — a zero count contributes nothing to Eq. 8.
pub fn occupied_entries(n_bits: u8, taps: u64) -> u64 {
    let entries = blut_entries(n_bits) as f64;
    let occ = entries * (1.0 - (-(taps as f64) / entries).exp());
    occ.ceil().min(entries) as u64
}

/// Cycles of the post-processing stage.
pub fn postprocess_cycles(
    cfg: &AccelConfig,
    scheme: Scheme,
    out_elems: u64,
    taps: u64,
    n_bits: u8,
) -> u64 {
    let units = (cfg.dequant_units_per_pe * cfg.n_pes) as u64;
    match scheme {
        // One dequant multiply per output activation.
        Scheme::Int8 => out_elems.div_ceil(units),
        // Count tables drain at a bank row per unit-cycle (§V-C banking),
        // skipping empty entries.
        Scheme::DnaTeq => (out_elems * occupied_entries(n_bits, taps))
            .div_ceil(units * cfg.dequant_vector_width as u64),
    }
}

/// At `n ≤ 6` the Counter-Set SRAMs have spare banks (they are sized for
/// the 7-bit worst case, §V-C), so the Dequantizer drains one bank set
/// while the next neuron group counts into the other — post-processing
/// overlaps counting. At `n = 7` every bank is live and the stages run
/// serially (§V-D), which is exactly the regime §VI-D flags as costly.
pub fn post_overlaps(n_bits: u8) -> bool {
    n_bits <= 6
}

/// Total pipeline cycles for a layer's compute phase (memory overlap is
/// handled by the caller): counting overlapped with pre-processing, then
/// serial post-processing.
pub fn pipeline_cycles(
    cfg: &AccelConfig,
    scheme: Scheme,
    macs: u64,
    in_elems: u64,
    out_elems: u64,
    n_bits: u8,
) -> u64 {
    let compute = compute_cycles(cfg, macs).max(preprocess_cycles(cfg, in_elems));
    let taps = macs / out_elems.max(1);
    let post = postprocess_cycles(cfg, scheme, out_elems, taps, n_bits);
    if scheme == Scheme::DnaTeq && !post_overlaps(n_bits) {
        compute + post
    } else {
        compute.max(post)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blut_entries_match_hardware_tables() {
        // n=3: R_max=3 → 13 pair + 2·7 = 27 entries.
        assert_eq!(blut_entries(3), 27);
        // n=7: R_max=63 → 253 + 2·127 = 507.
        assert_eq!(blut_entries(7), 507);
    }

    #[test]
    fn compute_stage_is_throughput_bound() {
        let cfg = AccelConfig::default();
        assert_eq!(compute_cycles(&cfg, 256), 1);
        assert_eq!(compute_cycles(&cfg, 257), 2);
    }

    #[test]
    fn preprocessing_hides_behind_compute_for_convs() {
        // Conv layers: many MACs per activation → pre never binds.
        let cfg = AccelConfig::default();
        let macs = 100_000_000;
        let in_elems = 150_528; // 3·224·224
        assert!(preprocess_cycles(&cfg, in_elems) < compute_cycles(&cfg, macs));
    }

    #[test]
    fn int8_postprocessing_negligible() {
        let cfg = AccelConfig::default();
        let p = postprocess_cycles(&cfg, Scheme::Int8, 4096, 4096, 8);
        assert_eq!(p, 128);
    }

    #[test]
    fn dnateq_post_grows_with_bitwidth() {
        let cfg = AccelConfig::default();
        let p3 = postprocess_cycles(&cfg, Scheme::DnaTeq, 4096, 4096, 3);
        let p7 = postprocess_cycles(&cfg, Scheme::DnaTeq, 4096, 4096, 7);
        assert!(p7 > p3 * 10, "p3={p3} p7={p7}");
    }

    #[test]
    fn occupancy_bounded_by_taps_and_entries() {
        assert!(occupied_entries(7, 16) <= 17);
        assert_eq!(occupied_entries(3, 100_000), blut_entries(3));
    }

    #[test]
    fn post_small_vs_counting_for_deep_layers() {
        // §V-D: "its latency is very small compared to the counting
        // stage" — true when inputs-per-neuron ≫ BLUT entries / units.
        let cfg = AccelConfig::default();
        // ResNet conv: 4608 taps per output neuron, 100k outputs.
        let out_elems = 100_352u64;
        let macs = out_elems * 4608;
        let post = postprocess_cycles(&cfg, Scheme::DnaTeq, out_elems, 4608, 5);
        let count = compute_cycles(&cfg, macs);
        assert!(post < count / 3, "post {post} vs count {count}");
    }

    #[test]
    fn seven_bit_serializes_post() {
        assert!(post_overlaps(3) && post_overlaps(6));
        assert!(!post_overlaps(7));
    }
}
