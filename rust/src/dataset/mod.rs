//! Evaluation/calibration workloads (§VI-A, substitutions in DESIGN.md).
//!
//! * [`ImageDataset`] — 32×32×3 10-class images. The python compile path
//!   trains the CNN minis on its synthetic set and dumps calib/eval
//!   splits as `.bt`; [`ImageDataset::synthetic`] generates an equivalent
//!   population in rust for tests and benches.
//! * [`SeqDataset`] — the synthetic reversal-translation task standing in
//!   for WMT En–De: `tgt = BOS ++ cipher(reverse(payload)) ++ EOS`.
//!   The cipher spec is shared verbatim with `python/compile/datagen.py`.

use crate::nn::transformer::{BOS, EOS, PAD, VOCAB};
use crate::tensor::{load_tensor, SplitMix64, Tensor};
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Labeled image set, NCHW.
#[derive(Clone, Debug)]
pub struct ImageDataset {
    /// `[n, 3, 32, 32]`.
    pub images: Tensor,
    pub labels: Vec<usize>,
}

impl ImageDataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Image `i` as a standalone `[3, 32, 32]` tensor.
    pub fn image(&self, i: usize) -> Tensor {
        Tensor::from_vec(&[3, 32, 32], self.images.batch(i).to_vec())
    }

    /// Load `<dir>/<split>_images.bt` + `<dir>/<split>_labels.bt`.
    pub fn load<P: AsRef<Path>>(dir: P, split: &str) -> Result<Self> {
        let dir = dir.as_ref();
        let images = load_tensor(dir.join(format!("{split}_images.bt")))
            .with_context(|| format!("loading {split} images"))?;
        let labels_t = load_tensor(dir.join(format!("{split}_labels.bt")))
            .with_context(|| format!("loading {split} labels"))?;
        ensure!(images.ndim() == 4, "images must be [n,3,32,32]");
        ensure!(images.shape()[0] == labels_t.len(), "image/label count mismatch");
        let labels = labels_t.data().iter().map(|&x| x as usize).collect();
        Ok(Self { images, labels })
    }

    /// Deterministic synthetic population: each class is a distinct
    /// spatial frequency/orientation pattern plus noise — separable by a
    /// small CNN, same footprint as the python training set.
    pub fn synthetic(n: usize, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut data = Vec::with_capacity(n * 3 * 32 * 32);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.next_below(10);
            labels.push(class);
            let fx = 1.0 + (class % 5) as f32;
            let fy = 1.0 + (class / 5) as f32 * 2.0;
            let phase = rng.next_f32() * std::f32::consts::TAU;
            for c in 0..3usize {
                for y in 0..32usize {
                    for x in 0..32usize {
                        let signal = ((x as f32 * fx / 32.0 * std::f32::consts::TAU
                            + y as f32 * fy / 32.0 * std::f32::consts::TAU
                            + phase)
                            .sin())
                            * (1.0 - 0.2 * c as f32);
                        let noise = (rng.next_f32() - 0.5) * 0.6;
                        data.push(signal + noise);
                    }
                }
            }
        }
        Self { images: Tensor::from_vec(&[n, 3, 32, 32], data), labels }
    }

    /// Images `[lo, hi)` as one `[hi-lo, 3, 32, 32]` batch tensor — the
    /// unit the batched evaluation/serving paths forward in one GEMM.
    pub fn batch_tensor(&self, lo: usize, hi: usize) -> Tensor {
        assert!(lo <= hi && hi <= self.len(), "batch range {lo}..{hi} out of bounds");
        let stride = 3 * 32 * 32;
        Tensor::from_vec(
            &[hi - lo, 3, 32, 32],
            self.images.data()[lo * stride..hi * stride].to_vec(),
        )
    }

    /// First `n` samples as a new dataset (calibration subset).
    pub fn take(&self, n: usize) -> Self {
        let n = n.min(self.len());
        let stride = 3 * 32 * 32;
        Self {
            images: Tensor::from_vec(&[n, 3, 32, 32], self.images.data()[..n * stride].to_vec()),
            labels: self.labels[..n].to_vec(),
        }
    }
}

/// The substitution cipher of the synthetic translation task: a bijection
/// over the payload alphabet `[3, VOCAB)`.
pub fn cipher(tok: usize) -> usize {
    debug_assert!((3..VOCAB).contains(&tok));
    let payload = VOCAB - 3; // 29 symbols; 5 is coprime with 29
    3 + ((tok - 3) * 5 + 7) % payload
}

/// Reference translation: reverse the payload and cipher each token.
pub fn translate(src_payload: &[usize]) -> Vec<usize> {
    src_payload.iter().rev().map(|&t| cipher(t)).collect()
}

/// Sequence-to-sequence dataset (token ids, unpadded rows).
#[derive(Clone, Debug)]
pub struct SeqDataset {
    /// Source: `payload ++ [EOS]`.
    pub src: Vec<Vec<usize>>,
    /// Target: `[BOS] ++ translated payload ++ [EOS]`.
    pub tgt: Vec<Vec<usize>>,
}

impl SeqDataset {
    pub fn len(&self) -> usize {
        self.src.len()
    }

    pub fn is_empty(&self) -> bool {
        self.src.is_empty()
    }

    /// Load from `[n, max_len]` PAD-filled `.bt` matrices.
    pub fn load<P: AsRef<Path>>(dir: P, split: &str) -> Result<Self> {
        let dir = dir.as_ref();
        let unpad = |t: &Tensor| -> Vec<Vec<usize>> {
            let (n, l) = (t.shape()[0], t.shape()[1]);
            (0..n)
                .map(|i| {
                    t.data()[i * l..(i + 1) * l]
                        .iter()
                        .map(|&x| x as usize)
                        .take_while(|&x| x != PAD)
                        .collect()
                })
                .collect()
        };
        let src_t = load_tensor(dir.join(format!("{split}_src.bt")))
            .with_context(|| format!("loading {split} src"))?;
        let tgt_t = load_tensor(dir.join(format!("{split}_tgt.bt")))
            .with_context(|| format!("loading {split} tgt"))?;
        ensure!(src_t.ndim() == 2 && tgt_t.ndim() == 2, "seq data must be 2-D");
        ensure!(src_t.shape()[0] == tgt_t.shape()[0], "src/tgt count mismatch");
        Ok(Self { src: unpad(&src_t), tgt: unpad(&tgt_t) })
    }

    /// Deterministic synthetic sample of the reversal-translation task.
    pub fn synthetic(n: usize, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut src = Vec::with_capacity(n);
        let mut tgt = Vec::with_capacity(n);
        for _ in 0..n {
            let len = 4 + rng.next_below(9); // payload length 4..=12
            let payload: Vec<usize> = (0..len).map(|_| 3 + rng.next_below(VOCAB - 3)).collect();
            let mut s = payload.clone();
            s.push(EOS);
            let mut t = vec![BOS];
            t.extend(translate(&payload));
            t.push(EOS);
            src.push(s);
            tgt.push(t);
        }
        Self { src, tgt }
    }

    pub fn take(&self, n: usize) -> Self {
        let n = n.min(self.len());
        Self { src: self.src[..n].to_vec(), tgt: self.tgt[..n].to_vec() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cipher_is_bijective() {
        let mut seen = [false; VOCAB];
        for t in 3..VOCAB {
            let c = cipher(t);
            assert!((3..VOCAB).contains(&c));
            assert!(!seen[c], "cipher collision at {t} -> {c}");
            seen[c] = true;
        }
    }

    #[test]
    fn translate_reverses_and_ciphers() {
        let payload = vec![3, 10, 20];
        let t = translate(&payload);
        assert_eq!(t, vec![cipher(20), cipher(10), cipher(3)]);
    }

    #[test]
    fn synthetic_images_shapes_and_classes() {
        let d = ImageDataset::synthetic(32, 161);
        assert_eq!(d.len(), 32);
        assert_eq!(d.images.shape(), &[32, 3, 32, 32]);
        assert!(d.labels.iter().all(|&l| l < 10));
        assert_eq!(d.image(5).shape(), &[3, 32, 32]);
    }

    #[test]
    fn synthetic_seq_structure() {
        let d = SeqDataset::synthetic(20, 162);
        for (s, t) in d.src.iter().zip(&d.tgt) {
            assert_eq!(*s.last().unwrap(), EOS);
            assert_eq!(t[0], BOS);
            assert_eq!(*t.last().unwrap(), EOS);
            assert_eq!(t.len(), s.len() + 1); // BOS + payload + EOS vs payload + EOS
            let payload = &s[..s.len() - 1];
            assert_eq!(&t[1..t.len() - 1], translate(payload).as_slice());
        }
    }

    #[test]
    fn batch_tensor_slices_images() {
        let d = ImageDataset::synthetic(6, 165);
        let b = d.batch_tensor(2, 5);
        assert_eq!(b.shape(), &[3, 3, 32, 32]);
        for (k, i) in (2..5).enumerate() {
            assert_eq!(b.batch(k), d.image(i).data());
        }
        assert_eq!(d.batch_tensor(3, 3).shape(), &[0, 3, 32, 32]);
    }

    #[test]
    fn take_truncates() {
        let d = ImageDataset::synthetic(10, 163);
        assert_eq!(d.take(4).len(), 4);
        assert_eq!(d.take(100).len(), 10);
        let s = SeqDataset::synthetic(10, 164);
        assert_eq!(s.take(3).len(), 3);
    }

    #[test]
    fn load_roundtrip_via_bt() {
        use crate::tensor::save_tensor;
        let dir = crate::util::TempDir::new().unwrap();
        let d = ImageDataset::synthetic(4, 165);
        save_tensor(dir.path().join("eval_images.bt"), &d.images).unwrap();
        let labels =
            Tensor::from_vec(&[4], d.labels.iter().map(|&l| l as f32).collect());
        save_tensor(dir.path().join("eval_labels.bt"), &labels).unwrap();
        let d2 = ImageDataset::load(dir.path(), "eval").unwrap();
        assert_eq!(d2.len(), 4);
        assert_eq!(d2.labels, d.labels);
    }
}
