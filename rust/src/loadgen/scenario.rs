//! Scenario = arrival process × traffic mix × run length.
//!
//! A [`Scenario`] owns everything about the *offered load*: pattern,
//! rate, duration, seed, priority mix, and optional per-request
//! deadline. It deliberately knows nothing about the serving side (the
//! engine, pool sizing, queue policy live in `CoordinatorConfig`), so
//! one scenario can be replayed against any coordinator. `run` drives
//! the schedule open-loop against an [`InferenceClient`] and returns a
//! [`LoadReport`].

use super::arrival::ArrivalPattern;
use super::recorder::{LoadReport, Recorder};
use crate::coordinator::{
    Deadline, InferenceClient, Payload, Priority, ServeError, SubmitOptions, Ticket,
};
use crate::tensor::SplitMix64;
use crate::util::Json;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One open-loop load scenario, fully determined by its fields (the
/// seed covers both arrival times and the priority draw).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub pattern: ArrivalPattern,
    /// Long-run offered rate, requests per second.
    pub rate_rps: f64,
    pub duration_s: f64,
    pub seed: u64,
    /// Relative weights of High/Normal/Low traffic.
    pub priority_mix: [f64; 3],
    /// Per-request deadline, if the scenario models an SLO per call.
    pub deadline: Option<Duration>,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            name: "poisson".into(),
            pattern: ArrivalPattern::Poisson,
            rate_rps: 200.0,
            duration_s: 2.0,
            seed: 0x10AD_9E4,
            priority_mix: [1.0, 2.0, 1.0],
            deadline: None,
        }
    }
}

/// One planned arrival.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    /// Offset from scenario start, seconds.
    pub at_s: f64,
    pub priority: Priority,
}

fn pick_priority(rng: &mut SplitMix64, mix: &[f64; 3]) -> Priority {
    let total: f64 = mix.iter().sum();
    if total <= 0.0 {
        return Priority::Normal;
    }
    let x = rng.next_f64() * total;
    if x < mix[0] {
        Priority::High
    } else if x < mix[0] + mix[1] {
        Priority::Normal
    } else {
        Priority::Low
    }
}

impl Scenario {
    /// The full arrival plan — deterministic in the seed, computed
    /// before any request is sent (open-loop).
    pub fn arrivals(&self) -> Vec<Arrival> {
        let mut rng = SplitMix64::new(self.seed);
        let times = self.pattern.schedule(self.rate_rps, self.duration_s, &mut rng);
        times
            .into_iter()
            .map(|at_s| Arrival { at_s, priority: pick_priority(&mut rng, &self.priority_mix) })
            .collect()
    }

    /// Scenario config as emitted into `BENCH_loadgen.json`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str())
            .set("pattern", self.pattern.name())
            .set("rate_rps", self.rate_rps)
            .set("duration_s", self.duration_s)
            .set("seed", self.seed)
            .set(
                "priority_mix",
                self.priority_mix.iter().map(|&w| Json::from(w)).collect::<Vec<Json>>(),
            );
        if let ArrivalPattern::Burst { on_s, off_s } = self.pattern {
            j.set("burst_on_s", on_s).set("burst_off_s", off_s);
        }
        match self.deadline {
            Some(d) => j.set("deadline_ms", d.as_secs_f64() * 1e3),
            None => j.set("deadline_ms", Json::Null),
        };
        j
    }

    /// Run the scenario open-loop against `client`, cycling `payloads`
    /// across arrivals. Submission happens on the calling thread at the
    /// scheduled offsets; tickets resolve on a collector thread, so a
    /// slow response never stalls the arrival process (the latency
    /// numbers come from the `Response` timestamps, not from collector
    /// scheduling). Blocks until every outcome is recorded.
    pub fn run(&self, client: &InferenceClient, payloads: &[Payload]) -> LoadReport {
        assert!(!payloads.is_empty(), "scenario needs at least one payload");
        let plan = self.arrivals();
        let offered = plan.len();
        let (tx, rx) = mpsc::channel::<(Priority, Result<Ticket, ServeError>)>();
        let collector = std::thread::spawn(move || {
            let mut rec = Recorder::new();
            for (priority, submitted) in rx {
                match submitted {
                    Ok(ticket) => match ticket.wait() {
                        Ok(resp) => rec.record_ok_energy(
                            priority,
                            resp.e2e_s,
                            resp.queue_s,
                            resp.energy_j,
                        ),
                        Err(e) => rec.record_err(priority, &e),
                    },
                    Err(e) => rec.record_err(priority, &e),
                }
            }
            rec
        });
        let t0 = Instant::now();
        for (i, arrival) in plan.iter().enumerate() {
            let due = t0 + Duration::from_secs_f64(arrival.at_s);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            let mut opts = SubmitOptions::default().with_priority(arrival.priority);
            if let Some(d) = self.deadline {
                opts = opts.with_deadline(Deadline::within(d));
            }
            let outcome = client.submit_with(payloads[i % payloads.len()].clone(), opts);
            if tx.send((arrival.priority, outcome)).is_err() {
                break;
            }
        }
        drop(tx);
        let recorder = collector.join().expect("loadgen collector thread");
        recorder.report(offered, t0.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        AdmissionPolicy, BatcherConfig, Coordinator, CoordinatorConfig, EchoEngine,
    };
    use std::sync::Arc;

    #[test]
    fn arrival_plans_are_deterministic_and_mixed() {
        let s = Scenario { rate_rps: 400.0, duration_s: 1.0, ..Scenario::default() };
        let a = s.arrivals();
        let b = s.arrivals();
        assert_eq!(a.len(), b.len());
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.at_s == y.at_s && x.priority == y.priority));
        // The 1:2:1 default mix produces all three classes at n≈400.
        for p in [Priority::High, Priority::Normal, Priority::Low] {
            assert!(a.iter().any(|x| x.priority == p), "missing {p:?}");
        }
    }

    #[test]
    fn degenerate_priority_mix_defaults_to_normal() {
        let s = Scenario {
            priority_mix: [0.0, 0.0, 0.0],
            rate_rps: 300.0,
            duration_s: 0.5,
            ..Scenario::default()
        };
        assert!(s.arrivals().iter().all(|a| a.priority == Priority::Normal));
    }

    #[test]
    fn scenario_json_names_the_pattern() {
        let s = Scenario {
            pattern: ArrivalPattern::Burst { on_s: 0.05, off_s: 0.1 },
            deadline: Some(Duration::from_millis(250)),
            ..Scenario::default()
        };
        let j = s.to_json();
        assert_eq!(j.req("pattern").unwrap().as_str().unwrap(), "burst");
        assert!(j.req("burst_on_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.req("deadline_ms").unwrap().as_f64().unwrap(), 250.0);
        assert!(Scenario::default().to_json().get("burst_on_s").is_none());
    }

    #[test]
    fn scenario_json_always_emits_the_seed() {
        // The seed is what makes an emitted report reproducible; it must
        // be present for default and custom scenarios alike.
        let j = Scenario::default().to_json();
        assert_eq!(
            j.req("seed").unwrap().as_usize().unwrap() as u64,
            Scenario::default().seed
        );
        let custom = Scenario { seed: 0xDEAD_BEEF, ..Scenario::default() };
        assert_eq!(
            custom.to_json().req("seed").unwrap().as_usize().unwrap(),
            0xDEAD_BEEF
        );
    }

    #[test]
    fn echo_scenario_end_to_end_completes_everything() {
        let c = Coordinator::start(
            Arc::new(EchoEngine { delay_us: 100 }),
            CoordinatorConfig {
                batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
                min_workers: 1,
                max_workers: 2,
                queue_depth: 1024,
                admission: AdmissionPolicy::Block,
                power_envelope_watts: None,
            },
        );
        let s = Scenario {
            name: "echo-smoke".into(),
            rate_rps: 400.0,
            duration_s: 0.5,
            ..Scenario::default()
        };
        let report = s.run(&c.client(), &[Payload::Seq(vec![1, 2, 3])]);
        assert_eq!(report.offered as u64, report.submitted);
        assert_eq!(report.submitted, report.completed, "failures: {:?}", report.failures);
        assert_eq!(report.failed, 0);
        assert!(report.offered > 0);
        assert!(report.e2e.p50 > 0.0);
        assert!(report.e2e.p999 >= report.e2e.p99);
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, report.completed);
    }
}
