//! Open-loop arrival processes.
//!
//! Open-loop means the schedule is decided before the system is
//! observed: arrival offsets are generated ahead of time from a seeded
//! [`SplitMix64`] stream (`tensor::rng` — no wall-clock randomness), so
//! a slow server cannot push back on the arrival rate, which is exactly
//! what makes tail latency under overload measurable. The same seed
//! always yields the same schedule.

use crate::tensor::SplitMix64;

/// The arrival process shape.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalPattern {
    /// Memoryless Poisson arrivals at the scenario rate.
    Poisson,
    /// ON-OFF bursty arrivals: Poisson bursts during `on_s`-long ON
    /// windows separated by silent `off_s`-long OFF windows. The ON
    /// rate is scaled by `(on+off)/on`, so the long-run offered rate
    /// still matches the scenario rate while each burst overloads the
    /// server by that factor.
    Burst { on_s: f64, off_s: f64 },
}

impl ArrivalPattern {
    /// Stable CLI/JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalPattern::Poisson => "poisson",
            ArrivalPattern::Burst { .. } => "burst",
        }
    }

    /// Arrival offsets in seconds from scenario start — strictly
    /// increasing, fully determined by `rng`'s seed.
    pub fn schedule(&self, rate_rps: f64, duration_s: f64, rng: &mut SplitMix64) -> Vec<f64> {
        assert!(rate_rps > 0.0, "arrival rate must be positive");
        assert!(duration_s > 0.0, "duration must be positive");
        let mut out = Vec::new();
        match *self {
            ArrivalPattern::Poisson => {
                let mut t = 0.0;
                loop {
                    t += exp_sample(rng, rate_rps);
                    if t >= duration_s {
                        break;
                    }
                    out.push(t);
                }
            }
            ArrivalPattern::Burst { on_s, off_s } => {
                assert!(on_s > 0.0, "burst ON window must be positive");
                assert!(off_s >= 0.0, "burst OFF window must be non-negative");
                let cycle = on_s + off_s;
                let on_rate = rate_rps * cycle / on_s;
                // Generate a Poisson process on compressed "ON time",
                // then re-insert the OFF gaps to map onto wall time.
                let mut on_t = 0.0;
                loop {
                    on_t += exp_sample(rng, on_rate);
                    let bursts = (on_t / on_s).floor();
                    let wall = bursts * cycle + (on_t - bursts * on_s);
                    if wall >= duration_s {
                        break;
                    }
                    out.push(wall);
                }
            }
        }
        out
    }
}

/// Inverse-CDF exponential inter-arrival sample. `next_f64` is in
/// `[0, 1)`, so `1 - u` is in `(0, 1]` and the log is always finite.
fn exp_sample(rng: &mut SplitMix64, rate: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_deterministic_in_the_seed() {
        for pattern in
            [ArrivalPattern::Poisson, ArrivalPattern::Burst { on_s: 0.1, off_s: 0.3 }]
        {
            let a = pattern.schedule(500.0, 2.0, &mut SplitMix64::new(42));
            let b = pattern.schedule(500.0, 2.0, &mut SplitMix64::new(42));
            assert_eq!(a, b);
            let c = pattern.schedule(500.0, 2.0, &mut SplitMix64::new(43));
            assert_ne!(a, c, "different seeds must differ ({})", pattern.name());
        }
    }

    #[test]
    fn poisson_count_matches_rate_and_offsets_increase() {
        let xs = ArrivalPattern::Poisson.schedule(500.0, 4.0, &mut SplitMix64::new(7));
        // E[count] = 2000, sd ≈ 45 — ±20% is > 8 sigma.
        assert!((1600..=2400).contains(&xs.len()), "count {}", xs.len());
        for w in xs.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert!(xs.iter().all(|&t| t > 0.0 && t < 4.0));
    }

    #[test]
    fn burst_arrivals_stay_inside_on_windows_at_the_requested_rate() {
        let (on_s, off_s) = (0.05, 0.15);
        let xs = ArrivalPattern::Burst { on_s, off_s }
            .schedule(500.0, 4.0, &mut SplitMix64::new(9));
        // Long-run rate matches the requested 500 rps despite 75%
        // silence.
        assert!((1600..=2400).contains(&xs.len()), "count {}", xs.len());
        let cycle = on_s + off_s;
        for &t in &xs {
            let phase = t - (t / cycle).floor() * cycle;
            assert!(phase <= on_s + 1e-9, "arrival at {t} sits in an OFF window");
        }
        for w in xs.windows(2) {
            assert!(w[1] > w[0]);
        }
    }
}
