//! Open-loop load generation + tail-latency recording (L3.5).
//!
//! The serving stand-in for "heavy traffic from millions of users":
//! a seeded, open-loop arrival process ([`ArrivalPattern`]: Poisson or
//! bursty ON-OFF) drives a [`Scenario`] — rate, duration, priority mix,
//! optional per-request deadline — against any coordinator through the
//! normal typed [`crate::coordinator::InferenceClient`]. Open-loop
//! means arrivals never wait for responses, so queueing delay under
//! overload shows up in the tail instead of silently throttling the
//! generator.
//!
//! Outcomes land in a [`Recorder`] (per-priority-class completions,
//! typed failures, latency samples) and fold into a [`LoadReport`]:
//! goodput plus p50/p99/p999 end-to-end and queue-wait latency per
//! class, emitted as `BENCH_loadgen.json`. The same recorder backs the
//! closed-loop `Coordinator::drive` bench path, so benches, the CI
//! bench gate, and the load generator all measure through one code
//! path — and `bench_gate` holds a p99 SLO line against the committed
//! baseline.
//!
//! Everything is deterministic in the scenario seed (`tensor::rng`
//! SplitMix64, no wall-clock randomness): the same seed offers the
//! same requests at the same offsets with the same priorities.

pub mod arrival;
pub mod cli;
pub mod recorder;
pub mod scenario;

pub use arrival::ArrivalPattern;
pub use recorder::{ClassReport, LoadReport, Recorder, PRIORITY_NAMES};
pub use scenario::{Arrival, Scenario};
