//! Latency/goodput recording shared by the open-loop load generator
//! and the closed-loop `Coordinator::drive` bench path.
//!
//! The [`Recorder`] accumulates per-priority-class outcomes — end-to-end
//! and queue-wait latency samples for completions, typed-failure tallies
//! keyed by [`ServeError::kind`] — and folds into a [`LoadReport`]:
//! goodput plus p50/p99/p999 per class and overall, ready to emit as
//! `BENCH_loadgen.json` (via the NaN-free [`Percentiles::to_json_ms`]).

use crate::coordinator::{Percentiles, Priority, ServeError};
use crate::util::Json;
use std::collections::BTreeMap;
use std::time::Duration;

/// Display/JSON names of the three priority classes, in lane order.
pub const PRIORITY_NAMES: [&str; 3] = ["high", "normal", "low"];

fn lane(p: Priority) -> usize {
    match p {
        Priority::High => 0,
        Priority::Normal => 1,
        Priority::Low => 2,
    }
}

#[derive(Default)]
struct ClassRecord {
    submitted: u64,
    e2e: Vec<f64>,
    queue: Vec<f64>,
    /// Per-request co-simulated joules (only for responses that carried
    /// an energy report — engines without co-simulation record none).
    energy: Vec<f64>,
    failures: BTreeMap<&'static str, u64>,
}

/// Accumulates request outcomes per priority class.
#[derive(Default)]
pub struct Recorder {
    classes: [ClassRecord; 3],
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request (latencies in seconds, as carried
    /// by `Response`).
    pub fn record_ok(&mut self, priority: Priority, e2e_s: f64, queue_s: f64) {
        self.record_ok_energy(priority, e2e_s, queue_s, None);
    }

    /// [`Self::record_ok`] plus the response's co-simulated joules, when
    /// the serving engine reported them.
    pub fn record_ok_energy(
        &mut self,
        priority: Priority,
        e2e_s: f64,
        queue_s: f64,
        energy_j: Option<f64>,
    ) {
        let c = &mut self.classes[lane(priority)];
        c.submitted += 1;
        c.e2e.push(e2e_s);
        c.queue.push(queue_s);
        if let Some(j) = energy_j {
            c.energy.push(j);
        }
    }

    /// Record one request that ended in a typed failure.
    pub fn record_err(&mut self, priority: Priority, err: &ServeError) {
        let c = &mut self.classes[lane(priority)];
        c.submitted += 1;
        *c.failures.entry(err.kind()).or_insert(0) += 1;
    }

    /// Fold into the final report. `offered` is the planned request
    /// count (arrivals in the scenario, `n` for a closed-loop drive);
    /// `wall` the elapsed run time.
    pub fn report(self, offered: usize, wall: Duration) -> LoadReport {
        let wall_s = wall.as_secs_f64().max(1e-9);
        let mut all_e2e = Vec::new();
        let mut all_queue = Vec::new();
        let mut all_energy = Vec::new();
        let mut failures: BTreeMap<&'static str, u64> = BTreeMap::new();
        let mut submitted = 0u64;
        let mut completed = 0u64;
        let classes: Vec<ClassReport> = self
            .classes
            .iter()
            .enumerate()
            .map(|(i, c)| {
                submitted += c.submitted;
                completed += c.e2e.len() as u64;
                all_e2e.extend_from_slice(&c.e2e);
                all_queue.extend_from_slice(&c.queue);
                all_energy.extend_from_slice(&c.energy);
                for (k, v) in &c.failures {
                    *failures.entry(k).or_insert(0) += v;
                }
                ClassReport {
                    priority: PRIORITY_NAMES[i],
                    submitted: c.submitted,
                    completed: c.e2e.len() as u64,
                    e2e: Percentiles::of(c.e2e.clone()),
                    queue: Percentiles::of(c.queue.clone()),
                    energy_j: Percentiles::of(c.energy.clone()),
                    energy_total_j: c.energy.iter().sum(),
                    energy_samples: c.energy.len() as u64,
                    failures: c.failures.clone(),
                }
            })
            .collect();
        let failed = failures.values().sum();
        LoadReport {
            offered,
            submitted,
            completed,
            failed,
            wall_s,
            offered_rps: offered as f64 / wall_s,
            goodput_rps: completed as f64 / wall_s,
            e2e: Percentiles::of(all_e2e),
            queue: Percentiles::of(all_queue),
            energy_total_j: all_energy.iter().sum(),
            energy_samples: all_energy.len() as u64,
            energy_j: Percentiles::of(all_energy),
            classes,
            failures,
        }
    }
}

/// Per-priority-class slice of a [`LoadReport`].
#[derive(Clone, Debug)]
pub struct ClassReport {
    pub priority: &'static str,
    pub submitted: u64,
    pub completed: u64,
    pub e2e: Percentiles,
    pub queue: Percentiles,
    /// Per-request co-simulated joules distribution (all-zero when the
    /// engine reported no energy).
    pub energy_j: Percentiles,
    /// Total co-simulated joules this class spent.
    pub energy_total_j: f64,
    /// Completions that carried an energy report.
    pub energy_samples: u64,
    pub failures: BTreeMap<&'static str, u64>,
}

/// The final scenario/drive report: goodput and tail latency, overall
/// and per priority class.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Requests the arrival plan called for.
    pub offered: usize,
    /// Requests actually pushed at the client (== offered unless the
    /// submitter was aborted).
    pub submitted: u64,
    /// Requests that produced a normal response.
    pub completed: u64,
    /// Requests that ended in any typed failure.
    pub failed: u64,
    pub wall_s: f64,
    pub offered_rps: f64,
    /// Completions per wall second — the SLO-facing throughput.
    pub goodput_rps: f64,
    /// Overall end-to-end latency distribution (seconds).
    pub e2e: Percentiles,
    /// Overall queue-wait distribution (seconds).
    pub queue: Percentiles,
    /// Overall per-request co-simulated joules distribution.
    pub energy_j: Percentiles,
    /// Total co-simulated joules across every completion that reported.
    pub energy_total_j: f64,
    /// Completions that carried an energy report.
    pub energy_samples: u64,
    /// One entry per priority class, lane order (high, normal, low).
    pub classes: Vec<ClassReport>,
    /// Aggregated typed-failure tallies keyed by [`ServeError::kind`].
    pub failures: BTreeMap<&'static str, u64>,
}

impl LoadReport {
    pub fn engine_failures(&self) -> u64 {
        self.failures.get("engine_failure").copied().unwrap_or(0)
    }

    /// The `BENCH_loadgen.json` body (scenario/serving config is
    /// attached by the caller).
    pub fn to_json(&self) -> Json {
        // Joules are emitted raw (not ms-scaled like the latencies).
        fn energy_json(p: &Percentiles) -> Json {
            let mut j = Json::obj();
            j.set("mean_j", p.mean)
                .set("p50_j", p.p50)
                .set("p99_j", p.p99)
                .set("p999_j", p.p999)
                .set("max_j", p.max);
            j
        }
        let mut failures = Json::obj();
        for (k, v) in &self.failures {
            failures.set(*k, *v);
        }
        let mut per_priority = Json::obj();
        for c in &self.classes {
            let mut cj = Json::obj();
            cj.set("submitted", c.submitted)
                .set("completed", c.completed)
                .set("e2e_ms", c.e2e.to_json_ms())
                .set("queue_ms", c.queue.to_json_ms())
                .set("energy_j", energy_json(&c.energy_j))
                .set("energy_total_j", c.energy_total_j)
                .set("energy_samples", c.energy_samples);
            let mut cf = Json::obj();
            for (k, v) in &c.failures {
                cf.set(*k, *v);
            }
            cj.set("failures", cf);
            per_priority.set(c.priority, cj);
        }
        let mut j = Json::obj();
        j.set("offered", self.offered)
            .set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("failed", self.failed)
            .set("wall_s", self.wall_s)
            .set("offered_rps", self.offered_rps)
            .set("goodput_rps", self.goodput_rps)
            .set("e2e_ms", self.e2e.to_json_ms())
            .set("queue_ms", self.queue.to_json_ms())
            .set("energy_j", energy_json(&self.energy_j))
            .set("energy_total_j", self.energy_total_j)
            .set("energy_samples", self.energy_samples)
            .set("failures", failures)
            .set("per_priority", per_priority);
        j
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let failures = if self.failed > 0 {
            let parts: Vec<String> =
                self.failures.iter().map(|(k, v)| format!("{v} {k}")).collect();
            format!(", failed: {}", parts.join(" / "))
        } else {
            String::new()
        };
        format!(
            "offered {} ({:.1} rps), completed {} (goodput {:.1} rps), e2e p50/p99/p999 = \
             {:.2}/{:.2}/{:.2} ms, queue p99 = {:.2} ms{failures}",
            self.offered,
            self.offered_rps,
            self.completed,
            self.goodput_rps,
            self.e2e.p50 * 1e3,
            self.e2e.p99 * 1e3,
            self.e2e.p999 * 1e3,
            self.queue.p99 * 1e3,
        )
    }

    /// Per-priority breakdown, one line per class.
    pub fn class_table(&self) -> String {
        self.classes
            .iter()
            .map(|c| {
                format!(
                    "  {:<6} {:>6}/{:<6} e2e p50/p99/p999 = {:.2}/{:.2}/{:.2} ms",
                    c.priority,
                    c.completed,
                    c.submitted,
                    c.e2e.p50 * 1e3,
                    c.e2e.p99 * 1e3,
                    c.e2e.p999 * 1e3,
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_tallies_per_class_and_overall() {
        let mut r = Recorder::new();
        for i in 0..10 {
            r.record_ok(Priority::Normal, 0.010 + i as f64 * 1e-4, 0.001);
        }
        r.record_ok(Priority::High, 0.002, 0.0005);
        r.record_err(Priority::Low, &ServeError::QueueFull);
        r.record_err(Priority::Low, &ServeError::EngineFailure("boom".into()));
        let rep = r.report(13, Duration::from_secs(1));
        assert_eq!(rep.offered, 13);
        assert_eq!(rep.submitted, 13);
        assert_eq!(rep.completed, 11);
        assert_eq!(rep.failed, 2);
        assert_eq!(rep.engine_failures(), 1);
        assert_eq!(rep.failures["queue_full"], 1);
        assert!((rep.goodput_rps - 11.0).abs() < 1e-6);
        assert_eq!(rep.classes.len(), 3);
        assert_eq!(rep.classes[0].priority, "high");
        assert_eq!(rep.classes[0].completed, 1);
        assert_eq!(rep.classes[2].submitted, 2);
        assert_eq!(rep.classes[2].completed, 0);
        // High class: its single sample is every percentile.
        assert_eq!(rep.classes[0].e2e.p999, 0.002);
        assert!(rep.e2e.p50 >= 0.002);
    }

    #[test]
    fn report_json_is_nan_free_even_when_empty() {
        let rep = Recorder::new().report(0, Duration::from_millis(1));
        let encoded = rep.to_json().encode();
        assert!(!encoded.contains("null"), "{encoded}");
        assert!(!encoded.contains("NaN"), "{encoded}");
        // Per-priority sections exist for all three classes.
        let j = rep.to_json();
        let pp = j.req("per_priority").unwrap();
        for name in PRIORITY_NAMES {
            assert!(pp.get(name).is_some(), "missing class {name}");
        }
    }

    #[test]
    fn energy_percentiles_aggregate_per_priority() {
        let mut r = Recorder::new();
        r.record_ok_energy(Priority::High, 0.002, 0.0005, Some(2.0e-7));
        r.record_ok_energy(Priority::Normal, 0.010, 0.001, Some(2.0e-7));
        r.record_ok_energy(Priority::Normal, 0.011, 0.001, Some(4.0e-7));
        // A response without an energy report adds no sample.
        r.record_ok(Priority::Low, 0.020, 0.002);
        let rep = r.report(4, Duration::from_secs(1));
        assert_eq!(rep.energy_samples, 3);
        assert!((rep.energy_total_j - 8.0e-7).abs() < 1e-18);
        assert!((rep.energy_j.max - 4.0e-7).abs() < 1e-18);
        assert_eq!(rep.classes[1].energy_samples, 2);
        assert!((rep.classes[1].energy_total_j - 6.0e-7).abs() < 1e-18);
        assert_eq!(rep.classes[2].energy_samples, 0);
        assert_eq!(rep.classes[2].energy_total_j, 0.0);
        let j = rep.to_json();
        assert!(j.req("energy_total_j").is_ok());
        let normal = j.req("per_priority").unwrap().req("normal").unwrap();
        assert!(
            (normal.req("energy_j").unwrap().req("max_j").unwrap().as_f64().unwrap() - 4.0e-7)
                .abs()
                < 1e-18
        );
    }

    #[test]
    fn summary_and_table_render() {
        let mut r = Recorder::new();
        r.record_ok(Priority::Normal, 0.010, 0.001);
        r.record_err(Priority::Low, &ServeError::QueueFull);
        let rep = r.report(2, Duration::from_secs(2));
        let s = rep.summary();
        assert!(s.contains("offered 2"), "{s}");
        assert!(s.contains("1 queue_full"), "{s}");
        assert_eq!(rep.class_table().lines().count(), 3);
    }
}
