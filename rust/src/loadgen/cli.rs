//! Flag-driven entrypoint shared by the standalone `loadgen` bin and
//! the `repro loadgen` subcommand — one implementation, two front
//! doors, identical flags.
//!
//! ```bash
//! loadgen --engine counting --pattern poisson --rate 150 --duration 2 \
//!     --seed 42 --admission block --out artifacts/reports/BENCH_loadgen.json
//! ```

use super::arrival::ArrivalPattern;
use super::recorder::LoadReport;
use super::scenario::Scenario;
use crate::accel::{AccelConfig, EnergyModel};
use crate::coordinator::{
    AdmissionPolicy, BatcherConfig, Coordinator, CoordinatorConfig, CountingFcBackend,
    EchoEngine, Payload,
};
use crate::dataset::ImageDataset;
use crate::dnateq::ExpQuantParams;
use crate::energysim::{ci, CoSimEngine, CostModel};
use crate::expdot::CountingFc;
use crate::tensor::{SplitMix64, Tensor};
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// Seed of the fixed CI counting layer (distinct from the bench_gate
/// timing sweep so the two never alias).
pub const CI_ENGINE_SEED: u64 = 0xC1_10AD;

/// Flags `run_from_flags` understands. `simd` and `fail-on-errors` are
/// accepted but handled by the callers (global dispatch override /
/// bin exit code).
const KNOWN_FLAGS: [&str; 20] = [
    "name",
    "pattern",
    "rate",
    "duration",
    "seed",
    "burst-on",
    "burst-off",
    "priority-mix",
    "deadline-ms",
    "admission",
    "power-envelope-watts",
    "engine",
    "delay-us",
    "max-batch",
    "max-wait-ms",
    "min-workers",
    "max-workers",
    "queue-depth",
    "out",
    "simd",
];

/// The fixed-shape 4-bit 3072→256 counting-FC backend the CI jobs
/// drive — the same construction as the bench_gate timing sweep, so
/// the tail-latency SLO gate exercises the real quantized hot path.
pub fn counting_engine(seed: u64) -> Arc<CountingFcBackend> {
    let mut rng = SplitMix64::new(seed);
    let w = Tensor::rand_signed_exponential(&[256, 3 * 32 * 32], 3.0, &mut rng);
    let x_cal = Tensor::rand_signed_exponential(&[1, 3 * 32 * 32], 1.0, &mut rng);
    let wp = ExpQuantParams::init_for_tensor(&w, 4);
    let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: 4 };
    ap.refit_scale_offset(&x_cal);
    Arc::new(CountingFcBackend { fc: CountingFc::new(&w, wp, ap, None) })
}

fn f64_flag(flags: &BTreeMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().with_context(|| format!("--{key} must be a number, got `{v}`")),
    }
}

fn usize_flag(flags: &BTreeMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().with_context(|| format!("--{key} must be an integer, got `{v}`")),
    }
}

fn u64_flag(flags: &BTreeMap<String, String>, key: &str, default: u64) -> Result<u64> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().with_context(|| format!("--{key} must be an integer, got `{v}`")),
    }
}

/// Parse `h:n:l` priority weights.
fn parse_mix(s: &str) -> Result<[f64; 3]> {
    let parts: Vec<&str> = s.split(':').collect();
    if parts.len() != 3 {
        bail!("--priority-mix wants `high:normal:low` weights, e.g. 1:2:1 (got `{s}`)");
    }
    let mut mix = [0.0; 3];
    for (slot, part) in mix.iter_mut().zip(&parts) {
        *slot = part
            .parse()
            .with_context(|| format!("--priority-mix weight `{part}` is not a number"))?;
        if !slot.is_finite() || *slot < 0.0 {
            bail!("--priority-mix weights must be non-negative finite numbers (got `{part}`)");
        }
    }
    Ok(mix)
}

/// Build the [`Scenario`] described by the flags.
pub fn scenario_from_flags(flags: &BTreeMap<String, String>) -> Result<Scenario> {
    let pattern = match flags.get("pattern").map(String::as_str).unwrap_or("poisson") {
        "poisson" => ArrivalPattern::Poisson,
        "burst" => ArrivalPattern::Burst {
            on_s: f64_flag(flags, "burst-on", 0.05)?,
            off_s: f64_flag(flags, "burst-off", 0.15)?,
        },
        other => bail!("unknown arrival pattern `{other}` (poisson|burst)"),
    };
    let deadline = match flags.get("deadline-ms") {
        None => None,
        Some(v) => {
            let ms: f64 = v.parse().with_context(|| format!("--deadline-ms got `{v}`"))?;
            Some(Duration::from_secs_f64(ms / 1e3))
        }
    };
    let defaults = Scenario::default();
    Ok(Scenario {
        name: flags.get("name").cloned().unwrap_or_else(|| pattern.name().to_string()),
        pattern,
        rate_rps: f64_flag(flags, "rate", defaults.rate_rps)?,
        duration_s: f64_flag(flags, "duration", defaults.duration_s)?,
        seed: u64_flag(flags, "seed", defaults.seed)?,
        priority_mix: match flags.get("priority-mix") {
            None => defaults.priority_mix,
            Some(s) => parse_mix(s)?,
        },
        deadline,
    })
}

/// Run a scenario end-to-end from CLI flags: build the engine and
/// coordinator, replay the arrival plan, print the report, optionally
/// emit `BENCH_loadgen.json`. Returns the report so callers can gate
/// on it (exit codes, SLO checks).
pub fn run_from_flags(flags: &BTreeMap<String, String>) -> Result<LoadReport> {
    for key in flags.keys() {
        if !KNOWN_FLAGS.contains(&key.as_str()) && key != "fail-on-errors" {
            bail!("unknown loadgen flag `--{key}`");
        }
    }
    let scenario = scenario_from_flags(flags)?;
    let admission =
        AdmissionPolicy::parse(flags.get("admission").map(String::as_str).unwrap_or("block"))
            .map_err(anyhow::Error::msg)?;
    let max_batch = usize_flag(flags, "max-batch", 8)?;
    let max_wait_ms = f64_flag(flags, "max-wait-ms", 1.0)?;
    let min_workers = usize_flag(flags, "min-workers", 1)?;
    let max_workers = usize_flag(flags, "max-workers", 4)?.max(min_workers);
    let queue_depth = usize_flag(flags, "queue-depth", 1024)?;
    let power_envelope_watts = match flags.get("power-envelope-watts") {
        None => None,
        Some(v) => Some(v.parse::<f64>().with_context(|| {
            format!("--power-envelope-watts must be a number, got `{v}`")
        })?),
    };
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch,
            max_wait: Duration::from_secs_f64(max_wait_ms / 1e3),
        },
        min_workers,
        max_workers,
        queue_depth,
        admission,
        power_envelope_watts,
    };

    let engine_kind = flags.get("engine").map(String::as_str).unwrap_or("counting");
    let (coordinator, payloads): (Coordinator, Vec<Payload>) = match engine_kind {
        "counting" => {
            let data = ImageDataset::synthetic(32, 0xC1DA7A);
            let payloads = (0..data.len()).map(|i| Payload::Image(data.image(i))).collect();
            // The counting engine is the real exp-4 hot path; co-simulate
            // it under the matching exp-4 plan so every response carries
            // joules (and the energy-budget admission has a power signal).
            let cost = CostModel::from_config(
                &ci::exp_plan(),
                &EnergyModel::default(),
                &AccelConfig::default(),
            );
            let engine = Arc::new(CoSimEngine::new(counting_engine(CI_ENGINE_SEED), cost));
            (Coordinator::start(engine, cfg), payloads)
        }
        "echo" => {
            let delay_us = u64_flag(flags, "delay-us", 200)?;
            let payloads = (0..8).map(|i| Payload::Seq(vec![i, i + 1, i + 2])).collect();
            (Coordinator::start(Arc::new(EchoEngine { delay_us }), cfg), payloads)
        }
        other => bail!("unknown loadgen engine `{other}` (counting|echo)"),
    };

    println!(
        "loadgen: scenario `{}` ({} @ {:.0} rps for {:.1}s, seed {:#x}), engine {engine_kind}, \
         admission {}, pool {}..{} x batch {}",
        scenario.name,
        scenario.pattern.name(),
        scenario.rate_rps,
        scenario.duration_s,
        scenario.seed,
        admission.name(),
        min_workers,
        max_workers,
        max_batch,
    );
    let report = scenario.run(&coordinator.client(), &payloads);
    let snap = coordinator.shutdown_and_drain();
    println!("{}", report.summary());
    println!("{}", report.class_table());
    println!("serving: {}", snap.summary());

    if let Some(out) = flags.get("out") {
        let mut serving = Json::obj();
        serving
            .set("engine", engine_kind)
            .set("admission", admission.name())
            .set("max_batch", max_batch)
            .set("max_wait_ms", max_wait_ms)
            .set("min_workers", min_workers)
            .set("max_workers", max_workers)
            .set("queue_depth", queue_depth)
            .set("scale_ups", snap.scale_ups)
            .set("scale_downs", snap.scale_downs)
            .set("energy_total_j", snap.energy_total_j)
            .set("energy_j_per_request", snap.energy_j_per_request)
            .set("energy_j_per_output", snap.energy_j_per_output)
            .set("energy_shed", snap.energy_shed);
        match power_envelope_watts {
            Some(w) => serving.set("power_envelope_watts", w),
            None => serving.set("power_envelope_watts", Json::Null),
        };
        let mut j = report.to_json();
        j.set("scenario", scenario.to_json()).set("serving", serving);
        j.write_file(out).with_context(|| format!("writing loadgen report to {out}"))?;
        println!("JSON -> {out}");
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(pairs: &[(&str, &str)]) -> BTreeMap<String, String> {
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn scenario_flags_parse_round_trip() {
        let s = scenario_from_flags(&flags(&[
            ("pattern", "burst"),
            ("burst-on", "0.02"),
            ("burst-off", "0.08"),
            ("rate", "333"),
            ("duration", "1.5"),
            ("seed", "99"),
            ("priority-mix", "3:1:0"),
            ("deadline-ms", "120"),
        ]))
        .unwrap();
        assert_eq!(s.pattern, ArrivalPattern::Burst { on_s: 0.02, off_s: 0.08 });
        assert_eq!(s.rate_rps, 333.0);
        assert_eq!(s.seed, 99);
        assert_eq!(s.priority_mix, [3.0, 1.0, 0.0]);
        assert_eq!(s.deadline, Some(Duration::from_millis(120)));
        assert!(scenario_from_flags(&flags(&[("pattern", "sine")])).is_err());
        assert!(scenario_from_flags(&flags(&[("priority-mix", "1:2")])).is_err());
        assert!(scenario_from_flags(&flags(&[("priority-mix", "1:-2:1")])).is_err());
    }

    #[test]
    fn unknown_flags_are_rejected() {
        let err = run_from_flags(&flags(&[("rat", "100")])).unwrap_err();
        assert!(err.to_string().contains("rat"), "{err}");
    }

    #[test]
    fn echo_run_from_flags_is_deterministic_in_offered_count() {
        let f = flags(&[
            ("engine", "echo"),
            ("rate", "300"),
            ("duration", "0.4"),
            ("seed", "42"),
            ("max-workers", "2"),
        ]);
        let a = run_from_flags(&f).unwrap();
        let b = run_from_flags(&f).unwrap();
        assert_eq!(a.offered, b.offered, "same seed must offer the same request count");
        assert!(a.offered > 0);
        assert_eq!(a.failed, 0, "failures: {:?}", a.failures);
        assert_eq!(a.completed as usize, a.offered);
    }
}
