//! Multi-model serving registry.
//!
//! Owns N named models, each with its own coordinator (typed client →
//! priority submission queue → dynamic batcher → worker pool → engine)
//! and its own metrics stream. Callers obtain the same
//! [`InferenceClient`] type the single-model path uses
//! ([`ModelRegistry::client`]), so tickets, deadlines, priorities,
//! cancellation, and the typed [`super::ServeError`] taxonomy behave
//! identically whether one model or many are being served; because
//! every model keeps a private FIFO queue, interleaved multi-model
//! traffic preserves per-model submission order end to end.
//!
//! Engines registered through [`ModelRegistry::register_swappable`]
//! additionally support **atomic plan hot-swap**: the registry hands the
//! new [`QuantConfig`] to the engine, which publishes the rebuilt plan
//! with a single `Arc` store. In-flight requests are neither dropped nor
//! reordered — a batch that already started keeps the plan it began
//! with, and the next batch picks up the new one.

use super::client::InferenceClient;
use super::engine::Engine;
use super::metrics::MetricsSnapshot;
use super::request::{Payload, Response};
use super::server::{Coordinator, CoordinatorConfig};
use super::Ticket;
use crate::dnateq::{PlanPolicy, PlanStore, QuantConfig};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

/// An engine whose quantization plan can be replaced while serving.
pub trait SwappableEngine: Engine {
    /// Atomically install the plan derived from `cfg`. Must not block
    /// inference for longer than a pointer swap.
    fn swap_plan(&self, cfg: &QuantConfig) -> Result<()>;

    /// Short description of the plan currently being served.
    fn plan_label(&self) -> String;
}

struct ModelEntry {
    coordinator: Coordinator,
    swap: Option<Arc<dyn SwappableEngine>>,
    engine_name: String,
}

/// Registry of named serving models.
#[derive(Default)]
pub struct ModelRegistry {
    entries: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a fixed-plan engine under `name` and start its
    /// coordinator. Errors if the name is taken.
    pub fn register(
        &self,
        name: &str,
        engine: Arc<dyn Engine>,
        cfg: CoordinatorConfig,
    ) -> Result<()> {
        let engine_name = engine.name().to_string();
        let coordinator = Coordinator::start(engine, cfg);
        self.insert(name, coordinator, None, engine_name)
    }

    /// Register a hot-swappable engine under `name`. The registry keeps
    /// a handle for [`Self::swap_plan`] alongside the coordinator.
    pub fn register_swappable(
        &self,
        name: &str,
        engine: Arc<dyn SwappableEngine>,
        cfg: CoordinatorConfig,
    ) -> Result<()> {
        let engine_name = engine.name().to_string();
        let coordinator = Coordinator::start(Arc::clone(&engine), cfg);
        self.insert(name, coordinator, Some(engine), engine_name)
    }

    fn insert(
        &self,
        name: &str,
        coordinator: Coordinator,
        swap: Option<Arc<dyn SwappableEngine>>,
        engine_name: String,
    ) -> Result<()> {
        let mut entries = self.entries.write().unwrap();
        if entries.contains_key(name) {
            bail!("model `{name}` is already registered");
        }
        entries.insert(name.to_string(), Arc::new(ModelEntry { coordinator, swap, engine_name }));
        Ok(())
    }

    fn entry(&self, model: &str) -> Result<Arc<ModelEntry>> {
        let entries = self.entries.read().unwrap();
        match entries.get(model) {
            Some(e) => Ok(Arc::clone(e)),
            None => {
                let known: Vec<String> = entries.keys().cloned().collect();
                bail!("unknown model `{model}`; registered: {known:?}")
            }
        }
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<String> {
        self.entries.read().unwrap().keys().cloned().collect()
    }

    /// Name the engine under `model` reports for itself.
    pub fn engine_name(&self, model: &str) -> Result<String> {
        Ok(self.entry(model)?.engine_name.clone())
    }

    /// Plan label of a swappable model (errors for fixed engines).
    pub fn plan_label(&self, model: &str) -> Result<String> {
        let entry = self.entry(model)?;
        match &entry.swap {
            Some(b) => Ok(b.plan_label()),
            None => bail!("model `{model}` has a fixed plan"),
        }
    }

    /// Typed client onto `model`'s coordinator — the same
    /// [`InferenceClient`] single-model callers use, with deadlines,
    /// priorities, cancellation, and typed errors.
    pub fn client(&self, model: &str) -> Result<InferenceClient> {
        Ok(self.entry(model)?.coordinator.client())
    }

    /// Route a payload to `model`; returns its ticket. (Convenience for
    /// one-shot callers; sustained traffic should hold a
    /// [`Self::client`].)
    pub fn submit(&self, model: &str, payload: Payload) -> Result<Ticket> {
        Ok(self.entry(model)?.coordinator.submit(payload)?)
    }

    /// Route a payload to `model` and block for the response.
    pub fn submit_wait(&self, model: &str, payload: Payload) -> Result<Response> {
        Ok(self.entry(model)?.coordinator.submit_wait(payload)?)
    }

    /// Hot-swap the quantization plan of a running model.
    pub fn swap_plan(&self, model: &str, cfg: &QuantConfig) -> Result<()> {
        let entry = self.entry(model)?;
        match &entry.swap {
            Some(b) => {
                b.swap_plan(cfg)?;
                entry.coordinator.metrics_handle().record_swap();
                Ok(())
            }
            None => bail!(
                "model `{model}` (engine `{}`) does not support plan hot-swap",
                entry.engine_name
            ),
        }
    }

    /// Resolve an SLA [`PlanPolicy`] against `model`'s stored Pareto
    /// front and hot-swap the winning plan version in. Returns the
    /// chosen version and its (checksum-verified) config, so callers
    /// can log which front point is now serving.
    pub fn apply_policy(
        &self,
        model: &str,
        store: &PlanStore,
        policy: PlanPolicy,
    ) -> Result<(u32, QuantConfig)> {
        let front = match store.load_front(model)? {
            Some(f) => f,
            None => bail!("model `{model}` has no plan front; run `plans build {model}` first"),
        };
        let point = match front.select(policy) {
            Some(p) => p,
            None => bail!("plan front for `{model}` is empty"),
        };
        let cfg = store.load(model, point.version)?;
        self.swap_plan(model, &cfg)?;
        Ok((point.version, cfg))
    }

    /// Live metrics of one model.
    pub fn metrics(&self, model: &str) -> Result<MetricsSnapshot> {
        Ok(self.entry(model)?.coordinator.metrics())
    }

    /// Live metrics of every model.
    pub fn metrics_all(&self) -> BTreeMap<String, MetricsSnapshot> {
        let entries = self.entries.read().unwrap();
        entries.iter().map(|(k, e)| (k.clone(), e.coordinator.metrics())).collect()
    }

    /// Gracefully drain and stop every model's workers, returning final
    /// metrics. Every outstanding ticket resolves (with a response or a
    /// typed error) before this returns.
    pub fn shutdown_and_drain(self) -> BTreeMap<String, MetricsSnapshot> {
        let entries = std::mem::take(&mut *self.entries.write().unwrap());
        let mut out = BTreeMap::new();
        for (name, arc) in entries {
            // `shutdown_and_drain(self)` takes the registry by value, so
            // no &self method (the only place entry Arcs are cloned, and
            // they never outlive the call) can still be running — the
            // map holds the last reference.
            let entry = Arc::try_unwrap(arc).ok().expect("no live entry references at shutdown");
            out.insert(name, entry.coordinator.shutdown_and_drain());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::EchoEngine;
    use super::*;
    use crate::coordinator::request::Output;

    fn reg_with_echo(names: &[&str]) -> ModelRegistry {
        let reg = ModelRegistry::new();
        for n in names {
            reg.register(n, Arc::new(EchoEngine { delay_us: 0 }), CoordinatorConfig::default())
                .unwrap();
        }
        reg
    }

    #[test]
    fn routes_by_model_name() {
        let reg = reg_with_echo(&["a", "b"]);
        assert_eq!(reg.models(), vec!["a".to_string(), "b".to_string()]);
        let ra = reg.submit_wait("a", Payload::Seq(vec![1])).unwrap();
        let rb = reg.submit_wait("b", Payload::Seq(vec![2])).unwrap();
        assert_eq!(ra.output, Output::Tokens(vec![1]));
        assert_eq!(rb.output, Output::Tokens(vec![2]));
        let snaps = reg.shutdown_and_drain();
        assert_eq!(snaps["a"].completed, 1);
        assert_eq!(snaps["b"].completed, 1);
    }

    #[test]
    fn client_handles_route_like_direct_submission() {
        let reg = reg_with_echo(&["m"]);
        let client = reg.client("m").unwrap();
        assert_eq!(client.engine_name(), "echo");
        let resp = client.infer(Payload::Seq(vec![9])).unwrap();
        assert_eq!(resp.output, Output::Tokens(vec![9]));
        assert!(reg.client("nope").is_err());
        let snaps = reg.shutdown_and_drain();
        assert_eq!(snaps["m"].completed, 1);
    }

    #[test]
    fn unknown_model_lists_registered_names() {
        let reg = reg_with_echo(&["alexnet"]);
        let err = reg.submit_wait("resnet", Payload::Seq(vec![1])).unwrap_err().to_string();
        assert!(err.contains("alexnet"), "err: {err}");
        reg.shutdown_and_drain();
    }

    #[test]
    fn duplicate_registration_rejected() {
        let reg = reg_with_echo(&["m"]);
        let dup = reg.register(
            "m",
            Arc::new(EchoEngine { delay_us: 0 }),
            CoordinatorConfig::default(),
        );
        assert!(dup.is_err());
        reg.shutdown_and_drain();
    }

    #[test]
    fn fixed_engine_refuses_swap() {
        let reg = reg_with_echo(&["m"]);
        let cfg = QuantConfig { model: "m".into(), thr_w: 0.04, layers: vec![] };
        let err = reg.swap_plan("m", &cfg).unwrap_err().to_string();
        assert!(err.contains("hot-swap"), "err: {err}");
        assert!(reg.plan_label("m").is_err());
        reg.shutdown_and_drain();
    }

    #[test]
    fn apply_policy_requires_a_stored_front() {
        use crate::util::TempDir;
        let reg = reg_with_echo(&["m"]);
        let dir = TempDir::new().unwrap();
        let store = PlanStore::new(dir.path());
        let err = reg.apply_policy("m", &store, PlanPolicy::MinBits).unwrap_err().to_string();
        assert!(err.contains("no plan front"), "err: {err}");
        reg.shutdown_and_drain();
    }

    #[test]
    fn per_model_metrics_are_isolated() {
        let reg = reg_with_echo(&["a", "b"]);
        for _ in 0..5 {
            reg.submit_wait("a", Payload::Seq(vec![9])).unwrap();
        }
        let all = reg.metrics_all();
        assert_eq!(all["a"].completed, 5);
        assert_eq!(all["b"].completed, 0);
        assert_eq!(reg.metrics("a").unwrap().completed, 5);
        reg.shutdown_and_drain();
    }
}
