//! Multi-model serving registry.
//!
//! Owns N named models, each with its own coordinator (submission queue
//! → dynamic batcher → worker pool → backend) and its own metrics
//! stream. Requests are routed by model name; because every model keeps
//! a private FIFO queue, interleaved multi-model traffic preserves
//! per-model submission order end to end.
//!
//! Backends registered through [`ModelRegistry::register_swappable`]
//! additionally support **atomic plan hot-swap**: the registry hands the
//! new [`QuantConfig`] to the backend, which publishes the rebuilt plan
//! with a single `Arc` store. In-flight requests are neither dropped nor
//! reordered — a batch that already started keeps the plan it began
//! with, and the next batch picks up the new one.

use super::metrics::MetricsSnapshot;
use super::request::{Payload, Response};
use super::server::{Backend, Coordinator, CoordinatorConfig};
use crate::dnateq::QuantConfig;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, RwLock};

/// A backend whose quantization plan can be replaced while serving.
pub trait SwappableBackend: Backend {
    /// Atomically install the plan derived from `cfg`. Must not block
    /// inference for longer than a pointer swap.
    fn swap_plan(&self, cfg: &QuantConfig) -> Result<()>;

    /// Short description of the plan currently being served.
    fn plan_label(&self) -> String;
}

struct ModelEntry {
    coordinator: Coordinator,
    swap: Option<Arc<dyn SwappableBackend>>,
    backend_name: String,
}

/// Registry of named serving models.
#[derive(Default)]
pub struct ModelRegistry {
    entries: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a fixed-plan backend under `name` and start its
    /// coordinator. Errors if the name is taken.
    pub fn register(
        &self,
        name: &str,
        backend: Arc<dyn Backend>,
        cfg: CoordinatorConfig,
    ) -> Result<()> {
        let backend_name = backend.name().to_string();
        let coordinator = Coordinator::start(backend, cfg);
        self.insert(name, coordinator, None, backend_name)
    }

    /// Register a hot-swappable backend under `name`. The registry keeps
    /// a handle for [`Self::swap_plan`] alongside the coordinator.
    pub fn register_swappable(
        &self,
        name: &str,
        backend: Arc<dyn SwappableBackend>,
        cfg: CoordinatorConfig,
    ) -> Result<()> {
        let backend_name = backend.name().to_string();
        let coordinator = Coordinator::start(Arc::clone(&backend), cfg);
        self.insert(name, coordinator, Some(backend), backend_name)
    }

    fn insert(
        &self,
        name: &str,
        coordinator: Coordinator,
        swap: Option<Arc<dyn SwappableBackend>>,
        backend_name: String,
    ) -> Result<()> {
        let mut entries = self.entries.write().unwrap();
        if entries.contains_key(name) {
            bail!("model `{name}` is already registered");
        }
        entries.insert(name.to_string(), Arc::new(ModelEntry { coordinator, swap, backend_name }));
        Ok(())
    }

    fn entry(&self, model: &str) -> Result<Arc<ModelEntry>> {
        let entries = self.entries.read().unwrap();
        match entries.get(model) {
            Some(e) => Ok(Arc::clone(e)),
            None => {
                let known: Vec<String> = entries.keys().cloned().collect();
                bail!("unknown model `{model}`; registered: {known:?}")
            }
        }
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<String> {
        self.entries.read().unwrap().keys().cloned().collect()
    }

    /// Name the backend under `model` reports for itself.
    pub fn backend_name(&self, model: &str) -> Result<String> {
        Ok(self.entry(model)?.backend_name.clone())
    }

    /// Plan label of a swappable model (errors for fixed backends).
    pub fn plan_label(&self, model: &str) -> Result<String> {
        let entry = self.entry(model)?;
        match &entry.swap {
            Some(b) => Ok(b.plan_label()),
            None => bail!("model `{model}` has a fixed plan"),
        }
    }

    /// Route a payload to `model`; returns its response channel.
    pub fn submit(&self, model: &str, payload: Payload) -> Result<Receiver<Response>> {
        self.entry(model)?.coordinator.submit(payload)
    }

    /// Route a payload to `model` and block for the response.
    pub fn submit_wait(&self, model: &str, payload: Payload) -> Result<Response> {
        self.entry(model)?.coordinator.submit_wait(payload)
    }

    /// Hot-swap the quantization plan of a running model.
    pub fn swap_plan(&self, model: &str, cfg: &QuantConfig) -> Result<()> {
        let entry = self.entry(model)?;
        match &entry.swap {
            Some(b) => {
                b.swap_plan(cfg)?;
                entry.coordinator.metrics_handle().record_swap();
                Ok(())
            }
            None => bail!(
                "model `{model}` (backend `{}`) does not support plan hot-swap",
                entry.backend_name
            ),
        }
    }

    /// Live metrics of one model.
    pub fn metrics(&self, model: &str) -> Result<MetricsSnapshot> {
        Ok(self.entry(model)?.coordinator.metrics())
    }

    /// Live metrics of every model.
    pub fn metrics_all(&self) -> BTreeMap<String, MetricsSnapshot> {
        let entries = self.entries.read().unwrap();
        entries.iter().map(|(k, e)| (k.clone(), e.coordinator.metrics())).collect()
    }

    /// Drain and stop every model's workers, returning final metrics.
    pub fn shutdown(self) -> BTreeMap<String, MetricsSnapshot> {
        let entries = std::mem::take(&mut *self.entries.write().unwrap());
        let mut out = BTreeMap::new();
        for (name, arc) in entries {
            // `shutdown(self)` takes the registry by value, so no &self
            // method (the only place entry Arcs are cloned, and they
            // never outlive the call) can still be running — the map
            // holds the last reference.
            let entry = Arc::try_unwrap(arc).ok().expect("no live entry references at shutdown");
            out.insert(name, entry.coordinator.shutdown());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::server::EchoBackend;
    use super::*;
    use crate::coordinator::request::Output;

    fn reg_with_echo(names: &[&str]) -> ModelRegistry {
        let reg = ModelRegistry::new();
        for n in names {
            reg.register(n, Arc::new(EchoBackend { delay_us: 0 }), CoordinatorConfig::default())
                .unwrap();
        }
        reg
    }

    #[test]
    fn routes_by_model_name() {
        let reg = reg_with_echo(&["a", "b"]);
        assert_eq!(reg.models(), vec!["a".to_string(), "b".to_string()]);
        let ra = reg.submit_wait("a", Payload::Seq(vec![1])).unwrap();
        let rb = reg.submit_wait("b", Payload::Seq(vec![2])).unwrap();
        assert_eq!(ra.output, Output::Tokens(vec![1]));
        assert_eq!(rb.output, Output::Tokens(vec![2]));
        let snaps = reg.shutdown();
        assert_eq!(snaps["a"].completed, 1);
        assert_eq!(snaps["b"].completed, 1);
    }

    #[test]
    fn unknown_model_lists_registered_names() {
        let reg = reg_with_echo(&["alexnet"]);
        let err = reg.submit_wait("resnet", Payload::Seq(vec![1])).unwrap_err().to_string();
        assert!(err.contains("alexnet"), "err: {err}");
        reg.shutdown();
    }

    #[test]
    fn duplicate_registration_rejected() {
        let reg = reg_with_echo(&["m"]);
        let dup = reg.register(
            "m",
            Arc::new(EchoBackend { delay_us: 0 }),
            CoordinatorConfig::default(),
        );
        assert!(dup.is_err());
        reg.shutdown();
    }

    #[test]
    fn fixed_backend_refuses_swap() {
        let reg = reg_with_echo(&["m"]);
        let cfg = QuantConfig { model: "m".into(), thr_w: 0.04, layers: vec![] };
        let err = reg.swap_plan("m", &cfg).unwrap_err().to_string();
        assert!(err.contains("hot-swap"), "err: {err}");
        assert!(reg.plan_label("m").is_err());
        reg.shutdown();
    }

    #[test]
    fn per_model_metrics_are_isolated() {
        let reg = reg_with_echo(&["a", "b"]);
        for _ in 0..5 {
            reg.submit_wait("a", Payload::Seq(vec![9])).unwrap();
        }
        let all = reg.metrics_all();
        assert_eq!(all["a"].completed, 5);
        assert_eq!(all["b"].completed, 0);
        assert_eq!(reg.metrics("a").unwrap().completed, 5);
        reg.shutdown();
    }
}
