//! Dynamic batcher: group queued requests up to `max_batch`, waiting at
//! most `max_wait` for stragglers once the first request of a batch
//! arrives (the standard serving trade-off between latency and batch
//! efficiency).
//!
//! Invariants (property-tested below):
//! * conservation — every submitted request appears in exactly one batch;
//! * FIFO — batch concatenation preserves submission order;
//! * bound — every batch has `1..=max_batch` requests.

use super::request::Request;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Batch-forming policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pulls requests off the shared queue and forms batches. Multiple
/// workers may share one `Batcher` (the receiver is mutex-guarded; each
/// batch is formed under the lock so interleaving cannot split FIFO
/// order *within* a batch).
pub struct Batcher {
    rx: Mutex<Receiver<Request>>,
    cfg: BatcherConfig,
}

impl Batcher {
    pub fn new(rx: Receiver<Request>, cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Self { rx: Mutex::new(rx), cfg }
    }

    /// Block for the next batch. Returns `None` once the queue is closed
    /// and drained (worker shutdown signal).
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let rx = self.rx.lock().unwrap();
        // Block for the first request.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return None,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + self.cfg.max_wait;
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Payload, Request};
    use crate::tensor::SplitMix64;
    use std::sync::mpsc;
    use std::time::Instant;

    fn mk_request(id: u64) -> (Request, mpsc::Receiver<super::super::request::Response>) {
        let (tx, rx) = mpsc::sync_channel(1);
        let req = Request {
            id,
            payload: Payload::Seq(vec![1, 2]),
            submitted: Instant::now(),
            respond_to: tx,
        };
        (req, rx)
    }

    #[test]
    fn batches_respect_max_batch() {
        let (tx, rx) = mpsc::channel();
        let b =
            Batcher::new(rx, BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(50) });
        let mut keep = Vec::new();
        for i in 0..7 {
            let (r, rx) = mk_request(i);
            keep.push(rx);
            tx.send(r).unwrap();
        }
        drop(tx);
        let mut sizes = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(!batch.is_empty() && batch.len() <= 3);
            sizes.push(batch.len());
        }
        assert_eq!(sizes.iter().sum::<usize>(), 7);
        assert_eq!(sizes[0], 3);
    }

    #[test]
    fn closed_empty_queue_returns_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let b = Batcher::new(rx, BatcherConfig::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn max_wait_flushes_partial_batches() {
        let (tx, rx) = mpsc::channel();
        let b = Batcher::new(
            rx,
            BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(5) },
        );
        let (r, _keep) = mk_request(0);
        tx.send(r).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn property_conservation_and_fifo() {
        // Random request counts / batch configs: every id appears exactly
        // once, in submission order across concatenated batches.
        crate::util::prop::for_all(
            crate::util::prop::PropConfig { cases: 32, seed: 0xBA7C4 },
            |rng: &mut SplitMix64, size| {
                let n = 1 + rng.next_below(8 * size.max(1));
                let max_batch = 1 + rng.next_below(9);
                (n, max_batch)
            },
            |&(n, max_batch)| {
                let (tx, rx) = mpsc::channel();
                let b = Batcher::new(
                    rx,
                    BatcherConfig {
                        max_batch,
                        max_wait: Duration::from_micros(200),
                    },
                );
                let mut keep = Vec::new();
                for i in 0..n {
                    let (r, rx2) = mk_request(i as u64);
                    keep.push(rx2);
                    tx.send(r).map_err(|e| e.to_string())?;
                }
                drop(tx);
                let mut seen = Vec::new();
                while let Some(batch) = b.next_batch() {
                    if batch.is_empty() || batch.len() > max_batch {
                        return Err(format!("bad batch size {}", batch.len()));
                    }
                    seen.extend(batch.iter().map(|r| r.id));
                }
                let want: Vec<u64> = (0..n as u64).collect();
                if seen != want {
                    return Err(format!("order/conservation broken: {seen:?}"));
                }
                Ok(())
            },
        );
    }
}
