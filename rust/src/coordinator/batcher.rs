//! Submission queue + continuous batcher.
//!
//! The queue holds one FIFO lane per [`Priority`] class behind a mutex +
//! condvars; admission under a full queue is explicit policy
//! ([`AdmissionPolicy`]): block the submitter, reject with
//! `ServeError::QueueFull`, or shed the oldest lowest-priority queued
//! request to admit the newcomer.
//!
//! Batch formation is a **slot-refill** API ([`Batcher::fill_slots`]):
//! a worker asks for up to `free` requests — however many of its batch
//! slots just opened — and the batcher fills them from the priority
//! lanes immediately, waiting at most `max_wait` for stragglers once
//! the first request is in hand. Workers therefore refill as their
//! slots free up instead of forming stop-the-world batches on a fixed
//! cadence, and an idle timeout lets pool workers surface to re-check
//! autoscaling decisions. Cancelled or deadline-expired requests are
//! dropped **at slot-fill time**, resolving their tickets with the
//! matching typed error before they ever reach an engine.
//!
//! Invariants (property-tested below):
//! * conservation — every admitted request is either batched exactly
//!   once or resolved with a typed error;
//! * FIFO — within one priority class, batch concatenation preserves
//!   submission order;
//! * bound — every fill returns `1..=free` requests.

use super::metrics::Metrics;
use super::request::{Priority, Request, ServeError};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batch-forming policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// What happens to a submission when the queue is full.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Backpressure: block the submitter until space frees up.
    #[default]
    Block,
    /// Fail fast: the submission returns `ServeError::QueueFull`.
    Reject,
    /// Admit the newcomer by shedding the oldest queued request of the
    /// lowest priority class at or below the newcomer's priority (its
    /// ticket resolves to `QueueFull`). If everything queued outranks
    /// the newcomer, the newcomer is rejected instead.
    ShedOldest,
    /// Energy-budget admission: while the co-simulated rolling power
    /// (see [`crate::energysim::PowerMeter`]) exceeds the configured
    /// envelope, lowest-priority submissions are shed with
    /// `ServeError::QueueFull`; higher classes are admitted normally.
    /// A full queue otherwise behaves like `Block`. Without an
    /// envelope (or an engine that reports energy) it degenerates to
    /// plain `Block`.
    EnergyBudget,
}

impl AdmissionPolicy {
    /// Stable CLI/JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::Reject => "reject",
            AdmissionPolicy::ShedOldest => "shed",
            AdmissionPolicy::EnergyBudget => "energy-budget",
        }
    }

    /// Parse the CLI/JSON name; `Err` carries the unknown input.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "block" => Ok(AdmissionPolicy::Block),
            "reject" => Ok(AdmissionPolicy::Reject),
            "shed" => Ok(AdmissionPolicy::ShedOldest),
            "energy-budget" => Ok(AdmissionPolicy::EnergyBudget),
            other => Err(format!(
                "unknown admission policy `{other}` (block|reject|shed|energy-budget)"
            )),
        }
    }
}

struct QueueState {
    lanes: [VecDeque<Request>; Priority::LANES],
    len: usize,
    closed: bool,
}

impl QueueState {
    fn pop_front(&mut self) -> Option<Request> {
        for lane in self.lanes.iter_mut() {
            if let Some(r) = lane.pop_front() {
                self.len -= 1;
                return Some(r);
            }
        }
        None
    }
}

pub(crate) enum PopResult {
    Item(Request),
    TimedOut,
    Closed,
}

/// Bounded multi-priority submission queue shared by every client
/// handle and worker of one coordinator.
pub(crate) struct SubmissionQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    depth: usize,
    policy: AdmissionPolicy,
    /// Simulated power envelope (W) for [`AdmissionPolicy::EnergyBudget`];
    /// `None` disables budget shedding even under that policy.
    envelope: Option<f64>,
}

impl SubmissionQueue {
    pub fn new(depth: usize, policy: AdmissionPolicy) -> Self {
        assert!(depth >= 1, "queue depth must be >= 1");
        Self {
            state: Mutex::new(QueueState {
                lanes: std::array::from_fn(|_| VecDeque::new()),
                len: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            depth,
            policy,
            envelope: None,
        }
    }

    /// Set the power envelope `EnergyBudget` admission sheds against.
    pub fn with_envelope(mut self, watts: Option<f64>) -> Self {
        self.envelope = watts;
        self
    }

    /// Admit `req` under the queue's policy. On `ShedOldest`, the shed
    /// victim's ticket is resolved (and counted) before this returns.
    /// On `EnergyBudget`, a lowest-priority submission is shed up front
    /// whenever the rolling simulated power exceeds the envelope —
    /// before the queue lock is even taken, so budget shedding can
    /// never interact with the drain path.
    pub fn push(&self, req: Request, metrics: &Metrics) -> Result<(), ServeError> {
        if self.policy == AdmissionPolicy::EnergyBudget {
            if let Some(envelope) = self.envelope {
                if req.priority.lane() == Priority::LANES - 1
                    && metrics.rolling_watts() > envelope
                {
                    metrics.record_energy_shed();
                    return Err(ServeError::QueueFull);
                }
            }
        }
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(ServeError::ShuttingDown);
            }
            if st.len < self.depth {
                st.len += 1;
                st.lanes[req.priority.lane()].push_back(req);
                self.not_empty.notify_one();
                return Ok(());
            }
            match self.policy {
                // Under `EnergyBudget` a full queue backpressures like
                // `Block`: the budget decision already happened above.
                AdmissionPolicy::Block | AdmissionPolicy::EnergyBudget => {
                    // Backpressure is bounded by the request's own
                    // deadline: blocking the submitter past it would
                    // only enqueue a request already doomed to expire.
                    match req.deadline.until() {
                        None => st = self.not_full.wait(st).unwrap(),
                        Some(d) => {
                            let now = Instant::now();
                            if now >= d {
                                return Err(ServeError::DeadlineExceeded);
                            }
                            st = self.not_full.wait_timeout(st, d - now).unwrap().0;
                        }
                    }
                }
                AdmissionPolicy::Reject => return Err(ServeError::QueueFull),
                AdmissionPolicy::ShedOldest => {
                    // Never evict higher-priority work for a lower-
                    // priority newcomer: scan lanes from lowest priority
                    // down to the newcomer's own class.
                    let victim = (req.priority.lane()..Priority::LANES)
                        .rev()
                        .find_map(|lane| st.lanes[lane].pop_front());
                    match victim {
                        Some(v) => {
                            st.len -= 1;
                            metrics.record_shed();
                            if !v.resolve(Err(ServeError::QueueFull)) {
                                metrics.record_dropped_send();
                            }
                            // Loop re-checks: there is room now.
                        }
                        None => return Err(ServeError::QueueFull),
                    }
                }
            }
        }
    }

    /// Block until a request is available; `None` once the queue is
    /// closed **and** drained (worker shutdown signal).
    fn pop(&self) -> Option<Request> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = st.pop_front() {
                self.not_full.notify_one();
                return Some(r);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Like [`Self::pop`] but gives up after `timeout`.
    fn pop_timeout(&self, timeout: Duration) -> PopResult {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = st.pop_front() {
                self.not_full.notify_one();
                return PopResult::Item(r);
            }
            if st.closed {
                return PopResult::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopResult::TimedOut;
            }
            let (guard, res) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if res.timed_out() && st.len == 0 {
                return if st.closed { PopResult::Closed } else { PopResult::TimedOut };
            }
        }
    }

    /// Close the queue: new pushes fail with `ShuttingDown`; queued
    /// requests remain to be drained by the workers.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Instantaneous queued-request count (the autoscaler's load
    /// signal).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether [`Self::close`] has been called (drain in progress).
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

/// Outcome of one [`Batcher::fill_slots`] call.
pub(crate) enum SlotFill {
    /// `1..=free` live requests, ready for an engine step.
    Batch(Vec<Request>),
    /// No request arrived within the idle timeout. Pool workers use
    /// this to surface and re-check whether the autoscaler retired
    /// them; the queue is still open.
    Idle,
    /// The queue is closed and fully drained — worker shutdown signal.
    Closed,
}

/// Fills worker slots from the shared queue. Multiple workers share one
/// `Batcher`; each call pulls an exclusive set of requests (the queue is
/// the synchronization point), and cancelled/expired requests are
/// resolved here — at slot-fill time — instead of running inference.
pub(crate) struct Batcher {
    queue: Arc<SubmissionQueue>,
    metrics: Arc<Metrics>,
    cfg: BatcherConfig,
}

impl Batcher {
    pub fn new(queue: Arc<SubmissionQueue>, metrics: Arc<Metrics>, cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Self { queue, metrics, cfg }
    }

    /// Drop requests that must not reach an engine: cancelled or
    /// deadline-expired ones get their typed error here and now.
    fn still_live(&self, req: Request) -> Option<Request> {
        let verdict = if req.is_cancelled() {
            self.metrics.record_cancelled();
            Some(ServeError::Cancelled)
        } else if req.deadline.expired() {
            self.metrics.record_expired();
            Some(ServeError::DeadlineExceeded)
        } else {
            None
        };
        match verdict {
            Some(err) => {
                if !req.resolve(Err(err)) {
                    self.metrics.record_dropped_send();
                }
                None
            }
            None => Some(req),
        }
    }

    /// Upper bound a worker should request per engine step.
    pub fn max_batch(&self) -> usize {
        self.cfg.max_batch
    }

    /// Fill up to `free` freshly-opened batch slots — the continuous-
    /// batching core. Blocks until the first live request arrives (or
    /// `idle_timeout` elapses, or the queue closes), then gathers
    /// stragglers for at most `max_wait` before handing the slots to
    /// the engine. `idle_timeout: None` waits indefinitely, so the call
    /// can only return `Batch` or `Closed`.
    pub fn fill_slots(&self, free: usize, idle_timeout: Option<Duration>) -> SlotFill {
        assert!(free >= 1, "a worker must have at least one free slot");
        // Phase 1: the first live request. Dead (cancelled/expired)
        // requests are resolved and never occupy a slot.
        let first = loop {
            let popped = match idle_timeout {
                None => match self.queue.pop() {
                    Some(r) => r,
                    None => return SlotFill::Closed,
                },
                Some(t) => match self.queue.pop_timeout(t) {
                    PopResult::Item(r) => r,
                    PopResult::TimedOut => return SlotFill::Idle,
                    PopResult::Closed => return SlotFill::Closed,
                },
            };
            if let Some(r) = self.still_live(popped) {
                break r;
            }
        };
        // Phase 2: straggler gathering, bounded by `max_wait` and the
        // caller's free-slot budget.
        let mut batch = vec![first];
        let deadline = Instant::now() + self.cfg.max_wait;
        while batch.len() < free {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.queue.pop_timeout(deadline - now) {
                PopResult::Item(r) => {
                    if let Some(r) = self.still_live(r) {
                        batch.push(r);
                    }
                }
                PopResult::TimedOut | PopResult::Closed => break,
            }
        }
        SlotFill::Batch(batch)
    }

    /// Block for the next full-width fill. Returns `None` once the
    /// queue is closed and fully drained (worker shutdown signal).
    /// Convenience wrapper over [`Self::fill_slots`] for callers
    /// without an autoscaling pool (tests, fixed single-purpose
    /// workers).
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        match self.fill_slots(self.cfg.max_batch, None) {
            SlotFill::Batch(b) => Some(b),
            SlotFill::Closed => None,
            SlotFill::Idle => unreachable!("no idle timeout was set"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Deadline, Payload, Response};
    use crate::tensor::SplitMix64;
    use std::sync::atomic::AtomicBool;
    use std::sync::mpsc;
    use std::time::Instant;

    type ResultRx = mpsc::Receiver<Result<Response, ServeError>>;

    fn mk_request(id: u64, priority: Priority) -> (Request, ResultRx) {
        let (tx, rx) = mpsc::sync_channel(1);
        let req = Request {
            id,
            payload: Payload::Seq(vec![1, 2]),
            submitted: Instant::now(),
            deadline: Deadline::NONE,
            priority,
            cancelled: Arc::new(AtomicBool::new(false)),
            respond_to: tx,
        };
        (req, rx)
    }

    fn batcher(
        depth: usize,
        policy: AdmissionPolicy,
        cfg: BatcherConfig,
    ) -> (Batcher, Arc<SubmissionQueue>, Arc<Metrics>) {
        let q = Arc::new(SubmissionQueue::new(depth, policy));
        let m = Arc::new(Metrics::new());
        (Batcher::new(Arc::clone(&q), Arc::clone(&m), cfg), q, m)
    }

    #[test]
    fn batches_respect_max_batch() {
        let (b, q, m) = batcher(
            64,
            AdmissionPolicy::Block,
            BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(50) },
        );
        let mut keep = Vec::new();
        for i in 0..7 {
            let (r, rx) = mk_request(i, Priority::Normal);
            keep.push(rx);
            q.push(r, &m).unwrap();
        }
        q.close();
        let mut sizes = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(!batch.is_empty() && batch.len() <= 3);
            sizes.push(batch.len());
        }
        assert_eq!(sizes.iter().sum::<usize>(), 7);
        assert_eq!(sizes[0], 3);
    }

    #[test]
    fn closed_empty_queue_returns_none() {
        let (b, q, _m) = batcher(8, AdmissionPolicy::Block, BatcherConfig::default());
        q.close();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn push_after_close_is_shutting_down() {
        let (_b, q, m) = batcher(8, AdmissionPolicy::Block, BatcherConfig::default());
        q.close();
        let (r, _rx) = mk_request(0, Priority::Normal);
        assert_eq!(q.push(r, &m).unwrap_err(), ServeError::ShuttingDown);
    }

    #[test]
    fn max_wait_flushes_partial_batches() {
        let (b, q, m) = batcher(
            64,
            AdmissionPolicy::Block,
            BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(5) },
        );
        let (r, _keep) = mk_request(0, Priority::Normal);
        q.push(r, &m).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn fill_slots_honors_the_free_slot_budget() {
        let (b, q, m) = batcher(
            64,
            AdmissionPolicy::Block,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, rx) = mk_request(i, Priority::Normal);
            keep.push(rx);
            q.push(r, &m).unwrap();
        }
        // A worker with only 2 free slots takes exactly 2; the rest
        // stay queued for the next refill.
        match b.fill_slots(2, None) {
            SlotFill::Batch(batch) => {
                assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
            }
            _ => panic!("expected a batch"),
        }
        assert_eq!(q.len(), 3);
        match b.fill_slots(8, None) {
            SlotFill::Batch(batch) => {
                assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3, 4]);
            }
            _ => panic!("expected a batch"),
        }
    }

    #[test]
    fn fill_slots_idle_timeout_surfaces_without_a_batch() {
        let (b, q, m) = batcher(8, AdmissionPolicy::Block, BatcherConfig::default());
        let t0 = Instant::now();
        assert!(matches!(b.fill_slots(4, Some(Duration::from_millis(5))), SlotFill::Idle));
        assert!(t0.elapsed() < Duration::from_millis(500));
        // With traffic present the same call returns a batch...
        let (r, _keep) = mk_request(7, Priority::Normal);
        q.push(r, &m).unwrap();
        match b.fill_slots(4, Some(Duration::from_millis(5))) {
            SlotFill::Batch(batch) => assert_eq!(batch[0].id, 7),
            _ => panic!("expected a batch"),
        }
        // ...and a closed drained queue reports Closed, not Idle.
        q.close();
        assert!(matches!(
            b.fill_slots(4, Some(Duration::from_millis(5))),
            SlotFill::Closed
        ));
    }

    #[test]
    fn high_priority_overtakes_queued_normal_traffic() {
        let (b, q, m) = batcher(
            64,
            AdmissionPolicy::Block,
            BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) },
        );
        let mut keep = Vec::new();
        for i in 0..3 {
            let (r, rx) = mk_request(i, Priority::Normal);
            keep.push(rx);
            q.push(r, &m).unwrap();
        }
        let (hi, rx) = mk_request(99, Priority::High);
        keep.push(rx);
        q.push(hi, &m).unwrap();
        let order: Vec<u64> = (0..4).map(|_| b.next_batch().unwrap()[0].id).collect();
        assert_eq!(order, vec![99, 0, 1, 2]);
    }

    #[test]
    fn reject_policy_fails_fast_when_full() {
        let (_b, q, m) = batcher(2, AdmissionPolicy::Reject, BatcherConfig::default());
        let mut keep = Vec::new();
        for i in 0..2 {
            let (r, rx) = mk_request(i, Priority::Normal);
            keep.push(rx);
            q.push(r, &m).unwrap();
        }
        let (r, _rx) = mk_request(2, Priority::Normal);
        assert_eq!(q.push(r, &m).unwrap_err(), ServeError::QueueFull);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn shed_oldest_evicts_lowest_priority_first() {
        let (_b, q, m) = batcher(2, AdmissionPolicy::ShedOldest, BatcherConfig::default());
        let (r0, rx0) = mk_request(0, Priority::Low);
        let (r1, rx1) = mk_request(1, Priority::Normal);
        q.push(r0, &m).unwrap();
        q.push(r1, &m).unwrap();
        // Normal newcomer sheds the Low request, not the Normal one.
        let (r2, _rx2) = mk_request(2, Priority::Normal);
        q.push(r2, &m).unwrap();
        assert_eq!(rx0.recv().unwrap(), Err(ServeError::QueueFull));
        assert!(rx1.try_recv().is_err(), "normal request must survive");
        assert_eq!(m.snapshot().shed, 1);
        // A Low newcomer cannot evict the queued Normal traffic.
        let (r3, _rx3) = mk_request(3, Priority::Low);
        assert_eq!(q.push(r3, &m).unwrap_err(), ServeError::QueueFull);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn cancelled_and_expired_are_dropped_at_batch_formation() {
        let (b, q, m) = batcher(
            16,
            AdmissionPolicy::Block,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        );
        let (live, live_rx) = mk_request(0, Priority::Normal);
        let (cancelled, cancelled_rx) = mk_request(1, Priority::Normal);
        cancelled.cancelled.store(true, std::sync::atomic::Ordering::Release);
        let (mut expired, expired_rx) = mk_request(2, Priority::Normal);
        expired.deadline = Deadline::at(Instant::now() - Duration::from_millis(1));
        q.push(live, &m).unwrap();
        q.push(cancelled, &m).unwrap();
        q.push(expired, &m).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 0);
        assert_eq!(cancelled_rx.recv().unwrap(), Err(ServeError::Cancelled));
        assert_eq!(expired_rx.recv().unwrap(), Err(ServeError::DeadlineExceeded));
        assert!(live_rx.try_recv().is_err(), "live request still pending");
        let snap = m.snapshot();
        assert_eq!((snap.cancelled, snap.expired), (1, 1));
    }

    #[test]
    fn admission_policy_names_round_trip() {
        for policy in [
            AdmissionPolicy::Block,
            AdmissionPolicy::Reject,
            AdmissionPolicy::ShedOldest,
            AdmissionPolicy::EnergyBudget,
        ] {
            assert_eq!(AdmissionPolicy::parse(policy.name()), Ok(policy));
        }
        let err = AdmissionPolicy::parse("bogus").unwrap_err();
        assert!(err.contains("energy-budget"), "{err}");
    }

    #[test]
    fn energy_budget_sheds_low_only_while_over_envelope() {
        let q = Arc::new(
            SubmissionQueue::new(8, AdmissionPolicy::EnergyBudget).with_envelope(Some(1e-15)),
        );
        let m = Arc::new(Metrics::new());
        // Heat the rolling window past the (tiny) envelope.
        m.record_energy(1.0e-6, 1);
        assert!(m.rolling_watts() > 1e-15);
        let (low, _low_rx) = mk_request(0, Priority::Low);
        assert_eq!(q.push(low, &m).unwrap_err(), ServeError::QueueFull);
        // Normal and High are admitted regardless of the budget.
        let (normal, _n_rx) = mk_request(1, Priority::Normal);
        let (high, _h_rx) = mk_request(2, Priority::High);
        q.push(normal, &m).unwrap();
        q.push(high, &m).unwrap();
        assert_eq!(q.len(), 2);
        let snap = m.snapshot();
        assert_eq!(snap.energy_shed, 1);
        assert_eq!(snap.shed, 0, "budget shedding is not ShedOldest eviction");
    }

    #[test]
    fn energy_budget_without_envelope_never_sheds() {
        let q = Arc::new(SubmissionQueue::new(8, AdmissionPolicy::EnergyBudget));
        let m = Arc::new(Metrics::new());
        m.record_energy(1.0, 1); // absurdly hot window
        let (low, _rx) = mk_request(0, Priority::Low);
        q.push(low, &m).unwrap();
        assert_eq!(m.snapshot().energy_shed, 0);
        // Close still wakes everything: drain path unaffected.
        q.close();
        let (late, _rx2) = mk_request(1, Priority::Low);
        assert_eq!(q.push(late, &m).unwrap_err(), ServeError::ShuttingDown);
    }

    #[test]
    fn property_conservation_and_fifo() {
        // Random request counts / batch configs: every id appears exactly
        // once, in submission order across concatenated batches.
        crate::util::prop::for_all(
            crate::util::prop::PropConfig { cases: 32, seed: 0xBA7C4 },
            |rng: &mut SplitMix64, size| {
                let n = 1 + rng.next_below(8 * size.max(1));
                let max_batch = 1 + rng.next_below(9);
                (n, max_batch)
            },
            |&(n, max_batch)| {
                let (b, q, m) = batcher(
                    n.max(1),
                    AdmissionPolicy::Block,
                    BatcherConfig { max_batch, max_wait: Duration::from_micros(200) },
                );
                let mut keep = Vec::new();
                for i in 0..n {
                    let (r, rx2) = mk_request(i as u64, Priority::Normal);
                    keep.push(rx2);
                    q.push(r, &m).map_err(|e| e.to_string())?;
                }
                q.close();
                let mut seen = Vec::new();
                while let Some(batch) = b.next_batch() {
                    if batch.is_empty() || batch.len() > max_batch {
                        return Err(format!("bad batch size {}", batch.len()));
                    }
                    seen.extend(batch.iter().map(|r| r.id));
                }
                let want: Vec<u64> = (0..n as u64).collect();
                if seen != want {
                    return Err(format!("order/conservation broken: {seen:?}"));
                }
                Ok(())
            },
        );
    }
}
