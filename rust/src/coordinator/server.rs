//! The serving coordinator: typed client front door → priority
//! submission queue → continuous batcher → autoscaling worker pool →
//! per-ticket results. Pure std (threads + condvars); the engine is
//! pluggable ([`Engine`]) — rust engine, counting engine, or a PJRT
//! executable.
//!
//! Workers run a **continuous batching** loop: each engine step asks
//! the shared [`Batcher`] to refill exactly the slots that just opened
//! ([`Batcher::fill_slots`]), so freshly-arrived high-priority work is
//! picked up the moment capacity exists instead of waiting for a
//! stop-the-world batch cadence. The pool **autoscales** between
//! `min_workers` and `max_workers`: a supervisor thread samples queue
//! depth, spawns a worker when the backlog exceeds what the active
//! workers can absorb in one step, and retires one after a sustained
//! idle period (the retiring worker exits at its next idle slot-fill).
//!
//! Every failure is a typed [`ServeError`] delivered through the
//! request's [`super::Ticket`]: engines report per-item `Result`s,
//! batch-contract violations (wrong result count) fail the whole batch
//! with `EngineFailure` — in release builds too, not behind a
//! `debug_assert` — and responses whose ticket was abandoned are
//! counted (`dropped_sends`) instead of vanishing.

use super::batcher::{AdmissionPolicy, Batcher, BatcherConfig, SlotFill, SubmissionQueue};
use super::client::{ClientCore, InferenceClient};
use super::engine::Engine;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{Output, Payload, Priority, Request, Response, ServeError};
use crate::loadgen::{LoadReport, Recorder};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long an idle worker waits for traffic before surfacing to
/// re-check whether the autoscaler retired it.
const IDLE_RECHECK: Duration = Duration::from_millis(20);
/// Autoscaler sampling period.
const SCALE_TICK: Duration = Duration::from_millis(5);
/// Consecutive empty-queue autoscaler ticks before one worker is
/// retired (~100ms of sustained idleness at `SCALE_TICK`).
const IDLE_TICKS_TO_SHRINK: u32 = 20;

/// Coordinator configuration.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// Worker-pool floor — the pool starts here and never shrinks
    /// below it.
    pub min_workers: usize,
    /// Worker-pool ceiling. Equal to `min_workers` disables
    /// autoscaling entirely (no supervisor thread is spawned).
    pub max_workers: usize,
    /// Submission queue bound.
    pub queue_depth: usize,
    /// What happens to submissions when the queue is full.
    pub admission: AdmissionPolicy,
    /// Simulated rolling-power envelope (W) that
    /// [`AdmissionPolicy::EnergyBudget`] sheds against. `None` disables
    /// budget shedding; ignored under every other policy.
    pub power_envelope_watts: Option<f64>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            min_workers: 2,
            max_workers: 2,
            queue_depth: 256,
            admission: AdmissionPolicy::Block,
            power_envelope_watts: None,
        }
    }
}

impl CoordinatorConfig {
    /// Fixed-size pool of `n` workers (autoscaling disabled).
    pub fn with_workers(n: usize) -> Self {
        Self { min_workers: n, max_workers: n, ..Self::default() }
    }
}

/// Shared autoscaling state: how many workers should exist (`target`),
/// how many currently do (`active`), and their join handles.
struct Pool {
    target: AtomicUsize,
    active: AtomicUsize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Pool {
    /// Called by an idle worker: retire iff the pool is over target.
    /// The CAS loop guarantees exactly one worker wins each decrement,
    /// so the pool never undershoots the supervisor's target.
    fn try_retire(&self) -> bool {
        let mut active = self.active.load(Ordering::SeqCst);
        loop {
            if active <= self.target.load(Ordering::SeqCst) {
                return false;
            }
            match self.active.compare_exchange(
                active,
                active - 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return true,
                Err(now) => active = now,
            }
        }
    }
}

/// Handle to a running serving instance.
pub struct Coordinator {
    core: Arc<ClientCore>,
    queue: Arc<SubmissionQueue>,
    pool: Arc<Pool>,
    supervisor: Option<JoinHandle<()>>,
}

/// Deliver one resolved request, counting an abandoned ticket.
fn resolve(metrics: &Metrics, req: Request, result: Result<Response, ServeError>) {
    if !req.resolve(result) {
        metrics.record_dropped_send();
    }
}

/// How many output units one response carries — the denominator of the
/// joules-per-output gauge (tokens for sequence models, one class id
/// for classifiers, logit elements for raw heads).
fn output_units(out: &Output) -> u64 {
    match out {
        Output::ClassId(_) => 1,
        Output::Tokens(toks) => toks.len().max(1) as u64,
        Output::Logits(t) => t.len().max(1) as u64,
    }
}

/// Run one engine step over a filled batch and resolve every ticket.
fn process_batch<E: Engine + ?Sized>(engine: &E, metrics: &Metrics, batch: Vec<Request>) {
    metrics.record_batch(batch.len());
    let formed = Instant::now();
    let payloads: Vec<Payload> = batch.iter().map(|r| r.payload.clone()).collect();
    let results = engine.infer_batch(&payloads);
    if results.len() != batch.len() {
        // Batch-contract violation: fail every request of this batch,
        // in release too.
        let why = format!(
            "engine `{}` returned {} results for a batch of {}",
            engine.name(),
            results.len(),
            batch.len()
        );
        metrics.record_engine_failures(batch.len() as u64);
        for req in batch {
            let e = ServeError::EngineFailure(why.clone());
            resolve(metrics, req, Err(e));
        }
        return;
    }
    // Energy co-simulation prices the same payloads the engine just
    // ran; `reports[i]` answers `batch[i]`, like the results do.
    let energy = engine.cosim_energy(&payloads);
    for (i, (req, item)) in batch.into_iter().zip(results).enumerate() {
        let e2e = req.submitted.elapsed().as_secs_f64();
        let queue_s = formed.duration_since(req.submitted).as_secs_f64();
        match item {
            Ok(output) => {
                metrics.record_response(e2e, queue_s);
                let energy_j = energy.as_ref().and_then(|v| v.get(i)).map(|r| {
                    metrics.record_energy(r.joules, output_units(&output));
                    r.joules
                });
                let resp = Response { id: req.id, output, queue_s, e2e_s: e2e, energy_j };
                resolve(metrics, req, Ok(resp));
            }
            Err(infer_err) => {
                metrics.record_engine_failures(1);
                resolve(metrics, req, Err(infer_err.into()));
            }
        }
    }
}

/// Spawn one pool worker running the continuous slot-refill loop.
fn spawn_worker<E: Engine + ?Sized>(
    pool: &Arc<Pool>,
    batcher: &Arc<Batcher>,
    engine: &Arc<E>,
    metrics: &Arc<Metrics>,
) {
    pool.active.fetch_add(1, Ordering::SeqCst);
    let pool2 = Arc::clone(pool);
    let batcher = Arc::clone(batcher);
    let engine = Arc::clone(engine);
    let metrics = Arc::clone(metrics);
    let handle = std::thread::spawn(move || {
        loop {
            // The engine step consumed every slot it was given, so the
            // whole batch width is free again each iteration.
            match batcher.fill_slots(batcher.max_batch(), Some(IDLE_RECHECK)) {
                SlotFill::Closed => break,
                SlotFill::Idle => {
                    if pool2.try_retire() {
                        // `try_retire` already decremented `active`.
                        return;
                    }
                }
                SlotFill::Batch(batch) => process_batch(engine.as_ref(), &metrics, batch),
            }
        }
        pool2.active.fetch_sub(1, Ordering::SeqCst);
    });
    pool.handles.lock().unwrap().push(handle);
}

/// Spawn the autoscaler: sample queue depth every `SCALE_TICK`, grow
/// when the backlog exceeds one step's worth of active capacity,
/// shrink after sustained idleness. Exits when the queue closes.
fn spawn_supervisor<E: Engine + ?Sized>(
    queue: Arc<SubmissionQueue>,
    pool: Arc<Pool>,
    batcher: Arc<Batcher>,
    engine: Arc<E>,
    metrics: Arc<Metrics>,
    min_workers: usize,
    max_workers: usize,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let mut idle_ticks = 0u32;
        loop {
            std::thread::sleep(SCALE_TICK);
            if queue.is_closed() {
                return;
            }
            let depth = queue.len();
            let active = pool.active.load(Ordering::SeqCst);
            if depth > active.saturating_mul(batcher.max_batch()) && active < max_workers {
                // More queued than the pool can absorb in one step:
                // add a worker.
                pool.target.store(active + 1, Ordering::SeqCst);
                spawn_worker(&pool, &batcher, &engine, &metrics);
                metrics.record_scale_up();
                idle_ticks = 0;
            } else if queue.is_empty() {
                let target = pool.target.load(Ordering::SeqCst);
                if target > min_workers {
                    idle_ticks += 1;
                    if idle_ticks >= IDLE_TICKS_TO_SHRINK {
                        // Lower the target; the next idle worker to
                        // surface from `fill_slots` retires itself.
                        pool.target.store(target - 1, Ordering::SeqCst);
                        metrics.record_scale_down();
                        idle_ticks = 0;
                    }
                } else {
                    idle_ticks = 0;
                }
            } else {
                idle_ticks = 0;
            }
        }
    })
}

/// What [`Coordinator::drive`] returns: the legacy per-request mean
/// plus the full latency distribution, computed by the same
/// [`Recorder`] the open-loop load generator uses — one measurement
/// code path for benches, the CI gate, and loadgen.
pub struct DriveReport {
    /// Mean wall time per request (total wall / n).
    pub per_request: Duration,
    /// Full closed-loop latency/goodput report.
    pub load: LoadReport,
}

impl Coordinator {
    /// Start the worker pool over `engine`. The batcher is clamped to
    /// the engine's declared `max_batch` capability; the pool starts at
    /// `min_workers` and autoscales up to `max_workers` by queue depth.
    pub fn start<E: Engine + ?Sized>(engine: Arc<E>, cfg: CoordinatorConfig) -> Self {
        let caps = engine.capabilities();
        let mut batcher_cfg = cfg.batcher;
        if let Some(cap) = caps.max_batch {
            batcher_cfg.max_batch = batcher_cfg.max_batch.min(cap.max(1));
        }
        let min_workers = cfg.min_workers.max(1);
        let max_workers = cfg.max_workers.max(min_workers);
        let queue = Arc::new(
            SubmissionQueue::new(cfg.queue_depth, cfg.admission)
                .with_envelope(cfg.power_envelope_watts),
        );
        let metrics = Arc::new(Metrics::new());
        let batcher =
            Arc::new(Batcher::new(Arc::clone(&queue), Arc::clone(&metrics), batcher_cfg));
        let pool = Arc::new(Pool {
            target: AtomicUsize::new(min_workers),
            active: AtomicUsize::new(0),
            handles: Mutex::new(Vec::new()),
        });
        for _ in 0..min_workers {
            spawn_worker(&pool, &batcher, &engine, &metrics);
        }
        let supervisor = (max_workers > min_workers).then(|| {
            spawn_supervisor(
                Arc::clone(&queue),
                Arc::clone(&pool),
                Arc::clone(&batcher),
                Arc::clone(&engine),
                Arc::clone(&metrics),
                min_workers,
                max_workers,
            )
        });
        let core = Arc::new(ClientCore {
            queue: Arc::clone(&queue),
            metrics,
            caps,
            next_id: AtomicU64::new(0),
            engine_name: engine.name().to_string(),
        });
        Self { core, queue, pool, supervisor }
    }

    /// A cloneable typed client onto this coordinator.
    pub fn client(&self) -> InferenceClient {
        InferenceClient::new(Arc::clone(&self.core))
    }

    /// Submit a request with default options; returns its ticket.
    pub fn submit(&self, payload: Payload) -> Result<super::Ticket, ServeError> {
        self.client().submit(payload)
    }

    /// Submit and block for the result.
    pub fn submit_wait(&self, payload: Payload) -> Result<Response, ServeError> {
        self.client().infer(payload)
    }

    /// Currently running pool workers.
    pub fn active_workers(&self) -> usize {
        self.pool.active.load(Ordering::SeqCst)
    }

    /// Instantaneous submission-queue depth.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Submit `n` requests cycling through `payloads`, then block until
    /// every response arrives. The shared measurement core of the
    /// serving benches and the CI bench gate — latency is recorded by
    /// the same [`Recorder`] the open-loop load generator uses, so both
    /// report through one code path. The first failed request aborts
    /// with its error.
    pub fn drive(&self, payloads: &[Payload], n: usize) -> Result<DriveReport> {
        if payloads.is_empty() || n == 0 {
            anyhow::bail!("drive needs at least one payload and one request");
        }
        let client = self.client();
        let mut recorder = Recorder::new();
        let t0 = Instant::now();
        let mut tickets = Vec::with_capacity(n);
        for i in 0..n {
            tickets.push(client.submit(payloads[i % payloads.len()].clone())?);
        }
        for t in tickets {
            match t.wait() {
                Ok(resp) => recorder.record_ok_energy(
                    Priority::Normal,
                    resp.e2e_s,
                    resp.queue_s,
                    resp.energy_j,
                ),
                Err(e) => {
                    recorder.record_err(Priority::Normal, &e);
                    return Err(e.into());
                }
            }
        }
        let wall = t0.elapsed();
        Ok(DriveReport { per_request: wall / n as u32, load: recorder.report(n, wall) })
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.core.metrics.snapshot()
    }

    /// Shared handle to the live metrics sink, so owners layered above
    /// the coordinator (the model registry) can record their own events
    /// — e.g. plan hot-swaps — into the same per-model stream.
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.core.metrics)
    }

    /// Graceful drain: stop admitting (subsequent submissions fail with
    /// `ShuttingDown`), let the workers finish everything already
    /// queued or in flight, join them, and return the final metrics.
    /// Outstanding tickets all resolve — with a response or a typed
    /// error — before this returns.
    pub fn shutdown_and_drain(mut self) -> MetricsSnapshot {
        self.queue.close();
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.pool.handles.lock().unwrap());
        for w in handles {
            let _ = w.join();
        }
        self.core.metrics.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::EchoEngine;
    use super::super::request::Output;
    use super::*;

    #[test]
    fn serves_and_echoes() {
        let c =
            Coordinator::start(Arc::new(EchoEngine { delay_us: 0 }), CoordinatorConfig::default());
        let resp = c.submit_wait(Payload::Seq(vec![4, 5, 6])).unwrap();
        assert_eq!(resp.output, Output::Tokens(vec![4, 5, 6]));
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn many_concurrent_clients_all_answered() {
        let c = Coordinator::start(
            Arc::new(EchoEngine { delay_us: 50 }),
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: std::time::Duration::from_millis(1),
                },
                min_workers: 3,
                max_workers: 3,
                queue_depth: 64,
                admission: AdmissionPolicy::Block,
                power_envelope_watts: None,
            },
        );
        let mut clients = Vec::new();
        for t in 0..4 {
            let client = c.client();
            clients.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let resp = client.infer(Payload::Seq(vec![t, i])).unwrap();
                    assert_eq!(resp.output, Output::Tokens(vec![t, i]));
                }
            }));
        }
        for cl in clients {
            cl.join().unwrap();
        }
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 100);
        assert!(snap.avg_batch >= 1.0);
        assert!(snap.e2e.p50 > 0.0);
        // Fixed-size pool: the autoscaler never runs.
        assert_eq!((snap.scale_ups, snap.scale_downs), (0, 0));
    }

    #[test]
    fn drive_cycles_payloads_and_answers_all() {
        let c =
            Coordinator::start(Arc::new(EchoEngine { delay_us: 0 }), CoordinatorConfig::default());
        let payloads = vec![Payload::Seq(vec![1]), Payload::Seq(vec![2])];
        let report = c.drive(&payloads, 10).unwrap();
        assert!(report.per_request > std::time::Duration::ZERO);
        // drive measures through the loadgen recorder: the closed-loop
        // report agrees with what the coordinator served.
        assert_eq!(report.load.completed, 10);
        assert_eq!(report.load.offered, 10);
        assert_eq!(report.load.failed, 0);
        assert!(report.load.e2e.p99 > 0.0);
        assert!(c.drive(&[], 4).is_err());
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 10);
    }

    #[test]
    fn drained_coordinator_rejects_new_requests_with_typed_error() {
        let c =
            Coordinator::start(Arc::new(EchoEngine { delay_us: 0 }), CoordinatorConfig::default());
        let client = c.client();
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 0);
        // The client handle survives the drain but every submission now
        // fails with the typed shutdown error.
        let err = client.submit(Payload::Seq(vec![1])).unwrap_err();
        assert_eq!(err, ServeError::ShuttingDown);
    }

    #[test]
    fn batching_actually_groups() {
        // One slow worker + many queued requests → avg batch > 1.
        let c = Coordinator::start(
            Arc::new(EchoEngine { delay_us: 2000 }),
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: Duration::from_millis(4),
                },
                min_workers: 1,
                max_workers: 1,
                queue_depth: 256,
                admission: AdmissionPolicy::Block,
                power_envelope_watts: None,
            },
        );
        let mut tickets = Vec::new();
        for i in 0..64 {
            tickets.push(c.submit(Payload::Seq(vec![i])).unwrap());
        }
        for t in tickets {
            t.wait().unwrap();
        }
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 64);
        assert!(snap.avg_batch > 1.5, "avg batch {}", snap.avg_batch);
    }

    #[test]
    fn engine_max_batch_capability_clamps_the_batcher() {
        struct Cap2;
        impl super::super::engine::InfallibleEngine for Cap2 {
            fn infer(&self, batch: &[Payload]) -> Vec<Output> {
                assert!(batch.len() <= 2, "batch exceeded declared capability");
                std::thread::sleep(Duration::from_micros(500));
                batch.iter().map(|_| Output::ClassId(0)).collect()
            }
            fn accepts(&self) -> super::super::engine::Capabilities {
                super::super::engine::Capabilities::all().with_max_batch(2)
            }
        }
        let c = Coordinator::start(
            Arc::new(super::super::engine::Infallible(Cap2)),
            CoordinatorConfig {
                batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(2) },
                min_workers: 1,
                max_workers: 1,
                queue_depth: 64,
                admission: AdmissionPolicy::Block,
                power_envelope_watts: None,
            },
        );
        let tickets: Vec<_> =
            (0..12).map(|i| c.submit(Payload::Seq(vec![i])).unwrap()).collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 12);
        assert!(snap.avg_batch <= 2.0, "avg batch {}", snap.avg_batch);
    }

    #[test]
    fn pool_scales_up_under_load_and_back_down_when_idle() {
        // One slow worker cannot absorb 160 queued requests, so the
        // supervisor must grow the pool; once the burst drains, the
        // pool must settle back to `min_workers`.
        let c = Coordinator::start(
            Arc::new(EchoEngine { delay_us: 3000 }),
            CoordinatorConfig {
                batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
                min_workers: 1,
                max_workers: 4,
                queue_depth: 512,
                admission: AdmissionPolicy::Block,
                power_envelope_watts: None,
            },
        );
        assert_eq!(c.active_workers(), 1);
        let tickets: Vec<_> =
            (0..160).map(|i| c.submit(Payload::Seq(vec![i])).unwrap()).collect();
        for t in tickets {
            t.wait().unwrap();
        }
        assert!(
            c.metrics().scale_ups >= 1,
            "160 queued requests against one 3ms worker must trigger a scale-up"
        );
        // Idle: the supervisor lowers the target and idle workers
        // retire at their next slot-fill.
        let deadline = Instant::now() + Duration::from_secs(10);
        while c.active_workers() > 1 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(c.active_workers(), 1, "pool must shrink back to min_workers when idle");
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 160);
        assert!(snap.scale_downs >= 1);
        assert_eq!(snap.failed_total(), 0);
    }
}
