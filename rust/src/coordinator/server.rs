//! The serving coordinator: submission queue → dynamic batcher → worker
//! pool → per-request response channels. Pure std (threads + mpsc); the
//! backend is pluggable ([`Backend`]) — rust engine, counting engine, or
//! a PJRT executable.

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{Output, Payload, Request, Response};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

/// Inference backend: maps a batch of payloads to outputs (1:1, in
/// order). Must be cheap to share across worker threads.
pub trait Backend: Send + Sync + 'static {
    fn infer(&self, batch: &[Payload]) -> Vec<Output>;
    fn name(&self) -> &str {
        "backend"
    }
}

/// Coordinator configuration.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
    /// Submission queue bound (backpressure: submit blocks when full).
    pub queue_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { batcher: BatcherConfig::default(), workers: 2, queue_depth: 256 }
    }
}

/// Handle to a running serving instance.
pub struct Coordinator {
    tx: Option<SyncSender<Request>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Start the worker pool over `backend`.
    pub fn start<B: Backend + ?Sized>(backend: Arc<B>, cfg: CoordinatorConfig) -> Self {
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth);
        let batcher = Arc::new(Batcher::new(rx, cfg.batcher));
        let metrics = Arc::new(Metrics::new());
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let batcher = Arc::clone(&batcher);
                let backend = Arc::clone(&backend);
                let metrics = Arc::clone(&metrics);
                std::thread::spawn(move || {
                    while let Some(batch) = batcher.next_batch() {
                        metrics.record_batch(batch.len());
                        let formed = Instant::now();
                        let payloads: Vec<Payload> =
                            batch.iter().map(|r| r.payload.clone()).collect();
                        let outputs = backend.infer(&payloads);
                        debug_assert_eq!(outputs.len(), batch.len());
                        for (req, output) in batch.into_iter().zip(outputs) {
                            let e2e = req.submitted.elapsed().as_secs_f64();
                            let queue = formed.duration_since(req.submitted).as_secs_f64();
                            metrics.record_response(e2e, queue);
                            // A dropped client receiver is not an error.
                            let _ = req.respond_to.send(Response {
                                id: req.id,
                                output,
                                queue_s: queue,
                                e2e_s: e2e,
                            });
                        }
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers, metrics, next_id: AtomicU64::new(0) }
    }

    /// Submit a request; returns the response channel (async-style).
    pub fn submit(&self, payload: Payload) -> Result<Receiver<Response>> {
        let (rtx, rrx) = mpsc::sync_channel(1);
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            payload,
            submitted: Instant::now(),
            respond_to: rtx,
        };
        self.tx
            .as_ref()
            .expect("coordinator running")
            .send(req)
            .map_err(|_| anyhow::anyhow!("coordinator shut down"))?;
        Ok(rrx)
    }

    /// Submit and block for the response.
    pub fn submit_wait(&self, payload: Payload) -> Result<Response> {
        let rx = self.submit(payload)?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker dropped response"))
    }

    /// Submit `n` requests cycling through `payloads`, then block until
    /// every response arrives; returns mean wall time per request. The
    /// shared measurement core of the serving benches and the CI bench
    /// gate (one implementation so the gate measures exactly what the
    /// bench reports).
    pub fn drive(&self, payloads: &[Payload], n: usize) -> Result<std::time::Duration> {
        if payloads.is_empty() || n == 0 {
            anyhow::bail!("drive needs at least one payload and one request");
        }
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            rxs.push(self.submit(payloads[i % payloads.len()].clone())?);
        }
        for rx in rxs {
            rx.recv().map_err(|_| anyhow::anyhow!("worker dropped response"))?;
        }
        Ok(t0.elapsed() / n as u32)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to the live metrics sink, so owners layered above
    /// the coordinator (the model registry) can record their own events
    /// — e.g. plan hot-swaps — into the same per-model stream.
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Drain and stop all workers, returning final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.tx.take(); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

/// Trivial backend used by tests: echoes sequence payloads, classifies
/// images as 0 after a configurable busy-delay.
pub struct EchoBackend {
    pub delay_us: u64,
}

impl Backend for EchoBackend {
    fn infer(&self, batch: &[Payload]) -> Vec<Output> {
        if self.delay_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.delay_us));
        }
        batch
            .iter()
            .map(|p| match p {
                Payload::Seq(s) => Output::Tokens(s.clone()),
                Payload::Image(_) => Output::ClassId(0),
            })
            .collect()
    }

    fn name(&self) -> &str {
        "echo"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_and_echoes() {
        let c =
            Coordinator::start(Arc::new(EchoBackend { delay_us: 0 }), CoordinatorConfig::default());
        let resp = c.submit_wait(Payload::Seq(vec![4, 5, 6])).unwrap();
        assert_eq!(resp.output, Output::Tokens(vec![4, 5, 6]));
        let snap = c.shutdown();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn many_concurrent_clients_all_answered() {
        let c = Arc::new(Coordinator::start(
            Arc::new(EchoBackend { delay_us: 50 }),
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: std::time::Duration::from_millis(1),
                },
                workers: 3,
                queue_depth: 64,
            },
        ));
        let mut clients = Vec::new();
        for t in 0..4 {
            let c = Arc::clone(&c);
            clients.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let resp = c.submit_wait(Payload::Seq(vec![t, i])).unwrap();
                    assert_eq!(resp.output, Output::Tokens(vec![t, i]));
                }
            }));
        }
        for cl in clients {
            cl.join().unwrap();
        }
        let c = Arc::try_unwrap(c).ok().expect("sole owner");
        let snap = c.shutdown();
        assert_eq!(snap.completed, 100);
        assert!(snap.avg_batch >= 1.0);
        assert!(snap.e2e.p50 > 0.0);
    }

    #[test]
    fn drive_cycles_payloads_and_answers_all() {
        let c =
            Coordinator::start(Arc::new(EchoBackend { delay_us: 0 }), CoordinatorConfig::default());
        let payloads = vec![Payload::Seq(vec![1]), Payload::Seq(vec![2])];
        let per = c.drive(&payloads, 10).unwrap();
        assert!(per > std::time::Duration::ZERO);
        assert!(c.drive(&[], 4).is_err());
        let snap = c.shutdown();
        assert_eq!(snap.completed, 10);
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let c =
            Coordinator::start(Arc::new(EchoBackend { delay_us: 0 }), CoordinatorConfig::default());
        let snap = c.shutdown();
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn batching_actually_groups() {
        // One slow worker + many queued requests → avg batch > 1.
        let c = Arc::new(Coordinator::start(
            Arc::new(EchoBackend { delay_us: 2000 }),
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(4),
                },
                workers: 1,
                queue_depth: 256,
            },
        ));
        let mut rxs = Vec::new();
        for i in 0..64 {
            rxs.push(c.submit(Payload::Seq(vec![i])).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let c = Arc::try_unwrap(c).ok().expect("sole owner");
        let snap = c.shutdown();
        assert_eq!(snap.completed, 64);
        assert!(snap.avg_batch > 1.5, "avg batch {}", snap.avg_batch);
    }
}
