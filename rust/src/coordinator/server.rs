//! The serving coordinator: typed client front door → priority
//! submission queue → dynamic batcher → worker pool → per-ticket
//! results. Pure std (threads + condvars); the engine is pluggable
//! ([`Engine`]) — rust engine, counting engine, or a PJRT executable.
//!
//! Every failure is a typed [`ServeError`] delivered through the
//! request's [`super::Ticket`]: engines report per-item `Result`s,
//! batch-contract violations (wrong result count) fail the whole batch
//! with `EngineFailure` — in release builds too, not behind a
//! `debug_assert` — and responses whose ticket was abandoned are
//! counted (`dropped_sends`) instead of vanishing.

use super::batcher::{AdmissionPolicy, Batcher, BatcherConfig, SubmissionQueue};
use super::client::{ClientCore, InferenceClient};
use super::engine::Engine;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{Payload, Request, Response, ServeError};
use anyhow::Result;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
    /// Submission queue bound.
    pub queue_depth: usize,
    /// What happens to submissions when the queue is full.
    pub admission: AdmissionPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            workers: 2,
            queue_depth: 256,
            admission: AdmissionPolicy::Block,
        }
    }
}

/// Handle to a running serving instance.
pub struct Coordinator {
    core: Arc<ClientCore>,
    queue: Arc<SubmissionQueue>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Deliver one resolved request, counting an abandoned ticket.
fn resolve(metrics: &Metrics, req: Request, result: Result<Response, ServeError>) {
    if !req.resolve(result) {
        metrics.record_dropped_send();
    }
}

impl Coordinator {
    /// Start the worker pool over `engine`. The batcher is clamped to
    /// the engine's declared `max_batch` capability.
    pub fn start<E: Engine + ?Sized>(engine: Arc<E>, cfg: CoordinatorConfig) -> Self {
        let caps = engine.capabilities();
        let mut batcher_cfg = cfg.batcher;
        if let Some(cap) = caps.max_batch {
            batcher_cfg.max_batch = batcher_cfg.max_batch.min(cap.max(1));
        }
        let queue = Arc::new(SubmissionQueue::new(cfg.queue_depth, cfg.admission));
        let metrics = Arc::new(Metrics::new());
        let batcher =
            Arc::new(Batcher::new(Arc::clone(&queue), Arc::clone(&metrics), batcher_cfg));
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let batcher = Arc::clone(&batcher);
                let engine = Arc::clone(&engine);
                let metrics = Arc::clone(&metrics);
                std::thread::spawn(move || {
                    while let Some(batch) = batcher.next_batch() {
                        metrics.record_batch(batch.len());
                        let formed = Instant::now();
                        let payloads: Vec<Payload> =
                            batch.iter().map(|r| r.payload.clone()).collect();
                        let results = engine.infer_batch(&payloads);
                        if results.len() != batch.len() {
                            // Batch-contract violation: fail every
                            // request of this batch, in release too.
                            let why = format!(
                                "engine `{}` returned {} results for a batch of {}",
                                engine.name(),
                                results.len(),
                                batch.len()
                            );
                            metrics.record_engine_failures(batch.len() as u64);
                            for req in batch {
                                let e = ServeError::EngineFailure(why.clone());
                                resolve(&metrics, req, Err(e));
                            }
                            continue;
                        }
                        for (req, item) in batch.into_iter().zip(results) {
                            let e2e = req.submitted.elapsed().as_secs_f64();
                            let queue_s = formed.duration_since(req.submitted).as_secs_f64();
                            match item {
                                Ok(output) => {
                                    metrics.record_response(e2e, queue_s);
                                    let resp = Response {
                                        id: req.id,
                                        output,
                                        queue_s,
                                        e2e_s: e2e,
                                    };
                                    resolve(&metrics, req, Ok(resp));
                                }
                                Err(infer_err) => {
                                    metrics.record_engine_failures(1);
                                    resolve(&metrics, req, Err(infer_err.into()));
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        let core = Arc::new(ClientCore {
            queue: Arc::clone(&queue),
            metrics,
            caps,
            next_id: AtomicU64::new(0),
            engine_name: engine.name().to_string(),
        });
        Self { core, queue, workers }
    }

    /// A cloneable typed client onto this coordinator.
    pub fn client(&self) -> InferenceClient {
        InferenceClient::new(Arc::clone(&self.core))
    }

    /// Submit a request with default options; returns its ticket.
    pub fn submit(&self, payload: Payload) -> Result<super::Ticket, ServeError> {
        self.client().submit(payload)
    }

    /// Submit and block for the result.
    pub fn submit_wait(&self, payload: Payload) -> Result<Response, ServeError> {
        self.client().infer(payload)
    }

    /// Submit `n` requests cycling through `payloads`, then block until
    /// every response arrives; returns mean wall time per request. The
    /// shared measurement core of the serving benches and the CI bench
    /// gate (one implementation so the gate measures exactly what the
    /// bench reports).
    pub fn drive(&self, payloads: &[Payload], n: usize) -> Result<std::time::Duration> {
        if payloads.is_empty() || n == 0 {
            anyhow::bail!("drive needs at least one payload and one request");
        }
        let client = self.client();
        let t0 = Instant::now();
        let mut tickets = Vec::with_capacity(n);
        for i in 0..n {
            tickets.push(client.submit(payloads[i % payloads.len()].clone())?);
        }
        for t in tickets {
            t.wait()?;
        }
        Ok(t0.elapsed() / n as u32)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.core.metrics.snapshot()
    }

    /// Shared handle to the live metrics sink, so owners layered above
    /// the coordinator (the model registry) can record their own events
    /// — e.g. plan hot-swaps — into the same per-model stream.
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.core.metrics)
    }

    /// Graceful drain: stop admitting (subsequent submissions fail with
    /// `ShuttingDown`), let the workers finish everything already
    /// queued or in flight, join them, and return the final metrics.
    /// Outstanding tickets all resolve — with a response or a typed
    /// error — before this returns.
    pub fn shutdown_and_drain(mut self) -> MetricsSnapshot {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.core.metrics.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::EchoEngine;
    use super::super::request::Output;
    use super::*;
    use std::time::Duration;

    #[test]
    fn serves_and_echoes() {
        let c =
            Coordinator::start(Arc::new(EchoEngine { delay_us: 0 }), CoordinatorConfig::default());
        let resp = c.submit_wait(Payload::Seq(vec![4, 5, 6])).unwrap();
        assert_eq!(resp.output, Output::Tokens(vec![4, 5, 6]));
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn many_concurrent_clients_all_answered() {
        let c = Coordinator::start(
            Arc::new(EchoEngine { delay_us: 50 }),
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: std::time::Duration::from_millis(1),
                },
                workers: 3,
                queue_depth: 64,
                admission: AdmissionPolicy::Block,
            },
        );
        let mut clients = Vec::new();
        for t in 0..4 {
            let client = c.client();
            clients.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let resp = client.infer(Payload::Seq(vec![t, i])).unwrap();
                    assert_eq!(resp.output, Output::Tokens(vec![t, i]));
                }
            }));
        }
        for cl in clients {
            cl.join().unwrap();
        }
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 100);
        assert!(snap.avg_batch >= 1.0);
        assert!(snap.e2e.p50 > 0.0);
    }

    #[test]
    fn drive_cycles_payloads_and_answers_all() {
        let c =
            Coordinator::start(Arc::new(EchoEngine { delay_us: 0 }), CoordinatorConfig::default());
        let payloads = vec![Payload::Seq(vec![1]), Payload::Seq(vec![2])];
        let per = c.drive(&payloads, 10).unwrap();
        assert!(per > std::time::Duration::ZERO);
        assert!(c.drive(&[], 4).is_err());
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 10);
    }

    #[test]
    fn drained_coordinator_rejects_new_requests_with_typed_error() {
        let c =
            Coordinator::start(Arc::new(EchoEngine { delay_us: 0 }), CoordinatorConfig::default());
        let client = c.client();
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 0);
        // The client handle survives the drain but every submission now
        // fails with the typed shutdown error.
        let err = client.submit(Payload::Seq(vec![1])).unwrap_err();
        assert_eq!(err, ServeError::ShuttingDown);
    }

    #[test]
    fn batching_actually_groups() {
        // One slow worker + many queued requests → avg batch > 1.
        let c = Coordinator::start(
            Arc::new(EchoEngine { delay_us: 2000 }),
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: Duration::from_millis(4),
                },
                workers: 1,
                queue_depth: 256,
                admission: AdmissionPolicy::Block,
            },
        );
        let mut tickets = Vec::new();
        for i in 0..64 {
            tickets.push(c.submit(Payload::Seq(vec![i])).unwrap());
        }
        for t in tickets {
            t.wait().unwrap();
        }
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 64);
        assert!(snap.avg_batch > 1.5, "avg batch {}", snap.avg_batch);
    }

    #[test]
    fn engine_max_batch_capability_clamps_the_batcher() {
        struct Cap2;
        impl super::super::engine::InfallibleEngine for Cap2 {
            fn infer(&self, batch: &[Payload]) -> Vec<Output> {
                assert!(batch.len() <= 2, "batch exceeded declared capability");
                std::thread::sleep(Duration::from_micros(500));
                batch.iter().map(|_| Output::ClassId(0)).collect()
            }
            fn accepts(&self) -> super::super::engine::Capabilities {
                super::super::engine::Capabilities::all().with_max_batch(2)
            }
        }
        let c = Coordinator::start(
            Arc::new(super::super::engine::Infallible(Cap2)),
            CoordinatorConfig {
                batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(2) },
                workers: 1,
                queue_depth: 64,
                admission: AdmissionPolicy::Block,
            },
        );
        let tickets: Vec<_> =
            (0..12).map(|i| c.submit(Payload::Seq(vec![i])).unwrap()).collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.completed, 12);
        assert!(snap.avg_batch <= 2.0, "avg batch {}", snap.avg_batch);
    }
}
