//! The fallible inference engine contract.
//!
//! [`Engine`] replaces the old infallible `Backend`: `infer_batch`
//! returns one `Result` **per item**, so a single bad payload or a
//! per-item engine fault fails that request with a typed error instead
//! of poisoning the batch (or panicking mid-batch), and
//! [`Engine::capabilities`] declares up front what payloads the engine
//! accepts so the client can reject mismatches at submission.
//!
//! [`InfallibleEngine`] + [`Infallible`] are the migration adapter:
//! anything written against the legacy infallible shape
//! (`&[Payload] -> Vec<Output>`) keeps compiling and serves through
//! the blanket `Engine` impl on the [`Infallible`] wrapper, which
//! wraps every output in `Ok`.

use super::request::{InferError, Output, Payload, ServeError};

/// What an engine accepts, declared once at registration so payloads
/// are validated at submission instead of panicking mid-batch.
#[derive(Clone, Copy, Debug)]
pub struct Capabilities {
    /// Accepts `Payload::Image` (shape `[3, 32, 32]`).
    pub images: bool,
    /// Accepts `Payload::Seq` (non-empty token sequences).
    pub seqs: bool,
    /// Exclusive upper bound on sequence token ids (`None` = any id).
    pub vocab: Option<usize>,
    /// Largest batch one `infer_batch` call can take (`None` = any);
    /// the coordinator clamps its batcher to this.
    pub max_batch: Option<usize>,
}

/// The image shape every classifier engine expects.
pub const IMAGE_SHAPE: [usize; 3] = [3, 32, 32];

impl Capabilities {
    /// Accepts every payload kind (echo/test engines).
    pub fn all() -> Self {
        Self { images: true, seqs: true, vocab: None, max_batch: None }
    }

    /// Image classifier: `[3, 32, 32]` images only.
    pub fn images_only() -> Self {
        Self { images: true, seqs: false, vocab: None, max_batch: None }
    }

    /// Sequence model with token ids in `[0, vocab)`.
    pub fn seqs_only(vocab: usize) -> Self {
        Self { images: false, seqs: true, vocab: Some(vocab), max_batch: None }
    }

    pub fn with_max_batch(mut self, n: usize) -> Self {
        self.max_batch = Some(n);
        self
    }

    /// Validate one payload against these capabilities — the submission
    /// gate behind [`ServeError::WrongPayload`].
    pub fn admit(&self, payload: &Payload) -> Result<(), ServeError> {
        match payload {
            Payload::Image(img) => {
                if !self.images {
                    return Err(ServeError::WrongPayload(
                        "engine does not accept image payloads".into(),
                    ));
                }
                if img.shape() != &IMAGE_SHAPE[..] {
                    return Err(ServeError::WrongPayload(format!(
                        "image must have shape {IMAGE_SHAPE:?}, got {:?}",
                        img.shape()
                    )));
                }
            }
            Payload::Seq(toks) => {
                if !self.seqs {
                    return Err(ServeError::WrongPayload(
                        "engine does not accept sequence payloads".into(),
                    ));
                }
                if toks.is_empty() {
                    return Err(ServeError::WrongPayload(
                        "token sequence must be non-empty".into(),
                    ));
                }
                if let Some(vocab) = self.vocab {
                    if let Some(&bad) = toks.iter().find(|&&t| t >= vocab) {
                        return Err(ServeError::WrongPayload(format!(
                            "token id {bad} outside vocab 0..{vocab}"
                        )));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Inference engine: maps a batch of payloads to **per-item results**
/// (1:1, in order). Must be cheap to share across worker threads.
pub trait Engine: Send + Sync + 'static {
    /// Run one batch; `results[i]` answers `batch[i]`. Returning a
    /// different length is a contract violation the coordinator turns
    /// into `EngineFailure` for every request of the batch.
    fn infer_batch(&self, batch: &[Payload]) -> Vec<Result<Output, InferError>>;

    /// What this engine accepts; checked at submission.
    fn capabilities(&self) -> Capabilities;

    fn name(&self) -> &str {
        "engine"
    }

    /// Per-item co-simulated energy for `batch`, parallel to the
    /// results of [`Engine::infer_batch`] (`reports[i]` prices
    /// `batch[i]`). `None` — the default — means this engine does no
    /// energy accounting; [`crate::energysim::CoSimEngine`] overrides
    /// it, and the coordinator threads the joules into metrics and
    /// responses whenever a batch reports them.
    fn cosim_energy(&self, batch: &[Payload]) -> Option<Vec<crate::energysim::EnergyReport>> {
        let _ = batch;
        None
    }
}

/// Legacy infallible engine shape, kept as a migration adapter: a type
/// that can only produce outputs (never per-item errors) implements
/// this and serves by wrapping itself in [`Infallible`].
pub trait InfallibleEngine: Send + Sync + 'static {
    fn infer(&self, batch: &[Payload]) -> Vec<Output>;

    fn accepts(&self) -> Capabilities {
        Capabilities::all()
    }

    fn name(&self) -> &str {
        "engine"
    }
}

/// Blanket adapter from the legacy infallible shape to [`Engine`]:
/// `Infallible(legacy_backend)` serves through any coordinator, with
/// every output wrapped in `Ok`. (A wrapper rather than a direct
/// blanket impl so concrete engines can still implement [`Engine`]
/// themselves without coherence conflicts.)
pub struct Infallible<B>(pub B);

impl<B: InfallibleEngine> Engine for Infallible<B> {
    fn infer_batch(&self, batch: &[Payload]) -> Vec<Result<Output, InferError>> {
        self.0.infer(batch).into_iter().map(Ok).collect()
    }

    fn capabilities(&self) -> Capabilities {
        self.0.accepts()
    }

    fn name(&self) -> &str {
        self.0.name()
    }
}

/// Trivial engine used by tests: echoes sequence payloads, classifies
/// images as 0 after a configurable busy-delay.
pub struct EchoEngine {
    pub delay_us: u64,
}

impl Engine for EchoEngine {
    fn infer_batch(&self, batch: &[Payload]) -> Vec<Result<Output, InferError>> {
        if self.delay_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.delay_us));
        }
        batch
            .iter()
            .map(|p| match p {
                Payload::Seq(s) => Ok(Output::Tokens(s.clone())),
                Payload::Image(_) => Ok(Output::ClassId(0)),
            })
            .collect()
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::all()
    }

    fn name(&self) -> &str {
        "echo"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn capabilities_reject_wrong_kind_and_shape() {
        let caps = Capabilities::images_only();
        assert!(caps.admit(&Payload::Image(Tensor::zeros(&[3, 32, 32]))).is_ok());
        let bad_shape = caps.admit(&Payload::Image(Tensor::zeros(&[1, 32, 32])));
        assert!(matches!(bad_shape, Err(ServeError::WrongPayload(_))), "{bad_shape:?}");
        let seq = caps.admit(&Payload::Seq(vec![1, 2]));
        assert!(matches!(seq, Err(ServeError::WrongPayload(_))));
    }

    #[test]
    fn capabilities_validate_sequences() {
        let caps = Capabilities::seqs_only(32);
        assert!(caps.admit(&Payload::Seq(vec![0, 31])).is_ok());
        let empty = caps.admit(&Payload::Seq(vec![]));
        assert!(matches!(empty, Err(ServeError::WrongPayload(ref w)) if w.contains("non-empty")));
        let oov = caps.admit(&Payload::Seq(vec![3, 32]));
        assert!(matches!(oov, Err(ServeError::WrongPayload(ref w)) if w.contains("32")));
        let img = caps.admit(&Payload::Image(Tensor::zeros(&[3, 32, 32])));
        assert!(matches!(img, Err(ServeError::WrongPayload(_))));
    }

    #[test]
    fn blanket_adapter_wraps_every_output_in_ok() {
        struct Legacy;
        impl InfallibleEngine for Legacy {
            fn infer(&self, batch: &[Payload]) -> Vec<Output> {
                batch.iter().map(|_| Output::ClassId(7)).collect()
            }
            fn name(&self) -> &str {
                "legacy"
            }
        }
        let adapted = Infallible(Legacy);
        let results = adapted.infer_batch(&[Payload::Seq(vec![7])]);
        assert_eq!(results, vec![Ok(Output::ClassId(7))]);
        assert_eq!(Engine::name(&adapted), "legacy");
        let caps = adapted.capabilities();
        assert!(caps.images && caps.seqs);
    }
}
