//! Serving coordinator (L3): a typed, fallible serving API.
//!
//! The front door is [`InferenceClient`]: `submit` validates the
//! payload against the engine's declared [`Capabilities`], applies the
//! queue's [`AdmissionPolicy`], and returns a [`Ticket`] supporting
//! `wait()`, `wait_timeout()`, and `cancel()`, with a per-request
//! [`Deadline`] and [`Priority`]. Behind it: priority submission queue
//! → **continuous batcher** (workers refill exactly the batch slots
//! that just opened via `fill_slots`, instead of forming batches
//! stop-the-world; cancelled and deadline-expired requests are dropped
//! **at slot-fill time**, never run) → **autoscaling worker pool**
//! (min/max workers, grown and shrunk by observed queue depth) over a
//! pluggable fallible [`Engine`] (rust engine, exponential counting
//! engine, or a PJRT-compiled AOT artifact), with per-request latency
//! metrics (p50/p95/p99/p999) and typed failure counters.
//!
//! **Error taxonomy** ([`ServeError`]): every way a request can fail is
//! a typed, observable outcome —
//! * `QueueFull` — refused at admission (`Reject`) or shed from a full
//!   queue (`ShedOldest`);
//! * `Cancelled` — the ticket was cancelled before inference;
//! * `DeadlineExceeded` — the deadline expired at submit or in queue;
//! * `WrongPayload` — payload failed validation against the engine's
//!   capabilities (kind, image shape, empty/out-of-vocab sequence);
//! * `EngineFailure` — the engine failed that item, or broke its batch
//!   contract (wrong result count fails the whole batch, in release
//!   builds too);
//! * `ShuttingDown` — submission after `shutdown_and_drain` began.
//!
//! **Admission policies** ([`AdmissionPolicy`]): a full queue either
//! blocks the submitter (`Block`, backpressure), fails fast
//! (`Reject`), or sheds the oldest lowest-priority queued request to
//! admit the newcomer (`ShedOldest`). All drops are counted in
//! [`Metrics`] (cancelled / expired / rejected / shed / engine
//! failures / dropped sends).
//!
//! The [`registry::ModelRegistry`] layers multi-model serving on top:
//! N named models, each with its own batcher/worker pool and metrics,
//! routed by model name through the **same client type**, with atomic
//! quantization-plan hot-swap for engines that support it. Both the
//! coordinator and the registry drain gracefully via
//! `shutdown_and_drain()` — every outstanding ticket resolves before it
//! returns.

pub mod backends;
pub mod batcher;
pub mod client;
pub mod engine;
pub mod metrics;
pub mod registry;
pub mod request;
pub mod server;

pub use backends::{
    AlexNetBackend, ClassifierBackend, CountingFcBackend, PjrtClassifierBackend, ResNetBackend,
    TranslatorBackend,
};
pub use batcher::{AdmissionPolicy, BatcherConfig};
pub use client::{InferenceClient, Ticket};
pub use engine::{Capabilities, EchoEngine, Engine, Infallible, InfallibleEngine};
pub use metrics::{Metrics, MetricsSnapshot, Percentiles};
pub use registry::{ModelRegistry, SwappableEngine};
pub use request::{
    Deadline, InferError, Output, Payload, Priority, Response, ServeError, SubmitOptions,
};
pub use server::{Coordinator, CoordinatorConfig, DriveReport};
