//! Serving coordinator (L3): submission queue → dynamic batcher → worker
//! pool over a pluggable inference [`server::Backend`] (rust engine,
//! exponential counting engine, or a PJRT-compiled AOT artifact), with
//! per-request latency metrics and bounded-queue backpressure.
//!
//! The [`registry::ModelRegistry`] layers multi-model serving on top:
//! N named models, each with its own batcher/worker pool and metrics,
//! routed by model name, with atomic quantization-plan hot-swap for
//! backends that support it.

pub mod backends;
pub mod batcher;
pub mod metrics;
pub mod registry;
pub mod request;
pub mod server;

pub use backends::{
    AlexNetBackend, ClassifierBackend, CountingFcBackend, PjrtClassifierBackend, ResNetBackend,
    TranslatorBackend,
};
pub use batcher::{Batcher, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot, Percentiles};
pub use registry::{ModelRegistry, SwappableBackend};
pub use request::{Output, Payload, Request, Response};
pub use server::{Backend, Coordinator, CoordinatorConfig, EchoBackend};
