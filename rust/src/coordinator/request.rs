//! Request/response types of the serving layer.

use crate::tensor::Tensor;
use std::sync::mpsc::SyncSender;
use std::time::Instant;

/// What a client submits.
#[derive(Clone, Debug)]
pub enum Payload {
    /// One `[3, 32, 32]` image for the CNN classifiers.
    Image(Tensor),
    /// A source token sequence for the translator.
    Seq(Vec<usize>),
}

/// What the backend produces.
#[derive(Clone, Debug, PartialEq)]
pub enum Output {
    ClassId(usize),
    Logits(Tensor),
    Tokens(Vec<usize>),
}

/// Internal queued request.
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    pub submitted: Instant,
    pub respond_to: SyncSender<Response>,
}

/// Completed response with timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub output: Output,
    /// Time spent queued before the batch formed (seconds).
    pub queue_s: f64,
    /// End-to-end latency (seconds).
    pub e2e_s: f64,
}
