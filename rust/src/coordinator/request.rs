//! Request/response types of the serving layer: payloads, outputs, the
//! typed error taxonomy ([`ServeError`] at the client boundary,
//! [`InferError`] per item inside an engine), per-request [`Deadline`]s
//! and [`Priority`] classes, and the internal queued [`Request`].

use crate::tensor::Tensor;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What a client submits.
#[derive(Clone, Debug)]
pub enum Payload {
    /// One `[3, 32, 32]` image for the CNN classifiers.
    Image(Tensor),
    /// A source token sequence for the translator.
    Seq(Vec<usize>),
}

impl Payload {
    /// Short label for error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Image(_) => "image",
            Payload::Seq(_) => "sequence",
        }
    }
}

/// What an engine produces per accepted item.
#[derive(Clone, Debug, PartialEq)]
pub enum Output {
    ClassId(usize),
    Logits(Tensor),
    Tokens(Vec<usize>),
}

/// Scheduling class of a request. Within one class the queue is strict
/// FIFO; across classes, batch formation always drains higher priority
/// first.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    /// Queue-lane index: 0 is served first.
    pub(crate) fn lane(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
    pub(crate) const LANES: usize = 3;
}

/// Absolute completion deadline of a request. Expired requests are
/// dropped at batch-formation time (they never reach the engine) and
/// their tickets resolve to [`ServeError::DeadlineExceeded`]; a deadline
/// already expired at submission is rejected synchronously.
#[derive(Clone, Copy, Debug, Default)]
pub struct Deadline(Option<Instant>);

impl Deadline {
    /// No deadline: the request waits as long as it has to.
    pub const NONE: Deadline = Deadline(None);

    /// Deadline `d` from now.
    pub fn within(d: Duration) -> Self {
        Deadline(Some(Instant::now() + d))
    }

    /// Deadline at an absolute instant.
    pub fn at(t: Instant) -> Self {
        Deadline(Some(t))
    }

    pub fn expired(&self) -> bool {
        matches!(self.0, Some(t) if Instant::now() >= t)
    }

    /// The absolute instant, if any (bounds how long admission may
    /// block the submitter).
    pub(crate) fn until(&self) -> Option<Instant> {
        self.0
    }
}

/// How a client's submission options reach the queue.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOptions {
    pub deadline: Deadline,
    pub priority: Priority,
}

impl SubmitOptions {
    pub fn with_deadline(mut self, d: Deadline) -> Self {
        self.deadline = d;
        self
    }

    pub fn with_priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }
}

/// Why serving a request failed — the typed error every client-facing
/// call returns instead of silent drops or sentinel outputs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The submission queue was full (policy `Reject`), or the request
    /// was shed from a full queue to admit newer work (`ShedOldest`).
    QueueFull,
    /// The ticket was cancelled before the request reached an engine.
    Cancelled,
    /// The deadline expired before the request reached an engine (or
    /// was already expired at submission).
    DeadlineExceeded,
    /// The payload failed validation against the engine's capabilities.
    WrongPayload(String),
    /// The engine failed on this item (or broke its batch contract).
    EngineFailure(String),
    /// The coordinator is draining or has shut down.
    ShuttingDown,
}

impl ServeError {
    /// Stable snake_case key for this failure class — the aggregation
    /// key used by the loadgen recorder and emitted JSON reports.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::QueueFull => "queue_full",
            ServeError::Cancelled => "cancelled",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::WrongPayload(_) => "wrong_payload",
            ServeError::EngineFailure(_) => "engine_failure",
            ServeError::ShuttingDown => "shutting_down",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull => write!(f, "submission queue full"),
            ServeError::Cancelled => write!(f, "request cancelled"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ServeError::WrongPayload(why) => write!(f, "wrong payload: {why}"),
            ServeError::EngineFailure(why) => write!(f, "engine failure: {why}"),
            ServeError::ShuttingDown => write!(f, "coordinator shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-item failure reported by an [`super::Engine`]. The worker maps it
/// into the client-facing [`ServeError`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InferError {
    /// The engine cannot process this payload kind/shape.
    Unsupported(String),
    /// The engine tried and failed.
    Failed(String),
}

impl InferError {
    pub fn unsupported(why: impl Into<String>) -> Self {
        InferError::Unsupported(why.into())
    }

    pub fn failed(why: impl Into<String>) -> Self {
        InferError::Failed(why.into())
    }
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::Unsupported(why) => write!(f, "unsupported payload: {why}"),
            InferError::Failed(why) => write!(f, "inference failed: {why}"),
        }
    }
}

impl std::error::Error for InferError {}

impl From<InferError> for ServeError {
    fn from(e: InferError) -> Self {
        match e {
            InferError::Unsupported(why) => ServeError::WrongPayload(why),
            InferError::Failed(why) => ServeError::EngineFailure(why),
        }
    }
}

/// Internal queued request (crate-private: clients hold a
/// [`super::Ticket`], never the raw request).
pub(crate) struct Request {
    pub id: u64,
    pub payload: Payload,
    pub submitted: Instant,
    pub deadline: Deadline,
    pub priority: Priority,
    pub cancelled: Arc<AtomicBool>,
    pub respond_to: SyncSender<Result<Response, ServeError>>,
}

impl Request {
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// Resolve the ticket with `result`; returns `false` when the
    /// receiver was dropped (an abandoned ticket — callers count it).
    pub fn resolve(self, result: Result<Response, ServeError>) -> bool {
        self.respond_to.send(result).is_ok()
    }
}

/// Completed response with timing.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub id: u64,
    pub output: Output,
    /// Time spent queued before the batch formed (seconds).
    pub queue_s: f64,
    /// End-to-end latency (seconds).
    pub e2e_s: f64,
    /// Co-simulated energy spent serving this request (joules). `None`
    /// when the engine does no energy accounting (see
    /// [`super::Engine::cosim_energy`]).
    pub energy_j: Option<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_none_never_expires() {
        assert!(!Deadline::NONE.expired());
        assert!(!Deadline::within(Duration::from_secs(60)).expired());
    }

    #[test]
    fn deadline_in_the_past_is_expired() {
        assert!(Deadline::at(Instant::now() - Duration::from_millis(1)).expired());
        let soon = Deadline::within(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(3));
        assert!(soon.expired());
    }

    #[test]
    fn priority_lanes_order_high_first() {
        assert!(Priority::High.lane() < Priority::Normal.lane());
        assert!(Priority::Normal.lane() < Priority::Low.lane());
        assert_eq!(Priority::default(), Priority::Normal);
    }

    #[test]
    fn serve_error_kinds_are_distinct() {
        let all = [
            ServeError::QueueFull,
            ServeError::Cancelled,
            ServeError::DeadlineExceeded,
            ServeError::WrongPayload("x".into()),
            ServeError::EngineFailure("x".into()),
            ServeError::ShuttingDown,
        ];
        let kinds: std::collections::BTreeSet<&str> = all.iter().map(|e| e.kind()).collect();
        assert_eq!(kinds.len(), all.len());
    }

    #[test]
    fn serve_error_display_is_specific() {
        let e = ServeError::WrongPayload("image must be [3, 32, 32]".into());
        assert!(e.to_string().contains("[3, 32, 32]"));
        assert_eq!(ServeError::from(InferError::failed("boom")), {
            ServeError::EngineFailure("boom".into())
        });
        assert!(matches!(
            ServeError::from(InferError::unsupported("seq")),
            ServeError::WrongPayload(_)
        ));
    }
}
