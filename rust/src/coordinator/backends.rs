//! Concrete inference backends for the serving coordinator.

use super::request::{Output, Payload};
use super::server::Backend;
use crate::dnateq::QuantConfig;
use crate::expdot::CountingFc;
use crate::nn::eval::ImageModel;
use crate::nn::{AlexNetMini, ExecPlan, ResNetMini, TransformerMini};
use crate::runtime::Executable;
use crate::tensor::Tensor;

/// Classifier backend over the rust f32/fake-quant engine.
pub struct ClassifierBackend<M: ImageModel + 'static> {
    pub model: M,
    pub plan: ExecPlan,
    pub label: String,
}

impl<M: ImageModel + 'static> ClassifierBackend<M> {
    pub fn fp32(model: M, label: &str) -> Self {
        Self { model, plan: ExecPlan::fp32(), label: label.to_string() }
    }

    pub fn quantized(model: M, cfg: &QuantConfig, label: &str) -> Self {
        let plan = ExecPlan::exp(&model, cfg);
        Self { model, plan, label: label.to_string() }
    }
}

impl<M: ImageModel + 'static> Backend for ClassifierBackend<M> {
    fn infer(&self, batch: &[Payload]) -> Vec<Output> {
        batch
            .iter()
            .map(|p| match p {
                Payload::Image(img) => Output::ClassId(self.model.predict(img, &self.plan)),
                Payload::Seq(_) => Output::ClassId(usize::MAX), // wrong modality
            })
            .collect()
    }

    fn name(&self) -> &str {
        &self.label
    }
}

/// Type aliases for the two CNN backends.
pub type AlexNetBackend = ClassifierBackend<AlexNetMini>;
pub type ResNetBackend = ClassifierBackend<ResNetMini>;

/// Translator backend: greedy decode via the rust engine.
pub struct TranslatorBackend {
    pub model: TransformerMini,
    pub plan: ExecPlan,
    pub max_len: usize,
}

impl Backend for TranslatorBackend {
    fn infer(&self, batch: &[Payload]) -> Vec<Output> {
        batch
            .iter()
            .map(|p| match p {
                Payload::Seq(src) => {
                    Output::Tokens(self.model.greedy_decode(src, self.max_len, &self.plan))
                }
                Payload::Image(_) => Output::Tokens(vec![]),
            })
            .collect()
    }

    fn name(&self) -> &str {
        "translator"
    }
}

/// PJRT backend: runs the AOT-compiled FP32 classifier artifact.
///
/// PJRT handles are `!Send` (raw pointers + `Rc` inside the xla crate),
/// so the executable lives on a dedicated owner thread; the backend
/// forwards images over a channel and waits for logits. No python
/// anywhere on this path — the HLO was compiled at `make artifacts`.
pub struct PjrtClassifierBackend {
    tx: std::sync::Mutex<std::sync::mpsc::Sender<(Tensor, std::sync::mpsc::SyncSender<usize>)>>,
    _owner: std::thread::JoinHandle<()>,
}

impl PjrtClassifierBackend {
    /// Spawn the owner thread: create the CPU client, load + compile the
    /// artifact, then serve inference requests until the channel closes.
    pub fn spawn(artifact: std::path::PathBuf) -> anyhow::Result<Self> {
        let (tx, rx) =
            std::sync::mpsc::channel::<(Tensor, std::sync::mpsc::SyncSender<usize>)>();
        let (ready_tx, ready_rx) = std::sync::mpsc::sync_channel::<anyhow::Result<()>>(1);
        let owner = std::thread::spawn(move || {
            let exe: Executable = match crate::runtime::Runtime::cpu()
                .and_then(|rt| rt.load_hlo(&artifact))
            {
                Ok(exe) => {
                    let _ = ready_tx.send(Ok(()));
                    exe
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok((img, reply)) = rx.recv() {
                let input = Tensor::from_vec(&[1, 3, 32, 32], img.data().to_vec());
                let class = exe.run1(&input).map(|l| l.argmax()).unwrap_or(usize::MAX);
                let _ = reply.send(class);
            }
        });
        ready_rx.recv().map_err(|_| anyhow::anyhow!("pjrt owner thread died"))??;
        Ok(Self { tx: std::sync::Mutex::new(tx), _owner: owner })
    }
}

impl Backend for PjrtClassifierBackend {
    fn infer(&self, batch: &[Payload]) -> Vec<Output> {
        batch
            .iter()
            .map(|p| match p {
                Payload::Image(img) => {
                    let (rtx, rrx) = std::sync::mpsc::sync_channel(1);
                    let sent = self.tx.lock().unwrap().send((img.clone(), rtx)).is_ok();
                    if !sent {
                        return Output::ClassId(usize::MAX);
                    }
                    Output::ClassId(rrx.recv().unwrap_or(usize::MAX))
                }
                Payload::Seq(_) => Output::ClassId(usize::MAX),
            })
            .collect()
    }

    fn name(&self) -> &str {
        "pjrt"
    }
}

/// Counting-engine backend: an FC head evaluated entirely in the
/// exponential domain (demonstrates the §IV software path end-to-end).
pub struct CountingFcBackend {
    pub fc: CountingFc,
}

impl Backend for CountingFcBackend {
    fn infer(&self, batch: &[Payload]) -> Vec<Output> {
        batch
            .iter()
            .map(|p| match p {
                Payload::Image(img) => {
                    let flat = Tensor::from_vec(&[1, img.len()], img.data().to_vec());
                    let out = self.fc.forward(&flat);
                    Output::ClassId(out.argmax())
                }
                Payload::Seq(_) => Output::ClassId(usize::MAX),
            })
            .collect()
    }

    fn name(&self) -> &str {
        "counting-fc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{Coordinator, CoordinatorConfig};
    use crate::dataset::{ImageDataset, SeqDataset};
    use std::sync::Arc;

    #[test]
    fn classifier_backend_serves_images() {
        let backend = Arc::new(AlexNetBackend::fp32(AlexNetMini::random(201), "alexnet-fp32"));
        let c = Coordinator::start(backend, CoordinatorConfig::default());
        let data = ImageDataset::synthetic(4, 202);
        for i in 0..4 {
            let resp = c.submit_wait(Payload::Image(data.image(i))).unwrap();
            match resp.output {
                Output::ClassId(k) => assert!(k < 10),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(c.shutdown().completed, 4);
    }

    #[test]
    fn translator_backend_decodes() {
        let backend = Arc::new(TranslatorBackend {
            model: TransformerMini::random(203),
            plan: ExecPlan::fp32(),
            max_len: 8,
        });
        let c = Coordinator::start(backend, CoordinatorConfig::default());
        let data = SeqDataset::synthetic(2, 204);
        let resp = c.submit_wait(Payload::Seq(data.src[0].clone())).unwrap();
        match resp.output {
            Output::Tokens(toks) => assert!(!toks.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn wrong_modality_yields_sentinel() {
        let backend = Arc::new(AlexNetBackend::fp32(AlexNetMini::random(205), "x"));
        let c = Coordinator::start(backend, CoordinatorConfig::default());
        let resp = c.submit_wait(Payload::Seq(vec![1, 2])).unwrap();
        assert_eq!(resp.output, Output::ClassId(usize::MAX));
        c.shutdown();
    }
}
