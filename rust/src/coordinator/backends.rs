//! Concrete inference engines for the serving coordinator.
//!
//! `Engine::infer_batch` receives the batch the dynamic batcher formed;
//! every engine here forwards the *whole* batch through a batched
//! engine (batch-wide GEMMs / counting GEMMs) instead of looping per
//! payload, so the batcher is a real throughput lever rather than a
//! grouping formality. Results are per-item: a payload the engine
//! cannot process fails as `InferError::Unsupported` (the submission
//! gate normally catches these first), and execution faults fail as
//! `InferError::Failed` — no sentinel outputs, no panics mid-batch.

use super::engine::{Capabilities, Engine};
use super::registry::SwappableEngine;
use super::request::{InferError, Output, Payload};
use crate::dnateq::QuantConfig;
use crate::expdot::CountingFc;
use crate::nn::eval::ImageModel;
use crate::nn::ops::argmax_slice;
use crate::nn::transformer::VOCAB;
use crate::nn::{AlexNetMini, ExecPlan, ResNetMini, TransformerMini};
use crate::runtime::Executable;
use crate::tensor::Tensor;
use std::sync::{Arc, RwLock};

/// Gather the image payloads of a mixed batch into one flat data vector
/// (`idx.len() * flat_len` elements) plus the positions they came from,
/// so non-image payloads keep their per-item error. The caller shapes
/// the data for its engine (`[n, 3, 32, 32]` for CNNs, `[n, in]` for
/// the counting FC).
fn gather_images(batch: &[Payload], flat_len: usize) -> (Vec<usize>, Vec<f32>) {
    let idx: Vec<usize> = batch
        .iter()
        .enumerate()
        .filter_map(|(i, p)| matches!(p, Payload::Image(_)).then_some(i))
        .collect();
    let mut data = Vec::with_capacity(idx.len() * flat_len);
    for &i in &idx {
        if let Payload::Image(img) = &batch[i] {
            data.extend_from_slice(img.data());
        }
    }
    (idx, data)
}

/// Seed every slot with an `Unsupported` error; engines overwrite the
/// positions they actually served.
fn unsupported_slots(batch: &[Payload], expects: &str) -> Vec<Result<Output, InferError>> {
    batch
        .iter()
        .map(|p| {
            Err(InferError::unsupported(format!(
                "engine expects {expects}, got a {} payload",
                p.kind()
            )))
        })
        .collect()
}

/// Classifier engine over the rust f32/fake-quant engine.
///
/// The execution plan sits behind an `RwLock<Arc<_>>` so the registry
/// can hot-swap a recalibrated plan while requests are in flight: each
/// batch clones the current `Arc` once on entry, so a whole batch always
/// runs under one consistent plan and in-flight batches finish on the
/// plan they started with.
pub struct ClassifierBackend<M: ImageModel + 'static> {
    pub model: M,
    /// Plan + its label behind ONE lock so a swap publishes both
    /// atomically (a reader can never see a label from a different plan).
    plan: RwLock<PlanSlot>,
    pub label: String,
}

struct PlanSlot {
    plan: Arc<ExecPlan>,
    label: String,
}

impl<M: ImageModel + 'static> ClassifierBackend<M> {
    pub fn fp32(model: M, label: &str) -> Self {
        let slot = PlanSlot { plan: Arc::new(ExecPlan::fp32()), label: "fp32".to_string() };
        Self { model, plan: RwLock::new(slot), label: label.to_string() }
    }

    pub fn quantized(model: M, cfg: &QuantConfig, label: &str) -> Self {
        let slot = PlanSlot {
            plan: Arc::new(ExecPlan::for_config(&model, cfg)),
            label: plan_label_of(cfg),
        };
        Self { model, plan: RwLock::new(slot), label: label.to_string() }
    }

    /// The plan the next batch will run under.
    pub fn current_plan(&self) -> Arc<ExecPlan> {
        Arc::clone(&self.plan.read().unwrap().plan)
    }
}

fn plan_label_of(cfg: &QuantConfig) -> String {
    format!(
        "dnateq thr_w={:.2}% [{}] ({})",
        cfg.thr_w * 100.0,
        cfg.scheme_names().join("+"),
        cfg.checksum_hex()
    )
}

impl<M: ImageModel + 'static> Engine for ClassifierBackend<M> {
    fn infer_batch(&self, batch: &[Payload]) -> Vec<Result<Output, InferError>> {
        let plan = self.current_plan();
        let (idx, data) = gather_images(batch, 3 * 32 * 32);
        let mut results = unsupported_slots(batch, "[3, 32, 32] images");
        if !idx.is_empty() {
            let images = Tensor::from_vec(&[idx.len(), 3, 32, 32], data);
            let preds = self.model.predict_batch(&images, &plan);
            for (&i, p) in idx.iter().zip(preds) {
                results[i] = Ok(Output::ClassId(p));
            }
        }
        results
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::images_only()
    }

    fn name(&self) -> &str {
        &self.label
    }
}

impl<M: ImageModel + 'static> SwappableEngine for ClassifierBackend<M> {
    fn swap_plan(&self, cfg: &QuantConfig) -> anyhow::Result<()> {
        cfg.validate()?;
        // Build the new plan outside the lock (it round-trips every
        // weight tensor), then publish plan + label in one store. The
        // per-layer scheme dispatch means a swap can move a layer
        // between exp/uniform/pwl, not just change its parameters.
        let slot = PlanSlot {
            plan: Arc::new(ExecPlan::for_config(&self.model, cfg)),
            label: plan_label_of(cfg),
        };
        *self.plan.write().unwrap() = slot;
        Ok(())
    }

    fn plan_label(&self) -> String {
        self.plan.read().unwrap().label.clone()
    }
}

/// Type aliases for the two CNN engines.
pub type AlexNetBackend = ClassifierBackend<AlexNetMini>;
pub type ResNetBackend = ClassifierBackend<ResNetMini>;

/// Translator engine: greedy decode via the rust engine.
pub struct TranslatorBackend {
    pub model: TransformerMini,
    pub plan: ExecPlan,
    pub max_len: usize,
}

impl Engine for TranslatorBackend {
    fn infer_batch(&self, batch: &[Payload]) -> Vec<Result<Output, InferError>> {
        let idx: Vec<usize> = batch
            .iter()
            .enumerate()
            .filter_map(|(i, p)| matches!(p, Payload::Seq(_)).then_some(i))
            .collect();
        let srcs: Vec<Vec<usize>> = idx
            .iter()
            .map(|&i| match &batch[i] {
                Payload::Seq(s) => s.clone(),
                Payload::Image(_) => unreachable!("filtered to Seq"),
            })
            .collect();
        let mut results = unsupported_slots(batch, "token sequences");
        for (&i, toks) in
            idx.iter().zip(self.model.greedy_decode_batch(&srcs, self.max_len, &self.plan))
        {
            results[i] = Ok(Output::Tokens(toks));
        }
        results
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::seqs_only(VOCAB)
    }

    fn name(&self) -> &str {
        "translator"
    }
}

/// PJRT engine: runs the AOT-compiled FP32 classifier artifact.
///
/// PJRT handles are `!Send` (raw pointers + `Rc` inside the xla crate),
/// so the executable lives on a dedicated owner thread; the engine
/// forwards images over a channel and waits for the classification (or
/// the typed execution error). No python anywhere on this path — the
/// HLO was compiled at `make artifacts`.
pub struct PjrtClassifierBackend {
    #[allow(clippy::type_complexity)]
    tx: std::sync::Mutex<
        std::sync::mpsc::Sender<(Tensor, std::sync::mpsc::SyncSender<Result<usize, String>>)>,
    >,
    _owner: std::thread::JoinHandle<()>,
}

impl PjrtClassifierBackend {
    /// Spawn the owner thread: create the CPU client, load + compile the
    /// artifact, then serve inference requests until the channel closes.
    pub fn spawn(artifact: std::path::PathBuf) -> anyhow::Result<Self> {
        let (tx, rx) = std::sync::mpsc::channel::<(
            Tensor,
            std::sync::mpsc::SyncSender<Result<usize, String>>,
        )>();
        let (ready_tx, ready_rx) = std::sync::mpsc::sync_channel::<anyhow::Result<()>>(1);
        let owner = std::thread::spawn(move || {
            let exe: Executable = match crate::runtime::Runtime::cpu()
                .and_then(|rt| rt.load_hlo(&artifact))
            {
                Ok(exe) => {
                    let _ = ready_tx.send(Ok(()));
                    exe
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok((img, reply)) = rx.recv() {
                let input = Tensor::from_vec(&[1, 3, 32, 32], img.data().to_vec());
                let class =
                    exe.run1(&input).map(|l| l.argmax()).map_err(|e| format!("{e:#}"));
                let _ = reply.send(class);
            }
        });
        ready_rx.recv().map_err(|_| anyhow::anyhow!("pjrt owner thread died"))??;
        Ok(Self { tx: std::sync::Mutex::new(tx), _owner: owner })
    }
}

impl Engine for PjrtClassifierBackend {
    fn infer_batch(&self, batch: &[Payload]) -> Vec<Result<Output, InferError>> {
        batch
            .iter()
            .map(|p| match p {
                Payload::Image(img) => {
                    let (rtx, rrx) = std::sync::mpsc::sync_channel(1);
                    let sent = self.tx.lock().unwrap().send((img.clone(), rtx)).is_ok();
                    if !sent {
                        return Err(InferError::failed("pjrt owner thread is gone"));
                    }
                    match rrx.recv() {
                        Ok(Ok(class)) => Ok(Output::ClassId(class)),
                        Ok(Err(why)) => Err(InferError::failed(why)),
                        Err(_) => Err(InferError::failed("pjrt owner dropped the reply")),
                    }
                }
                Payload::Seq(_) => {
                    Err(InferError::unsupported("pjrt classifier expects images"))
                }
            })
            .collect()
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::images_only()
    }

    fn name(&self) -> &str {
        "pjrt"
    }
}

/// Counting-engine backend: an FC head evaluated entirely in the
/// exponential domain (demonstrates the §IV software path end-to-end).
pub struct CountingFcBackend {
    pub fc: CountingFc,
}

impl Engine for CountingFcBackend {
    fn infer_batch(&self, batch: &[Payload]) -> Vec<Result<Output, InferError>> {
        // Stack every image payload into one [n, in] matrix and run a
        // single batched counting GEMM — the §IV kernel amortizes its
        // weight stream and quantization pass across the whole batch.
        // The FC head consumes a flat feature vector, so beyond the
        // submission-gate shape check the image's element count must
        // match `in_features` (declared capabilities can only promise
        // the [3, 32, 32] shape).
        let mut results = unsupported_slots(batch, "[3, 32, 32] images");
        let mut idx = Vec::new();
        let mut data = Vec::new();
        for (i, p) in batch.iter().enumerate() {
            if let Payload::Image(img) = p {
                if img.data().len() == self.fc.in_features {
                    idx.push(i);
                    data.extend_from_slice(img.data());
                } else {
                    results[i] = Err(InferError::unsupported(format!(
                        "counting FC expects {} features, image has {}",
                        self.fc.in_features,
                        img.data().len()
                    )));
                }
            }
        }
        if !idx.is_empty() {
            let flat = Tensor::from_vec(&[idx.len(), self.fc.in_features], data);
            let out = self.fc.forward_batch(&flat);
            for (k, &i) in idx.iter().enumerate() {
                results[i] = Ok(Output::ClassId(argmax_slice(out.row(k))));
            }
        }
        results
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::images_only()
    }

    fn name(&self) -> &str {
        "counting-fc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ServeError;
    use crate::coordinator::server::{Coordinator, CoordinatorConfig};
    use crate::dataset::{ImageDataset, SeqDataset};
    use std::sync::Arc;

    #[test]
    fn classifier_backend_serves_images() {
        let backend = Arc::new(AlexNetBackend::fp32(AlexNetMini::random(201), "alexnet-fp32"));
        let c = Coordinator::start(backend, CoordinatorConfig::default());
        let data = ImageDataset::synthetic(4, 202);
        for i in 0..4 {
            let resp = c.submit_wait(Payload::Image(data.image(i))).unwrap();
            match resp.output {
                Output::ClassId(k) => assert!(k < 10),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(c.shutdown_and_drain().completed, 4);
    }

    #[test]
    fn translator_backend_decodes() {
        let backend = Arc::new(TranslatorBackend {
            model: TransformerMini::random(203),
            plan: ExecPlan::fp32(),
            max_len: 8,
        });
        let c = Coordinator::start(backend, CoordinatorConfig::default());
        let data = SeqDataset::synthetic(2, 204);
        let resp = c.submit_wait(Payload::Seq(data.src[0].clone())).unwrap();
        match resp.output {
            Output::Tokens(toks) => assert!(!toks.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
        c.shutdown_and_drain();
    }

    #[test]
    fn batched_infer_preserves_positions_in_mixed_batches() {
        let model = AlexNetMini::random(206);
        let data = ImageDataset::synthetic(3, 207);
        let backend = AlexNetBackend::fp32(model, "mixed");
        let batch = vec![
            Payload::Image(data.image(0)),
            Payload::Seq(vec![1, 2, 3]),
            Payload::Image(data.image(1)),
            Payload::Image(data.image(2)),
        ];
        let out = backend.infer_batch(&batch);
        assert_eq!(out.len(), 4);
        assert!(matches!(out[1], Err(InferError::Unsupported(_))), "{:?}", out[1]);
        // Batched predictions must equal per-image predictions, in place.
        let plan = backend.current_plan();
        for (slot, img_idx) in [(0usize, 0usize), (2, 1), (3, 2)] {
            let want = backend.model.predict(&data.image(img_idx), &plan);
            assert_eq!(out[slot], Ok(Output::ClassId(want)), "slot {slot}");
        }
    }

    #[test]
    fn counting_backend_batches_whole_payload_set() {
        use crate::dnateq::ExpQuantParams;
        use crate::tensor::SplitMix64;
        let mut rng = SplitMix64::new(208);
        let inf = 3 * 32 * 32;
        let w = Tensor::rand_signed_exponential(&[10, inf], 2.0, &mut rng);
        let x = Tensor::rand_signed_exponential(&[1, inf], 1.0, &mut rng);
        let wp = ExpQuantParams::init_for_tensor(&w, 4);
        let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: 4 };
        ap.refit_scale_offset(&x);
        let backend = CountingFcBackend { fc: CountingFc::new(&w, wp, ap, None) };
        let data = ImageDataset::synthetic(4, 209);
        let batch: Vec<Payload> = (0..4).map(|i| Payload::Image(data.image(i))).collect();
        let out = backend.infer_batch(&batch);
        for (i, o) in out.iter().enumerate() {
            let img = data.image(i);
            let flat = Tensor::from_vec(&[1, inf], img.data().to_vec());
            let want = backend.fc.forward(&flat).argmax();
            assert_eq!(*o, Ok(Output::ClassId(want)), "payload {i}");
        }
    }

    #[test]
    fn classifier_plan_hot_swap_switches_served_plan() {
        use crate::dnateq::{config_for_threshold, SearchOptions};
        use crate::nn::collect_image_calibration;
        let model = AlexNetMini::random(210);
        let data = ImageDataset::synthetic(4, 211);
        let backend = AlexNetBackend::fp32(model, "swap");
        assert_eq!(backend.plan_label(), "fp32");
        let input = collect_image_calibration(&backend.model, &data.take(2));
        let cfg = config_for_threshold(&input, 0.08, &SearchOptions::default());
        backend.swap_plan(&cfg).unwrap();
        assert!(backend.plan_label().starts_with("dnateq"), "{}", backend.plan_label());
        // Predictions after the swap match the quantized plan exactly.
        let out = backend.infer_batch(&[Payload::Image(data.image(0))]);
        let want = backend.model.predict(&data.image(0), &backend.current_plan());
        assert_eq!(out[0], Ok(Output::ClassId(want)));
    }

    #[test]
    fn wrong_modality_is_rejected_at_submission() {
        let backend = Arc::new(AlexNetBackend::fp32(AlexNetMini::random(205), "x"));
        let c = Coordinator::start(backend, CoordinatorConfig::default());
        let err = c.submit(Payload::Seq(vec![1, 2])).unwrap_err();
        assert!(matches!(err, ServeError::WrongPayload(_)), "{err:?}");
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.completed, 0);
    }
}
