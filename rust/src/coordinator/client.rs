//! The typed serving front door: [`InferenceClient`] + [`Ticket`].
//!
//! A client is a cheap, cloneable handle onto one coordinator's
//! submission queue. `submit` validates the payload against the
//! engine's declared [`super::Capabilities`] (so a malformed image or
//! out-of-vocab sequence is rejected with `WrongPayload` *before* it
//! can reach a batch), applies the admission policy, and returns a
//! [`Ticket`] — the one handle a caller needs to `wait()`,
//! `wait_timeout()`, or `cancel()` the request. Every failure mode is a
//! typed [`ServeError`]; nothing is silently dropped.

use super::batcher::SubmissionQueue;
use super::engine::Capabilities;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{Payload, Request, Response, ServeError, SubmitOptions};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// State shared by the coordinator and every client handle.
pub(crate) struct ClientCore {
    pub queue: Arc<SubmissionQueue>,
    pub metrics: Arc<Metrics>,
    pub caps: Capabilities,
    pub next_id: AtomicU64,
    pub engine_name: String,
}

/// Handle for submitting inference requests to one running coordinator.
/// Cloning is cheap (an `Arc` bump); clones share the queue, id space,
/// and metrics. The handle stays valid across `shutdown_and_drain` —
/// submissions then fail with [`ServeError::ShuttingDown`].
#[derive(Clone)]
pub struct InferenceClient {
    core: Arc<ClientCore>,
}

impl InferenceClient {
    pub(crate) fn new(core: Arc<ClientCore>) -> Self {
        Self { core }
    }

    /// Name of the engine this client feeds.
    pub fn engine_name(&self) -> &str {
        &self.core.engine_name
    }

    /// The engine's declared capabilities (what [`Self::submit`] will
    /// admit).
    pub fn capabilities(&self) -> Capabilities {
        self.core.caps
    }

    /// Submit with default options (no deadline, normal priority).
    pub fn submit(&self, payload: Payload) -> Result<Ticket, ServeError> {
        self.submit_with(payload, SubmitOptions::default())
    }

    /// Submit with an explicit deadline/priority. Fails synchronously
    /// with a typed error when the payload is invalid for this engine
    /// (`WrongPayload`), the deadline already expired
    /// (`DeadlineExceeded`), the queue refused admission (`QueueFull`),
    /// or the coordinator is draining (`ShuttingDown`).
    pub fn submit_with(
        &self,
        payload: Payload,
        opts: SubmitOptions,
    ) -> Result<Ticket, ServeError> {
        if let Err(e) = self.core.caps.admit(&payload) {
            self.core.metrics.record_rejected();
            return Err(e);
        }
        if opts.deadline.expired() {
            self.core.metrics.record_expired();
            return Err(ServeError::DeadlineExceeded);
        }
        let (rtx, rrx) = std::sync::mpsc::sync_channel(1);
        let cancelled = Arc::new(AtomicBool::new(false));
        let id = self.core.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            payload,
            submitted: Instant::now(),
            deadline: opts.deadline,
            priority: opts.priority,
            cancelled: Arc::clone(&cancelled),
            respond_to: rtx,
        };
        match self.core.queue.push(req, &self.core.metrics) {
            Ok(()) => Ok(Ticket { id, cancelled, rx: rrx }),
            Err(e) => {
                match e {
                    ServeError::QueueFull => self.core.metrics.record_rejected(),
                    // Blocked admission timed out at the request's own
                    // deadline.
                    ServeError::DeadlineExceeded => self.core.metrics.record_expired(),
                    // ShuttingDown is a lifecycle outcome, not an
                    // admission failure — not counted as rejected.
                    _ => {}
                }
                Err(e)
            }
        }
    }

    /// Submit and block for the result (no deadline, normal priority).
    pub fn infer(&self, payload: Payload) -> Result<Response, ServeError> {
        self.submit(payload)?.wait()
    }

    /// Live metrics of the coordinator behind this client.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.core.metrics.snapshot()
    }
}

/// Handle to one in-flight request. The result is delivered exactly
/// once: `wait` consumes the ticket; `wait_timeout` returns `None`
/// while the request is still pending so the caller can keep waiting —
/// or [`Ticket::cancel`] it.
#[derive(Debug)]
pub struct Ticket {
    id: u64,
    cancelled: Arc<AtomicBool>,
    rx: Receiver<Result<Response, ServeError>>,
}

impl Ticket {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation (idempotent, never blocks). Cooperative: a
    /// request still queued is dropped at batch formation and resolves
    /// to [`ServeError::Cancelled`]; one already inside an engine
    /// completes normally.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Release);
    }

    /// Block until the request resolves.
    pub fn wait(self) -> Result<Response, ServeError> {
        match self.rx.recv() {
            Ok(result) => result,
            // Workers gone without resolving the ticket: hard shutdown.
            Err(_) => Err(ServeError::ShuttingDown),
        }
    }

    /// Wait up to `timeout`; `None` means still pending (the ticket
    /// remains valid — wait again or cancel).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<Response, ServeError>> {
        match self.rx.recv_timeout(timeout) {
            Ok(result) => Some(result),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Err(ServeError::ShuttingDown)),
        }
    }

    /// Non-blocking poll; `None` means still pending.
    pub fn try_wait(&self) -> Option<Result<Response, ServeError>> {
        match self.rx.try_recv() {
            Ok(result) => Some(result),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Err(ServeError::ShuttingDown)),
        }
    }
}
