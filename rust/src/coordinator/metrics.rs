//! Serving metrics: counters + latency distribution.
//!
//! Beyond throughput/latency, every way a request can fail to produce a
//! normal response is counted — cancelled, deadline-expired, rejected at
//! admission, shed from a full queue, failed inside the engine — plus
//! `dropped_sends` for responses whose ticket was abandoned (receiver
//! gone), so nothing disappears silently.

use crate::energysim::PowerMeter;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Co-simulated energy accounting: cumulative joules/output units plus
/// the rolling power window the `EnergyBudget` admission policy reads.
#[derive(Debug, Default)]
struct EnergyState {
    meter: PowerMeter,
    requests: u64,
    output_units: u64,
}

/// Shared metrics sink (thread-safe).
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    completed: AtomicU64,
    batches: AtomicU64,
    batch_items: AtomicU64,
    /// Plan hot-swaps applied to the backend behind this sink.
    swaps: AtomicU64,
    /// Tickets cancelled before their request reached an engine.
    cancelled: AtomicU64,
    /// Requests dropped because their deadline expired (at submit or at
    /// batch formation).
    expired: AtomicU64,
    /// Submissions refused at admission (queue full under `Reject`, or
    /// wrong payload).
    rejected: AtomicU64,
    /// Admitted requests later evicted by `ShedOldest`.
    shed: AtomicU64,
    /// Per-item engine failures (including batch-contract violations).
    engine_failures: AtomicU64,
    /// Results that could not be delivered: the ticket was dropped.
    dropped_sends: AtomicU64,
    /// Submissions shed by the `EnergyBudget` admission policy while
    /// the rolling power window exceeded the envelope. A refinement of
    /// `rejected` (the client counts the returned `QueueFull` there
    /// too), surfaced separately so energy shedding is observable.
    energy_shed: AtomicU64,
    /// Worker-pool grow events (autoscaler added a worker).
    scale_ups: AtomicU64,
    /// Worker-pool shrink events (autoscaler retired a worker).
    scale_downs: AtomicU64,
    /// End-to-end latencies (seconds).
    e2e: Mutex<Vec<f64>>,
    /// Queue-wait latencies (seconds).
    queue: Mutex<Vec<f64>>,
    /// Co-simulated energy (cumulative + rolling power window).
    energy: Mutex<EnergyState>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            started: Instant::now(),
            completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_items: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            engine_failures: AtomicU64::new(0),
            dropped_sends: AtomicU64::new(0),
            energy_shed: AtomicU64::new(0),
            scale_ups: AtomicU64::new(0),
            scale_downs: AtomicU64::new(0),
            e2e: Mutex::new(Vec::new()),
            queue: Mutex::new(Vec::new()),
            energy: Mutex::new(EnergyState::default()),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_items.fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn record_response(&self, e2e_s: f64, queue_s: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.e2e.lock().unwrap().push(e2e_s);
        self.queue.lock().unwrap().push(queue_s);
    }

    /// Count one plan hot-swap (recorded by the model registry).
    pub fn record_swap(&self) {
        self.swaps.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_engine_failures(&self, n: u64) {
        self.engine_failures.fetch_add(n, Ordering::Relaxed);
    }

    pub fn record_dropped_send(&self) {
        self.dropped_sends.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_scale_up(&self) {
        self.scale_ups.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_scale_down(&self) {
        self.scale_downs.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one co-simulated request: `joules` spent producing
    /// `output_units` output units (tokens / class ids / logit
    /// elements). Also feeds the rolling power window behind
    /// [`Metrics::rolling_watts`].
    pub fn record_energy(&self, joules: f64, output_units: u64) {
        let mut e = self.energy.lock().unwrap();
        e.meter.record(joules);
        e.requests += 1;
        e.output_units += output_units;
    }

    /// Simulated power over the recent window (W) — what the
    /// `EnergyBudget` admission policy compares against its envelope.
    pub fn rolling_watts(&self) -> f64 {
        self.energy.lock().unwrap().meter.watts()
    }

    /// Count one submission shed by `EnergyBudget` admission.
    pub fn record_energy_shed(&self) {
        self.energy_shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let e2e = self.e2e.lock().unwrap().clone();
        let queue = self.queue.lock().unwrap().clone();
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed).max(1);
        let (energy_total_j, energy_requests, energy_j_per_request, energy_j_per_output) = {
            let e = self.energy.lock().unwrap();
            let total = e.meter.total_j();
            let per_req = if e.requests > 0 { total / e.requests as f64 } else { 0.0 };
            let per_out = if e.output_units > 0 { total / e.output_units as f64 } else { 0.0 };
            (total, e.requests, per_req, per_out)
        };
        MetricsSnapshot {
            completed,
            throughput_rps: completed as f64 / self.started.elapsed().as_secs_f64().max(1e-9),
            avg_batch: self.batch_items.load(Ordering::Relaxed) as f64 / batches as f64,
            swaps: self.swaps.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            engine_failures: self.engine_failures.load(Ordering::Relaxed),
            dropped_sends: self.dropped_sends.load(Ordering::Relaxed),
            energy_shed: self.energy_shed.load(Ordering::Relaxed),
            scale_ups: self.scale_ups.load(Ordering::Relaxed),
            scale_downs: self.scale_downs.load(Ordering::Relaxed),
            energy_total_j,
            energy_requests,
            energy_j_per_request,
            energy_j_per_output,
            e2e: Percentiles::of(e2e),
            queue: Percentiles::of(queue),
        }
    }
}

/// Latency percentiles (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct Percentiles {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    pub max: f64,
}

impl Percentiles {
    /// Well-defined on any input: non-finite samples are discarded, an
    /// empty set yields all-zero percentiles (never NaN — these numbers
    /// flow into emitted JSON and gate comparisons), and a single
    /// sample is every percentile of itself.
    pub fn of(xs: Vec<f64>) -> Self {
        let mut xs: Vec<f64> = xs.into_iter().filter(|x| x.is_finite()).collect();
        if xs.is_empty() {
            return Self::default();
        }
        xs.sort_by(f64::total_cmp);
        let q = |p: f64| xs[((xs.len() as f64 - 1.0) * p).floor() as usize];
        Self {
            mean: xs.iter().sum::<f64>() / xs.len() as f64,
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
            p999: q(0.999),
            max: *xs.last().unwrap(),
        }
    }

    /// JSON view in milliseconds — the unit every emitted report uses.
    pub fn to_json_ms(&self) -> crate::util::Json {
        let mut j = crate::util::Json::obj();
        j.set("mean_ms", self.mean * 1e3)
            .set("p50_ms", self.p50 * 1e3)
            .set("p95_ms", self.p95 * 1e3)
            .set("p99_ms", self.p99 * 1e3)
            .set("p999_ms", self.p999 * 1e3)
            .set("max_ms", self.max * 1e3);
        j
    }
}

/// Point-in-time view of the metrics.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub throughput_rps: f64,
    pub avg_batch: f64,
    /// Plan hot-swaps applied while serving.
    pub swaps: u64,
    pub cancelled: u64,
    pub expired: u64,
    pub rejected: u64,
    pub shed: u64,
    pub engine_failures: u64,
    pub dropped_sends: u64,
    /// Submissions shed by `EnergyBudget` admission (also counted in
    /// `rejected` by the client, which sees the `QueueFull` error).
    pub energy_shed: u64,
    /// Worker-pool autoscaler grow events.
    pub scale_ups: u64,
    /// Worker-pool autoscaler shrink events.
    pub scale_downs: u64,
    /// Cumulative co-simulated joules across all completed requests
    /// (0 when the engine does no energy accounting).
    pub energy_total_j: f64,
    /// Requests that carried a co-simulated energy report.
    pub energy_requests: u64,
    /// Mean co-simulated joules per request (0 when none recorded).
    pub energy_j_per_request: f64,
    /// Mean co-simulated joules per output unit — token, class id or
    /// logit element (0 when none recorded).
    pub energy_j_per_output: f64,
    pub e2e: Percentiles,
    pub queue: Percentiles,
}

impl MetricsSnapshot {
    /// Every failure counter as `(name, value)` pairs, in display
    /// order — the one list shared by consumers that aggregate or
    /// serialize them (e.g. the bench gate).
    pub fn failure_counters(&self) -> [(&'static str, u64); 7] {
        [
            ("cancelled", self.cancelled),
            ("expired", self.expired),
            ("rejected", self.rejected),
            ("shed", self.shed),
            ("engine_failures", self.engine_failures),
            ("dropped_sends", self.dropped_sends),
            ("energy_shed", self.energy_shed),
        ]
    }

    /// Requests that ended in any typed failure. `energy_shed` is
    /// deliberately absent: those submissions already count under
    /// `rejected` (the client records the returned `QueueFull`).
    pub fn failed_total(&self) -> u64 {
        self.cancelled + self.expired + self.rejected + self.shed + self.engine_failures
    }

    pub fn summary(&self) -> String {
        let swaps = if self.swaps > 0 { format!(", {} swaps", self.swaps) } else { String::new() };
        let failures = if self.failed_total() > 0 || self.dropped_sends > 0 {
            format!(
                ", failed: {} cancelled / {} expired / {} rejected / {} shed / {} engine\
                 {}",
                self.cancelled,
                self.expired,
                self.rejected,
                self.shed,
                self.engine_failures,
                if self.dropped_sends > 0 {
                    format!(" ({} dropped sends)", self.dropped_sends)
                } else {
                    String::new()
                },
            )
        } else {
            String::new()
        };
        let pool = if self.scale_ups > 0 || self.scale_downs > 0 {
            format!(", pool +{}/-{}", self.scale_ups, self.scale_downs)
        } else {
            String::new()
        };
        let energy = if self.energy_requests > 0 {
            format!(
                ", energy {:.3e} J total ({:.3e} J/req{})",
                self.energy_total_j,
                self.energy_j_per_request,
                if self.energy_shed > 0 {
                    format!(", {} energy-shed", self.energy_shed)
                } else {
                    String::new()
                },
            )
        } else {
            String::new()
        };
        format!(
            "{} req, {:.1} req/s, avg batch {:.2}{swaps}{pool}{energy}, e2e p50/p95/p99/p999 = \
             {:.2}/{:.2}/{:.2}/{:.2} ms{failures}",
            self.completed,
            self.throughput_rps,
            self.avg_batch,
            self.e2e.p50 * 1e3,
            self.e2e.p95 * 1e3,
            self.e2e.p99 * 1e3,
            self.e2e.p999 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_data() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let p = Percentiles::of(xs);
        assert_eq!(p.p50, 50.0);
        assert_eq!(p.p95, 95.0);
        assert_eq!(p.p99, 99.0);
        assert_eq!(p.p999, 99.0);
        assert_eq!(p.max, 100.0);
        assert!((p.mean - 50.5).abs() < 1e-9);
        // At 2000 samples p999 separates from p99.
        let xs: Vec<f64> = (1..=2000).map(|x| x as f64).collect();
        let p = Percentiles::of(xs);
        assert_eq!(p.p99, 1980.0);
        assert_eq!(p.p999, 1999.0);
    }

    #[test]
    fn empty_percentiles_are_zero() {
        let p = Percentiles::of(vec![]);
        assert_eq!(p.p99, 0.0);
        assert_eq!(p.p999, 0.0);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let p = Percentiles::of(vec![0.25]);
        assert_eq!((p.p50, p.p99, p.p999, p.max), (0.25, 0.25, 0.25, 0.25));
        assert_eq!(p.mean, 0.25);
    }

    #[test]
    fn non_finite_samples_never_reach_the_json() {
        let p = Percentiles::of(vec![f64::NAN, 1.0, f64::INFINITY, 3.0, f64::NEG_INFINITY]);
        assert_eq!(p.p50, 1.0);
        assert_eq!(p.max, 3.0);
        let encoded = p.to_json_ms().encode();
        assert!(!encoded.contains("null"), "{encoded}");
        // All-NaN input degrades to zeros, not NaN.
        let p = Percentiles::of(vec![f64::NAN, f64::NAN]);
        assert_eq!(p.p999, 0.0);
        assert!(!p.to_json_ms().encode().contains("null"));
    }

    #[test]
    fn metrics_accumulate() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(2);
        for _ in 0..6 {
            m.record_response(0.010, 0.001);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 6);
        assert!((s.avg_batch - 3.0).abs() < 1e-9);
        assert!((s.e2e.p50 - 0.010).abs() < 1e-9);
        assert!(s.summary().contains("6 req"));
        assert!(!s.summary().contains("swaps"));
        assert!(!s.summary().contains("failed"));
        assert_eq!(s.failed_total(), 0);
    }

    #[test]
    fn swaps_are_counted_and_surfaced() {
        let m = Metrics::new();
        m.record_swap();
        m.record_swap();
        let s = m.snapshot();
        assert_eq!(s.swaps, 2);
        assert!(s.summary().contains("2 swaps"), "{}", s.summary());
    }

    #[test]
    fn scale_events_are_counted_and_surfaced() {
        let m = Metrics::new();
        m.record_scale_up();
        m.record_scale_up();
        m.record_scale_down();
        let s = m.snapshot();
        assert_eq!((s.scale_ups, s.scale_downs), (2, 1));
        assert!(s.summary().contains("pool +2/-1"), "{}", s.summary());
        // Fixed pools keep the summary clean.
        assert!(!Metrics::new().snapshot().summary().contains("pool"));
    }

    #[test]
    fn failure_counters_are_counted_and_surfaced() {
        let m = Metrics::new();
        m.record_cancelled();
        m.record_expired();
        m.record_expired();
        m.record_rejected();
        m.record_shed();
        m.record_engine_failures(3);
        m.record_dropped_send();
        let s = m.snapshot();
        assert_eq!(
            (s.cancelled, s.expired, s.rejected, s.shed, s.engine_failures, s.dropped_sends),
            (1, 2, 1, 1, 3, 1)
        );
        assert_eq!(s.failed_total(), 8);
        let text = s.summary();
        assert!(text.contains("1 cancelled"), "{text}");
        assert!(text.contains("2 expired"), "{text}");
        assert!(text.contains("3 engine"), "{text}");
        assert!(text.contains("1 dropped sends"), "{text}");
    }

    #[test]
    fn energy_accumulates_into_gauges() {
        let m = Metrics::new();
        m.record_energy(2.0e-6, 4);
        m.record_energy(4.0e-6, 8);
        let s = m.snapshot();
        assert_eq!(s.energy_requests, 2);
        assert!((s.energy_total_j - 6.0e-6).abs() < 1e-18);
        assert!((s.energy_j_per_request - 3.0e-6).abs() < 1e-18);
        assert!((s.energy_j_per_output - 0.5e-6).abs() < 1e-18);
        // Both samples landed inside the rolling window just now.
        assert!(m.rolling_watts() > 0.0);
        assert!(s.summary().contains("energy"), "{}", s.summary());
    }

    #[test]
    fn energy_gauges_are_zero_not_nan_when_unused() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.energy_requests, 0);
        assert_eq!(s.energy_total_j, 0.0);
        assert_eq!(s.energy_j_per_request, 0.0);
        assert_eq!(s.energy_j_per_output, 0.0);
        assert!(!s.summary().contains("energy"), "{}", s.summary());
    }

    #[test]
    fn energy_shed_is_surfaced_but_not_double_counted_in_failed_total() {
        let m = Metrics::new();
        m.record_energy_shed();
        m.record_energy_shed();
        // The client also records the QueueFull it got back.
        m.record_rejected();
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.energy_shed, 2);
        assert_eq!(s.failed_total(), 2, "energy_shed must not double-count");
        let counters = s.failure_counters();
        assert_eq!(counters[6], ("energy_shed", 2));
    }
}
