//! Summary statistics + histograms used by the distribution analysis
//! (§III-A) and the report emitters.

/// Single-pass summary statistics of a value slice.
#[derive(Clone, Copy, Debug, Default)]
pub struct TensorStats {
    pub n: usize,
    pub min: f32,
    pub max: f32,
    pub mean: f32,
    pub std: f32,
    /// Mean of |x| — feeds the Thr_act scaling (Eq. 7).
    pub mean_abs: f32,
    /// Fraction of exact zeros (the reserved zero code point, §III-B).
    pub zero_frac: f32,
}

impl TensorStats {
    pub fn of(xs: &[f32]) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        let n = xs.len();
        let mut min = f32::INFINITY;
        let mut max = f32::NEG_INFINITY;
        let mut sum = 0.0f64;
        let mut sum_abs = 0.0f64;
        let mut zeros = 0usize;
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
            sum += x as f64;
            sum_abs += x.abs() as f64;
            if x == 0.0 {
                zeros += 1;
            }
        }
        let mean = (sum / n as f64) as f32;
        let mut var = 0.0f64;
        for &x in xs {
            let d = (x - mean) as f64;
            var += d * d;
        }
        Self {
            n,
            min,
            max,
            mean,
            std: (var / n as f64).sqrt() as f32,
            mean_abs: (sum_abs / n as f64) as f32,
            zero_frac: zeros as f32 / n as f32,
        }
    }
}

/// Equal-width histogram over `[lo, hi]` with density normalization —
/// the empirical distribution the RSS fits are computed against.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    /// Build a histogram with `bins` equal-width buckets. Values outside
    /// `[lo, hi]` clamp to the edge buckets (outliers stay visible).
    pub fn build(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo, "degenerate histogram range");
        let mut counts = vec![0u64; bins];
        let scale = bins as f32 / (hi - lo);
        for &x in xs {
            let mut b = ((x - lo) * scale) as isize;
            if b < 0 {
                b = 0;
            }
            if b >= bins as isize {
                b = bins as isize - 1;
            }
            counts[b as usize] += 1;
        }
        Self { lo, hi, counts, total: xs.len() as u64 }
    }

    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    /// Bin centers.
    pub fn centers(&self) -> Vec<f32> {
        let w = self.width();
        (0..self.bins()).map(|i| self.lo + (i as f32 + 0.5) * w).collect()
    }

    pub fn width(&self) -> f32 {
        (self.hi - self.lo) / self.bins() as f32
    }

    /// Probability-density estimate per bin (integrates to ~1).
    pub fn density(&self) -> Vec<f32> {
        let norm = 1.0 / (self.total.max(1) as f32 * self.width());
        self.counts.iter().map(|&c| c as f32 * norm).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_values() {
        let s = TensorStats::of(&[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 1.5).abs() < 1e-6);
        assert!((s.mean_abs - 1.5).abs() < 1e-6);
        assert!((s.zero_frac - 0.25).abs() < 1e-6);
        // population std of [0,1,2,3] = sqrt(1.25)
        assert!((s.std - 1.25f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn stats_empty_is_default() {
        let s = TensorStats::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn histogram_counts_and_density() {
        let xs = [0.1f32, 0.1, 0.9, 2.5, -1.0];
        let h = Histogram::build(&xs, 0.0, 1.0, 2);
        // -1.0 clamps to bin 0; 2.5 clamps to bin 1.
        assert_eq!(h.counts, vec![3, 2]);
        let d = h.density();
        // total mass = sum(d_i * width) = 1
        let mass: f32 = d.iter().map(|&x| x * h.width()).sum();
        assert!((mass - 1.0).abs() < 1e-6);
    }

    #[test]
    fn histogram_centers_are_midpoints() {
        let h = Histogram::build(&[0.0, 1.0], 0.0, 1.0, 4);
        let c = h.centers();
        assert!((c[0] - 0.125).abs() < 1e-6);
        assert!((c[3] - 0.875).abs() < 1e-6);
    }
}
