//! Dense nd-array substrate.
//!
//! A deliberately small tensor library: row-major `f32` (and `i8`) arrays
//! with the handful of operations the DNA-TEQ pipeline needs — shape
//! bookkeeping, elementwise maps, reductions/statistics, and a
//! little-endian binary interchange format (`.bt`) shared with the python
//! compile path (see `python/compile/btio.py`).

mod io;
mod rng;
mod stats;

pub use io::{load_tensor, read_bt, save_tensor, write_bt, BtDtype};
pub use rng::SplitMix64;
pub use stats::{Histogram, TensorStats};

/// Row-major dense `f32` tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Create a tensor from raw parts. Panics if `data.len()` does not
    /// match the product of `shape`.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(
            n,
            data.len(),
            "shape {:?} implies {} elements, got {}",
            shape,
            n,
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Tensor filled with `v`.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// Uniform random tensor in `[lo, hi)` from a deterministic stream.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut SplitMix64) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| lo + (hi - lo) * rng.next_f32()).collect();
        Self { shape: shape.to_vec(), data }
    }

    /// Standard-normal random tensor (Box–Muller over the deterministic
    /// stream), optionally scaled.
    pub fn rand_normal(shape: &[usize], mean: f32, std: f32, rng: &mut SplitMix64) -> Self {
        let n: usize = shape.iter().product();
        let mut data = Vec::with_capacity(n);
        while data.len() < n {
            let (a, b) = rng.next_gauss_pair();
            data.push(mean + std * a);
            if data.len() < n {
                data.push(mean + std * b);
            }
        }
        Self { shape: shape.to_vec(), data }
    }

    /// Exponentially distributed magnitudes with random signs — the tensor
    /// population DNA-TEQ targets (§III-A). Used by tests and benches to
    /// synthesize realistic layer tensors without artifacts on disk.
    pub fn rand_signed_exponential(shape: &[usize], rate: f32, rng: &mut SplitMix64) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n)
            .map(|_| {
                let u = rng.next_f32().max(1e-9);
                let mag = -u.ln() / rate;
                if rng.next_f32() < 0.5 {
                    -mag
                } else {
                    mag
                }
            })
            .collect();
        Self { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape without copying. Panics if element counts differ.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        self.shape = shape.to_vec();
        self
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2, "row() requires a 2-D tensor");
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Batch element `i` of an N-D tensor (leading axis).
    pub fn batch(&self, i: usize) -> &[f32] {
        let stride: usize = self.shape[1..].iter().product();
        &self.data[i * stride..(i + 1) * stride]
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Self { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Elementwise binary op; shapes must match exactly.
    pub fn zip(&self, other: &Self, f: impl Fn(f32, f32) -> f32) -> Self {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Self { shape: self.shape.clone(), data }
    }

    pub fn add(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f32) -> Self {
        self.map(|x| x * s)
    }

    /// Maximum absolute value (0 for empty tensors).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Minimum absolute value over the *nonzero* elements — DNA-TEQ's
    /// `min(t)` in Eq. 5 operates on magnitudes with zeros carved out (the
    /// zero code point is reserved, §III-B).
    pub fn abs_min_nonzero(&self) -> f32 {
        self.data
            .iter()
            .filter(|&&x| x != 0.0)
            .fold(f32::INFINITY, |m, &x| m.min(x.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn mean_abs(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|x| x.abs()).sum::<f32>() / self.data.len() as f32
    }

    /// Index of the maximum element of a 1-D slice view (argmax over the
    /// whole buffer for 1-D tensors).
    pub fn argmax(&self) -> usize {
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in self.data.iter().enumerate() {
            if v > bv {
                bv = v;
                best = i;
            }
        }
        best
    }

    /// 2-D matrix multiply (naive blocked); used only off the hot path —
    /// the inference engine has its own GEMM in `nn::linalg`.
    pub fn matmul(&self, other: &Self) -> Self {
        assert_eq!(self.ndim(), 2);
        assert_eq!(other.ndim(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dim mismatch");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Tensor::from_vec(&[m, n], out)
    }

    /// Relative mean absolute error against a reference tensor — the
    /// paper's RMAE metric (Eq. 6).
    pub fn rmae(&self, reference: &Self) -> f32 {
        assert_eq!(self.shape, reference.shape, "rmae shape mismatch");
        let denom: f32 = reference.data.iter().map(|x| x.abs()).sum();
        if denom == 0.0 {
            return 0.0;
        }
        let num: f32 = self
            .data
            .iter()
            .zip(&reference.data)
            .map(|(&a, &b)| (a - b).abs())
            .sum();
        num / denom
    }

    /// Summary statistics used by the distribution analysis and reports.
    pub fn stats(&self) -> TensorStats {
        TensorStats::of(&self.data)
    }
}

/// Row-major dense `i8` tensor — storage for uniformly quantized values.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI8 {
    shape: Vec<usize>,
    data: Vec<i8>,
}

impl TensorI8 {
    pub fn from_vec(shape: &[usize], data: Vec<i8>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[i8] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i8] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_and_shape() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1., 2., 3.]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]).reshape(&[2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![1., 1., 1., 1.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn rmae_zero_for_identical() {
        let a = Tensor::from_vec(&[3], vec![1., -2., 3.]);
        assert_eq!(a.rmae(&a), 0.0);
    }

    #[test]
    fn rmae_matches_hand_computation() {
        let t = Tensor::from_vec(&[2], vec![1.0, -1.0]);
        let q = Tensor::from_vec(&[2], vec![1.5, -0.5]);
        // num = 0.5 + 0.5 = 1.0, denom = 2.0
        assert!((q.rmae(&t) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn abs_min_nonzero_skips_zeros() {
        let t = Tensor::from_vec(&[4], vec![0.0, -0.25, 4.0, 0.0]);
        assert_eq!(t.abs_min_nonzero(), 0.25);
        assert_eq!(t.abs_max(), 4.0);
    }

    #[test]
    fn rand_signed_exponential_is_signed_and_deterministic() {
        let mut r1 = SplitMix64::new(7);
        let mut r2 = SplitMix64::new(7);
        let a = Tensor::rand_signed_exponential(&[1000], 4.0, &mut r1);
        let b = Tensor::rand_signed_exponential(&[1000], 4.0, &mut r2);
        assert_eq!(a, b);
        assert!(a.data().iter().any(|&x| x > 0.0));
        assert!(a.data().iter().any(|&x| x < 0.0));
    }

    #[test]
    fn argmax_finds_peak() {
        let t = Tensor::from_vec(&[5], vec![0.1, 0.9, 0.3, 0.95, 0.2]);
        assert_eq!(t.argmax(), 3);
    }

    #[test]
    fn batch_slices_leading_axis() {
        let t = Tensor::from_vec(&[2, 2, 2], (0..8).map(|x| x as f32).collect());
        assert_eq!(t.batch(1), &[4., 5., 6., 7.]);
    }
}
