//! `.bt` binary tensor interchange.
//!
//! The python compile path (`python/compile/btio.py`) writes tensors in
//! this format; the rust side reads them (weights, datasets, calibration
//! traces) and writes them back for reports. Layout, all little-endian:
//!
//! ```text
//! magic   : 4 bytes  b"BT01"
//! dtype   : u32      0 = f32, 1 = i8, 2 = i32
//! ndim    : u32
//! dims    : ndim × u64
//! payload : product(dims) × sizeof(dtype)
//! ```

use super::Tensor;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"BT01";

/// Element type tag in the `.bt` header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BtDtype {
    F32 = 0,
    I8 = 1,
    I32 = 2,
}

impl BtDtype {
    fn from_u32(v: u32) -> Result<Self> {
        Ok(match v {
            0 => BtDtype::F32,
            1 => BtDtype::I8,
            2 => BtDtype::I32,
            other => bail!("unknown bt dtype tag {other}"),
        })
    }
}

/// Write an f32 tensor to a writer in `.bt` format.
pub fn write_bt<W: Write>(w: &mut W, t: &Tensor) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&(BtDtype::F32 as u32).to_le_bytes())?;
    w.write_all(&(t.ndim() as u32).to_le_bytes())?;
    for &d in t.shape() {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    // Bulk conversion: safe byte-wise copy of the f32 slice.
    let mut buf = Vec::with_capacity(t.len() * 4);
    for &v in t.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

/// Read an f32 tensor from a reader in `.bt` format. I8/I32 payloads are
/// widened to f32 (they store exponents/labels).
pub fn read_bt<R: Read>(r: &mut R) -> Result<Tensor> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("reading bt magic")?;
    ensure!(&magic == MAGIC, "bad magic {:?}, want BT01", magic);

    let mut u32buf = [0u8; 4];
    r.read_exact(&mut u32buf)?;
    let dtype = BtDtype::from_u32(u32::from_le_bytes(u32buf))?;
    r.read_exact(&mut u32buf)?;
    let ndim = u32::from_le_bytes(u32buf) as usize;
    ensure!(ndim <= 8, "implausible ndim {ndim}");

    let mut dims = Vec::with_capacity(ndim);
    let mut u64buf = [0u8; 8];
    for _ in 0..ndim {
        r.read_exact(&mut u64buf)?;
        dims.push(u64::from_le_bytes(u64buf) as usize);
    }
    let n: usize = dims.iter().product();
    ensure!(n <= 1 << 31, "implausible element count {n}");

    let data = match dtype {
        BtDtype::F32 => {
            let mut raw = vec![0u8; n * 4];
            r.read_exact(&mut raw)?;
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        }
        BtDtype::I8 => {
            let mut raw = vec![0u8; n];
            r.read_exact(&mut raw)?;
            raw.iter().map(|&b| b as i8 as f32).collect()
        }
        BtDtype::I32 => {
            let mut raw = vec![0u8; n * 4];
            r.read_exact(&mut raw)?;
            raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
                .collect()
        }
    };
    Ok(Tensor::from_vec(&dims, data))
}

/// Load a tensor from a `.bt` file.
pub fn load_tensor<P: AsRef<Path>>(path: P) -> Result<Tensor> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    read_bt(&mut bytes.as_slice())
}

/// Save a tensor to a `.bt` file, creating parent directories.
pub fn save_tensor<P: AsRef<Path>>(path: P, t: &Tensor) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_bt(&mut f, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    #[test]
    fn roundtrip_f32() {
        let mut rng = SplitMix64::new(11);
        let t = Tensor::rand_normal(&[3, 4, 5], 0.0, 2.0, &mut rng);
        let mut buf = Vec::new();
        write_bt(&mut buf, &t).unwrap();
        let t2 = read_bt(&mut buf.as_slice()).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = b"NOPE\x00\x00\x00\x00".to_vec();
        assert!(read_bt(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn reads_i8_payload_as_f32() {
        // Hand-build an i8 tensor file: shape [3], values [-1, 0, 7].
        let mut buf = Vec::new();
        buf.extend_from_slice(b"BT01");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&3u64.to_le_bytes());
        buf.extend_from_slice(&[(-1i8) as u8, 0, 7]);
        let t = read_bt(&mut buf.as_slice()).unwrap();
        assert_eq!(t.data(), &[-1.0, 0.0, 7.0]);
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("nested/dir/t.bt");
        let t = Tensor::from_vec(&[2, 2], vec![1.0, -2.5, 3.25, 0.0]);
        save_tensor(&p, &t).unwrap();
        assert_eq!(load_tensor(&p).unwrap(), t);
    }

    #[test]
    fn truncated_payload_errors() {
        let t = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]);
        let mut buf = Vec::new();
        write_bt(&mut buf, &t).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_bt(&mut buf.as_slice()).is_err());
    }
}
