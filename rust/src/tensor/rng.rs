//! Deterministic pseudo-random stream.
//!
//! SplitMix64 is used everywhere randomness is needed inside the crate
//! (synthetic tensors for tests/benches, the serving workload generator)
//! so runs are reproducible without pulling in a heavyweight RNG crate.
//! Dataset/weight randomness shared with python lives in artifacts instead
//! — nothing in the crate relies on cross-language RNG agreement.

/// SplitMix64 PRNG (Steele, Lea & Flood 2014). Passes BigCrush for the
/// statistical quality we need here; `new(seed)` streams are independent.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 high-quality mantissa bits.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Uses rejection-free multiply-shift;
    /// bias is < 2^-32 for the n used here.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Pair of independent standard normals (Box–Muller).
    pub fn next_gauss_pair(&mut self) -> (f32, f32) {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        ((r * th.cos()) as f32, (r * th.sin()) as f32)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = SplitMix64::new(1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = SplitMix64::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let k = r.next_below(7);
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments_roughly_standard() {
        let mut r = SplitMix64::new(9);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n / 2 {
            let (a, b) = r.next_gauss_pair();
            sum += (a + b) as f64;
            sq += (a * a + b * b) as f64;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
