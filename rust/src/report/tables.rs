//! Emitters for every table and figure of the paper's evaluation.
//!
//! Each function returns the formatted exhibit (and writes machine-
//! readable CSV under `artifacts/reports/`); `repro report --all`
//! regenerates the lot for EXPERIMENTS.md.

use super::pipeline::{CalibOutcome, ModelBundle, MODELS};
use crate::accel::{
    alexnet_shapes, assign_bits, geomean, resnet50_shapes, transformer_shapes, AccelConfig,
    AreaModel, Comparison, EnergyModel, Scheme,
};
use crate::artifact_path;
use crate::dnateq::{fit_distributions, DistKind, ExpQuantParams, QuantConfig};
use crate::expdot::{CountingFc, Int8Fc};
use crate::tensor::{SplitMix64, Tensor};
use crate::util::bench::{bench, black_box};
use anyhow::Result;
use std::collections::BTreeMap;
use std::fmt::Write as _;

fn save_csv(name: &str, header: &str, rows: &[String]) -> Result<()> {
    let path = artifact_path(&format!("reports/{name}.csv"));
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = String::from(header);
    out.push('\n');
    for r in rows {
        out.push_str(r);
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Tables I & II: mean RSS of the four candidate distributions over all
/// layers' activations (`acts=true`) or weights.
pub fn table_rss(outcomes: &BTreeMap<String, CalibOutcome>, acts: bool) -> Result<String> {
    let which = if acts { "activations" } else { "weights" };
    let idx = if acts { "I" } else { "II" };
    let mut s = format!("Table {idx}: Mean RSS of {which} for different distributions\n");
    let _ = writeln!(
        s,
        "{:<18} {:>10} {:>12} {:>10} {:>10}",
        "DNN", "Normal", "Exponential", "Pareto", "Uniform"
    );
    let mut rows = Vec::new();
    for name in MODELS {
        let bundle = ModelBundle::load(name)?;
        let input = bundle.calibration_input();
        let mut sums = [0.0f64; 4];
        for layer in &input.layers {
            let t = if acts { &layer.acts } else { &layer.weights };
            let rep = fit_distributions(t);
            for (i, kind) in DistKind::ALL.iter().enumerate() {
                sums[i] += rep.rss_of(*kind);
            }
        }
        let n = input.layers.len() as f64;
        let m: Vec<f64> = sums.iter().map(|x| x / n).collect();
        let _ =
            writeln!(s, "{:<18} {:>10.3} {:>12.3} {:>10.3} {:>10.3}", name, m[0], m[1], m[2], m[3]);
        rows.push(format!("{name},{},{},{},{}", m[0], m[1], m[2], m[3]));
        // Sanity echo: exponential should win (paper's core observation).
        let _ = outcomes; // bitwidths not needed here
    }
    save_csv(
        &format!("table{}_rss_{which}", if acts { 1 } else { 2 }),
        "model,normal,exponential,pareto,uniform",
        &rows,
    )?;
    Ok(s)
}

/// Figs. 1 & 2: histogram + fitted exponential for a representative layer
/// (CSV only; the figure itself is a plot of these series).
pub fn figure_fit(acts: bool) -> Result<String> {
    let fig = if acts { 1 } else { 2 };
    let mut out = format!("Figure {fig}: empirical density vs exponential fit (CSV series)\n");
    for (model, layer_name) in [("alexnet_mini", "conv2"), ("transformer_mini", "dec1.ff2")] {
        let bundle = ModelBundle::load(model)?;
        let input = bundle.calibration_input();
        let layer = input
            .layers
            .iter()
            .find(|l| l.name == layer_name)
            .unwrap_or(&input.layers[0]);
        let t = if acts { &layer.acts } else { &layer.weights };
        let rep = fit_distributions(t);
        let pred = rep.predicted(DistKind::Exponential);
        let rows: Vec<String> = rep
            .centers
            .iter()
            .zip(&rep.density)
            .zip(&pred)
            .map(|((c, d), p)| format!("{c},{d},{p}"))
            .collect();
        let csv = format!("fig{fig}_{model}_{}", layer.name.replace('.', "_"));
        save_csv(&csv, "bin_center,empirical_density,exponential_fit", &rows)?;
        let rss = rep.rss_of(DistKind::Exponential);
        let _ =
            writeln!(out, "  {model}/{}: exp-fit RSS = {rss:.4}  → reports/{csv}.csv", layer.name);
    }
    Ok(out)
}

/// Table III: execution time (ms) of FC layers, INT8 vs DNA-TEQ counting.
pub fn table3(quick: bool) -> Result<String> {
    let sizes = [1024usize, 2048, 4096];
    let target_ms = if quick { 120 } else { 600 };
    let mut s =
        String::from("Table III: FC execution time (ms), INT8 SIMD-baseline vs DNA-TEQ counting\n");
    let _ = writeln!(
        s,
        "{:<22} {:>14} {:>14} {:>14}",
        "Scheme", "FC(1024,1024)", "FC(2048,2048)", "FC(4096,4096)"
    );
    let mut rng = SplitMix64::new(0xF00D);
    let mut int8_ms = Vec::new();
    let mut dna3_ms = Vec::new();
    let mut dna4_ms = Vec::new();
    for &n in &sizes {
        let w = Tensor::rand_signed_exponential(&[n, n], 4.0, &mut rng);
        let x = Tensor::rand_signed_exponential(&[1, n], 1.0, &mut rng);
        let int8 = Int8Fc::new(&w, None);
        let r = bench(&format!("int8-{n}"), target_ms, || {
            black_box(int8.forward(&x));
        });
        int8_ms.push(r.per_iter_ms());
        for (bits, acc) in [(3u8, &mut dna3_ms), (4u8, &mut dna4_ms)] {
            let wp = ExpQuantParams::init_for_tensor(&w, bits);
            let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: bits };
            ap.refit_scale_offset(&x);
            let fc = CountingFc::new(&w, wp, ap, None);
            let r = bench(&format!("dnateq{bits}-{n}"), target_ms, || {
                black_box(fc.forward(&x));
            });
            acc.push(r.per_iter_ms());
        }
    }
    let ws = |s: &mut String, scheme: &str, ms: &[f64]| {
        let _ = writeln!(s, "{:<22} {:>14.3} {:>14.3} {:>14.3}", scheme, ms[0], ms[1], ms[2]);
    };
    ws(&mut s, "Uniform INT8", &int8_ms);
    ws(&mut s, "DNA-TEQ 3-bit", &dna3_ms);
    ws(&mut s, "DNA-TEQ 4-bit", &dna4_ms);
    let rows = vec![
        format!("int8,{},{},{}", int8_ms[0], int8_ms[1], int8_ms[2]),
        format!("dnateq3,{},{},{}", dna3_ms[0], dna3_ms[1], dna3_ms[2]),
        format!("dnateq4,{},{},{}", dna4_ms[0], dna4_ms[1], dna4_ms[2]),
    ];
    save_csv("table3_simd_fc", "scheme,fc1024,fc2048,fc4096", &rows)?;
    Ok(s)
}

/// Table IV: accumulated RMAE + accuracy loss, uniform (same bits) vs
/// DNA-TEQ.
pub fn table4(outcomes: &BTreeMap<String, CalibOutcome>) -> Result<String> {
    let mut s = String::from("Table IV: error/loss comparison between quantization schemes\n");
    let _ =
        writeln!(s, "{:<14} {:>22} {:>22}", "DNN", "Uniform (RMAE/loss)", "DNA-TEQ (RMAE/loss)");
    let mut rows = Vec::new();
    for name in MODELS {
        let o = &outcomes[name];
        let bundle = ModelBundle::load(name)?;
        // Uniform at the SAME per-layer bitwidths DNA-TEQ searched.
        let input = bundle.calibration_input();
        let mut uni_rmae = 0.0f64;
        for layer in &input.layers {
            if let Some(lq) = o.config.layer(&layer.name) {
                let wq = crate::dnateq::UniformParams::calibrate(&layer.weights, lq.n_bits);
                let aq = crate::dnateq::UniformParams::calibrate(&layer.acts, lq.n_bits);
                uni_rmae += wq.rmae(&layer.weights) + aq.rmae(&layer.acts);
            }
        }
        let dna_rmae = o.config.accumulated_rmae();
        let uni_loss = o.fp32_accuracy - o.uniform_matched_accuracy;
        let dna_loss = o.fp32_accuracy - o.dnateq_accuracy;
        let _ = writeln!(
            s,
            "{:<14} {:>14.3}/{:>6.2}% {:>14.3}/{:>6.2}%",
            name, uni_rmae, uni_loss * 100.0, dna_rmae, dna_loss * 100.0
        );
        rows.push(format!("{name},{uni_rmae},{uni_loss},{dna_rmae},{dna_loss}"));
    }
    let header4 = "model,uniform_rmae,uniform_loss,dnateq_rmae,dnateq_loss";
    save_csv("table4_error_loss", header4, &rows)?;
    Ok(s)
}

/// Table V: accuracy / avg bitwidth / compression ratio.
pub fn table5(outcomes: &BTreeMap<String, CalibOutcome>) -> Result<String> {
    let mut s = String::from("Table V: DNA-TEQ accuracy, average bitwidth and compression ratio\n");
    let _ = writeln!(
        s,
        "{:<18} {:>18} {:>12} {:>10} {:>14}",
        "Network", "Baseline(FP32/INT8)", "DNA-TEQ", "AVG bits", "Compression %"
    );
    let mut rows = Vec::new();
    for name in MODELS {
        let o = &outcomes[name];
        let bits = o.config.avg_bitwidth();
        let comp = o.config.compression_ratio() * 100.0;
        let (fp, i8v, dna) = if name == "transformer_mini" {
            // Report BLEU alongside token accuracy for the translator.
            (
                format!("{:.3}", o.fp32_accuracy),
                format!("{:.3}", o.int8_accuracy),
                match o.dnateq_bleu {
                    Some(b) => format!("{:.3} ({b:.1} BLEU)", o.dnateq_accuracy),
                    None => format!("{:.3}", o.dnateq_accuracy),
                },
            )
        } else {
            (
                format!("{:.4}", o.fp32_accuracy),
                format!("{:.4}", o.int8_accuracy),
                format!("{:.4}", o.dnateq_accuracy),
            )
        };
        let _ = writeln!(
            s,
            "{:<18} {:>11}/{:>7} {:>12} {:>10.2} {:>14.2}",
            name, fp, i8v, dna, bits, comp
        );
        rows.push(format!(
            "{name},{},{},{},{bits},{comp}",
            o.fp32_accuracy, o.int8_accuracy, o.dnateq_accuracy
        ));
    }
    let avg_bits: f64 =
        MODELS.iter().map(|m| outcomes[*m].config.avg_bitwidth()).sum::<f64>()
            / MODELS.len() as f64;
    let _ = writeln!(
        s,
        "  average bitwidth across DNNs: {avg_bits:.2} (compression {:.1}% vs INT8)",
        (1.0 - avg_bits / 8.0) * 100.0
    );
    let header5 = "model,fp32,int8,dnateq,avg_bits,compression_pct";
    save_csv("table5_accuracy_compression", header5, &rows)?;
    Ok(s)
}

/// Resolve the full-size workload + transplanted bits for a mini config.
fn sim_workload(name: &str, cfg: &QuantConfig) -> (Vec<crate::accel::LayerShape>, Vec<u8>) {
    let shapes = match name {
        "alexnet_mini" => alexnet_shapes(),
        "resnet_mini" => resnet50_shapes(),
        _ => transformer_shapes(25),
    };
    let bits = assign_bits(&shapes, cfg, 5);
    (shapes, bits)
}

/// Figs. 8 & 9: accelerator speedups + normalized energy savings.
pub fn figures_8_9(outcomes: &BTreeMap<String, CalibOutcome>) -> Result<String> {
    let cfg = AccelConfig::default();
    let em = EnergyModel::default();
    let mut s =
        String::from("Figures 8 & 9: DNA-TEQ accelerator vs INT8 baseline (full-size workloads)\n");
    let _ =
        writeln!(s, "{:<18} {:>10} {:>16} {:>12}", "DNN", "Speedup", "Energy savings", "avg bits");
    let mut speedups = Vec::new();
    let mut savings = Vec::new();
    let mut rows = Vec::new();
    for name in MODELS {
        let o = &outcomes[name];
        let (shapes, bits) = sim_workload(name, &o.config);
        let cmp = Comparison::run(&cfg, &em, &shapes, &bits);
        let (sp, en) = (cmp.speedup(), cmp.energy_savings());
        let avg = bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64;
        let _ = writeln!(s, "{:<18} {:>10.2} {:>16.2} {:>12.2}", name, sp, en, avg);
        rows.push(format!("{name},{sp},{en},{avg}"));
        speedups.push(sp);
        savings.push(en);
    }
    let _ =
        writeln!(s, "{:<18} {:>10.2} {:>16.2}", "geomean", geomean(&speedups), geomean(&savings));
    rows.push(format!("geomean,{},{},", geomean(&speedups), geomean(&savings)));
    save_csv("fig8_9_accelerator", "model,speedup,energy_savings,avg_bits", &rows)?;
    Ok(s)
}

/// Fig. 10: dynamic energy of a counting step per bitwidth vs INT8 MAC.
pub fn figure10() -> Result<String> {
    let em = EnergyModel::default();
    let mut s = String::from("Figure 10: dynamic energy per counting step (pJ)\n");
    let mut rows = Vec::new();
    for n in 3..=7u8 {
        let e = em.counting_step_pj(n);
        let _ = writeln!(s, "  {n}-bit counting step : {e:>7.3} pJ");
        rows.push(format!("dnateq{n},{e}"));
    }
    let _ = writeln!(s, "  INT8 MAC (baseline)  : {:>7.3} pJ", em.mac_int8_pj);
    rows.push(format!("int8_mac,{}", em.mac_int8_pj));
    save_csv("fig10_counting_energy", "op,energy_pj", &rows)?;
    Ok(s)
}

/// Fig. 11: Thr_w sensitivity sweep (accuracy loss + avg bitwidth).
pub fn figure11(outcomes: &BTreeMap<String, CalibOutcome>) -> Result<String> {
    let mut s =
        String::from("Figure 11: accuracy loss vs error threshold (avg bitwidth annotated)\n");
    let mut rows = Vec::new();
    for name in MODELS {
        let o = &outcomes[name];
        let _ = writeln!(s, "  {name}:");
        for p in &o.sweep {
            let _ = writeln!(
                s,
                "    Thr_w {:>5.2}%  loss {:>6.3}%  avg bits {:>5.2}  compression {:>5.1}%",
                p.thr_w * 100.0,
                p.accuracy_loss * 100.0,
                p.avg_bitwidth,
                p.compression_ratio * 100.0
            );
            rows.push(format!(
                "{name},{},{},{},{}",
                p.thr_w, p.accuracy_loss, p.avg_bitwidth, p.compression_ratio
            ));
        }
    }
    save_csv("fig11_threshold_sweep", "model,thr_w,accuracy_loss,avg_bits,compression", &rows)?;
    Ok(s)
}

/// §VI-D area comparison.
pub fn area_report() -> String {
    let a = AreaModel::default();
    format!(
        "Area (§VI-D, 32nm logic die, 16 PEs)\n  \
         baseline INT8 total : {:.2} mm² (MACs {:.2} mm²)\n  \
         DNA-TEQ total       : {:.2} mm² (Counter-Sets {:.2} mm²)\n  \
         saving              : {:.1}%\n",
        a.baseline_total_mm2,
        a.baseline_macs_mm2,
        a.dnateq_total_mm2,
        a.dnateq_cs_mm2,
        a.saving() * 100.0
    )
}

/// Per-layer bitwidth histogram — supports the §VI-D "layers at 7-bit
/// < 3%" observation.
pub fn bitwidth_histogram(outcomes: &BTreeMap<String, CalibOutcome>) -> String {
    let mut s = String::from("Per-layer bitwidth distribution\n");
    for name in MODELS {
        let h = outcomes[name].config.bitwidth_histogram();
        let total: usize = h.iter().sum();
        let _ = writeln!(
            s,
            "  {:<18} 3b:{:>2} 4b:{:>2} 5b:{:>2} 6b:{:>2} 7b:{:>2}  (7-bit share {:.1}%)",
            name, h[3], h[4], h[5], h[6], h[7],
            100.0 * h[7] as f64 / total.max(1) as f64
        );
    }
    s
}

/// §VI-C scheme: one `Scheme` label for CSV naming.
pub fn scheme_name(s: Scheme) -> &'static str {
    s.name()
}
