//! Regeneration of every exhibit in the paper's evaluation (§VI):
//! Tables I–V, Figures 1/2/8/9/10/11 and the §VI-D area/overhead
//! numbers. [`pipeline`] runs (and caches) the Fig.-3 calibration per
//! model; [`tables`] formats each exhibit and writes CSVs under
//! `artifacts/reports/`.

pub mod pipeline;
pub mod tables;

pub use pipeline::{calibrate, calibrate_or_load, CalibOutcome, ModelBundle, MODELS};
