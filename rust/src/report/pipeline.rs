//! Experiment pipeline: load artifacts, run (and cache) the DNA-TEQ
//! calibration for each model, and expose everything the table/figure
//! emitters need.

use crate::dataset::{ImageDataset, SeqDataset};
use crate::dnateq::{
    calibrate_model, CalibrationInput, CalibrationOptions, PlanStore, QuantConfig, SweepPoint,
};
use crate::nn::{
    collect_image_calibration, collect_seq_calibration, eval_classifier, eval_translator,
    eval_translator_bleu, AlexNetMini, ExecPlan, ResNetMini, TransformerMini, WeightMap,
};
use crate::util::Json;
use crate::artifact_path;
use anyhow::{Context, Result};

pub const MODELS: [&str; 3] = ["alexnet_mini", "resnet_mini", "transformer_mini"];

/// Everything loaded from `artifacts/` for one model.
pub enum ModelBundle {
    Alex { model: AlexNetMini, calib: ImageDataset, eval: ImageDataset },
    Res { model: ResNetMini, calib: ImageDataset, eval: ImageDataset },
    Tr { model: TransformerMini, calib: SeqDataset, eval: SeqDataset },
}

impl ModelBundle {
    /// Load a model + its calibration/eval splits from artifacts.
    pub fn load(name: &str) -> Result<Self> {
        let wdir = artifact_path(&format!("models/{name}"));
        let w = WeightMap::load_dir(&wdir)?;
        let data = artifact_path("data");
        Ok(match name {
            "alexnet_mini" => ModelBundle::Alex {
                model: AlexNetMini::from_weights(&w)?,
                calib: ImageDataset::load(&data, "calib")?,
                eval: ImageDataset::load(&data, "eval")?,
            },
            "resnet_mini" => ModelBundle::Res {
                model: ResNetMini::from_weights(&w)?,
                calib: ImageDataset::load(&data, "calib")?,
                eval: ImageDataset::load(&data, "eval")?,
            },
            "transformer_mini" => ModelBundle::Tr {
                model: TransformerMini::from_weights(&w)?,
                calib: SeqDataset::load(&data, "calib")?,
                eval: SeqDataset::load(&data, "eval")?,
            },
            other => anyhow::bail!("unknown model `{other}`"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelBundle::Alex { .. } => "alexnet_mini",
            ModelBundle::Res { .. } => "resnet_mini",
            ModelBundle::Tr { .. } => "transformer_mini",
        }
    }

    /// The paper's accuracy metric for this model, under a plan.
    pub fn accuracy(&self, plan: &ExecPlan, subset: usize) -> f64 {
        match self {
            ModelBundle::Alex { model, eval, .. } => {
                eval_classifier(model, &eval.take(subset), plan)
            }
            ModelBundle::Res { model, eval, .. } => {
                eval_classifier(model, &eval.take(subset), plan)
            }
            ModelBundle::Tr { model, eval, .. } => eval_translator(model, &eval.take(subset), plan),
        }
    }

    /// Step-1 trace collection (Fig. 3).
    pub fn calibration_input(&self) -> CalibrationInput {
        match self {
            ModelBundle::Alex { model, calib, .. } => collect_image_calibration(model, calib),
            ModelBundle::Res { model, calib, .. } => collect_image_calibration(model, calib),
            ModelBundle::Tr { model, calib, .. } => collect_seq_calibration(model, calib),
        }
    }

    /// Build an exec plan of each scheme against this model.
    pub fn plan_exp(&self, cfg: &QuantConfig) -> ExecPlan {
        match self {
            ModelBundle::Alex { model, .. } => ExecPlan::exp(model, cfg),
            ModelBundle::Res { model, .. } => ExecPlan::exp(model, cfg),
            ModelBundle::Tr { model, .. } => ExecPlan::exp(model, cfg),
        }
    }

    pub fn plan_uniform_matched(&self, cfg: &QuantConfig) -> ExecPlan {
        match self {
            ModelBundle::Alex { model, .. } => ExecPlan::uniform_matched(model, cfg),
            ModelBundle::Res { model, .. } => ExecPlan::uniform_matched(model, cfg),
            ModelBundle::Tr { model, .. } => ExecPlan::uniform_matched(model, cfg),
        }
    }

    pub fn plan_int8(&self) -> ExecPlan {
        match self {
            ModelBundle::Alex { model, .. } => ExecPlan::int8(model),
            ModelBundle::Res { model, .. } => ExecPlan::int8(model),
            ModelBundle::Tr { model, .. } => ExecPlan::int8(model),
        }
    }

    /// BLEU for the translator (Table V), None for classifiers.
    pub fn bleu(&self, plan: &ExecPlan, subset: usize) -> Option<f64> {
        match self {
            ModelBundle::Tr { model, eval, .. } => {
                Some(eval_translator_bleu(model, &eval.take(subset), plan))
            }
            _ => None,
        }
    }
}

/// Eval-set slice used inside the Thr_w controller (full eval set is used
/// for the final reported numbers).
pub const SWEEP_EVAL_SUBSET: usize = 160;
/// Full-eval subset for final reported accuracies.
pub const FINAL_EVAL_SUBSET: usize = 512;

/// Complete calibration outcome for one model (cached as JSON).
#[derive(Clone, Debug)]
pub struct CalibOutcome {
    pub config: QuantConfig,
    pub sweep: Vec<SweepPoint>,
    pub fp32_accuracy: f64,
    pub dnateq_accuracy: f64,
    pub int8_accuracy: f64,
    pub uniform_matched_accuracy: f64,
    pub dnateq_bleu: Option<f64>,
    pub fp32_bleu: Option<f64>,
}

/// Run the full Fig.-3 pipeline for one model.
pub fn calibrate(bundle: &ModelBundle, opts: &CalibrationOptions) -> CalibOutcome {
    let input = bundle.calibration_input();
    let fp32_plan = ExecPlan::fp32();
    let baseline_sweep = bundle.accuracy(&fp32_plan, SWEEP_EVAL_SUBSET);
    let report = calibrate_model(&input, baseline_sweep, opts, |cfg| {
        bundle.accuracy(&bundle.plan_exp(cfg), SWEEP_EVAL_SUBSET)
    });

    let cfg = report.config.clone();
    let fp32_accuracy = bundle.accuracy(&fp32_plan, FINAL_EVAL_SUBSET);
    let dnateq_accuracy = bundle.accuracy(&bundle.plan_exp(&cfg), FINAL_EVAL_SUBSET);
    let int8_accuracy = bundle.accuracy(&bundle.plan_int8(), FINAL_EVAL_SUBSET);
    let uniform_matched_accuracy =
        bundle.accuracy(&bundle.plan_uniform_matched(&cfg), FINAL_EVAL_SUBSET);
    let bleu_subset = 96;
    let dnateq_bleu = bundle.bleu(&bundle.plan_exp(&cfg), bleu_subset);
    let fp32_bleu = bundle.bleu(&fp32_plan, bleu_subset);

    CalibOutcome {
        config: cfg,
        sweep: report.sweep,
        fp32_accuracy,
        dnateq_accuracy,
        int8_accuracy,
        uniform_matched_accuracy,
        dnateq_bleu,
        fp32_bleu,
    }
}

impl CalibOutcome {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        let sweep: Vec<Json> = self
            .sweep
            .iter()
            .map(|s| {
                let mut p = Json::obj();
                p.set("thr_w", s.thr_w)
                    .set("accuracy", s.accuracy)
                    .set("accuracy_loss", s.accuracy_loss)
                    .set("avg_bitwidth", s.avg_bitwidth)
                    .set("compression_ratio", s.compression_ratio);
                p
            })
            .collect();
        o.set("config", self.config.to_json())
            .set("sweep", sweep)
            .set("fp32_accuracy", self.fp32_accuracy)
            .set("dnateq_accuracy", self.dnateq_accuracy)
            .set("int8_accuracy", self.int8_accuracy)
            .set("uniform_matched_accuracy", self.uniform_matched_accuracy);
        if let (Some(db), Some(fb)) = (self.dnateq_bleu, self.fp32_bleu) {
            o.set("dnateq_bleu", db).set("fp32_bleu", fb);
        }
        o
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let sweep = j
            .req("sweep")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(SweepPoint {
                    thr_w: p.req("thr_w")?.as_f64()?,
                    accuracy: p.req("accuracy")?.as_f64()?,
                    accuracy_loss: p.req("accuracy_loss")?.as_f64()?,
                    avg_bitwidth: p.req("avg_bitwidth")?.as_f64()?,
                    compression_ratio: p.req("compression_ratio")?.as_f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            config: QuantConfig::from_json(j.req("config")?)?,
            sweep,
            fp32_accuracy: j.req("fp32_accuracy")?.as_f64()?,
            dnateq_accuracy: j.req("dnateq_accuracy")?.as_f64()?,
            int8_accuracy: j.req("int8_accuracy")?.as_f64()?,
            uniform_matched_accuracy: j.req("uniform_matched_accuracy")?.as_f64()?,
            dnateq_bleu: j.get("dnateq_bleu").and_then(|v| v.as_f64().ok()),
            fp32_bleu: j.get("fp32_bleu").and_then(|v| v.as_f64().ok()),
        })
    }
}

/// Run or load the cached calibration for `name`.
///
/// Either way, the accepted [`QuantConfig`] is mirrored into the
/// versioned plan store (`artifacts/plans/<model>/<version>.json`) so
/// the serving registry and the `plans` CLI always see every calibrated
/// plan. Mirroring is idempotent: a plan whose content checksum matches
/// the latest stored version does not mint a new one.
pub fn calibrate_or_load(
    name: &str,
    force: bool,
    opts: &CalibrationOptions,
) -> Result<CalibOutcome> {
    let cache = artifact_path(&format!("configs/{name}.json"));
    if !force && cache.exists() {
        let outcome = CalibOutcome::from_json(&Json::read_file(&cache)?)
            .context("parsing cached calibration")?;
        // Bootstrap-only mirror: seed the plan store if this model has no
        // stored versions yet (pre-store caches). Never write on a cache
        // hit otherwise — the store's latest version is authoritative
        // (e.g. after a `swap`), and a load must stay read-only.
        let store = PlanStore::open_default();
        if store.versions(name).map(|v| v.is_empty()).unwrap_or(false) {
            if let Err(e) = store.save_next(&outcome.config) {
                eprintln!("[calibrate] {name}: plan-store mirror skipped: {e:#}");
            }
        }
        return Ok(outcome);
    }
    let bundle = ModelBundle::load(name)?;
    eprintln!("[calibrate] {name}: running Fig.-3 pipeline (cached afterwards)");
    let outcome = calibrate(&bundle, opts);
    outcome.to_json().write_file(&cache)?;
    let store = PlanStore::open_default();
    let version = store.save_next(&outcome.config)?;
    eprintln!(
        "[calibrate] {name}: plan stored as {} (checksum {})",
        store.path(name, version).display(),
        outcome.config.checksum_hex()
    );
    Ok(outcome)
}
