//! Real PJRT runtime (`--features pjrt`; requires a vendored `xla` crate).
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. Text (not
//! serialized protos) is the interchange format — jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.

use super::ArgValue;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::path::Path;

impl ArgValue {
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            ArgValue::F32(shape, data) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            ArgValue::I32(shape, data) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

/// A PJRT client (CPU) that compiles model executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.file_stem().and_then(|s| s.to_str()).unwrap_or("model").to_string(),
        })
    }
}

/// A compiled model artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with the given arguments; returns the tuple elements as
    /// f32 tensors (all our artifacts are lowered with
    /// `return_tuple=True`).
    pub fn run(&self, args: &[ArgValue]) -> Result<Vec<Tensor>> {
        let literals = args.iter().map(|a| a.to_literal()).collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit
                    .array_shape()
                    .map_err(|e| anyhow::anyhow!("{e:?}"))?
                    .dims()
                    .iter()
                    .map(|&d| d as usize)
                    .collect::<Vec<_>>();
                // Outputs may be f32 or i32; widen i32 to f32 tensors.
                let data: Vec<f32> = match lit.to_vec::<f32>() {
                    Ok(v) => v,
                    Err(_) => lit
                        .to_vec::<i32>()
                        .map_err(|e| anyhow::anyhow!("{e:?}"))?
                        .into_iter()
                        .map(|x| x as f32)
                        .collect(),
                };
                Ok(Tensor::from_vec(&shape, data))
            })
            .collect()
    }

    /// Convenience: single f32 input, single output.
    pub fn run1(&self, input: &Tensor) -> Result<Tensor> {
        let mut out = self.run(&[ArgValue::from_tensor(input)])?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        Ok(out.remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Executable round-trips against real artifacts live in
    // rust/tests/integration.rs; these tests are artifact-free.

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(rt.device_count() >= 1);
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn loading_missing_file_errors() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load_hlo("/nonexistent/model.hlo.txt").is_err());
    }
}
