//! PJRT runtime: load AOT HLO-text artifacts and execute them on the hot
//! path — python is never involved after `make artifacts`.
//!
//! The real loader lives in [`pjrt`] behind the `pjrt` cargo feature (it
//! needs a vendored `xla` crate that is not part of the offline build).
//! Without the feature this module keeps the same API surface as a stub:
//! [`Runtime::cpu`] returns an error, so every PJRT-backed path degrades
//! gracefully at runtime while the rest of the crate (serving
//! coordinator, counting engines, simulator) is fully functional.

use crate::tensor::Tensor;
use anyhow::Result;
use std::path::Path;

#[cfg(feature = "pjrt")]
mod pjrt;

/// One argument to a compiled executable.
#[derive(Clone, Debug)]
pub enum ArgValue {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl ArgValue {
    pub fn from_tensor(t: &Tensor) -> Self {
        ArgValue::F32(t.shape().to_vec(), t.data().to_vec())
    }

    pub fn from_ids(shape: &[usize], ids: &[usize]) -> Self {
        ArgValue::I32(shape.to_vec(), ids.iter().map(|&x| x as i32).collect())
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};

/// Stub PJRT client: the `pjrt` feature is off, so construction fails
/// with an actionable error and nothing downstream can reach
/// [`Executable::run`].
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    _priv: (),
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always errors: the crate was built without the `pjrt` feature.
    pub fn cpu() -> Result<Self> {
        anyhow::bail!(
            "PJRT runtime unavailable: built without the `pjrt` cargo feature \
             (add a vendored `xla` path dependency to rust/Cargo.toml, then \
             rebuild with `--features pjrt`)"
        )
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn load_hlo<P: AsRef<Path>>(&self, _path: P) -> Result<Executable> {
        anyhow::bail!("PJRT runtime unavailable: built without the `pjrt` cargo feature")
    }
}

/// Stub executable: cannot be constructed (its only constructor is
/// [`Runtime::load_hlo`], which always errors), so the methods exist for
/// type-checking only and can never actually run.
#[cfg(not(feature = "pjrt"))]
pub struct Executable {
    #[allow(dead_code)]
    _never: std::convert::Infallible,
    pub name: String,
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    pub fn run(&self, _args: &[ArgValue]) -> Result<Vec<Tensor>> {
        unreachable!("stub Executable cannot be constructed")
    }

    pub fn run1(&self, _input: &Tensor) -> Result<Tensor> {
        unreachable!("stub Executable cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argvalue_constructors() {
        let t = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        match ArgValue::from_tensor(&t) {
            ArgValue::F32(shape, data) => {
                assert_eq!(shape, vec![2, 2]);
                assert_eq!(data.len(), 4);
            }
            _ => panic!("wrong variant"),
        }
        match ArgValue::from_ids(&[1, 3], &[1, 2, 3]) {
            ArgValue::I32(shape, data) => {
                assert_eq!(shape, vec![1, 3]);
                assert_eq!(data, vec![1, 2, 3]);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_errors_actionably() {
        let err = Runtime::cpu().err().expect("stub must not construct");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
    }
}
