//! Small self-contained substrates.
//!
//! The build is fully offline against a minimal vendored crate set, so the
//! facilities a production crate would normally pull in (a JSON codec, a
//! data-parallel map, a micro-benchmark harness, temp-dir helpers, a
//! property-testing loop) are implemented here from scratch.

pub mod bench;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod tmp;

pub use bench::{bench, write_json, BenchResult};
pub use json::Json;
pub use parallel::{chunk_ranges, parallel_map, parallel_row_blocks, suggested_pieces};
pub use tmp::TempDir;
