//! Small self-contained substrates.
//!
//! The build is fully offline against a minimal vendored crate set, so the
//! facilities a production crate would normally pull in (a JSON codec, a
//! data-parallel map, a micro-benchmark harness, temp-dir helpers, a
//! property-testing loop) are implemented here from scratch.

pub mod bench;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod tmp;

pub use bench::{bench, write_json, BenchResult};
pub use json::Json;
pub use parallel::{chunk_ranges, parallel_map, parallel_row_blocks, suggested_pieces};
pub use tmp::TempDir;

/// FNV-1a 64-bit hash — the content checksum of plan artifacts
/// ([`crate::dnateq::QuantConfig`]). Stable across platforms and rust
/// versions (pure arithmetic, no dependency on `Hasher` internals).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a64_known_vectors() {
        // Reference values for the canonical FNV-1a 64 test strings.
        assert_eq!(super::fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(super::fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
