//! Micro-benchmark harness (criterion stand-in).
//!
//! Warm-up, calibrated iteration count targeting a fixed measurement
//! window, and robust statistics (median + MAD) over per-batch timings.
//! Used by every `rust/benches/*` target and by `repro report` when it
//! regenerates the paper's timing tables. Results serialize to JSON
//! ([`BenchResult::to_json`] / [`write_json`]) so BENCH output is
//! machine-readable alongside the text summaries.

use super::json::Json;
use std::path::Path;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Median wall time per iteration.
    pub median: Duration,
    /// Mean wall time per iteration.
    pub mean: Duration,
    /// Median absolute deviation (robust spread).
    pub mad: Duration,
    /// Total iterations measured.
    pub iters: u64,
    /// SIMD backend the case ran under (`None` for cases where dispatch
    /// is irrelevant); emitted into the bench JSON so `BENCH_*.json`
    /// trajectories are attributable per backend.
    pub backend: Option<String>,
}

impl BenchResult {
    pub fn per_iter_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }

    pub fn per_iter_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }

    /// Tag this result with the SIMD backend it ran under.
    pub fn with_backend(mut self, backend: &str) -> Self {
        self.backend = Some(backend.to_string());
        self
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        let mut line = format!(
            "{:<40} {:>12.4} ms/iter  (±{:.4} ms MAD, {} iters)",
            self.name,
            self.per_iter_ms(),
            self.mad.as_secs_f64() * 1e3,
            self.iters
        );
        if let Some(b) = &self.backend {
            line.push_str(&format!("  [{b}]"));
        }
        line
    }

    /// Machine-readable form (times in milliseconds per iteration).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("median_ms", self.per_iter_ms())
            .set("mean_ms", self.mean.as_secs_f64() * 1e3)
            .set("mad_ms", self.mad.as_secs_f64() * 1e3)
            .set("iters", self.iters);
        if let Some(b) = &self.backend {
            o.set("backend", b.as_str());
        }
        o
    }
}

/// Write a bench run as a pretty-printed JSON array (creating parent
/// directories) — the machine-readable companion of the text summaries.
pub fn write_json<P: AsRef<Path>>(path: P, results: &[BenchResult]) -> anyhow::Result<()> {
    let arr = Json::Arr(results.iter().map(|r| r.to_json()).collect());
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path.as_ref(), arr.encode_pretty())?;
    Ok(())
}

/// Benchmark `f`, targeting ~`target_ms` of measurement after a short
/// warm-up. The closure should perform one logical iteration.
pub fn bench(name: &str, target_ms: u64, mut f: impl FnMut()) -> BenchResult {
    // Warm-up & cost estimate: run until 10% of target or 3 iterations.
    let warm_budget = Duration::from_millis((target_ms / 10).max(5));
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_start.elapsed() < warm_budget || warm_iters < 3 {
        f();
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    let est_per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

    // Choose a batch size so one batch is ~1/30 of the window, then run
    // batches until the window closes (≥5 batches for stats).
    let target = Duration::from_millis(target_ms);
    let batch =
        ((target.as_secs_f64() / 30.0 / est_per_iter.max(1e-9)).ceil() as u64).clamp(1, 1 << 24);

    let mut samples: Vec<f64> = Vec::new();
    let mut total_iters = 0u64;
    let start = Instant::now();
    while start.elapsed() < target || samples.len() < 5 {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64() / batch as f64;
        samples.push(dt);
        total_iters += batch;
        if samples.len() > 10_000 {
            break;
        }
    }

    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];

    BenchResult {
        name: name.to_string(),
        median: Duration::from_secs_f64(median),
        mean: Duration::from_secs_f64(mean),
        mad: Duration::from_secs_f64(mad),
        iters: total_iters,
        backend: None,
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_sleep() {
        let r = bench("sleep1ms", 60, || std::thread::sleep(Duration::from_millis(1)));
        let ms = r.per_iter_ms();
        assert!((0.9..5.0).contains(&ms), "measured {ms} ms");
        assert!(r.iters >= 5);
    }

    #[test]
    fn fast_closures_get_batched() {
        let mut acc = 0u64;
        let r = bench("add", 30, || {
            acc = acc.wrapping_add(1);
            black_box(acc);
        });
        assert!(r.iters > 1000, "expected large iteration count, got {}", r.iters);
        assert!(r.median < Duration::from_micros(10));
    }

    #[test]
    fn summary_contains_name() {
        let r = bench("mycase", 20, || {
            black_box(3u32.pow(7));
        });
        assert!(r.summary().contains("mycase"));
    }

    #[test]
    fn json_roundtrip_and_file_emission() {
        use crate::util::TempDir;
        let r = BenchResult {
            name: "fc1024 b=8".into(),
            median: Duration::from_micros(1500),
            mean: Duration::from_micros(1600),
            mad: Duration::from_micros(20),
            iters: 42,
            backend: None,
        }
        .with_backend("avx512");
        let j = r.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "fc1024 b=8");
        assert!((j.get("median_ms").unwrap().as_f64().unwrap() - 1.5).abs() < 1e-9);
        assert_eq!(j.get("iters").unwrap().as_usize().unwrap(), 42);
        assert_eq!(j.get("backend").unwrap().as_str().unwrap(), "avx512");
        assert!(r.summary().ends_with("[avx512]"));

        let dir = TempDir::new().unwrap();
        let path = dir.path().join("reports/bench.json");
        write_json(&path, &[r]).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
    }
}
