//! Lightweight property-testing loop (proptest stand-in).
//!
//! `for_all(cases, gen, check)` drives `check` over `cases` generated
//! inputs from a deterministic stream and, on failure, retries with a
//! simple halving shrink over the generator's size hint before panicking
//! with the seed so the case can be replayed.

use crate::tensor::SplitMix64;

/// Configuration of a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 128, seed: 0xDA7A_7E99 }
    }
}

/// Run `check` against `cases` inputs produced by `gen`. The generator
/// receives the RNG plus a size parameter ramping from small to large so
/// early failures are small. Panics with the failing seed/case index.
pub fn for_all<T: std::fmt::Debug>(
    cfg: PropConfig,
    mut gen: impl FnMut(&mut SplitMix64, usize) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        // Fresh, addressable stream per case → replayable failures.
        let mut rng = SplitMix64::new(cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9));
        // Ramp size 1..=64 over the run.
        let size = 1 + (case * 64) / cfg.cases.max(1);
        let input = gen(&mut rng, size);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed at case {case} (seed {:#x}, size {size}): {msg}\ninput: {input:?}",
                cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_a_true_property() {
        for_all(
            PropConfig::default(),
            |rng, size| (0..size).map(|_| rng.next_f32()).collect::<Vec<f32>>(),
            |xs| {
                if xs.iter().all(|&x| (0.0..1.0).contains(&x)) {
                    Ok(())
                } else {
                    Err("value out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_a_false_property() {
        for_all(
            PropConfig { cases: 50, seed: 1 },
            |rng, _| rng.next_below(10),
            |&x| if x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) },
        );
    }

    #[test]
    fn size_ramps_up() {
        let mut max_seen = 0usize;
        for_all(
            PropConfig { cases: 64, seed: 2 },
            |_, size| size,
            |&s| {
                if s > 0 && s <= 64 {
                    Ok(())
                } else {
                    Err("size out of ramp".into())
                }
            },
        );
        for_all(PropConfig { cases: 64, seed: 3 }, |_, size| size, |&s| {
            max_seen = max_seen.max(s);
            Ok(())
        });
        assert!(max_seen >= 60, "ramp max {max_seen}");
    }
}
