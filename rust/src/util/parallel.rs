//! Data-parallel map over std threads (rayon stand-in).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    /// Set while the current thread is a `parallel_map` worker: nested
    /// calls (e.g. a parallel GEMM inside a parallel dataset-eval chunk)
    /// run serially instead of multiplying thread counts.
    static IN_PARALLEL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Apply `f` to every item of `items` across up to `available_parallelism`
/// worker threads, preserving order. `f` must be `Sync` (called from many
/// threads) and the items are handed out by an atomic work-stealing index,
/// so uneven per-item cost balances well. Calls from inside another
/// `parallel_map` worker degrade to a serial map (the outer call already
/// owns the cores). Calls from independent threads (e.g. two coordinator
/// workers) each spawn up to a core's worth of workers — mild, bounded
/// oversubscription (`callers × cores`) that the OS time-slices; per-call
/// scoped threads join before return, so it never accumulates.
pub fn parallel_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(n);
    if workers <= 1 || IN_PARALLEL_WORKER.with(|flag| flag.get()) {
        return items.iter().map(&f).collect();
    }

    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                IN_PARALLEL_WORKER.with(|flag| flag.set(true));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(&items[i]);
                    *out[i].lock().unwrap() = Some(r);
                }
            });
        }
    });

    out.into_iter().map(|m| m.into_inner().unwrap().expect("worker filled every slot")).collect()
}

/// Split `0..n` into at most `pieces` contiguous, non-empty ranges —
/// the work items handed to [`parallel_map`] by the batched GEMM paths.
pub fn chunk_ranges(n: usize, pieces: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let pieces = pieces.clamp(1, n);
    let per = n.div_ceil(pieces);
    (0..pieces)
        .map(|k| (k * per, ((k + 1) * per).min(n)))
        .filter(|&(lo, hi)| lo < hi)
        .collect()
}

/// Run `kernel` over contiguous ranges of `0..rows` — in parallel when
/// `total_work` supports at least `min_work` units per piece — and
/// gather the per-range `[batch, width]` row-major blocks into one
/// `[batch, rows]` buffer. The shared fan-out/gather scaffolding of the
/// batched GEMM engines; `kernel(j0, j1)` must return a `[batch, j1-j0]`
/// block.
pub fn parallel_row_blocks(
    rows: usize,
    batch: usize,
    total_work: usize,
    min_work: usize,
    kernel: impl Fn(usize, usize) -> Vec<f32> + Sync,
) -> Vec<f32> {
    let ranges = chunk_ranges(rows, suggested_pieces(total_work, min_work));
    let blocks = parallel_map(&ranges, |&(j0, j1)| kernel(j0, j1));
    let mut out = vec![0.0f32; batch * rows];
    for (&(j0, j1), block) in ranges.iter().zip(&blocks) {
        let width = j1 - j0;
        for b in 0..batch {
            out[b * rows + j0..b * rows + j1].copy_from_slice(&block[b * width..(b + 1) * width]);
        }
    }
    out
}

/// How many parallel pieces a workload of `total_work` units supports:
/// keeps at least `min_work` units per piece (1 == stay serial, avoiding
/// thread-spawn overhead on small layers) and caps at 2× the available
/// cores so the atomic work-stealing index can still balance.
pub fn suggested_pieces(total_work: usize, min_work: usize) -> usize {
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    (total_work / min_work.max(1)).clamp(1, workers * 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, |&x| x).is_empty());
    }

    #[test]
    fn single_item() {
        assert_eq!(parallel_map(&[7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn nested_parallel_map_degrades_to_serial_and_stays_correct() {
        let outer: Vec<usize> = (0..8).collect();
        let got = parallel_map(&outer, |&x| {
            let inner: Vec<usize> = (0..50).collect();
            parallel_map(&inner, |&y| x * 100 + y).iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..8).map(|x| (0..50).map(|y| x * 100 + y).sum()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn chunk_ranges_cover_exactly_once() {
        for (n, pieces) in [(10usize, 3usize), (1, 8), (64, 64), (7, 2), (100, 1)] {
            let ranges = chunk_ranges(n, pieces);
            assert!(ranges.len() <= pieces);
            let mut seen = 0usize;
            let mut prev_hi = 0usize;
            for &(lo, hi) in &ranges {
                assert_eq!(lo, prev_hi, "ranges must be contiguous");
                assert!(lo < hi);
                seen += hi - lo;
                prev_hi = hi;
            }
            assert_eq!(seen, n, "n={n} pieces={pieces}");
        }
        assert!(chunk_ranges(0, 4).is_empty());
    }

    #[test]
    fn parallel_row_blocks_gathers_strided_output() {
        // kernel writes value row*1000 + col so the gather is checkable.
        let (rows, batch) = (7, 3);
        let out = parallel_row_blocks(rows, batch, usize::MAX / 4, 1, |j0, j1| {
            let width = j1 - j0;
            let mut block = vec![0.0f32; batch * width];
            for b in 0..batch {
                for (jj, j) in (j0..j1).enumerate() {
                    block[b * width + jj] = (b * 1000 + j) as f32;
                }
            }
            block
        });
        for b in 0..batch {
            for j in 0..rows {
                assert_eq!(out[b * rows + j], (b * 1000 + j) as f32);
            }
        }
    }

    #[test]
    fn suggested_pieces_serial_for_small_work() {
        assert_eq!(suggested_pieces(100, 1_000_000), 1);
        assert!(suggested_pieces(usize::MAX / 2, 1) >= 1);
    }

    #[test]
    fn uneven_work_balances() {
        // Items with wildly different cost still land in the right slots.
        let items: Vec<usize> = (0..64).collect();
        let out = parallel_map(&items, |&x| {
            if x % 7 == 0 {
                // Busy work.
                let mut acc = 0u64;
                for i in 0..200_000u64 {
                    acc = acc.wrapping_add(i ^ x as u64);
                }
                std::hint::black_box(acc);
            }
            x
        });
        assert_eq!(out, items);
    }
}
