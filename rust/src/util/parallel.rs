//! Data-parallel map over std threads (rayon stand-in).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Apply `f` to every item of `items` across up to `available_parallelism`
/// worker threads, preserving order. `f` must be `Sync` (called from many
/// threads) and the items are handed out by an atomic work-stealing index,
/// so uneven per-item cost balances well.
pub fn parallel_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(n);
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }

    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *out[i].lock().unwrap() = Some(r);
            });
        }
    });

    out.into_iter().map(|m| m.into_inner().unwrap().expect("worker filled every slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, |&x| x).is_empty());
    }

    #[test]
    fn single_item() {
        assert_eq!(parallel_map(&[7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn uneven_work_balances() {
        // Items with wildly different cost still land in the right slots.
        let items: Vec<usize> = (0..64).collect();
        let out = parallel_map(&items, |&x| {
            if x % 7 == 0 {
                // Busy work.
                let mut acc = 0u64;
                for i in 0..200_000u64 {
                    acc = acc.wrapping_add(i ^ x as u64);
                }
                std::hint::black_box(acc);
            }
            x
        });
        assert_eq!(out, items);
    }
}
