//! Self-deleting temp directories (tempfile stand-in, test support).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique temp directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> std::io::Result<Self> {
        let pid = std::process::id();
        let seq = COUNTER.fetch_add(1, Ordering::Relaxed);
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!("dnateq-{pid}-{seq}-{nanos}"));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let d = TempDir::new().unwrap();
            p = d.path().to_path_buf();
            assert!(p.is_dir());
            std::fs::write(p.join("x.txt"), b"hi").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn directories_are_unique() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
