//! Minimal JSON codec.
//!
//! A small, dependency-free JSON implementation used for the calibration
//! configs, report emission, and the serving API. Supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP (sufficient for the
//! ASCII configs this crate produces).

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a `BTreeMap` so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects — construction bug).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Typed field access with error context for config parsing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing field `{key}`"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("expected non-negative integer, got {v}");
        }
        Ok(v as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    // ---- encoding ------------------------------------------------------

    /// Compact encoding.
    pub fn encode(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty encoding with 2-space indentation.
    pub fn encode_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    // `-0.0` must take the float path ("-0") so the parse
                    // round-trip is bit-exact (plan checksums rely on it).
                    if *v == v.trunc() && v.abs() < 1e15 && !(*v == 0.0 && v.is_sign_negative()) {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v}");
                    }
                } else {
                    // JSON has no Inf/NaN; emit null (matches serde_json's
                    // lossy behaviour under arbitrary_precision off).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- decoding ------------------------------------------------------

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json> {
        let bytes = input.as_bytes();
        let mut pos = 0usize;
        let val = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing data at byte {pos}");
        }
        Ok(val)
    }

    // ---- file helpers --------------------------------------------------

    /// Parse a JSON document from a file (with path context on errors).
    pub fn read_file<P: AsRef<std::path::Path>>(path: P) -> Result<Json> {
        let path = path.as_ref();
        let raw = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&raw).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }

    /// Pretty-print this value to a file, creating parent directories.
    pub fn write_file<P: AsRef<std::path::Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.encode_pretty())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(val)
    } else {
        bail!("invalid literal at byte {pos}");
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    let v: f64 = s.parse().map_err(|_| anyhow::anyhow!("bad number `{s}` at byte {start}"))?;
    Ok(Json::Num(v))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            bail!("unterminated string");
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("dangling escape");
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // Copy one UTF-8 scalar.
                let s = std::str::from_utf8(&b[*pos..])?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected , or ] at byte {pos}"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            bail!("expected object key at byte {pos}");
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            bail!("expected : at byte {pos}");
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => bail!("expected , or }} at byte {pos}"),
        }
    }
}

// ---- From conversions ---------------------------------------------------

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u8> for Json {
    fn from(v: u8) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let mut o = Json::obj();
        o.set("name", "conv1").set("bits", 5u8).set("rmae", 0.0123f64).set("ok", true);
        let mut top = Json::obj();
        top.set("layers", vec![o.clone(), o]).set("model", "alexnet_mini");
        let enc = top.encode_pretty();
        let dec = Json::parse(&enc).unwrap();
        assert_eq!(dec, top);
        assert_eq!(dec.get("layers").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parses_standard_document() {
        let doc = r#" { "a": [1, 2.5, -3e-2], "b": {"c": null}, "s": "x\n\"y\"" } "#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -0.03);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "x\n\"y\"");
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_control_chars() {
        let j = Json::Str("a\u{1}b".into());
        assert_eq!(j.encode(), "\"a\\u0001b\"");
        assert_eq!(Json::parse(&j.encode()).unwrap(), j);
    }

    #[test]
    fn integers_encode_without_decimal_point() {
        assert_eq!(Json::Num(42.0).encode(), "42");
        assert_eq!(Json::Num(0.5).encode(), "0.5");
    }

    #[test]
    fn f64_roundtrip_is_bit_exact() {
        for v in [
            0.1f64 + 0.2,
            -0.0,
            0.0,
            f64::MIN_POSITIVE,
            1.0 / 3.0,
            -1e-300,
            9.007199254740991e15,
        ] {
            let enc = Json::Num(v).encode();
            match Json::parse(&enc).unwrap() {
                Json::Num(got) => {
                    assert_eq!(got.to_bits(), v.to_bits(), "value {v} encoded as {enc}")
                }
                other => panic!("parsed {other:?}"),
            }
        }
    }

    #[test]
    fn file_helpers_roundtrip() {
        let mut o = Json::obj();
        o.set("k", 1.25f64);
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("sub/doc.json");
        o.write_file(&p).unwrap();
        assert_eq!(Json::read_file(&p).unwrap(), o);
        assert!(Json::read_file(dir.path().join("missing.json")).is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::Str("αβγ → ∞".into());
        assert_eq!(Json::parse(&j.encode()).unwrap(), j);
    }

    #[test]
    fn typed_accessors_error_cleanly() {
        let v = Json::parse(r#"{"n": 1.5}"#).unwrap();
        assert!(v.req("missing").is_err());
        assert!(v.get("n").unwrap().as_usize().is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), 1.5);
    }
}
