//! Model weight storage: a name → tensor map backed by `.bt` files.
//!
//! The python compile path (`python/compile/aot.py`) trains the mini
//! models and dumps every parameter as `artifacts/models/<model>/<name>.bt`
//! plus a `manifest.json` with architecture metadata; this module loads
//! them back for the rust engine.

use crate::tensor::{load_tensor, save_tensor, Tensor};
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Name → tensor map for one model.
#[derive(Clone, Debug, Default)]
pub struct WeightMap {
    map: HashMap<String, Tensor>,
}

impl WeightMap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Fetch a tensor by name (errors list available keys for debugging).
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).ok_or_else(|| {
            let mut keys: Vec<&str> = self.map.keys().map(|s| s.as_str()).collect();
            keys.sort();
            anyhow::anyhow!("missing weight `{name}`; available: {keys:?}")
        })
    }

    /// Fetch + clone with an expected shape check.
    pub fn tensor(&self, name: &str, shape: &[usize]) -> Result<Tensor> {
        let t = self.get(name)?;
        if t.shape() != shape {
            bail!("weight `{name}` has shape {:?}, expected {:?}", t.shape(), shape);
        }
        Ok(t.clone())
    }

    /// Fetch a 1-D tensor as a plain vector (biases, norms).
    pub fn vec(&self, name: &str, len: usize) -> Result<Vec<f32>> {
        let t = self.get(name)?;
        if t.len() != len {
            bail!("weight `{name}` has {} elements, expected {len}", t.len());
        }
        Ok(t.data().to_vec())
    }

    /// Load every `.bt` file in `dir` (key = file stem).
    pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref();
        let mut map = HashMap::new();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("reading weight dir {}", dir.display()))?;
        for entry in entries {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) == Some("bt") {
                let stem = path
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .context("non-utf8 weight filename")?
                    .to_string();
                map.insert(stem, load_tensor(&path)?);
            }
        }
        if map.is_empty() {
            bail!("no .bt weights found in {}", dir.display());
        }
        Ok(Self { map })
    }

    /// Save every tensor as `<dir>/<name>.bt`.
    pub fn save_dir<P: AsRef<Path>>(&self, dir: P) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        for (name, t) in &self.map {
            save_tensor(dir.join(format!("{name}.bt")), t)?;
        }
        Ok(())
    }

    /// Read the model manifest (`manifest.json`) next to the weights.
    pub fn load_manifest<P: AsRef<Path>>(dir: P) -> Result<Json> {
        let p = dir.as_ref().join("manifest.json");
        let raw = std::fs::read_to_string(&p)
            .with_context(|| format!("reading {}", p.display()))?;
        Json::parse(&raw)
    }

    /// Model names available under a weights root (`artifacts/models`):
    /// every subdirectory containing at least one `.bt` tensor. Sorted;
    /// empty (not an error) when the root does not exist, so callers can
    /// distinguish "no artifacts yet" from a bad model name.
    pub fn list_models<P: AsRef<Path>>(root: P) -> Vec<String> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(root.as_ref()) {
            Ok(e) => e,
            Err(_) => return out,
        };
        for entry in entries.flatten() {
            let dir = entry.path();
            if !dir.is_dir() {
                continue;
            }
            let has_weights = std::fs::read_dir(&dir)
                .map(|mut it| {
                    it.any(|f| {
                        f.map(|f| f.path().extension().and_then(|e| e.to_str()) == Some("bt"))
                            .unwrap_or(false)
                    })
                })
                .unwrap_or(false);
            if has_weights {
                out.push(entry.file_name().to_string_lossy().into_owned());
            }
        }
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;
    use crate::util::TempDir;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = SplitMix64::new(121);
        let mut wm = WeightMap::new();
        wm.insert("conv1.w", Tensor::rand_normal(&[8, 27], 0.0, 1.0, &mut rng));
        wm.insert("conv1.b", Tensor::zeros(&[8]));
        let dir = TempDir::new().unwrap();
        wm.save_dir(dir.path()).unwrap();
        let wm2 = WeightMap::load_dir(dir.path()).unwrap();
        assert_eq!(wm2.len(), 2);
        assert_eq!(wm2.tensor("conv1.w", &[8, 27]).unwrap(), *wm.get("conv1.w").unwrap());
        assert_eq!(wm2.vec("conv1.b", 8).unwrap(), vec![0.0; 8]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut wm = WeightMap::new();
        wm.insert("w", Tensor::zeros(&[2, 2]));
        assert!(wm.tensor("w", &[4]).is_err());
        assert!(wm.vec("w", 3).is_err());
    }

    #[test]
    fn missing_weight_lists_keys() {
        let mut wm = WeightMap::new();
        wm.insert("present", Tensor::zeros(&[1]));
        let err = wm.get("absent").unwrap_err().to_string();
        assert!(err.contains("present"), "err: {err}");
    }

    #[test]
    fn empty_dir_errors() {
        let dir = TempDir::new().unwrap();
        assert!(WeightMap::load_dir(dir.path()).is_err());
    }

    #[test]
    fn list_models_finds_weight_dirs() {
        let root = TempDir::new().unwrap();
        let mut wm = WeightMap::new();
        wm.insert("w", Tensor::zeros(&[2]));
        wm.save_dir(root.path().join("beta_model")).unwrap();
        wm.save_dir(root.path().join("alpha_model")).unwrap();
        std::fs::create_dir_all(root.path().join("empty_model")).unwrap();
        assert_eq!(
            WeightMap::list_models(root.path()),
            vec!["alpha_model".to_string(), "beta_model".to_string()]
        );
        assert!(WeightMap::list_models(root.path().join("missing")).is_empty());
    }
}
