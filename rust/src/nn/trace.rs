//! Activation trace collection (step 1 of Fig. 3).
//!
//! During calibration, inference over a small representative subset of
//! the training data records each quantizable layer's input activations.
//! Per-layer storage is capped: once a layer's buffer is full, incoming
//! values are subsampled with a deterministic stride so the trace stays
//! representative of all calibration samples rather than just the first.

use crate::tensor::Tensor;
use std::collections::HashMap;

/// Capped per-layer activation store.
#[derive(Debug, Default)]
pub struct TraceStore {
    cap_per_layer: usize,
    layers: HashMap<String, LayerTrace>,
}

#[derive(Debug)]
struct LayerTrace {
    values: Vec<f32>,
    /// Total values offered (for subsample bookkeeping).
    seen: u64,
}

impl TraceStore {
    /// `cap_per_layer`: maximum retained values per layer (0 = unlimited).
    pub fn new(cap_per_layer: usize) -> Self {
        Self { cap_per_layer, layers: HashMap::new() }
    }

    /// Record one layer invocation's input activations.
    pub fn record(&mut self, layer: &str, values: &[f32]) {
        let cap = self.cap_per_layer;
        let entry = self
            .layers
            .entry(layer.to_string())
            .or_insert_with(|| LayerTrace { values: Vec::new(), seen: 0 });
        entry.seen += values.len() as u64;
        if cap == 0 || entry.values.len() + values.len() <= cap {
            entry.values.extend_from_slice(values);
            return;
        }
        // Buffer would overflow: reservoir-by-stride. Keep every k-th
        // value where k grows with the overflow factor, then overwrite a
        // rotating region so later samples keep landing in the buffer.
        let remaining = cap.saturating_sub(entry.values.len());
        if remaining > 0 {
            let stride = (values.len() / remaining).max(1);
            entry.values.extend(values.iter().step_by(stride).take(remaining));
        } else {
            // Replace a deterministic slice based on how much we've seen,
            // so long traces still influence the stored sample.
            let start = (entry.seen as usize) % cap;
            let n = (values.len() / 16).clamp(1, cap / 8 + 1);
            for i in 0..n {
                let src = (i * 16) % values.len();
                entry.values[(start + i) % cap] = values[src];
            }
        }
    }

    /// Number of layers traced so far.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Remove and return a layer's trace as a 1-D tensor.
    pub fn take(&mut self, layer: &str) -> Option<Tensor> {
        self.layers
            .remove(layer)
            .map(|lt| Tensor::from_vec(&[lt.values.len()], lt.values))
    }

    /// View a layer's trace.
    pub fn get(&self, layer: &str) -> Option<Tensor> {
        self.layers
            .get(layer)
            .map(|lt| Tensor::from_vec(&[lt.values.len()], lt.values.clone()))
    }

    pub fn layer_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.layers.keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_until_cap() {
        let mut t = TraceStore::new(10);
        t.record("a", &[1.0; 6]);
        t.record("a", &[2.0; 4]);
        assert_eq!(t.get("a").unwrap().len(), 10);
    }

    #[test]
    fn overflow_subsamples_but_stays_capped() {
        let mut t = TraceStore::new(100);
        for _ in 0..50 {
            t.record("a", &[1.0; 64]);
        }
        assert_eq!(t.get("a").unwrap().len(), 100);
    }

    #[test]
    fn later_samples_still_visible_after_cap() {
        let mut t = TraceStore::new(64);
        t.record("a", &vec![0.0; 64]);
        for _ in 0..20 {
            t.record("a", &vec![7.0; 64]);
        }
        let trace = t.get("a").unwrap();
        assert!(trace.data().iter().any(|&v| v == 7.0), "no late samples retained");
    }

    #[test]
    fn unlimited_when_cap_zero() {
        let mut t = TraceStore::new(0);
        t.record("a", &[1.0; 500]);
        t.record("a", &[2.0; 500]);
        assert_eq!(t.get("a").unwrap().len(), 1000);
    }

    #[test]
    fn take_removes_layer() {
        let mut t = TraceStore::new(10);
        t.record("x", &[1.0, 2.0]);
        assert!(t.take("x").is_some());
        assert!(t.take("x").is_none());
        assert!(t.is_empty());
    }
}
