//! AlexNet-mini: the AlexNet-class CNN of the evaluation (§VI-A).
//!
//! Same layer population as AlexNet — five CONV layers with pooling
//! followed by three FC layers — scaled to 32×32×3 inputs and 10 classes
//! (the ImageNet substitution is documented in DESIGN.md). Layer names
//! (`conv1..conv5`, `fc1..fc3`) are the calibration keys shared with the
//! python training side.

use super::layer::{Conv2d, ExecPlan, HasQuantLayers, Linear, QLayerRef};
use super::ops::{maxpool2x2, maxpool2x2_batch, relu_inplace};
use super::trace::TraceStore;
use super::weights::WeightMap;
use crate::dnateq::LayerKind;
use crate::tensor::{SplitMix64, Tensor};
use anyhow::Result;

/// Input geometry.
pub const IN_CHANNELS: usize = 3;
pub const IN_HW: usize = 32;
pub const NUM_CLASSES: usize = 10;

/// Channel plan of the five conv layers.
const CONV_CH: [usize; 5] = [32, 64, 96, 96, 64];
/// FC sizes: flatten(64·4·4) → 256 → 128 → 10.
const FC_DIMS: [usize; 4] = [64 * 4 * 4, 256, 128, NUM_CLASSES];

/// The model.
pub struct AlexNetMini {
    pub convs: Vec<Conv2d>,
    pub fcs: Vec<Linear>,
}

impl AlexNetMini {
    /// Build from trained weights (see `python/compile/models.py`).
    pub fn from_weights(w: &WeightMap) -> Result<Self> {
        let mut convs = Vec::new();
        let mut c_in = IN_CHANNELS;
        for (i, &c_out) in CONV_CH.iter().enumerate() {
            let name = format!("conv{}", i + 1);
            let weights = w.tensor(&format!("{name}.w"), &[c_out, c_in * 9])?;
            let bias = w.vec(&format!("{name}.b"), c_out)?;
            convs.push(Conv2d::new(&name, weights, bias, c_in, 3, 1, 1));
            c_in = c_out;
        }
        let mut fcs = Vec::new();
        for i in 0..3 {
            let name = format!("fc{}", i + 1);
            let weights = w.tensor(&format!("{name}.w"), &[FC_DIMS[i + 1], FC_DIMS[i]])?;
            let bias = w.vec(&format!("{name}.b"), FC_DIMS[i + 1])?;
            fcs.push(Linear::new(&name, weights, bias));
        }
        Ok(Self { convs, fcs })
    }

    /// Random He-initialized instance (tests/benches without artifacts).
    pub fn random(seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut w = WeightMap::new();
        let mut c_in = IN_CHANNELS;
        for (i, &c_out) in CONV_CH.iter().enumerate() {
            let fan_in = (c_in * 9) as f32;
            let std = (2.0 / fan_in).sqrt();
            w.insert(
                &format!("conv{}.w", i + 1),
                Tensor::rand_normal(&[c_out, c_in * 9], 0.0, std, &mut rng),
            );
            w.insert(&format!("conv{}.b", i + 1), Tensor::zeros(&[c_out]));
            c_in = c_out;
        }
        for i in 0..3 {
            let std = (2.0 / FC_DIMS[i] as f32).sqrt();
            w.insert(
                &format!("fc{}.w", i + 1),
                Tensor::rand_normal(&[FC_DIMS[i + 1], FC_DIMS[i]], 0.0, std, &mut rng),
            );
            w.insert(&format!("fc{}.b", i + 1), Tensor::zeros(&[FC_DIMS[i + 1]]));
        }
        Self::from_weights(&w).expect("random init is well-formed")
    }

    /// Forward one image `[3, 32, 32]` → logits `[10]`.
    pub fn forward(
        &self,
        image: &Tensor,
        plan: &ExecPlan,
        mut trace: Option<&mut TraceStore>,
    ) -> Tensor {
        assert_eq!(image.shape(), &[IN_CHANNELS, IN_HW, IN_HW], "bad input shape");
        let mut x = image.clone();
        for (i, conv) in self.convs.iter().enumerate() {
            x = conv.forward(&x, plan, trace.as_deref_mut());
            relu_inplace(&mut x);
            // Pools after conv1, conv2, conv5 (32→16→8→…→4).
            if i == 0 || i == 1 || i == 4 {
                x = maxpool2x2(&x);
            }
        }
        let flat = x.len();
        let mut h = x.reshape(&[1, flat]);
        for (i, fc) in self.fcs.iter().enumerate() {
            h = fc.forward(&h, plan, trace.as_deref_mut());
            if i + 1 < self.fcs.len() {
                relu_inplace(&mut h);
            }
        }
        h.reshape(&[NUM_CLASSES])
    }

    /// Predicted class of one image.
    pub fn predict(&self, image: &Tensor, plan: &ExecPlan) -> usize {
        self.forward(image, plan, None).argmax()
    }

    /// Forward a batch of images `[n, 3, 32, 32]` → logits `[n, 10]`:
    /// every conv lowers onto one batch-wide GEMM
    /// ([`Conv2d::forward_batch`]) and the FC stack runs with `n` as the
    /// GEMM batch axis ([`super::layer::Linear::forward_batch`]).
    /// Activation quantization is applied per image at every layer, so
    /// results are bit-identical to image-at-a-time
    /// [`AlexNetMini::forward`] under **every** plan, including
    /// dynamically calibrated Uniform.
    pub fn forward_batch(
        &self,
        images: &Tensor,
        plan: &ExecPlan,
        mut trace: Option<&mut TraceStore>,
    ) -> Tensor {
        assert_eq!(images.ndim(), 4, "bad batch shape");
        assert_eq!(&images.shape()[1..], &[IN_CHANNELS, IN_HW, IN_HW], "bad input shape");
        let n = images.shape()[0];
        if n == 0 {
            return Tensor::from_vec(&[0, NUM_CLASSES], Vec::new());
        }
        let mut x = images.clone();
        for (i, conv) in self.convs.iter().enumerate() {
            x = conv.forward_batch(&x, plan, trace.as_deref_mut());
            relu_inplace(&mut x);
            // Pools after conv1, conv2, conv5 (32→16→8→…→4).
            if i == 0 || i == 1 || i == 4 {
                x = maxpool2x2_batch(&x);
            }
        }
        let flat = x.len() / n;
        let mut h = x.reshape(&[n, flat]);
        for (i, fc) in self.fcs.iter().enumerate() {
            h = fc.forward_batch(&h, plan, trace.as_deref_mut());
            if i + 1 < self.fcs.len() {
                relu_inplace(&mut h);
            }
        }
        h
    }

    /// Multiply-accumulate count per forward pass (drives the accelerator
    /// simulation workload, §VI-C).
    pub fn macs_per_layer(&self) -> Vec<(String, u64)> {
        let mut out = Vec::new();
        let mut hw = IN_HW;
        for (i, conv) in self.convs.iter().enumerate() {
            // Output spatial size = input (pad 1, k 3, stride 1).
            let macs = (conv.c_out * conv.c_in * 9 * hw * hw) as u64;
            out.push((conv.name.clone(), macs));
            if i == 0 || i == 1 || i == 4 {
                hw /= 2;
            }
        }
        for fc in &self.fcs {
            out.push((fc.name.clone(), (fc.in_features() * fc.out_features()) as u64));
        }
        out
    }
}

impl HasQuantLayers for AlexNetMini {
    fn model_name(&self) -> &str {
        "alexnet_mini"
    }

    fn quant_layers(&self) -> Vec<QLayerRef<'_>> {
        let mut v: Vec<QLayerRef> = self
            .convs
            .iter()
            .map(|c| QLayerRef { name: &c.name, kind: LayerKind::Conv, weights: &c.weights })
            .collect();
        v.extend(
            self.fcs
                .iter()
                .map(|f| QLayerRef { name: &f.name, kind: LayerKind::Fc, weights: &f.weights }),
        );
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shape_and_determinism() {
        let m = AlexNetMini::random(131);
        let mut rng = SplitMix64::new(132);
        let img = Tensor::rand_normal(&[3, 32, 32], 0.0, 1.0, &mut rng);
        let a = m.forward(&img, &ExecPlan::fp32(), None);
        let b = m.forward(&img, &ExecPlan::fp32(), None);
        assert_eq!(a.shape(), &[10]);
        assert_eq!(a, b);
        assert!(a.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn has_eight_quant_layers() {
        let m = AlexNetMini::random(133);
        let layers = m.quant_layers();
        assert_eq!(layers.len(), 8);
        assert_eq!(layers[0].name, "conv1");
        assert_eq!(layers[0].kind, LayerKind::Conv);
        assert_eq!(layers[7].name, "fc3");
        assert_eq!(layers[7].kind, LayerKind::Fc);
    }

    #[test]
    fn trace_covers_every_layer() {
        let m = AlexNetMini::random(134);
        let mut rng = SplitMix64::new(135);
        let img = Tensor::rand_normal(&[3, 32, 32], 0.0, 1.0, &mut rng);
        let mut trace = TraceStore::new(1 << 16);
        m.forward(&img, &ExecPlan::fp32(), Some(&mut trace));
        assert_eq!(trace.len(), 8);
        assert_eq!(trace.layer_names()[0], "conv1");
    }

    #[test]
    fn forward_batch_matches_per_image() {
        let m = AlexNetMini::random(140);
        let mut rng = SplitMix64::new(141);
        let batch = Tensor::rand_normal(&[4, 3, 32, 32], 0.0, 1.0, &mut rng);
        // int8 exercises dynamically calibrated Uniform activation
        // quantization: per-image calibration must make batched ==
        // per-image bit-for-bit even with an outlier-heavy co-batch.
        for plan in [ExecPlan::fp32(), ExecPlan::int8(&m)] {
            let logits = m.forward_batch(&batch, &plan, None);
            assert_eq!(logits.shape(), &[4, 10]);
            for i in 0..4 {
                let img = Tensor::from_vec(&[3, 32, 32], batch.batch(i).to_vec());
                let want = m.forward(&img, &plan, None);
                assert_eq!(logits.row(i), want.data(), "image {i}");
            }
        }
        use crate::nn::eval::ImageModel;
        let fp32 = ExecPlan::fp32();
        assert_eq!(
            m.predict_batch(&batch, &fp32),
            (0..4)
                .map(|i| m.predict(&Tensor::from_vec(&[3, 32, 32], batch.batch(i).to_vec()), &fp32))
                .collect::<Vec<_>>()
        );
        let empty = m.forward_batch(&Tensor::zeros(&[0, 3, 32, 32]), &fp32, None);
        assert_eq!(empty.shape(), &[0, 10]);
    }

    #[test]
    fn int8_plan_keeps_prediction_on_easy_input() {
        // With a strong synthetic margin, INT8 must not flip the argmax.
        let m = AlexNetMini::random(136);
        let mut rng = SplitMix64::new(137);
        let img = Tensor::rand_normal(&[3, 32, 32], 0.0, 1.0, &mut rng);
        let fp = m.forward(&img, &ExecPlan::fp32(), None);
        let q = m.forward(&img, &ExecPlan::int8(&m), None);
        assert!(q.rmae(&fp) < 0.25, "INT8 output RMAE {}", q.rmae(&fp));
    }

    #[test]
    fn macs_match_architecture() {
        let m = AlexNetMini::random(138);
        let macs = m.macs_per_layer();
        assert_eq!(macs.len(), 8);
        // conv1: 32 out-ch × 27 taps × 32×32 positions.
        assert_eq!(macs[0].1, 32 * 27 * 32 * 32);
        // fc1: 1024×256.
        assert_eq!(macs[5].1, 1024 * 256);
    }

    #[test]
    fn from_weights_rejects_bad_shapes() {
        let m = AlexNetMini::random(139);
        let mut wm = WeightMap::new();
        for lr in m.quant_layers() {
            wm.insert(&format!("{}.w", lr.name), lr.weights.clone());
        }
        // Missing biases.
        assert!(AlexNetMini::from_weights(&wm).is_err());
    }
}
