//! ResNet-mini: the ResNet-50 stand-in of the evaluation (§VI-A).
//!
//! Residual CNN with a conv stem, three stages of two basic blocks
//! (16/32/64 channels, stride-2 stage transitions with 1×1 projection
//! shortcuts), global average pooling and an FC head — 15 CONV + 1 FC
//! quantizable layers. BatchNorm is folded into conv weights by the
//! python export (inference-time folding), so the rust graph is pure
//! conv/relu/add.

use super::layer::{Conv2d, ExecPlan, HasQuantLayers, Linear, QLayerRef};
use super::ops::{global_avg_pool, global_avg_pool_batch, relu_inplace};
use super::trace::TraceStore;
use super::weights::WeightMap;
use crate::dnateq::LayerKind;
use crate::tensor::{SplitMix64, Tensor};
use anyhow::Result;

pub const IN_CHANNELS: usize = 3;
pub const IN_HW: usize = 32;
pub const NUM_CLASSES: usize = 10;
/// Stage output channels.
const STAGE_CH: [usize; 3] = [16, 32, 64];
/// Blocks per stage.
const BLOCKS: usize = 2;

/// One basic residual block: two 3×3 convs + optional 1×1 projection.
pub struct BasicBlock {
    pub c1: Conv2d,
    pub c2: Conv2d,
    pub proj: Option<Conv2d>,
}

impl BasicBlock {
    fn forward(&self, x: &Tensor, plan: &ExecPlan, mut trace: Option<&mut TraceStore>) -> Tensor {
        let mut h = self.c1.forward(x, plan, trace.as_deref_mut());
        relu_inplace(&mut h);
        let h = self.c2.forward(&h, plan, trace.as_deref_mut());
        let shortcut = match &self.proj {
            Some(p) => p.forward(x, plan, trace.as_deref_mut()),
            None => x.clone(),
        };
        let mut out = h.add(&shortcut);
        relu_inplace(&mut out);
        out
    }

    /// Batched block forward: `[n, c, h, w]` in and out, convs lowered
    /// onto batch-wide GEMMs.
    fn forward_batch(
        &self,
        x: &Tensor,
        plan: &ExecPlan,
        mut trace: Option<&mut TraceStore>,
    ) -> Tensor {
        let mut h = self.c1.forward_batch(x, plan, trace.as_deref_mut());
        relu_inplace(&mut h);
        let h = self.c2.forward_batch(&h, plan, trace.as_deref_mut());
        let shortcut = match &self.proj {
            Some(p) => p.forward_batch(x, plan, trace.as_deref_mut()),
            None => x.clone(),
        };
        let mut out = h.add(&shortcut);
        relu_inplace(&mut out);
        out
    }
}

/// The model.
pub struct ResNetMini {
    pub stem: Conv2d,
    pub blocks: Vec<BasicBlock>,
    pub head: Linear,
}

impl ResNetMini {
    /// Names of all conv layers in forward order (shared with python).
    fn conv_plan() -> Vec<(String, usize, usize, usize, usize)> {
        // (name, c_in, c_out, stride, kernel)
        let mut v = vec![("conv0".to_string(), IN_CHANNELS, STAGE_CH[0], 1, 3)];
        let mut c_in = STAGE_CH[0];
        for (s, &c_out) in STAGE_CH.iter().enumerate() {
            for b in 0..BLOCKS {
                let stride = if s > 0 && b == 0 { 2 } else { 1 };
                v.push((format!("s{}b{}c1", s + 1, b + 1), c_in, c_out, stride, 3));
                v.push((format!("s{}b{}c2", s + 1, b + 1), c_out, c_out, 1, 3));
                if c_in != c_out || stride != 1 {
                    v.push((format!("s{}b{}d", s + 1, b + 1), c_in, c_out, stride, 1));
                }
                c_in = c_out;
            }
        }
        v
    }

    pub fn from_weights(w: &WeightMap) -> Result<Self> {
        let plan = Self::conv_plan();
        let mut convs = Vec::new();
        for (name, c_in, c_out, stride, k) in &plan {
            let weights = w.tensor(&format!("{name}.w"), &[*c_out, c_in * k * k])?;
            let bias = w.vec(&format!("{name}.b"), *c_out)?;
            let pad = if *k == 3 { 1 } else { 0 };
            convs.push(Conv2d::new(name, weights, bias, *c_in, *k, *stride, pad));
        }
        let mut it = convs.into_iter();
        let stem = it.next().unwrap();
        let mut blocks = Vec::new();
        let mut c_in = STAGE_CH[0];
        for (s, &c_out) in STAGE_CH.iter().enumerate() {
            for b in 0..BLOCKS {
                let stride = if s > 0 && b == 0 { 2 } else { 1 };
                let c1 = it.next().unwrap();
                let c2 = it.next().unwrap();
                let proj =
                    if c_in != c_out || stride != 1 { Some(it.next().unwrap()) } else { None };
                blocks.push(BasicBlock { c1, c2, proj });
                c_in = c_out;
            }
        }
        let head = Linear::new(
            "fc",
            w.tensor("fc.w", &[NUM_CLASSES, STAGE_CH[2]])?,
            w.vec("fc.b", NUM_CLASSES)?,
        );
        Ok(Self { stem, blocks, head })
    }

    /// Random He-initialized instance.
    pub fn random(seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut w = WeightMap::new();
        for (name, c_in, c_out, _stride, k) in Self::conv_plan() {
            let fan_in = (c_in * k * k) as f32;
            let std = (2.0 / fan_in).sqrt();
            w.insert(
                &format!("{name}.w"),
                Tensor::rand_normal(&[c_out, c_in * k * k], 0.0, std, &mut rng),
            );
            w.insert(&format!("{name}.b"), Tensor::zeros(&[c_out]));
        }
        w.insert(
            "fc.w",
            Tensor::rand_normal(&[NUM_CLASSES, STAGE_CH[2]], 0.0, 0.2, &mut rng),
        );
        w.insert("fc.b", Tensor::zeros(&[NUM_CLASSES]));
        Self::from_weights(&w).expect("random init is well-formed")
    }

    /// Forward one image `[3, 32, 32]` → logits `[10]`.
    pub fn forward(
        &self,
        image: &Tensor,
        plan: &ExecPlan,
        mut trace: Option<&mut TraceStore>,
    ) -> Tensor {
        assert_eq!(image.shape(), &[IN_CHANNELS, IN_HW, IN_HW]);
        let mut x = self.stem.forward(image, plan, trace.as_deref_mut());
        relu_inplace(&mut x);
        for block in &self.blocks {
            x = block.forward(&x, plan, trace.as_deref_mut());
        }
        let pooled = global_avg_pool(&x);
        let h = pooled.reshape(&[1, STAGE_CH[2]]);
        self.head.forward(&h, plan, trace).reshape(&[NUM_CLASSES])
    }

    pub fn predict(&self, image: &Tensor, plan: &ExecPlan) -> usize {
        self.forward(image, plan, None).argmax()
    }

    /// Forward a batch `[n, 3, 32, 32]` → logits `[n, 10]` with every
    /// conv lowered onto one batch-wide GEMM and per-image activation
    /// quantization throughout — bit-identical to image-at-a-time
    /// [`ResNetMini::forward`] under every plan.
    pub fn forward_batch(
        &self,
        images: &Tensor,
        plan: &ExecPlan,
        mut trace: Option<&mut TraceStore>,
    ) -> Tensor {
        assert_eq!(images.ndim(), 4, "bad batch shape");
        assert_eq!(&images.shape()[1..], &[IN_CHANNELS, IN_HW, IN_HW], "bad input shape");
        let n = images.shape()[0];
        if n == 0 {
            return Tensor::from_vec(&[0, NUM_CLASSES], Vec::new());
        }
        let mut x = self.stem.forward_batch(images, plan, trace.as_deref_mut());
        relu_inplace(&mut x);
        for block in &self.blocks {
            x = block.forward_batch(&x, plan, trace.as_deref_mut());
        }
        let pooled = global_avg_pool_batch(&x);
        self.head.forward_batch(&pooled, plan, trace)
    }

    /// MAC count per layer for the accelerator workload.
    pub fn macs_per_layer(&self) -> Vec<(String, u64)> {
        let mut out = Vec::new();
        let mut hw = IN_HW as u64;
        let stem_macs = self.stem.c_out as u64 * self.stem.c_in as u64 * 9 * hw * hw;
        out.push((self.stem.name.clone(), stem_macs));
        for block in &self.blocks {
            if block.c1.stride == 2 {
                hw /= 2;
            }
            for conv in [&block.c1, &block.c2].into_iter().chain(block.proj.as_ref()) {
                let taps = (conv.c_in * conv.k * conv.k) as u64;
                out.push((conv.name.clone(), conv.c_out as u64 * taps * hw * hw));
            }
        }
        out.push((
            self.head.name.clone(),
            (self.head.in_features() * self.head.out_features()) as u64,
        ));
        out
    }
}

impl HasQuantLayers for ResNetMini {
    fn model_name(&self) -> &str {
        "resnet_mini"
    }

    fn quant_layers(&self) -> Vec<QLayerRef<'_>> {
        let mut v = vec![QLayerRef {
            name: &self.stem.name,
            kind: LayerKind::Conv,
            weights: &self.stem.weights,
        }];
        for block in &self.blocks {
            for conv in [&block.c1, &block.c2].into_iter().chain(block.proj.as_ref()) {
                let weights = &conv.weights;
                v.push(QLayerRef { name: &conv.name, kind: LayerKind::Conv, weights });
            }
        }
        v.push(QLayerRef {
            name: &self.head.name,
            kind: LayerKind::Fc,
            weights: &self.head.weights,
        });
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shape() {
        let m = ResNetMini::random(141);
        let mut rng = SplitMix64::new(142);
        let img = Tensor::rand_normal(&[3, 32, 32], 0.0, 1.0, &mut rng);
        let y = m.forward(&img, &ExecPlan::fp32(), None);
        assert_eq!(y.shape(), &[10]);
        assert!(y.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sixteen_quant_layers() {
        let m = ResNetMini::random(143);
        // 1 stem + (2+2)·3 block convs + 2 projections + 1 fc = 16.
        assert_eq!(m.quant_layers().len(), 16);
    }

    #[test]
    fn forward_batch_matches_per_image() {
        let m = ResNetMini::random(150);
        let mut rng = SplitMix64::new(151);
        let batch = Tensor::rand_normal(&[3, 3, 32, 32], 0.0, 1.0, &mut rng);
        let plan = ExecPlan::fp32();
        let logits = m.forward_batch(&batch, &plan, None);
        assert_eq!(logits.shape(), &[3, 10]);
        for i in 0..3 {
            let img = Tensor::from_vec(&[3, 32, 32], batch.batch(i).to_vec());
            let want = m.forward(&img, &plan, None);
            assert_eq!(logits.row(i), want.data(), "image {i}");
        }
    }

    #[test]
    fn projection_only_on_stage_transitions() {
        let m = ResNetMini::random(144);
        let have_proj: Vec<bool> = m.blocks.iter().map(|b| b.proj.is_some()).collect();
        assert_eq!(have_proj, vec![false, false, true, false, true, false]);
    }

    #[test]
    fn residual_path_contributes() {
        // Zeroing out a block's conv weights must leave the shortcut.
        let mut m = ResNetMini::random(145);
        let mut rng = SplitMix64::new(146);
        let img = Tensor::rand_normal(&[3, 32, 32], 0.0, 0.5, &mut rng);
        let before = m.forward(&img, &ExecPlan::fp32(), None);
        // Zero block 0 (identity shortcut): output must change but stay
        // finite and non-zero (information flows through the residual).
        m.blocks[0].c2.weights.map_inplace(|_| 0.0);
        m.blocks[0].c2.bias.iter_mut().for_each(|b| *b = 0.0);
        let after = m.forward(&img, &ExecPlan::fp32(), None);
        assert_ne!(before, after);
        assert!(after.data().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn trace_covers_all_layers() {
        let m = ResNetMini::random(147);
        let mut rng = SplitMix64::new(148);
        let img = Tensor::rand_normal(&[3, 32, 32], 0.0, 1.0, &mut rng);
        let mut trace = TraceStore::new(1 << 14);
        m.forward(&img, &ExecPlan::fp32(), Some(&mut trace));
        assert_eq!(trace.len(), 16);
    }

    #[test]
    fn macs_positive_and_complete() {
        let m = ResNetMini::random(149);
        let macs = m.macs_per_layer();
        assert_eq!(macs.len(), 16);
        assert!(macs.iter().all(|(_, m)| *m > 0));
    }
}
