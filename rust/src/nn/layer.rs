//! Quantizable layers (CONV / FC) and execution plans.
//!
//! Accuracy evaluation uses *fake quantization*: weights and input
//! activations are passed through quantize→dequantize with the scheme
//! under test, then the f32 engine computes the layer — exactly how the
//! paper measures accuracy loss (§VI-A, TensorFlow implementation). The
//! bit-true counting engine in [`crate::expdot`] is validated against
//! this separately and used on the serving path.

use super::linalg::{gemm, gemm_bt, gemm_bt_par, gemm_par, im2col, im2col_batch};
use super::trace::TraceStore;
use crate::dnateq::{ExpQuantParams, LayerKind, PwlParams, QuantConfig, Scheme, UniformParams};
use crate::tensor::Tensor;
use std::collections::HashMap;

/// How to treat one layer's tensors during forward.
#[derive(Clone, Debug)]
pub struct LayerExec {
    /// Replacement (fake-quantized) weights, if quantizing.
    pub weights_override: Option<Tensor>,
    /// How to fake-quantize the input activations.
    pub act: ActQuant,
}

/// Activation quantization applied at layer input.
#[derive(Clone, Debug)]
pub enum ActQuant {
    None,
    /// Exponential with calibrated per-layer parameters.
    Exp(ExpQuantParams),
    /// Uniform symmetric at `n` bits, Δ calibrated dynamically per input
    /// (how both the INT8 baseline and Table IV's uniform rows work).
    Uniform(u8),
    /// Piecewise-linear at `n_bits` with `breaks` interior breakpoints,
    /// edges calibrated dynamically per input (like [`ActQuant::Uniform`],
    /// the quantizer sees exactly the tensor it encodes).
    Pwl { n_bits: u8, breaks: u8 },
}

impl ActQuant {
    fn apply(&self, x: &Tensor) -> Option<Tensor> {
        match self {
            ActQuant::None => None,
            ActQuant::Exp(p) => Some(p.roundtrip(x)),
            ActQuant::Uniform(n) => Some(UniformParams::calibrate(x, *n).roundtrip(x)),
            ActQuant::Pwl { n_bits, breaks } => {
                Some(PwlParams::calibrate(x, *n_bits, *breaks).roundtrip(x))
            }
        }
    }
}

/// Apply activation fake-quantization independently to every
/// leading-axis slice of `x` (each slice is one request/image of shape
/// `slice_shape`). Dynamically calibrated quantizers ([`ActQuant::Uniform`]
/// and [`ActQuant::Pwl`]) then see exactly the tensor they would in the
/// batch-1 path, so batched execution stays bit-identical to per-sample
/// execution and one request's range never rescales a co-batched request.
/// Fixed-parameter exponential quantization is element-wise, so it takes
/// the copy-free whole-batch path — already bit-identical per slice.
fn quantize_per_slice(act: &ActQuant, x: &Tensor, slice_shape: &[usize]) -> Option<Tensor> {
    match act {
        ActQuant::None => None,
        ActQuant::Exp(_) => act.apply(x),
        ActQuant::Uniform(_) | ActQuant::Pwl { .. } => {
            let n = x.shape()[0];
            let mut data = Vec::with_capacity(x.len());
            for i in 0..n {
                let slice = Tensor::from_vec(slice_shape, x.batch(i).to_vec());
                match act.apply(&slice) {
                    Some(q) => data.extend_from_slice(q.data()),
                    None => data.extend_from_slice(slice.data()),
                }
            }
            Some(Tensor::from_vec(x.shape(), data))
        }
    }
}

/// A reference to one quantizable layer of a model.
pub struct QLayerRef<'a> {
    pub name: &'a str,
    pub kind: LayerKind,
    pub weights: &'a Tensor,
}

/// Models expose their quantizable layers so generic plan builders and
/// the calibration pipeline can walk them.
pub trait HasQuantLayers {
    fn model_name(&self) -> &str;
    fn quant_layers(&self) -> Vec<QLayerRef<'_>>;
}

/// Execution plan: per-layer overrides; empty = plain FP32.
#[derive(Clone, Debug, Default)]
pub struct ExecPlan {
    layers: HashMap<String, LayerExec>,
}

impl ExecPlan {
    /// Plain FP32 execution.
    pub fn fp32() -> Self {
        Self::default()
    }

    pub fn get(&self, name: &str) -> Option<&LayerExec> {
        self.layers.get(name)
    }

    pub fn insert(&mut self, name: &str, exec: LayerExec) {
        self.layers.insert(name.to_string(), exec);
    }

    /// DNA-TEQ plan: fake-quantize every calibrated layer with its
    /// exponential parameters. Layers carrying a non-exponential scheme
    /// are skipped (their α/β are not [`ExpQuantParams`]); hybrid
    /// configs belong to [`ExecPlan::for_config`].
    pub fn exp(model: &dyn HasQuantLayers, cfg: &QuantConfig) -> Self {
        let mut plan = Self::default();
        for lr in model.quant_layers() {
            if let Some(lq) = cfg.layer(lr.name).filter(|l| l.scheme == Scheme::Exp) {
                plan.insert(
                    lr.name,
                    LayerExec {
                        weights_override: Some(lq.w_params().roundtrip(lr.weights)),
                        act: ActQuant::Exp(lq.a_params()),
                    },
                );
            }
        }
        plan
    }

    /// Hybrid plan: every calibrated layer fake-quantized with **its
    /// own scheme** — the serving-side realization of a [`PlanSet`]
    /// front point. Exponential layers replay their stored α/β/base;
    /// uniform and piecewise-linear layers re-calibrate their grids
    /// from the actual weights at the stored bitwidth (the artifact
    /// pins `scheme`+`n_bits`; the grid is cheap and deterministic to
    /// rebuild, exactly like the dynamic activation path).
    ///
    /// [`PlanSet`]: crate::dnateq::PlanSet
    pub fn for_config(model: &dyn HasQuantLayers, cfg: &QuantConfig) -> Self {
        let mut plan = Self::default();
        for lr in model.quant_layers() {
            if let Some(lq) = cfg.layer(lr.name) {
                let exec = match lq.scheme {
                    Scheme::Exp => LayerExec {
                        weights_override: Some(lq.w_params().roundtrip(lr.weights)),
                        act: ActQuant::Exp(lq.a_params()),
                    },
                    Scheme::Uniform => LayerExec {
                        weights_override: Some(
                            UniformParams::calibrate(lr.weights, lq.n_bits).roundtrip(lr.weights),
                        ),
                        act: ActQuant::Uniform(lq.n_bits),
                    },
                    Scheme::Pwl { breaks } => LayerExec {
                        weights_override: Some(
                            PwlParams::calibrate(lr.weights, lq.n_bits, breaks)
                                .roundtrip(lr.weights),
                        ),
                        act: ActQuant::Pwl { n_bits: lq.n_bits, breaks },
                    },
                };
                plan.insert(lr.name, exec);
            }
        }
        plan
    }

    /// Uniform quantization at the *same per-layer bitwidths* DNA-TEQ
    /// found — the "Uniform Quantization" row of Table IV.
    pub fn uniform_matched(model: &dyn HasQuantLayers, cfg: &QuantConfig) -> Self {
        let mut plan = Self::default();
        for lr in model.quant_layers() {
            if let Some(lq) = cfg.layer(lr.name) {
                let wp = UniformParams::calibrate(lr.weights, lq.n_bits);
                plan.insert(
                    lr.name,
                    LayerExec {
                        weights_override: Some(wp.roundtrip(lr.weights)),
                        act: ActQuant::Uniform(lq.n_bits),
                    },
                );
            }
        }
        plan
    }

    /// INT8 everywhere — the baseline accelerator's scheme (Table V).
    pub fn int8(model: &dyn HasQuantLayers) -> Self {
        let mut plan = Self::default();
        for lr in model.quant_layers() {
            let wp = UniformParams::calibrate(lr.weights, 8);
            plan.insert(
                lr.name,
                LayerExec {
                    weights_override: Some(wp.roundtrip(lr.weights)),
                    act: ActQuant::Uniform(8),
                },
            );
        }
        plan
    }
}

/// 2-D convolution, NCHW, weights stored `[c_out, c_in·kh·kw]` for the
/// im2col GEMM.
#[derive(Clone, Debug)]
pub struct Conv2d {
    pub name: String,
    pub weights: Tensor,
    pub bias: Vec<f32>,
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2d {
    pub fn new(
        name: &str,
        weights: Tensor,
        bias: Vec<f32>,
        c_in: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        assert_eq!(weights.ndim(), 2, "conv weights must be [c_out, c_in*k*k]");
        let c_out = weights.shape()[0];
        assert_eq!(weights.shape()[1], c_in * k * k, "conv weight shape mismatch");
        assert_eq!(bias.len(), c_out);
        Self { name: name.into(), weights, bias, c_in, c_out, k, stride, pad }
    }

    /// Forward one image `[c_in, h, w]` → `[c_out, oh, ow]`.
    pub fn forward(
        &self,
        x: &Tensor,
        plan: &ExecPlan,
        trace: Option<&mut TraceStore>,
    ) -> Tensor {
        assert_eq!(x.ndim(), 3);
        assert_eq!(x.shape()[0], self.c_in, "{}: channel mismatch", self.name);
        let exec = plan.get(&self.name);

        let xq = exec.and_then(|e| e.act.apply(x));
        let input = xq.as_ref().unwrap_or(x);
        if let Some(t) = trace {
            // The calibration trace records the *pre-quantization* input —
            // step 1 of Fig. 3 traces FP32 activations.
            t.record(&self.name, x.data());
        }

        let (h, w) = (x.shape()[1], x.shape()[2]);
        let (patches, oh, ow) =
            im2col(input.data(), self.c_in, h, w, self.k, self.k, self.stride, self.pad);
        let weights = exec
            .and_then(|e| e.weights_override.as_ref())
            .unwrap_or(&self.weights);
        let mut out = gemm(weights, &patches);
        // Add bias per output channel.
        let data = out.data_mut();
        for oc in 0..self.c_out {
            let b = self.bias[oc];
            for v in &mut data[oc * oh * ow..(oc + 1) * oh * ow] {
                *v += b;
            }
        }
        out.reshape(&[self.c_out, oh, ow])
    }

    /// Forward a whole batch `[n, c_in, h, w]` → `[n, c_out, oh, ow]`
    /// through ONE im2col + GEMM instead of a GEMM per image.
    ///
    /// Activation fake-quantization is applied **per image** so every
    /// plan — including dynamically calibrated [`ActQuant::Uniform`] —
    /// produces bit-identical values to the image-at-a-time
    /// [`Conv2d::forward`]; batching only regroups the GEMM.
    pub fn forward_batch(
        &self,
        x: &Tensor,
        plan: &ExecPlan,
        trace: Option<&mut TraceStore>,
    ) -> Tensor {
        assert_eq!(x.ndim(), 4);
        assert_eq!(x.shape()[1], self.c_in, "{}: channel mismatch", self.name);
        let n = x.shape()[0];
        let (h, w) = (x.shape()[2], x.shape()[3]);
        let exec = plan.get(&self.name);
        if let Some(t) = trace {
            // Pre-quantization input, as in the batch-1 path.
            t.record(&self.name, x.data());
        }

        let quantized = exec.and_then(|e| quantize_per_slice(&e.act, x, &[self.c_in, h, w]));
        let input = quantized.as_ref().unwrap_or(x);

        let (patches, oh, ow) =
            im2col_batch(input.data(), n, self.c_in, h, w, self.k, self.k, self.stride, self.pad);
        let weights = exec
            .and_then(|e| e.weights_override.as_ref())
            .unwrap_or(&self.weights);
        // One GEMM for the whole batch: [c_out, taps] × [taps, n·oh·ow].
        let flat = gemm_par(weights, &patches);

        // Scatter image-major columns into [n, c_out, oh, ow] + bias.
        let img_cols = oh * ow;
        let fdata = flat.data();
        let mut out = vec![0.0f32; n * self.c_out * img_cols];
        for oc in 0..self.c_out {
            let b = self.bias[oc];
            for img in 0..n {
                let src = &fdata[oc * n * img_cols + img * img_cols..][..img_cols];
                let dst = &mut out[(img * self.c_out + oc) * img_cols..][..img_cols];
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d = s + b;
                }
            }
        }
        Tensor::from_vec(&[n, self.c_out, oh, ow], out)
    }
}

/// Fully-connected layer, weights `[out, in]`.
#[derive(Clone, Debug)]
pub struct Linear {
    pub name: String,
    pub weights: Tensor,
    pub bias: Vec<f32>,
}

impl Linear {
    pub fn new(name: &str, weights: Tensor, bias: Vec<f32>) -> Self {
        assert_eq!(weights.ndim(), 2, "linear weights must be [out, in]");
        assert_eq!(bias.len(), weights.shape()[0]);
        Self { name: name.into(), weights, bias }
    }

    pub fn in_features(&self) -> usize {
        self.weights.shape()[1]
    }

    pub fn out_features(&self) -> usize {
        self.weights.shape()[0]
    }

    /// Forward `[rows, in]` → `[rows, out]`. The rows of one call share
    /// a single activation-calibration tensor (dynamic
    /// [`ActQuant::Uniform`] calibrates over the whole input) — correct
    /// when the rows belong to one sample, e.g. the token positions of a
    /// sequence. For rows that are *independent requests*, use
    /// [`Linear::forward_batch`]. Large products fan out over worker
    /// threads ([`gemm_bt_par`], bit-identical to the serial path).
    pub fn forward(
        &self,
        x: &Tensor,
        plan: &ExecPlan,
        trace: Option<&mut TraceStore>,
    ) -> Tensor {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.shape()[1], self.in_features(), "{}: feature mismatch", self.name);
        let exec = plan.get(&self.name);
        let xq = exec.and_then(|e| e.act.apply(x));
        let input = xq.as_ref().unwrap_or(x);
        if let Some(t) = trace {
            t.record(&self.name, x.data());
        }
        let weights = exec
            .and_then(|e| e.weights_override.as_ref())
            .unwrap_or(&self.weights);
        let mut out = gemm_bt_par(input, weights);
        let (rows, cols) = (out.shape()[0], out.shape()[1]);
        let data = out.data_mut();
        for r in 0..rows {
            for c in 0..cols {
                data[r * cols + c] += self.bias[c];
            }
        }
        out
    }

    /// Forward a batch of **independent** rows `[n, in]` → `[n, out]`:
    /// activation fake-quantization is applied per row, so every plan —
    /// including dynamically calibrated [`ActQuant::Uniform`] — produces
    /// bit-identical values to `n` separate `[1, in]` forwards, while
    /// the GEMM still runs once over the whole batch.
    pub fn forward_batch(
        &self,
        x: &Tensor,
        plan: &ExecPlan,
        trace: Option<&mut TraceStore>,
    ) -> Tensor {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.shape()[1], self.in_features(), "{}: feature mismatch", self.name);
        let exec = plan.get(&self.name);
        let xq = exec.and_then(|e| quantize_per_slice(&e.act, x, &[1, self.in_features()]));
        let input = xq.as_ref().unwrap_or(x);
        if let Some(t) = trace {
            t.record(&self.name, x.data());
        }
        let weights = exec
            .and_then(|e| e.weights_override.as_ref())
            .unwrap_or(&self.weights);
        let mut out = gemm_bt_par(input, weights);
        let (rows, cols) = (out.shape()[0], out.shape()[1]);
        let data = out.data_mut();
        for r in 0..rows {
            for c in 0..cols {
                data[r * cols + c] += self.bias[c];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    struct OneFc {
        fc: Linear,
    }

    impl HasQuantLayers for OneFc {
        fn model_name(&self) -> &str {
            "onefc"
        }
        fn quant_layers(&self) -> Vec<QLayerRef<'_>> {
            vec![QLayerRef { name: &self.fc.name, kind: LayerKind::Fc, weights: &self.fc.weights }]
        }
    }

    fn mk_fc(seed: u64) -> OneFc {
        let mut rng = SplitMix64::new(seed);
        let w = Tensor::rand_signed_exponential(&[4, 16], 2.0, &mut rng);
        OneFc { fc: Linear::new("fc0", w, vec![0.0; 4]) }
    }

    #[test]
    fn fp32_plan_is_identity() {
        let m = mk_fc(111);
        let mut rng = SplitMix64::new(112);
        let x = Tensor::rand_normal(&[2, 16], 0.0, 1.0, &mut rng);
        let plan = ExecPlan::fp32();
        let y = m.fc.forward(&x, &plan, None);
        let want = gemm_bt(&x, &m.fc.weights);
        assert_eq!(y, want);
    }

    #[test]
    fn int8_plan_close_to_fp32() {
        let m = mk_fc(113);
        let mut rng = SplitMix64::new(114);
        let x = Tensor::rand_normal(&[2, 16], 0.0, 1.0, &mut rng);
        let plan = ExecPlan::int8(&m);
        let y = m.fc.forward(&x, &plan, None);
        let want = m.fc.forward(&x, &ExecPlan::fp32(), None);
        let err = y.rmae(&want);
        assert!(err < 0.05, "INT8 RMAE {err}");
    }

    #[test]
    fn exp_plan_uses_config_layers_only() {
        use crate::dnateq::{LayerQuant, TensorQuant};
        let m = mk_fc(115);
        // Config naming a different layer: plan stays empty.
        let cfg = QuantConfig {
            model: "onefc".into(),
            thr_w: 0.01,
            layers: vec![LayerQuant {
                name: "other".into(),
                kind: LayerKind::Fc,
                scheme: Scheme::Exp,
                n_bits: 4,
                base: 1.2,
                weights: TensorQuant { alpha: 1.0, beta: 0.0, rmae: 0.0, elems: 1 },
                acts: TensorQuant { alpha: 1.0, beta: 0.0, rmae: 0.0, elems: 1 },
                seeded_by_weights: true,
                rss_w: 0.0,
                rss_a: 0.0,
                converged: true,
            }],
        };
        let plan = ExecPlan::exp(&m, &cfg);
        // The plan walks *model* layers: `fc0` is absent from the config
        // and `other` is absent from the model, so the plan stays empty.
        assert!(plan.get("fc0").is_none());
        assert!(plan.get("other").is_none());
    }

    #[test]
    fn for_config_dispatches_per_scheme() {
        use crate::dnateq::{LayerQuant, TensorQuant};
        let m = mk_fc(120);
        let tq = || TensorQuant { alpha: 1.0, beta: 0.0, rmae: 0.0, elems: 1 };
        let mk = |scheme, n_bits| QuantConfig {
            model: "onefc".into(),
            thr_w: 0.05,
            layers: vec![LayerQuant {
                name: "fc0".into(),
                kind: LayerKind::Fc,
                scheme,
                n_bits,
                base: 0.0,
                weights: tq(),
                acts: tq(),
                seeded_by_weights: true,
                rss_w: 0.0,
                rss_a: 0.0,
                converged: true,
            }],
        };
        let uni = ExecPlan::for_config(&m, &mk(Scheme::Uniform, 8));
        assert!(matches!(uni.get("fc0").unwrap().act, ActQuant::Uniform(8)));
        let pwl = ExecPlan::for_config(&m, &mk(Scheme::Pwl { breaks: 1 }, 6));
        assert!(matches!(pwl.get("fc0").unwrap().act, ActQuant::Pwl { n_bits: 6, breaks: 1 }));
        // The exp() builder skips non-exp layers instead of misreading
        // their α/β as exponential parameters.
        assert!(ExecPlan::exp(&m, &mk(Scheme::Uniform, 8)).get("fc0").is_none());
        // Both hybrid plans still track FP32 closely at their widths.
        let mut rng = SplitMix64::new(121);
        let x = Tensor::rand_normal(&[2, 16], 0.0, 1.0, &mut rng);
        let want = m.fc.forward(&x, &ExecPlan::fp32(), None);
        for plan in [&uni, &pwl] {
            let got = m.fc.forward(&x, plan, None);
            assert!(got.rmae(&want) < 0.08);
        }
    }

    #[test]
    fn conv_bias_and_shapes() {
        let mut rng = SplitMix64::new(116);
        let w = Tensor::rand_normal(&[2, 3 * 9], 0.0, 0.5, &mut rng);
        let conv = Conv2d::new("c", w, vec![1.0, -1.0], 3, 3, 1, 1);
        let x = Tensor::rand_normal(&[3, 5, 5], 0.0, 1.0, &mut rng);
        let y = conv.forward(&x, &ExecPlan::fp32(), None);
        assert_eq!(y.shape(), &[2, 5, 5]);
        // Bias shifts whole channels.
        let y0 = conv.forward(&Tensor::zeros(&[3, 5, 5]), &ExecPlan::fp32(), None);
        assert!(y0.data()[..25].iter().all(|&v| v == 1.0));
        assert!(y0.data()[25..].iter().all(|&v| v == -1.0));
    }

    #[test]
    fn conv_forward_batch_bit_matches_per_image() {
        struct OneConv {
            conv: Conv2d,
        }
        impl HasQuantLayers for OneConv {
            fn model_name(&self) -> &str {
                "oneconv"
            }
            fn quant_layers(&self) -> Vec<QLayerRef<'_>> {
                vec![QLayerRef {
                    name: &self.conv.name,
                    kind: LayerKind::Conv,
                    weights: &self.conv.weights,
                }]
            }
        }
        let mut rng = SplitMix64::new(119);
        let w = Tensor::rand_normal(&[4, 3 * 9], 0.0, 0.5, &mut rng);
        let m = OneConv { conv: Conv2d::new("c", w, vec![0.5, -0.5, 0.0, 1.0], 3, 3, 2, 1) };
        let batch = Tensor::rand_normal(&[3, 3, 7, 5], 0.0, 1.0, &mut rng);
        // Uniform and PWL act-quant calibrate dynamically per input: the
        // batched path must still match image-at-a-time bit-for-bit.
        let mut pwl = ExecPlan::fp32();
        pwl.insert(
            "c",
            LayerExec { weights_override: None, act: ActQuant::Pwl { n_bits: 6, breaks: 1 } },
        );
        for plan in [ExecPlan::fp32(), ExecPlan::int8(&m), pwl] {
            let got = m.conv.forward_batch(&batch, &plan, None);
            assert_eq!(got.shape()[0], 3);
            for i in 0..3 {
                let img = Tensor::from_vec(&[3, 7, 5], batch.batch(i).to_vec());
                let want = m.conv.forward(&img, &plan, None);
                assert_eq!(got.batch(i), want.data(), "image {i}");
                assert_eq!(&got.shape()[1..], want.shape());
            }
        }
    }

    #[test]
    fn trace_records_prequant_input() {
        let m = mk_fc(117);
        let mut rng = SplitMix64::new(118);
        let x = Tensor::rand_normal(&[1, 16], 0.0, 1.0, &mut rng);
        let mut trace = TraceStore::new(1024);
        let plan = ExecPlan::int8(&m);
        m.fc.forward(&x, &plan, Some(&mut trace));
        let rec = trace.take("fc0").unwrap();
        assert_eq!(rec.data(), x.data());
    }
}
