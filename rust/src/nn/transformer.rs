//! Transformer-mini: the encoder–decoder Transformer of the evaluation
//! (§VI-A), scaled to a synthetic translation task (DESIGN.md
//! §Substitutions): vocab 32, d_model 128, 4 heads, 2+2 layers.
//!
//! All projection and FFN matrices (Q/K/V/O, FF1/FF2 per layer, plus the
//! output head) are quantizable FC layers — 33 in total, the same tensor
//! population the paper quantizes in its 96-FC-layer Transformer.
//! Embeddings and LayerNorms stay FP32 (lookups/normalizers, not
//! dot-product layers).

use super::layer::{ExecPlan, HasQuantLayers, Linear, QLayerRef};
use super::ops::{add_positional, embed, layernorm_rows, relu_inplace, softmax_rows};
use super::trace::TraceStore;
use super::weights::WeightMap;
use crate::dnateq::LayerKind;
use crate::tensor::{SplitMix64, Tensor};
use crate::util::parallel_map;
use anyhow::Result;

pub const VOCAB: usize = 32;
pub const D_MODEL: usize = 128;
pub const N_HEADS: usize = 4;
pub const D_FF: usize = 256;
pub const N_ENC: usize = 2;
pub const N_DEC: usize = 2;
pub const HEAD_DIM: usize = D_MODEL / N_HEADS;

/// Special tokens of the synthetic task.
pub const PAD: usize = 0;
pub const BOS: usize = 1;
pub const EOS: usize = 2;

/// LayerNorm parameters.
#[derive(Clone, Debug)]
pub struct LnParams {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
}

impl LnParams {
    fn apply(&self, x: &Tensor) -> Tensor {
        layernorm_rows(x, &self.gamma, &self.beta, 1e-5)
    }
}

/// Multi-head attention block (self or cross).
pub struct MhAttention {
    pub q: Linear,
    pub k: Linear,
    pub v: Linear,
    pub o: Linear,
}

impl MhAttention {
    /// `x_q`: `[Lq, d]`, `x_kv`: `[Lkv, d]` → `[Lq, d]`.
    fn forward(
        &self,
        x_q: &Tensor,
        x_kv: &Tensor,
        causal: bool,
        plan: &ExecPlan,
        mut trace: Option<&mut TraceStore>,
    ) -> Tensor {
        let lq = x_q.shape()[0];
        let lkv = x_kv.shape()[0];
        let q = self.q.forward(x_q, plan, trace.as_deref_mut());
        let k = self.k.forward(x_kv, plan, trace.as_deref_mut());
        let v = self.v.forward(x_kv, plan, trace.as_deref_mut());

        let scale = 1.0 / (HEAD_DIM as f32).sqrt();
        let mut concat = vec![0.0f32; lq * D_MODEL];
        for h in 0..N_HEADS {
            let off = h * HEAD_DIM;
            // scores[i, j] = q_i · k_j * scale (head slice).
            let mut scores = vec![0.0f32; lq * lkv];
            for i in 0..lq {
                let qrow = &q.row(i)[off..off + HEAD_DIM];
                for j in 0..lkv {
                    if causal && j > i {
                        scores[i * lkv + j] = f32::NEG_INFINITY;
                        continue;
                    }
                    let krow = &k.row(j)[off..off + HEAD_DIM];
                    scores[i * lkv + j] =
                        super::linalg::dot(qrow, krow) * scale;
                }
            }
            let probs = softmax_rows(&Tensor::from_vec(&[lq, lkv], scores));
            for i in 0..lq {
                let prow = probs.row(i);
                let orow = &mut concat[i * D_MODEL + off..i * D_MODEL + off + HEAD_DIM];
                for (j, &p) in prow.iter().enumerate() {
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &v.row(j)[off..off + HEAD_DIM];
                    for (ov, &vv) in orow.iter_mut().zip(vrow) {
                        *ov += p * vv;
                    }
                }
            }
        }
        self.o.forward(&Tensor::from_vec(&[lq, D_MODEL], concat), plan, trace)
    }
}

/// Feed-forward block.
pub struct FeedForward {
    pub ff1: Linear,
    pub ff2: Linear,
}

impl FeedForward {
    fn forward(&self, x: &Tensor, plan: &ExecPlan, mut trace: Option<&mut TraceStore>) -> Tensor {
        let mut h = self.ff1.forward(x, plan, trace.as_deref_mut());
        relu_inplace(&mut h);
        self.ff2.forward(&h, plan, trace)
    }
}

/// Pre-LN encoder layer.
pub struct EncLayer {
    pub attn: MhAttention,
    pub ff: FeedForward,
    pub ln1: LnParams,
    pub ln2: LnParams,
}

/// Pre-LN decoder layer (self-attn + cross-attn + FFN).
pub struct DecLayer {
    pub self_attn: MhAttention,
    pub cross_attn: MhAttention,
    pub ff: FeedForward,
    pub ln1: LnParams,
    pub ln2: LnParams,
    pub ln3: LnParams,
}

/// The model.
pub struct TransformerMini {
    pub src_emb: Tensor,
    pub tgt_emb: Tensor,
    pub enc_layers: Vec<EncLayer>,
    pub dec_layers: Vec<DecLayer>,
    pub enc_ln: LnParams,
    pub dec_ln: LnParams,
    pub out: Linear,
}

fn mk_linear(w: &WeightMap, name: &str, out_f: usize, in_f: usize) -> Result<Linear> {
    Ok(Linear::new(
        name,
        w.tensor(&format!("{name}.w"), &[out_f, in_f])?,
        w.vec(&format!("{name}.b"), out_f)?,
    ))
}

fn mk_ln(w: &WeightMap, name: &str) -> Result<LnParams> {
    let gamma = w.vec(&format!("{name}.g"), D_MODEL)?;
    let beta = w.vec(&format!("{name}.b"), D_MODEL)?;
    Ok(LnParams { gamma, beta })
}

fn mk_attn(w: &WeightMap, prefix: &str) -> Result<MhAttention> {
    Ok(MhAttention {
        q: mk_linear(w, &format!("{prefix}.q"), D_MODEL, D_MODEL)?,
        k: mk_linear(w, &format!("{prefix}.k"), D_MODEL, D_MODEL)?,
        v: mk_linear(w, &format!("{prefix}.v"), D_MODEL, D_MODEL)?,
        o: mk_linear(w, &format!("{prefix}.o"), D_MODEL, D_MODEL)?,
    })
}

fn mk_ff(w: &WeightMap, prefix: &str) -> Result<FeedForward> {
    Ok(FeedForward {
        ff1: mk_linear(w, &format!("{prefix}.ff1"), D_FF, D_MODEL)?,
        ff2: mk_linear(w, &format!("{prefix}.ff2"), D_MODEL, D_FF)?,
    })
}

impl TransformerMini {
    pub fn from_weights(w: &WeightMap) -> Result<Self> {
        let mut enc_layers = Vec::new();
        for i in 0..N_ENC {
            enc_layers.push(EncLayer {
                attn: mk_attn(w, &format!("enc{i}"))?,
                ff: mk_ff(w, &format!("enc{i}"))?,
                ln1: mk_ln(w, &format!("enc{i}.ln1"))?,
                ln2: mk_ln(w, &format!("enc{i}.ln2"))?,
            });
        }
        let mut dec_layers = Vec::new();
        for i in 0..N_DEC {
            dec_layers.push(DecLayer {
                self_attn: mk_attn(w, &format!("dec{i}.s"))?,
                cross_attn: mk_attn(w, &format!("dec{i}.c"))?,
                ff: mk_ff(w, &format!("dec{i}"))?,
                ln1: mk_ln(w, &format!("dec{i}.ln1"))?,
                ln2: mk_ln(w, &format!("dec{i}.ln2"))?,
                ln3: mk_ln(w, &format!("dec{i}.ln3"))?,
            });
        }
        Ok(Self {
            src_emb: w.tensor("src_emb", &[VOCAB, D_MODEL])?,
            tgt_emb: w.tensor("tgt_emb", &[VOCAB, D_MODEL])?,
            enc_layers,
            dec_layers,
            enc_ln: mk_ln(w, "enc_ln")?,
            dec_ln: mk_ln(w, "dec_ln")?,
            out: mk_linear(w, "out", VOCAB, D_MODEL)?,
        })
    }

    /// Random Xavier-ish init (tests/benches without artifacts).
    pub fn random(seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut w = WeightMap::new();
        let lin = |w: &mut WeightMap, name: &str, o: usize, i: usize, rng: &mut SplitMix64| {
            let std = (1.0 / i as f32).sqrt();
            w.insert(&format!("{name}.w"), Tensor::rand_normal(&[o, i], 0.0, std, rng));
            w.insert(&format!("{name}.b"), Tensor::zeros(&[o]));
        };
        let ln = |w: &mut WeightMap, name: &str| {
            w.insert(&format!("{name}.g"), Tensor::full(&[D_MODEL], 1.0));
            w.insert(&format!("{name}.b"), Tensor::zeros(&[D_MODEL]));
        };
        w.insert("src_emb", Tensor::rand_normal(&[VOCAB, D_MODEL], 0.0, 0.1, &mut rng));
        w.insert("tgt_emb", Tensor::rand_normal(&[VOCAB, D_MODEL], 0.0, 0.1, &mut rng));
        for i in 0..N_ENC {
            for p in ["q", "k", "v", "o"] {
                lin(&mut w, &format!("enc{i}.{p}"), D_MODEL, D_MODEL, &mut rng);
            }
            lin(&mut w, &format!("enc{i}.ff1"), D_FF, D_MODEL, &mut rng);
            lin(&mut w, &format!("enc{i}.ff2"), D_MODEL, D_FF, &mut rng);
            ln(&mut w, &format!("enc{i}.ln1"));
            ln(&mut w, &format!("enc{i}.ln2"));
        }
        for i in 0..N_DEC {
            for p in ["s.q", "s.k", "s.v", "s.o", "c.q", "c.k", "c.v", "c.o"] {
                lin(&mut w, &format!("dec{i}.{p}"), D_MODEL, D_MODEL, &mut rng);
            }
            lin(&mut w, &format!("dec{i}.ff1"), D_FF, D_MODEL, &mut rng);
            lin(&mut w, &format!("dec{i}.ff2"), D_MODEL, D_FF, &mut rng);
            ln(&mut w, &format!("dec{i}.ln1"));
            ln(&mut w, &format!("dec{i}.ln2"));
            ln(&mut w, &format!("dec{i}.ln3"));
        }
        ln(&mut w, "enc_ln");
        ln(&mut w, "dec_ln");
        lin(&mut w, "out", VOCAB, D_MODEL, &mut rng);
        Self::from_weights(&w).expect("random init is well-formed")
    }

    /// Encode a source token sequence → `[L, d]`.
    pub fn encode(
        &self,
        src: &[usize],
        plan: &ExecPlan,
        mut trace: Option<&mut TraceStore>,
    ) -> Tensor {
        let mut x = embed(src, &self.src_emb);
        add_positional(&mut x);
        for layer in &self.enc_layers {
            let h = layer.attn.forward(
                &layer.ln1.apply(&x),
                &layer.ln1.apply(&x),
                false,
                plan,
                trace.as_deref_mut(),
            );
            x = x.add(&h);
            let h = layer.ff.forward(&layer.ln2.apply(&x), plan, trace.as_deref_mut());
            x = x.add(&h);
        }
        self.enc_ln.apply(&x)
    }

    /// Decode (teacher-forced) target prefix against encoder output →
    /// logits `[L_tgt, vocab]`.
    pub fn decode(
        &self,
        tgt: &[usize],
        enc_out: &Tensor,
        plan: &ExecPlan,
        mut trace: Option<&mut TraceStore>,
    ) -> Tensor {
        let mut x = embed(tgt, &self.tgt_emb);
        add_positional(&mut x);
        for layer in &self.dec_layers {
            let normed = layer.ln1.apply(&x);
            let h = layer.self_attn.forward(&normed, &normed, true, plan, trace.as_deref_mut());
            x = x.add(&h);
            let h = layer.cross_attn.forward(
                &layer.ln2.apply(&x),
                enc_out,
                false,
                plan,
                trace.as_deref_mut(),
            );
            x = x.add(&h);
            let h = layer.ff.forward(&layer.ln3.apply(&x), plan, trace.as_deref_mut());
            x = x.add(&h);
        }
        self.out.forward(&self.dec_ln.apply(&x), plan, trace)
    }

    /// Greedy decode until EOS or `max_len`.
    pub fn greedy_decode(&self, src: &[usize], max_len: usize, plan: &ExecPlan) -> Vec<usize> {
        let enc_out = self.encode(src, plan, None);
        let mut tgt = vec![BOS];
        for _ in 0..max_len {
            let logits = self.decode(&tgt, &enc_out, plan, None);
            let last = logits.row(logits.shape()[0] - 1);
            let next = last
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            tgt.push(next);
            if next == EOS {
                break;
            }
        }
        tgt
    }

    /// Greedy-decode a batch of source sequences, data-parallel over the
    /// sequences — the serving batcher's unit of work for the translator
    /// backend (autoregressive decodes have independent lengths, so the
    /// parallelism axis is the batch, not the GEMM).
    pub fn greedy_decode_batch(
        &self,
        srcs: &[Vec<usize>],
        max_len: usize,
        plan: &ExecPlan,
    ) -> Vec<Vec<usize>> {
        parallel_map(srcs, |src| self.greedy_decode(src, max_len, plan))
    }

    /// MAC count per quantizable layer for one (src, tgt) pair of length
    /// `l_src`/`l_tgt` — the accelerator workload generator.
    pub fn macs_per_layer(&self, l_src: usize, l_tgt: usize) -> Vec<(String, u64)> {
        let mut out = Vec::new();
        for lr in self.quant_layers() {
            let (o, i) = (lr.weights.shape()[0] as u64, lr.weights.shape()[1] as u64);
            let rows = if lr.name.starts_with("enc") {
                l_src
            } else if lr.name.starts_with("dec") {
                l_tgt
            } else {
                l_tgt // output head
            } as u64;
            out.push((lr.name.to_string(), o * i * rows));
        }
        out
    }
}

impl HasQuantLayers for TransformerMini {
    fn model_name(&self) -> &str {
        "transformer_mini"
    }

    fn quant_layers(&self) -> Vec<QLayerRef<'_>> {
        let mut v = Vec::new();
        fn add<'a>(v: &mut Vec<QLayerRef<'a>>, lin: &'a Linear) {
            v.push(QLayerRef { name: &lin.name, kind: LayerKind::Fc, weights: &lin.weights });
        }
        for layer in &self.enc_layers {
            for lin in [&layer.attn.q, &layer.attn.k, &layer.attn.v, &layer.attn.o] {
                add(&mut v, lin);
            }
            add(&mut v, &layer.ff.ff1);
            add(&mut v, &layer.ff.ff2);
        }
        for layer in &self.dec_layers {
            for lin in [
                &layer.self_attn.q,
                &layer.self_attn.k,
                &layer.self_attn.v,
                &layer.self_attn.o,
                &layer.cross_attn.q,
                &layer.cross_attn.k,
                &layer.cross_attn.v,
                &layer.cross_attn.o,
            ] {
                add(&mut v, lin);
            }
            add(&mut v, &layer.ff.ff1);
            add(&mut v, &layer.ff.ff2);
        }
        add(&mut v, &self.out);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_shapes() {
        let m = TransformerMini::random(151);
        let src = vec![BOS, 5, 9, 3, EOS];
        let enc = m.encode(&src, &ExecPlan::fp32(), None);
        assert_eq!(enc.shape(), &[5, D_MODEL]);
        let logits = m.decode(&[BOS, 7], &enc, &ExecPlan::fp32(), None);
        assert_eq!(logits.shape(), &[2, VOCAB]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn thirty_three_quant_layers() {
        let m = TransformerMini::random(152);
        // enc: 2×6, dec: 2×10, head: 1 → 33.
        assert_eq!(m.quant_layers().len(), 33);
    }

    #[test]
    fn causal_mask_blocks_future() {
        // Changing a future target token must not affect earlier logits.
        let m = TransformerMini::random(153);
        let src = vec![BOS, 4, 8, EOS];
        let enc = m.encode(&src, &ExecPlan::fp32(), None);
        let l1 = m.decode(&[BOS, 5, 6], &enc, &ExecPlan::fp32(), None);
        let l2 = m.decode(&[BOS, 5, 20], &enc, &ExecPlan::fp32(), None);
        for c in 0..VOCAB {
            assert_eq!(l1.row(0)[c], l2.row(0)[c], "position 0 leaked future");
            assert_eq!(l1.row(1)[c], l2.row(1)[c], "position 1 leaked future");
        }
    }

    #[test]
    fn greedy_decode_terminates() {
        let m = TransformerMini::random(154);
        let out = m.greedy_decode(&[BOS, 3, 4, EOS], 12, &ExecPlan::fp32());
        assert!(out.len() <= 13);
        assert_eq!(out[0], BOS);
        assert!(out.iter().all(|&t| t < VOCAB));
    }

    #[test]
    fn greedy_decode_batch_matches_sequential() {
        let m = TransformerMini::random(157);
        let plan = ExecPlan::fp32();
        let srcs = vec![
            vec![BOS, 3, 4, EOS],
            vec![BOS, 9, 8, 7, EOS],
            vec![BOS, 5, EOS],
        ];
        let batched = m.greedy_decode_batch(&srcs, 10, &plan);
        for (src, got) in srcs.iter().zip(&batched) {
            assert_eq!(got, &m.greedy_decode(src, 10, &plan));
        }
    }

    #[test]
    fn trace_covers_all_fc_layers() {
        let m = TransformerMini::random(155);
        let mut trace = TraceStore::new(1 << 12);
        let src = vec![BOS, 3, EOS];
        let enc = m.encode(&src, &ExecPlan::fp32(), Some(&mut trace));
        m.decode(&[BOS, 4], &enc, &ExecPlan::fp32(), Some(&mut trace));
        assert_eq!(trace.len(), 33);
    }

    #[test]
    fn macs_scale_with_length() {
        let m = TransformerMini::random(156);
        let a: u64 = m.macs_per_layer(4, 4).iter().map(|(_, x)| x).sum();
        let b: u64 = m.macs_per_layer(8, 8).iter().map(|(_, x)| x).sum();
        assert_eq!(b, 2 * a);
    }
}
