//! f32 inference engine + the evaluated model zoo (§VI-A).
//!
//! Three mini models mirror the paper's benchmarks (the ImageNet/WMT
//! substitutions are documented in DESIGN.md):
//!
//! * [`alexnet::AlexNetMini`] — 5 CONV + 3 FC classifier (AlexNet class)
//! * [`resnet::ResNetMini`] — residual CNN, 15 CONV + 1 FC (ResNet class)
//! * [`transformer::TransformerMini`] — encoder-decoder, 33 FC layers
//!
//! Quantized execution uses [`layer::ExecPlan`]s (fake quantization — the
//! paper's accuracy methodology); [`eval`] hosts the dataset-level
//! accuracy metrics and the calibration-trace collector that feeds
//! [`crate::dnateq::calibrate_model`].

pub mod alexnet;
pub mod eval;
pub mod layer;
pub mod linalg;
pub mod ops;
pub mod resnet;
pub mod trace;
pub mod transformer;
pub mod weights;

pub use alexnet::AlexNetMini;
pub use eval::{
    collect_image_calibration, collect_seq_calibration, eval_classifier, eval_translator,
    eval_translator_bleu,
};
pub use layer::{ActQuant, Conv2d, ExecPlan, HasQuantLayers, LayerExec, Linear, QLayerRef};
pub use resnet::ResNetMini;
pub use trace::TraceStore;
pub use transformer::TransformerMini;
pub use weights::WeightMap;
