//! Dataset-level evaluation + calibration-trace collection.
//!
//! These are the two halves of the paper's outer loop (Fig. 3): collect
//! FP32 activation traces over the calibration subset (step 1), and
//! measure quantized accuracy over the eval set to drive the `Thr_w`
//! controller (step 4).

use super::alexnet::AlexNetMini;
use super::layer::{ExecPlan, HasQuantLayers};
use super::resnet::ResNetMini;
use super::trace::TraceStore;
use super::transformer::TransformerMini;
use super::ops::argmax_slice;
use crate::dataset::{ImageDataset, SeqDataset};
use crate::dnateq::{CalibrationInput, LayerTensors};
use crate::tensor::Tensor;
use crate::util::parallel::{chunk_ranges, parallel_map};

/// Unified image-classifier interface over the two CNN minis.
pub trait ImageModel: HasQuantLayers + Send + Sync {
    fn logits(
        &self,
        image: &Tensor,
        plan: &ExecPlan,
        trace: Option<&mut TraceStore>,
    ) -> Tensor;

    /// Batched logits `[n, 3, 32, 32]` → `[n, classes]`. Implementations
    /// lower the whole batch onto batch-wide GEMMs.
    fn logits_batch(&self, images: &Tensor, plan: &ExecPlan) -> Tensor;

    fn predict(&self, image: &Tensor, plan: &ExecPlan) -> usize {
        self.logits(image, plan, None).argmax()
    }

    /// Predicted classes for a batch `[n, 3, 32, 32]`.
    fn predict_batch(&self, images: &Tensor, plan: &ExecPlan) -> Vec<usize> {
        let logits = self.logits_batch(images, plan);
        (0..logits.shape()[0]).map(|r| argmax_slice(logits.row(r))).collect()
    }
}

impl ImageModel for AlexNetMini {
    fn logits(&self, image: &Tensor, plan: &ExecPlan, trace: Option<&mut TraceStore>) -> Tensor {
        self.forward(image, plan, trace)
    }

    fn logits_batch(&self, images: &Tensor, plan: &ExecPlan) -> Tensor {
        self.forward_batch(images, plan, None)
    }
}

impl ImageModel for ResNetMini {
    fn logits(&self, image: &Tensor, plan: &ExecPlan, trace: Option<&mut TraceStore>) -> Tensor {
        self.forward(image, plan, trace)
    }

    fn logits_batch(&self, images: &Tensor, plan: &ExecPlan) -> Tensor {
        self.forward_batch(images, plan, None)
    }
}

/// Upper bound on the chunk size used by dataset-level evaluation:
/// large enough to amortize per-batch overhead, small enough that
/// chunks spread across cores. (`chunk_ranges` equalizes the pieces, so
/// actual chunks may be smaller — e.g. 40 samples split 20 + 20.)
pub const EVAL_BATCH: usize = 32;

/// Top-1 accuracy of a classifier over a dataset. The dataset is
/// evaluated in at-most-[`EVAL_BATCH`]-sized chunks (each one
/// GEMM-batched forward), spread across worker threads. Chunking does
/// not affect the numbers: the batched model paths quantize per image,
/// so any chunk size reproduces per-image evaluation exactly.
pub fn eval_classifier<M: ImageModel>(model: &M, data: &ImageDataset, plan: &ExecPlan) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    let ranges = chunk_ranges(data.len(), data.len().div_ceil(EVAL_BATCH));
    let hits = parallel_map(&ranges, |&(lo, hi)| {
        let preds = model.predict_batch(&data.batch_tensor(lo, hi), plan);
        preds.iter().zip(&data.labels[lo..hi]).filter(|(p, l)| p == l).count()
    });
    hits.iter().sum::<usize>() as f64 / data.len() as f64
}

/// Teacher-forced next-token accuracy of the translator — the smooth
/// BLEU stand-in used by the `Thr_w` controller (greedy-decode BLEU is
/// reported separately by [`eval_translator_bleu`]).
pub fn eval_translator(model: &TransformerMini, data: &SeqDataset, plan: &ExecPlan) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    let idx: Vec<usize> = (0..data.len()).collect();
    let counts = parallel_map(&idx, |&i| {
        let src = &data.src[i];
        let tgt = &data.tgt[i];
        let enc = model.encode(src, plan, None);
        // Predict tgt[1..] from tgt[..len-1].
        let logits = model.decode(&tgt[..tgt.len() - 1], &enc, plan, None);
        let mut hit = 0usize;
        for (pos, &gold) in tgt[1..].iter().enumerate() {
            let row = logits.row(pos);
            let pred = row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap());
            if pred.unwrap().0 == gold {
                hit += 1;
            }
        }
        (hit, tgt.len() - 1)
    });
    let (hits, total) = counts.iter().fold((0usize, 0usize), |(h, t), &(hh, tt)| (h + hh, t + tt));
    hits as f64 / total.max(1) as f64
}

/// Corpus-level BLEU (up to 4-grams, uniform weights, brevity penalty)
/// over greedy decodes — the Table V "BLEU" metric.
pub fn eval_translator_bleu(model: &TransformerMini, data: &SeqDataset, plan: &ExecPlan) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    let idx: Vec<usize> = (0..data.len()).collect();
    let pairs = parallel_map(&idx, |&i| {
        let hyp = model.greedy_decode(&data.src[i], data.tgt[i].len() + 4, plan);
        // Strip BOS/EOS from both sides for n-gram matching.
        let strip = |s: &[usize]| -> Vec<usize> {
            s.iter().copied().filter(|&t| t > 2).collect()
        };
        (strip(&hyp), strip(&data.tgt[i]))
    });
    corpus_bleu(&pairs)
}

/// Standard corpus BLEU-4.
pub fn corpus_bleu(pairs: &[(Vec<usize>, Vec<usize>)]) -> f64 {
    let mut match_n = [0usize; 4];
    let mut total_n = [0usize; 4];
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    for (hyp, reference) in pairs {
        hyp_len += hyp.len();
        ref_len += reference.len();
        for n in 1..=4usize {
            if hyp.len() < n {
                continue;
            }
            total_n[n - 1] += hyp.len() - n + 1;
            // Clipped n-gram matches.
            let mut ref_counts: std::collections::HashMap<&[usize], usize> =
                std::collections::HashMap::new();
            if reference.len() >= n {
                for w in reference.windows(n) {
                    *ref_counts.entry(w).or_default() += 1;
                }
            }
            for w in hyp.windows(n) {
                if let Some(c) = ref_counts.get_mut(w) {
                    if *c > 0 {
                        *c -= 1;
                        match_n[n - 1] += 1;
                    }
                }
            }
        }
    }
    let mut log_prec = 0.0f64;
    for n in 0..4 {
        if total_n[n] == 0 || match_n[n] == 0 {
            return 0.0;
        }
        log_prec += (match_n[n] as f64 / total_n[n] as f64).ln();
    }
    let bp = if hyp_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len.max(1) as f64).exp()
    };
    bp * (log_prec / 4.0).exp() * 100.0
}

/// Cap on retained activation values per layer during calibration.
pub const TRACE_CAP: usize = 1 << 16;

/// Collect a [`CalibrationInput`] for a CNN by tracing FP32 inference
/// over the calibration subset (step 1 of Fig. 3).
pub fn collect_image_calibration<M: ImageModel>(
    model: &M,
    calib: &ImageDataset,
) -> CalibrationInput {
    let mut trace = TraceStore::new(TRACE_CAP);
    let plan = ExecPlan::fp32();
    for i in 0..calib.len() {
        model.logits(&calib.image(i), &plan, Some(&mut trace));
    }
    build_input(model, trace)
}

/// Collect a [`CalibrationInput`] for the translator.
pub fn collect_seq_calibration(model: &TransformerMini, calib: &SeqDataset) -> CalibrationInput {
    let mut trace = TraceStore::new(TRACE_CAP);
    let plan = ExecPlan::fp32();
    for i in 0..calib.len() {
        let enc = model.encode(&calib.src[i], &plan, Some(&mut trace));
        let tgt = &calib.tgt[i];
        model.decode(&tgt[..tgt.len() - 1], &enc, &plan, Some(&mut trace));
    }
    build_input(model, trace)
}

fn build_input(model: &dyn HasQuantLayers, mut trace: TraceStore) -> CalibrationInput {
    let mut layers = Vec::new();
    for (i, lr) in model.quant_layers().iter().enumerate() {
        let acts = trace
            .take(lr.name)
            .unwrap_or_else(|| panic!("no activation trace for layer {}", lr.name));
        layers.push(LayerTensors {
            name: lr.name.to_string(),
            kind: lr.kind,
            weights: lr.weights.clone(),
            acts,
            is_first: i == 0,
        });
    }
    CalibrationInput { model: model.model_name().to_string(), layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifier_eval_in_unit_interval() {
        let m = AlexNetMini::random(171);
        let d = ImageDataset::synthetic(16, 172);
        let acc = eval_classifier(&m, &d, &ExecPlan::fp32());
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn batched_eval_matches_per_image_eval() {
        // 40 samples → two equalized chunks (20 + 20) under EVAL_BATCH.
        let m = AlexNetMini::random(179);
        let d = ImageDataset::synthetic(40, 180);
        let plan = ExecPlan::fp32();
        let batched = eval_classifier(&m, &d, &plan);
        let serial = (0..d.len())
            .filter(|&i| m.predict(&d.image(i), &plan) == d.labels[i])
            .count() as f64
            / d.len() as f64;
        assert_eq!(batched, serial);
    }

    #[test]
    fn translator_eval_in_unit_interval() {
        let m = TransformerMini::random(173);
        let d = SeqDataset::synthetic(4, 174);
        let acc = eval_translator(&m, &d, &ExecPlan::fp32());
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn bleu_perfect_match_is_100() {
        let pairs = vec![(vec![3, 4, 5, 6, 7], vec![3, 4, 5, 6, 7])];
        assert!((corpus_bleu(&pairs) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn bleu_no_match_is_0() {
        let pairs = vec![(vec![3, 3, 3, 3], vec![4, 5, 6, 7])];
        assert_eq!(corpus_bleu(&pairs), 0.0);
    }

    #[test]
    fn bleu_partial_between() {
        let pairs = vec![(vec![3, 4, 5, 6, 9], vec![3, 4, 5, 6, 7])];
        let b = corpus_bleu(&pairs);
        assert!(b > 0.0 && b < 100.0, "bleu {b}");
    }

    #[test]
    fn image_calibration_covers_all_layers() {
        let m = AlexNetMini::random(175);
        let d = ImageDataset::synthetic(2, 176);
        let input = collect_image_calibration(&m, &d);
        assert_eq!(input.layers.len(), 8);
        assert!(input.layers[0].is_first);
        assert!(!input.layers[1].is_first);
        assert!(input.layers.iter().all(|l| !l.acts.is_empty()));
        assert_eq!(input.model, "alexnet_mini");
    }

    #[test]
    fn seq_calibration_covers_all_layers() {
        let m = TransformerMini::random(177);
        let d = SeqDataset::synthetic(2, 178);
        let input = collect_seq_calibration(&m, &d);
        assert_eq!(input.layers.len(), 33);
        assert_eq!(input.model, "transformer_mini");
    }
}
