//! Elementwise / structural ops of the inference engine.

use crate::tensor::Tensor;

/// ReLU in place.
pub fn relu_inplace(t: &mut Tensor) {
    for v in t.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// GELU (tanh approximation) in place — used by the Transformer FFN.
pub fn gelu_inplace(t: &mut Tensor) {
    for v in t.data_mut() {
        let x = *v;
        let inner = 0.7978845608f32 * (x + 0.044715 * x * x * x);
        *v = 0.5 * x * (1.0 + inner.tanh());
    }
}

/// Row-wise softmax of a 2-D tensor (numerically stabilized).
pub fn softmax_rows(t: &Tensor) -> Tensor {
    assert_eq!(t.ndim(), 2);
    let (rows, cols) = (t.shape()[0], t.shape()[1]);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = t.row(r);
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        let orow = &mut out[r * cols..(r + 1) * cols];
        for (o, &x) in orow.iter_mut().zip(row) {
            let e = (x - m).exp();
            *o = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
    Tensor::from_vec(&[rows, cols], out)
}

/// Row-wise LayerNorm with learned gain/bias.
pub fn layernorm_rows(t: &Tensor, gamma: &[f32], beta: &[f32], eps: f32) -> Tensor {
    assert_eq!(t.ndim(), 2);
    let (rows, cols) = (t.shape()[0], t.shape()[1]);
    assert_eq!(gamma.len(), cols);
    assert_eq!(beta.len(), cols);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = t.row(r);
        let mean = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = &mut out[r * cols..(r + 1) * cols];
        for i in 0..cols {
            orow[i] = (row[i] - mean) * inv * gamma[i] + beta[i];
        }
    }
    Tensor::from_vec(&[rows, cols], out)
}

/// 2×2 max pooling (stride 2) over a `[c, h, w]` tensor. Odd trailing
/// rows/cols are dropped (floor semantics, matching jax `max_pool` with
/// VALID padding).
pub fn maxpool2x2(t: &Tensor) -> Tensor {
    assert_eq!(t.ndim(), 3);
    let (c, h, w) = (t.shape()[0], t.shape()[1], t.shape()[2]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; c * oh * ow];
    let data = t.data();
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let v = data[(ch * h + oy * 2 + dy) * w + ox * 2 + dx];
                        m = m.max(v);
                    }
                }
                out[(ch * oh + oy) * ow + ox] = m;
            }
        }
    }
    Tensor::from_vec(&[c, oh, ow], out)
}

/// Batched 2×2 max pooling: `[n, c, h, w]` → `[n, c, h/2, w/2]`.
/// Pools directly over the batch buffer (no per-image copies — this
/// sits on the batched CNN hot path); same window math as
/// [`maxpool2x2`], so results are bit-identical per image.
pub fn maxpool2x2_batch(t: &Tensor) -> Tensor {
    assert_eq!(t.ndim(), 4);
    let (n, c, h, w) = (t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; n * c * oh * ow];
    let data = t.data();
    for i in 0..n {
        let img = &data[i * c * h * w..(i + 1) * c * h * w];
        let dst = &mut out[i * c * oh * ow..(i + 1) * c * oh * ow];
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            m = m.max(img[(ch * h + oy * 2 + dy) * w + ox * 2 + dx]);
                        }
                    }
                    dst[(ch * oh + oy) * ow + ox] = m;
                }
            }
        }
    }
    Tensor::from_vec(&[n, c, oh, ow], out)
}

/// Global average pooling: `[c, h, w]` → `[c]`.
pub fn global_avg_pool(t: &Tensor) -> Tensor {
    assert_eq!(t.ndim(), 3);
    let (c, h, w) = (t.shape()[0], t.shape()[1], t.shape()[2]);
    let hw = (h * w) as f32;
    let out = (0..c)
        .map(|ch| t.data()[ch * h * w..(ch + 1) * h * w].iter().sum::<f32>() / hw)
        .collect();
    Tensor::from_vec(&[c], out)
}

/// Batched global average pooling: `[n, c, h, w]` → `[n, c]`.
pub fn global_avg_pool_batch(t: &Tensor) -> Tensor {
    assert_eq!(t.ndim(), 4);
    let (n, c, h, w) = (t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]);
    let hw = (h * w) as f32;
    let mut out = Vec::with_capacity(n * c);
    for i in 0..n {
        let img = t.batch(i);
        for ch in 0..c {
            out.push(img[ch * h * w..(ch + 1) * h * w].iter().sum::<f32>() / hw);
        }
    }
    Tensor::from_vec(&[n, c], out)
}

/// Index of the maximum element of a slice (row-wise argmax helper for
/// batched logits).
pub fn argmax_slice(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Embedding lookup: token ids → `[len, d_model]`.
pub fn embed(ids: &[usize], table: &Tensor) -> Tensor {
    assert_eq!(table.ndim(), 2);
    let d = table.shape()[1];
    let mut out = Vec::with_capacity(ids.len() * d);
    for &id in ids {
        assert!(id < table.shape()[0], "token id {id} out of vocab");
        out.extend_from_slice(table.row(id));
    }
    Tensor::from_vec(&[ids.len(), d], out)
}

/// Sinusoidal positional encoding added in place to `[len, d]` rows.
pub fn add_positional(t: &mut Tensor) {
    assert_eq!(t.ndim(), 2);
    let (len, d) = (t.shape()[0], t.shape()[1]);
    let data = t.data_mut();
    for pos in 0..len {
        for i in 0..d {
            let angle = pos as f32 / 10000f32.powf((2 * (i / 2)) as f32 / d as f32);
            data[pos * d + i] += if i % 2 == 0 { angle.sin() } else { angle.cos() };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps_negatives() {
        let mut t = Tensor::from_vec(&[4], vec![-1.0, 0.0, 2.0, -0.5]);
        relu_inplace(&mut t);
        assert_eq!(t.data(), &[0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 1000.0, 1000.0, 1000.0]);
        let s = softmax_rows(&t);
        for r in 0..2 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // Large logits don't overflow (stabilized).
        assert!(s.data().iter().all(|x| x.is_finite()));
        assert!((s.data()[5] - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let t = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let n = layernorm_rows(&t, &g, &b, 1e-5);
        let mean: f32 = n.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = n.row(0).iter().map(|&x| x * x).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn maxpool_picks_window_max() {
        let t = Tensor::from_vec(&[1, 2, 4], vec![1., 5., 2., 0., 3., 4., 0., 9.]);
        let p = maxpool2x2(&t);
        assert_eq!(p.shape(), &[1, 1, 2]);
        assert_eq!(p.data(), &[5.0, 9.0]);
    }

    #[test]
    fn gap_averages_channels() {
        let t = Tensor::from_vec(&[2, 1, 2], vec![1.0, 3.0, 10.0, 20.0]);
        let g = global_avg_pool(&t);
        assert_eq!(g.data(), &[2.0, 15.0]);
    }

    #[test]
    fn batched_pools_match_per_image() {
        use crate::tensor::SplitMix64;
        let mut rng = SplitMix64::new(61);
        let batch = Tensor::rand_normal(&[3, 2, 4, 6], 0.0, 1.0, &mut rng);
        let mp = maxpool2x2_batch(&batch);
        assert_eq!(mp.shape(), &[3, 2, 2, 3]);
        let gap = global_avg_pool_batch(&batch);
        assert_eq!(gap.shape(), &[3, 2]);
        for i in 0..3 {
            let img = Tensor::from_vec(&[2, 4, 6], batch.batch(i).to_vec());
            assert_eq!(mp.batch(i), maxpool2x2(&img).data());
            assert_eq!(gap.batch(i), global_avg_pool(&img).data());
        }
    }

    #[test]
    fn argmax_slice_finds_peak() {
        assert_eq!(argmax_slice(&[0.1, 0.9, 0.3, 0.95, 0.2]), 3);
        assert_eq!(argmax_slice(&[1.0]), 0);
    }

    #[test]
    fn embed_looks_up_rows() {
        let table = Tensor::from_vec(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let e = embed(&[2, 0], &table);
        assert_eq!(e.data(), &[20., 21., 0., 1.]);
    }

    #[test]
    fn positional_encoding_deterministic_and_bounded() {
        let mut a = Tensor::zeros(&[4, 8]);
        add_positional(&mut a);
        let mut b = Tensor::zeros(&[4, 8]);
        add_positional(&mut b);
        assert_eq!(a, b);
        assert!(a.data().iter().all(|&x| (-1.0..=1.0).contains(&x)));
        // Position 0: sin(0)=0, cos(0)=1 alternating.
        assert_eq!(a.row(0)[0], 0.0);
        assert_eq!(a.row(0)[1], 1.0);
    }
}
