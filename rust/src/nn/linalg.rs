//! Dense linear algebra for the f32 inference engine.
//!
//! A register-blocked GEMM (good enough to evaluate the mini model zoo at
//! interactive speed) plus the im2col transform that lowers convolutions
//! onto it.

use crate::expdot::simd;
use crate::tensor::Tensor;
use crate::util::parallel::{chunk_ranges, parallel_map, suggested_pieces};

/// Minimum FLOPs per parallel work item before the `_par` GEMM variants
/// fan out over `util::parallel::parallel_map`.
const PAR_MIN_FLOPS: usize = 1 << 21;

/// `C[m,n] = A[m,k] · B[k,n]` — blocked i-k-j loop with 4-wide unrolled
/// accumulation over `j`; the compiler vectorizes the inner row AXPY.
pub fn gemm(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "gemm inner dimension mismatch: {k} vs {k2}");
    let mut c = vec![0.0f32; m * n];
    gemm_into(a.data(), b.data(), &mut c, m, k, n);
    Tensor::from_vec(&[m, n], c)
}

/// GEMM into a caller-provided buffer (hot path, no allocation).
pub fn gemm_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    // Block over k to keep the B panel in cache for consecutive rows of A.
    const KB: usize = 256;
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KB).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for p in k0..k1 {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                axpy(av, brow, crow);
            }
        }
        k0 = k1;
    }
}

/// `y += a·x` over equal-length slices.
#[inline]
fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    let n = y.len().min(x.len());
    let (x4, xr) = x[..n].split_at(n - n % 4);
    let (y4, yr) = y[..n].split_at_mut(n - n % 4);
    for (yc, xc) in y4.chunks_exact_mut(4).zip(x4.chunks_exact(4)) {
        yc[0] += a * xc[0];
        yc[1] += a * xc[1];
        yc[2] += a * xc[2];
        yc[3] += a * xc[3];
    }
    for (yv, xv) in yr.iter_mut().zip(xr) {
        *yv += a * xv;
    }
}

/// [`gemm`] fanned out over `A`'s rows with `parallel_map` when the
/// product is large enough to amortize thread spawn; row-splitting keeps
/// every output element's accumulation order — and therefore the result
/// bits — identical to the serial path.
pub fn gemm_par(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "gemm inner dimension mismatch: {k} vs {k2}");
    let ranges = chunk_ranges(m, suggested_pieces(m * k * n, PAR_MIN_FLOPS));
    if ranges.len() <= 1 {
        return gemm(a, b);
    }
    let blocks = parallel_map(&ranges, |&(r0, r1)| {
        let mut c = vec![0.0f32; (r1 - r0) * n];
        gemm_into(&a.data()[r0 * k..r1 * k], b.data(), &mut c, r1 - r0, k, n);
        c
    });
    let mut c = Vec::with_capacity(m * n);
    for block in blocks {
        c.extend_from_slice(&block);
    }
    Tensor::from_vec(&[m, n], c)
}

/// `C = A · Bᵀ` for `B[n,k]` — the natural layout for FC layers whose
/// weights are stored `[out, in]`.
pub fn gemm_bt(a: &Tensor, b_t: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b_t.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b_t.shape()[0], b_t.shape()[1]);
    assert_eq!(k, k2, "gemm_bt inner dimension mismatch");
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = a.row(i);
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = dot(arow, b_t.row(j));
        }
    }
    Tensor::from_vec(&[m, n], c)
}

/// [`gemm_bt`] fanned out over `A`'s rows (the batch axis of an FC
/// layer) when the product is large; per-element dot order is unchanged,
/// so results are bit-identical to the serial path.
pub fn gemm_bt_par(a: &Tensor, b_t: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b_t.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b_t.shape()[0], b_t.shape()[1]);
    assert_eq!(k, k2, "gemm_bt inner dimension mismatch");
    let ranges = chunk_ranges(m, suggested_pieces(m * k * n, PAR_MIN_FLOPS));
    if ranges.len() <= 1 {
        return gemm_bt(a, b_t);
    }
    let blocks = parallel_map(&ranges, |&(r0, r1)| {
        let mut block = vec![0.0f32; (r1 - r0) * n];
        for (ri, i) in (r0..r1).enumerate() {
            let arow = a.row(i);
            let crow = &mut block[ri * n..(ri + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                *cv = dot(arow, b_t.row(j));
            }
        }
        block
    });
    let mut c = Vec::with_capacity(m * n);
    for block in blocks {
        c.extend_from_slice(&block);
    }
    Tensor::from_vec(&[m, n], c)
}

/// Unrolled dot product.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let c = n / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < c {
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
        i += 4;
    }
    let mut tail = 0.0f32;
    for j in c..n {
        tail += x[j] * y[j];
    }
    s0 + s1 + s2 + s3 + tail
}

/// One output row of an im2col patch row for stride-1 kernels: the taps
/// `ix = ox + kx - pad` read a *contiguous* input run, so the inner `ox`
/// loop collapses to a single block copy of the in-bounds span
/// (`orow` positions outside it keep their zero padding), vectorized via
/// [`simd::copy_f32`] (8-wide on AVX2, 16-wide on AVX-512).
fn copy_patch_row(
    backend: simd::SimdBackend,
    in_row: &[f32],
    orow: &mut [f32],
    kx: usize,
    pad: usize,
) {
    let (w, ow) = (in_row.len(), orow.len());
    let lo = pad.saturating_sub(kx);
    let hi = (w + pad).saturating_sub(kx).min(ow);
    if lo < hi {
        let ix0 = lo + kx - pad;
        simd::copy_f32(backend, &mut orow[lo..hi], &in_row[ix0..ix0 + (hi - lo)]);
    }
}

/// im2col for NCHW input: `[c, h, w]` → `[kh·kw·c_in, oh·ow]` patch
/// matrix, so `conv = gemm(W[out, kh·kw·c_in], patches)`.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    input: &[f32],
    c_in: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> (Tensor, usize, usize) {
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let rows = c_in * kh * kw;
    let cols = oh * ow;
    let backend = simd::active_backend();
    let mut out = vec![0.0f32; rows * cols];
    for c in 0..c_in {
        for ky in 0..kh {
            for kx in 0..kw {
                let r = (c * kh + ky) * kw + kx;
                let orow = &mut out[r * cols..(r + 1) * cols];
                for oy in 0..oh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // zero padding already in place
                    }
                    let in_row = &input[(c * h + iy as usize) * w..(c * h + iy as usize + 1) * w];
                    if stride == 1 {
                        copy_patch_row(backend, in_row, &mut orow[oy * ow..(oy + 1) * ow], kx, pad);
                        continue;
                    }
                    for ox in 0..ow {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        orow[oy * ow + ox] = in_row[ix as usize];
                    }
                }
            }
        }
    }
    (Tensor::from_vec(&[rows, cols], out), oh, ow)
}

/// Batched im2col: flat NCHW batch `[n, c_in, h, w]` → one
/// `[kh·kw·c_in, n·oh·ow]` patch matrix with columns grouped image-major
/// (`col = img·oh·ow + pos`), so an entire batch of convolutions lowers
/// onto a single GEMM instead of one GEMM per image.
#[allow(clippy::too_many_arguments)]
pub fn im2col_batch(
    input: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> (Tensor, usize, usize) {
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let rows = c_in * kh * kw;
    let img_cols = oh * ow;
    let cols = n * img_cols;
    let img_stride = c_in * h * w;
    debug_assert_eq!(input.len(), n * img_stride);
    let backend = simd::active_backend();
    let mut out = vec![0.0f32; rows * cols];
    for img in 0..n {
        let data = &input[img * img_stride..(img + 1) * img_stride];
        for c in 0..c_in {
            for ky in 0..kh {
                for kx in 0..kw {
                    let r = (c * kh + ky) * kw + kx;
                    let orow = &mut out[r * cols + img * img_cols..r * cols + (img + 1) * img_cols];
                    for oy in 0..oh {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue; // zero padding already in place
                        }
                        let in_row =
                            &data[(c * h + iy as usize) * w..(c * h + iy as usize + 1) * w];
                        if stride == 1 {
                            let oyrow = &mut orow[oy * ow..(oy + 1) * ow];
                            copy_patch_row(backend, in_row, oyrow, kx, pad);
                            continue;
                        }
                        for ox in 0..ow {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            orow[oy * ow + ox] = in_row[ix as usize];
                        }
                    }
                }
            }
        }
    }
    (Tensor::from_vec(&[rows, cols], out), oh, ow)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    #[test]
    fn gemm_matches_naive() {
        let mut rng = SplitMix64::new(101);
        let a = Tensor::rand_normal(&[7, 13], 0.0, 1.0, &mut rng);
        let b = Tensor::rand_normal(&[13, 9], 0.0, 1.0, &mut rng);
        let c = gemm(&a, &b);
        let want = a.matmul(&b);
        for (x, y) in c.data().iter().zip(want.data()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn gemm_bt_matches_gemm() {
        let mut rng = SplitMix64::new(102);
        let a = Tensor::rand_normal(&[5, 8], 0.0, 1.0, &mut rng);
        let bt = Tensor::rand_normal(&[6, 8], 0.0, 1.0, &mut rng);
        // Build B = Bᵀᵀ explicitly.
        let mut b = vec![0.0f32; 8 * 6];
        for j in 0..6 {
            for p in 0..8 {
                b[p * 6 + j] = bt.data()[j * 8 + p];
            }
        }
        let want = gemm(&a, &Tensor::from_vec(&[8, 6], b));
        let got = gemm_bt(&a, &bt);
        for (x, y) in got.data().iter().zip(want.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn parallel_gemms_bit_match_serial() {
        let mut rng = SplitMix64::new(104);
        // Big enough to cross the parallel threshold (m·k·n > 2^21).
        let a = Tensor::rand_normal(&[96, 160], 0.0, 1.0, &mut rng);
        let b = Tensor::rand_normal(&[160, 192], 0.0, 1.0, &mut rng);
        let bt = Tensor::rand_normal(&[192, 160], 0.0, 1.0, &mut rng);
        assert_eq!(gemm_par(&a, &b).data(), gemm(&a, &b).data());
        assert_eq!(gemm_bt_par(&a, &bt).data(), gemm_bt(&a, &bt).data());
        // Tiny products stay on (and match) the serial path.
        let sa = Tensor::rand_normal(&[3, 4], 0.0, 1.0, &mut rng);
        let sb = Tensor::rand_normal(&[4, 5], 0.0, 1.0, &mut rng);
        assert_eq!(gemm_par(&sa, &sb).data(), gemm(&sa, &sb).data());
    }

    #[test]
    fn im2col_batch_stacks_per_image_patches() {
        let mut rng = SplitMix64::new(105);
        let (n, c, h, w, k, stride, pad) = (3, 2, 5, 4, 3, 1, 1);
        let batch = Tensor::rand_normal(&[n, c, h, w], 0.0, 1.0, &mut rng);
        let (m, oh, ow) = im2col_batch(batch.data(), n, c, h, w, k, k, stride, pad);
        assert_eq!(m.shape(), &[c * k * k, n * oh * ow]);
        let img_cols = oh * ow;
        for img in 0..n {
            let (single, soh, sow) = im2col(batch.batch(img), c, h, w, k, k, stride, pad);
            assert_eq!((soh, sow), (oh, ow));
            for r in 0..c * k * k {
                let got = &m.data()[r * n * img_cols + img * img_cols..][..img_cols];
                let want = &single.data()[r * img_cols..(r + 1) * img_cols];
                assert_eq!(got, want, "img {img} row {r}");
            }
        }
    }

    #[test]
    fn im2col_stride1_matches_naive_gather() {
        // The stride-1 fast path block-copies the in-bounds run; check it
        // against per-element gathering, including kernels wider than the
        // input (runs clamped on both sides).
        let mut rng = SplitMix64::new(106);
        let shapes = [
            (2usize, 4usize, 5usize, 3usize, 3usize, 1usize),
            (1, 3, 3, 5, 5, 2),
            (2, 5, 3, 1, 3, 1),
        ];
        for (c_in, h, w, kh, kw, pad) in shapes {
            let input = Tensor::rand_normal(&[c_in, h, w], 0.0, 1.0, &mut rng);
            let (m, oh, ow) = im2col(input.data(), c_in, h, w, kh, kw, 1, pad);
            for c in 0..c_in {
                for ky in 0..kh {
                    for kx in 0..kw {
                        let r = (c * kh + ky) * kw + kx;
                        for oy in 0..oh {
                            for ox in 0..ow {
                                let iy = (oy + ky) as isize - pad as isize;
                                let ix = (ox + kx) as isize - pad as isize;
                                let oob = iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize;
                                let want = if oob {
                                    0.0
                                } else {
                                    input.data()[(c * h + iy as usize) * w + ix as usize]
                                };
                                let got = m.data()[r * oh * ow + oy * ow + ox];
                                assert_eq!(got, want, "r={r} oy={oy} ox={ox}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn copy_patch_row_identical_across_simd_backends() {
        // The stride-1 fast path is a pure block copy under every
        // backend, so patch rows must be bit-identical regardless of
        // dispatch (including spans that exercise the 16-wide AVX-512
        // body plus a ragged tail).
        let mut rng = SplitMix64::new(107);
        let in_row: Vec<f32> = (0..37).map(|_| rng.next_below(1000) as f32 - 500.0).collect();
        for (ow, kx, pad) in [(37usize, 0usize, 0usize), (37, 2, 1), (5, 1, 2), (40, 0, 3)] {
            let mut want = vec![0.0f32; ow];
            copy_patch_row(simd::SimdBackend::Scalar, &in_row, &mut want, kx, pad);
            for b in [simd::SimdBackend::Avx2, simd::SimdBackend::Avx512] {
                if !simd::available(b) {
                    continue;
                }
                let mut got = vec![0.0f32; ow];
                copy_patch_row(b, &in_row, &mut got, kx, pad);
                assert_eq!(got, want, "backend {} ow={ow} kx={kx} pad={pad}", b.name());
            }
        }
    }

    #[test]
    fn dot_handles_odd_lengths() {
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let y = [5.0f32, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&x, &y), 35.0);
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: im2col is the identity reshape.
        let input: Vec<f32> = (0..2 * 3 * 3).map(|x| x as f32).collect();
        let (m, oh, ow) = im2col(&input, 2, 3, 3, 1, 1, 1, 0);
        assert_eq!((oh, ow), (3, 3));
        assert_eq!(m.shape(), &[2, 9]);
        assert_eq!(m.data(), &input[..]);
    }

    #[test]
    fn im2col_3x3_manual_check() {
        // Single channel 3x3 input, 3x3 kernel, pad 1: center column of
        // the patch matrix (r = 4) must equal the input itself.
        let input: Vec<f32> = (1..=9).map(|x| x as f32).collect();
        let (m, oh, ow) = im2col(&input, 1, 3, 3, 3, 3, 1, 1);
        assert_eq!((oh, ow), (3, 3));
        let center = &m.data()[4 * 9..5 * 9];
        assert_eq!(center, &input[..]);
        // Top-left kernel tap at output (0,0) reads the padded corner.
        assert_eq!(m.data()[0], 0.0);
        // Bottom-right tap (r=8) at output (0,0) reads input(1,1)=5.
        assert_eq!(m.data()[8 * 9], 5.0);
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        // Direct convolution vs im2col+gemm on random data.
        let mut rng = SplitMix64::new(103);
        let (c_in, h, w, c_out, k, pad, stride) = (3, 6, 5, 4, 3, 1, 2);
        let input = Tensor::rand_normal(&[c_in, h, w], 0.0, 1.0, &mut rng);
        let weights = Tensor::rand_normal(&[c_out, c_in * k * k], 0.0, 0.5, &mut rng);
        let (patches, oh, ow) = im2col(input.data(), c_in, h, w, k, k, stride, pad);
        let out = gemm(&weights, &patches);
        // Direct computation at a few positions.
        for (oc, oy, ox) in [(0usize, 0usize, 0usize), (3, 1, 2), (2, 2, 1)] {
            let mut acc = 0.0f32;
            for c in 0..c_in {
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let iv = input.data()[(c * h + iy as usize) * w + ix as usize];
                        let wv = weights.data()[oc * c_in * k * k + (c * k + ky) * k + kx];
                        acc += iv * wv;
                    }
                }
            }
            let got = out.data()[oc * oh * ow + oy * ow + ox];
            assert!((got - acc).abs() < 1e-4, "({oc},{oy},{ox}): {got} vs {acc}");
        }
    }
}
