//! INT8 dot-product baseline (§IV, Fig. 4).
//!
//! Mirrors the paper's best-effort VNNI implementation: weights quantized
//! offline to INT8, activations quantized dynamically per input vector,
//! i32-accumulating GEMV with an unrolled inner loop (the portable analog
//! of `VPDPBUSD`), then a single dequantization multiply per output. The
//! inner dot dispatches through [`simd::dot_i8`] — scalar, AVX2
//! (`pmaddwd`), or AVX-512 (`vpmaddwd` on 512-bit lanes) — all exact i32
//! arithmetic, so every backend is bit-identical.

use super::simd::{self, SimdBackend};
use crate::dnateq::UniformParams;
use crate::tensor::Tensor;
use crate::util::parallel::parallel_row_blocks;

/// Minimum MACs per parallel work item before `forward_batch` fans the
/// output-row loop out over `util::parallel::parallel_map`.
const PAR_MIN_MACS: usize = 1 << 21;

/// INT8 FC layer: the Table III / accelerator-baseline reference point.
pub struct Int8Fc {
    w_q: Vec<i8>,
    w_params: UniformParams,
    pub out_features: usize,
    pub in_features: usize,
    bias: Option<Vec<f32>>,
    /// SIMD backend captured at construction ([`simd::active_backend`]);
    /// override per instance with [`Int8Fc::with_backend`].
    backend: SimdBackend,
}

impl Int8Fc {
    /// Quantize `[out, in]` weights offline (symmetric INT8).
    pub fn new(weights: &Tensor, bias: Option<Vec<f32>>) -> Self {
        assert_eq!(weights.ndim(), 2, "Int8Fc expects [out, in] weights");
        let (out_features, in_features) = (weights.shape()[0], weights.shape()[1]);
        if let Some(b) = &bias {
            assert_eq!(b.len(), out_features);
        }
        let w_params = UniformParams::calibrate(weights, 8);
        let w_q = weights.data().iter().map(|&x| w_params.encode(x)).collect();
        let backend = simd::active_backend();
        Self { w_q, w_params, out_features, in_features, bias, backend }
    }

    /// Rebind this layer to `backend` (must be available on this host).
    pub fn with_backend(mut self, backend: SimdBackend) -> Self {
        assert!(simd::available(backend), "backend {} unavailable on this CPU", backend.name());
        self.backend = backend;
        self
    }

    /// The SIMD backend this instance dispatches to.
    pub fn backend(&self) -> SimdBackend {
        self.backend
    }

    /// Weight storage in bytes (1 B/element).
    pub fn weight_bytes(&self) -> usize {
        self.w_q.len()
    }

    /// Forward one batch (`[batch, in]` → `[batch, out]`): dynamic INT8
    /// activation quantization + i32 GEMV + dequantization.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.shape()[1], self.in_features, "input feature mismatch");
        let batch = x.shape()[0];
        let a_params = UniformParams::calibrate(x, 8);
        let mut a_q = vec![0i8; self.in_features];
        let mut out = vec![0.0f32; batch * self.out_features];
        let scale = (a_params.delta * self.w_params.delta) as f32;

        for b in 0..batch {
            let row = x.row(b);
            for (dst, &src) in a_q.iter_mut().zip(row) {
                *dst = a_params.encode(src);
            }
            let orow = &mut out[b * self.out_features..(b + 1) * self.out_features];
            for j in 0..self.out_features {
                let wrow = &self.w_q[j * self.in_features..(j + 1) * self.in_features];
                orow[j] = simd::dot_i8(self.backend, &a_q, wrow) as f32 * scale
                    + self.bias.as_ref().map_or(0.0, |bb| bb[j]);
            }
        }
        Tensor::from_vec(&[batch, self.out_features], out)
    }

    /// Batched INT8 GEMM (`[batch, in]` → `[batch, out]`) — the baseline
    /// counterpart of [`crate::expdot::CountingFc::forward_batch`] so
    /// Table III stays apples-to-apples at every batch size.
    ///
    /// Each batch row is calibrated and quantized **independently** (a
    /// served batch is a bag of unrelated requests), which also makes the
    /// result bit-identical to stacking batch-1 [`Int8Fc::forward`]
    /// calls: `gemv_i8` is exact i32 arithmetic on identical inputs. The
    /// kernel streams every weight row once per batch (batch-1 looping
    /// re-streams the whole weight matrix per request) and fans the
    /// output-row loop out over [`parallel_row_blocks`] for large layers.
    pub fn forward_batch(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.shape()[1], self.in_features, "input feature mismatch");
        let batch = x.shape()[0];
        let inf = self.in_features;
        if batch == 0 {
            return Tensor::from_vec(&[0, self.out_features], Vec::new());
        }
        // Per-row dynamic quantization — one pass over the batch.
        let mut a_q = vec![0i8; batch * inf];
        let mut scales = vec![0.0f32; batch];
        for b in 0..batch {
            let row = x.row(b);
            let p = UniformParams::calibrate_slice(row, 8);
            for (dst, &src) in a_q[b * inf..(b + 1) * inf].iter_mut().zip(row) {
                *dst = p.encode(src);
            }
            scales[b] = (p.delta * self.w_params.delta) as f32;
        }

        let macs = batch * self.out_features * inf;
        let out = parallel_row_blocks(self.out_features, batch, macs, PAR_MIN_MACS, |j0, j1| {
            self.gemm_rows(&a_q, &scales, batch, j0, j1)
        });
        Tensor::from_vec(&[batch, self.out_features], out)
    }

    /// Kernel for output rows `[j0, j1)`: each weight row is loaded once
    /// and reused across every batch column. Returns `[batch, j1-j0]`.
    fn gemm_rows(
        &self,
        a_q: &[i8],
        scales: &[f32],
        batch: usize,
        j0: usize,
        j1: usize,
    ) -> Vec<f32> {
        let inf = self.in_features;
        let width = j1 - j0;
        let mut out = vec![0.0f32; batch * width];
        for (jj, j) in (j0..j1).enumerate() {
            let wrow = &self.w_q[j * inf..(j + 1) * inf];
            let bias = self.bias.as_ref().map_or(0.0, |bb| bb[j]);
            for b in 0..batch {
                let arow = &a_q[b * inf..(b + 1) * inf];
                let dot = simd::dot_i8(self.backend, arow, wrow) as f32;
                out[b * width + jj] = dot * scales[b] + bias;
            }
        }
        out
    }
}

/// i32-accumulating i8 dot product, unrolled ×4 with independent partial
/// sums so the autovectorizer maps it onto pmaddwd-style lanes.
#[inline]
pub fn gemv_i8(a: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), w.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] as i32 * w[i] as i32;
        s1 += a[i + 1] as i32 * w[i + 1] as i32;
        s2 += a[i + 2] as i32 * w[i + 2] as i32;
        s3 += a[i + 3] as i32 * w[i + 3] as i32;
    }
    let mut tail = 0i32;
    for i in chunks * 4..n {
        tail += a[i] as i32 * w[i] as i32;
    }
    s0 + s1 + s2 + s3 + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    #[test]
    fn gemv_matches_naive() {
        let mut rng = SplitMix64::new(91);
        let a: Vec<i8> = (0..1001).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
        let w: Vec<i8> = (0..1001).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
        let naive: i32 = a.iter().zip(&w).map(|(&x, &y)| x as i32 * y as i32).sum();
        assert_eq!(gemv_i8(&a, &w), naive);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: full f32 matmul cross-check
    fn int8_fc_approximates_f32_matmul() {
        let mut rng = SplitMix64::new(92);
        let (outf, inf, batch) = (9, 257, 2);
        let w = Tensor::rand_normal(&[outf, inf], 0.0, 0.1, &mut rng);
        let x = Tensor::rand_uniform(&[batch, inf], -1.0, 1.0, &mut rng);
        let fc = Int8Fc::new(&w, None);
        let got = fc.forward(&x);
        for b in 0..batch {
            for j in 0..outf {
                let want: f64 = x
                    .row(b)
                    .iter()
                    .zip(w.row(j))
                    .map(|(&a, &ww)| a as f64 * ww as f64)
                    .sum();
                let got_v = got.data()[b * outf + j] as f64;
                // INT8 error budget: ~1% of the accumulated magnitude.
                let mag: f64 =
                    x.row(b).iter().zip(w.row(j)).map(|(&a, &ww)| (a * ww).abs() as f64).sum();
                assert!(
                    (got_v - want).abs() < mag * 0.02 + 1e-3,
                    "b={b} j={j}: {got_v} vs {want}"
                );
            }
        }
    }

    #[test]
    fn forced_scalar_backend_is_bit_identical() {
        // `dot_i8` is exact i32 arithmetic under both backends, so whole
        // forwards agree bitwise (identity on scalar-only hosts).
        let mut rng = SplitMix64::new(93);
        let w = Tensor::rand_normal(&[6, 37], 0.0, 0.2, &mut rng);
        let x = Tensor::rand_uniform(&[4, 37], -1.0, 1.0, &mut rng);
        let best = Int8Fc::new(&w, None).with_backend(simd::best_available());
        let scalar = Int8Fc::new(&w, None).with_backend(SimdBackend::Scalar);
        assert_eq!(scalar.forward_batch(&x).data(), best.forward_batch(&x).data());
        assert_eq!(scalar.forward(&x).data(), best.forward(&x).data());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: 20-case property sweep
    fn forward_batch_bit_identical_to_stacked_forward() {
        use crate::util::prop::{for_all, PropConfig};
        for_all(
            PropConfig { cases: 20, seed: 0x18A7C },
            |rng, size| {
                let inf = 3 + rng.next_below(32 * size.max(1));
                let outf = 1 + rng.next_below(24);
                let batch = 1 + rng.next_below(9);
                let w = Tensor::rand_normal(&[outf, inf], 0.0, 0.2, rng);
                let x = Tensor::rand_uniform(&[batch, inf], -1.5, 1.5, rng);
                (w, x)
            },
            |(w, x)| {
                let bias: Vec<f32> = (0..w.shape()[0]).map(|j| 0.5 - j as f32 * 0.125).collect();
                let fc = Int8Fc::new(w, Some(bias));
                let got = fc.forward_batch(x);
                let (batch, inf) = (x.shape()[0], x.shape()[1]);
                for b in 0..batch {
                    let row = Tensor::from_vec(&[1, inf], x.row(b).to_vec());
                    let want = fc.forward(&row);
                    for (j, (&g, &r)) in
                        got.row(b).iter().zip(want.data()).enumerate()
                    {
                        if g.to_bits() != r.to_bits() {
                            return Err(format!("b={b} j={j}: {g} vs {r} (bits differ)"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn forward_batch_handles_empty_batch() {
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let fc = Int8Fc::new(&w, None);
        let y = fc.forward_batch(&Tensor::zeros(&[0, 2]));
        assert_eq!(y.shape(), &[0, 2]);
    }

    #[test]
    fn bias_applied() {
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let x = Tensor::from_vec(&[1, 2], vec![0.5, -0.5]);
        let fc = Int8Fc::new(&w, Some(vec![10.0, 20.0]));
        let y = fc.forward(&x);
        assert!((y.data()[0] - 10.5).abs() < 0.05);
        assert!((y.data()[1] - 19.5).abs() < 0.05);
    }

    #[test]
    fn weight_bytes_one_per_element() {
        let w = Tensor::zeros(&[4, 8]);
        let fc = Int8Fc::new(&w, None);
        assert_eq!(fc.weight_bytes(), 32);
    }
}
