//! INT8 dot-product baseline (§IV, Fig. 4).
//!
//! Mirrors the paper's best-effort VNNI implementation: weights quantized
//! offline to INT8, activations quantized dynamically per input vector,
//! i32-accumulating GEMV with an unrolled inner loop (the portable analog
//! of `VPDPBUSD`), then a single dequantization multiply per output.

use crate::dnateq::UniformParams;
use crate::tensor::Tensor;

/// INT8 FC layer: the Table III / accelerator-baseline reference point.
pub struct Int8Fc {
    w_q: Vec<i8>,
    w_params: UniformParams,
    pub out_features: usize,
    pub in_features: usize,
    bias: Option<Vec<f32>>,
}

impl Int8Fc {
    /// Quantize `[out, in]` weights offline (symmetric INT8).
    pub fn new(weights: &Tensor, bias: Option<Vec<f32>>) -> Self {
        assert_eq!(weights.ndim(), 2, "Int8Fc expects [out, in] weights");
        let (out_features, in_features) = (weights.shape()[0], weights.shape()[1]);
        if let Some(b) = &bias {
            assert_eq!(b.len(), out_features);
        }
        let w_params = UniformParams::calibrate(weights, 8);
        let w_q = weights.data().iter().map(|&x| w_params.encode(x)).collect();
        Self { w_q, w_params, out_features, in_features, bias }
    }

    /// Weight storage in bytes (1 B/element).
    pub fn weight_bytes(&self) -> usize {
        self.w_q.len()
    }

    /// Forward one batch (`[batch, in]` → `[batch, out]`): dynamic INT8
    /// activation quantization + i32 GEMV + dequantization.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.shape()[1], self.in_features, "input feature mismatch");
        let batch = x.shape()[0];
        let a_params = UniformParams::calibrate(x, 8);
        let mut a_q = vec![0i8; self.in_features];
        let mut out = vec![0.0f32; batch * self.out_features];
        let scale = (a_params.delta * self.w_params.delta) as f32;

        for b in 0..batch {
            let row = x.row(b);
            for (dst, &src) in a_q.iter_mut().zip(row) {
                *dst = a_params.encode(src);
            }
            let orow = &mut out[b * self.out_features..(b + 1) * self.out_features];
            for j in 0..self.out_features {
                let wrow = &self.w_q[j * self.in_features..(j + 1) * self.in_features];
                orow[j] = gemv_i8(&a_q, wrow) as f32 * scale
                    + self.bias.as_ref().map_or(0.0, |bb| bb[j]);
            }
        }
        Tensor::from_vec(&[batch, self.out_features], out)
    }
}

/// i32-accumulating i8 dot product, unrolled ×4 with independent partial
/// sums so the autovectorizer maps it onto pmaddwd-style lanes.
#[inline]
pub fn gemv_i8(a: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), w.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] as i32 * w[i] as i32;
        s1 += a[i + 1] as i32 * w[i + 1] as i32;
        s2 += a[i + 2] as i32 * w[i + 2] as i32;
        s3 += a[i + 3] as i32 * w[i + 3] as i32;
    }
    let mut tail = 0i32;
    for i in chunks * 4..n {
        tail += a[i] as i32 * w[i] as i32;
    }
    s0 + s1 + s2 + s3 + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    #[test]
    fn gemv_matches_naive() {
        let mut rng = SplitMix64::new(91);
        let a: Vec<i8> = (0..1001).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
        let w: Vec<i8> = (0..1001).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
        let naive: i32 = a.iter().zip(&w).map(|(&x, &y)| x as i32 * y as i32).sum();
        assert_eq!(gemv_i8(&a, &w), naive);
    }

    #[test]
    fn int8_fc_approximates_f32_matmul() {
        let mut rng = SplitMix64::new(92);
        let (outf, inf, batch) = (9, 257, 2);
        let w = Tensor::rand_normal(&[outf, inf], 0.0, 0.1, &mut rng);
        let x = Tensor::rand_uniform(&[batch, inf], -1.0, 1.0, &mut rng);
        let fc = Int8Fc::new(&w, None);
        let got = fc.forward(&x);
        for b in 0..batch {
            for j in 0..outf {
                let want: f64 = x
                    .row(b)
                    .iter()
                    .zip(w.row(j))
                    .map(|(&a, &ww)| a as f64 * ww as f64)
                    .sum();
                let got_v = got.data()[b * outf + j] as f64;
                // INT8 error budget: ~1% of the accumulated magnitude.
                let mag: f64 = x.row(b).iter().zip(w.row(j)).map(|(&a, &ww)| (a * ww).abs() as f64).sum();
                assert!(
                    (got_v - want).abs() < mag * 0.02 + 1e-3,
                    "b={b} j={j}: {got_v} vs {want}"
                );
            }
        }
    }

    #[test]
    fn bias_applied() {
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let x = Tensor::from_vec(&[1, 2], vec![0.5, -0.5]);
        let fc = Int8Fc::new(&w, Some(vec![10.0, 20.0]));
        let y = fc.forward(&x);
        assert!((y.data()[0] - 10.5).abs() < 0.05);
        assert!((y.data()[1] - 19.5).abs() < 0.05);
    }

    #[test]
    fn weight_bytes_one_per_element() {
        let w = Tensor::zeros(&[4, 8]);
        let fc = Int8Fc::new(&w, None);
        assert_eq!(fc.weight_bytes(), 32);
    }
}
