//! Nibble packing of (sign, exponent) codes.
//!
//! For 3-bit layers a full (sign, exponent) pair fits in 4 bits, so two
//! tensor elements pack per byte — the 2× footprint reduction over INT8
//! that drives the large-layer speedups of Table III. Encoding:
//!
//! ```text
//! nibble = 0xF                      for exact zero
//!          sign<<3 | (code + R_max) otherwise  (code ∈ [-3, 3] → 0..6)
//! ```

use crate::dnateq::{QuantizedTensor, ZERO_CODE_SENTINEL};

/// Zero marker nibble.
pub const ZERO_NIBBLE: u8 = 0xF;

/// Packed 3-bit (sign, exponent) codes, two per byte, low nibble first.
#[derive(Clone, Debug)]
pub struct PackedCodes {
    /// Packed payload.
    pub bytes: Vec<u8>,
    /// Number of logical elements (may be odd).
    pub len: usize,
}

/// Pack a 3-bit quantized tensor. Panics if `n_bits != 3` — wider codes
/// use the byte-per-element layout.
pub fn pack_codes(q: &QuantizedTensor) -> PackedCodes {
    assert_eq!(q.params.n_bits, 3, "nibble packing requires 3-bit codes");
    let r_max = q.params.r_max(); // = 3
    let nibble = |idx: usize| -> u8 {
        let c = q.codes[idx];
        if c == ZERO_CODE_SENTINEL {
            ZERO_NIBBLE
        } else {
            let sign_bit = if q.signs[idx] < 0 { 8u8 } else { 0u8 };
            sign_bit | (c as i32 + r_max) as u8
        }
    };
    let len = q.codes.len();
    let mut bytes = Vec::with_capacity(len.div_ceil(2));
    let mut i = 0;
    while i + 1 < len {
        bytes.push(nibble(i) | (nibble(i + 1) << 4));
        i += 2;
    }
    if i < len {
        bytes.push(nibble(i) | (ZERO_NIBBLE << 4));
    }
    PackedCodes { bytes, len }
}

/// Unpack to parallel (codes, signs) vectors (zeros restored to the
/// sentinel). Mainly for tests — the hot kernels consume nibbles via a
/// 16-entry LUT without materializing this.
pub fn unpack_codes(p: &PackedCodes, r_max: i32) -> (Vec<i8>, Vec<i8>) {
    let mut codes = Vec::with_capacity(p.len);
    let mut signs = Vec::with_capacity(p.len);
    for i in 0..p.len {
        let byte = p.bytes[i / 2];
        let nib = if i % 2 == 0 { byte & 0xF } else { byte >> 4 };
        if nib == ZERO_NIBBLE {
            codes.push(ZERO_CODE_SENTINEL);
            signs.push(1);
        } else {
            codes.push(((nib & 0x7) as i32 - r_max) as i8);
            signs.push(if nib & 0x8 != 0 { -1 } else { 1 });
        }
    }
    (codes, signs)
}

/// Pre-shift exponent codes to table offsets: `code + R_max` with `0xFF`
/// marking exact zeros — the Input Shift-Reg trick (§V-B). Shared by the
/// batch-1 and batched counting kernels; the batched path calls it once
/// per batch so quantized activations are shifted a single time.
pub fn shift_codes(codes: &[i8], r_max: i32) -> Vec<u8> {
    codes
        .iter()
        .map(|&c| if c == ZERO_CODE_SENTINEL { 0xFF } else { (c as i32 + r_max) as u8 })
        .collect()
}

/// Pre-split decode LUT: `pairs` feeds the scalar path; `plus` /
/// `signs` are the same 16 entries as parallel byte tables in exactly
/// the operand layout the SIMD lookups consume (`pshufb` /
/// `vpermb`), built once per forward pass instead of re-split per
/// decoded weight row.
#[derive(Clone, Debug)]
pub struct NibbleLut {
    /// `(code + R_max, sign)` per nibble — the scalar kernel's view.
    pub pairs: [(u8, i8); 16],
    /// Pre-shifted codes only (`0xFF` for zero/invalid nibbles).
    pub plus: [u8; 16],
    /// Signs only (`0` for zero/invalid nibbles).
    pub signs: [i8; 16],
}

/// Build the pre-split decode LUT (see [`NibbleLut`]).
pub fn nibble_lut_tables(r_max: i32) -> NibbleLut {
    let pairs = nibble_lut(r_max);
    let mut plus = [0u8; 16];
    let mut signs = [0i8; 16];
    for (k, &(p, s)) in pairs.iter().enumerate() {
        plus[k] = p;
        signs[k] = s;
    }
    NibbleLut { pairs, plus, signs }
}

/// Decode LUT for the counting kernel: maps a nibble to
/// `(code + R_max, sign)` with `(0xFF, 0)` for zero — so the kernel's
/// inner loop is a table load + add + signed increment.
pub fn nibble_lut(r_max: i32) -> [(u8, i8); 16] {
    let mut lut = [(0xFFu8, 0i8); 16];
    for nib in 0u8..16 {
        if nib == ZERO_NIBBLE {
            continue;
        }
        let code = (nib & 0x7) as i32 - r_max;
        if code > r_max {
            continue; // unreachable encodings stay marked invalid
        }
        let sign = if nib & 0x8 != 0 { -1i8 } else { 1i8 };
        lut[nib as usize] = ((code + r_max) as u8, sign);
    }
    lut
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnateq::ExpQuantParams;
    use crate::tensor::{SplitMix64, Tensor};

    fn quantized(n_elems: usize, seed: u64) -> QuantizedTensor {
        let mut rng = SplitMix64::new(seed);
        let mut t = Tensor::rand_signed_exponential(&[n_elems], 2.0, &mut rng);
        // Sprinkle exact zeros.
        for i in (0..n_elems).step_by(17) {
            t.data_mut()[i] = 0.0;
        }
        let p = ExpQuantParams::init_for_tensor(&t, 3);
        p.quantize(&t)
    }

    #[test]
    fn pack_unpack_roundtrip_even() {
        let q = quantized(1024, 71);
        let packed = pack_codes(&q);
        assert_eq!(packed.bytes.len(), 512);
        let (codes, signs) = unpack_codes(&packed, q.params.r_max());
        assert_eq!(codes, q.codes);
        assert_eq!(signs, q.signs);
    }

    #[test]
    fn pack_unpack_roundtrip_odd() {
        let q = quantized(333, 72);
        let packed = pack_codes(&q);
        assert_eq!(packed.bytes.len(), 167);
        let (codes, signs) = unpack_codes(&packed, q.params.r_max());
        assert_eq!(codes, q.codes);
        assert_eq!(signs, q.signs);
    }

    #[test]
    fn footprint_is_half_a_byte_per_element() {
        let q = quantized(4096, 73);
        let packed = pack_codes(&q);
        assert_eq!(packed.bytes.len() * 2, 4096);
    }

    #[test]
    fn shift_codes_marks_zeros_and_offsets_rest() {
        let q = quantized(257, 75);
        let r_max = q.params.r_max();
        let shifted = shift_codes(&q.codes, r_max);
        for (i, &c) in q.codes.iter().enumerate() {
            if c == ZERO_CODE_SENTINEL {
                assert_eq!(shifted[i], 0xFF);
            } else {
                assert_eq!(shifted[i] as i32, c as i32 + r_max);
            }
        }
    }

    #[test]
    fn split_lut_tables_mirror_the_pair_lut() {
        for r_max in [1, 3, 7] {
            let split = nibble_lut_tables(r_max);
            assert_eq!(split.pairs, nibble_lut(r_max));
            for k in 0..16 {
                assert_eq!(split.plus[k], split.pairs[k].0, "r_max={r_max} nib={k}");
                assert_eq!(split.signs[k], split.pairs[k].1, "r_max={r_max} nib={k}");
            }
        }
    }

    #[test]
    fn lut_matches_unpack() {
        let r_max = 3;
        let lut = nibble_lut(r_max);
        let q = quantized(256, 74);
        let packed = pack_codes(&q);
        for i in 0..packed.len {
            let byte = packed.bytes[i / 2];
            let nib = if i % 2 == 0 { byte & 0xF } else { byte >> 4 };
            let (plus, sign) = lut[nib as usize];
            if q.codes[i] == crate::dnateq::ZERO_CODE_SENTINEL {
                assert_eq!(sign, 0);
            } else {
                assert_eq!(plus as i32, q.codes[i] as i32 + r_max);
                assert_eq!(sign, q.signs[i]);
            }
        }
    }
}
