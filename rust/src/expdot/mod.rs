//! Exponential-domain dot product (§III-C) and its software
//! implementations (§IV).
//!
//! With both tensors in the form `S(α·bⁱ + β)` and a shared base `b`, a
//! dot product expands into four terms (Eq. 8), each computable by
//! *counting exponent occurrences* instead of multiplying:
//!
//! ```text
//! Σ AᵢWᵢ = αA·αW Σ s·b^(aᵢ+wᵢ)  +  αW·βA Σ s·b^(wᵢ)
//!        + αA·βW Σ s·b^(aᵢ)     +  βA·βW Σ s
//! ```
//!
//! * [`context`] — per-layer reconstruction context: base-power lookup
//!   tables (the hardware BLUT) and the four coefficient products.
//! * [`counting`] — the counting engines: a reference per-pair
//!   implementation and the register-blocked FC kernel that mirrors the
//!   paper's SIMD design (counter arrays kept L1/register-resident).
//! * [`int8`] — the VNNI-style INT8 dot-product baseline of Table III.
//! * [`pack`] — nibble packing of (sign, exponent) codes; the 2×
//!   footprint reduction is where the large-layer speedups come from.
//! * [`simd`] — explicit AVX2 and AVX-512 kernels for the counting/INT8
//!   inner loops and the BLUT reconstruction, behind runtime feature
//!   detection, bit-exact with the scalar fallbacks and forcible to any
//!   backend for testing. The AVX-512 counting path replaces the
//!   movemask drain with replicated counter copies folded at row end.

pub mod context;
pub mod counting;
pub mod int8;
pub mod pack;
pub mod simd;

pub use context::ExpDotContext;
pub use counting::{exp_dot_reference, CountingFc};
pub use int8::Int8Fc;
pub use pack::{pack_codes, shift_codes, unpack_codes, PackedCodes};
pub use simd::SimdBackend;
