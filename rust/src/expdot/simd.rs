//! Explicit SIMD kernels for the exponential-domain hot loops, behind
//! runtime dispatch.
//!
//! The counting GEMM's inner loops are exponent extraction/shifting
//! ([`shift_codes`]), nibble decoding of the packed 3-bit store
//! ([`decode_nibbles`]), the counter-table scatter itself
//! ([`accumulate_row`]), and the BLUT reconstruction dot
//! ([`blut_dot`]); the INT8 baseline's is the i8 dot product
//! ([`dot_i8`]) and the f32 engine's im2col is a strided copy
//! ([`copy_f32`]). Each has AVX2 and AVX-512 implementations
//! (`std::arch` intrinsics behind `is_x86_feature_detected!`) and the
//! original scalar code as the portable fallback. **Every SIMD path is
//! bit-exact with scalar**: the vector work is integer (wrapping adds,
//! compares, table lookups) or pure copies, counter updates are
//! commutative i32 adds, and the float reconstruction shares one fixed
//! 8-lane reduction tree across all backends, so only the order of
//! side-effect-free operations changes.
//!
//! The AVX-512 accumulate path additionally breaks the histogram
//! scatter dependency with *replicated counter copies*: lanes scatter
//! round-robin into [`HIST_COPIES`] private copies of the counter set
//! (lane `k` → copy `k mod HIST_COPIES`), so consecutive updates that
//! hit the same (ap+wp) slot — common, exponent codes concentrate near
//! zero — land in different cache lines and retire independently. The
//! copies are folded back with a vectorized i32 reduction at row end;
//! every update is a commutative i32 add, so the result is
//! bit-identical to the single-table scalar scheme.
//!
//! Backend resolution (cheapest override wins):
//! 1. a process-wide programmatic override installed via [`force`]
//!    (the `--simd` CLI flag);
//! 2. the `DNATEQ_SIMD` environment variable (`scalar` / `avx2` /
//!    `avx512` / `auto`) — how the CI matrix pins each dispatch arm;
//! 3. runtime CPU detection ([`detect`]).
//!
//! The engines capture [`active_backend`] at construction and expose a
//! `with_backend` builder, so scalar and SIMD instances can be compared
//! side by side in the same process (the equivalence property suite and
//! `bench_gate` both do).

use super::pack::NibbleLut;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::__m512i;

/// A counting-kernel instruction-set backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdBackend {
    /// Portable scalar code — the reference semantics on every arch.
    Scalar,
    /// 256-bit AVX2 integer kernels (x86_64 only, runtime-detected).
    Avx2,
    /// 512-bit AVX-512 kernels (x86_64 only, runtime-detected via
    /// `avx512f` + `avx512bw`): mask-register sentinel remap, single
    /// `vpermb` nibble decode where `avx512vbmi` is present (512-bit
    /// `pshufb` otherwise), and the replicated-histogram accumulate.
    Avx512,
}

impl SimdBackend {
    /// Stable lower-case name (used in bench case labels and logs).
    pub fn name(self) -> &'static str {
        match self {
            SimdBackend::Scalar => "scalar",
            SimdBackend::Avx2 => "avx2",
            SimdBackend::Avx512 => "avx512",
        }
    }

    /// Every backend the crate knows, strongest first — the probe order
    /// used by [`detect`] and the capability report in `bench_gate`.
    pub fn all() -> [SimdBackend; 3] {
        [SimdBackend::Avx512, SimdBackend::Avx2, SimdBackend::Scalar]
    }
}

/// `FORCE` values: 0 = no override, 1 = scalar, 2 = avx2, 3 = avx512.
static FORCE: AtomicU8 = AtomicU8::new(0);
/// Resolved env-or-detected default, computed once.
static DEFAULT: OnceLock<SimdBackend> = OnceLock::new();

/// What the CPU supports, ignoring every override (strongest backend).
pub fn detect() -> SimdBackend {
    for b in SimdBackend::all() {
        if available(b) {
            return b;
        }
    }
    SimdBackend::Scalar
}

/// The best backend this host can run (cached [`detect`]).
pub fn best_available() -> SimdBackend {
    static BEST: OnceLock<SimdBackend> = OnceLock::new();
    *BEST.get_or_init(detect)
}

/// Whether `backend` can execute on this host. Per-feature, not
/// best-only: an AVX-512 host can still force `avx2` (the CI matrix
/// relies on exactly that to pin its dispatch arms).
pub fn available(backend: SimdBackend) -> bool {
    match backend {
        SimdBackend::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2 => is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx512 => {
            is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512bw")
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => false,
    }
}

/// Whether the nibble decode can use `vpermb` (cached; the AVX-512
/// backend otherwise falls back to a 512-bit `pshufb`, bit-identical).
#[cfg(target_arch = "x86_64")]
fn has_avx512vbmi() -> bool {
    static VBMI: OnceLock<bool> = OnceLock::new();
    *VBMI.get_or_init(|| is_x86_feature_detected!("avx512vbmi"))
}

/// Parse a backend name: `scalar`, `avx2` (alias `simd`), `avx512`, or
/// `auto` (= clear the override and fall back to env/detection).
pub fn parse(name: &str) -> Result<Option<SimdBackend>, String> {
    match name {
        "auto" | "" => Ok(None),
        "scalar" => Ok(Some(SimdBackend::Scalar)),
        "avx2" | "simd" => Ok(Some(SimdBackend::Avx2)),
        "avx512" => Ok(Some(SimdBackend::Avx512)),
        other => {
            Err(format!("unknown SIMD backend `{other}`; use scalar, avx2, avx512 or auto"))
        }
    }
}

/// Install (or clear, with `None`) the process-wide backend override.
/// Takes precedence over `DNATEQ_SIMD` and detection for every engine
/// constructed afterwards. Fails if the host cannot run `backend`.
pub fn force(backend: Option<SimdBackend>) -> Result<(), String> {
    if let Some(b) = backend {
        if !available(b) {
            return Err(format!("SIMD backend `{}` is not supported on this CPU", b.name()));
        }
    }
    let code = match backend {
        None => 0,
        Some(SimdBackend::Scalar) => 1,
        Some(SimdBackend::Avx2) => 2,
        Some(SimdBackend::Avx512) => 3,
    };
    FORCE.store(code, Ordering::Relaxed);
    Ok(())
}

/// The backend new engines bind to: [`force`] override, else
/// `DNATEQ_SIMD`, else [`detect`]. Panics (loudly, for CI) if the env
/// var names an unknown or unsupported backend.
pub fn active_backend() -> SimdBackend {
    match FORCE.load(Ordering::Relaxed) {
        1 => SimdBackend::Scalar,
        2 => SimdBackend::Avx2,
        3 => SimdBackend::Avx512,
        _ => *DEFAULT.get_or_init(env_default),
    }
}

fn env_default() -> SimdBackend {
    match std::env::var("DNATEQ_SIMD") {
        Ok(v) => match parse(&v) {
            Ok(Some(b)) => {
                assert!(
                    available(b),
                    "DNATEQ_SIMD={v} but this host cannot run the `{}` backend",
                    b.name()
                );
                b
            }
            Ok(None) => detect(),
            Err(e) => panic!("DNATEQ_SIMD: {e}"),
        },
        Err(_) => detect(),
    }
}

// ---------------------------------------------------------------------
// Exponent extraction / code shifting (the `log_shift` idiom).
// ---------------------------------------------------------------------

/// Pre-shift exponent codes to table offsets: `code + R_max`, with
/// `0xFF` marking exact zeros. Dispatching twin of
/// [`crate::expdot::pack::shift_codes`] (the scalar reference).
pub fn shift_codes(backend: SimdBackend, codes: &[i8], r_max: i32) -> Vec<u8> {
    match backend {
        SimdBackend::Scalar => super::pack::shift_codes(codes, r_max),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` is only constructible on hosts where
        // `is_x86_feature_detected!("avx2")` held (see `available`).
        SimdBackend::Avx2 => unsafe { shift_codes_avx2(codes, r_max) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx512` implies runtime avx512f+bw (see `available`).
        SimdBackend::Avx512 => unsafe { shift_codes_avx512(codes, r_max) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => super::pack::shift_codes(codes, r_max),
    }
}

/// 32 codes per iteration: compare-to-sentinel mask, wrapping byte add
/// of `R_max` (codes ∈ [-127, 127], shifted ∈ [0, 254], so the i8
/// wrapping add yields the exact u8 offset), blend in `0xFF` for zeros.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn shift_codes_avx2(codes: &[i8], r_max: i32) -> Vec<u8> {
    use std::arch::x86_64::*;
    let n = codes.len();
    let mut out = vec![0u8; n];
    let sentinel = _mm256_set1_epi8(crate::dnateq::ZERO_CODE_SENTINEL);
    let offset = _mm256_set1_epi8(r_max as i8);
    let ff = _mm256_set1_epi8(-1);
    let mut i = 0usize;
    while i + 32 <= n {
        let v = _mm256_loadu_si256(codes.as_ptr().add(i) as *const __m256i);
        let is_zero = _mm256_cmpeq_epi8(v, sentinel);
        let shifted = _mm256_add_epi8(v, offset);
        let res = _mm256_blendv_epi8(shifted, ff, is_zero);
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, res);
        i += 32;
    }
    for j in i..n {
        let c = codes[j];
        out[j] = if c == crate::dnateq::ZERO_CODE_SENTINEL {
            0xFF
        } else {
            (c as i32 + r_max) as u8
        };
    }
    out
}

/// 64 codes per iteration. The sentinel test lands in a `__mmask64`
/// register (`vpcmpeqb k, zmm, zmm`) and the `0xFF` remap is a single
/// mask blend — no 256-bit cmp/blendv pair, no vector mask
/// materialization.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn shift_codes_avx512(codes: &[i8], r_max: i32) -> Vec<u8> {
    use std::arch::x86_64::*;
    let n = codes.len();
    let mut out = vec![0u8; n];
    let sentinel = _mm512_set1_epi8(crate::dnateq::ZERO_CODE_SENTINEL);
    let offset = _mm512_set1_epi8(r_max as i8);
    let ff = _mm512_set1_epi8(-1);
    let mut i = 0usize;
    while i + 64 <= n {
        let v = (codes.as_ptr().add(i) as *const __m512i).read_unaligned();
        let is_zero = _mm512_cmpeq_epi8_mask(v, sentinel);
        let shifted = _mm512_add_epi8(v, offset);
        let res = _mm512_mask_blend_epi8(is_zero, shifted, ff);
        (out.as_mut_ptr().add(i) as *mut __m512i).write_unaligned(res);
        i += 64;
    }
    for j in i..n {
        let c = codes[j];
        out[j] = if c == crate::dnateq::ZERO_CODE_SENTINEL {
            0xFF
        } else {
            (c as i32 + r_max) as u8
        };
    }
    out
}

// ---------------------------------------------------------------------
// Nibble decoding of the packed 3-bit weight store.
// ---------------------------------------------------------------------

/// Decode `n` nibble-packed elements into parallel pre-shifted-code /
/// sign buffers via the 16-entry LUT (invalid or zero nibbles decode to
/// `(0xFF, 0)`, which the accumulators mask out). The AVX2 path maps
/// the LUT onto `pshufb` (32 elements per iteration from 16 packed
/// bytes, double-pumped per table); the AVX-512 path decodes 64
/// elements per iteration with one `vpermb` table lookup per output
/// stream (512-bit `pshufb` on pre-VBMI parts — bit-identical).
pub fn decode_nibbles(
    backend: SimdBackend,
    bytes: &[u8],
    n: usize,
    lut: &NibbleLut,
    plus: &mut Vec<u8>,
    signs: &mut Vec<i8>,
) {
    assert!(bytes.len() * 2 >= n, "packed row too short: {} bytes for {n} elems", bytes.len());
    plus.clear();
    plus.resize(n, 0);
    signs.clear();
    signs.resize(n, 0);
    match backend {
        SimdBackend::Scalar => decode_nibbles_scalar(bytes, n, &lut.pairs, plus, signs),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` implies runtime AVX2 support (see `available`).
        SimdBackend::Avx2 => unsafe { decode_nibbles_avx2(bytes, n, lut, plus, signs) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx512` implies runtime avx512f+bw; the `vpermb`
        // branch is additionally gated on `has_avx512vbmi`.
        SimdBackend::Avx512 => unsafe { decode_nibbles_avx512(bytes, n, lut, plus, signs) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => decode_nibbles_scalar(bytes, n, &lut.pairs, plus, signs),
    }
}

fn decode_nibbles_scalar(
    bytes: &[u8],
    n: usize,
    lut: &[(u8, i8); 16],
    plus: &mut [u8],
    signs: &mut [i8],
) {
    for i in 0..n {
        let byte = bytes[i / 2];
        let nib = (byte >> ((i & 1) * 4)) & 0xF;
        let (p, s) = lut[nib as usize];
        plus[i] = p;
        signs[i] = s;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn decode_nibbles_avx2(
    bytes: &[u8],
    n: usize,
    lut: &NibbleLut,
    plus: &mut [u8],
    signs: &mut [i8],
) {
    use std::arch::x86_64::*;
    let plus_lut = _mm_loadu_si128(lut.plus.as_ptr() as *const __m128i);
    let sign_lut = _mm_loadu_si128(lut.signs.as_ptr() as *const __m128i);
    let low = _mm_set1_epi8(0x0F);
    let mut i = 0usize;
    while i + 32 <= n {
        let b = _mm_loadu_si128(bytes.as_ptr().add(i / 2) as *const __m128i);
        let lo = _mm_and_si128(b, low);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(b), low);
        // Interleave low/high nibbles back into element order: byte k
        // holds elements 2k (low nibble) and 2k+1 (high nibble).
        let n0 = _mm_unpacklo_epi8(lo, hi); // elements i .. i+15
        let n1 = _mm_unpackhi_epi8(lo, hi); // elements i+16 .. i+31
        _mm_storeu_si128(plus.as_mut_ptr().add(i) as *mut __m128i, _mm_shuffle_epi8(plus_lut, n0));
        _mm_storeu_si128(
            plus.as_mut_ptr().add(i + 16) as *mut __m128i,
            _mm_shuffle_epi8(plus_lut, n1),
        );
        _mm_storeu_si128(signs.as_mut_ptr().add(i) as *mut __m128i, _mm_shuffle_epi8(sign_lut, n0));
        _mm_storeu_si128(
            signs.as_mut_ptr().add(i + 16) as *mut __m128i,
            _mm_shuffle_epi8(sign_lut, n1),
        );
        i += 32;
    }
    decode_nibbles_scalar(&bytes[i / 2..], n - i, &lut.pairs, &mut plus[i..], &mut signs[i..]);
}

/// `vpermb` lookup: one instruction maps 64 nibble indices to 64 LUT
/// bytes (indices are < 16, so only the table's first 128-bit copy is
/// ever read — same bytes the `pshufb` fallback selects per lane).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512vbmi")]
unsafe fn vpermb_lookup(table: __m512i, idx: __m512i) -> __m512i {
    std::arch::x86_64::_mm512_permutexvar_epi8(idx, table)
}

/// 64 elements per iteration from 32 packed bytes: widen bytes to
/// 16-bit lanes, split nibbles into the lane's (low, high) byte pair —
/// which *is* element order — then one table lookup per output stream.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn decode_nibbles_avx512(
    bytes: &[u8],
    n: usize,
    lut: &NibbleLut,
    plus: &mut [u8],
    signs: &mut [i8],
) {
    use std::arch::x86_64::*;
    let plus_tbl =
        _mm512_broadcast_i32x4(_mm_loadu_si128(lut.plus.as_ptr() as *const __m128i));
    let sign_tbl =
        _mm512_broadcast_i32x4(_mm_loadu_si128(lut.signs.as_ptr() as *const __m128i));
    let nib = _mm512_set1_epi16(0x000F);
    let vbmi = has_avx512vbmi();
    let mut i = 0usize;
    while i + 64 <= n {
        let b = _mm256_loadu_si256(bytes.as_ptr().add(i / 2) as *const __m256i);
        let w = _mm512_cvtepu8_epi16(b);
        let lo = _mm512_and_si512(w, nib);
        let hi = _mm512_and_si512(_mm512_srli_epi16::<4>(w), nib);
        // 16-bit lane k → bytes (2k, 2k+1) = (low nibble, high nibble):
        // exactly elements 2k and 2k+1 (low nibble first).
        let idx = _mm512_or_si512(lo, _mm512_slli_epi16::<8>(hi));
        let (pv, sv) = if vbmi {
            // SAFETY: `has_avx512vbmi` checked above.
            (vpermb_lookup(plus_tbl, idx), vpermb_lookup(sign_tbl, idx))
        } else {
            (_mm512_shuffle_epi8(plus_tbl, idx), _mm512_shuffle_epi8(sign_tbl, idx))
        };
        (plus.as_mut_ptr().add(i) as *mut __m512i).write_unaligned(pv);
        (signs.as_mut_ptr().add(i) as *mut __m512i).write_unaligned(sv);
        i += 64;
    }
    decode_nibbles_scalar(&bytes[i / 2..], n - i, &lut.pairs, &mut plus[i..], &mut signs[i..]);
}

// ---------------------------------------------------------------------
// Counter-table scatter: the §IV counting hot spot.
// ---------------------------------------------------------------------

/// Private counter-set copies kept by the replicated-histogram scheme
/// (copy 0 is the caller's tables). Lane `k` scatters into copy
/// `k mod HIST_COPIES`, so consecutive live lanes update independent
/// cache lines even when their `(ap+wp)` indices collide.
pub const HIST_COPIES: usize = 4;

/// The replicated path pays `(HIST_COPIES-1)` zero+fold sweeps over the
/// counter set per row; it only wins when the row is long relative to
/// the tables. Replication turns on when
/// `row_len >= REPLICATE_MIN_RATIO × counter_set_len` — a pure
/// performance policy, both schemes are bit-identical.
pub const REPLICATE_MIN_RATIO: usize = 8;

/// Reusable backing for the replicated-histogram copies
/// (`HIST_COPIES - 1` private `[pair | wcnt | acnt]` counter sets).
/// Construct one per forward pass and thread it through
/// [`accumulate_row`]; scalar and AVX2 backends leave it untouched.
#[derive(Default)]
pub struct AccumScratch {
    buf: Vec<i32>,
}

/// Accumulate one (weight row × activation row) pass into the three
/// count tables: `pair[ap+wp] += s`, `wcnt[wp] += s`, `acnt[ap] += s`
/// for every position where neither side is the `0xFF` zero marker,
/// with `s = w_sign · a_sign`.
///
/// The AVX2 path computes the 32-lane validity mask and sign products
/// branchlessly, then drains only the live lanes through the scatter
/// (bit-scan over the movemask); zero-dense tensors — DNA-TEQ's common
/// case — skip their dead lanes almost for free. The AVX-512 path does
/// the same over 64 lanes with mask registers and, for long rows,
/// scatters round-robin into [`HIST_COPIES`] replicated counter copies
/// (gather-free: no `vpconflictd` probing, no same-address dependency
/// chains) folded back with a vectorized i32 reduction at row end.
/// Updates are commutative i32 adds, so every path is bit-identical to
/// scalar.
///
/// Caller contract (same trust the scalar kernel always had, checked
/// via `debug_assert`): every non-`0xFF` byte in `w_plus`/`a_plus` is
/// `< wcnt.len()`/`< acnt.len()`, their sum is `< pair.len()`, and the
/// sign slices hold ±1 at every live position.
#[allow(clippy::too_many_arguments)]
pub fn accumulate_row(
    backend: SimdBackend,
    w_plus: &[u8],
    w_signs: &[i8],
    a_plus: &[u8],
    a_signs: &[i8],
    pair: &mut [i32],
    wcnt: &mut [i32],
    acnt: &mut [i32],
    scratch: &mut AccumScratch,
) {
    assert_eq!(w_plus.len(), w_signs.len());
    assert_eq!(a_plus.len(), a_signs.len());
    assert_eq!(w_plus.len(), a_plus.len());
    let _ = &scratch; // non-AVX-512 arms leave the scratch untouched
    match backend {
        SimdBackend::Scalar => {
            accumulate_row_scalar(w_plus, w_signs, a_plus, a_signs, pair, wcnt, acnt)
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` implies runtime AVX2 support (see `available`).
        SimdBackend::Avx2 => unsafe {
            accumulate_row_avx2(w_plus, w_signs, a_plus, a_signs, pair, wcnt, acnt)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx512` implies runtime avx512f+bw (see `available`).
        SimdBackend::Avx512 => unsafe {
            accumulate_row_avx512(w_plus, w_signs, a_plus, a_signs, pair, wcnt, acnt, scratch)
        },
        #[cfg(not(target_arch = "x86_64"))]
        _ => accumulate_row_scalar(w_plus, w_signs, a_plus, a_signs, pair, wcnt, acnt),
    }
}

/// The portable reference: the register-blocked scalar loop the
/// counting engines always ran. Zero-skip branches are well-predicted
/// and skipping saves table RMWs (a branchless trash-slot variant was
/// measured 8% slower — see EXPERIMENTS.md §Perf).
fn accumulate_row_scalar(
    w_plus: &[u8],
    w_signs: &[i8],
    a_plus: &[u8],
    a_signs: &[i8],
    pair: &mut [i32],
    wcnt: &mut [i32],
    acnt: &mut [i32],
) {
    for i in 0..w_plus.len() {
        // SAFETY: `i < w_plus.len()` and the slice lengths were asserted
        // equal by the dispatch wrapper.
        let wp = unsafe { *w_plus.get_unchecked(i) } as usize;
        let ap = unsafe { *a_plus.get_unchecked(i) } as usize;
        if wp == 0xFF || ap == 0xFF {
            continue;
        }
        let s = (unsafe { *w_signs.get_unchecked(i) } as i32)
            * (unsafe { *a_signs.get_unchecked(i) } as i32);
        debug_assert!(ap + wp < pair.len() && wp < wcnt.len() && ap < acnt.len());
        // SAFETY: live codes are bounded by the caller contract above.
        unsafe {
            *pair.get_unchecked_mut(ap + wp) += s;
            *wcnt.get_unchecked_mut(wp) += s;
            *acnt.get_unchecked_mut(ap) += s;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn accumulate_row_avx2(
    w_plus: &[u8],
    w_signs: &[i8],
    a_plus: &[u8],
    a_signs: &[i8],
    pair: &mut [i32],
    wcnt: &mut [i32],
    acnt: &mut [i32],
) {
    use std::arch::x86_64::*;
    let n = w_plus.len();
    let ff = _mm256_set1_epi8(-1);
    let mut sbuf = [0i8; 32];
    let mut i = 0usize;
    while i + 32 <= n {
        let wv = _mm256_loadu_si256(w_plus.as_ptr().add(i) as *const __m256i);
        let av = _mm256_loadu_si256(a_plus.as_ptr().add(i) as *const __m256i);
        let dead = _mm256_or_si256(_mm256_cmpeq_epi8(wv, ff), _mm256_cmpeq_epi8(av, ff));
        let mut live = !(_mm256_movemask_epi8(dead) as u32);
        if live != 0 {
            // psignb: w_sign · sign(a_sign) — exact ±1 product, dead
            // lanes are never read back.
            let ws = _mm256_loadu_si256(w_signs.as_ptr().add(i) as *const __m256i);
            let asv = _mm256_loadu_si256(a_signs.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(sbuf.as_mut_ptr() as *mut __m256i, _mm256_sign_epi8(ws, asv));
            while live != 0 {
                let k = live.trailing_zeros() as usize;
                live &= live - 1;
                let wp = *w_plus.get_unchecked(i + k) as usize;
                let ap = *a_plus.get_unchecked(i + k) as usize;
                let s = *sbuf.get_unchecked(k) as i32;
                debug_assert!(ap + wp < pair.len() && wp < wcnt.len() && ap < acnt.len());
                *pair.get_unchecked_mut(ap + wp) += s;
                *wcnt.get_unchecked_mut(wp) += s;
                *acnt.get_unchecked_mut(ap) += s;
            }
        }
        i += 32;
    }
    accumulate_row_scalar(
        &w_plus[i..],
        &w_signs[i..],
        &a_plus[i..],
        &a_signs[i..],
        pair,
        wcnt,
        acnt,
    );
}

/// 64 lanes per iteration with mask-register liveness and the
/// replicated-histogram scatter for long rows: lane `k` drains into
/// counter copy `k & (HIST_COPIES-1)`, so adjacent live lanes — the
/// ones most likely to share an `(ap+wp)` slot, exponent codes being
/// concentrated — never serialize on one cache line. Short rows skip
/// replication (the fold would dominate) and drain into the caller's
/// tables directly, exactly like the AVX2 path.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
#[allow(clippy::too_many_arguments)]
unsafe fn accumulate_row_avx512(
    w_plus: &[u8],
    w_signs: &[i8],
    a_plus: &[u8],
    a_signs: &[i8],
    pair: &mut [i32],
    wcnt: &mut [i32],
    acnt: &mut [i32],
    scratch: &mut AccumScratch,
) {
    use std::arch::x86_64::*;
    let n = w_plus.len();
    let (plen, wlen, alen) = (pair.len(), wcnt.len(), acnt.len());
    let set = plen + wlen + alen;
    let replicate = n >= 64 && n >= REPLICATE_MIN_RATIO * set;

    // Copy 0 is the caller's tables; copies 1.. live in the scratch
    // (zeroed here, folded back below). Raw pointers because the drain
    // picks its copy per lane.
    let mut pair_ptrs = [pair.as_mut_ptr(); HIST_COPIES];
    let mut wcnt_ptrs = [wcnt.as_mut_ptr(); HIST_COPIES];
    let mut acnt_ptrs = [acnt.as_mut_ptr(); HIST_COPIES];
    if replicate {
        scratch.buf.clear();
        scratch.buf.resize((HIST_COPIES - 1) * set, 0);
        for c in 1..HIST_COPIES {
            let base = scratch.buf.as_mut_ptr().add((c - 1) * set);
            pair_ptrs[c] = base;
            wcnt_ptrs[c] = base.add(plen);
            acnt_ptrs[c] = base.add(plen + wlen);
        }
    }

    let ff = _mm512_set1_epi8(-1);
    let zero = _mm512_setzero_si512();
    let mut sbuf = [0i8; 64];
    let mut i = 0usize;
    while i + 64 <= n {
        let wv = (w_plus.as_ptr().add(i) as *const __m512i).read_unaligned();
        let av = (a_plus.as_ptr().add(i) as *const __m512i).read_unaligned();
        let dead = _mm512_cmpeq_epi8_mask(wv, ff) | _mm512_cmpeq_epi8_mask(av, ff);
        let mut live: u64 = !dead;
        if live != 0 {
            // ±1 sign product without psignb (no EVEX encoding): negate
            // the weight signs wherever the activation sign is negative.
            // Dead lanes may hold junk but are never read back.
            let ws = (w_signs.as_ptr().add(i) as *const __m512i).read_unaligned();
            let asv = (a_signs.as_ptr().add(i) as *const __m512i).read_unaligned();
            let negate = _mm512_cmplt_epi8_mask(asv, zero);
            let prod = _mm512_mask_blend_epi8(negate, ws, _mm512_sub_epi8(zero, ws));
            (sbuf.as_mut_ptr() as *mut __m512i).write_unaligned(prod);
            while live != 0 {
                let k = live.trailing_zeros() as usize;
                live &= live - 1;
                let wp = *w_plus.get_unchecked(i + k) as usize;
                let ap = *a_plus.get_unchecked(i + k) as usize;
                let s = *sbuf.get_unchecked(k) as i32;
                let c = k & (HIST_COPIES - 1);
                debug_assert!(ap + wp < plen && wp < wlen && ap < alen);
                *pair_ptrs[c].add(ap + wp) += s;
                *wcnt_ptrs[c].add(wp) += s;
                *acnt_ptrs[c].add(ap) += s;
            }
        }
        i += 64;
    }
    // Tail (< 64 lanes) goes straight into the caller's tables.
    accumulate_row_scalar(
        &w_plus[i..],
        &w_signs[i..],
        &a_plus[i..],
        &a_signs[i..],
        pair,
        wcnt,
        acnt,
    );
    if replicate {
        for c in 1..HIST_COPIES {
            let base = (c - 1) * set;
            let src = &scratch.buf[base..base + set];
            fold_add_avx512(pair, &src[..plen]);
            fold_add_avx512(wcnt, &src[plen..plen + wlen]);
            fold_add_avx512(acnt, &src[plen + wlen..]);
        }
    }
}

/// Vectorized i32 fold of one replicated counter copy back into the
/// caller's table (`dst[i] += src[i]`, 16 lanes per iteration).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn fold_add_avx512(dst: &mut [i32], src: &[i32]) {
    use std::arch::x86_64::*;
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let mut i = 0usize;
    while i + 16 <= n {
        let d = (dst.as_ptr().add(i) as *const __m512i).read_unaligned();
        let s = (src.as_ptr().add(i) as *const __m512i).read_unaligned();
        (dst.as_mut_ptr().add(i) as *mut __m512i).write_unaligned(_mm512_add_epi32(d, s));
        i += 16;
    }
    while i < n {
        dst[i] += src[i];
        i += 1;
    }
}

// ---------------------------------------------------------------------
// BLUT reconstruction dot (the Dequantizer stage, §V-D).
// ---------------------------------------------------------------------

/// Fixed 8-lane reduction tree shared by every [`blut_dot`] backend:
/// element `i` accumulates into lane `i mod 8` (in index order within
/// the lane), and the lanes combine pairwise. Scalar and SIMD execute
/// the exact same IEEE adds/multiplies in the exact same order, so the
/// reconstruction stays bitwise identical across backends.
#[inline]
fn fold_tree8(acc: &[f64; 8]) -> f64 {
    let b0 = acc[0] + acc[1];
    let b1 = acc[2] + acc[3];
    let b2 = acc[4] + acc[5];
    let b3 = acc[6] + acc[7];
    (b0 + b1) + (b2 + b3)
}

/// Weighted count sum of the BLUT reconstruction:
/// `Σ counts[i] · blut[i]` in f64, over the fixed [`fold_tree8`]
/// reduction order. `i32 → f64` conversion and the mul/add pair are
/// exact per IEEE-754 lane-for-lane (no FMA contraction on any path),
/// so scalar, AVX2, and AVX-512 return the same bits.
pub fn blut_dot(backend: SimdBackend, counts: &[i32], blut: &[f64]) -> f64 {
    assert_eq!(counts.len(), blut.len(), "counts/BLUT length mismatch");
    match backend {
        SimdBackend::Scalar => blut_dot_scalar(counts, blut),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` implies runtime AVX2 support (see `available`).
        SimdBackend::Avx2 => unsafe { blut_dot_avx2(counts, blut) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx512` implies runtime avx512f+bw (see `available`).
        SimdBackend::Avx512 => unsafe { blut_dot_avx512(counts, blut) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => blut_dot_scalar(counts, blut),
    }
}

/// The scalar twin of the vector paths: strided 8-lane partials in the
/// same per-lane order, folded by the same tree.
fn blut_dot_scalar(counts: &[i32], blut: &[f64]) -> f64 {
    let mut acc = [0.0f64; 8];
    for (i, (&c, &p)) in counts.iter().zip(blut).enumerate() {
        acc[i & 7] += c as f64 * p;
    }
    fold_tree8(&acc)
}

/// Two 4-lane f64 accumulators = the 8 tree lanes; `vcvtdq2pd` widens
/// counts exactly, separate mul + add (no FMA) keeps lane arithmetic
/// identical to scalar.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn blut_dot_avx2(counts: &[i32], blut: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    let n = counts.len();
    let mut acc_lo = _mm256_setzero_pd();
    let mut acc_hi = _mm256_setzero_pd();
    let mut i = 0usize;
    while i + 8 <= n {
        let c_lo = _mm256_cvtepi32_pd(_mm_loadu_si128(counts.as_ptr().add(i) as *const __m128i));
        let c_hi =
            _mm256_cvtepi32_pd(_mm_loadu_si128(counts.as_ptr().add(i + 4) as *const __m128i));
        acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(c_lo, _mm256_loadu_pd(blut.as_ptr().add(i))));
        acc_hi =
            _mm256_add_pd(acc_hi, _mm256_mul_pd(c_hi, _mm256_loadu_pd(blut.as_ptr().add(i + 4))));
        i += 8;
    }
    let mut acc = [0.0f64; 8];
    _mm256_storeu_pd(acc.as_mut_ptr(), acc_lo);
    _mm256_storeu_pd(acc.as_mut_ptr().add(4), acc_hi);
    for j in i..n {
        acc[j & 7] += counts[j] as f64 * blut[j];
    }
    fold_tree8(&acc)
}

/// One 8-lane f64 accumulator — the tree lanes map 1:1 onto the zmm.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn blut_dot_avx512(counts: &[i32], blut: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    let n = counts.len();
    let mut accv = _mm512_setzero_pd();
    let mut i = 0usize;
    while i + 8 <= n {
        let c = _mm512_cvtepi32_pd(_mm256_loadu_si256(counts.as_ptr().add(i) as *const __m256i));
        accv = _mm512_add_pd(accv, _mm512_mul_pd(c, _mm512_loadu_pd(blut.as_ptr().add(i))));
        i += 8;
    }
    let mut acc = [0.0f64; 8];
    _mm512_storeu_pd(acc.as_mut_ptr(), accv);
    for j in i..n {
        acc[j & 7] += counts[j] as f64 * blut[j];
    }
    fold_tree8(&acc)
}

// ---------------------------------------------------------------------
// INT8 dot product (the VNNI-style baseline).
// ---------------------------------------------------------------------

/// i32-accumulating i8 dot product. The AVX2 path widens 16 lanes at a
/// time to i16 and uses `pmaddwd` (exact i32 pair sums of i8 products);
/// AVX-512 does the same 32 lanes at a time. Both compute the same
/// mod-2³² integer sum as the scalar reference
/// [`crate::expdot::int8::gemv_i8`] in a different association order —
/// identical results, integer adds being commutative.
pub fn dot_i8(backend: SimdBackend, a: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), w.len());
    match backend {
        SimdBackend::Scalar => super::int8::gemv_i8(a, w),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` implies runtime AVX2 support (see `available`).
        SimdBackend::Avx2 => unsafe { dot_i8_avx2(a, w) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx512` implies runtime avx512f+bw (see `available`).
        SimdBackend::Avx512 => unsafe { dot_i8_avx512(a, w) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => super::int8::gemv_i8(a, w),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(a: &[i8], w: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 16 <= n {
        let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(i) as *const __m128i));
        let vw = _mm256_cvtepi8_epi16(_mm_loadu_si128(w.as_ptr().add(i) as *const __m128i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vw));
        i += 16;
    }
    let lo = _mm256_castsi256_si128(acc);
    let hi = _mm256_extracti128_si256::<1>(acc);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<1>(s));
    _mm_cvtsi128_si32(s) + super::int8::gemv_i8(&a[i..], &w[i..])
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn dot_i8_avx512(a: &[i8], w: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc = _mm512_setzero_si512();
    let mut i = 0usize;
    while i + 32 <= n {
        let va = _mm512_cvtepi8_epi16(_mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i));
        let vw = _mm512_cvtepi8_epi16(_mm256_loadu_si256(w.as_ptr().add(i) as *const __m256i));
        acc = _mm512_add_epi32(acc, _mm512_madd_epi16(va, vw));
        i += 32;
    }
    let lo256 = _mm512_castsi512_si256(acc);
    let hi256 = _mm512_extracti64x4_epi64::<1>(acc);
    let s256 = _mm256_add_epi32(lo256, hi256);
    let lo = _mm256_castsi256_si128(s256);
    let hi = _mm256_extracti128_si256::<1>(s256);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<1>(s));
    _mm_cvtsi128_si32(s) + super::int8::gemv_i8(&a[i..], &w[i..])
}

// ---------------------------------------------------------------------
// f32 block copy (im2col's stride-1 inner loop).
// ---------------------------------------------------------------------

/// Copy `src` into `dst` (equal lengths). Scalar uses `copy_from_slice`
/// (memcpy); AVX2 runs explicit 8-wide and AVX-512 16-wide unaligned
/// vector moves. Copies are trivially bit-exact.
pub fn copy_f32(backend: SimdBackend, dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    match backend {
        SimdBackend::Scalar => dst.copy_from_slice(src),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` implies runtime AVX2 (and thus AVX) support.
        SimdBackend::Avx2 => unsafe { copy_f32_avx(dst.as_mut_ptr(), src.as_ptr(), dst.len()) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx512` implies runtime avx512f support.
        SimdBackend::Avx512 => unsafe {
            copy_f32_avx512(dst.as_mut_ptr(), src.as_ptr(), dst.len())
        },
        #[cfg(not(target_arch = "x86_64"))]
        _ => dst.copy_from_slice(src),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn copy_f32_avx(dst: *mut f32, src: *const f32, n: usize) {
    use std::arch::x86_64::*;
    let mut i = 0usize;
    while i + 8 <= n {
        _mm256_storeu_ps(dst.add(i), _mm256_loadu_ps(src.add(i)));
        i += 8;
    }
    while i < n {
        *dst.add(i) = *src.add(i);
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn copy_f32_avx512(dst: *mut f32, src: *const f32, n: usize) {
    use std::arch::x86_64::*;
    let mut i = 0usize;
    while i + 16 <= n {
        _mm512_storeu_ps(dst.add(i), _mm512_loadu_ps(src.add(i)));
        i += 16;
    }
    while i < n {
        *dst.add(i) = *src.add(i);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnateq::ZERO_CODE_SENTINEL;
    use crate::expdot::pack::{self, nibble_lut_tables};
    use crate::tensor::SplitMix64;

    /// Every non-scalar backend this host can execute (empty on
    /// scalar-only hosts — the vs-scalar tests then pass vacuously;
    /// CI's simd/avx512 lanes and the sanitizer job run them for real).
    fn simd_backends() -> Vec<SimdBackend> {
        [SimdBackend::Avx2, SimdBackend::Avx512]
            .into_iter()
            .filter(|&b| available(b))
            .collect()
    }

    fn rand_codes(
        n: usize,
        r_max: i32,
        zero_every: usize,
        rng: &mut SplitMix64,
    ) -> (Vec<i8>, Vec<i8>) {
        let mut codes = Vec::with_capacity(n);
        let mut signs = Vec::with_capacity(n);
        for i in 0..n {
            if zero_every > 0 && i % zero_every == 0 {
                codes.push(ZERO_CODE_SENTINEL);
                signs.push(1);
            } else {
                let span = (2 * r_max + 1) as usize;
                codes.push((rng.next_below(span) as i32 - r_max) as i8);
                signs.push(if rng.next_below(2) == 0 { 1 } else { -1 });
            }
        }
        (codes, signs)
    }

    #[test]
    fn parse_accepts_known_names() {
        assert_eq!(parse("scalar"), Ok(Some(SimdBackend::Scalar)));
        assert_eq!(parse("avx2"), Ok(Some(SimdBackend::Avx2)));
        assert_eq!(parse("simd"), Ok(Some(SimdBackend::Avx2)));
        assert_eq!(parse("avx512"), Ok(Some(SimdBackend::Avx512)));
        assert_eq!(parse("auto"), Ok(None));
        assert!(parse("neon").is_err());
    }

    #[test]
    fn scalar_is_always_available() {
        assert!(available(SimdBackend::Scalar));
        // Whatever detection says is, by definition, available.
        assert!(available(best_available()));
        // And the stronger backend always implies the weaker one.
        if available(SimdBackend::Avx512) {
            assert!(available(SimdBackend::Avx2));
        }
    }

    #[test]
    fn shift_codes_matches_scalar_all_widths() {
        let mut rng = SplitMix64::new(0x5111);
        // Odd lengths hit the tail; r_max 127 hits the wrapping add.
        for (n, r_max, zero_every) in [(33, 1, 3), (257, 7, 5), (96, 127, 1), (500, 127, 7)] {
            let (codes, _) = rand_codes(n, r_max, zero_every, &mut rng);
            let want = pack::shift_codes(&codes, r_max);
            for b in simd_backends() {
                let got = shift_codes(b, &codes, r_max);
                assert_eq!(got, want, "{} n={n} r_max={r_max}", b.name());
            }
        }
    }

    #[test]
    fn decode_nibbles_matches_scalar() {
        let mut rng = SplitMix64::new(0x5112);
        let lut = nibble_lut_tables(3);
        for n in [31usize, 32, 63, 64, 97, 320] {
            let bytes: Vec<u8> = (0..n.div_ceil(2)).map(|_| rng.next_below(256) as u8).collect();
            let (mut ps, mut ss) = (Vec::new(), Vec::new());
            decode_nibbles(SimdBackend::Scalar, &bytes, n, &lut, &mut ps, &mut ss);
            for b in simd_backends() {
                let (mut pv, mut sv) = (Vec::new(), Vec::new());
                decode_nibbles(b, &bytes, n, &lut, &mut pv, &mut sv);
                assert_eq!(pv, ps, "{} plus n={n}", b.name());
                assert_eq!(sv, ss, "{} signs n={n}", b.name());
            }
        }
    }

    #[test]
    fn accumulate_row_matches_scalar() {
        let mut rng = SplitMix64::new(0x5113);
        // n=2048 with r_max ≤ 7 crosses the replication threshold, so
        // the AVX-512 fold path is exercised; 31/64/129/333 stay on the
        // direct drain.
        for (n, r_max, zero_every) in
            [(64usize, 3, 4), (129, 7, 0), (333, 127, 2), (31, 1, 1), (2048, 3, 3), (2048, 7, 0)]
        {
            let (wc, ws) = rand_codes(n, r_max, zero_every, &mut rng);
            let (ac, asn) = rand_codes(n, r_max, zero_every.max(1) + 1, &mut rng);
            let wp = pack::shift_codes(&wc, r_max);
            let ap = pack::shift_codes(&ac, r_max);
            let (plen, slen) = ((4 * r_max + 1) as usize, (2 * r_max + 1) as usize);
            let mut t_s = (vec![0i32; plen], vec![0i32; slen], vec![0i32; slen]);
            let sc = SimdBackend::Scalar;
            let mut scratch = AccumScratch::default();
            accumulate_row(
                sc, &wp, &ws, &ap, &asn, &mut t_s.0, &mut t_s.1, &mut t_s.2, &mut scratch,
            );
            for b in simd_backends() {
                let mut t_v = (vec![0i32; plen], vec![0i32; slen], vec![0i32; slen]);
                accumulate_row(
                    b, &wp, &ws, &ap, &asn, &mut t_v.0, &mut t_v.1, &mut t_v.2, &mut scratch,
                );
                assert_eq!(t_v, t_s, "{} n={n} r_max={r_max}", b.name());
            }
        }
    }

    #[test]
    fn accumulate_row_accumulates_into_nonzero_tables() {
        // The `+=` contract must survive the replicated-copy fold: a
        // second pass lands on top of the first, on every backend.
        let mut rng = SplitMix64::new(0x5117);
        let (n, r_max) = (2048usize, 3);
        let (wc, ws) = rand_codes(n, r_max, 3, &mut rng);
        let (ac, asn) = rand_codes(n, r_max, 4, &mut rng);
        let wp = pack::shift_codes(&wc, r_max);
        let ap = pack::shift_codes(&ac, r_max);
        let (plen, slen) = ((4 * r_max + 1) as usize, (2 * r_max + 1) as usize);
        let mut want = (vec![0i32; plen], vec![0i32; slen], vec![0i32; slen]);
        let mut scratch = AccumScratch::default();
        let sc = SimdBackend::Scalar;
        for _ in 0..2 {
            accumulate_row(
                sc, &wp, &ws, &ap, &asn, &mut want.0, &mut want.1, &mut want.2, &mut scratch,
            );
        }
        for b in simd_backends() {
            let mut got = (vec![0i32; plen], vec![0i32; slen], vec![0i32; slen]);
            for _ in 0..2 {
                accumulate_row(
                    b, &wp, &ws, &ap, &asn, &mut got.0, &mut got.1, &mut got.2, &mut scratch,
                );
            }
            assert_eq!(got, want, "{} double accumulate", b.name());
        }
    }

    #[test]
    fn accumulate_row_all_sentinel_is_a_noop() {
        let n = 70;
        let wp = vec![0xFFu8; n];
        let ws = vec![1i8; n];
        let mut scratch = AccumScratch::default();
        for b in [SimdBackend::Scalar].into_iter().chain(simd_backends()) {
            let mut tables = (vec![0i32; 13], vec![0i32; 7], vec![0i32; 7]);
            accumulate_row(
                b, &wp, &ws, &wp, &ws, &mut tables.0, &mut tables.1, &mut tables.2, &mut scratch,
            );
            assert!(tables.0.iter().chain(&tables.1).chain(&tables.2).all(|&c| c == 0));
        }
    }

    /// Portable model of the replicated-histogram scheme — the fold
    /// logic the AVX-512 kernel relies on, runnable under Miri's
    /// scalar-forced lane: scatter round-robin (`lane mod HIST_COPIES`)
    /// into private copies, fold by plain i32 adds, compare against the
    /// single-table scalar kernel.
    #[test]
    fn replicated_fold_model_matches_plain_scalar() {
        let mut rng = SplitMix64::new(0x5116);
        let (n, r_max) = (320usize, 5);
        let (wc, ws) = rand_codes(n, r_max, 3, &mut rng);
        let (ac, asn) = rand_codes(n, r_max, 5, &mut rng);
        let wp = pack::shift_codes(&wc, r_max);
        let ap = pack::shift_codes(&ac, r_max);
        let (plen, slen) = ((4 * r_max + 1) as usize, (2 * r_max + 1) as usize);

        // Replicated scheme, portable: HIST_COPIES private table sets.
        let mut copies = vec![(vec![0i32; plen], vec![0i32; slen], vec![0i32; slen]); HIST_COPIES];
        for i in 0..n {
            let (w, a) = (wp[i] as usize, ap[i] as usize);
            if w == 0xFF || a == 0xFF {
                continue;
            }
            let s = (ws[i] * asn[i]) as i32;
            let t = &mut copies[i & (HIST_COPIES - 1)];
            t.0[a + w] += s;
            t.1[w] += s;
            t.2[a] += s;
        }
        let mut folded = copies[0].clone();
        for c in &copies[1..] {
            for (d, s) in folded.0.iter_mut().zip(&c.0) {
                *d += *s;
            }
            for (d, s) in folded.1.iter_mut().zip(&c.1) {
                *d += *s;
            }
            for (d, s) in folded.2.iter_mut().zip(&c.2) {
                *d += *s;
            }
        }

        let mut want = (vec![0i32; plen], vec![0i32; slen], vec![0i32; slen]);
        accumulate_row_scalar(&wp, &ws, &ap, &asn, &mut want.0, &mut want.1, &mut want.2);
        assert_eq!(folded, want);
    }

    #[test]
    fn blut_dot_matches_scalar_bitwise() {
        let mut rng = SplitMix64::new(0x5118);
        for n in [0usize, 1, 7, 8, 9, 29, 61, 509] {
            let counts: Vec<i32> =
                (0..n).map(|_| rng.next_below(2001) as i32 - 1000).collect();
            let blut: Vec<f64> = (0..n).map(|i| 1.3f64.powi(i as i32 - (n as i32) / 2)).collect();
            let want = blut_dot(SimdBackend::Scalar, &counts, &blut);
            for b in simd_backends() {
                let got = blut_dot(b, &counts, &blut);
                assert_eq!(got.to_bits(), want.to_bits(), "{} n={n}", b.name());
            }
        }
    }

    #[test]
    fn blut_dot_scalar_agrees_with_naive_sum() {
        // The fixed tree reassociates, so compare within f64 tolerance.
        let counts = [3i32, -2, 0, 7, 1, -5, 4, 0, 2, -1, 6];
        let blut: Vec<f64> = (0..counts.len()).map(|i| 1.25f64.powi(i as i32 - 5)).collect();
        let naive: f64 = counts.iter().zip(&blut).map(|(&c, &p)| c as f64 * p).sum();
        let got = blut_dot(SimdBackend::Scalar, &counts, &blut);
        assert!((got - naive).abs() < 1e-12 * naive.abs().max(1.0), "{got} vs {naive}");
    }

    #[test]
    fn dot_i8_matches_scalar_reference() {
        let mut rng = SplitMix64::new(0x5114);
        for n in [0usize, 1, 15, 16, 17, 31, 32, 64, 333, 1001] {
            let a: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            let w: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            let want = super::super::int8::gemv_i8(&a, &w);
            for b in simd_backends() {
                assert_eq!(dot_i8(b, &a, &w), want, "{} n={n}", b.name());
            }
        }
    }

    #[test]
    fn copy_f32_matches_scalar() {
        let mut rng = SplitMix64::new(0x5115);
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 31, 100] {
            let src: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let mut a = vec![0.0f32; n];
            copy_f32(SimdBackend::Scalar, &mut a, &src);
            for bk in simd_backends() {
                let mut b = vec![0.0f32; n];
                copy_f32(bk, &mut b, &src);
                assert_eq!(a, b, "{} n={n}", bk.name());
            }
        }
    }
}
