//! Explicit SIMD kernels for the exponential-domain hot loops, behind
//! runtime dispatch.
//!
//! The counting GEMM's inner loops are exponent extraction/shifting
//! ([`shift_codes`]), nibble decoding of the packed 3-bit store
//! ([`decode_nibbles`]), and the counter-table scatter itself
//! ([`accumulate_row`]); the INT8 baseline's is the i8 dot product
//! ([`dot_i8`]) and the f32 engine's im2col is a strided copy
//! ([`copy_f32`]). Each has an AVX2 implementation (`std::arch`
//! intrinsics behind `is_x86_feature_detected!`) and the original
//! scalar code as the portable fallback. **Every SIMD path is bit-exact
//! with scalar**: the vector work is integer (wrapping adds, compares,
//! table lookups) or pure copies, and counter updates are commutative
//! i32 adds, so only the order of side-effect-free operations changes.
//!
//! Backend resolution (cheapest override wins):
//! 1. a process-wide programmatic override installed via [`force`]
//!    (the `--simd` CLI flag);
//! 2. the `DNATEQ_SIMD` environment variable (`scalar` / `avx2` /
//!    `auto`) — how the CI matrix pins each dispatch arm;
//! 3. runtime CPU detection ([`detect`]).
//!
//! The engines capture [`active_backend`] at construction and expose a
//! `with_backend` builder, so scalar and SIMD instances can be compared
//! side by side in the same process (the equivalence property suite and
//! `bench_gate` both do).
//!
//! AVX-512 is deliberately left out for now: the counter tables are
//! scatter-bound, detection/intrinsic coverage on stable is younger,
//! and the win over AVX2 would be marginal for these loops.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// A counting-kernel instruction-set backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdBackend {
    /// Portable scalar code — the reference semantics on every arch.
    Scalar,
    /// 256-bit AVX2 integer kernels (x86_64 only, runtime-detected).
    Avx2,
}

impl SimdBackend {
    /// Stable lower-case name (used in bench case labels and logs).
    pub fn name(self) -> &'static str {
        match self {
            SimdBackend::Scalar => "scalar",
            SimdBackend::Avx2 => "avx2",
        }
    }
}

/// `FORCE` values: 0 = no override, 1 = scalar, 2 = avx2.
static FORCE: AtomicU8 = AtomicU8::new(0);
/// Resolved env-or-detected default, computed once.
static DEFAULT: OnceLock<SimdBackend> = OnceLock::new();

/// What the CPU supports, ignoring every override.
pub fn detect() -> SimdBackend {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdBackend::Avx2;
        }
    }
    SimdBackend::Scalar
}

/// The best backend this host can run (cached [`detect`]).
pub fn best_available() -> SimdBackend {
    static BEST: OnceLock<SimdBackend> = OnceLock::new();
    *BEST.get_or_init(detect)
}

/// Whether `backend` can execute on this host.
pub fn available(backend: SimdBackend) -> bool {
    backend == SimdBackend::Scalar || best_available() == backend
}

/// Parse a backend name: `scalar`, `avx2`/`simd`, or `auto` (= clear
/// the override and fall back to env/detection).
pub fn parse(name: &str) -> Result<Option<SimdBackend>, String> {
    match name {
        "auto" | "" => Ok(None),
        "scalar" => Ok(Some(SimdBackend::Scalar)),
        "avx2" | "simd" => Ok(Some(SimdBackend::Avx2)),
        other => Err(format!("unknown SIMD backend `{other}`; use scalar, avx2 or auto")),
    }
}

/// Install (or clear, with `None`) the process-wide backend override.
/// Takes precedence over `DNATEQ_SIMD` and detection for every engine
/// constructed afterwards. Fails if the host cannot run `backend`.
pub fn force(backend: Option<SimdBackend>) -> Result<(), String> {
    if let Some(b) = backend {
        if !available(b) {
            return Err(format!("SIMD backend `{}` is not supported on this CPU", b.name()));
        }
    }
    let code = match backend {
        None => 0,
        Some(SimdBackend::Scalar) => 1,
        Some(SimdBackend::Avx2) => 2,
    };
    FORCE.store(code, Ordering::Relaxed);
    Ok(())
}

/// The backend new engines bind to: [`force`] override, else
/// `DNATEQ_SIMD`, else [`detect`]. Panics (loudly, for CI) if the env
/// var names an unknown or unsupported backend.
pub fn active_backend() -> SimdBackend {
    match FORCE.load(Ordering::Relaxed) {
        1 => SimdBackend::Scalar,
        2 => SimdBackend::Avx2,
        _ => *DEFAULT.get_or_init(env_default),
    }
}

fn env_default() -> SimdBackend {
    match std::env::var("DNATEQ_SIMD") {
        Ok(v) => match parse(&v) {
            Ok(Some(b)) => {
                assert!(
                    available(b),
                    "DNATEQ_SIMD={v} but this host cannot run the `{}` backend",
                    b.name()
                );
                b
            }
            Ok(None) => detect(),
            Err(e) => panic!("DNATEQ_SIMD: {e}"),
        },
        Err(_) => detect(),
    }
}

// ---------------------------------------------------------------------
// Exponent extraction / code shifting (the `log_shift` idiom).
// ---------------------------------------------------------------------

/// Pre-shift exponent codes to table offsets: `code + R_max`, with
/// `0xFF` marking exact zeros. Dispatching twin of
/// [`crate::expdot::pack::shift_codes`] (the scalar reference).
pub fn shift_codes(backend: SimdBackend, codes: &[i8], r_max: i32) -> Vec<u8> {
    match backend {
        SimdBackend::Scalar => super::pack::shift_codes(codes, r_max),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` is only constructible on hosts where
        // `is_x86_feature_detected!("avx2")` held (see `available`).
        SimdBackend::Avx2 => unsafe { shift_codes_avx2(codes, r_max) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdBackend::Avx2 => super::pack::shift_codes(codes, r_max),
    }
}

/// 32 codes per iteration: compare-to-sentinel mask, wrapping byte add
/// of `R_max` (codes ∈ [-127, 127], shifted ∈ [0, 254], so the i8
/// wrapping add yields the exact u8 offset), blend in `0xFF` for zeros.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn shift_codes_avx2(codes: &[i8], r_max: i32) -> Vec<u8> {
    use std::arch::x86_64::*;
    let n = codes.len();
    let mut out = vec![0u8; n];
    let sentinel = _mm256_set1_epi8(crate::dnateq::ZERO_CODE_SENTINEL);
    let offset = _mm256_set1_epi8(r_max as i8);
    let ff = _mm256_set1_epi8(-1);
    let mut i = 0usize;
    while i + 32 <= n {
        let v = _mm256_loadu_si256(codes.as_ptr().add(i) as *const __m256i);
        let is_zero = _mm256_cmpeq_epi8(v, sentinel);
        let shifted = _mm256_add_epi8(v, offset);
        let res = _mm256_blendv_epi8(shifted, ff, is_zero);
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, res);
        i += 32;
    }
    for j in i..n {
        let c = codes[j];
        out[j] = if c == crate::dnateq::ZERO_CODE_SENTINEL {
            0xFF
        } else {
            (c as i32 + r_max) as u8
        };
    }
    out
}

// ---------------------------------------------------------------------
// Nibble decoding of the packed 3-bit weight store.
// ---------------------------------------------------------------------

/// Decode `n` nibble-packed elements into parallel pre-shifted-code /
/// sign buffers via the 16-entry LUT (invalid or zero nibbles decode to
/// `(0xFF, 0)`, which the accumulators mask out). The AVX2 path maps
/// the LUT onto `pshufb`: 32 elements per iteration from 16 packed
/// bytes.
pub fn decode_nibbles(
    backend: SimdBackend,
    bytes: &[u8],
    n: usize,
    lut: &[(u8, i8); 16],
    plus: &mut Vec<u8>,
    signs: &mut Vec<i8>,
) {
    assert!(bytes.len() * 2 >= n, "packed row too short: {} bytes for {n} elems", bytes.len());
    plus.clear();
    plus.resize(n, 0);
    signs.clear();
    signs.resize(n, 0);
    match backend {
        SimdBackend::Scalar => decode_nibbles_scalar(bytes, n, lut, plus, signs),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` implies runtime AVX2 support (see `available`).
        SimdBackend::Avx2 => unsafe { decode_nibbles_avx2(bytes, n, lut, plus, signs) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdBackend::Avx2 => decode_nibbles_scalar(bytes, n, lut, plus, signs),
    }
}

fn decode_nibbles_scalar(
    bytes: &[u8],
    n: usize,
    lut: &[(u8, i8); 16],
    plus: &mut [u8],
    signs: &mut [i8],
) {
    for i in 0..n {
        let byte = bytes[i / 2];
        let nib = (byte >> ((i & 1) * 4)) & 0xF;
        let (p, s) = lut[nib as usize];
        plus[i] = p;
        signs[i] = s;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn decode_nibbles_avx2(
    bytes: &[u8],
    n: usize,
    lut: &[(u8, i8); 16],
    plus: &mut [u8],
    signs: &mut [i8],
) {
    use std::arch::x86_64::*;
    let mut plus_tbl = [0u8; 16];
    let mut sign_tbl = [0i8; 16];
    for (k, &(p, s)) in lut.iter().enumerate() {
        plus_tbl[k] = p;
        sign_tbl[k] = s;
    }
    let plus_lut = _mm_loadu_si128(plus_tbl.as_ptr() as *const __m128i);
    let sign_lut = _mm_loadu_si128(sign_tbl.as_ptr() as *const __m128i);
    let low = _mm_set1_epi8(0x0F);
    let mut i = 0usize;
    while i + 32 <= n {
        let b = _mm_loadu_si128(bytes.as_ptr().add(i / 2) as *const __m128i);
        let lo = _mm_and_si128(b, low);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(b), low);
        // Interleave low/high nibbles back into element order: byte k
        // holds elements 2k (low nibble) and 2k+1 (high nibble).
        let n0 = _mm_unpacklo_epi8(lo, hi); // elements i .. i+15
        let n1 = _mm_unpackhi_epi8(lo, hi); // elements i+16 .. i+31
        _mm_storeu_si128(plus.as_mut_ptr().add(i) as *mut __m128i, _mm_shuffle_epi8(plus_lut, n0));
        _mm_storeu_si128(
            plus.as_mut_ptr().add(i + 16) as *mut __m128i,
            _mm_shuffle_epi8(plus_lut, n1),
        );
        _mm_storeu_si128(signs.as_mut_ptr().add(i) as *mut __m128i, _mm_shuffle_epi8(sign_lut, n0));
        _mm_storeu_si128(
            signs.as_mut_ptr().add(i + 16) as *mut __m128i,
            _mm_shuffle_epi8(sign_lut, n1),
        );
        i += 32;
    }
    decode_nibbles_scalar(&bytes[i / 2..], n - i, lut, &mut plus[i..], &mut signs[i..]);
}

// ---------------------------------------------------------------------
// Counter-table scatter: the §IV counting hot spot.
// ---------------------------------------------------------------------

/// Accumulate one (weight row × activation row) pass into the three
/// count tables: `pair[ap+wp] += s`, `wcnt[wp] += s`, `acnt[ap] += s`
/// for every position where neither side is the `0xFF` zero marker,
/// with `s = w_sign · a_sign`.
///
/// The AVX2 path computes the 32-lane validity mask and sign products
/// branchlessly, then drains only the live lanes through the scatter
/// (bit-scan over the movemask); zero-dense tensors — DNA-TEQ's common
/// case — skip their dead lanes almost for free. Updates are
/// commutative i32 adds, so the result is bit-identical to scalar.
///
/// Caller contract (same trust the scalar kernel always had, checked
/// via `debug_assert`): every non-`0xFF` byte in `w_plus`/`a_plus` is
/// `< wcnt.len()`/`< acnt.len()`, their sum is `< pair.len()`, and the
/// sign slices hold ±1 at every live position.
#[allow(clippy::too_many_arguments)]
pub fn accumulate_row(
    backend: SimdBackend,
    w_plus: &[u8],
    w_signs: &[i8],
    a_plus: &[u8],
    a_signs: &[i8],
    pair: &mut [i32],
    wcnt: &mut [i32],
    acnt: &mut [i32],
) {
    assert_eq!(w_plus.len(), w_signs.len());
    assert_eq!(a_plus.len(), a_signs.len());
    assert_eq!(w_plus.len(), a_plus.len());
    match backend {
        SimdBackend::Scalar => {
            accumulate_row_scalar(w_plus, w_signs, a_plus, a_signs, pair, wcnt, acnt)
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` implies runtime AVX2 support (see `available`).
        SimdBackend::Avx2 => unsafe {
            accumulate_row_avx2(w_plus, w_signs, a_plus, a_signs, pair, wcnt, acnt)
        },
        #[cfg(not(target_arch = "x86_64"))]
        SimdBackend::Avx2 => {
            accumulate_row_scalar(w_plus, w_signs, a_plus, a_signs, pair, wcnt, acnt)
        }
    }
}

/// The portable reference: the register-blocked scalar loop the
/// counting engines always ran. Zero-skip branches are well-predicted
/// and skipping saves table RMWs (a branchless trash-slot variant was
/// measured 8% slower — see EXPERIMENTS.md §Perf).
fn accumulate_row_scalar(
    w_plus: &[u8],
    w_signs: &[i8],
    a_plus: &[u8],
    a_signs: &[i8],
    pair: &mut [i32],
    wcnt: &mut [i32],
    acnt: &mut [i32],
) {
    for i in 0..w_plus.len() {
        // SAFETY: `i < w_plus.len()` and the slice lengths were asserted
        // equal by the dispatch wrapper.
        let wp = unsafe { *w_plus.get_unchecked(i) } as usize;
        let ap = unsafe { *a_plus.get_unchecked(i) } as usize;
        if wp == 0xFF || ap == 0xFF {
            continue;
        }
        let s = (unsafe { *w_signs.get_unchecked(i) } as i32)
            * (unsafe { *a_signs.get_unchecked(i) } as i32);
        debug_assert!(ap + wp < pair.len() && wp < wcnt.len() && ap < acnt.len());
        // SAFETY: live codes are bounded by the caller contract above.
        unsafe {
            *pair.get_unchecked_mut(ap + wp) += s;
            *wcnt.get_unchecked_mut(wp) += s;
            *acnt.get_unchecked_mut(ap) += s;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn accumulate_row_avx2(
    w_plus: &[u8],
    w_signs: &[i8],
    a_plus: &[u8],
    a_signs: &[i8],
    pair: &mut [i32],
    wcnt: &mut [i32],
    acnt: &mut [i32],
) {
    use std::arch::x86_64::*;
    let n = w_plus.len();
    let ff = _mm256_set1_epi8(-1);
    let mut sbuf = [0i8; 32];
    let mut i = 0usize;
    while i + 32 <= n {
        let wv = _mm256_loadu_si256(w_plus.as_ptr().add(i) as *const __m256i);
        let av = _mm256_loadu_si256(a_plus.as_ptr().add(i) as *const __m256i);
        let dead = _mm256_or_si256(_mm256_cmpeq_epi8(wv, ff), _mm256_cmpeq_epi8(av, ff));
        let mut live = !(_mm256_movemask_epi8(dead) as u32);
        if live != 0 {
            // psignb: w_sign · sign(a_sign) — exact ±1 product, dead
            // lanes are never read back.
            let ws = _mm256_loadu_si256(w_signs.as_ptr().add(i) as *const __m256i);
            let asv = _mm256_loadu_si256(a_signs.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(sbuf.as_mut_ptr() as *mut __m256i, _mm256_sign_epi8(ws, asv));
            while live != 0 {
                let k = live.trailing_zeros() as usize;
                live &= live - 1;
                let wp = *w_plus.get_unchecked(i + k) as usize;
                let ap = *a_plus.get_unchecked(i + k) as usize;
                let s = *sbuf.get_unchecked(k) as i32;
                debug_assert!(ap + wp < pair.len() && wp < wcnt.len() && ap < acnt.len());
                *pair.get_unchecked_mut(ap + wp) += s;
                *wcnt.get_unchecked_mut(wp) += s;
                *acnt.get_unchecked_mut(ap) += s;
            }
        }
        i += 32;
    }
    accumulate_row_scalar(
        &w_plus[i..],
        &w_signs[i..],
        &a_plus[i..],
        &a_signs[i..],
        pair,
        wcnt,
        acnt,
    );
}

// ---------------------------------------------------------------------
// INT8 dot product (the VNNI-style baseline).
// ---------------------------------------------------------------------

/// i32-accumulating i8 dot product. The AVX2 path widens 16 lanes at a
/// time to i16 and uses `pmaddwd` (exact i32 pair sums of i8 products),
/// so it computes the same mod-2³² integer sum as the scalar reference
/// [`crate::expdot::int8::gemv_i8`] in a different association order —
/// identical results, integer adds being commutative.
pub fn dot_i8(backend: SimdBackend, a: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), w.len());
    match backend {
        SimdBackend::Scalar => super::int8::gemv_i8(a, w),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` implies runtime AVX2 support (see `available`).
        SimdBackend::Avx2 => unsafe { dot_i8_avx2(a, w) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdBackend::Avx2 => super::int8::gemv_i8(a, w),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(a: &[i8], w: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 16 <= n {
        let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(i) as *const __m128i));
        let vw = _mm256_cvtepi8_epi16(_mm_loadu_si128(w.as_ptr().add(i) as *const __m128i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vw));
        i += 16;
    }
    let lo = _mm256_castsi256_si128(acc);
    let hi = _mm256_extracti128_si256::<1>(acc);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<1>(s));
    _mm_cvtsi128_si32(s) + super::int8::gemv_i8(&a[i..], &w[i..])
}

// ---------------------------------------------------------------------
// f32 block copy (im2col's stride-1 inner loop).
// ---------------------------------------------------------------------

/// Copy `src` into `dst` (equal lengths). Scalar uses `copy_from_slice`
/// (memcpy); AVX2 runs explicit 8-wide unaligned vector moves. Copies
/// are trivially bit-exact.
pub fn copy_f32(backend: SimdBackend, dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    match backend {
        SimdBackend::Scalar => dst.copy_from_slice(src),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` implies runtime AVX2 (and thus AVX) support.
        SimdBackend::Avx2 => unsafe { copy_f32_avx(dst.as_mut_ptr(), src.as_ptr(), dst.len()) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdBackend::Avx2 => dst.copy_from_slice(src),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn copy_f32_avx(dst: *mut f32, src: *const f32, n: usize) {
    use std::arch::x86_64::*;
    let mut i = 0usize;
    while i + 8 <= n {
        _mm256_storeu_ps(dst.add(i), _mm256_loadu_ps(src.add(i)));
        i += 8;
    }
    while i < n {
        *dst.add(i) = *src.add(i);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnateq::ZERO_CODE_SENTINEL;
    use crate::expdot::pack::{self, nibble_lut};
    use crate::tensor::SplitMix64;

    /// The SIMD backend to exercise, or `None` on scalar-only hosts
    /// (the avx2-vs-scalar tests then pass vacuously; CI's simd lane
    /// and the sanitizer job run them for real).
    fn simd() -> Option<SimdBackend> {
        match best_available() {
            SimdBackend::Scalar => None,
            b => Some(b),
        }
    }

    fn rand_codes(
        n: usize,
        r_max: i32,
        zero_every: usize,
        rng: &mut SplitMix64,
    ) -> (Vec<i8>, Vec<i8>) {
        let mut codes = Vec::with_capacity(n);
        let mut signs = Vec::with_capacity(n);
        for i in 0..n {
            if zero_every > 0 && i % zero_every == 0 {
                codes.push(ZERO_CODE_SENTINEL);
                signs.push(1);
            } else {
                let span = (2 * r_max + 1) as usize;
                codes.push((rng.next_below(span) as i32 - r_max) as i8);
                signs.push(if rng.next_below(2) == 0 { 1 } else { -1 });
            }
        }
        (codes, signs)
    }

    #[test]
    fn parse_accepts_known_names() {
        assert_eq!(parse("scalar"), Ok(Some(SimdBackend::Scalar)));
        assert_eq!(parse("avx2"), Ok(Some(SimdBackend::Avx2)));
        assert_eq!(parse("simd"), Ok(Some(SimdBackend::Avx2)));
        assert_eq!(parse("auto"), Ok(None));
        assert!(parse("neon").is_err());
    }

    #[test]
    fn scalar_is_always_available() {
        assert!(available(SimdBackend::Scalar));
        // Whatever detection says is, by definition, available.
        assert!(available(best_available()));
    }

    #[test]
    fn shift_codes_matches_scalar_all_widths() {
        let Some(simd) = simd() else { return };
        let mut rng = SplitMix64::new(0x5111);
        // Odd lengths hit the tail; r_max 127 hits the wrapping add.
        for (n, r_max, zero_every) in [(33, 1, 3), (257, 7, 5), (96, 127, 1), (500, 127, 7)] {
            let (codes, _) = rand_codes(n, r_max, zero_every, &mut rng);
            let want = pack::shift_codes(&codes, r_max);
            let got = shift_codes(simd, &codes, r_max);
            assert_eq!(got, want, "n={n} r_max={r_max}");
        }
    }

    #[test]
    fn decode_nibbles_matches_scalar() {
        let Some(simd) = simd() else { return };
        let mut rng = SplitMix64::new(0x5112);
        let lut = nibble_lut(3);
        for n in [31usize, 32, 64, 97, 320] {
            let bytes: Vec<u8> = (0..n.div_ceil(2)).map(|_| rng.next_below(256) as u8).collect();
            let (mut ps, mut ss) = (Vec::new(), Vec::new());
            let (mut pv, mut sv) = (Vec::new(), Vec::new());
            decode_nibbles(SimdBackend::Scalar, &bytes, n, &lut, &mut ps, &mut ss);
            decode_nibbles(simd, &bytes, n, &lut, &mut pv, &mut sv);
            assert_eq!(pv, ps, "plus n={n}");
            assert_eq!(sv, ss, "signs n={n}");
        }
    }

    #[test]
    fn accumulate_row_matches_scalar() {
        let Some(simd) = simd() else { return };
        let mut rng = SplitMix64::new(0x5113);
        for (n, r_max, zero_every) in [(64usize, 3, 4), (129, 7, 0), (333, 127, 2), (31, 1, 1)] {
            let (wc, ws) = rand_codes(n, r_max, zero_every, &mut rng);
            let (ac, asn) = rand_codes(n, r_max, zero_every.max(1) + 1, &mut rng);
            let wp = pack::shift_codes(&wc, r_max);
            let ap = pack::shift_codes(&ac, r_max);
            let (plen, slen) = ((4 * r_max + 1) as usize, (2 * r_max + 1) as usize);
            let mut t_s = (vec![0i32; plen], vec![0i32; slen], vec![0i32; slen]);
            let mut t_v = t_s.clone();
            let sc = SimdBackend::Scalar;
            accumulate_row(sc, &wp, &ws, &ap, &asn, &mut t_s.0, &mut t_s.1, &mut t_s.2);
            accumulate_row(simd, &wp, &ws, &ap, &asn, &mut t_v.0, &mut t_v.1, &mut t_v.2);
            assert_eq!(t_v, t_s, "n={n} r_max={r_max}");
        }
    }

    #[test]
    fn accumulate_row_all_sentinel_is_a_noop() {
        let n = 70;
        let wp = vec![0xFFu8; n];
        let ws = vec![1i8; n];
        let mut tables = (vec![0i32; 13], vec![0i32; 7], vec![0i32; 7]);
        for b in [SimdBackend::Scalar, best_available()] {
            accumulate_row(b, &wp, &ws, &wp, &ws, &mut tables.0, &mut tables.1, &mut tables.2);
            assert!(tables.0.iter().chain(&tables.1).chain(&tables.2).all(|&c| c == 0));
        }
    }

    #[test]
    fn dot_i8_matches_scalar_reference() {
        let Some(simd) = simd() else { return };
        let mut rng = SplitMix64::new(0x5114);
        for n in [0usize, 1, 15, 16, 17, 64, 333, 1001] {
            let a: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            let w: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
            assert_eq!(dot_i8(simd, &a, &w), super::super::int8::gemv_i8(&a, &w), "n={n}");
        }
    }

    #[test]
    fn copy_f32_matches_scalar() {
        let Some(simd) = simd() else { return };
        let mut rng = SplitMix64::new(0x5115);
        for n in [0usize, 1, 7, 8, 9, 31, 100] {
            let src: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            copy_f32(SimdBackend::Scalar, &mut a, &src);
            copy_f32(simd, &mut b, &src);
            assert_eq!(a, b, "n={n}");
        }
    }
}
