//! Per-layer reconstruction context for the exponential dot product.
//!
//! Holds the Base-LookUp Tables (BLUT, §V-D) — precomputed powers of the
//! shared base — plus the four coefficient products of Eq. 8, so the
//! counting engines only accumulate integer counts and do a short
//! float post-process per output neuron.

use super::simd::{self, SimdBackend};
use crate::dnateq::ExpQuantParams;

/// Reconstruction context shared by all counting engines for one layer.
#[derive(Clone, Debug)]
pub struct ExpDotContext {
    /// Activation-tensor parameters.
    pub a_params: ExpQuantParams,
    /// Weight-tensor parameters.
    pub w_params: ExpQuantParams,
    /// `R_max` of the shared bitwidth.
    pub r_max: i32,
    /// BLUT for term 1: `b^k` for `k ∈ [2·R_min, 2·R_max]`
    /// (`blut_pair[k - 2·R_min]`); `2^{n+1}` entries in hardware.
    pub blut_pair: Vec<f64>,
    /// BLUT for terms 2 & 3: `b^i` for `i ∈ [R_min, R_max]`
    /// (`blut_single[i - R_min]`); `2^n` entries in hardware.
    pub blut_single: Vec<f64>,
    /// αA·αW — coefficient of term 1.
    pub c1: f64,
    /// αW·βA — coefficient of term 2 (counts of weight exponents).
    pub c2: f64,
    /// αA·βW — coefficient of term 3 (counts of activation exponents).
    pub c3: f64,
    /// βA·βW — coefficient of term 4 (signed pair count).
    pub c4: f64,
}

impl ExpDotContext {
    /// Build the context. Panics if the two tensors do not share base and
    /// bitwidth — DNA-TEQ constrains them per layer exactly so the
    /// exponent-sum trick works (§III-B).
    pub fn new(a_params: ExpQuantParams, w_params: ExpQuantParams) -> Self {
        assert_eq!(
            a_params.n_bits, w_params.n_bits,
            "layer tensors must share bitwidth"
        );
        assert!(
            (a_params.base - w_params.base).abs() < 1e-12,
            "layer tensors must share base"
        );
        let r_max = a_params.r_max();
        let base = a_params.base;
        let blut_pair: Vec<f64> = (-2 * r_max..=2 * r_max).map(|k| base.powi(k)).collect();
        let blut_single: Vec<f64> = (-r_max..=r_max).map(|i| base.powi(i)).collect();
        Self {
            a_params,
            w_params,
            r_max,
            blut_pair,
            blut_single,
            c1: a_params.alpha * w_params.alpha,
            c2: w_params.alpha * a_params.beta,
            c3: a_params.alpha * w_params.beta,
            c4: a_params.beta * w_params.beta,
        }
    }

    /// Number of entries in the pair table (`4·R_max + 1 ≤ 2^{n+1}`).
    #[inline]
    pub fn pair_table_len(&self) -> usize {
        (4 * self.r_max + 1) as usize
    }

    /// Number of entries in the single-exponent tables (`2·R_max + 1 < 2^n`).
    #[inline]
    pub fn single_table_len(&self) -> usize {
        (2 * self.r_max + 1) as usize
    }

    /// Bytes of one live counter set (pair + weight + activation tables,
    /// each with one trailing trash slot, i32 entries). The batched
    /// kernel sizes its (neuron × batch) tile so all live sets fit the
    /// L1 budget — the same pressure §IV discusses for the SIMD design.
    #[inline]
    pub fn counter_set_bytes(&self) -> usize {
        4 * ((self.pair_table_len() + 1) + 2 * (self.single_table_len() + 1))
    }

    /// Largest legal pre-shifted code (`2·R_max`, always < `0xFF`, the
    /// zero marker) — the invariant the SIMD kernels' debug asserts
    /// check before indexing count tables.
    #[inline]
    pub fn max_shifted_code(&self) -> u8 {
        (2 * self.r_max) as u8
    }

    /// Index into the pair table for an exponent sum `a + w`.
    #[inline]
    pub fn pair_index(&self, code_sum: i32) -> usize {
        (code_sum + 2 * self.r_max) as usize
    }

    /// Index into a single table for an exponent `i`.
    #[inline]
    pub fn single_index(&self, code: i32) -> usize {
        (code + self.r_max) as usize
    }

    /// Reconstruct one output value from the four count tables
    /// (the Dequantizer stage, §V-D): each count is multiplied by its
    /// `b^int` from the BLUT and the terms are combined with the
    /// coefficient products. Scalar-kernel convenience wrapper around
    /// [`ExpDotContext::reconstruct_with`] — every backend returns the
    /// same bits, so the choice is pure speed.
    pub fn reconstruct(
        &self,
        pair_counts: &[i32],
        w_counts: &[i32],
        a_counts: &[i32],
        sign_count: i32,
    ) -> f32 {
        self.reconstruct_with(SimdBackend::Scalar, pair_counts, w_counts, a_counts, sign_count)
    }

    /// Backend-dispatched reconstruction: the three counter × BLUT
    /// weighted sums run through [`simd::blut_dot`], whose fixed 8-lane
    /// reduction tree is shared by the scalar twin — scalar, AVX2, and
    /// AVX-512 produce bitwise-identical outputs.
    pub fn reconstruct_with(
        &self,
        backend: SimdBackend,
        pair_counts: &[i32],
        w_counts: &[i32],
        a_counts: &[i32],
        sign_count: i32,
    ) -> f32 {
        debug_assert_eq!(pair_counts.len(), self.pair_table_len());
        debug_assert_eq!(w_counts.len(), self.single_table_len());
        debug_assert_eq!(a_counts.len(), self.single_table_len());
        let t1 = simd::blut_dot(backend, pair_counts, &self.blut_pair);
        let t2 = simd::blut_dot(backend, w_counts, &self.blut_single);
        let t3 = simd::blut_dot(backend, a_counts, &self.blut_single);
        (self.c1 * t1 + self.c2 * t2 + self.c3 * t3 + self.c4 * sign_count as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(n: u8, base: f64, alpha: f64, beta: f64) -> ExpQuantParams {
        ExpQuantParams { base, alpha, beta, n_bits: n }
    }

    #[test]
    fn table_sizes_match_hardware_budget() {
        // §V-C: AC1 has 2^{n+1} entries worst case, AC2/AC3 have 2^n.
        for n in 3..=7u8 {
            let p = params(n, 1.3, 1.0, 0.0);
            let ctx = ExpDotContext::new(p, p);
            assert!(ctx.pair_table_len() <= 1 << (n + 1), "n={n}");
            assert!(ctx.single_table_len() <= 1 << n, "n={n}");
        }
    }

    #[test]
    fn counter_set_bytes_matches_table_sizes() {
        let p = params(4, 1.2, 1.0, 0.0);
        let ctx = ExpDotContext::new(p, p);
        let want = 4 * ((ctx.pair_table_len() + 1) + 2 * (ctx.single_table_len() + 1));
        assert_eq!(ctx.counter_set_bytes(), want);
    }

    #[test]
    fn pair_index_covers_extremes() {
        let p = params(4, 1.2, 1.0, 0.0);
        let ctx = ExpDotContext::new(p, p);
        assert_eq!(ctx.pair_index(-2 * ctx.r_max), 0);
        assert_eq!(ctx.pair_index(2 * ctx.r_max), ctx.pair_table_len() - 1);
    }

    #[test]
    fn reconstruct_single_pair_matches_direct_product() {
        // One activation a = α_A·b^2 + β_A, one weight w = -(α_W·b^-1 + β_W).
        let pa = params(4, 1.25, 0.7, 0.01);
        let pw = params(4, 1.25, 0.3, 0.002);
        let ctx = ExpDotContext::new(pa, pw);
        let mut pair = vec![0i32; ctx.pair_table_len()];
        let mut wc = vec![0i32; ctx.single_table_len()];
        let mut ac = vec![0i32; ctx.single_table_len()];
        // signs: s = -1
        pair[ctx.pair_index(2 + (-1))] -= 1;
        wc[ctx.single_index(-1)] -= 1;
        ac[ctx.single_index(2)] -= 1;
        let got = ctx.reconstruct(&pair, &wc, &ac, -1);

        let a_val = 0.7 * 1.25f64.powi(2) + 0.01;
        let w_val = 0.3 * 1.25f64.powi(-1) + 0.002;
        let want = -(a_val * w_val);
        // `got` is f32; compare at f32 precision.
        assert!((got as f64 - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn reconstruct_is_bitwise_identical_across_backends() {
        use crate::tensor::SplitMix64;
        let pa = params(6, 1.22, 0.8, 0.015);
        let pw = params(6, 1.22, 0.4, 0.003);
        let ctx = ExpDotContext::new(pa, pw);
        let mut rng = SplitMix64::new(0xB1C7);
        let mut pair = vec![0i32; ctx.pair_table_len()];
        let mut wc = vec![0i32; ctx.single_table_len()];
        let mut ac = vec![0i32; ctx.single_table_len()];
        for c in pair.iter_mut().chain(&mut wc).chain(&mut ac) {
            *c = rng.next_below(41) as i32 - 20;
        }
        let want = ctx.reconstruct(&pair, &wc, &ac, 9);
        for b in [SimdBackend::Avx2, SimdBackend::Avx512] {
            if !simd::available(b) {
                continue;
            }
            let got = ctx.reconstruct_with(b, &pair, &wc, &ac, 9);
            assert_eq!(got.to_bits(), want.to_bits(), "{}", b.name());
        }
    }

    #[test]
    #[should_panic(expected = "share base")]
    fn mismatched_bases_rejected() {
        let pa = params(4, 1.25, 1.0, 0.0);
        let pw = params(4, 1.30, 1.0, 0.0);
        ExpDotContext::new(pa, pw);
    }
}
