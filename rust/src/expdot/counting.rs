//! Counting engines for the exponential dot product.
//!
//! [`exp_dot_reference`] is the direct per-pair realization of Eq. 8 —
//! the correctness oracle. [`CountingFc`] is the optimized FC kernel
//! mirroring the paper's SIMD design (§IV): per-neuron counter arrays
//! sized `4·R_max+1 ≤ 2^{n+1}` kept hot in L1, activations quantized once
//! per input vector and broadcast across a block of output neurons, and
//! nibble-packed weights for 3-bit layers.

use super::context::ExpDotContext;
use super::pack::{nibble_lut_tables, pack_codes, NibbleLut, PackedCodes};
use super::simd::{self, AccumScratch, SimdBackend};
use crate::dnateq::{ExpQuantParams, QuantizedTensor, ZERO_CODE_SENTINEL};
use crate::tensor::Tensor;
use crate::util::parallel::parallel_row_blocks;

/// Reference exponential dot product over two quantized vectors: fills
/// the four count tables pair-by-pair, then reconstructs. Semantically
/// identical to `dot(dequant(a), dequant(w))` up to float association.
pub fn exp_dot_reference(ctx: &ExpDotContext, a: &QuantizedTensor, w: &QuantizedTensor) -> f32 {
    assert_eq!(a.len(), w.len(), "vector length mismatch");
    let mut pair = vec![0i32; ctx.pair_table_len()];
    let mut wc = vec![0i32; ctx.single_table_len()];
    let mut ac = vec![0i32; ctx.single_table_len()];
    let mut sign_count = 0i32;
    for i in 0..a.len() {
        let (ca, cw) = (a.codes[i], w.codes[i]);
        if ca == ZERO_CODE_SENTINEL || cw == ZERO_CODE_SENTINEL {
            continue; // a zero factor annihilates the product
        }
        let s = (a.signs[i] * w.signs[i]) as i32;
        pair[ctx.pair_index(ca as i32 + cw as i32)] += s;
        wc[ctx.single_index(cw as i32)] += s;
        ac[ctx.single_index(ca as i32)] += s;
        sign_count += s;
    }
    ctx.reconstruct(&pair, &wc, &ac, sign_count)
}

/// Weight storage of one FC layer for the counting kernel.
enum WeightStore {
    /// One byte per element: `code + R_max` in the low bits (0xFF = zero),
    /// sign in a parallel vector.
    Bytes { plus: Vec<u8>, signs: Vec<i8> },
    /// Nibble-packed 3-bit codes (two elements per byte).
    Packed(PackedCodes),
}

/// Reusable decode buffers for one weight row of a [`WeightStore::Packed`]
/// layer (unused by the byte layout, which hands out slices directly).
#[derive(Default)]
struct RowScratch {
    plus: Vec<u8>,
    signs: Vec<i8>,
}

impl WeightStore {
    /// Weight row `j` as parallel pre-shifted-code / sign slices — the
    /// one representation [`simd::accumulate_row`] consumes. Packed rows
    /// decode into `scratch` once per row (amortized across the batch
    /// tile); zero/invalid nibbles decode to `(0xFF, 0)`, which the
    /// accumulator masks out exactly like byte-layout zeros.
    fn row<'a>(
        &'a self,
        j: usize,
        inf: usize,
        lut: &NibbleLut,
        backend: SimdBackend,
        scratch: &'a mut RowScratch,
    ) -> (&'a [u8], &'a [i8]) {
        match self {
            WeightStore::Bytes { plus, signs } => {
                (&plus[j * inf..(j + 1) * inf], &signs[j * inf..(j + 1) * inf])
            }
            WeightStore::Packed(packed) => {
                let row_off = j * inf;
                debug_assert!(row_off % 2 == 0, "in_features must keep rows byte-aligned");
                let row_bytes = &packed.bytes[row_off / 2..(row_off + inf).div_ceil(2)];
                simd::decode_nibbles(
                    backend,
                    row_bytes,
                    inf,
                    lut,
                    &mut scratch.plus,
                    &mut scratch.signs,
                );
                (&scratch.plus, &scratch.signs)
            }
        }
    }
}

/// FC layer executed entirely in the exponential domain (§IV).
///
/// Weights are quantized offline at construction; activations are
/// quantized per forward call (the runtime Quantizer stage, §V-B).
pub struct CountingFc {
    ctx: ExpDotContext,
    store: WeightStore,
    /// [out, in] dims.
    pub out_features: usize,
    pub in_features: usize,
    bias: Option<Vec<f32>>,
    /// SIMD backend captured at construction ([`simd::active_backend`]);
    /// override per instance with [`CountingFc::with_backend`].
    backend: SimdBackend,
}

/// Output neurons processed per activation pass. Each neuron needs a
/// pair-count array (≤ 2^{n+1} i32 = 1 KiB at n=7); a block of 8 keeps
/// all live counters within L1 (§IV discusses exactly this pressure).
const NEURON_BLOCK: usize = 8;

/// Batch columns processed per weight pass in the batched kernel: each
/// loaded weight code updates `BATCH_TILE` counter sets before the next
/// weight load, amortizing the weight stream across the batch.
const BATCH_TILE: usize = 4;

/// L1 budget (bytes) for the live counter block of the batched kernel;
/// the neuron tile shrinks at high bitwidths so
/// `neuron_tile × BATCH_TILE` counter sets stay resident.
const L1_COUNTER_BUDGET: usize = 32 * 1024;

/// Minimum MACs per parallel work item before `forward_batch` fans the
/// output-row loop out over `util::parallel::parallel_map`.
const PAR_MIN_MACS: usize = 1 << 21;

impl CountingFc {
    /// Quantize `weights` (`[out, in]`) with `w_params` and prepare the
    /// counting kernel. `a_params` is used to quantize activations at
    /// forward time (shared base/bitwidth enforced by [`ExpDotContext`]).
    pub fn new(
        weights: &Tensor,
        w_params: ExpQuantParams,
        a_params: ExpQuantParams,
        bias: Option<Vec<f32>>,
    ) -> Self {
        assert_eq!(weights.ndim(), 2, "CountingFc expects [out, in] weights");
        let (out_features, in_features) = (weights.shape()[0], weights.shape()[1]);
        if let Some(b) = &bias {
            assert_eq!(b.len(), out_features);
        }
        let q = w_params.quantize(weights);
        let ctx = ExpDotContext::new(a_params, w_params);
        let store = if w_params.n_bits == 3 {
            WeightStore::Packed(pack_codes(&q))
        } else {
            let r_max = w_params.r_max();
            let plus = q
                .codes
                .iter()
                .map(|&c| if c == ZERO_CODE_SENTINEL { 0xFF } else { (c as i32 + r_max) as u8 })
                .collect();
            WeightStore::Bytes { plus, signs: q.signs }
        };
        let backend = simd::active_backend();
        Self { ctx, store, out_features, in_features, bias, backend }
    }

    /// Rebind this layer to `backend` (must be available on this host).
    /// Lets scalar and SIMD instances coexist in one process — the
    /// equivalence property suite and `bench_gate` compare them live.
    pub fn with_backend(mut self, backend: SimdBackend) -> Self {
        assert!(simd::available(backend), "backend {} unavailable on this CPU", backend.name());
        self.backend = backend;
        self
    }

    /// The SIMD backend this instance dispatches to.
    pub fn backend(&self) -> SimdBackend {
        self.backend
    }

    pub fn context(&self) -> &ExpDotContext {
        &self.ctx
    }

    /// Bytes of weight storage (drives the Table III footprint analysis).
    pub fn weight_bytes(&self) -> usize {
        match &self.store {
            WeightStore::Bytes { plus, signs } => plus.len() + signs.len() / 8 + 1,
            WeightStore::Packed(p) => p.bytes.len(),
        }
    }

    /// Forward `[batch, in]` → `[batch, out]` one row at a time — the
    /// batch-1 GEMV path (each row streams the full weight store). Kept
    /// as the reference/baseline; the serving hot path is
    /// [`CountingFc::forward_batch`], which amortizes the weight stream
    /// across batch columns.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.shape()[1], self.in_features, "input feature mismatch");
        let batch = x.shape()[0];
        let mut out = vec![0.0f32; batch * self.out_features];
        let qa = self.ctx.a_params.quantize(x);
        for b in 0..batch {
            let a_codes = &qa.codes[b * self.in_features..(b + 1) * self.in_features];
            let a_signs = &qa.signs[b * self.in_features..(b + 1) * self.in_features];
            let out_row = &mut out[b * self.out_features..(b + 1) * self.out_features];
            self.forward_one(a_codes, a_signs, out_row);
        }
        Tensor::from_vec(&[batch, self.out_features], out)
    }

    /// Batched counting GEMM (`[batch, in]` → `[batch, out]`): the §IV
    /// counting kernel register-blocked over output rows *and* batch
    /// columns. Activations are quantized and shifted **once** for the
    /// whole batch; every weight code loaded from the store then updates
    /// up to [`BATCH_TILE`] counter sets before the next weight load, so
    /// the weight stream — the batch-1 bottleneck — is amortized across
    /// the batch. The live `neuron_tile × BATCH_TILE` counter block is
    /// sized to stay within [`L1_COUNTER_BUDGET`], and large layers fan
    /// the output-row loop out over [`parallel_row_blocks`].
    ///
    /// Bit-identical to stacking batch-1 [`CountingFc::forward`] calls:
    /// quantization is element-wise with fixed parameters, counter
    /// updates are order-free i32 adds, and the per-(row, neuron)
    /// reconstruction is unchanged.
    pub fn forward_batch(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.ndim(), 2);
        assert_eq!(x.shape()[1], self.in_features, "input feature mismatch");
        let batch = x.shape()[0];
        if batch == 0 {
            return Tensor::from_vec(&[0, self.out_features], Vec::new());
        }
        // One quantization + shift pass per batch (runtime Quantizer).
        let qa = self.ctx.a_params.quantize(x);
        let a_plus = simd::shift_codes(self.backend, &qa.codes, self.ctx.r_max);
        debug_assert!(a_plus.iter().all(|&p| p == 0xFF || p <= self.ctx.max_shifted_code()));

        let macs = batch * self.out_features * self.in_features;
        let out = parallel_row_blocks(self.out_features, batch, macs, PAR_MIN_MACS, |j0, j1| {
            self.forward_rows_batched(&a_plus, &qa.signs, batch, j0, j1)
        });
        Tensor::from_vec(&[batch, self.out_features], out)
    }

    /// Batched kernel for one contiguous output-row range `[j0, j1)` over
    /// the whole batch; returns a `[batch, j1-j0]` row-major block.
    fn forward_rows_batched(
        &self,
        a_plus: &[u8],
        a_signs: &[i8],
        batch: usize,
        j0: usize,
        j1: usize,
    ) -> Vec<f32> {
        let inf = self.in_features;
        let plen = self.ctx.pair_table_len();
        let slen = self.ctx.single_table_len();
        // Adaptive neuron tile: neuron_tile × BATCH_TILE counter sets
        // (with trash slots) must fit the L1 budget — high bitwidths
        // shrink the tile instead of spilling.
        let neuron_tile = (L1_COUNTER_BUDGET / (BATCH_TILE * self.ctx.counter_set_bytes()))
            .clamp(1, NEURON_BLOCK);
        let sets = neuron_tile * BATCH_TILE;
        let mut pair = vec![0i32; sets * (plen + 1)];
        let mut wcnt = vec![0i32; sets * (slen + 1)];
        let mut acnt = vec![0i32; sets * (slen + 1)];

        let lut = nibble_lut_tables(self.ctx.r_max);
        let mut scratch = RowScratch::default();
        let mut accum = AccumScratch::default();
        let width = j1 - j0;
        let mut out = vec![0.0f32; batch * width];
        let mut b0 = 0usize;
        while b0 < batch {
            let bt = (batch - b0).min(BATCH_TILE);
            let mut t0 = j0;
            while t0 < j1 {
                let tn = (t0 + neuron_tile).min(j1);
                let jt = tn - t0;
                let live = jt * bt;
                pair[..live * (plen + 1)].fill(0);
                wcnt[..live * (slen + 1)].fill(0);
                acnt[..live * (slen + 1)].fill(0);

                // Each weight row is materialized once (packed rows decode
                // into scratch) and swept against every batch column of the
                // tile while it is L1-hot; counter updates are order-free
                // i32 adds, so any sweep order is bit-identical.
                for (jj, j) in (t0..tn).enumerate() {
                    let (wrow, srow) = self.store.row(j, inf, &lut, self.backend, &mut scratch);
                    for bb in 0..bt {
                        let ai0 = (b0 + bb) * inf;
                        let set = jj * bt + bb;
                        let (pb, sb) = (set * (plen + 1), set * (slen + 1));
                        simd::accumulate_row(
                            self.backend,
                            wrow,
                            srow,
                            &a_plus[ai0..ai0 + inf],
                            &a_signs[ai0..ai0 + inf],
                            &mut pair[pb..pb + plen],
                            &mut wcnt[sb..sb + slen],
                            &mut acnt[sb..sb + slen],
                            &mut accum,
                        );
                    }
                }

                // Dequantizer stage per (neuron, batch column) of the tile.
                for jj in 0..jt {
                    let j = t0 + jj;
                    let bias = self.bias.as_ref().map_or(0.0, |b| b[j]);
                    for bb in 0..bt {
                        let set = jj * bt + bb;
                        let pbase = set * (plen + 1);
                        let sbase = set * (slen + 1);
                        let sign_count: i32 = pair[pbase..pbase + plen].iter().sum();
                        let v = self.ctx.reconstruct_with(
                            self.backend,
                            &pair[pbase..pbase + plen],
                            &wcnt[sbase..sbase + slen],
                            &acnt[sbase..sbase + slen],
                            sign_count,
                        );
                        out[(b0 + bb) * width + (j - j0)] = v + bias;
                    }
                }
                t0 = tn;
            }
            b0 += bt;
        }
        out
    }

    /// One input vector against all output neurons.
    fn forward_one(&self, a_codes: &[i8], a_signs: &[i8], out: &mut [f32]) {
        let r_max = self.ctx.r_max;
        // Pre-shift activation codes once: `a + R_max` (0xFF = zero), the
        // same trick the Input Shift-Reg plays in hardware (§V-B).
        let a_plus = simd::shift_codes(self.backend, a_codes, r_max);

        let plen = self.ctx.pair_table_len();
        let slen = self.ctx.single_table_len();
        // Counter block: NEURON_BLOCK × (pair + w + a) tables plus one
        // trash slot per table, L1-resident.
        let mut pair = vec![0i32; NEURON_BLOCK * (plen + 1)];
        let mut wcnt = vec![0i32; NEURON_BLOCK * (slen + 1)];
        let mut acnt = vec![0i32; NEURON_BLOCK * (slen + 1)];

        let lut = nibble_lut_tables(r_max);
        let mut scratch = RowScratch::default();
        let mut accum = AccumScratch::default();
        let mut j0 = 0usize;
        while j0 < self.out_features {
            let jn = (j0 + NEURON_BLOCK).min(self.out_features);
            let width = jn - j0;
            pair[..width * (plen + 1)].fill(0);
            wcnt[..width * (slen + 1)].fill(0);
            acnt[..width * (slen + 1)].fill(0);

            // Inner loop of the §IV hot spot, one weight row per counter
            // set (see `simd::accumulate_row` for the scalar/AVX2/AVX-512
            // kernel trio).
            for (jj, j) in (j0..jn).enumerate() {
                let (wrow, srow) =
                    self.store.row(j, self.in_features, &lut, self.backend, &mut scratch);
                let (pb, sb) = (jj * (plen + 1), jj * (slen + 1));
                simd::accumulate_row(
                    self.backend,
                    wrow,
                    srow,
                    &a_plus,
                    a_signs,
                    &mut pair[pb..pb + plen],
                    &mut wcnt[sb..sb + slen],
                    &mut acnt[sb..sb + slen],
                    &mut accum,
                );
            }

            // Post-processing (Dequantizer stage): short float pass —
            // slices exclude the trash slot.
            for (jj, j) in (j0..jn).enumerate() {
                let pbase = jj * (plen + 1);
                let sbase = jj * (slen + 1);
                let sign_count: i32 = pair[pbase..pbase + plen].iter().sum();
                let v = self.ctx.reconstruct_with(
                    self.backend,
                    &pair[pbase..pbase + plen],
                    &wcnt[sbase..sbase + slen],
                    &acnt[sbase..sbase + slen],
                    sign_count,
                );
                out[j] = v + self.bias.as_ref().map_or(0.0, |b| b[j]);
            }
            j0 = jn;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SplitMix64;

    fn shared_params(w: &Tensor, a: &Tensor, n: u8) -> (ExpQuantParams, ExpQuantParams) {
        let wp = ExpQuantParams::init_for_tensor(w, n);
        let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: n };
        ap.refit_scale_offset(a);
        (wp, ap)
    }

    fn f32_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: 512-wide dequantized dot sweep
    fn reference_dot_equals_dequantized_dot() {
        let mut rng = SplitMix64::new(81);
        for n in [3u8, 4, 5, 7] {
            let w = Tensor::rand_signed_exponential(&[512], 3.0, &mut rng);
            let a = Tensor::rand_signed_exponential(&[512], 0.8, &mut rng);
            let (wp, ap) = shared_params(&w, &a, n);
            let qw = wp.quantize(&w);
            let qa = ap.quantize(&a);
            let ctx = ExpDotContext::new(ap, wp);
            let got = exp_dot_reference(&ctx, &qa, &qw) as f64;
            let want = f32_dot(qa.dequantize().data(), qw.dequantize().data());
            let tol = want.abs().max(1.0) * 1e-4;
            assert!((got - want).abs() < tol, "n={n}: {got} vs {want}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: full matmul cross-check
    fn counting_fc_matches_dequantized_matmul() {
        let mut rng = SplitMix64::new(82);
        for n in [3u8, 4, 6] {
            let (outf, inf, batch) = (13, 96, 3);
            let w = Tensor::rand_signed_exponential(&[outf, inf], 2.0, &mut rng);
            let x = Tensor::rand_signed_exponential(&[batch, inf], 0.9, &mut rng);
            let (wp, ap) = shared_params(&w, &x, n);
            let fc = CountingFc::new(&w, wp, ap, None);
            let got = fc.forward(&x);

            let dq_w = wp.quantize(&w).dequantize();
            let dq_x = ap.quantize(&x).dequantize();
            for b in 0..batch {
                for j in 0..outf {
                    let want = f32_dot(dq_x.row(b), dq_w.row(j));
                    let got_v = got.data()[b * outf + j] as f64;
                    let tol = want.abs().max(0.5) * 2e-4;
                    assert!(
                        (got_v - want).abs() < tol,
                        "n={n} b={b} j={j}: {got_v} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn counting_fc_handles_zeros_and_bias() {
        let mut rng = SplitMix64::new(83);
        let (outf, inf) = (5, 64);
        let mut w = Tensor::rand_signed_exponential(&[outf, inf], 2.0, &mut rng);
        let mut x = Tensor::rand_signed_exponential(&[1, inf], 1.0, &mut rng);
        for i in (0..inf).step_by(3) {
            x.data_mut()[i] = 0.0;
        }
        for i in (0..outf * inf).step_by(7) {
            w.data_mut()[i] = 0.0;
        }
        let (wp, ap) = shared_params(&w, &x, 4);
        let bias = vec![1.0f32; outf];
        let fc = CountingFc::new(&w, wp, ap, Some(bias));
        let got = fc.forward(&x);

        let dq_w = wp.quantize(&w).dequantize();
        let dq_x = ap.quantize(&x).dequantize();
        for j in 0..outf {
            let want = f32_dot(dq_x.row(0), dq_w.row(j)) + 1.0;
            let got_v = got.data()[j] as f64;
            assert!((got_v - want).abs() < 1e-3, "j={j}: {got_v} vs {want}");
        }
    }

    #[test]
    fn packed_path_used_for_3bit() {
        let mut rng = SplitMix64::new(84);
        let w = Tensor::rand_signed_exponential(&[16, 128], 2.0, &mut rng);
        let x = Tensor::rand_signed_exponential(&[1, 128], 1.0, &mut rng);
        let (wp3, ap3) = shared_params(&w, &x, 3);
        let fc3 = CountingFc::new(&w, wp3, ap3, None);
        // 16×128 elements at 0.5 B each.
        assert_eq!(fc3.weight_bytes(), 16 * 128 / 2);
        let (wp5, ap5) = shared_params(&w, &x, 5);
        let fc5 = CountingFc::new(&w, wp5, ap5, None);
        assert!(fc5.weight_bytes() > fc3.weight_bytes());
    }

    /// Stack batch-1 forwards into a `[batch, out]` reference.
    fn stacked_forward(fc: &CountingFc, x: &Tensor) -> Vec<f32> {
        let (batch, inf) = (x.shape()[0], x.shape()[1]);
        let mut out = Vec::with_capacity(batch * fc.out_features);
        for b in 0..batch {
            let row = Tensor::from_vec(&[1, inf], x.row(b).to_vec());
            out.extend_from_slice(fc.forward(&row).data());
        }
        out
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: 20-case property sweep
    fn forward_batch_bit_identical_to_stacked_forward() {
        use crate::util::prop::{for_all, PropConfig};
        for_all(
            PropConfig { cases: 20, seed: 0xBA7C1 },
            |rng, size| {
                let inf = 2 * (4 + rng.next_below(16 * size.max(1))); // even, packed-safe
                let outf = 1 + rng.next_below(24);
                let batch = 1 + rng.next_below(9);
                let n = 3 + (rng.next_below(5) as u8); // 3..=7
                let mut w = Tensor::rand_signed_exponential(&[outf, inf], 2.0, rng);
                let mut x = Tensor::rand_signed_exponential(&[batch, inf], 0.9, rng);
                // Sprinkle exact zeros on both sides.
                for i in (0..w.len()).step_by(5) {
                    w.data_mut()[i] = 0.0;
                }
                for i in (0..x.len()).step_by(7) {
                    x.data_mut()[i] = 0.0;
                }
                (w, x, n)
            },
            |(w, x, n)| {
                let (wp, ap) = shared_params(w, x, *n);
                let bias: Vec<f32> = (0..w.shape()[0]).map(|j| j as f32 * 0.25 - 1.0).collect();
                let fc = CountingFc::new(w, wp, ap, Some(bias));
                let got = fc.forward_batch(x);
                let want = stacked_forward(&fc, x);
                for (i, (&g, &r)) in got.data().iter().zip(&want).enumerate() {
                    if g.to_bits() != r.to_bits() {
                        return Err(format!("elem {i}: {g} vs {r} (bits differ)"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: per-pair oracle over the whole batch
    fn forward_batch_matches_reference_dot_within_bound() {
        // The blocked batched kernel against the per-pair Eq.-8 oracle
        // (§IV error bound: short-float reconstruction noise only).
        let mut rng = SplitMix64::new(85);
        for n in [3u8, 4] {
            let (outf, inf, batch) = (11, 128, 6);
            let w = Tensor::rand_signed_exponential(&[outf, inf], 2.0, &mut rng);
            let x = Tensor::rand_signed_exponential(&[batch, inf], 0.9, &mut rng);
            let (wp, ap) = shared_params(&w, &x, n);
            let fc = CountingFc::new(&w, wp, ap, None);
            let got = fc.forward_batch(&x);
            let ctx = ExpDotContext::new(ap, wp);
            for b in 0..batch {
                let qa = ap.quantize(&Tensor::from_vec(&[inf], x.row(b).to_vec()));
                for j in 0..outf {
                    let qw = wp.quantize(&Tensor::from_vec(&[inf], w.row(j).to_vec()));
                    let want = exp_dot_reference(&ctx, &qa, &qw);
                    let g = got.data()[b * outf + j];
                    let tol = want.abs().max(0.5) * 1e-3;
                    assert!((g - want).abs() < tol, "n={n} b={b} j={j}: {g} vs {want}");
                }
            }
        }
    }

    #[test]
    fn forced_scalar_backend_is_bit_identical() {
        // Both backends (and both weight layouts: packed 3-bit, bytes
        // 5-bit) must agree bitwise; on scalar-only hosts the "best"
        // instance simply is scalar and the check is an identity.
        let mut rng = SplitMix64::new(87);
        for n in [3u8, 5] {
            let w = Tensor::rand_signed_exponential(&[7, 48], 2.0, &mut rng);
            let x = Tensor::rand_signed_exponential(&[3, 48], 0.9, &mut rng);
            let (wp, ap) = shared_params(&w, &x, n);
            let best = CountingFc::new(&w, wp, ap, None)
                .with_backend(crate::expdot::simd::best_available());
            let scalar = CountingFc::new(&w, wp, ap, None)
                .with_backend(crate::expdot::simd::SimdBackend::Scalar);
            assert_eq!(scalar.forward_batch(&x).data(), best.forward_batch(&x).data());
            assert_eq!(scalar.forward(&x).data(), best.forward(&x).data());
        }
    }

    #[test]
    fn forward_batch_handles_empty_and_single_batches() {
        let mut rng = SplitMix64::new(86);
        let w = Tensor::rand_signed_exponential(&[5, 32], 2.0, &mut rng);
        let x1 = Tensor::rand_signed_exponential(&[1, 32], 1.0, &mut rng);
        let (wp, ap) = shared_params(&w, &x1, 4);
        let fc = CountingFc::new(&w, wp, ap, None);
        let empty = fc.forward_batch(&Tensor::zeros(&[0, 32]));
        assert_eq!(empty.shape(), &[0, 5]);
        let single = fc.forward_batch(&x1);
        assert_eq!(single.data(), fc.forward(&x1).data());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: 24-case property sweep
    fn property_counting_equals_reference() {
        use crate::util::prop::{for_all, PropConfig};
        for_all(
            PropConfig { cases: 24, seed: 0xC0FFEE },
            |rng, size| {
                let inf = 8 * size.max(2);
                let n = 3 + (rng.next_below(5) as u8); // 3..=7
                let w = Tensor::rand_signed_exponential(&[3, inf], 2.0, rng);
                let x = Tensor::rand_signed_exponential(&[1, inf], 1.0, rng);
                (w, x, n)
            },
            |(w, x, n)| {
                let (wp, ap) = shared_params(w, x, *n);
                let fc = CountingFc::new(w, wp, ap, None);
                let got = fc.forward(x);
                let ctx = ExpDotContext::new(ap, wp);
                let qa = ap.quantize(x);
                for j in 0..3 {
                    let wq = wp.quantize(&Tensor::from_vec(&[w.shape()[1]], w.row(j).to_vec()));
                    let want = exp_dot_reference(&ctx, &qa, &wq);
                    let g = got.data()[j];
                    let tol = want.abs().max(0.5) * 1e-3;
                    if (g - want).abs() > tol {
                        return Err(format!("j={j}: {g} vs {want}"));
                    }
                }
                Ok(())
            },
        );
    }
}
