//! Scalar == AVX2 == AVX-512 bit-exactness suite for the explicit
//! `expdot::simd` kernels, driven through the public engine APIs and
//! the kernel entry points directly.
//!
//! Every test compares a `SimdBackend::Scalar` run against **every**
//! non-scalar backend this host can execute (and the vector backends
//! against each other), requiring **bitwise identical** outputs across
//! bit-widths 2..=8 (all `R_max` values the quantizer produces), odd
//! vector lengths (tail handling), random sign patterns, and
//! `ZERO_CODE_SENTINEL`-dense inputs — including the AVX-512
//! replicated-histogram accumulate (both below and above its
//! replication threshold) and the backend-dispatched BLUT
//! reconstruction. On scalar-only hosts the pairs collapse to
//! scalar==scalar identities and the suite still passes; CI's forced
//! avx2/avx512 lanes run it with the vector kernels actually engaged.
//! Heavy property sweeps are `cfg_attr(miri, ignore)`; the Miri lane
//! covers the fold logic through the in-crate scalar-model unit test.

use dnateq::dnateq::ExpQuantParams;
use dnateq::expdot::pack::nibble_lut_tables;
use dnateq::expdot::simd::{self, dot_i8, AccumScratch, REPLICATE_MIN_RATIO};
use dnateq::expdot::{exp_dot_reference, CountingFc, ExpDotContext, Int8Fc, SimdBackend};
use dnateq::tensor::{SplitMix64, Tensor};
use dnateq::util::prop::{for_all, PropConfig};

/// The best non-scalar backend under test, or `None` (with a notice)
/// when this host has nothing beyond scalar — the pairs then degenerate
/// to identities rather than silently skipping the whole suite.
fn simd_backend() -> Option<SimdBackend> {
    match simd::best_available() {
        SimdBackend::Scalar => {
            eprintln!("note: scalar-only host; scalar==SIMD pairs collapse to identities");
            None
        }
        b => Some(b),
    }
}

/// Every non-scalar backend this host can execute (possibly empty).
fn nonscalar_backends() -> Vec<SimdBackend> {
    SimdBackend::all()
        .into_iter()
        .filter(|&b| b != SimdBackend::Scalar && simd::available(b))
        .collect()
}

fn shared_params(w: &Tensor, a: &Tensor, n: u8) -> (ExpQuantParams, ExpQuantParams) {
    let wp = ExpQuantParams::init_for_tensor(w, n);
    let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: n };
    ap.refit_scale_offset(a);
    (wp, ap)
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (&g, &r)) in got.iter().zip(want).enumerate() {
        if g.to_bits() != r.to_bits() {
            return Err(format!("{what}: elem {i}: {g} vs {r} (bits differ)"));
        }
    }
    Ok(())
}

#[test]
#[cfg_attr(miri, ignore)] // heavy: 16-case property sweep per backend
fn counting_fc_scalar_and_simd_agree_bitwise() {
    let _ = simd_backend(); // emit the scalar-only notice once
    for_all(
        PropConfig { cases: 16, seed: 0x51D0_7E57 },
        |rng, size| {
            // Bit-widths 2..=8; 3-bit layers take the nibble-packed store
            // and need even in_features, every other width gets odd
            // lengths on purpose to hit the vector tails.
            let n = 2 + (rng.next_below(7) as u8);
            let inf = if n == 3 {
                2 * (2 + rng.next_below(12 * size.max(1)))
            } else {
                2 * (2 + rng.next_below(12 * size.max(1))) + 1
            };
            let outf = 1 + rng.next_below(19);
            let batch = 1 + rng.next_below(9);
            let mut w = Tensor::rand_signed_exponential(&[outf, inf], 2.0, rng);
            let mut x = Tensor::rand_signed_exponential(&[batch, inf], 0.9, rng);
            // Sentinel-dense inputs: zero out a random stride on each side.
            for i in (0..w.len()).step_by(2 + rng.next_below(5)) {
                w.data_mut()[i] = 0.0;
            }
            for i in (0..x.len()).step_by(2 + rng.next_below(6)) {
                x.data_mut()[i] = 0.0;
            }
            (w, x, n)
        },
        |(w, x, n)| {
            let (wp, ap) = shared_params(w, x, *n);
            let bias: Vec<f32> = (0..w.shape()[0]).map(|j| j as f32 * 0.5 - 1.0).collect();
            let scalar = CountingFc::new(w, wp, ap, Some(bias.clone()))
                .with_backend(SimdBackend::Scalar);
            let want_batch = scalar.forward_batch(x);
            let want_one = scalar.forward(x);
            // Pairwise across all executable backends: each vector
            // backend vs scalar, which chains into avx2==avx512.
            for b in nonscalar_backends() {
                let vector =
                    CountingFc::new(w, wp, ap, Some(bias.clone())).with_backend(b);
                assert_bits_eq(
                    vector.forward_batch(x).data(),
                    want_batch.data(),
                    &format!("forward_batch [{}]", b.name()),
                )?;
                assert_bits_eq(
                    vector.forward(x).data(),
                    want_one.data(),
                    &format!("forward [{}]", b.name()),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn counting_fc_all_zero_input_yields_bias_exactly() {
    // All-sentinel activations: every counter stays zero, so the output
    // is exactly the bias under both backends.
    let mut rng = SplitMix64::new(0x2E50);
    for n in 2..=8u8 {
        let inf = if n == 3 { 64 } else { 63 };
        let w = Tensor::rand_signed_exponential(&[9, inf], 2.0, &mut rng);
        let cal = Tensor::rand_signed_exponential(&[1, inf], 1.0, &mut rng);
        let (wp, ap) = shared_params(&w, &cal, n);
        let bias: Vec<f32> = (0..9).map(|j| j as f32 - 4.0).collect();
        let zero = Tensor::zeros(&[3, inf]);
        let mut backends = vec![SimdBackend::Scalar];
        backends.extend(nonscalar_backends());
        for backend in backends {
            let fc =
                CountingFc::new(&w, wp, ap, Some(bias.clone())).with_backend(backend);
            let out = fc.forward_batch(&zero);
            for b in 0..3 {
                for (j, &bj) in bias.iter().enumerate() {
                    let got = out.data()[b * 9 + j];
                    assert_eq!(
                        got.to_bits(),
                        bj.to_bits(),
                        "n={n} backend={} b={b} j={j}",
                        backend.name()
                    );
                }
            }
        }
    }
}

#[test]
fn counting_kernel_tracks_reference_dot_under_both_backends() {
    // `exp_dot_reference` is the per-pair Eq.-8 oracle; the blocked
    // kernel must stay within short-float reconstruction noise of it
    // under BOTH backends, and the two backends must agree bitwise.
    let mut rng = SplitMix64::new(0xE8AC1E);
    for n in 2..=8u8 {
        let inf = if n == 3 { 96 } else { 97 };
        let outf = 5;
        let w = Tensor::rand_signed_exponential(&[outf, inf], 2.0, &mut rng);
        let x = Tensor::rand_signed_exponential(&[1, inf], 0.9, &mut rng);
        let (wp, ap) = shared_params(&w, &x, n);
        let ctx = ExpDotContext::new(ap, wp);
        let qa = ap.quantize(&Tensor::from_vec(&[inf], x.row(0).to_vec()));
        let scalar = CountingFc::new(&w, wp, ap, None).with_backend(SimdBackend::Scalar);
        let got_s = scalar.forward(&x);
        let got_v: Vec<(SimdBackend, Tensor)> = nonscalar_backends()
            .into_iter()
            .map(|b| (b, CountingFc::new(&w, wp, ap, None).with_backend(b).forward(&x)))
            .collect();
        for j in 0..outf {
            let qw = wp.quantize(&Tensor::from_vec(&[inf], w.row(j).to_vec()));
            let want = exp_dot_reference(&ctx, &qa, &qw);
            let g = got_s.data()[j];
            let tol = want.abs().max(0.5) * 1e-3;
            assert!((g - want).abs() < tol, "n={n} j={j}: {g} vs oracle {want}");
            for (b, got) in &got_v {
                assert_eq!(
                    got.data()[j].to_bits(),
                    g.to_bits(),
                    "n={n} j={j}: {} disagrees with scalar",
                    b.name()
                );
            }
        }
    }
}

#[test]
#[cfg_attr(miri, ignore)] // heavy: 16-case property sweep
fn int8_fc_scalar_and_simd_agree_bitwise() {
    let simd_b = simd_backend().unwrap_or(SimdBackend::Scalar);
    for_all(
        PropConfig { cases: 16, seed: 0x1D07_1D07 },
        |rng, size| {
            let inf = 3 + rng.next_below(40 * size.max(1)); // odd sizes included
            let outf = 1 + rng.next_below(17);
            let batch = 1 + rng.next_below(9);
            let w = Tensor::rand_normal(&[outf, inf], 0.0, 0.2, rng);
            let x = Tensor::rand_uniform(&[batch, inf], -1.5, 1.5, rng);
            (w, x)
        },
        |(w, x)| {
            let bias: Vec<f32> = (0..w.shape()[0]).map(|j| 0.25 * j as f32).collect();
            let scalar =
                Int8Fc::new(w, Some(bias.clone())).with_backend(SimdBackend::Scalar);
            let vector = Int8Fc::new(w, Some(bias)).with_backend(simd_b);
            assert_bits_eq(
                vector.forward_batch(x).data(),
                scalar.forward_batch(x).data(),
                "int8 forward_batch",
            )?;
            assert_bits_eq(vector.forward(x).data(), scalar.forward(x).data(), "int8 forward")
        },
    );
}

#[test]
fn dot_i8_exact_across_lengths_and_backends() {
    let mut rng = SplitMix64::new(0xD071);
    for n in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 500, 1001] {
        let a: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
        let w: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
        let naive: i32 = a.iter().zip(&w).map(|(&x, &y)| x as i32 * y as i32).sum();
        assert_eq!(dot_i8(SimdBackend::Scalar, &a, &w), naive, "scalar n={n}");
        for b in nonscalar_backends() {
            assert_eq!(dot_i8(b, &a, &w), naive, "{} n={n}", b.name());
        }
    }
}

/// Random valid (plus, sign) rows for `accumulate_row`, sentinel-dense,
/// with codes bounded by `r_max` on each side.
fn accum_inputs(
    rng: &mut SplitMix64,
    n: usize,
    r_max: usize,
) -> (Vec<u8>, Vec<i8>, Vec<u8>, Vec<i8>) {
    let mut mk = |rng: &mut SplitMix64| {
        let mut plus = Vec::with_capacity(n);
        let mut signs = Vec::with_capacity(n);
        for _ in 0..n {
            if rng.next_below(4) == 0 {
                plus.push(0xFFu8);
                signs.push(0i8);
            } else {
                plus.push(rng.next_below(2 * r_max + 1) as u8);
                signs.push(if rng.next_below(2) == 0 { 1 } else { -1 });
            }
        }
        (plus, signs)
    };
    let (wp, ws) = mk(rng);
    let (ap, asg) = mk(rng);
    (wp, ws, ap, asg)
}

#[test]
fn accumulate_row_bitwise_across_backends_and_replication_regimes() {
    // Direct kernel-level check of the AVX-512 replicated-histogram
    // fold: row lengths straddle the `REPLICATE_MIN_RATIO` threshold so
    // both the plain mask-drain path and the replicated+fold path run,
    // and tables start from a nonzero state to pin the `+=` contract.
    let mut rng = SplitMix64::new(0xACC0);
    for r_max in [1usize, 3, 7] {
        let (plen, slen) = (4 * r_max + 1, 2 * r_max + 1);
        let set = plen + 2 * slen;
        for n in [0usize, 1, 63, 64, 65, 257, REPLICATE_MIN_RATIO * set + 64, 4096] {
            let (wp, ws, ap, asg) = accum_inputs(&mut rng, n, r_max);
            let seed: Vec<i32> = (0..set).map(|i| i as i32 % 5 - 2).collect();
            let run = |backend: SimdBackend| {
                let mut pair = seed[..plen].to_vec();
                let mut wcnt = seed[plen..plen + slen].to_vec();
                let mut acnt = seed[plen + slen..].to_vec();
                let mut scratch = AccumScratch::default();
                // Two passes through the same scratch: accumulation must
                // compose, and scratch reuse must not leak state.
                for _ in 0..2 {
                    simd::accumulate_row(
                        backend, &wp, &ws, &ap, &asg, &mut pair, &mut wcnt, &mut acnt,
                        &mut scratch,
                    );
                }
                (pair, wcnt, acnt)
            };
            let want = run(SimdBackend::Scalar);
            for b in nonscalar_backends() {
                assert_eq!(run(b), want, "{} r_max={r_max} n={n}", b.name());
            }
        }
    }
}

#[test]
fn decode_nibbles_bitwise_across_backends() {
    let mut rng = SplitMix64::new(0xDEC0);
    let lut = nibble_lut_tables(3);
    for n in [0usize, 1, 31, 63, 64, 65, 127, 509] {
        let bytes: Vec<u8> = (0..n.div_ceil(2)).map(|_| rng.next_below(256) as u8).collect();
        let (mut wplus, mut wsigns) = (Vec::new(), Vec::new());
        simd::decode_nibbles(SimdBackend::Scalar, &bytes, n, &lut, &mut wplus, &mut wsigns);
        for b in nonscalar_backends() {
            let (mut vplus, mut vsigns) = (Vec::new(), Vec::new());
            simd::decode_nibbles(b, &bytes, n, &lut, &mut vplus, &mut vsigns);
            assert_eq!(vplus, wplus, "{} n={n} plus", b.name());
            assert_eq!(vsigns, wsigns, "{} n={n} signs", b.name());
        }
    }
}

#[test]
fn shift_codes_bitwise_across_backends() {
    let mut rng = SplitMix64::new(0x5F1F);
    for r_max in [1i32, 3, 7, 127] {
        for n in [0usize, 1, 31, 32, 33, 63, 64, 65, 257] {
            let codes: Vec<i8> = (0..n)
                .map(|_| {
                    if rng.next_below(5) == 0 {
                        dnateq::dnateq::ZERO_CODE_SENTINEL
                    } else {
                        (rng.next_below((2 * r_max + 1) as usize) as i32 - r_max) as i8
                    }
                })
                .collect();
            let want = simd::shift_codes(SimdBackend::Scalar, &codes, r_max);
            for b in nonscalar_backends() {
                let got = simd::shift_codes(b, &codes, r_max);
                assert_eq!(got, want, "{} r_max={r_max} n={n}", b.name());
            }
        }
    }
}

#[test]
fn blut_reconstruction_bitwise_across_backends() {
    // The backend-dispatched BLUT weighted sum shares one fixed 8-lane
    // reduction tree, so `reconstruct_with` must return identical bits
    // under every backend — both at the raw `blut_dot` level and
    // through a full `ExpDotContext`.
    let mut rng = SplitMix64::new(0xB1_D07);
    for n in [0usize, 1, 7, 8, 9, 16, 17, 61, 127, 509] {
        let counts: Vec<i32> = (0..n).map(|_| rng.next_below(81) as i32 - 40).collect();
        let blut: Vec<f64> = (0..n).map(|_| rng.next_below(1000) as f64 / 250.0 - 2.0).collect();
        let want = simd::blut_dot(SimdBackend::Scalar, &counts, &blut);
        for b in nonscalar_backends() {
            let got = simd::blut_dot(b, &counts, &blut);
            assert_eq!(got.to_bits(), want.to_bits(), "{} n={n}", b.name());
        }
    }
    for n_bits in [3u8, 5, 8] {
        let wp = ExpQuantParams { base: 1.3, alpha: 0.6, beta: 0.004, n_bits };
        let ap = ExpQuantParams { base: 1.3, alpha: 0.9, beta: 0.02, n_bits };
        let ctx = ExpDotContext::new(ap, wp);
        let pair: Vec<i32> =
            (0..ctx.pair_table_len()).map(|_| rng.next_below(41) as i32 - 20).collect();
        let wc: Vec<i32> =
            (0..ctx.single_table_len()).map(|_| rng.next_below(41) as i32 - 20).collect();
        let ac: Vec<i32> =
            (0..ctx.single_table_len()).map(|_| rng.next_below(41) as i32 - 20).collect();
        let want = ctx.reconstruct(&pair, &wc, &ac, 7);
        for b in nonscalar_backends() {
            let got = ctx.reconstruct_with(b, &pair, &wc, &ac, 7);
            assert_eq!(got.to_bits(), want.to_bits(), "{} n_bits={n_bits}", b.name());
        }
    }
}
