//! Scalar == SIMD bit-exactness suite for the explicit `expdot::simd`
//! kernels, driven through the public engine APIs.
//!
//! Every test builds paired engine instances — one forced to
//! `SimdBackend::Scalar`, one bound to the best backend this host can
//! run — and requires **bitwise identical** outputs across bit-widths
//! 2..=8 (all `R_max` values the quantizer produces), odd vector
//! lengths (tail handling), random sign patterns, and
//! `ZERO_CODE_SENTINEL`-dense inputs. On scalar-only hosts the pairs
//! collapse to scalar==scalar identities and the suite still passes;
//! CI's forced-SIMD lane runs it with AVX2 actually engaged.

use dnateq::dnateq::ExpQuantParams;
use dnateq::expdot::simd::{self, dot_i8};
use dnateq::expdot::{exp_dot_reference, CountingFc, ExpDotContext, Int8Fc, SimdBackend};
use dnateq::tensor::{SplitMix64, Tensor};
use dnateq::util::prop::{for_all, PropConfig};

/// The non-scalar backend under test, or `None` (with a notice) when
/// this host has nothing beyond scalar — the pairs then degenerate to
/// identities rather than silently skipping the whole suite.
fn simd_backend() -> Option<SimdBackend> {
    match simd::best_available() {
        SimdBackend::Scalar => {
            eprintln!("note: scalar-only host; scalar==SIMD pairs collapse to identities");
            None
        }
        b => Some(b),
    }
}

fn shared_params(w: &Tensor, a: &Tensor, n: u8) -> (ExpQuantParams, ExpQuantParams) {
    let wp = ExpQuantParams::init_for_tensor(w, n);
    let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: n };
    ap.refit_scale_offset(a);
    (wp, ap)
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (&g, &r)) in got.iter().zip(want).enumerate() {
        if g.to_bits() != r.to_bits() {
            return Err(format!("{what}: elem {i}: {g} vs {r} (bits differ)"));
        }
    }
    Ok(())
}

#[test]
fn counting_fc_scalar_and_simd_agree_bitwise() {
    let simd_b = simd_backend().unwrap_or(SimdBackend::Scalar);
    for_all(
        PropConfig { cases: 16, seed: 0x51D0_7E57 },
        |rng, size| {
            // Bit-widths 2..=8; 3-bit layers take the nibble-packed store
            // and need even in_features, every other width gets odd
            // lengths on purpose to hit the vector tails.
            let n = 2 + (rng.next_below(7) as u8);
            let inf = if n == 3 {
                2 * (2 + rng.next_below(12 * size.max(1)))
            } else {
                2 * (2 + rng.next_below(12 * size.max(1))) + 1
            };
            let outf = 1 + rng.next_below(19);
            let batch = 1 + rng.next_below(9);
            let mut w = Tensor::rand_signed_exponential(&[outf, inf], 2.0, rng);
            let mut x = Tensor::rand_signed_exponential(&[batch, inf], 0.9, rng);
            // Sentinel-dense inputs: zero out a random stride on each side.
            for i in (0..w.len()).step_by(2 + rng.next_below(5)) {
                w.data_mut()[i] = 0.0;
            }
            for i in (0..x.len()).step_by(2 + rng.next_below(6)) {
                x.data_mut()[i] = 0.0;
            }
            (w, x, n)
        },
        |(w, x, n)| {
            let (wp, ap) = shared_params(w, x, *n);
            let bias: Vec<f32> = (0..w.shape()[0]).map(|j| j as f32 * 0.5 - 1.0).collect();
            let scalar = CountingFc::new(w, wp, ap, Some(bias.clone()))
                .with_backend(SimdBackend::Scalar);
            let vector = CountingFc::new(w, wp, ap, Some(bias)).with_backend(simd_b);
            assert_bits_eq(
                vector.forward_batch(x).data(),
                scalar.forward_batch(x).data(),
                "forward_batch",
            )?;
            assert_bits_eq(vector.forward(x).data(), scalar.forward(x).data(), "forward")
        },
    );
}

#[test]
fn counting_fc_all_zero_input_yields_bias_exactly() {
    // All-sentinel activations: every counter stays zero, so the output
    // is exactly the bias under both backends.
    let mut rng = SplitMix64::new(0x2E50);
    for n in 2..=8u8 {
        let inf = if n == 3 { 64 } else { 63 };
        let w = Tensor::rand_signed_exponential(&[9, inf], 2.0, &mut rng);
        let cal = Tensor::rand_signed_exponential(&[1, inf], 1.0, &mut rng);
        let (wp, ap) = shared_params(&w, &cal, n);
        let bias: Vec<f32> = (0..9).map(|j| j as f32 - 4.0).collect();
        let zero = Tensor::zeros(&[3, inf]);
        for backend in [SimdBackend::Scalar, simd::best_available()] {
            let fc =
                CountingFc::new(&w, wp, ap, Some(bias.clone())).with_backend(backend);
            let out = fc.forward_batch(&zero);
            for b in 0..3 {
                for (j, &bj) in bias.iter().enumerate() {
                    let got = out.data()[b * 9 + j];
                    assert_eq!(
                        got.to_bits(),
                        bj.to_bits(),
                        "n={n} backend={} b={b} j={j}",
                        backend.name()
                    );
                }
            }
        }
    }
}

#[test]
fn counting_kernel_tracks_reference_dot_under_both_backends() {
    // `exp_dot_reference` is the per-pair Eq.-8 oracle; the blocked
    // kernel must stay within short-float reconstruction noise of it
    // under BOTH backends, and the two backends must agree bitwise.
    let mut rng = SplitMix64::new(0xE8AC1E);
    for n in 2..=8u8 {
        let inf = if n == 3 { 96 } else { 97 };
        let outf = 5;
        let w = Tensor::rand_signed_exponential(&[outf, inf], 2.0, &mut rng);
        let x = Tensor::rand_signed_exponential(&[1, inf], 0.9, &mut rng);
        let (wp, ap) = shared_params(&w, &x, n);
        let ctx = ExpDotContext::new(ap, wp);
        let qa = ap.quantize(&Tensor::from_vec(&[inf], x.row(0).to_vec()));
        let scalar = CountingFc::new(&w, wp, ap, None).with_backend(SimdBackend::Scalar);
        let vector =
            CountingFc::new(&w, wp, ap, None).with_backend(simd::best_available());
        let got_s = scalar.forward(&x);
        let got_v = vector.forward(&x);
        for j in 0..outf {
            let qw = wp.quantize(&Tensor::from_vec(&[inf], w.row(j).to_vec()));
            let want = exp_dot_reference(&ctx, &qa, &qw);
            let g = got_s.data()[j];
            let tol = want.abs().max(0.5) * 1e-3;
            assert!((g - want).abs() < tol, "n={n} j={j}: {g} vs oracle {want}");
            assert_eq!(
                got_v.data()[j].to_bits(),
                g.to_bits(),
                "n={n} j={j}: backends disagree"
            );
        }
    }
}

#[test]
fn int8_fc_scalar_and_simd_agree_bitwise() {
    let simd_b = simd_backend().unwrap_or(SimdBackend::Scalar);
    for_all(
        PropConfig { cases: 16, seed: 0x1D07_1D07 },
        |rng, size| {
            let inf = 3 + rng.next_below(40 * size.max(1)); // odd sizes included
            let outf = 1 + rng.next_below(17);
            let batch = 1 + rng.next_below(9);
            let w = Tensor::rand_normal(&[outf, inf], 0.0, 0.2, rng);
            let x = Tensor::rand_uniform(&[batch, inf], -1.5, 1.5, rng);
            (w, x)
        },
        |(w, x)| {
            let bias: Vec<f32> = (0..w.shape()[0]).map(|j| 0.25 * j as f32).collect();
            let scalar =
                Int8Fc::new(w, Some(bias.clone())).with_backend(SimdBackend::Scalar);
            let vector = Int8Fc::new(w, Some(bias)).with_backend(simd_b);
            assert_bits_eq(
                vector.forward_batch(x).data(),
                scalar.forward_batch(x).data(),
                "int8 forward_batch",
            )?;
            assert_bits_eq(vector.forward(x).data(), scalar.forward(x).data(), "int8 forward")
        },
    );
}

#[test]
fn dot_i8_exact_across_lengths_and_backends() {
    let Some(simd_b) = simd_backend() else { return };
    let mut rng = SplitMix64::new(0xD071);
    for n in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 500, 1001] {
        let a: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
        let w: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i32 - 127) as i8).collect();
        let naive: i32 = a.iter().zip(&w).map(|(&x, &y)| x as i32 * y as i32).sum();
        assert_eq!(dot_i8(SimdBackend::Scalar, &a, &w), naive, "scalar n={n}");
        assert_eq!(dot_i8(simd_b, &a, &w), naive, "simd n={n}");
    }
}
