//! Batched-engine integration: serving through the coordinator with the
//! batched backends must reproduce batch-1 results request-for-request,
//! and the batched engines must stay bit-true to stacked batch-1
//! forwards at shapes large enough to engage the parallel row fan-out.

use dnateq::coordinator::{
    AlexNetBackend, BatcherConfig, Coordinator, CoordinatorConfig, Output, Payload,
};
use dnateq::dataset::ImageDataset;
use dnateq::dnateq::ExpQuantParams;
use dnateq::expdot::{CountingFc, Int8Fc};
use dnateq::nn::{AlexNetMini, ExecPlan};
use dnateq::tensor::{SplitMix64, Tensor};
use std::sync::Arc;
use std::time::Duration;

#[test]
fn batched_serving_matches_per_image_predictions() {
    let model = AlexNetMini::random(401);
    let data = ImageDataset::synthetic(24, 402);
    let plan = ExecPlan::fp32();
    let want: Vec<usize> =
        (0..data.len()).map(|i| model.predict(&data.image(i), &plan)).collect();
    let c = Coordinator::start(
        Arc::new(AlexNetBackend::fp32(model, "fp32")),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(4) },
            min_workers: 2,
            max_workers: 2,
            queue_depth: 64,
            ..CoordinatorConfig::default()
        },
    );
    let tickets: Vec<_> =
        (0..data.len()).map(|i| c.submit(Payload::Image(data.image(i))).unwrap()).collect();
    for (i, t) in tickets.into_iter().enumerate() {
        assert_eq!(t.wait().unwrap().output, Output::ClassId(want[i]), "request {i}");
    }
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.completed, 24);
    assert_eq!(snap.failed_total(), 0);
}

#[test]
fn batched_engines_bit_match_stacked_forwards_at_parallel_scale() {
    // 256×512×33 MACs crosses the engines' parallel fan-out threshold;
    // the odd batch size exercises the tail batch tile.
    let mut rng = SplitMix64::new(403);
    let (outf, inf, batch) = (256, 512, 33);
    let w = Tensor::rand_signed_exponential(&[outf, inf], 3.0, &mut rng);
    let x = Tensor::rand_signed_exponential(&[batch, inf], 1.0, &mut rng);

    let wp = ExpQuantParams::init_for_tensor(&w, 4);
    let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: 4 };
    ap.refit_scale_offset(&x);
    let counting = CountingFc::new(&w, wp, ap, None);
    let got_counting = counting.forward_batch(&x);
    let int8 = Int8Fc::new(&w, None);
    let got_int8 = int8.forward_batch(&x);
    assert_eq!(got_counting.shape(), &[batch, outf]);
    assert_eq!(got_int8.shape(), &[batch, outf]);
    for b in 0..batch {
        let row = Tensor::from_vec(&[1, inf], x.row(b).to_vec());
        assert_eq!(got_counting.row(b), counting.forward(&row).data(), "counting row {b}");
        assert_eq!(got_int8.row(b), int8.forward(&row).data(), "int8 row {b}");
    }
}

#[test]
fn batched_resnet_serving_stays_consistent() {
    use dnateq::coordinator::ResNetBackend;
    use dnateq::nn::ResNetMini;
    let model = ResNetMini::random(404);
    let data = ImageDataset::synthetic(6, 405);
    let plan = ExecPlan::fp32();
    let want: Vec<usize> =
        (0..data.len()).map(|i| model.predict(&data.image(i), &plan)).collect();
    let c = Coordinator::start(
        Arc::new(ResNetBackend::fp32(model, "resnet-fp32")),
        CoordinatorConfig::default(),
    );
    let tickets: Vec<_> =
        (0..data.len()).map(|i| c.submit(Payload::Image(data.image(i))).unwrap()).collect();
    for (i, t) in tickets.into_iter().enumerate() {
        assert_eq!(t.wait().unwrap().output, Output::ClassId(want[i]), "request {i}");
    }
    c.shutdown_and_drain();
}
