//! Integration + property tests for the hybrid planner (PR: Pareto-front
//! PlanSet + SLA plan policies).
//!
//! Pinned here:
//! * every PlanSet front point round-trips **bit-exactly** through the
//!   versioned PlanStore (checksums + per-field bit patterns), and the
//!   persisted front index reloads with bit-identical metrics;
//! * the front is a strictly ascending, non-dominated staircase, and the
//!   fixture model's front spans several points and several schemes;
//! * `apply_policy` resolves an SLA policy against the stored front and
//!   hot-swaps the winning version into a serving registry — visible via
//!   the per-model swap counter and the plan label.

use dnateq::coordinator::{AlexNetBackend, CoordinatorConfig, ModelRegistry, Output, Payload};
use dnateq::dataset::ImageDataset;
use dnateq::dnateq::{
    CalibrationInput, LayerKind, LayerTensors, PlanPolicy, PlanStore, Planner, SearchSpace,
};
use dnateq::nn::{collect_image_calibration, AlexNetMini};
use dnateq::tensor::{SplitMix64, Tensor};
use dnateq::util::prop::{for_all, PropConfig};
use dnateq::util::TempDir;
use std::sync::Arc;

/// A small synthetic model whose layers favor different schemes: one
/// exponential-shaped (exp codes win), one uniform-shaped (linear grids
/// win), one heavy-tailed with outliers (pwl-friendly).
fn fixture_input(seed: u64) -> CalibrationInput {
    let mut rng = SplitMix64::new(seed);
    let mut tail_w = Tensor::rand_normal(&[3072], 0.0, 0.05, &mut rng);
    for v in tail_w.data_mut().iter_mut().step_by(97) {
        *v *= 50.0;
    }
    let layers = vec![
        LayerTensors {
            name: "conv1".into(),
            kind: LayerKind::Conv,
            weights: Tensor::rand_signed_exponential(&[2048], 3.0, &mut rng),
            acts: Tensor::rand_signed_exponential(&[4096], 0.7, &mut rng),
            is_first: true,
        },
        LayerTensors {
            name: "fc1".into(),
            kind: LayerKind::Fc,
            weights: Tensor::rand_uniform(&[2048], -1.0, 1.0, &mut rng),
            acts: Tensor::rand_uniform(&[4096], 0.0, 2.0, &mut rng),
            is_first: false,
        },
        LayerTensors {
            name: "fc2".into(),
            kind: LayerKind::Fc,
            weights: tail_w,
            acts: Tensor::rand_normal(&[4096], 0.0, 1.0, &mut rng),
            is_first: false,
        },
    ];
    CalibrationInput { model: "fixture".into(), layers }
}

// ---------------------------------------------------------------------
// Front points round-trip bit-exactly through the store.
// ---------------------------------------------------------------------

#[test]
fn property_front_points_roundtrip_bit_exactly_through_store() {
    let dir = TempDir::new().unwrap();
    let mut case = 0u32;
    for_all(
        PropConfig { cases: 3, seed: 0xF207 },
        |rng: &mut SplitMix64, _size| rng.next_u64(),
        |&seed| {
            case += 1;
            let store = PlanStore::new(dir.path().join(format!("case{case}")));
            let set = Planner::new(SearchSpace::full(0.05)).plan_set(&fixture_input(seed));
            let front = store.save_front(&set).map_err(|e| format!("{e:#}"))?;
            if front.points.len() != set.points.len() {
                return Err(format!(
                    "front stored {} of {} points",
                    front.points.len(),
                    set.points.len()
                ));
            }
            let reloaded = store
                .load_front(&set.model)
                .map_err(|e| format!("{e:#}"))?
                .ok_or("front index missing after save")?;
            for ((fp, rp), pp) in front.points.iter().zip(&reloaded.points).zip(&set.points) {
                // The stored plan artifact is the exact config.
                let stored = store.load(&set.model, fp.version).map_err(|e| format!("{e:#}"))?;
                if stored.checksum() != pp.config.checksum() {
                    return Err(format!("v{}: checksum drifted through store", fp.version));
                }
                for (la, lb) in stored.layers.iter().zip(&pp.config.layers) {
                    if la.scheme != lb.scheme || la.n_bits != lb.n_bits {
                        return Err(format!("layer `{}`: scheme/bits drifted", la.name));
                    }
                    let pairs = [
                        (la.base, lb.base),
                        (la.weights.alpha, lb.weights.alpha),
                        (la.weights.beta, lb.weights.beta),
                        (la.weights.rmae, lb.weights.rmae),
                        (la.acts.alpha, lb.acts.alpha),
                        (la.acts.beta, lb.acts.beta),
                    ];
                    for (x, y) in pairs {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!("layer `{}`: {x:?} != {y:?}", la.name));
                        }
                    }
                }
                // The reloaded index carries bit-identical metrics.
                let metric_pairs = [
                    (rp.rmae, pp.rmae),
                    (rp.compression, pp.compression),
                    (rp.avg_bits, pp.avg_bits),
                    (rp.energy_j, pp.energy_j),
                ];
                for (x, y) in metric_pairs {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("v{}: index metric {x:?} != {y:?}", fp.version));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Fixture front shape: several points, several schemes, non-dominated.
// ---------------------------------------------------------------------

#[test]
fn fixture_front_spans_points_and_schemes() {
    let set = Planner::new(SearchSpace::full(0.05)).plan_set(&fixture_input(0xF1));
    assert!(set.points.len() >= 3, "front has only {} point(s)", set.points.len());
    let mut schemes: Vec<String> = Vec::new();
    for p in &set.points {
        for s in p.config.scheme_names() {
            if !schemes.contains(&s) {
                schemes.push(s);
            }
        }
    }
    assert!(schemes.len() >= 2, "front should span ≥ 2 schemes, got {schemes:?}");
    for w in set.points.windows(2) {
        assert!(w[0].rmae < w[1].rmae, "front not strictly ascending in rmae");
        assert!(w[0].compression < w[1].compression, "front not ascending in compression");
    }
    for p in &set.points {
        p.config.validate().unwrap();
        assert!(p.energy_j > 0.0);
    }
}

// ---------------------------------------------------------------------
// SLA policy → stored front → hot-swap into a serving registry.
// ---------------------------------------------------------------------

#[test]
fn policies_swap_distinct_front_versions_into_serving() {
    let model = AlexNetMini::random(907);
    let data = ImageDataset::synthetic(6, 908);
    let input = collect_image_calibration(&model, &data.take(2));
    let set = Planner::new(SearchSpace::full(0.08)).plan_set(&input);
    assert!(set.points.len() >= 2, "need a non-trivial front, got {} point(s)", set.points.len());

    let dir = TempDir::new().unwrap();
    let store = PlanStore::new(dir.path());
    let front = store.save_front(&set).unwrap();

    let registry = ModelRegistry::new();
    registry
        .register_swappable(
            &set.model,
            Arc::new(AlexNetBackend::fp32(model, "alexnet")),
            CoordinatorConfig::default(),
        )
        .unwrap();
    assert_eq!(registry.plan_label(&set.model).unwrap(), "fp32");

    let (v_acc, cfg_acc) =
        registry.apply_policy(&set.model, &store, PlanPolicy::MaxAccuracy).unwrap();
    assert_eq!(registry.metrics(&set.model).unwrap().swaps, 1);
    let label_acc = registry.plan_label(&set.model).unwrap();
    assert!(label_acc.contains(&cfg_acc.checksum_hex()), "label: {label_acc}");

    let (v_bits, cfg_bits) =
        registry.apply_policy(&set.model, &store, PlanPolicy::MinBits).unwrap();
    assert_eq!(registry.metrics(&set.model).unwrap().swaps, 2);
    assert_ne!(v_acc, v_bits, "policies must pick different front versions");
    assert!(cfg_bits.avg_bitwidth() < cfg_acc.avg_bitwidth());
    assert_ne!(registry.plan_label(&set.model).unwrap(), label_acc);

    // The registry installed exactly what the front index selects.
    assert_eq!(front.select(PlanPolicy::MaxAccuracy).unwrap().version, v_acc);
    assert_eq!(front.select(PlanPolicy::MinBits).unwrap().version, v_bits);

    // The hybrid plan serves requests after the swap.
    let resp = registry.submit_wait(&set.model, Payload::Image(data.image(0))).unwrap();
    assert!(matches!(resp.output, Output::ClassId(k) if k < 10), "{:?}", resp.output);

    registry.shutdown_and_drain();
}
