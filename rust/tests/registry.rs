//! Integration + property tests for the plan-artifact store and the
//! multi-model serving registry (PR: versioned plans + hot-swap).
//!
//! Properties pinned here:
//! * plan artifacts round-trip **bit-exactly** (checksum-verified, every
//!   f64 compared by bit pattern);
//! * registry routing preserves per-model request order under
//!   interleaved multi-model traffic;
//! * plan hot-swap under concurrent load never drops, corrupts, or
//!   reorders a response.

use dnateq::coordinator::{
    AlexNetBackend, BatcherConfig, CoordinatorConfig, Engine, Infallible, InfallibleEngine,
    ModelRegistry, Output, Payload,
};
use dnateq::dataset::ImageDataset;
use dnateq::dnateq::{
    config_for_threshold, LayerKind, LayerQuant, PlanStore, QuantConfig, Scheme, SearchOptions,
    TensorQuant,
};
use dnateq::nn::{collect_image_calibration, AlexNetMini};
use dnateq::tensor::SplitMix64;
use dnateq::util::prop::{for_all, PropConfig};
use dnateq::util::TempDir;
use std::sync::{Arc, Mutex};
use std::time::Duration;

// ---------------------------------------------------------------------
// Plan-artifact round-trip.
// ---------------------------------------------------------------------

/// A finite f64 drawn from raw bit patterns (exercises subnormals,
/// shortest-repr edge cases, and negative zero — not just "nice" values).
fn finite_f64(rng: &mut SplitMix64) -> f64 {
    loop {
        let v = f64::from_bits(rng.next_u64());
        if v.is_finite() {
            return v;
        }
    }
}

fn random_config(rng: &mut SplitMix64, size: usize) -> QuantConfig {
    let n_layers = 1 + rng.next_below(size.max(1));
    let layers = (0..n_layers)
        .map(|i| LayerQuant {
            name: format!("layer{i}"),
            kind: if rng.next_below(2) == 0 { LayerKind::Conv } else { LayerKind::Fc },
            scheme: match rng.next_below(3) {
                0 => Scheme::Exp,
                1 => Scheme::Uniform,
                _ => Scheme::Pwl { breaks: 1 + rng.next_below(3) as u8 },
            },
            n_bits: 1 + rng.next_below(7) as u8,
            base: 1.0 + rng.next_f64().abs() * 4.0 + 1e-9,
            weights: TensorQuant {
                alpha: finite_f64(rng),
                beta: if rng.next_below(8) == 0 { -0.0 } else { finite_f64(rng) },
                rmae: rng.next_f64(),
                elems: rng.next_below(1 << 20),
            },
            acts: TensorQuant {
                alpha: finite_f64(rng),
                beta: finite_f64(rng),
                rmae: rng.next_f64(),
                elems: rng.next_below(1 << 20),
            },
            seeded_by_weights: rng.next_below(2) == 0,
            rss_w: finite_f64(rng),
            rss_a: finite_f64(rng),
            converged: rng.next_below(2) == 0,
        })
        .collect();
    QuantConfig {
        model: format!("prop_model_{}", rng.next_below(4)),
        thr_w: rng.next_f64() + 1e-9,
        layers,
    }
}

fn assert_bit_exact(a: &QuantConfig, b: &QuantConfig) -> Result<(), String> {
    if a.checksum() != b.checksum() {
        return Err(format!("checksum {} != {}", a.checksum_hex(), b.checksum_hex()));
    }
    if a.thr_w.to_bits() != b.thr_w.to_bits() || a.model != b.model {
        return Err("header mismatch".into());
    }
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        let pairs = [
            (la.base, lb.base),
            (la.weights.alpha, lb.weights.alpha),
            (la.weights.beta, lb.weights.beta),
            (la.weights.rmae, lb.weights.rmae),
            (la.acts.alpha, lb.acts.alpha),
            (la.acts.beta, lb.acts.beta),
            (la.acts.rmae, lb.acts.rmae),
            (la.rss_w, lb.rss_w),
            (la.rss_a, lb.rss_a),
        ];
        for (x, y) in pairs {
            if x.to_bits() != y.to_bits() {
                return Err(format!("layer `{}`: {x:?} != {y:?} (bits differ)", la.name));
            }
        }
        if la.n_bits != lb.n_bits
            || la.kind != lb.kind
            || la.name != lb.name
            || la.scheme != lb.scheme
        {
            return Err(format!("layer `{}` metadata mismatch", la.name));
        }
    }
    Ok(())
}

#[test]
fn property_plan_artifact_roundtrip_is_bit_exact() {
    let dir = TempDir::new().unwrap();
    let store = PlanStore::new(dir.path());
    let mut case = 0u32;
    for_all(
        PropConfig { cases: 48, seed: 0x9_1A45 },
        random_config,
        |cfg| {
            case += 1;
            // Through the raw artifact path…
            let p = dir.path().join(format!("raw/{case}.json"));
            cfg.save_json(&p).map_err(|e| e.to_string())?;
            let back = QuantConfig::load_json(&p).map_err(|e| format!("{e:#}"))?;
            assert_bit_exact(cfg, &back)?;
            // …and through the versioned store.
            let v = store.save_next(cfg).map_err(|e| e.to_string())?;
            let stored = store.load(&cfg.model, v).map_err(|e| format!("{e:#}"))?;
            assert_bit_exact(cfg, &stored)
        },
    );
}

// ---------------------------------------------------------------------
// Registry routing order under mixed-model traffic.
// ---------------------------------------------------------------------

/// Echoes sequence payloads and records the order in which payloads hit
/// the backend. With one worker per model, backend order == per-model
/// submission order iff the queue + batcher preserve FIFO. Written
/// against the legacy infallible shape and registered through the
/// `Infallible` adapter, so the migration path is exercised end to end.
struct RecordingBackend {
    tag: usize,
    log: Arc<Mutex<Vec<(usize, usize)>>>,
    delay_us: u64,
}

impl InfallibleEngine for RecordingBackend {
    fn infer(&self, batch: &[Payload]) -> Vec<Output> {
        if self.delay_us > 0 {
            std::thread::sleep(Duration::from_micros(self.delay_us));
        }
        batch
            .iter()
            .map(|p| match p {
                Payload::Seq(s) => {
                    self.log.lock().unwrap().push((self.tag, s[0]));
                    Output::Tokens(s.clone())
                }
                Payload::Image(_) => Output::ClassId(0),
            })
            .collect()
    }

    fn name(&self) -> &str {
        "recording"
    }
}

#[test]
fn property_routing_preserves_per_model_order_under_mixed_batches() {
    for_all(
        PropConfig { cases: 16, seed: 0x0DE2 },
        |rng: &mut SplitMix64, size| {
            let n_models = 1 + rng.next_below(3);
            let n_requests = 4 + rng.next_below(16 * size.max(1));
            let max_batch = 1 + rng.next_below(8);
            (n_models, n_requests, max_batch)
        },
        |&(n_models, n_requests, max_batch)| {
            let log = Arc::new(Mutex::new(Vec::new()));
            let registry = ModelRegistry::new();
            let names: Vec<String> = (0..n_models).map(|m| format!("model{m}")).collect();
            for (tag, name) in names.iter().enumerate() {
                let backend = RecordingBackend { tag, log: Arc::clone(&log), delay_us: 80 };
                let cfg = CoordinatorConfig {
                    batcher: BatcherConfig { max_batch, max_wait: Duration::from_micros(300) },
                    min_workers: 1,
                    max_workers: 1,
                    queue_depth: 256,
                    ..CoordinatorConfig::default()
                };
                registry
                    .register(name, Arc::new(Infallible(backend)), cfg)
                    .map_err(|e| e.to_string())?;
            }
            // Interleave round-robin through per-model typed clients:
            // request i goes to model i % n with per-model sequence
            // number i / n.
            let clients: Vec<_> = names
                .iter()
                .map(|name| registry.client(name).map_err(|e| e.to_string()))
                .collect::<Result<_, _>>()?;
            let mut tickets = Vec::new();
            for i in 0..n_requests {
                let seq = i / n_models;
                let ticket = clients[i % n_models]
                    .submit(Payload::Seq(vec![seq]))
                    .map_err(|e| e.to_string())?;
                tickets.push((seq, ticket));
            }
            for (seq, ticket) in tickets {
                let resp = ticket.wait().map_err(|e| e.to_string())?;
                if resp.output != Output::Tokens(vec![seq]) {
                    return Err(format!("response mismatch: wanted {seq}, got {:?}", resp.output));
                }
            }
            registry.shutdown_and_drain();
            // Per-model arrival order at the backend must be 0, 1, 2, …
            let log = log.lock().unwrap();
            for tag in 0..n_models {
                let seen: Vec<usize> =
                    log.iter().filter(|(t, _)| *t == tag).map(|(_, s)| *s).collect();
                let want: Vec<usize> = (0..seen.len()).collect();
                if seen != want {
                    return Err(format!("model{tag} order broken: {seen:?}"));
                }
            }
            let total: usize = log.len();
            if total != n_requests {
                return Err(format!("conservation broken: {total} != {n_requests}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Hot-swap under concurrent load.
// ---------------------------------------------------------------------

#[test]
fn hot_swap_under_concurrent_load_never_drops_a_response() {
    let model = AlexNetMini::random(501);
    let data = ImageDataset::synthetic(8, 502);
    let input = collect_image_calibration(&model, &data.take(2));
    let cfg_a = config_for_threshold(&input, 0.05, &SearchOptions::default());
    let cfg_b = config_for_threshold(&input, 0.10, &SearchOptions::default());

    let registry = Arc::new(ModelRegistry::new());
    registry
        .register_swappable(
            "alexnet_mini",
            Arc::new(AlexNetBackend::fp32(model, "alexnet")),
            CoordinatorConfig {
                batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(500) },
                min_workers: 2,
                max_workers: 2,
                queue_depth: 128,
                ..CoordinatorConfig::default()
            },
        )
        .unwrap();

    let clients = 3usize;
    let per_client = 16usize;
    let mut handles = Vec::new();
    for t in 0..clients {
        let reg = Arc::clone(&registry);
        let data = data.clone();
        handles.push(std::thread::spawn(move || {
            let mut answered = 0usize;
            for i in 0..per_client {
                let resp = reg
                    .submit_wait("alexnet_mini", Payload::Image(data.image((t + i) % data.len())))
                    .expect("submit during swap");
                match resp.output {
                    Output::ClassId(k) if k < 10 => answered += 1,
                    other => panic!("bad output under swap: {other:?}"),
                }
            }
            answered
        }));
    }

    // Swap plans continuously while the clients hammer the registry.
    let swaps = 6;
    for s in 0..swaps {
        let cfg = if s % 2 == 0 { &cfg_a } else { &cfg_b };
        registry.swap_plan("alexnet_mini", cfg).unwrap();
        assert!(registry.plan_label("alexnet_mini").unwrap().starts_with("dnateq"));
        std::thread::sleep(Duration::from_millis(2));
    }

    let answered: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(answered, clients * per_client, "responses dropped during hot-swap");

    let registry = Arc::try_unwrap(registry).ok().expect("sole owner");
    let snaps = registry.shutdown_and_drain();
    let snap = &snaps["alexnet_mini"];
    assert_eq!(snap.completed as usize, clients * per_client);
    assert_eq!(snap.swaps, swaps as u64);
    assert_eq!(snap.failed_total(), 0, "no request may fail during hot-swap");
}

// ---------------------------------------------------------------------
// Store-to-serving end-to-end: calibrate → store → load → serve → swap.
// ---------------------------------------------------------------------

#[test]
fn stored_plan_serves_identically_to_in_memory_plan() {
    let model = AlexNetMini::random(503);
    let data = ImageDataset::synthetic(6, 504);
    let input = collect_image_calibration(&model, &data.take(2));
    let cfg = config_for_threshold(&input, 0.08, &SearchOptions::default());

    let dir = TempDir::new().unwrap();
    let store = PlanStore::new(dir.path());
    let v = store.save_next(&cfg).unwrap();
    let stored = store.load(&cfg.model, v).unwrap();
    assert_eq!(stored.checksum(), cfg.checksum());

    // Serving through the reloaded plan must predict exactly like the
    // in-memory plan it was stored from.
    let direct = AlexNetBackend::quantized(AlexNetMini::random(503), &cfg, "direct");
    let reloaded = AlexNetBackend::quantized(AlexNetMini::random(503), &stored, "reloaded");
    let batch: Vec<Payload> = (0..data.len()).map(|i| Payload::Image(data.image(i))).collect();
    assert_eq!(direct.infer_batch(&batch), reloaded.infer_batch(&batch));
}
