//! Integration tests across the whole stack. Tests that need `make
//! artifacts` skip gracefully when the artifacts are absent so
//! `cargo test` stays green on a fresh checkout.

use dnateq::artifact_path;
use dnateq::coordinator::{AlexNetBackend, Coordinator, CoordinatorConfig, Output, Payload};
use dnateq::dataset::{ImageDataset, SeqDataset};
use dnateq::dnateq::{config_for_threshold, ExpQuantParams, SearchOptions};
use dnateq::expdot::{CountingFc, Int8Fc};
use dnateq::nn::eval::ImageModel;
use dnateq::nn::{
    collect_image_calibration, eval_classifier, AlexNetMini, ExecPlan, ResNetMini,
    TransformerMini, WeightMap,
};
use dnateq::runtime::{ArgValue, Runtime};
use dnateq::tensor::{SplitMix64, Tensor};
use std::sync::Arc;

fn have_artifacts() -> bool {
    artifact_path(".stamp.json").exists()
}

// ---------------------------------------------------------------------
// Artifact-free integration: synthetic end-to-end calibration.
// ---------------------------------------------------------------------

#[test]
fn calibration_to_quantized_inference_roundtrip() {
    // Random CNN + synthetic data: calibrate at a loose threshold and run
    // quantized inference — the plan must cover every layer and produce
    // finite logits.
    let model = AlexNetMini::random(301);
    let data = ImageDataset::synthetic(6, 302);
    let input = collect_image_calibration(&model, &data.take(2));
    let cfg = config_for_threshold(&input, 0.08, &SearchOptions::default());
    assert_eq!(cfg.layers.len(), 8);
    let plan = ExecPlan::exp(&model, &cfg);
    let acc = eval_classifier(&model, &data, &plan);
    assert!((0.0..=1.0).contains(&acc));
    let logits = model.forward(&data.image(0), &plan, None);
    assert!(logits.data().iter().all(|v| v.is_finite()));
}

#[test]
fn counting_engine_matches_fake_quant_linear() {
    // The bit-true counting engine and the fake-quant engine must agree:
    // same quantizer, two execution strategies.
    let mut rng = SplitMix64::new(303);
    let w = Tensor::rand_signed_exponential(&[16, 256], 3.0, &mut rng);
    let x = Tensor::rand_signed_exponential(&[1, 256], 1.0, &mut rng);
    let wp = ExpQuantParams::init_for_tensor(&w, 5);
    let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: 5 };
    ap.refit_scale_offset(&x);
    let fc = CountingFc::new(&w, wp, ap, None);
    let got = fc.forward(&x);

    let wq = wp.roundtrip(&w);
    let xq = ap.roundtrip(&x);
    for j in 0..16 {
        let want: f64 = xq
            .row(0)
            .iter()
            .zip(wq.row(j))
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        let g = got.data()[j] as f64;
        assert!((g - want).abs() < want.abs().max(0.5) * 1e-3, "{g} vs {want}");
    }
}

#[test]
fn int8_and_counting_backends_serve_through_coordinator() {
    let model = AlexNetMini::random(304);
    let data = ImageDataset::synthetic(8, 305);
    let c = Coordinator::start(
        Arc::new(AlexNetBackend::fp32(model, "fp32")),
        CoordinatorConfig::default(),
    );
    let mut tickets = Vec::new();
    for i in 0..8 {
        tickets.push(c.submit(Payload::Image(data.image(i))).unwrap());
    }
    for t in tickets {
        match t.wait().unwrap().output {
            Output::ClassId(k) => assert!(k < 10),
            other => panic!("unexpected {other:?}"),
        }
    }
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.completed, 8);
    assert_eq!(snap.failed_total(), 0);
}

#[test]
fn resnet_and_transformer_random_models_quantize() {
    let res = ResNetMini::random(306);
    let data = ImageDataset::synthetic(2, 307);
    let input = collect_image_calibration(&res, &data);
    let cfg = config_for_threshold(&input, 0.10, &SearchOptions::default());
    assert_eq!(cfg.layers.len(), 16);
    assert!(cfg.avg_bitwidth() >= 3.0 && cfg.avg_bitwidth() <= 7.0);

    let tr = TransformerMini::random(308);
    let seqs = SeqDataset::synthetic(2, 309);
    let input = dnateq::nn::collect_seq_calibration(&tr, &seqs);
    let cfg = config_for_threshold(&input, 0.10, &SearchOptions::default());
    assert_eq!(cfg.layers.len(), 33);
}

// ---------------------------------------------------------------------
// Artifact-backed integration (skips without `make artifacts`).
// ---------------------------------------------------------------------

#[test]
fn pjrt_and_engine_agree_on_trained_alexnet() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(artifact_path("alexnet_fp32.hlo.txt")).unwrap();
    let w = WeightMap::load_dir(artifact_path("models/alexnet_mini")).unwrap();
    let model = AlexNetMini::from_weights(&w).unwrap();
    let data = ImageDataset::load(artifact_path("data"), "eval").unwrap();
    let plan = ExecPlan::fp32();
    for i in 0..16 {
        let img = data.image(i);
        let input = Tensor::from_vec(&[1, 3, 32, 32], img.data().to_vec());
        let pjrt_logits = exe.run1(&input).unwrap();
        let rust_logits = model.forward(&img, &plan, None);
        let err = pjrt_logits
            .data()
            .iter()
            .zip(rust_logits.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-3, "sample {i}: max |Δlogit| = {err}");
    }
}

#[test]
fn dnateq_fc_artifact_composes_l1_l2_l3() {
    // The dnateq_fc HLO contains the Pallas exponential quantizer lowered
    // inline; executing it through PJRT must match the rust quantizer's
    // fake-quant semantics on the same weights.
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(artifact_path("dnateq_fc.hlo.txt")).unwrap();
    let w = WeightMap::load_dir(artifact_path("models/alexnet_mini")).unwrap();
    let weights = w.get("fc2.w").unwrap(); // [128, 256]

    let mut rng = SplitMix64::new(310);
    let x = Tensor::rand_signed_exponential(&[1, 256], 1.0, &mut rng);
    let out = exe.run1(&x).unwrap();
    assert_eq!(out.shape(), &[1, 128]);

    // Reproduce in rust: same quantizer parameters as aot.py's demo.
    let r_max = 7f64; // n_bits=4
    let max = weights.abs_max() as f64;
    let wp = ExpQuantParams { base: 1.22, alpha: max / 1.22f64.powf(r_max), beta: 0.0, n_bits: 4 };
    let ap = ExpQuantParams { base: 1.22, alpha: 0.05, beta: 0.0, n_bits: 4 };
    let wq = wp.roundtrip(weights);
    let xq = ap.roundtrip(&x);
    for j in 0..128 {
        let want: f64 = xq
            .row(0)
            .iter()
            .zip(wq.row(j))
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        let got = out.data()[j] as f64;
        assert!(
            (got - want).abs() < want.abs().max(0.5) * 5e-3,
            "neuron {j}: pjrt {got} vs rust {want}"
        );
    }
}

#[test]
fn pair_hist_artifact_matches_rust_counting() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(artifact_path("pair_hist.hlo.txt")).unwrap();
    // Build 4096 random 4-bit codes (R_max = 7, zero code = -8).
    let mut rng = SplitMix64::new(311);
    let n = 4096;
    let codes = |rng: &mut SplitMix64| -> Vec<i32> {
        (0..n)
            .map(|_| {
                if rng.next_below(9) == 0 {
                    -8
                } else {
                    rng.next_below(15) as i32 - 7
                }
            })
            .collect()
    };
    let signs = |rng: &mut SplitMix64| -> Vec<i32> {
        (0..n).map(|_| if rng.next_below(2) == 0 { -1 } else { 1 }).collect()
    };
    let (ac, asn, wc, wsn) = (codes(&mut rng), signs(&mut rng), codes(&mut rng), signs(&mut rng));
    let arg = |v: &Vec<i32>| ArgValue::I32(vec![n], v.clone());
    let out = exe
        .run(&[arg(&ac), arg(&asn), arg(&wc), arg(&wsn)])
        .unwrap()
        .remove(0);
    assert_eq!(out.len(), 29); // 4·R_max + 1

    // Rust reference histogram.
    let mut want = vec![0i32; 29];
    for i in 0..n {
        if ac[i] == -8 || wc[i] == -8 {
            continue;
        }
        want[(ac[i] + wc[i] + 14) as usize] += asn[i] * wsn[i];
    }
    for (k, (&g, &w)) in out.data().iter().zip(&want).enumerate() {
        assert_eq!(g as i32, w, "bin {k}");
    }
}

#[test]
fn transformer_artifacts_decode_greedily() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let enc = rt.load_hlo(artifact_path("transformer_enc.hlo.txt")).unwrap();
    let dec = rt.load_hlo(artifact_path("transformer_dec.hlo.txt")).unwrap();
    let data = SeqDataset::load(artifact_path("data"), "eval").unwrap();
    let l = 16usize;
    let pad = |s: &[usize]| -> Vec<usize> {
        let mut v = s.to_vec();
        v.resize(l, 0);
        v
    };
    // Greedy decode sample 0 through the PJRT pair and check ≥ half the
    // tokens match the reference translation (trained to ~100%).
    let src = &data.src[0];
    let gold = &data.tgt[0];
    let enc_out = enc
        .run(&[ArgValue::from_ids(&[1, l], &pad(src))])
        .unwrap()
        .remove(0);
    let mut tgt = vec![1usize]; // BOS
    for _ in 0..gold.len() - 1 {
        let logits = dec
            .run(&[
                ArgValue::from_ids(&[1, l], &pad(&tgt)),
                ArgValue::from_tensor(&enc_out),
                ArgValue::from_ids(&[1, l], &pad(src)),
            ])
            .unwrap()
            .remove(0);
        // logits [1, 16, 32]; take position tgt.len()-1.
        let pos = tgt.len() - 1;
        let row = &logits.data()[pos * 32..(pos + 1) * 32];
        let next = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        tgt.push(next);
        if next == 2 {
            break;
        }
    }
    let hits = tgt.iter().zip(gold).filter(|(a, b)| a == b).count();
    assert!(
        hits * 2 >= gold.len(),
        "PJRT greedy decode diverged: {tgt:?} vs {gold:?}"
    );
}

#[test]
fn int8_fc_vs_counting_fc_accuracy_parity() {
    // Both engines implement an approximate FC; on exponential data the
    // counting engine at 5 bits should not be wildly worse than INT8.
    let mut rng = SplitMix64::new(312);
    let w = Tensor::rand_signed_exponential(&[32, 512], 3.0, &mut rng);
    let x = Tensor::rand_signed_exponential(&[1, 512], 1.0, &mut rng);
    let reference: Vec<f64> = (0..32)
        .map(|j| {
            x.row(0)
                .iter()
                .zip(w.row(j))
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum()
        })
        .collect();

    let int8 = Int8Fc::new(&w, None).forward(&x);
    let wp = ExpQuantParams::init_for_tensor(&w, 5);
    let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: 5 };
    ap.refit_scale_offset(&x);
    let dna = CountingFc::new(&w, wp, ap, None).forward(&x);

    let err = |y: &Tensor| -> f64 {
        y.data()
            .iter()
            .zip(&reference)
            .map(|(&g, &r)| (g as f64 - r).abs())
            .sum::<f64>()
            / reference.iter().map(|r| r.abs()).sum::<f64>()
    };
    let (e8, ed) = (err(&int8), err(&dna));
    assert!(e8 < 0.10, "int8 err {e8}");
    assert!(ed < 0.30, "dnateq err {ed}");
}
