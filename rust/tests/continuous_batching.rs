//! Continuous-batching semantics: slot-refill serving must be
//! result-identical to stop-the-world batching, and `Block` admission
//! must respect per-request deadlines while waiting for a queue slot.
//!
//! The equivalence property leans on the PR-1 kernel guarantee that
//! `forward_batch` is bit-identical across batch splits, so the
//! stop-the-world reference (chunking the submission order at
//! `max_batch`) predicts the served outputs exactly, no matter how the
//! continuous batcher actually grouped them. The CI matrix runs this
//! under both scalar and SIMD dispatch.

use dnateq::coordinator::{
    AdmissionPolicy, BatcherConfig, Coordinator, CoordinatorConfig, Deadline, EchoEngine, Engine,
    Output, Payload, ServeError, SubmitOptions,
};
use dnateq::dataset::ImageDataset;
use dnateq::loadgen::cli::counting_engine;
use dnateq::util::prop::{for_all, PropConfig};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[test]
fn slot_refill_serving_matches_stop_the_world_batching() {
    let engine = counting_engine(0xE9_0115);
    let data = ImageDataset::synthetic(16, 0x7E57);
    for_all(
        PropConfig { cases: 12, seed: 0xC0_BA7C },
        |rng, size| {
            let n = 1 + rng.next_below((2 * size).min(24));
            let max_batch = 1 + rng.next_below(8);
            let min_workers = 1 + rng.next_below(2);
            let idxs: Vec<usize> = (0..n).map(|_| rng.next_below(data.len())).collect();
            (idxs, max_batch, min_workers)
        },
        |(idxs, max_batch, min_workers)| {
            let payloads: Vec<Payload> =
                idxs.iter().map(|&i| Payload::Image(data.image(i))).collect();

            // Reference: stop-the-world batches in submission order.
            let mut expect: Vec<Output> = Vec::with_capacity(payloads.len());
            for chunk in payloads.chunks(*max_batch) {
                for r in engine.infer_batch(chunk) {
                    expect.push(r.map_err(|e| format!("reference inference failed: {e}"))?);
                }
            }

            // Served: continuous batching, slots refill as items finish,
            // with the autoscaler allowed to grow the pool mid-run.
            let c = Coordinator::start(
                Arc::clone(&engine),
                CoordinatorConfig {
                    batcher: BatcherConfig {
                        max_batch: *max_batch,
                        max_wait: Duration::from_micros(200),
                    },
                    min_workers: *min_workers,
                    max_workers: min_workers + 2,
                    queue_depth: 256,
                    admission: AdmissionPolicy::Block,
                    power_envelope_watts: None,
                },
            );
            let tickets: Vec<_> = payloads
                .iter()
                .map(|p| c.submit(p.clone()).expect("healthy submit"))
                .collect();
            let mut got = Vec::with_capacity(tickets.len());
            for t in tickets {
                got.push(t.wait().map_err(|e| format!("serving failed: {e}"))?.output);
            }
            let snap = c.shutdown_and_drain();
            if snap.failed_total() != 0 {
                return Err(format!("unexpected serving failures: {}", snap.summary()));
            }
            if got != expect {
                return Err(format!(
                    "served outputs diverged from the stop-the-world reference\n\
                     expect: {expect:?}\n   got: {got:?}"
                ));
            }
            Ok(())
        },
    );
}

/// A single-slot coordinator whose queue holds one request: submitting
/// a third request under `Block` admission must wait for a slot, get
/// admitted when one frees up mid-wait, and still complete.
#[test]
fn block_admission_admits_when_a_slot_frees_before_the_deadline() {
    let c = Coordinator::start(
        Arc::new(EchoEngine { delay_us: 50_000 }),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(200) },
            min_workers: 1,
            max_workers: 1,
            queue_depth: 1,
            admission: AdmissionPolicy::Block,
            power_envelope_watts: None,
        },
    );
    // First request occupies the worker, second fills the queue.
    let a = c.submit(Payload::Seq(vec![1])).unwrap();
    let b = c.submit(Payload::Seq(vec![2])).unwrap();

    // The third blocks at admission; a slot opens once the worker picks
    // up `b` (~50 ms in), well before its 500 ms deadline.
    let t0 = Instant::now();
    let opts = SubmitOptions::default().with_deadline(Deadline::within(Duration::from_millis(500)));
    let ticket = c.client().submit_with(Payload::Seq(vec![3]), opts).expect("admitted mid-wait");
    let waited = t0.elapsed();
    assert!(
        waited >= Duration::from_millis(40),
        "expected to block for a slot, waited only {waited:?}"
    );

    let resp = ticket.wait().expect("admitted request completes");
    assert_eq!(resp.output, Output::Tokens(vec![3]));
    assert!(a.wait().is_ok() && b.wait().is_ok());
    c.shutdown_and_drain();
}

/// Same setup, but the deadline expires while still blocked at
/// admission: the submit must fail with `DeadlineExceeded` at roughly
/// the deadline, not wait for the queue indefinitely.
#[test]
fn block_admission_gives_up_when_the_deadline_expires_mid_wait() {
    let c = Coordinator::start(
        Arc::new(EchoEngine { delay_us: 200_000 }),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(200) },
            min_workers: 1,
            max_workers: 1,
            queue_depth: 1,
            admission: AdmissionPolicy::Block,
            power_envelope_watts: None,
        },
    );
    let a = c.submit(Payload::Seq(vec![1])).unwrap();
    let b = c.submit(Payload::Seq(vec![2])).unwrap();

    let t0 = Instant::now();
    let opts = SubmitOptions::default().with_deadline(Deadline::within(Duration::from_millis(60)));
    let err = c.client().submit_with(Payload::Seq(vec![3]), opts).unwrap_err();
    let waited = t0.elapsed();
    assert!(matches!(err, ServeError::DeadlineExceeded), "got {err:?}");
    assert!(
        waited < Duration::from_millis(150),
        "blocked past the deadline: waited {waited:?}"
    );

    assert!(a.wait().is_ok() && b.wait().is_ok());
    c.shutdown_and_drain();
}
