//! Integration tests for the typed serving API: `InferenceClient`
//! tickets (wait / wait_timeout / cancel), per-request deadlines and
//! priorities, admission policies, graceful drain, and the typed
//! `ServeError` taxonomy.
//!
//! The acceptance property pinned at the bottom: cancellation,
//! deadline expiry, queue rejection, and engine failure each surface as
//! their own typed error while concurrent healthy traffic completes in
//! FIFO order.

use dnateq::coordinator::{
    AdmissionPolicy, BatcherConfig, Capabilities, Coordinator, CoordinatorConfig, Deadline,
    EchoEngine, Engine, InferError, Output, Payload, Priority, ServeError, SubmitOptions,
    TranslatorBackend,
};
use dnateq::nn::{ExecPlan, TransformerMini};
use dnateq::tensor::Tensor;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Echoes sequences after a per-batch delay, recording the first token
/// of every sequence in engine-arrival order. Token `FAIL_TOKEN` fails
/// that item; token `GATE_TOKEN` sleeps `gate_ms` (used to hold the
/// worker while the queue fills).
struct RecordingEngine {
    log: Arc<Mutex<Vec<usize>>>,
    delay_us: u64,
    gate_ms: u64,
}

const FAIL_TOKEN: usize = 500;
const GATE_TOKEN: usize = 999;

impl Engine for RecordingEngine {
    fn infer_batch(&self, batch: &[Payload]) -> Vec<Result<Output, InferError>> {
        if batch.iter().any(|p| matches!(p, Payload::Seq(s) if s[0] == GATE_TOKEN)) {
            std::thread::sleep(Duration::from_millis(self.gate_ms));
        } else if self.delay_us > 0 {
            std::thread::sleep(Duration::from_micros(self.delay_us));
        }
        batch
            .iter()
            .map(|p| match p {
                Payload::Seq(s) => {
                    self.log.lock().unwrap().push(s[0]);
                    if s[0] == FAIL_TOKEN {
                        Err(InferError::failed("magic fail token"))
                    } else {
                        Ok(Output::Tokens(s.clone()))
                    }
                }
                Payload::Image(_) => Err(InferError::unsupported("sequences only")),
            })
            .collect()
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { images: false, seqs: true, vocab: None, max_batch: None }
    }

    fn name(&self) -> &str {
        "recording"
    }
}

/// Engine that violates the batch contract: always returns zero
/// results regardless of batch size.
struct LengthBugEngine;

impl Engine for LengthBugEngine {
    fn infer_batch(&self, _batch: &[Payload]) -> Vec<Result<Output, InferError>> {
        Vec::new()
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::all()
    }

    fn name(&self) -> &str {
        "length-bug"
    }
}

fn slow_single_worker(delay_us: u64) -> Coordinator {
    Coordinator::start(
        Arc::new(EchoEngine { delay_us }),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(200) },
            min_workers: 1,
            max_workers: 1,
            queue_depth: 64,
            admission: AdmissionPolicy::Block,
            power_envelope_watts: None,
        },
    )
}

// ---------------------------------------------------------------------
// Deadlines.
// ---------------------------------------------------------------------

#[test]
fn deadline_already_expired_at_submit_is_rejected_synchronously() {
    let c = Coordinator::start(Arc::new(EchoEngine { delay_us: 0 }), CoordinatorConfig::default());
    let client = c.client();
    let opts = SubmitOptions::default()
        .with_deadline(Deadline::at(Instant::now() - Duration::from_millis(1)));
    let err = client.submit_with(Payload::Seq(vec![1]), opts).unwrap_err();
    assert_eq!(err, ServeError::DeadlineExceeded);
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.expired, 1);
    assert_eq!(snap.completed, 0);
}

#[test]
fn deadline_expiring_in_queue_drops_the_request_at_batch_formation() {
    let c = slow_single_worker(50_000); // 50 ms per request
    let client = c.client();
    // Occupy the single worker, then queue a request that can only
    // expire while it waits.
    let gate = client.submit(Payload::Seq(vec![7])).unwrap();
    let doomed = client
        .submit_with(
            Payload::Seq(vec![8]),
            SubmitOptions::default().with_deadline(Deadline::within(Duration::from_millis(5))),
        )
        .unwrap();
    assert_eq!(doomed.wait().unwrap_err(), ServeError::DeadlineExceeded);
    assert_eq!(gate.wait().unwrap().output, Output::Tokens(vec![7]));
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.expired, 1);
    assert_eq!(snap.completed, 1);
}

#[test]
fn blocked_admission_gives_up_at_the_requests_deadline() {
    // Depth-1 queue under Block policy, held full by a slow worker: a
    // deadlined submission must stop blocking at its own deadline and
    // fail typed, not park until space frees.
    let c = Coordinator::start(
        Arc::new(EchoEngine { delay_us: 100_000 }),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(200) },
            min_workers: 1,
            max_workers: 1,
            queue_depth: 1,
            admission: AdmissionPolicy::Block,
            power_envelope_watts: None,
        },
    );
    let client = c.client();
    let gate = client.submit(Payload::Seq(vec![1])).unwrap();
    let queued = client.submit(Payload::Seq(vec![2])).unwrap(); // fills depth 1
    let t0 = Instant::now();
    let err = client
        .submit_with(
            Payload::Seq(vec![3]),
            SubmitOptions::default().with_deadline(Deadline::within(Duration::from_millis(20))),
        )
        .unwrap_err();
    assert_eq!(err, ServeError::DeadlineExceeded);
    assert!(
        t0.elapsed() < Duration::from_millis(90),
        "blocked {}ms — past the 20ms deadline",
        t0.elapsed().as_millis()
    );
    gate.wait().unwrap();
    queued.wait().unwrap();
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.expired, 1);
    assert_eq!(snap.completed, 2);
}

// ---------------------------------------------------------------------
// Cancellation.
// ---------------------------------------------------------------------

#[test]
fn cancel_between_enqueue_and_batch_formation_resolves_cancelled() {
    let c = slow_single_worker(50_000);
    let client = c.client();
    let gate = client.submit(Payload::Seq(vec![1])).unwrap();
    let victim = client.submit(Payload::Seq(vec![2])).unwrap();
    victim.cancel();
    assert_eq!(victim.wait().unwrap_err(), ServeError::Cancelled);
    assert_eq!(gate.wait().unwrap().output, Output::Tokens(vec![1]));
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.completed, 1);
}

#[test]
fn wait_timeout_reports_pending_then_delivers() {
    let c = slow_single_worker(30_000);
    let ticket = c.submit(Payload::Seq(vec![3])).unwrap();
    // Still inside the ~30 ms inference: the first short wait times out
    // without consuming the result.
    assert!(ticket.wait_timeout(Duration::from_millis(1)).is_none());
    let resolved = ticket
        .wait_timeout(Duration::from_secs(10))
        .expect("request must resolve well within 10 s");
    assert_eq!(resolved.unwrap().output, Output::Tokens(vec![3]));
    c.shutdown_and_drain();
}

// ---------------------------------------------------------------------
// Admission policies.
// ---------------------------------------------------------------------

#[test]
fn reject_policy_surfaces_queue_full_to_the_submitter() {
    let c = Coordinator::start(
        Arc::new(EchoEngine { delay_us: 50_000 }),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(200) },
            min_workers: 1,
            max_workers: 1,
            queue_depth: 1,
            admission: AdmissionPolicy::Reject,
            power_envelope_watts: None,
        },
    );
    let client = c.client();
    let mut ok = 0usize;
    let mut rejected = 0usize;
    let mut tickets = Vec::new();
    for i in 0..3 {
        match client.submit(Payload::Seq(vec![i])) {
            Ok(t) => {
                ok += 1;
                tickets.push(t);
            }
            Err(e) => {
                assert_eq!(e, ServeError::QueueFull);
                rejected += 1;
            }
        }
    }
    assert!(ok >= 1, "some traffic must be admitted");
    assert!(rejected >= 1, "a depth-1 queue must reject a 3-burst");
    for t in tickets {
        t.wait().unwrap();
    }
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.completed as usize, ok);
    assert_eq!(snap.rejected as usize, rejected);
}

#[test]
fn shed_oldest_under_full_queue_resolves_shed_tickets_with_queue_full() {
    let c = Coordinator::start(
        Arc::new(EchoEngine { delay_us: 50_000 }),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(200) },
            min_workers: 1,
            max_workers: 1,
            queue_depth: 2,
            admission: AdmissionPolicy::ShedOldest,
            power_envelope_watts: None,
        },
    );
    let client = c.client();
    // Every submission is admitted (shedding makes room), so a 6-burst
    // against a depth-2 queue must shed at least one older request.
    let tickets: Vec<_> =
        (0..6).map(|i| client.submit(Payload::Seq(vec![i])).unwrap()).collect();
    let mut completed = 0u64;
    let mut shed = 0u64;
    for t in tickets {
        match t.wait() {
            Ok(_) => completed += 1,
            Err(ServeError::QueueFull) => shed += 1,
            Err(other) => panic!("unexpected error under shed: {other:?}"),
        }
    }
    assert_eq!(completed + shed, 6);
    assert!(shed >= 1, "a 6-burst against depth 2 must shed");
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.completed, completed);
    assert_eq!(snap.shed, shed);
}

// ---------------------------------------------------------------------
// Payload validation at submission.
// ---------------------------------------------------------------------

#[test]
fn wrong_payloads_are_rejected_before_reaching_an_engine() {
    // Echo accepts both kinds, so shape/content validation still runs.
    let c = Coordinator::start(Arc::new(EchoEngine { delay_us: 0 }), CoordinatorConfig::default());
    let client = c.client();
    let bad_shape = client.submit(Payload::Image(Tensor::zeros(&[1, 16, 16]))).unwrap_err();
    assert!(matches!(bad_shape, ServeError::WrongPayload(ref w) if w.contains("[3, 32, 32]")));
    let empty_seq = client.submit(Payload::Seq(vec![])).unwrap_err();
    assert!(matches!(empty_seq, ServeError::WrongPayload(_)));
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.rejected, 2);
    assert_eq!(snap.completed, 0);

    // The translator additionally bounds token ids by its vocab.
    let t = Coordinator::start(
        Arc::new(TranslatorBackend {
            model: TransformerMini::random(77),
            plan: ExecPlan::fp32(),
            max_len: 4,
        }),
        CoordinatorConfig::default(),
    );
    let client = t.client();
    let image = client.submit(Payload::Image(Tensor::zeros(&[3, 32, 32]))).unwrap_err();
    assert!(matches!(image, ServeError::WrongPayload(_)));
    let oov = client.submit(Payload::Seq(vec![4, 1_000])).unwrap_err();
    assert!(matches!(oov, ServeError::WrongPayload(ref w) if w.contains("1000")));
    let ok = client.infer(Payload::Seq(vec![4, 5, 6])).unwrap();
    assert!(matches!(ok.output, Output::Tokens(_)));
    let snap = t.shutdown_and_drain();
    assert_eq!(snap.rejected, 2);
    assert_eq!(snap.completed, 1);
}

// ---------------------------------------------------------------------
// Engine failures.
// ---------------------------------------------------------------------

#[test]
fn batch_length_mismatch_fails_every_request_with_engine_failure() {
    let c = Coordinator::start(Arc::new(LengthBugEngine), CoordinatorConfig::default());
    let client = c.client();
    let tickets: Vec<_> =
        (0..3).map(|i| client.submit(Payload::Seq(vec![i])).unwrap()).collect();
    for t in tickets {
        let err = t.wait().unwrap_err();
        assert!(
            matches!(err, ServeError::EngineFailure(ref w) if w.contains("0 results")),
            "{err:?}"
        );
    }
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.engine_failures, 3);
    assert_eq!(snap.completed, 0);
}

#[test]
fn per_item_engine_failure_leaves_the_rest_of_the_batch_healthy() {
    let log = Arc::new(Mutex::new(Vec::new()));
    let c = Coordinator::start(
        Arc::new(RecordingEngine { log, delay_us: 0, gate_ms: 0 }),
        CoordinatorConfig::default(),
    );
    let client = c.client();
    let good1 = client.submit(Payload::Seq(vec![1])).unwrap();
    let bad = client.submit(Payload::Seq(vec![FAIL_TOKEN])).unwrap();
    let good2 = client.submit(Payload::Seq(vec![2])).unwrap();
    assert_eq!(good1.wait().unwrap().output, Output::Tokens(vec![1]));
    assert!(matches!(bad.wait().unwrap_err(), ServeError::EngineFailure(_)));
    assert_eq!(good2.wait().unwrap().output, Output::Tokens(vec![2]));
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.engine_failures, 1);
    assert_eq!(snap.completed, 2);
}

// ---------------------------------------------------------------------
// Graceful drain.
// ---------------------------------------------------------------------

#[test]
fn drain_with_in_flight_batches_resolves_every_outstanding_ticket() {
    let c = Coordinator::start(
        Arc::new(EchoEngine { delay_us: 10_000 }),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 2, max_wait: Duration::from_micros(500) },
            min_workers: 2,
            max_workers: 2,
            queue_depth: 64,
            admission: AdmissionPolicy::Block,
            power_envelope_watts: None,
        },
    );
    let client = c.client();
    let tickets: Vec<_> =
        (0..8).map(|i| client.submit(Payload::Seq(vec![i])).unwrap()).collect();
    // Wait from another thread while the main thread drains.
    let waiter = std::thread::spawn(move || {
        tickets
            .into_iter()
            .map(|t| t.wait().expect("drain must complete in-flight requests"))
            .count()
    });
    let snap = c.shutdown_and_drain();
    assert_eq!(waiter.join().unwrap(), 8);
    assert_eq!(snap.completed, 8);
    assert_eq!(snap.failed_total(), 0);
    // And the surviving client handle now gets the typed shutdown error.
    assert_eq!(
        client.submit(Payload::Seq(vec![9])).unwrap_err(),
        ServeError::ShuttingDown
    );
}

// ---------------------------------------------------------------------
// Priorities.
// ---------------------------------------------------------------------

#[test]
fn high_priority_requests_overtake_queued_normal_traffic() {
    let log = Arc::new(Mutex::new(Vec::new()));
    let c = Coordinator::start(
        Arc::new(RecordingEngine { log: Arc::clone(&log), delay_us: 1_000, gate_ms: 60 }),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(200) },
            min_workers: 1,
            max_workers: 1,
            queue_depth: 64,
            admission: AdmissionPolicy::Block,
            power_envelope_watts: None,
        },
    );
    let client = c.client();
    let gate = client.submit(Payload::Seq(vec![GATE_TOKEN])).unwrap();
    std::thread::sleep(Duration::from_millis(10)); // gate batch formed
    let mut tickets = Vec::new();
    for i in 0..3 {
        tickets.push(client.submit(Payload::Seq(vec![i])).unwrap());
    }
    tickets.push(
        client
            .submit_with(
                Payload::Seq(vec![42]),
                SubmitOptions::default().with_priority(Priority::High),
            )
            .unwrap(),
    );
    for t in tickets {
        t.wait().unwrap();
    }
    gate.wait().unwrap();
    c.shutdown_and_drain();
    let order = log.lock().unwrap().clone();
    assert_eq!(order, vec![GATE_TOKEN, 42, 0, 1, 2], "high priority must run first");
}

// ---------------------------------------------------------------------
// Acceptance: every failure mode typed, healthy traffic FIFO.
// ---------------------------------------------------------------------

#[test]
fn typed_errors_surface_while_concurrent_healthy_traffic_stays_fifo() {
    const HEALTHY: usize = 24;
    const CANCEL_TOKEN: usize = 100;
    const EXPIRE_TOKEN: usize = 101;
    const EXTRA_A: usize = 200;
    const EXTRA_B: usize = 201;
    // Depth sized so the queue holds the healthy burst + the three
    // error-case requests + one extra, and the next submission after
    // that must be rejected.
    let depth = HEALTHY + 3 + 1;
    let log = Arc::new(Mutex::new(Vec::new()));
    let c = Coordinator::start(
        Arc::new(RecordingEngine { log: Arc::clone(&log), delay_us: 500, gate_ms: 120 }),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(500) },
            min_workers: 1,
            max_workers: 1,
            queue_depth: depth,
            admission: AdmissionPolicy::Reject,
            power_envelope_watts: None,
        },
    );
    let client = c.client();

    // Hold the single worker inside a long batch so everything below
    // queues up behind it.
    let gate = client.submit(Payload::Seq(vec![GATE_TOKEN])).unwrap();
    std::thread::sleep(Duration::from_millis(15)); // gate batch formed

    let mut healthy = Vec::new();
    for i in 0..HEALTHY / 2 {
        healthy.push((i, client.submit(Payload::Seq(vec![i])).unwrap()));
    }
    let cancelled = client.submit(Payload::Seq(vec![CANCEL_TOKEN])).unwrap();
    cancelled.cancel();
    let expired = client
        .submit_with(
            Payload::Seq(vec![EXPIRE_TOKEN]),
            SubmitOptions::default().with_deadline(Deadline::within(Duration::from_millis(5))),
        )
        .unwrap();
    let failing = client.submit(Payload::Seq(vec![FAIL_TOKEN])).unwrap();
    for i in HEALTHY / 2..HEALTHY {
        healthy.push((i, client.submit(Payload::Seq(vec![i])).unwrap()));
    }
    // Queue now holds HEALTHY + 3 requests; one more fits, the next is
    // rejected by admission.
    let extra_a = client.submit(Payload::Seq(vec![EXTRA_A]));
    let extra_b = client.submit(Payload::Seq(vec![EXTRA_B]));
    let rejections = [&extra_a, &extra_b]
        .iter()
        .filter(|r| matches!(r, Err(ServeError::QueueFull)))
        .count();
    assert_eq!(rejections, 1, "exactly one extra must overflow the sized queue");

    // Each failure mode surfaces as its own typed error…
    assert_eq!(cancelled.wait().unwrap_err(), ServeError::Cancelled);
    assert_eq!(expired.wait().unwrap_err(), ServeError::DeadlineExceeded);
    assert!(matches!(failing.wait().unwrap_err(), ServeError::EngineFailure(_)));
    // …while every healthy request completes with its own payload.
    for (i, t) in healthy {
        assert_eq!(t.wait().unwrap().output, Output::Tokens(vec![i]), "healthy {i}");
    }
    gate.wait().unwrap();
    for extra in [extra_a, extra_b] {
        if let Ok(t) = extra {
            t.wait().unwrap();
        }
    }

    let snap = c.shutdown_and_drain();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.expired, 1);
    assert_eq!(snap.engine_failures, 1);
    assert_eq!(snap.rejected, 1);
    assert_eq!(snap.completed as usize, HEALTHY + 2); // gate + one extra
    assert_eq!(snap.dropped_sends, 0);

    // FIFO: the healthy tokens must reach the engine in submission
    // order (cancelled/expired never appear — they were dropped at
    // batch formation).
    let order = log.lock().unwrap().clone();
    let healthy_order: Vec<usize> =
        order.iter().copied().filter(|&t| t < HEALTHY).collect();
    assert_eq!(healthy_order, (0..HEALTHY).collect::<Vec<_>>(), "FIFO broken: {order:?}");
    assert!(!order.contains(&CANCEL_TOKEN), "cancelled request reached the engine");
    assert!(!order.contains(&EXPIRE_TOKEN), "expired request reached the engine");
}
