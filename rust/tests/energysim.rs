//! Integration tests for the energy co-simulation subsystem: the
//! `EnergyBudget` admission mode through the full serving path, the
//! bit-determinism of the seeded `ci-energy` head-to-head, the paper's
//! exp-vs-INT8 joules ratio as observed through coordinator metrics,
//! and the `PlanPolicy::MinEnergy` ↔ co-sim agreement.

use dnateq::accel::{AccelConfig, EnergyModel};
use dnateq::coordinator::{
    AdmissionPolicy, BatcherConfig, Coordinator, CoordinatorConfig, EchoEngine, Payload,
    Priority, ServeError, SubmitOptions,
};
use dnateq::dnateq::{FrontIndex, FrontPoint, PlanPolicy, QuantConfig, Scheme};
use dnateq::energysim::{ci, run_ci_energy, CoSimEngine, CostModel};
use std::sync::Arc;
use std::time::Duration;

/// A co-simulating echo coordinator: every completed request records
/// the plan's per-item joules into the metrics power meter.
fn cosim_echo(plan: &QuantConfig, cfg: CoordinatorConfig) -> Coordinator {
    let cost = CostModel::from_config(plan, &EnergyModel::default(), &AccelConfig::default());
    Coordinator::start(Arc::new(CoSimEngine::new(Arc::new(EchoEngine { delay_us: 0 }), cost)), cfg)
}

#[test]
fn energy_budget_sheds_only_low_priority_and_never_deadlocks() {
    // A sub-physical envelope (1e-15 W) guarantees the rolling power is
    // "over budget" from the first completed request onward, so the
    // admission decision — not meter timing — is what the test observes.
    let c = cosim_echo(
        &ci::exp_plan(),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            min_workers: 1,
            max_workers: 1,
            queue_depth: 1024,
            admission: AdmissionPolicy::EnergyBudget,
            power_envelope_watts: Some(1e-15),
        },
    );
    let client = c.client();

    // Before any energy is recorded the meter reads 0 W ≤ envelope, so
    // even Low traffic is admitted.
    let first_low = client
        .submit_with(
            Payload::Seq(vec![1]),
            SubmitOptions::default().with_priority(Priority::Low),
        )
        .and_then(|t| t.wait());
    assert!(first_low.is_ok(), "cold-meter Low must be admitted: {first_low:?}");

    let mut low_shed = 0usize;
    let mut completed_ok = 1u64; // the cold-meter Low above
    for i in 0..30 {
        // A completed Normal request heats the 250 ms power window...
        let resp = client
            .submit_with(
                Payload::Seq(vec![i]),
                SubmitOptions::default().with_priority(Priority::Normal),
            )
            .and_then(|t| t.wait())
            .expect("Normal traffic is never energy-shed");
        assert!(resp.energy_j.unwrap() > 0.0, "co-sim engine attaches joules");
        completed_ok += 1;
        // ...so an immediately following Low submission must be shed,
        // and a High one must still get through.
        match client.submit_with(
            Payload::Seq(vec![i]),
            SubmitOptions::default().with_priority(Priority::Low),
        ) {
            Err(ServeError::QueueFull) => low_shed += 1,
            Ok(t) => {
                t.wait().expect("admitted Low completes");
                completed_ok += 1;
            }
            Err(e) => panic!("unexpected Low outcome: {e:?}"),
        }
        let resp = client
            .submit_with(
                Payload::Seq(vec![i]),
                SubmitOptions::default().with_priority(Priority::High),
            )
            .and_then(|t| t.wait())
            .expect("High traffic is never energy-shed");
        assert!(resp.energy_j.is_some());
        completed_ok += 1;
    }
    assert!(low_shed > 0, "an over-envelope meter must shed some Low traffic");

    // The drain path must terminate despite the shedding (no ticket is
    // left unresolved), and the metrics must agree with what happened.
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.completed, completed_ok);
    assert_eq!(snap.energy_shed, low_shed as u64);
    assert_eq!(snap.shed, 0, "energy shedding must not masquerade as queue shedding");
    assert_eq!(snap.energy_requests, completed_ok);
}

#[test]
fn energy_budget_without_envelope_admits_everything() {
    let c = cosim_echo(
        &ci::exp_plan(),
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            min_workers: 1,
            max_workers: 1,
            queue_depth: 256,
            admission: AdmissionPolicy::EnergyBudget,
            power_envelope_watts: None,
        },
    );
    let client = c.client();
    for i in 0..20 {
        client
            .submit_with(
                Payload::Seq(vec![i]),
                SubmitOptions::default().with_priority(Priority::Low),
            )
            .and_then(|t| t.wait())
            .expect("EnergyBudget without an envelope behaves like Block");
    }
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.completed, 20);
    assert_eq!(snap.energy_shed, 0);
}

#[test]
fn ci_energy_totals_are_bit_deterministic() {
    // Per-request joules are pure arithmetic over the plan and Block
    // admission completes every offered request, so two runs of the
    // seeded scenario must agree *exactly* — this is the property the
    // CI `energy-smoke` job asserts with jq across process boundaries.
    let a = run_ci_energy(60.0, 0.3);
    let b = run_ci_energy(60.0, 0.3);
    assert_eq!(a.exp.offered, b.exp.offered);
    assert_eq!(a.int8.offered, b.int8.offered);
    assert_eq!(a.exp.completed, a.exp.offered as u64, "Block admission completes all");
    assert_eq!(a.exp.energy_total_j, b.exp.energy_total_j);
    assert_eq!(a.int8.energy_total_j, b.int8.energy_total_j);
    assert_eq!(a.exp.j_per_request, b.exp.j_per_request);
    assert_eq!(a.int8.j_per_request, b.int8.j_per_request);
    assert_eq!(a.ratio(), b.ratio());
    assert_eq!(a.exp.energy_shed, 0);
}

#[test]
fn exp_plan_halves_int8_joules_through_the_coordinator() {
    // The paper's headline, measured where it matters: through the real
    // client → queue → batcher path, via the metrics gauges rather than
    // the cost model directly.
    let per_req = |plan: &QuantConfig| {
        let c = cosim_echo(plan, CoordinatorConfig::default());
        for i in 0..16 {
            c.submit_wait(Payload::Seq(vec![i])).unwrap();
        }
        let snap = c.shutdown_and_drain();
        assert_eq!(snap.energy_requests, 16);
        assert!(snap.energy_j_per_request > 0.0);
        snap.energy_j_per_request
    };
    let exp = per_req(&ci::exp_plan());
    let int8 = per_req(&ci::int8_plan());
    let ratio = exp / int8;
    assert!(
        ratio <= 0.5,
        "exp/int8 joules-per-request through the coordinator: {ratio:.4}"
    );
}

#[test]
fn min_energy_policy_selects_the_cosim_cheapest_plan() {
    // Build a front whose energy_j column is priced by the same
    // EnergyModel the co-sim engine uses; MinEnergy must pick the plan
    // the co-simulation would bill the fewest joules for.
    let em = EnergyModel::default();
    let accel = AccelConfig::default();
    let plans = [
        ci::ci_fc_plan(Scheme::Exp, 3),
        ci::ci_fc_plan(Scheme::Exp, 5),
        ci::ci_fc_plan(Scheme::Uniform, 8),
    ];
    let joules: Vec<f64> = plans
        .iter()
        .map(|p| CostModel::from_config(p, &em, &accel).joules_per_item())
        .collect();
    let index = FrontIndex {
        model: "ci-front".into(),
        thr_w: 0.05,
        points: plans
            .iter()
            .zip(&joules)
            .enumerate()
            .map(|(i, (plan, &j))| FrontPoint {
                version: (i + 1) as u32,
                checksum: plan.model.clone(),
                rmae: 0.01 * (i + 1) as f64,
                compression: 32.0 / (i + 3) as f64,
                avg_bits: (i + 3) as f64,
                energy_j: j,
                schemes: vec![plan.layers[0].scheme.name()],
            })
            .collect(),
    };
    let picked = index.select(PlanPolicy::MinEnergy).expect("non-empty front");
    let (argmin, &min_j) = joules
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    assert_eq!(picked.version, (argmin + 1) as u32);
    assert_eq!(picked.energy_j, min_j);
    // And the front's cheapest point really is cheaper than the INT8
    // anchor — the policy is selecting on a meaningful axis.
    assert!(min_j < *joules.last().unwrap());
}
