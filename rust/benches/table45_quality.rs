//! Tables IV & V data prep: quantizer-quality microbench — RMAE of
//! DNA-TEQ vs uniform at matched bitwidths on exponential populations,
//! and the wall-time of Algorithm 1 itself.
//!
//! `cargo bench --bench table45_quality`

use dnateq::dnateq::{search_base, ExpQuantParams, SearchOptions, UniformParams};
use dnateq::tensor::{SplitMix64, Tensor};
use dnateq::util::bench::{bench, black_box};

fn main() {
    let mut rng = SplitMix64::new(0x7AB1E);
    let t = Tensor::rand_signed_exponential(&[1 << 16], 3.0, &mut rng);
    println!("{:<8} {:>14} {:>14} {:>8}", "bits", "uniform RMAE", "dnateq RMAE", "ratio");
    for n in 3..=7u8 {
        let u = UniformParams::calibrate(&t, n).rmae(&t);
        let d = search_base(&t, n, &SearchOptions::default()).rmae;
        println!("{:<8} {:>14.4} {:>14.4} {:>8.2}", n, u, d, u / d);
    }
    println!();
    for n in [3u8, 5, 7] {
        println!(
            "{}",
            bench(&format!("Algorithm-1 base search (64k elems, {n}-bit)"), 600, || {
                black_box(search_base(&t, n, &SearchOptions::default()));
            })
            .summary()
        );
    }
    let p = ExpQuantParams::init_for_tensor(&t, 4);
    println!(
        "{}",
        bench("LogExpQuant roundtrip (64k elems)", 400, || {
            black_box(p.roundtrip(&t));
        })
        .summary()
    );
}
