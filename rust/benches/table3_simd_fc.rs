//! Table III: FC execution time, INT8 baseline vs DNA-TEQ counting
//! engine at 3/4 bits, sizes 1024/2048/4096.
//!
//! `cargo bench --bench table3_simd_fc`

use dnateq::dnateq::ExpQuantParams;
use dnateq::expdot::{CountingFc, Int8Fc};
use dnateq::tensor::{SplitMix64, Tensor};
use dnateq::util::bench::{bench, black_box};

fn main() {
    let mut rng = SplitMix64::new(0xF00D);
    println!("Table III bench — per-forward latency (batch 1)\n");
    for n in [1024usize, 2048, 4096] {
        let w = Tensor::rand_signed_exponential(&[n, n], 4.0, &mut rng);
        let x = Tensor::rand_signed_exponential(&[1, n], 1.0, &mut rng);
        let int8 = Int8Fc::new(&w, None);
        println!("{}", bench(&format!("FC({n},{n}) int8"), 900, || {
            black_box(int8.forward(&x));
        }).summary());
        for bits in [3u8, 4] {
            let wp = ExpQuantParams::init_for_tensor(&w, bits);
            let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: bits };
            ap.refit_scale_offset(&x);
            let fc = CountingFc::new(&w, wp, ap, None);
            println!("{}", bench(&format!("FC({n},{n}) dnateq {bits}-bit"), 900, || {
                black_box(fc.forward(&x));
            }).summary());
        }
        println!();
    }
}
