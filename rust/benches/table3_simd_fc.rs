//! Table III: FC execution time, INT8 baseline vs DNA-TEQ counting
//! engine at 3/4 bits, sizes 1024/2048/4096 — now with batch ∈ {1, 8, 32}
//! columns. Batch-1 rows run the GEMV loop; batched rows run the batched
//! engines (`forward_batch`), so the speedup from amortizing the weight
//! stream and quantization pass across the batch is directly visible.
//! Every row (including the forced-`[scalar]` twins) carries its SIMD
//! backend in the summary line and the JSON `backend` field, so
//! `BENCH_*.json` trajectories are attributable per backend.
//! Emits `reports/bench_table3_simd_fc.json` alongside the text summary.
//!
//! `cargo bench --bench table3_simd_fc`

use dnateq::artifact_path;
use dnateq::dnateq::ExpQuantParams;
use dnateq::expdot::{simd, CountingFc, Int8Fc, SimdBackend};
use dnateq::tensor::{SplitMix64, Tensor};
use dnateq::util::bench::{bench, black_box, write_json, BenchResult};

const BATCHES: [usize; 3] = [1, 8, 32];

fn main() {
    let mut rng = SplitMix64::new(0xF00D);
    let mut results: Vec<BenchResult> = Vec::new();
    let backend = simd::active_backend();
    println!(
        "Table III bench — latency per forward call (whole batch), batch ∈ {BATCHES:?} \
         (simd backend: {})\n",
        backend.name()
    );
    for n in [1024usize, 2048, 4096] {
        let w = Tensor::rand_signed_exponential(&[n, n], 4.0, &mut rng);
        let x_cal = Tensor::rand_signed_exponential(&[1, n], 1.0, &mut rng);
        let int8 = Int8Fc::new(&w, None);
        let counting: Vec<(u8, CountingFc)> = [3u8, 4]
            .into_iter()
            .map(|bits| {
                let wp = ExpQuantParams::init_for_tensor(&w, bits);
                let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: bits };
                ap.refit_scale_offset(&x_cal);
                (bits, CountingFc::new(&w, wp, ap, None))
            })
            .collect();
        // Forced-scalar twins on SIMD-capable hosts, so the dispatch win
        // is visible in one report (existing case names stay untouched
        // for baseline compatibility).
        let counting_scalar: Vec<(u8, CountingFc)> = if backend != SimdBackend::Scalar {
            [3u8, 4]
                .into_iter()
                .map(|bits| {
                    let wp = ExpQuantParams::init_for_tensor(&w, bits);
                    let mut ap =
                        ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: bits };
                    ap.refit_scale_offset(&x_cal);
                    let fc = CountingFc::new(&w, wp, ap, None).with_backend(SimdBackend::Scalar);
                    (bits, fc)
                })
                .collect()
        } else {
            Vec::new()
        };
        for batch in BATCHES {
            let x = Tensor::rand_signed_exponential(&[batch, n], 1.0, &mut rng);
            let r = bench(&format!("FC({n},{n}) int8 b={batch}"), 600, || {
                if batch == 1 {
                    black_box(int8.forward(&x));
                } else {
                    black_box(int8.forward_batch(&x));
                }
            })
            .with_backend(backend.name());
            println!("{}", r.summary());
            results.push(r);
            for (bits, fc) in &counting {
                let r = bench(&format!("FC({n},{n}) dnateq {bits}-bit b={batch}"), 600, || {
                    if batch == 1 {
                        black_box(fc.forward(&x));
                    } else {
                        black_box(fc.forward_batch(&x));
                    }
                })
                .with_backend(backend.name());
                println!("{}", r.summary());
                results.push(r);
            }
            for (bits, fc) in &counting_scalar {
                let name = format!("FC({n},{n}) dnateq {bits}-bit b={batch} [scalar]");
                let r = bench(&name, 600, || {
                    if batch == 1 {
                        black_box(fc.forward(&x));
                    } else {
                        black_box(fc.forward_batch(&x));
                    }
                })
                .with_backend(SimdBackend::Scalar.name());
                println!("{}", r.summary());
                results.push(r);
            }
        }
        println!();
    }
    let path = artifact_path("reports/bench_table3_simd_fc.json");
    match write_json(&path, &results) {
        Ok(()) => println!("JSON → {}", path.display()),
        Err(e) => eprintln!("JSON write failed: {e:#}"),
    }
}
