//! Fig. 10: dynamic energy of a counting step per bitwidth vs an INT8
//! MAC, plus the per-neuron post-processing overhead (§VI-D).
//!
//! `cargo bench --bench fig10_counting_energy`

use dnateq::accel::EnergyModel;

fn main() {
    let em = EnergyModel::default();
    println!("{:<12} {:>14} {:>22}", "op", "count step pJ", "post/neuron pJ (512 taps)");
    for n in 3..=7u8 {
        println!(
            "{:<12} {:>14.3} {:>22.2}",
            format!("dnateq-{n}b"),
            em.counting_step_pj(n),
            em.post_process_pj(n, 512.0)
        );
    }
    println!("{:<12} {:>14.3} {:>22}", "int8-mac", em.mac_int8_pj, "-");
}
