//! End-to-end serving bench: coordinator + rust engine, fp32 vs DNA-TEQ
//! backends (needs `make artifacts`; skips politely otherwise).
//!
//! `cargo bench --bench e2e_serving`

use dnateq::artifact_path;
use dnateq::coordinator::{AlexNetBackend, Coordinator, CoordinatorConfig, Payload};
use dnateq::dataset::ImageDataset;
use dnateq::nn::{AlexNetMini, WeightMap};
use std::sync::Arc;

fn main() {
    let Ok(w) = WeightMap::load_dir(artifact_path("models/alexnet_mini")) else {
        eprintln!("skipping: artifacts not built (`make artifacts`)");
        return;
    };
    let data = ImageDataset::load(artifact_path("data"), "eval").expect("eval data");
    for (label, n_requests) in [("warm", 32usize), ("measured", 192)] {
        let c = Coordinator::start(
            Arc::new(AlexNetBackend::fp32(
                AlexNetMini::from_weights(&w).unwrap(),
                "fp32",
            )),
            CoordinatorConfig::default(),
        );
        let mut rxs = Vec::new();
        for i in 0..n_requests {
            rxs.push(c.submit(Payload::Image(data.image(i % data.len()))).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let snap = c.shutdown();
        if label == "measured" {
            println!("e2e serving (engine-fp32): {}", snap.summary());
        }
    }
}
