//! End-to-end serving bench: coordinator + batched engines, driven
//! through the typed `InferenceClient` API.
//!
//! The headline comparison is the FC-dominated counting backend served
//! with batcher `max_batch ∈ {1, 8, 32}`: at `max_batch = 1` every
//! request streams the full weight store (batch-1 looping); larger
//! batches run the batched counting GEMM, so the throughput ratio is the
//! batching speedup end-to-end (queue + batcher + worker included). The
//! AlexNet engine backend is also driven (trained weights when
//! `make artifacts` has run, random weights otherwise). Emits
//! `reports/bench_e2e_serving.json` alongside the text summary.
//!
//! `cargo bench --bench e2e_serving`

use dnateq::artifact_path;
use dnateq::coordinator::{
    AlexNetBackend, BatcherConfig, Coordinator, CoordinatorConfig, CountingFcBackend, Engine,
    ModelRegistry, Payload,
};
use dnateq::dataset::ImageDataset;
use dnateq::dnateq::ExpQuantParams;
use dnateq::expdot::CountingFc;
use dnateq::nn::{AlexNetMini, WeightMap};
use dnateq::tensor::{SplitMix64, Tensor};
use dnateq::util::bench::{write_json, BenchResult};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Drive `n` requests through a fresh coordinator; returns per-request
/// wall time as a `BenchResult` so the run lands in the JSON report.
fn drive(
    label: &str,
    engine: Arc<dyn Engine>,
    max_batch: usize,
    data: &ImageDataset,
    n: usize,
) -> BenchResult {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
        min_workers: 2,
        max_workers: 2,
        queue_depth: 512,
        ..CoordinatorConfig::default()
    };
    let c = Coordinator::start(engine, cfg);
    let payloads: Vec<Payload> =
        (0..data.len().min(n)).map(|i| Payload::Image(data.image(i))).collect();
    let report = c.drive(&payloads, n).expect("serving drive");
    let per = report.per_request;
    let snap = c.shutdown_and_drain();
    assert_eq!(snap.failed_total(), 0, "healthy bench traffic must not fail");
    println!("{label:<28} {}", snap.summary());
    println!("{label:<28} load: {}", report.load.summary());
    BenchResult {
        name: label.to_string(),
        median: per,
        mean: per,
        mad: Duration::ZERO,
        iters: n as u64,
        backend: None,
    }
}

/// Multi-model mixed-traffic sweep: the registry serves the engine model
/// and the counting-FC model side by side through per-model typed
/// clients; requests interleave round-robin so both batchers fill under
/// concurrent load.
fn drive_registry(
    engine: Arc<AlexNetBackend>,
    counting: Arc<CountingFcBackend>,
    max_batch: usize,
    data: &ImageDataset,
    n: usize,
) -> BenchResult {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
        min_workers: 2,
        max_workers: 2,
        queue_depth: 512,
        ..CoordinatorConfig::default()
    };
    let registry = ModelRegistry::new();
    registry.register_swappable("alexnet_mini", engine, cfg).unwrap();
    registry.register("counting_fc", counting, cfg).unwrap();
    let clients =
        [registry.client("alexnet_mini").unwrap(), registry.client("counting_fc").unwrap()];
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(n);
    for i in 0..n {
        let client = &clients[i % clients.len()];
        tickets.push(client.submit(Payload::Image(data.image(i % data.len()))).unwrap());
    }
    for t in tickets {
        t.wait().unwrap();
    }
    let per = t0.elapsed() / n as u32;
    let snaps = registry.shutdown_and_drain();
    for (model, snap) in &snaps {
        println!("  registry/{model:<20} {}", snap.summary());
    }
    BenchResult {
        name: format!("registry mixed max_batch={max_batch}"),
        median: per,
        mean: per,
        mad: Duration::ZERO,
        iters: n as u64,
        backend: None,
    }
}

fn main() {
    let data = ImageDataset::load(artifact_path("data"), "eval")
        .unwrap_or_else(|_| ImageDataset::synthetic(64, 0xDA7A));
    let mut results: Vec<BenchResult> = Vec::new();

    // FC-dominated counting backend: [3072 → 1024] exponential-domain FC.
    let mut rng = SplitMix64::new(0xE2E);
    let inf = 3 * 32 * 32;
    let w = Tensor::rand_signed_exponential(&[1024, inf], 3.0, &mut rng);
    let x_cal = Tensor::rand_signed_exponential(&[1, inf], 1.0, &mut rng);
    let wp = ExpQuantParams::init_for_tensor(&w, 4);
    let mut ap = ExpQuantParams { base: wp.base, alpha: 1.0, beta: 0.0, n_bits: 4 };
    ap.refit_scale_offset(&x_cal);
    let counting = Arc::new(CountingFcBackend { fc: CountingFc::new(&w, wp, ap, None) });

    println!("counting-fc backend (3072→1024, 4-bit), 96 requests:");
    for max_batch in [1usize, 8, 32] {
        // Warm one small run, then measure.
        drive("  (warmup)", counting.clone(), max_batch, &data, 16);
        results.push(drive(
            &format!("counting-fc max_batch={max_batch}"),
            counting.clone(),
            max_batch,
            &data,
            96,
        ));
    }
    if let (Some(b1), Some(b32)) = (
        results.iter().find(|r| r.name.ends_with("max_batch=1")),
        results.iter().find(|r| r.name.ends_with("max_batch=32")),
    ) {
        println!(
            "batching speedup (max_batch 32 vs 1): {:.2}×\n",
            b1.median.as_secs_f64() / b32.median.as_secs_f64().max(1e-12)
        );
    }

    // CNN engine backend: trained weights when available.
    let model = match WeightMap::load_dir(artifact_path("models/alexnet_mini")) {
        Ok(wm) => AlexNetMini::from_weights(&wm).expect("artifact weights well-formed"),
        Err(_) => {
            eprintln!("artifacts not built (`make artifacts`); using random weights");
            AlexNetMini::random(0x41E)
        }
    };
    let engine = Arc::new(AlexNetBackend::fp32(model, "fp32"));
    println!("alexnet engine backend, 96 requests:");
    for max_batch in [1usize, 32] {
        drive("  (warmup)", engine.clone(), max_batch, &data, 16);
        results.push(drive(
            &format!("engine-fp32 max_batch={max_batch}"),
            engine.clone(),
            max_batch,
            &data,
            96,
        ));
    }

    // Multi-model registry: engine + counting models under interleaved
    // mixed traffic (the `serve --models` path, measured end to end).
    println!("registry mixed traffic (alexnet_mini + counting_fc), 96 requests:");
    for max_batch in [1usize, 8, 32] {
        drive_registry(engine.clone(), counting.clone(), max_batch, &data, 16); // warm-up
        results.push(drive_registry(engine.clone(), counting.clone(), max_batch, &data, 96));
    }

    let path = artifact_path("reports/bench_e2e_serving.json");
    match write_json(&path, &results) {
        Ok(()) => println!("JSON → {}", path.display()),
        Err(e) => eprintln!("JSON write failed: {e:#}"),
    }
}
