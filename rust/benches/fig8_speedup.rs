//! Figs. 8 & 9: accelerator simulation of the full-size workloads with
//! calibrated bitwidths (falls back to 5-bit uniform without configs).
//!
//! `cargo bench --bench fig8_speedup`

use dnateq::accel::{
    alexnet_shapes, assign_bits, geomean, resnet50_shapes, transformer_shapes, uniform_bits,
    AccelConfig, Comparison, EnergyModel,
};
use dnateq::artifact_path;
use dnateq::dnateq::QuantConfig;

fn main() {
    let cfg = AccelConfig::default();
    let em = EnergyModel::default();
    let mut speedups = Vec::new();
    let mut savings = Vec::new();
    println!("{:<14} {:>9} {:>9} {:>9}", "network", "avg bits", "speedup", "energy×");
    for (name, mini, shapes) in [
        ("alexnet", "alexnet_mini", alexnet_shapes()),
        ("resnet50", "resnet_mini", resnet50_shapes()),
        ("transformer", "transformer_mini", transformer_shapes(25)),
    ] {
        let bits = match QuantConfig::load_json(artifact_path(&format!("configs/{mini}.json"))) {
            // configs/<m>.json stores the full outcome; the config field
            // is nested — fall back to uniform if parsing fails.
            _ => match std::fs::read_to_string(artifact_path(&format!("configs/{mini}.json"))) {
                Ok(raw) => match dnateq::util::Json::parse(&raw)
                    .ok()
                    .and_then(|j| j.get("config").cloned())
                    .and_then(|c| QuantConfig::from_json(&c).ok())
                {
                    Some(c) => assign_bits(&shapes, &c, 5),
                    None => uniform_bits(&shapes, 5),
                },
                Err(_) => uniform_bits(&shapes, 5),
            },
        };
        let avg = bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64;
        let cmp = Comparison::run(&cfg, &em, &shapes, &bits);
        println!("{:<14} {:>9.2} {:>9.2} {:>9.2}", name, avg, cmp.speedup(), cmp.energy_savings());
        speedups.push(cmp.speedup());
        savings.push(cmp.energy_savings());
    }
    println!("{:<14} {:>9} {:>9.2} {:>9.2}", "geomean", "", geomean(&speedups), geomean(&savings));
}
