//! End-to-end serving driver (the EXPERIMENTS.md §E2E workload).
//!
//! Loads the trained AlexNet-mini, serves batched classification
//! requests through the coordinator's typed `InferenceClient` with
//! THREE engines — the rust f32 engine, the DNA-TEQ fake-quantized
//! engine, and the PJRT-compiled AOT artifact — and reports accuracy +
//! latency/throughput for each.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_classifier
//! ```

use anyhow::Result;
use dnateq::artifact_path;
use dnateq::coordinator::{
    AlexNetBackend, Coordinator, CoordinatorConfig, Engine, Output, Payload,
    PjrtClassifierBackend,
};
use dnateq::dataset::ImageDataset;
use dnateq::dnateq::CalibrationOptions;
use dnateq::nn::{AlexNetMini, WeightMap};
use dnateq::report::calibrate_or_load;
use std::sync::Arc;

fn drive(name: &str, engine: Arc<dyn Engine>, data: &ImageDataset, n: usize) -> Result<()> {
    let c = Coordinator::start(engine, CoordinatorConfig::default());
    let client = c.client();
    let mut tickets = Vec::new();
    for i in 0..n {
        let idx = i % data.len();
        tickets.push((idx, client.submit(Payload::Image(data.image(idx)))?));
    }
    let mut hits = 0usize;
    for (idx, ticket) in tickets {
        if let Output::ClassId(k) = ticket.wait()?.output {
            if k == data.labels[idx] {
                hits += 1;
            }
        }
    }
    let snap = c.shutdown_and_drain();
    println!("{name:<18} accuracy {:.4} | {}", hits as f64 / n as f64, snap.summary());
    Ok(())
}

fn main() -> Result<()> {
    let data = ImageDataset::load(artifact_path("data"), "eval")?;
    let n = 256;

    let w = WeightMap::load_dir(artifact_path("models/alexnet_mini"))?;
    drive(
        "engine-fp32",
        Arc::new(AlexNetBackend::fp32(AlexNetMini::from_weights(&w)?, "fp32")),
        &data,
        n,
    )?;

    let outcome = calibrate_or_load("alexnet_mini", false, &CalibrationOptions::default())?;
    println!(
        "  (DNA-TEQ config: avg {:.2} bits, compression {:.1}%)",
        outcome.config.avg_bitwidth(),
        outcome.config.compression_ratio() * 100.0
    );
    drive(
        "engine-dnateq",
        Arc::new(AlexNetBackend::quantized(
            AlexNetMini::from_weights(&w)?,
            &outcome.config,
            "dnateq",
        )),
        &data,
        n,
    )?;

    drive(
        "pjrt-aot",
        Arc::new(PjrtClassifierBackend::spawn(artifact_path("alexnet_fp32.hlo.txt"))?),
        &data,
        n,
    )?;
    Ok(())
}
