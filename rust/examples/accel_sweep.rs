//! Accelerator design-space sweep: regenerate Figs. 8–10 and explore the
//! bitwidth axis (ablation for the DESIGN.md §Perf discussion).
//!
//! ```bash
//! cargo run --release --example accel_sweep
//! ```

use dnateq::accel::{
    alexnet_shapes, geomean, resnet50_shapes, transformer_shapes, uniform_bits, AccelConfig,
    Comparison, EnergyModel,
};

fn main() {
    let cfg = AccelConfig::default();
    let em = EnergyModel::default();
    println!("Fixed-bitwidth sweep over the full-size workloads (Figs. 8/9 axes)\n");
    println!("{:<14} {:>5} {:>9} {:>9}", "network", "bits", "speedup", "energy×");
    for (name, shapes) in [
        ("alexnet", alexnet_shapes()),
        ("resnet50", resnet50_shapes()),
        ("transformer", transformer_shapes(25)),
    ] {
        for bits in 3..=7u8 {
            let cmp = Comparison::run(&cfg, &em, &shapes, &uniform_bits(&shapes, bits));
            let (speedup, savings) = (cmp.speedup(), cmp.energy_savings());
            println!("{name:<14} {bits:>5} {speedup:>9.2} {savings:>9.2}");
        }
        println!();
    }

    println!("Fig. 10 — counting-step dynamic energy (pJ):");
    for n in 3..=7u8 {
        println!("  {n}-bit: {:.3}", em.counting_step_pj(n));
    }
    println!("  INT8 MAC: {:.3}", em.mac_int8_pj);

    let s3: Vec<f64> = [alexnet_shapes(), resnet50_shapes(), transformer_shapes(25)]
        .iter()
        .map(|sh| Comparison::run(&cfg, &em, sh, &uniform_bits(sh, 4)).speedup())
        .collect();
    println!("\ngeomean speedup @4 bits: {:.2}", geomean(&s3));
}
