//! Quickstart: calibrate DNA-TEQ on a synthetic FC stack and print a
//! Table-V-style row — no artifacts required.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dnateq::dnateq::{
    calibrate_model, CalibrationInput, CalibrationOptions, LayerKind, LayerTensors,
};
use dnateq::tensor::{SplitMix64, Tensor};

fn main() {
    // 1. Synthesize a "model": six FC layers with exponential-ish weights
    //    and activation traces (the tensor population of §III-A).
    let mut rng = SplitMix64::new(7);
    let layers = (0..6)
        .map(|i| LayerTensors {
            name: format!("fc{i}"),
            kind: LayerKind::Fc,
            weights: Tensor::rand_signed_exponential(&[512 * 128], 4.0, &mut rng),
            acts: Tensor::rand_signed_exponential(&[1 << 14], 0.8, &mut rng),
            is_first: i == 0,
        })
        .collect();
    let input = CalibrationInput { model: "quickstart".into(), layers };

    // 2. A stand-in accuracy model: degrades smoothly with quantization
    //    error (real pipelines plug in quantized inference here — see
    //    `repro calibrate`).
    let eval = |cfg: &dnateq::dnateq::QuantConfig| 1.0 - cfg.accumulated_rmae() * 0.02;

    // 3. Run the Fig.-3 pipeline: per-layer base search + bitwidth sweep
    //    inside a network-level Thr_w controller.
    let report = calibrate_model(&input, 1.0, &CalibrationOptions::default(), eval);

    println!("DNA-TEQ quickstart — calibrated `{}`", report.config.model);
    println!(
        "{:<8} {:>6} {:>10} {:>12} {:>12} {:>8}",
        "layer", "bits", "base", "rmae(w)", "rmae(act)", "seed"
    );
    for l in &report.config.layers {
        println!(
            "{:<8} {:>6} {:>10.4} {:>12.5} {:>12.5} {:>8}",
            l.name,
            l.n_bits,
            l.base,
            l.weights.rmae,
            l.acts.rmae,
            if l.seeded_by_weights { "W" } else { "A" }
        );
    }
    println!(
        "\naccepted Thr_w {:.1}% | avg bitwidth {:.2} | compression vs INT8 {:.1}% | accuracy {:.4} (fp32 {:.4})",
        report.config.thr_w * 100.0,
        report.config.avg_bitwidth(),
        report.config.compression_ratio() * 100.0,
        report.accuracy,
        report.baseline_accuracy,
    );
}
