//! Domain example: calibrate the trained Transformer-mini end-to-end and
//! translate a few synthetic sentences with the quantized model —
//! reproducing the paper's headline Transformer result (≈3-bit tensors,
//! negligible score loss) on the mini substrate.
//!
//! ```bash
//! make artifacts && cargo run --release --example calibrate_transformer
//! ```

use anyhow::Result;
use dnateq::dataset::{translate, SeqDataset};
use dnateq::dnateq::CalibrationOptions;
use dnateq::nn::{ExecPlan, TransformerMini, WeightMap};
use dnateq::report::calibrate_or_load;
use dnateq::artifact_path;

fn main() -> Result<()> {
    let outcome = calibrate_or_load("transformer_mini", false, &CalibrationOptions::default())?;
    println!(
        "transformer_mini: thr_w {:.0}% | avg bits {:.2} | compression {:.1}% | token-acc {:.4} (fp32 {:.4})",
        outcome.config.thr_w * 100.0,
        outcome.config.avg_bitwidth(),
        outcome.config.compression_ratio() * 100.0,
        outcome.dnateq_accuracy,
        outcome.fp32_accuracy,
    );
    if let (Some(b), Some(fb)) = (outcome.dnateq_bleu, outcome.fp32_bleu) {
        println!("BLEU: fp32 {fb:.1} → dnateq {b:.1}");
    }

    let w = WeightMap::load_dir(artifact_path("models/transformer_mini"))?;
    let model = TransformerMini::from_weights(&w)?;
    let plan = ExecPlan::exp(&model, &outcome.config);
    let data = SeqDataset::synthetic(3, 99);
    for src in &data.src {
        let hyp = model.greedy_decode(src, src.len() + 4, &plan);
        let payload = &src[..src.len() - 1];
        println!(
            "src {:?}\n  → quantized decode {:?}\n  → reference        {:?}",
            payload,
            &hyp[1..],
            translate(payload)
        );
    }
    Ok(())
}
