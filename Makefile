# DNA-TEQ reproduction — build / test / bench entry points.
#
# Tier-1 gate: `make verify` (== cargo build --release && cargo test -q).

CARGO ?= cargo

.PHONY: all build test verify bench bench-gate lint clean pytest

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

verify: build test

bench:
	$(CARGO) bench --no-run
	$(CARGO) bench --bench table3_simd_fc
	$(CARGO) bench --bench e2e_serving

# CI bench-regression gate (same invocation the bench-smoke job runs).
bench-gate:
	$(CARGO) run --release --bin bench_gate -- \
		--out artifacts/reports/BENCH_ci.json --baseline ci/bench_baseline.json

lint:
	$(CARGO) fmt --check
	$(CARGO) clippy --all-targets -- -D warnings

pytest:
	python -m pytest python/tests -q

clean:
	$(CARGO) clean
